"""Tests for the beyond-paper extensions: PQ (+LPQ composition) and int4
packing."""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # no hypothesis on this container: see pyproject [test]
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import pack as PK
from repro.core import quant as Qz
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import FlatIndex
from repro.knn.pq import PQIndex


def test_pq_beats_memory_at_reasonable_recall():
    corpus, queries, metric = synthetic.load("product", 2000, 32)
    queries = queries[:32]
    gt = FlatIndex.build(corpus, metric=metric).search(queries, 10)[1]
    pq = PQIndex.build(corpus, m=64, metric=metric)   # 4 dims / subspace
    ids = pq.search(queries, 10)[1]
    rec = float(recall_at_k(gt, ids))
    assert rec > 0.6, rec                       # PQ at 64B/vec vs 1KB/vec
    assert pq.memory_bytes() < 0.2 * corpus.nbytes


def test_pq_lpq_composition_close_to_pq():
    """The paper's composition claim: int8 ADC tables barely change PQ."""
    corpus, queries, metric = synthetic.load("product", 2000, 32)
    queries = queries[:32]
    pq_fp = PQIndex.build(corpus, m=32, metric=metric)
    pq_q8 = PQIndex.build(corpus, m=32, metric=metric, lpq_tables=True)
    ids_fp = pq_fp.search(queries, 20)[1]
    ids_q8 = pq_q8.search(queries, 20)[1]
    overlap = float(recall_at_k(ids_fp, ids_q8))
    assert overlap > 0.9, overlap


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 64),
       half_d=st.integers(1, 32))
def test_int4_pack_roundtrip(seed, n, half_d):
    key = jax.random.PRNGKey(seed)
    codes = jax.random.randint(key, (n, half_d * 2), -8, 8, dtype=jnp.int8)
    packed = PK.pack_int4(codes)
    assert packed.shape == (n, half_d)
    np.testing.assert_array_equal(np.asarray(PK.unpack_int4(packed)),
                                  np.asarray(codes))


def test_int4_scores_match_int8_path():
    from repro.core import distances as D

    kq, kx = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.randint(kq, (4, 16), -8, 8, dtype=jnp.int8)
    x = jax.random.randint(kx, (50, 16), -8, 8, dtype=jnp.int8)
    want = np.asarray(D.qip_scores(q, x))
    got = np.asarray(PK.qip_scores_packed(q, PK.pack_int4(x)))
    np.testing.assert_array_equal(got, want)


def test_int4_end_to_end_recall():
    """B=4 quantization + packing: 8x memory vs fp32, usable recall."""
    corpus, queries, metric = synthetic.load("product", 2000, 32)
    queries = queries[:32]
    gt = FlatIndex.build(corpus, metric=metric).search(queries, 10)[1]

    params = Qz.learn_params(corpus, bits=4, scheme="gaussian", sigmas=3.0)
    codes = Qz.quantize(corpus, params)
    qcodes = Qz.quantize(queries, params)
    packed = PK.pack_int4(codes)
    assert packed.nbytes * 8 == corpus.nbytes  # 8x compression

    s = PK.qip_scores_packed(qcodes, packed).astype(jnp.float32)
    ids = jax.lax.top_k(s, 10)[1]
    rec = float(recall_at_k(gt, ids.astype(jnp.int32)))
    assert rec > 0.5, rec   # int4 trades recall for 2x over int8 (paper's B knob)
