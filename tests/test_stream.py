"""Mutable segmented index tests (DESIGN.md §10) plus the PR's satellite
regressions: StreamingStats zero-count guards, the engine-consolidated
top-k, CodeStore concat/append/remap helpers, and the quant-params
save/load round-trip."""

import dataclasses
import io

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import quant as Qz
from repro.core import stats as St
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import (
    MutableIndex,
    SearchParams,
    load_index,
    make_index,
    parse_factory,
)

K = 10
D = 24


@pytest.fixture(scope="module")
def corpus():
    c, _q, _m = synthetic.load("product", 600, 8)
    return np.asarray(c[:, :D])


@pytest.fixture(scope="module")
def extra():
    c, _q, _m = synthetic.load("product", 400, 8, key=jax.random.PRNGKey(3))
    return np.asarray(c[:, :D])


@pytest.fixture(scope="module")
def queries(corpus):
    rng = np.random.default_rng(0)
    rows = corpus[rng.choice(corpus.shape[0], 16, replace=False)]
    return (rows + rng.normal(size=rows.shape).astype(np.float32) * 0.004
            ).astype(np.float32)


def _map_ids(scratch_ids: np.ndarray, ext_ids: np.ndarray) -> np.ndarray:
    return np.where(scratch_ids >= 0, ext_ids[scratch_ids], -1)


# ==========================================================================
# satellite: StreamingStats zero-count / empty-batch guards
# ==========================================================================

class TestStreamingStatsGuards:
    def test_empty_batch_update_is_identity(self):
        ss = St.StreamingStats(4)
        ss.update(jnp.zeros((0, 4)))
        assert not np.isnan(np.asarray(ss.stats.mean)).any()
        assert not np.isnan(np.asarray(ss.stats.std)).any()
        x = jnp.ones((5, 4)) * 2.0
        ss.update(x)
        np.testing.assert_allclose(np.asarray(ss.stats.mean), 2.0)
        np.testing.assert_allclose(np.asarray(ss.stats.std), 0.0, atol=1e-6)

    def test_fresh_merge_no_nan(self):
        merged = St.merge_stats(St.empty_stats(3), St.empty_stats(3))
        assert not np.isnan(np.asarray(merged.mean)).any()
        assert not np.isnan(np.asarray(merged.std)).any()
        assert float(merged.count) == 0.0

    def test_merge_fresh_into_real_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 6))
        real = St.corpus_stats(x)
        for a, b in ((St.empty_stats(6), real), (real, St.empty_stats(6))):
            m = St.merge_stats(a, b)
            np.testing.assert_allclose(np.asarray(m.mean),
                                       np.asarray(real.mean), atol=1e-6)
            np.testing.assert_allclose(np.asarray(m.std),
                                       np.asarray(real.std), atol=1e-6)

    def test_garbage_moments_masked_when_count_zero(self):
        # a zero-count DimStats with NaN placeholders must not poison a merge
        bad = dataclasses.replace(
            St.empty_stats(3), mean=jnp.full((3,), jnp.nan),
            m2=jnp.full((3,), jnp.nan),
        )
        real = St.corpus_stats(jnp.ones((4, 3)))
        m = St.merge_stats(bad, real)
        assert not np.isnan(np.asarray(m.mean)).any()
        assert not np.isnan(np.asarray(m.std)).any()

    def test_streaming_equals_oneshot(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (100, 5))
        one = St.corpus_stats(x)
        ss = St.StreamingStats(5)
        ss.update(x[:0]).update(x[:37]).merge(
            St.StreamingStats(5).update(x[37:])
        )
        np.testing.assert_allclose(np.asarray(ss.stats.mean),
                                   np.asarray(one.mean), atol=1e-5)
        np.testing.assert_allclose(np.asarray(ss.stats.std),
                                   np.asarray(one.std), atol=1e-4)

    def test_drift_metric(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (200, 4))
        s = St.corpus_stats(x)
        assert St.calibration_drift(s, s) == pytest.approx(0.0, abs=1e-5)
        shifted = St.corpus_stats(x + 2.0)
        assert St.calibration_drift(shifted, s) == pytest.approx(2.0, abs=0.2)
        assert St.calibration_drift(St.empty_stats(4), s) == float("inf")


# ==========================================================================
# satellite: one top-k implementation (engine) + legacy shim
# ==========================================================================

class TestTopkConsolidation:
    def test_chunked_topk_matches_dense(self):
        q = jax.random.normal(jax.random.PRNGKey(0), (5, 8))
        c = jax.random.normal(jax.random.PRNGKey(1), (137, 8))
        ref_s, ref_i = jax.lax.top_k(q @ c.T, K)
        for chunk in (32, 137, 4096):
            s, i = engine.chunked_topk(q, c, K, _ip, chunk=chunk)
            np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))
            np.testing.assert_allclose(np.asarray(s), np.asarray(ref_s),
                                       rtol=1e-6)

    def test_remap_ids(self):
        id_map = jnp.asarray([7, 8, 9], jnp.int32)
        out = engine.remap_ids(jnp.asarray([[0, -1, 2]], jnp.int32), id_map)
        assert np.asarray(out).tolist() == [[7, -1, 9]]


def _ip(a, b):
    return a @ b.T


# ==========================================================================
# satellite: CodeStore concat / append
# ==========================================================================

class TestCodeStoreHelpers:
    def test_concat_dense_and_append(self, corpus):
        a, b = jnp.asarray(corpus[:200]), jnp.asarray(corpus[200:])
        whole = engine.CodeStore.dense(jnp.asarray(corpus))
        cat = engine.CodeStore.concat(
            [engine.CodeStore.dense(a), engine.CodeStore.dense(b)]
        )
        np.testing.assert_array_equal(np.asarray(cat.data),
                                      np.asarray(whole.data))
        app = engine.CodeStore.dense(a).append(b)
        np.testing.assert_array_equal(np.asarray(app.data),
                                      np.asarray(whole.data))
        assert cat.n == app.n == whole.n

    @pytest.mark.parametrize("bits,packed", [(8, False), (4, True)])
    def test_concat_append_quantized(self, corpus, bits, packed):
        from repro.knn.spec import QuantSpec

        spec = QuantSpec(bits=bits)
        whole = spec.build_store(jnp.asarray(corpus))
        half = spec.with_params(whole.params).build_store(
            jnp.asarray(corpus[:200])
        )
        app = half.append(jnp.asarray(corpus[200:]))
        np.testing.assert_array_equal(np.asarray(app.data),
                                      np.asarray(whole.data))
        assert app.packed == packed

    def test_concat_rejects_mixed_params(self, corpus):
        from repro.knn.spec import QuantSpec

        a = QuantSpec(bits=8).build_store(jnp.asarray(corpus[:200]))
        b = QuantSpec(bits=8).build_store(jnp.asarray(corpus[200:]))
        with pytest.raises(ValueError, match="quantization constants"):
            engine.CodeStore.concat([a, b])


# ==========================================================================
# satellite: quant-params round-trip -> bit-identical codes
# ==========================================================================

class TestQuantParamsRoundTrip:
    @pytest.mark.parametrize("factory", ["flat,lpq8@gaussian:3", "flat,lpq4"])
    def test_save_load_bit_identical(self, corpus, queries, factory, tmp_path):
        idx = make_index(factory, corpus)
        path = str(tmp_path / "idx.npz")
        idx.save(path)
        back = load_index(path)
        np.testing.assert_array_equal(np.asarray(idx.store.data),
                                      np.asarray(back.store.data))
        for field in ("lo", "hi", "zero"):
            np.testing.assert_array_equal(
                np.asarray(getattr(idx.params, field)),
                np.asarray(getattr(back.params, field)),
            )
        assert (idx.params.bits, idx.params.scheme) == (
            back.params.bits, back.params.scheme)
        # restored constants re-encode the corpus to the same codes
        q = back.store.params
        fresh = Qz.quantize(jnp.asarray(corpus), q)
        if back.store.packed:
            from repro.core import pack as PK

            fresh = PK.pack_int4(fresh)
        np.testing.assert_array_equal(np.asarray(fresh),
                                      np.asarray(back.store.data))
        a, b = idx.search(queries, K), back.search(queries, K)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))

    def test_dimstats_to_params_deterministic(self, corpus):
        stats = St.corpus_stats(jnp.asarray(corpus))
        p1 = Qz.params_from_stats(stats, bits=4)
        p2 = Qz.learn_params(jnp.asarray(corpus), bits=4)
        for field in ("lo", "hi", "zero"):
            np.testing.assert_array_equal(np.asarray(getattr(p1, field)),
                                          np.asarray(getattr(p2, field)))


# ==========================================================================
# stream factory grammar
# ==========================================================================

class TestStreamSpec:
    def test_parse_fields(self):
        spec = parse_factory("stream(ivf256,lpq8,l2)+r32")
        assert spec.kind == "stream"
        assert spec.metric == "l2"
        assert spec.params["inner"] == "ivf256,lpq8,l2"
        assert spec.rerank_bits == 32

    def test_inner_rerank_lifted(self):
        spec = parse_factory("stream(flat,lpq4+r8)")
        assert spec.rerank_bits == 8
        assert "r8" not in spec.params["inner"]

    def test_requires_inner(self):
        with pytest.raises(ValueError, match="inner"):
            from repro.knn import IndexSpec

            IndexSpec(kind="stream")


# ==========================================================================
# tentpole: MutableIndex lifecycle
# ==========================================================================

class TestMutableIndex:
    def test_fresh_build_bit_parity_with_inner(self, corpus, queries):
        idx = make_index("stream(flat,lpq4)", corpus)
        ref = make_index("flat,lpq4", corpus)
        a, b = idx.search(queries, K), ref.search(queries, K)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_allclose(np.asarray(a.scores),
                                   np.asarray(b.scores))

    def test_upsert_visible_delete_gone(self, corpus, queries):
        idx = make_index("stream(flat,lpq8)", corpus, seal_threshold=128)
        probe = (queries[:1] * 0.0 + 0.09).astype(np.float32)
        idx.upsert([9999], probe)                   # an exact-match row
        res = idx.search(probe, 1)
        assert int(res.ids[0, 0]) == 9999
        idx.delete([9999])
        res = idx.search(probe, K)
        assert 9999 not in np.asarray(res.ids)

    def test_upsert_replaces(self, corpus):
        idx = make_index("stream(flat,lpq8)", corpus, seal_threshold=64)
        probe = np.full((1, D), 0.09, np.float32)
        idx.upsert([5], probe)                      # replace a sealed row
        res = idx.search(probe, 1)
        assert int(res.ids[0, 0]) == 5
        assert idx.n == corpus.shape[0]             # replaced, not added
        ids_l, vecs_l = idx.live_items()
        row = vecs_l[ids_l.tolist().index(5)]
        np.testing.assert_allclose(row, probe[0])

    def test_deleted_never_in_results_multisegment(self, corpus, extra,
                                                   queries):
        idx = make_index("stream(flat,lpq4)", corpus, seal_threshold=100)
        idx.upsert(np.arange(1000, 1000 + extra.shape[0]), extra)
        dead = np.arange(0, 600, 2)
        idx.delete(dead)
        ids = np.asarray(idx.search(queries, K).ids)
        assert not (set(ids.ravel().tolist()) & set(dead.tolist()))
        assert idx.stats()["tombstones"] > 0

    def test_exact_parity_after_churn_and_full_compaction(
        self, corpus, extra, queries
    ):
        """The acceptance criterion: N upserts + M deletes + full
        compaction == a from-scratch flat,lpq4 build on the surviving
        rows, bit for bit."""
        idx = make_index("stream(flat,lpq4)", corpus, seal_threshold=150)
        idx.upsert(np.arange(1000, 1300), extra[:300])       # N upserts
        idx.delete(np.arange(0, 600, 3))                     # M deletes
        idx.upsert(np.arange(50, 80), extra[300:330])        # replacements
        idx.delete([1000, 1001, 1299])
        idx.compact(full=True)
        assert idx.stats()["segments"] == 1

        ext_ids, vecs = idx.live_items()
        scratch = make_index("flat,lpq4", vecs)
        a = idx.search(queries, K)
        b = scratch.search(queries, K)
        np.testing.assert_array_equal(
            np.asarray(a.ids), _map_ids(np.asarray(b.ids), ext_ids)
        )
        np.testing.assert_allclose(np.asarray(a.scores),
                                   np.asarray(b.scores))

    def test_multisegment_recall(self, corpus, extra, queries):
        idx = make_index("stream(flat,lpq8)", corpus, seal_threshold=100,
                         auto_compact=False)
        idx.upsert(np.arange(1000, 1000 + extra.shape[0]), extra)
        ext_ids, vecs = idx.live_items()
        gt = _map_ids(
            np.asarray(make_index("flat", vecs).search(queries, K).ids),
            ext_ids,
        )
        ids = np.asarray(idx.search(queries, K).ids)
        assert float(recall_at_k(gt, ids)) > 0.9

    def test_auto_compaction_bounds_segments(self, corpus, extra):
        idx = make_index("stream(flat,lpq8)", corpus, seal_threshold=50,
                         max_segments=3)
        for i in range(8):
            idx.upsert(np.arange(2000 + i * 50, 2050 + i * 50),
                       extra[i * 50 : (i + 1) * 50])
        st = idx.stats()
        assert st["segments"] <= 4                  # bound + in-flight seal
        assert st["compactions"] >= 1

    def test_searcher_snapshot_and_rerank(self, corpus, extra, queries):
        idx = make_index("stream(flat,lpq4)+r32", corpus, seal_threshold=100)
        s = idx.searcher(K, batch_sizes=(8, 16))
        res1 = s(queries)
        assert res1.stats["reranked"] > 0           # +r32 default depth
        assert res1.stats["memtable_rows"] == 0
        idx.upsert(np.arange(1000, 1050), extra[:50])
        res2 = s(queries)                           # snapshot: still old view
        assert res2.stats["memtable_rows"] == 0
        s2 = idx.searcher(K, batch_sizes=(8, 16))   # re-plan sees the rows
        assert s2(queries).stats["memtable_rows"] == 50
        # depth override through the Searcher's rerank= argument
        deep = idx.searcher(K, rerank=64)(queries)
        assert deep.stats["reranked"] >= 64

    def test_save_load_roundtrip_with_tombstones_and_memtable(
        self, corpus, extra, queries, tmp_path
    ):
        idx = make_index("stream(ivf8,lpq8)+r32", corpus, seal_threshold=200,
                         kmeans_iters=2)
        idx.upsert(np.arange(1000, 1250), extra[:250])
        idx.delete(np.arange(0, 100))
        idx.upsert([3000], extra[250:251])          # leave a memtable row
        path = str(tmp_path / "stream.npz")
        idx.save(path)
        back = load_index(path)
        assert back.kind == "stream"
        assert back.n == idx.n
        assert back.memory_bytes() == idx.memory_bytes()
        a, b = idx.search(queries, K), back.search(queries, K)
        np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
        np.testing.assert_allclose(np.asarray(a.scores),
                                   np.asarray(b.scores), rtol=1e-6)
        st_a, st_b = idx.stats(), back.stats()
        for key in ("segments", "tombstones", "live", "memtable_rows"):
            assert st_a[key] == st_b[key], key

    def test_drift_recalibration_recovers_recall(self, corpus):
        """The acceptance drift scenario (bench_stream's measured arm):
        stale-constant compaction loses recall, recalibrating compaction
        recovers it."""
        rng = np.random.default_rng(7)
        n = corpus.shape[0]
        wide = corpus[rng.permutation(n)] + 0.4
        bulk = np.concatenate([corpus, wide]).astype(np.float32)
        fresh = (corpus[rng.permutation(n)][: n // 2] * 0.97).astype(
            np.float32)

        def build():
            idx = make_index("stream(flat,lpq4,l2)+r32", bulk,
                             seal_threshold=10 ** 9, auto_compact=False)
            idx.delete(np.arange(n, 2 * n))
            idx.upsert(np.arange(2 * n, 2 * n + fresh.shape[0]), fresh)
            idx.seal()
            return idx

        probe_idx = build()
        assert probe_idx.stats()["max_drift"] > probe_idx.policy.drift_threshold
        ext_ids, vecs = probe_idx.live_items()
        rows = vecs[rng.choice(vecs.shape[0], 48, replace=False)]
        qs = (rows + rng.normal(size=rows.shape).astype(np.float32) * 0.005
              ).astype(np.float32)
        gt = _map_ids(
            np.asarray(make_index("flat,l2", vecs).search(qs, K).ids), ext_ids
        )

        stale = build()
        stale.compact(full=True, recalibrate=False)
        r_stale = float(recall_at_k(gt, np.asarray(
            stale.searcher(K)(qs).ids)))
        recal = build()
        recal.compact(full=True)
        assert recal.counters["recalibrations"] == 1
        r_recal = float(recall_at_k(gt, np.asarray(
            recal.searcher(K)(qs).ids)))
        assert r_recal > r_stale + 0.1, (r_stale, r_recal)
        assert r_recal > 0.9

    def test_empty_and_error_paths(self, corpus):
        idx = make_index("stream(flat,lpq8)", corpus[:0])
        assert idx.n == 0
        res = idx.search(np.zeros((2, D), np.float32), 3)
        assert np.asarray(res.ids).tolist() == [[-1] * 3] * 2
        with pytest.raises(ValueError, match="ids"):
            idx.upsert([-1], np.zeros((1, D), np.float32))
        with pytest.raises(ValueError, match="duplicate"):
            idx.upsert([1, 1], np.zeros((2, D), np.float32))
        with pytest.raises(ValueError):
            idx.upsert([1], np.zeros((1, D + 1), np.float32))
        assert idx.delete([42]) == 0
        from repro.dist.placement import Placement
        with pytest.raises(ValueError, match="whole segments"):
            idx.plan(3, placement=Placement.rows(10, 1))

    def test_hnsw_inner_kind(self, corpus, queries):
        idx = make_index("stream(hnsw8,lpq8)", corpus, seal_threshold=300,
                         ef_construction=40)
        idx.upsert(np.arange(1000, 1100),
                   (corpus[:100] * 0.99).astype(np.float32))
        res = idx.search(queries, K, SearchParams(ef_search=60))
        assert res.ids.shape == (queries.shape[0], K)
        assert res.stats["kind"] == "stream"
        assert (np.asarray(res.ids) >= -1).all()
