"""KNN substrate tests: flat / IVF / HNSW / NGT-equivalent correctness,
chunked-topk equivalence, distributed top-k merge, and graph utilities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import (
    FlatIndex,
    GraphIndex,
    HNSWIndex,
    IVFIndex,
    knn_graph,
    merge_topk,
    radius_graph,
)
from repro.engine import distributed_topk


@pytest.fixture(scope="module")
def corpus_queries():
    corpus, queries, metric = synthetic.load("product", 2000, 32)
    return corpus, queries[:32], metric


def test_flat_chunked_equals_full(corpus_queries):
    corpus, queries, metric = corpus_queries
    idx = FlatIndex.build(corpus, metric=metric)
    _s1, i1 = idx.search(queries, 10)
    _s2, i2 = idx.search(queries, 10, chunk=256)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_flat_quantized_recall(corpus_queries):
    corpus, queries, metric = corpus_queries
    gt = FlatIndex.build(corpus, metric=metric).search(queries, 10)[1]
    q8 = FlatIndex.build(corpus, metric=metric, quantized=True, sigmas=3.0)
    ids = q8.search(queries, 10)[1]
    assert float(recall_at_k(gt, ids)) > 0.9
    assert q8.memory_bytes() < 0.3 * FlatIndex.build(corpus, metric=metric).memory_bytes()


def test_ivf_nprobe_monotone(corpus_queries):
    corpus, queries, metric = corpus_queries
    gt = FlatIndex.build(corpus, metric=metric).search(queries, 10)[1]
    ivf = IVFIndex.build(corpus, nlist=16, metric=metric)
    recalls = []
    for nprobe in (1, 4, 16):
        ids = ivf.search(queries, 10, nprobe=nprobe)[1]
        recalls.append(float(recall_at_k(gt, ids)))
    assert recalls[-1] >= recalls[0]
    assert recalls[-1] > 0.95  # nprobe = nlist == exhaustive


def test_hnsw_recall(corpus_queries):
    corpus, queries, metric = corpus_queries
    gt = FlatIndex.build(corpus, metric=metric).search(queries, 10)[1]
    h = HNSWIndex.build(corpus, m=16, ef_construction=120, metric=metric,
                        batch_size=256)
    r_lo = float(recall_at_k(gt, h.search(queries, 10, ef_search=80)[1]))
    r_hi = float(recall_at_k(gt, h.search(queries, 10, ef_search=160)[1]))
    assert r_hi > 0.9, r_hi
    assert r_hi >= r_lo - 1e-6       # paper Fig 2: recall rises with EFS


def test_graph_index_search(corpus_queries):
    # NGT-equivalent: non-hierarchical graph + seed entries; recall trails
    # HNSW on this deliberately harsh reduced setting (k=10, 2k rows,
    # 257-d) — the paper's Table 3 also reports NGT below FAISS/HNSW.
    corpus, queries, metric = corpus_queries
    gt = FlatIndex.build(corpus, metric=metric).search(queries, 10)[1]
    g = GraphIndex.build(corpus, degree=32, metric=metric, n_seeds=64)
    ids = g.search(queries, 10, ef_search=160)[1]
    assert float(recall_at_k(gt, ids)) > 0.65


def test_merge_topk():
    sa = jnp.array([[3.0, 1.0]])
    ia = jnp.array([[30, 10]], jnp.int32)
    sb = jnp.array([[2.0, 0.5]])
    ib = jnp.array([[20, 5]], jnp.int32)
    s, i = merge_topk(sa, ia, sb, ib, 3)
    np.testing.assert_array_equal(np.asarray(i)[0], [30, 20, 10])


def test_distributed_topk_matches_global():
    """shard_map distributed top-k == single-host top-k."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import shard_map

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    corpus = jax.random.normal(jax.random.PRNGKey(0), (64 * n_dev, 16))
    queries = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    k = 8

    gt = jax.lax.top_k(queries @ corpus.T, k)[1]

    def local(q, shard, idx):
        s = q @ shard.T
        ls, li = jax.lax.top_k(s, k)
        return distributed_topk(ls, li.astype(jnp.int32), k, ("data",),
                                  idx[0] * shard.shape[0])

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P("data", None), P("data")),
        out_specs=(P(), P()),
        check_vma=False,
    )
    _s, ids = fn(queries, corpus, jnp.arange(n_dev, dtype=jnp.int32))
    np.testing.assert_array_equal(np.sort(np.asarray(ids)), np.sort(np.asarray(gt)))


def test_knn_graph_quantized_close_to_exact():
    pts = jax.random.normal(jax.random.PRNGKey(0), (300, 8))
    g_fp = np.asarray(knn_graph(pts, 8, metric="l2"))
    g_q8 = np.asarray(knn_graph(pts, 8, metric="l2", quantized=True))
    overlap = np.mean([
        len(set(a) & set(b)) / 8 for a, b in zip(g_fp, g_q8)
    ])
    assert overlap > 0.85


def test_radius_graph_respects_cutoff():
    pts = jax.random.normal(jax.random.PRNGKey(0), (64, 3)) * 2
    senders, receivers, mask = radius_graph(pts, cutoff=1.5, max_neighbors=8)
    pts_np = np.asarray(pts)
    s, r, m = np.asarray(senders), np.asarray(receivers), np.asarray(mask)
    d = np.linalg.norm(pts_np[s[m]] - pts_np[r[m]], axis=-1)
    assert (d <= 1.5 + 1e-4).all()
    assert (s[m] != r[m]).all()
