"""Unified index API: one parametrized suite over the whole registry.

Every registered kind must round-trip build -> search -> save/load through
the same call shape, honor QuantSpec, and return SearchResult with
consistent shapes/dtypes; plus factory-string parse/round-trip cases.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as Qz
from repro.knn import (
    IndexSpec,
    QuantSpec,
    SearchParams,
    SearchResult,
    kinds,
    load_index,
    make_index,
    parse_factory,
)

K = 10

# per-kind factory string (int8 arm) + build overrides kept small for CI
CASES = {
    "flat": ("flat,lpq8@gaussian:3", {}),
    "ivf": ("ivf8,lpq8@gaussian:3", {"kmeans_iters": 4}),
    "hnsw": ("hnsw8,lpq8@gaussian:3", {"ef_construction": 40, "batch_size": 128}),
    "graph": ("graph16,lpq8@gaussian:3", {"n_seeds": 16}),
    "pq": ("pq16+lpq", {"kmeans_iters": 4}),
    "stream": ("stream(flat,lpq8@gaussian:3)", {"seal_threshold": 128}),
    "cascade": ("cascade(flat,lpq8@gaussian:3|r32)", {}),
}

FP32_CASES = {
    "flat": "flat",
    "ivf": "ivf8",
    "hnsw": "hnsw8",
    "graph": "graph16",
    "pq": "pq16",
    "stream": "stream(flat)",
    "cascade": "cascade(flat|r32)",
}


@pytest.fixture(scope="module")
def corpus_queries():
    corpus = jax.random.normal(jax.random.PRNGKey(0), (512, 32)) * 0.05
    queries = jax.random.normal(jax.random.PRNGKey(1), (8, 32)) * 0.05
    return corpus, queries


@pytest.fixture(scope="module")
def built(corpus_queries):
    corpus, _q = corpus_queries
    return {
        kind: make_index(factory, corpus, key=jax.random.PRNGKey(0), **over)
        for kind, (factory, over) in CASES.items()
    }


def test_registry_covers_all_cases():
    assert set(kinds()) == set(CASES) == set(FP32_CASES)


@pytest.mark.parametrize("kind", sorted(CASES))
def test_same_call_shape_everywhere(kind, corpus_queries, built):
    """The acceptance property: one SearchParams drives every kind."""
    _corpus, queries = corpus_queries
    sp = SearchParams(nprobe=8, ef_search=40, chunk=256)
    res = built[kind].search(queries, K, sp)
    assert isinstance(res, SearchResult)
    assert res.scores.shape == (queries.shape[0], K)
    assert res.ids.shape == (queries.shape[0], K)
    assert res.scores.dtype == jnp.float32
    assert res.ids.dtype == jnp.int32
    assert res.stats["kind"] == kind
    ids = np.asarray(res.ids)
    assert ids.min() >= -1 and ids.max() < 512
    # legacy pair protocol
    scores, ids2 = res
    np.testing.assert_array_equal(np.asarray(ids2), np.asarray(res.ids))
    np.testing.assert_array_equal(np.asarray(res[1]), np.asarray(res.ids))


@pytest.mark.parametrize("kind", sorted(CASES))
def test_quant_spec_honored(kind, corpus_queries, built):
    """The int8 arm must actually be smaller than the fp32 arm and (for
    scalar-quantized kinds) hold int8 codes from the shared quant path."""
    corpus, _q = corpus_queries
    fp = make_index(FP32_CASES[kind], corpus, key=jax.random.PRNGKey(0),
                    **CASES[kind][1])
    q8 = built[kind]
    if kind == "pq":  # lpq composes on the ADC tables, not the 1B codes
        assert q8.lpq_tables and not fp.lpq_tables
        return
    assert q8.memory_bytes() < fp.memory_bytes()
    if kind == "cascade":  # quant rides on the head; stages add stores
        assert q8.head.params is not None and q8.head.params.bits == 8
        assert q8.head.codes.dtype == jnp.int8
        return
    assert q8.params is not None and q8.params.bits == 8
    payload = q8.codes if kind == "flat" else q8.data
    assert payload.dtype == jnp.int8


@pytest.mark.parametrize("kind", sorted(CASES))
def test_save_load_roundtrip(kind, corpus_queries, built, tmp_path):
    _corpus, queries = corpus_queries
    idx = built[kind]
    path = str(tmp_path / f"{kind}.npz")
    idx.save(path)
    restored = load_index(path)
    assert restored.kind == kind
    sp = SearchParams(nprobe=8, ef_search=40)
    a = idx.search(queries, K, sp)
    b = restored.search(queries, K, sp)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=1e-6)
    assert restored.memory_bytes() == idx.memory_bytes()


def test_shared_quant_params_across_kinds(corpus_queries):
    """Learn Eq. 1 constants once, share them across index components."""
    corpus, queries = corpus_queries
    params = Qz.learn_params(corpus, bits=8, scheme="gaussian", sigmas=3.0)
    quant = QuantSpec(bits=8, scheme="gaussian", sigmas=3.0, params=params)
    flat = make_index(IndexSpec(kind="flat", quant=quant), corpus)
    ivf = make_index(IndexSpec(kind="ivf", quant=quant,
                               params={"nlist": 8}), corpus)
    assert flat.params is params and ivf.params is params
    np.testing.assert_array_equal(np.asarray(flat.codes), np.asarray(ivf.data))


def test_factory_parse_fields():
    spec = parse_factory("ivf256,lpq8@global_minmax:2.5,l2")
    assert spec.kind == "ivf"
    assert spec.params["nlist"] == 256
    assert spec.metric == "l2"
    assert spec.quant == QuantSpec(bits=8, scheme="global_minmax", sigmas=2.5)

    spec = parse_factory("pq64+lpq")
    assert spec.kind == "pq"
    assert spec.params == {"m": 64, "lpq_tables": True}
    assert spec.quant is None

    assert parse_factory("flat").quant is None
    assert parse_factory("hnsw32,lpq4").quant.bits == 4
    assert parse_factory("hnsw32").params["m"] == 32


@pytest.mark.parametrize(
    "factory",
    ["flat", "flat,lpq8@gaussian:3", "ivf256,lpq8", "hnsw32,lpq8",
     "pq64+lpq", "pq16x4", "pq16x4+lpq", "pq16x4,lpq8,l2", "pq64x8",
     "graph24,lpq8@global_absmax", "flat,lpq4,angular",
     "stream(flat,lpq4)", "stream(ivf256,lpq8)+r32",
     "stream(pq16x4,lpq8)+r32",
     "stream(hnsw32,lpq8@gaussian:3,l2)+r8",
     "cascade(flat,lpq4|r32)", "cascade(pq16x4|lpq8|r32)",
     "stream(cascade(flat,lpq8|r32))", "ivf64,lpq8,regions"],
)
def test_factory_string_roundtrip(factory):
    spec = parse_factory(factory)
    again = parse_factory(spec.to_factory())
    assert dataclasses.asdict(again) == dataclasses.asdict(spec)


@pytest.mark.parametrize(
    "bad", ["", "lpq8", "flat,bogus", "flat9", "ivf,nope", "flat,lpq8,lpq4",
            "ivf16,hnsw8", "flat,lpq8@nosuchscheme", "pq8,lpq4",
            "pq8,lpq8@absmax", "flat,l2,ip", "stream", "stream()",
            "stream(stream(flat))", "stream(bogus)+r32",
            "stream(flat,lpq4+r8)+r32", "stream(flat)+r16",
            "pq16x3", "pq16x12", "pq16x0", "flatx4", "ivf8x4"],
)
def test_factory_rejects_garbage(bad):
    with pytest.raises((ValueError, KeyError)):
        parse_factory(bad)


def test_pq_codeword_width_error_names_allowed_set():
    """pq16x3 must fail with a pointed error naming {4, 8}, not a
    generic cannot-parse fallthrough."""
    for bad in ("pq16x3", "pq16x12"):
        with pytest.raises(ValueError, match=r"one of \(4, 8\)"):
            parse_factory(bad)
    with pytest.raises(ValueError, match="only composes with pq"):
        parse_factory("flatx4")


def test_make_index_metric_override(corpus_queries):
    """metric= is a default for factory strings (fragment wins) and an
    explicit override for IndexSpec inputs."""
    corpus, _q = corpus_queries
    assert make_index("flat", corpus, metric="l2").metric == "l2"
    assert make_index("flat,angular", corpus, metric="l2").metric == "angular"
    assert make_index(IndexSpec(kind="flat"), corpus, metric="l2").metric == "l2"


def test_search_result_is_a_pytree(corpus_queries, built):
    """jitted callers could return the old (scores, ids) tuple; the
    SearchResult replacement must stay a valid jax type."""
    _corpus, queries = corpus_queries
    idx = built["flat"]
    res = jax.jit(lambda q: idx.search(q, K))(queries)
    assert isinstance(res, SearchResult)
    assert res.stats["kind"] == "flat"
    eager = idx.search(queries, K)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(eager.ids))


def test_legacy_params_kwarg_requires_quantized_flag(corpus_queries):
    """Pre-unification semantics: params= without quantized=True builds
    fp32 (params was only read when quantized was set)."""
    from repro.knn import FlatIndex

    corpus, _q = corpus_queries
    learned = Qz.learn_params(corpus, bits=8, scheme="gaussian", sigmas=3.0)
    idx = FlatIndex.build(corpus, params=learned)
    assert not idx.quantized and idx.codes is None


def test_quantized_beats_random_recall(corpus_queries, built):
    """Sanity: every int8 index returns mostly true neighbors on an easy
    narrow-band corpus (exact-scan ground truth)."""
    corpus, queries = corpus_queries
    gt = np.asarray(make_index("flat", corpus).search(queries, K).ids)
    sp = SearchParams(nprobe=8, ef_search=80)
    for kind, idx in built.items():
        ids = np.asarray(idx.search(queries, K, sp).ids)
        overlap = np.mean([
            len(set(a) & set(b)) / K for a, b in zip(gt, ids)
        ])
        assert overlap > 0.5, (kind, overlap)
