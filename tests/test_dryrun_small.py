"""Dry-run machinery sanity on the host device count (the 512-device
production sweep runs via ``python -m repro.launch.dryrun``; here we
verify the pieces — mesh construction, sharding rules, collective-byte
parsing, divisibility invariants — without touching XLA_FLAGS)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, cells, get
from repro.dist import sharding as SH
from repro.launch.mesh import make_host_mesh


def test_production_mesh_requires_devices():
    from repro.launch.mesh import make_production_mesh

    if len(jax.devices()) < 256:
        with pytest.raises(RuntimeError, match="XLA_FLAGS"):
            make_production_mesh()


def test_host_mesh():
    mesh = make_host_mesh()
    assert set(mesh.axis_names) == {"data", "model"}


def test_collective_byte_parser():
    from repro.launch.dryrun import collective_bytes  # safe: sets XLA_FLAGS

    hlo = """
      %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
      %ag.1 = (bf16[64]{0}, bf16[64]{0}) all-gather(bf16[32]{0} %y, bf16[32]{0} %z)
      %nothing = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)
    """
    out = collective_bytes(hlo)
    assert out["bytes"]["all-reduce"] == 128 * 256 * 4
    assert out["bytes"]["all-gather"] == 64 * 2 * 2
    assert out["counts"]["all-reduce"] == 1
    assert out["total_bytes"] == 128 * 256 * 4 + 256


def test_lm_tp_divisibility():
    """Every LM arch's sharded dims divide the 16-way model axis."""
    for arch in ASSIGNED:
        mod = get(arch)
        if mod.FAMILY != "lm":
            continue
        cfg = mod.config()
        assert cfg.padded_vocab % 16 == 0, arch
        assert (cfg.n_heads * cfg.head_dim) % 16 == 0, arch
        assert (cfg.n_kv * cfg.head_dim) % 16 == 0, arch
        assert cfg.d_ff % 16 == 0, arch
        if cfg.moe is not None:
            assert cfg.moe.n_experts % 16 == 0 or 16 % cfg.moe.n_experts == 0, arch


def test_lm_sharding_rules_cover_params():
    from repro.models import transformer as TF

    mesh = make_host_mesh()
    cfg = get("gemma-2b").reduced_config()
    aparams = TF.abstract_params(cfg)
    tree = SH.lm_params_sharding(mesh, aparams)
    # every leaf got a NamedSharding with matching rank
    for (path, leaf), (s_path, s) in zip(
        jax.tree_util.tree_leaves_with_path(aparams),
        jax.tree_util.tree_leaves_with_path(tree),
    ):
        assert len(s.spec) <= leaf.ndim, (path, s.spec, leaf.shape)


def test_zero_spec_adds_data_axis():
    class Leaf:
        ndim = 3
        shape = (4, 64, 128)

    spec = SH.lm_zero_spec("layers/mlp/gate/w", Leaf())
    assert "data" in spec
    assert "model" in spec


def test_cells_inventory():
    cs = cells()
    assert len(cs) == 40
    assert sum(1 for _a, _s, skip in cs if skip) == 2
    lm = [c for c in cs if get(c[0]).FAMILY == "lm"]
    rec = [c for c in cs if get(c[0]).FAMILY == "recsys"]
    gnn = [c for c in cs if get(c[0]).FAMILY == "gnn"]
    assert (len(lm), len(gnn), len(rec)) == (20, 4, 16)


def test_dryrun_artifacts_exist_and_clean():
    """The committed dry-run sweep: every cell present on both meshes,
    zero failures, collective schedule recorded."""
    import glob
    import json
    import os

    d = "experiments/dryrun"
    if not os.path.isdir(d):
        pytest.skip("dry-run sweep not yet executed")
    pod = sorted(glob.glob(f"{d}/*__pod.json"))
    multi = sorted(glob.glob(f"{d}/*__multipod.json"))
    assigned_pod = [f for f in pod if "lpq-ann" not in f]
    assigned_multi = [f for f in multi if "lpq-ann" not in f]
    assert len(assigned_pod) == 40, len(assigned_pod)
    assert len(assigned_multi) == 40, len(assigned_multi)
    # the paper's own full-scale ANN cells on both meshes (extras)
    assert len(pod) - len(assigned_pod) >= 3
    assert len(multi) - len(assigned_multi) >= 3
    for f in pod + multi:
        rec = json.load(open(f))
        if "skipped" in rec:
            continue
        assert rec["flops"] > 0, f
        assert "collectives" in rec, f
