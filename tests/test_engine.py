"""Scoring-engine tests: CodeStore storage/accounting, int4 pack round-trip
and packed-vs-unpacked score parity, fused score+top-k kernel parity vs the
jnp oracles + ``jax.lax.top_k``, the centralized pad/mask contract (the L2
zero-sentinel regression), lpq4 factory strings, and the uniform per-search
stats every kind emits.  Kernels run in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # no hypothesis on this container: see pyproject [test]
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine
from repro.core import pack as PK
from repro.core import quant as Qz
from repro.core.preserve import recall_at_k
from repro.kernels import ops as K
from repro.kernels import ref
from repro.knn import QuantSpec, SearchParams, make_index


# --------------------------------------------------------------------------
# int4 packing: round-trip + packed-vs-unpacked score parity (properties)
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 48),
       half_d=st.integers(1, 24))
def test_int4_roundtrip_through_store(seed, n, half_d):
    key = jax.random.PRNGKey(seed)
    codes = jax.random.randint(key, (n, half_d * 2), -8, 8, dtype=jnp.int8)
    params = Qz.QuantParams(
        lo=jnp.full((half_d * 2,), -1.0), hi=jnp.full((half_d * 2,), 1.0),
        zero=jnp.zeros((half_d * 2,)), bits=4, scheme="absmax",
    )
    store = engine.CodeStore.from_codes(codes, params, pack=True)
    assert store.data.dtype == jnp.uint8
    assert store.data.shape == (n, half_d)
    np.testing.assert_array_equal(np.asarray(store.unpacked()),
                                  np.asarray(codes))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 64),
       d=st.integers(1, 40), metric=st.sampled_from(["ip", "l2"]))
def test_packed_scores_match_unpacked(seed, n, d, metric):
    """qmip4/ql24 over packed bytes == qmip/ql2 over full-width codes."""
    d = d * 2  # kernels take the even/odd split; odd-d goes via CodeStore
    kq, kx = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.randint(kq, (3, d), -8, 8, dtype=jnp.int8)
    x = jax.random.randint(kx, (n, d), -8, 8, dtype=jnp.int8)
    packed = PK.pack_int4(x)
    if metric == "ip":
        got, want = K.qmip4(q, packed), ref.qmip_ref(q, x)
    else:
        got, want = K.ql24(q, packed), ref.ql2_ref(q, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# fused score+top-k kernel vs oracle scoring + lax.top_k
# --------------------------------------------------------------------------

FUSED_SHAPES = [
    (1, 1, 8),          # degenerate
    (1, 700, 64),       # single query, pad tail
    (7, 333, 100),      # ragged everything
    (37, 1000, 96),
    (9, 513, 128),      # one row over a tile
]


def _assert_topk_consistent(scores, ids, full, k):
    """Exact score parity; ids must reproduce their reported score (ties
    may legally reorder between selection algorithms)."""
    want_s = np.sort(np.asarray(full), axis=1)[:, ::-1][:, :k]
    np.testing.assert_array_equal(np.asarray(scores), want_s)
    got_i = np.asarray(ids)
    got_s = np.asarray(scores)
    for r in range(got_i.shape[0]):
        assert (got_i[r] >= 0).all()
        np.testing.assert_array_equal(np.asarray(full)[r][got_i[r]], got_s[r])


@pytest.mark.parametrize("q_rows,n_rows,d", FUSED_SHAPES)
@pytest.mark.parametrize("metric", ["ip", "l2"])
def test_fused_topk_matches_ref_int8(q_rows, n_rows, d, metric):
    kq, kx = jax.random.split(jax.random.PRNGKey(q_rows * 31 + n_rows))
    q = jax.random.randint(kq, (q_rows, d), -128, 128, dtype=jnp.int8)
    x = jax.random.randint(kx, (n_rows, d), -128, 128, dtype=jnp.int8)
    k = min(10, n_rows)
    s, i = K.fused_topk(q, x, k, metric)
    full = ref.qmip_ref(q, x) if metric == "ip" else ref.ql2_ref(q, x)
    _assert_topk_consistent(s, i, full, k)
    # and against lax.top_k end-to-end (scores sorted identically)
    ls, _li = jax.lax.top_k(full.astype(jnp.float32), k)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(ls))


@pytest.mark.parametrize("metric", ["ip", "l2"])
def test_fused_topk_matches_ref_int4_packed(metric):
    kq, kx = jax.random.split(jax.random.PRNGKey(5))
    q = jax.random.randint(kq, (6, 50), -8, 8, dtype=jnp.int8)
    x = jax.random.randint(kx, (777, 50), -8, 8, dtype=jnp.int8)
    s, i = K.fused_topk(q, PK.pack_int4(x), 17, metric, packed=True)
    full = ref.qmip_ref(q, x) if metric == "ip" else ref.ql2_ref(q, x)
    _assert_topk_consistent(s, i, full, 17)


def test_fused_topk_fp32_matches_xla():
    kq, kx = jax.random.split(jax.random.PRNGKey(7))
    q = jax.random.normal(kq, (5, 48))
    x = jax.random.normal(kx, (600, 48))
    for metric in ("ip", "l2"):
        s, i = K.fused_topk(q, x, 12, metric)
        ws, _ = K.fused_topk(q, x, 12, metric, use_pallas=False)
        np.testing.assert_allclose(np.asarray(s), np.asarray(ws),
                                   rtol=1e-5, atol=1e-5)


def test_fused_topk_l2_padding_never_wins():
    """The zero-sentinel regression: every corpus row is far from the
    origin, so an unmasked zero pad row would out-score all of them under
    negated L2.  The engine id-masks in-kernel — only valid ids return."""
    x = jnp.ones((1000, 16), jnp.float32) * 50.0       # pads to 1024 rows
    q = jnp.ones((4, 16), jnp.float32) * 49.0
    s, i = K.fused_topk(q, x, 10, "l2")
    ids = np.asarray(i)
    assert ids.min() >= 0 and ids.max() < 1000
    st = engine.CodeStore.dense(x)
    _s2, i2, _ = engine.topk(q, st, 10, "l2")
    assert np.asarray(i2).max() < 1000 and np.asarray(i2).min() >= 0


# --------------------------------------------------------------------------
# engine.topk over stores: precision arms agree with exact search
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def corpus_queries():
    corpus = jax.random.normal(jax.random.PRNGKey(0), (900, 32)) * 0.05
    queries = jax.random.normal(jax.random.PRNGKey(1), (16, 32)) * 0.05
    return corpus, queries


def test_engine_topk_packed_equals_unpacked(corpus_queries):
    """Bit-packing is a storage layout, not a math change: identical
    scores (exact integer parity) from packed and unpacked int4 stores."""
    corpus, queries = corpus_queries
    params = Qz.learn_params(corpus, bits=4, scheme="gaussian", sigmas=3.0)
    codes = Qz.quantize(corpus, params)
    packed = engine.CodeStore.from_codes(codes, params, pack=True)
    unpacked = engine.CodeStore.from_codes(codes, params, pack=False)
    for metric in ("ip", "l2", "angular"):
        sp, ip_ = engine.topk(queries, packed, 10, metric)[:2]
        su, iu = engine.topk(queries, unpacked, 10, metric)[:2]
        np.testing.assert_allclose(np.asarray(sp), np.asarray(su), rtol=1e-6)
    assert packed.memory_bytes() < 0.6 * unpacked.memory_bytes()


def test_engine_fused_path_matches_scan_path(corpus_queries):
    """interpret=True forces the fused Pallas kernel through engine.topk
    (the TPU hot path, interpreted); it must agree exactly with the XLA
    streaming scan the engine uses off-TPU."""
    corpus, queries = corpus_queries
    params = Qz.learn_params(corpus, bits=8, scheme="gaussian", sigmas=3.0)
    store = engine.CodeStore.from_codes(Qz.quantize(corpus, params), params)
    for metric in ("ip", "l2"):
        sf, idf, stf = engine.topk(queries, store, 10, metric, chunk=256,
                                   interpret=True)
        ss, ids, sts = engine.topk(queries, store, 10, metric, chunk=256)
        np.testing.assert_array_equal(np.asarray(sf), np.asarray(ss))
        np.testing.assert_array_equal(np.asarray(idf), np.asarray(ids))
        assert stf["bytes_read"] > 0 and sts["bytes_read"] > 0


def test_engine_store_base_rebases_ids(corpus_queries):
    """Shard-local stores rebase ids for the distributed merge."""
    corpus, queries = corpus_queries
    st = engine.CodeStore.dense(corpus, base=10_000)
    _s, i, _ = engine.topk(queries, st, 5, "ip")
    ids = np.asarray(i)
    assert ids.min() >= 10_000 and ids.max() < 10_000 + corpus.shape[0]


def test_engine_odd_dim_packs(corpus_queries):
    """Odd d packs via the zero-code pad column without score drift."""
    corpus, queries = corpus_queries
    corpus = corpus[:, :31]
    queries = queries[:, :31]
    params = Qz.learn_params(corpus, bits=4, scheme="gaussian", sigmas=3.0)
    codes = Qz.quantize(corpus, params)
    packed = engine.CodeStore.from_codes(codes, params, pack=True)
    unpacked = engine.CodeStore.from_codes(codes, params, pack=False)
    assert packed.data.shape == (900, 16)
    sp = engine.topk(queries, packed, 10, "l2")[0]
    su = engine.topk(queries, unpacked, 10, "l2")[0]
    np.testing.assert_allclose(np.asarray(sp), np.asarray(su), rtol=1e-6)


# --------------------------------------------------------------------------
# lpq4 factory arm: half the lpq8 bytes, recall parity with unpacked int4
# --------------------------------------------------------------------------

@pytest.mark.parametrize("factory8,factory4", [
    ("flat,lpq8@gaussian:3", "flat,lpq4@gaussian:3"),
    ("ivf8,lpq8@gaussian:3", "ivf8,lpq4@gaussian:3"),
])
def test_lpq4_memory_halves_vs_lpq8(corpus_queries, factory8, factory4):
    corpus, _queries = corpus_queries
    idx8 = make_index(factory8, corpus, key=jax.random.PRNGKey(0),
                      **({"kmeans_iters": 4} if "ivf" in factory8 else {}))
    idx4 = make_index(factory4, corpus, key=jax.random.PRNGKey(0),
                      **({"kmeans_iters": 4} if "ivf" in factory4 else {}))
    assert idx4.store.packed and idx4.store.bits == 4
    # payload is exactly half; the shared constants/centroids dilute the
    # total slightly — stay under 0.65x end to end
    ratio = idx4.memory_bytes() / idx8.memory_bytes()
    assert ratio < 0.65, ratio


@pytest.mark.parametrize("kind", ["flat", "ivf8"])
def test_lpq4_recall_parity_with_unpacked_int4(corpus_queries, kind):
    """Packed lpq4 returns the same neighbors as an unpacked-int4 build
    (identical integer scores; ties may reorder)."""
    corpus, queries = corpus_queries
    gt = np.asarray(make_index(kind.rstrip("8") if kind == "flat" else kind,
                               corpus).search(queries, 10).ids)
    packed_idx = make_index(f"{kind},lpq4@gaussian:3", corpus,
                            key=jax.random.PRNGKey(0))
    spec_unpacked = QuantSpec(bits=4, scheme="gaussian", sigmas=3.0,
                              packed=False)
    from repro.knn import IndexSpec

    params = {"nlist": 8} if kind == "ivf8" else {}
    unpacked_idx = make_index(
        IndexSpec(kind="flat" if kind == "flat" else "ivf",
                  quant=spec_unpacked, params=params),
        corpus, key=jax.random.PRNGKey(0),
    )
    assert not unpacked_idx.store.packed
    sp = SearchParams(nprobe=8)
    ids_p = np.asarray(packed_idx.search(queries, 10, sp).ids)
    ids_u = np.asarray(unpacked_idx.search(queries, 10, sp).ids)
    parity = float(recall_at_k(jnp.asarray(ids_u), jnp.asarray(ids_p)))
    assert parity > 0.99, parity
    # and the 4-bit arm still finds mostly-true neighbors
    rec = float(recall_at_k(jnp.asarray(gt), jnp.asarray(ids_p)))
    assert rec > 0.5, rec


def test_lpq4_hnsw_and_graph_build_and_search(corpus_queries):
    """Packed storage behind the graph walks: gather-unpack scoring."""
    corpus, queries = corpus_queries
    corpus, queries = corpus[:400], queries[:8]
    gt = np.asarray(make_index("flat", corpus).search(queries, 10).ids)
    for factory, over in (
        ("hnsw8,lpq4@gaussian:3", {"ef_construction": 40, "batch_size": 128}),
        ("graph16,lpq4@gaussian:3", {"n_seeds": 16}),
    ):
        idx = make_index(factory, corpus, key=jax.random.PRNGKey(0), **over)
        assert idx.store.packed and idx.store.bits == 4
        ids = np.asarray(idx.search(queries, 10,
                                    SearchParams(ef_search=80)).ids)
        overlap = np.mean([len(set(a) & set(b)) / 10 for a, b in zip(gt, ids)])
        assert overlap > 0.4, (factory, overlap)


# --------------------------------------------------------------------------
# uniform stats + accounting fixes
# --------------------------------------------------------------------------

def test_stats_uniform_across_kinds(corpus_queries):
    """Every kind reports the engine accounting block (satellite: real
    per-search stats surfaced uniformly)."""
    corpus, queries = corpus_queries
    cases = {
        "flat": ("flat,lpq8@gaussian:3", {}),
        "ivf": ("ivf8,lpq8@gaussian:3", {"kmeans_iters": 4}),
        "hnsw": ("hnsw8,lpq8@gaussian:3",
                 {"ef_construction": 40, "batch_size": 128}),
        "graph": ("graph16,lpq8@gaussian:3", {"n_seeds": 16}),
        "pq": ("pq16+lpq", {"kmeans_iters": 4}),
    }
    sp = SearchParams(nprobe=4, ef_search=40, chunk=256)
    for kind, (factory, over) in cases.items():
        idx = make_index(factory, corpus[:512], key=jax.random.PRNGKey(0),
                         **over)
        stats = idx.search(queries, 5, sp).stats
        for field in ("kind", "candidates", "chunks", "bytes_read",
                      "bits", "packed"):
            assert field in stats, (kind, field, stats)
        assert stats["kind"] == kind
        assert stats["candidates"] > 0 and stats["bytes_read"] > 0


def test_flat_memory_bytes_honest_at_4_bits(corpus_queries):
    """Regression: FlatIndex.memory_bytes hard-coded 1 byte/code, so the
    4-bit arm misreported Table 1 memory by 2x.  CodeStore accounting
    reports true packed bytes."""
    corpus, _q = corpus_queries
    n, d = corpus.shape
    idx4 = make_index("flat,lpq4@gaussian:3", corpus)
    idx8 = make_index("flat,lpq8@gaussian:3", corpus)
    consts = 3 * d * 4
    assert idx8.memory_bytes() == n * d + consts
    assert idx4.memory_bytes() == n * d // 2 + consts


def test_topk_pads_uniformly_when_k_exceeds_n(corpus_queries):
    """Every kind honors the [Q, k] / -1-pad SearchResult contract."""
    corpus, queries = corpus_queries
    small = corpus[:6]
    for factory in ("flat", "flat,lpq4@gaussian:3"):
        res = make_index(factory, small).search(queries, 10)
        assert res.ids.shape == (queries.shape[0], 10)
        assert (np.asarray(res.ids)[:, 6:] == -1).all()
    res = make_index("pq16", small, kmeans_iters=2).search(queries, 10)
    assert res.ids.shape == (queries.shape[0], 10)
    assert (np.asarray(res.ids)[:, 6:] == -1).all()


def test_wide_bits_rejected_early(corpus_queries):
    """B > 8 would overflow the engine's int32 score accumulation
    (d * (2^15)^2 > 2^31 at d >= 2) — rejected at parse/build, not by a
    kernel assert deep in the first search."""
    corpus, _q = corpus_queries
    with pytest.raises(ValueError, match=r"\[1, 8\]"):
        make_index("flat,lpq16@gaussian:3", corpus)
    with pytest.raises(ValueError, match="B <= 8"):
        QuantSpec(bits=16).build_store(corpus)


def test_pq_rejects_angular_at_build(corpus_queries):
    corpus, _q = corpus_queries
    with pytest.raises(ValueError, match="ip and l2"):
        make_index("pq16,angular", corpus[:256], kmeans_iters=2)


def test_store_roundtrips_through_save_load(corpus_queries, tmp_path):
    corpus, queries = corpus_queries
    idx = make_index("flat,lpq4@gaussian:3", corpus)
    path = str(tmp_path / "lpq4.npz")
    idx.save(path)
    from repro.knn import load_index

    back = load_index(path)
    assert back.store.packed and back.store.bits == 4
    a = idx.search(queries, 10)
    b = back.search(queries, 10)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    assert back.memory_bytes() == idx.memory_bytes()
