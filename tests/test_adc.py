"""Property tests for the fused Pallas ADC subsystem (kernels/adc.py).

Three contracts, swept with hypothesis (or the deterministic fallback
shim) across ragged shapes:

  * the fused kernel (interpret mode) bit-matches the ``ref.py``
    gather-sum oracle — scores AND ids — at both codeword widths,
    including non-dividing query/corpus tiles and odd subspace counts
    (whose packed layout carries a zero-code pad column);
  * Eq. 1 per-query abs-max LUT quantization preserves the fp32-LUT
    top-1 whenever the fp32 winner's margin exceeds the worst-case
    rounding bound (m subspaces x half an LSB each);
  * unsigned nibble packing round-trips, including the odd-m pad.
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # no hypothesis on this container: see pyproject [test]
    from _hypothesis_compat import given, settings, strategies as st

from repro import engine
from repro.core import pack as PK
from repro.kernels import ops as K
from repro.kernels import ref as R


def _codes(seed, n, m, n_codewords):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, n_codewords, (n, m)), jnp.uint8)


def _lut(seed, q, m, n_codewords):
    rng = np.random.default_rng(seed + 1)
    return jnp.asarray(rng.integers(-127, 128, (q, m, n_codewords)), jnp.int8)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    q=st.integers(1, 17),
    n=st.integers(4, 700),
    m=st.sampled_from([2, 3, 4, 8, 16]),
    bits=st.sampled_from([4, 8]),
    k=st.integers(1, 20),
)
def test_fused_adc_bit_matches_oracle(seed, q, n, m, bits, k):
    """Interpret-mode kernel == gather-sum oracle, exactly, everywhere."""
    n_codewords = 2 ** bits
    lut = _lut(seed, q, m, n_codewords)
    codes = _codes(seed, n, m, n_codewords)
    packed = bits == 4
    payload = PK.pack_uint4(codes) if packed else codes

    s_ref, i_ref = R.topk_ref(
        (R.adc4_ref(jnp.pad(lut, ((0, 0), (0, m % 2), (0, 0))), payload)
         if packed else R.adc_ref(lut, codes)),
        min(k, n), n,
    )
    s_k, i_k = K.fused_adc_topk(lut, payload, k, packed=packed,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_k))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    q=st.integers(1, 9),
    n=st.integers(40, 400),
    m=st.sampled_from([2, 4, 8]),
    bits=st.sampled_from([4, 8]),
    metric=st.sampled_from(["ip", "l2"]),
    chunk=st.integers(7, 130),
)
def test_engine_fused_matches_streaming_scan(seed, q, n, m, bits, metric,
                                             chunk):
    """engine.topk over a real PQStore: the fused kernel and the
    reference streaming scan are bit-identical at every chunking."""
    from repro.knn import make_index

    d = m * 4
    corpus = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 0.1
    queries = jax.random.normal(jax.random.PRNGKey(seed + 1), (q, d)) * 0.1
    idx = make_index(f"pq{m}x{bits}+lpq,{metric}", corpus, kmeans_iters=2,
                     key=jax.random.PRNGKey(0))
    k = min(10, n)
    s_ref, i_ref, _ = engine.topk(queries, idx.store, k, metric,
                                  chunk=chunk, use_pallas=False)
    s_f, i_f, _ = engine.topk(queries, idx.store, k, metric,
                              chunk=chunk, interpret=True)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_f))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i_f))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    q=st.integers(1, 8),
    m=st.sampled_from([2, 4, 8, 16]),
    bits=st.sampled_from([4, 8]),
    metric=st.sampled_from(["ip", "l2"]),
)
def test_int8_lut_preserves_fp32_top1_within_clamp_bound(seed, q, m, bits,
                                                         metric):
    """Eq. 1 LUT quantization: each int8 entry is within half an LSB
    (amax/127/2) of its fp32 value, so the summed ADC error is bounded by
    m LSB halves — whenever the fp32 top-1 margin beats twice that
    bound, the int8 scan must return the same top-1 row."""
    n, d = 300, m * 4
    corpus = jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * 0.1
    queries = jax.random.normal(jax.random.PRNGKey(seed + 1), (q, d)) * 0.1
    from repro.knn import make_index

    idx = make_index(f"pq{m}x{bits},{metric}", corpus, kmeans_iters=2,
                     key=jax.random.PRNGKey(0))
    store = idx.store

    lut_fp = engine.build_pq_lut(queries, store, metric)
    lut_q = engine.quantize_pq_lut(lut_fp)
    # the Eq. 1 scale is per query (each query's [M, K] table abs-max)
    amax = np.asarray(jnp.max(jnp.abs(lut_fp), axis=(1, 2))).clip(min=1e-12)
    lsb = amax / 127.0                                     # [Q]
    codes = store.unpacked_codes()
    idx_mn = codes.T[None].astype(jnp.int32)               # [1, M, N]
    s_fp = np.asarray(                                     # fp32 gather-sum
        jnp.sum(jnp.take_along_axis(lut_fp, idx_mn, axis=2), axis=1)
    )
    s_q = np.asarray(R.adc_ref(lut_q, codes)) * lsb[:, None]   # dequantized

    # per-entry quantization error is <= lsb/2, summed over m subspaces
    bound = m * lsb / 2.0                                  # [Q]
    assert np.all(np.abs(s_q - s_fp) <= bound[:, None] + 1e-4)

    order = np.argsort(-s_fp, axis=1)
    margin = s_fp[np.arange(q), order[:, 0]] - s_fp[np.arange(q), order[:, 1]]
    safe = margin > 2.0 * bound
    top1_q = np.argmax(s_q, axis=1)
    np.testing.assert_array_equal(top1_q[safe], order[safe, 0])


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(1, 64),
    m=st.integers(1, 33),
)
def test_uint4_pack_roundtrip(seed, n, m):
    """pack -> unpack is the identity on [0, 15] codes; odd m gains one
    zero-code pad column that slicing removes."""
    codes = _codes(seed, n, m, 16)
    packed = PK.pack_uint4(codes)
    assert packed.shape == (n, (m + 1) // 2)
    assert packed.dtype == jnp.uint8
    back = PK.unpack_uint4(packed)
    np.testing.assert_array_equal(np.asarray(back[:, :m]), np.asarray(codes))
    if m % 2:
        assert not np.asarray(back[:, m:]).any(), "pad column must be code 0"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(2, 50),
       m=st.integers(1, 17))
def test_packed_store_scores_match_unpacked_codes(seed, n, m):
    """A PQStore's packed code matrix and its unpacked_codes() view are
    the same codes — the oracle scores them identically."""
    codes = _codes(seed, n, m, 16)
    lut = _lut(seed, 3, m, 16)
    store = engine.PQStore(n=n, m=m, bits=4, lpq_tables=True,
                           codes=PK.pack_uint4(codes),
                           codebooks=jnp.zeros((m, 16, 2)))
    np.testing.assert_array_equal(
        np.asarray(R.adc_ref(lut, store.unpacked_codes())),
        np.asarray(R.adc_ref(lut, codes)),
    )
    assert store.row_bytes == (m + 1) // 2
