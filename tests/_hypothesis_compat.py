"""Deterministic fallback for ``hypothesis`` when it is not installed.

The property suites import ``given``/``settings``/``strategies`` from
hypothesis when available (the ``[test]`` extra in pyproject.toml installs
it; CI does).  On containers without it, this shim runs each property test
over a fixed pseudo-random sample of the strategy space — deterministic
(seeded per test name), so failures are reproducible, but far less
thorough than real hypothesis.  It implements only the strategy surface
these suites use: integers, floats, sampled_from.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

_DEFAULT_EXAMPLES = 8


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(options) -> _Strategy:
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_kw):
    """Records max_examples on the (already-wrapped) test function."""

    def deco(fn):
        fn._max_examples = min(max_examples, _DEFAULT_EXAMPLES)
        return fn

    return deco


def given(**strategy_kwargs):
    """Run the test over a deterministic sample of the strategy space."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(zlib.adler32(fn.__name__.encode()))
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strategy_kwargs.items()}
                fn(*args, **drawn, **kwargs)

        # pytest must see a no-arg test, not the strategy params (which it
        # would otherwise resolve as fixtures)
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        wrapper._max_examples = _DEFAULT_EXAMPLES
        return wrapper

    return deco
