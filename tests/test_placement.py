"""Distributed placement subsystem (DESIGN.md §15): Placement plans,
sentinel pad gids, replica fan-out, and the ReplicaSet serving layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import sentinel_gids, submeshes
from repro.dist.placement import Placement, balance, for_index
from repro.dist.replica import ReplicaSet, replicated_query_plan
from repro.knn import make_index


# --------------------------------------------------------------------------
# Placement plans (host-side)
# --------------------------------------------------------------------------

def test_balance_lpt_is_deterministic_and_bounded():
    sizes = [7, 1, 5, 5, 3, 9, 2]
    a1 = balance(sizes, 3)
    a2 = balance(list(sizes), 3)
    assert a1 == a2                      # reproducible across calls
    loads = [0, 0, 0]
    for u, s in enumerate(a1):
        loads[s] += sizes[u]
    # LPT guarantee: max load <= (4/3 - 1/3m) * OPT; generous bound here
    assert max(loads) <= 2 * (sum(sizes) + 2) // 3


def test_placement_rows_contiguous_blocks():
    p = Placement.rows(10, 3)
    assert p.kind == "rows" and p.n_shards == 3
    assert sum(p.unit_sizes) == 10
    assert p.shard_rows(0) == 4 and p.shard_rows(2) == 2
    assert p.n_rows == 10
    assert p.summary()["balance"] >= 1.0


def test_placement_lists_balances_skew():
    sizes = [100, 1, 1, 1, 1, 1, 1, 1]
    p = Placement.lists(sizes, 2)
    # the one giant list must not drag everything onto its shard
    big_shard = p.assign[0]
    assert all(s != big_shard for u, s in enumerate(p.assign) if u)
    assert p.shard_rows(big_shard) == 100
    assert p.n_rows == sum(sizes)


def test_placement_segments_and_bytes():
    p = Placement.segments([128, 128, 64], 2)
    assert p.kind == "segments" and p.n_units == 3
    assert p.shard_rows(0) + p.shard_rows(1) == 320
    assert p.shard_bytes(4) == (p.shard_rows(0) * 4, p.shard_rows(1) * 4)


def test_placement_replicated():
    p = Placement.replicated(500, 4)
    assert p.kind == "replicated" and p.n_units == 0
    assert p.n_rows == 500
    assert all(p.shard_rows(s) == 500 for s in range(4))


def test_placement_validates():
    with pytest.raises(ValueError):
        Placement("rows", 2, (0, 2), (5, 5))        # shard id out of range
    with pytest.raises(ValueError):
        Placement("bogus", 2, (0, 1), (5, 5))
    with pytest.raises(ValueError):
        balance([1, 2], 0)


def test_for_index_picks_the_kind_unit():
    corpus = np.random.RandomState(0).randn(256, 16).astype("float32")
    assert for_index(make_index("flat", corpus), 2).kind == "rows"
    assert for_index(make_index("ivf8", corpus, kmeans_iters=2), 2).kind == "lists"
    assert for_index(make_index("hnsw", corpus), 2).kind == "replicated"


# --------------------------------------------------------------------------
# sentinel pad gids (the PR 3 aliasing hazard)
# --------------------------------------------------------------------------

def test_sentinel_gids_unique_and_out_of_range():
    """Pad rows must never alias a *real* gid of another shard, at
    non-dividing chunk/shard combos: n=97 rows over 2 shards with
    chunk=10 tiles pads each shard to 50 rows, and shard 0's pad gids
    (49..) would alias shard 1's real rows without the sentinel bands."""
    n, n_shards, padded = 97, 2, 50
    all_gids = []
    for shard in range(n_shards):
        start = shard * 49
        lrow = jnp.arange(padded, dtype=jnp.int32)
        gid0 = start + lrow
        valid = (lrow < 49) & (gid0 < n)
        g = sentinel_gids(gid0, valid, shard=shard, local_rows=lrow,
                          n_total=n, padded_rows=padded)
        g = np.asarray(g)
        # every invalid slot is >= n (never a real row anywhere)
        assert (g[~np.asarray(valid)] >= n).all()
        all_gids.append(g)
    flat = np.concatenate(all_gids)
    real = flat[flat < n]
    sent = flat[flat >= n]
    # sentinels are globally unique: no two pad slots share a gid
    assert len(set(sent.tolist())) == sent.size
    # and they collide with no real row
    assert not (set(sent.tolist()) & set(real.tolist()))


def test_sharded_scan_non_dividing_rows_parity():
    """End-to-end regression for the aliasing hazard on the devices this
    host exposes: odd corpus size + tiny chunk forces pad tiles whose
    naive gids would run into the next shard."""
    corpus = np.random.RandomState(0).randn(97, 8).astype("float32")
    queries = np.random.RandomState(1).randn(5, 8).astype("float32")
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    from repro.knn import SearchParams

    for factory in ("flat", "flat,lpq4"):
        idx = make_index(factory, corpus)
        un = idx.searcher(20, SearchParams(chunk=10))(queries)
        sh = idx.searcher(20, SearchParams(chunk=10), shards=mesh)(queries)
        np.testing.assert_array_equal(np.asarray(un.ids), np.asarray(sh.ids))
        np.testing.assert_array_equal(np.asarray(un.scores),
                                      np.asarray(sh.scores))


# --------------------------------------------------------------------------
# replica fan-out
# --------------------------------------------------------------------------

def test_replicated_query_plan_pads_and_restores():
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))

    def core(qs):
        s = jnp.sum(qs, axis=-1, keepdims=True)
        return s, jnp.zeros_like(s, jnp.int32)

    run = replicated_query_plan(core, mesh)
    for Q in (1, 3, 8):
        q = jnp.asarray(np.random.RandomState(Q).randn(Q, 4), jnp.float32)
        s, i = run(q)
        assert s.shape == (Q, 1) and i.shape == (Q, 1)
        np.testing.assert_allclose(np.asarray(s),
                                   np.asarray(jnp.sum(q, -1, keepdims=True)),
                                   rtol=1e-6)


def test_submeshes_disjoint_cover():
    groups = submeshes(len(jax.devices()))
    seen = set()
    for m in groups:
        for d in m.devices.flat:
            assert d.id not in seen
            seen.add(d.id)


# --------------------------------------------------------------------------
# ReplicaSet serving layer
# --------------------------------------------------------------------------

def test_replicaset_routes_and_serves():
    served = []

    def make(r):
        return lambda x: served.append((r, x)) or (r, x * 10)

    rs = ReplicaSet(make, 2)
    futs = [rs.submit(i, queries=1) for i in range(6)]
    out = [f.result(timeout=10) for f in futs]
    rs.close()
    assert sorted(x for _r, x in out) == [0, 10, 20, 30, 40, 50]
    assert {r for r, _x in served} <= {0, 1}


def test_replicaset_admission_sheds_and_counts():
    import threading

    from repro.runtime.telemetry import Telemetry

    gate = threading.Event()
    tel = Telemetry()
    rs = ReplicaSet(lambda r: lambda x: (gate.wait(10), x)[1], 1,
                    max_queue=1, telemetry=tel)
    first = rs.submit(0, queries=1)
    # worker may or may not have picked up the first yet; fill to the cap
    while rs.submit(99, queries=1) is not None:
        pass
    assert tel.counters["replica_shed"] >= 1
    gate.set()
    assert first.result(timeout=10) == 0
    rs.close()
    assert tel.counters["replica0_requests"] >= 1
    assert tel.counters["replica0_queries"] >= 1


def test_replicaset_rebuild_is_a_write_barrier():
    epochs = {"e": 0}

    def make(r):
        e = epochs["e"]
        return lambda x: (e, x)

    rs = ReplicaSet(make, 2)
    assert rs.submit(1).result(timeout=10)[0] == 0
    epochs["e"] = 1
    rs.rebuild()
    assert rs.submit(1).result(timeout=10)[0] == 1
    rs.close()


def test_replicaset_surfaces_exceptions():
    def make(r):
        def run(x):
            raise RuntimeError("boom")
        return run

    rs = ReplicaSet(make, 1)
    fut = rs.submit(1)
    with pytest.raises(RuntimeError, match="boom"):
        fut.result(timeout=10)
    rs.close()
