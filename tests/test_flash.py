"""Flash attention (custom VJP) vs naive full-matrix reference: forward
and gradients, across GQA/window/chunk/softcap variants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def naive_attention(q, k, v, qpos, window, chunk, cap):
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    qf = q.astype(jnp.float32) * hd**-0.5
    qg = qf.reshape(B, Sq, Hkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32))
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    kpos = jnp.arange(Sk)
    i = qpos[:, None]
    j = kpos[None, :]
    mask = (j <= i) & ((i - j) < window) & ((i // chunk) == (j // chunk))
    s = jnp.where(mask[None, :, None, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


CASES = [
    # (B, S, H, Hkv, hd, window, chunk, cap, bq, bkv)
    (2, 32, 4, 2, 16, int(A.GLOBAL), int(A.GLOBAL), None, 8, 8),
    (1, 64, 4, 1, 8, 16, int(A.GLOBAL), None, 16, 16),         # MQA + window
    (2, 48, 4, 4, 8, int(A.GLOBAL), 16, None, 16, 8),          # MHA + chunked
    (1, 32, 8, 2, 16, int(A.GLOBAL), int(A.GLOBAL), 50.0, 8, 16),  # softcap
    (1, 40, 2, 2, 8, 8, int(A.GLOBAL), 30.0, 16, 8),           # ragged S
]


@pytest.mark.parametrize("B,S,H,Hkv,hd,window,chunk,cap,bq,bkv", CASES)
def test_flash_forward_matches_naive(B, S, H, Hkv, hd, window, chunk, cap, bq, bkv):
    keys = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(keys[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(keys[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(keys[2], (B, S, Hkv, hd), jnp.float32)
    qpos = jnp.arange(S)

    got = A.blockwise_attention(
        q, k, v, qpos, window=jnp.int32(window), chunk=jnp.int32(chunk),
        cap=cap, block_q=bq, block_kv=bkv,
    )
    want = naive_attention(q, k, v, qpos, window, chunk, cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,H,Hkv,hd,window,chunk,cap,bq,bkv", CASES)
def test_flash_grads_match_naive(B, S, H, Hkv, hd, window, chunk, cap, bq, bkv):
    keys = jax.random.split(jax.random.PRNGKey(S * 3 + H), 4)
    q = jax.random.normal(keys[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(keys[1], (B, S, Hkv, hd), jnp.float32)
    v = jax.random.normal(keys[2], (B, S, Hkv, hd), jnp.float32)
    co = jax.random.normal(keys[3], (B, S, H, hd), jnp.float32)  # cotangent
    qpos = jnp.arange(S)

    def loss_flash(q, k, v):
        o = A.blockwise_attention(
            q, k, v, qpos, window=jnp.int32(window), chunk=jnp.int32(chunk),
            cap=cap, block_q=bq, block_kv=bkv,
        )
        return jnp.sum(o * co)

    def loss_naive(q, k, v):
        return jnp.sum(naive_attention(q, k, v, qpos, window, chunk, cap) * co)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=5e-4, rtol=5e-4,
            err_msg=f"d{name} mismatch",
        )


def test_flash_kv_longer_than_q():
    """Cross-length (q shorter than kv) path used by chunked prefill."""
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    B, Sq, Sk, H, hd = 1, 16, 48, 4, 8
    q = jax.random.normal(keys[0], (B, Sq, H, hd))
    k = jax.random.normal(keys[1], (B, Sk, H, hd))
    v = jax.random.normal(keys[2], (B, Sk, H, hd))
    qpos = jnp.arange(Sk - Sq, Sk)   # q block at the end of the stream
    got = A.blockwise_attention(
        q, k, v, qpos, window=jnp.int32(int(A.GLOBAL)),
        chunk=jnp.int32(int(A.GLOBAL)), cap=None, block_q=8, block_kv=16,
    )
    want = naive_attention(q, k, v, qpos, int(A.GLOBAL), int(A.GLOBAL), None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)
