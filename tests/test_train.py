"""Training runtime tests: optimizer math, schedules, checkpoint
round-trip + corruption resilience, resume semantics, microbatch
equivalence, retries and elastic re-mesh."""

import itertools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import lm_data
from repro.models import transformer as TF
from repro.train import (
    OptConfig,
    TrainConfig,
    adamw_init,
    adamw_update,
    checkpoint as CKPT,
    lr_at,
    make_train_step,
    train,
)
from repro.train.fault_tolerance import best_mesh_shape, elastic_remesh, run_with_retries


@pytest.fixture(scope="module")
def tiny():
    cfg = TF.LMConfig(
        name="t", n_layers=2, d_model=32, n_heads=2, n_kv=1, head_dim=16,
        d_ff=64, vocab=128, dtype="float32", block_q=16, block_kv=16, remat=False,
    )
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    batch = lm_data.lm_batch(jax.random.PRNGKey(7), 8, 16, 128)
    return cfg, params, batch


def test_adamw_descends_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, schedule="const")
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _m = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_schedules():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd",
                    decay_frac=0.2, min_lr_ratio=0.1)
    assert float(lr_at(0, cfg)) == 0.0
    assert float(lr_at(10, cfg)) == pytest.approx(1.0)
    assert float(lr_at(50, cfg)) == pytest.approx(1.0)     # stable phase
    assert float(lr_at(99, cfg)) < 0.2                      # decay phase
    ccfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
    assert float(lr_at(99, ccfg)) <= float(lr_at(50, ccfg))


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    cfg = OptConfig(lr=0.0, clip_norm=1.0, warmup_steps=0)
    _p, _s, m = adamw_update({"w": jnp.full(3, 100.0)}, adamw_init(params), params, cfg)
    assert float(m["grad_norm"]) > 100  # reported pre-clip


def test_checkpoint_roundtrip(tmp_path, tiny):
    _cfg, params, _b = tiny
    tree = {"params": params, "step": jnp.int32(7)}
    CKPT.save(str(tmp_path), 7, tree)
    restored, meta = CKPT.restore_latest(str(tmp_path), tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_skips_corrupt(tmp_path, tiny):
    _cfg, params, _b = tiny
    tree = {"p": params}
    CKPT.save(str(tmp_path), 1, tree)
    CKPT.save(str(tmp_path), 2, tree)
    # corrupt the newest
    newest = os.path.join(str(tmp_path), "step_0000000002", "arrays.npz")
    with open(newest, "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    restored, meta = CKPT.restore_latest(str(tmp_path), tree)
    assert meta["step"] == 1  # fell back past the corrupt one


def test_checkpoint_retain(tmp_path, tiny):
    _cfg, params, _b = tiny
    for s in range(5):
        CKPT.save(str(tmp_path), s, {"p": params})
    CKPT.retain(str(tmp_path), keep=2)
    assert CKPT.list_steps(str(tmp_path)) == [3, 4]


def test_train_resume_continues(tmp_path, tiny):
    cfg, params, batch = tiny
    # train() donates its buffers; keep the shared fixture intact
    params = jax.tree.map(jnp.array, params)
    loss_fn = lambda p, b: TF.lm_loss(p, b, cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    data = itertools.repeat(batch)
    _p, _o, h1 = train(loss_fn, params, data, opt,
                       TrainConfig(steps=10, ckpt_dir=str(tmp_path), ckpt_every=5,
                                   log_every=2))
    _p, _o, h2 = train(loss_fn, params, itertools.repeat(batch), opt,
                       TrainConfig(steps=16, ckpt_dir=str(tmp_path), ckpt_every=5,
                                   log_every=2))
    assert h2[0]["step"] == 10  # resumed, not restarted


def test_microbatch_equivalence(tiny):
    cfg, params, batch = tiny
    loss_fn = lambda p, b: TF.lm_loss(p, b, cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=0)
    s1 = make_train_step(loss_fn, opt, microbatches=1, donate=False)
    s4 = make_train_step(loss_fn, opt, microbatches=4, donate=False)
    p1, _o, _m = s1(params, adamw_init(params), batch)
    p4, _o, _m = s4(params, adamw_init(params), batch)
    diff = max(
        jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p4))
    )
    assert diff < 1e-4


def test_run_with_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("node died")
        return "ok"

    assert run_with_retries(flaky, restore=lambda: None, backoff_s=0.0) == "ok"
    with pytest.raises(RuntimeError):
        run_with_retries(
            lambda: (_ for _ in ()).throw(RuntimeError("always")),
            restore=lambda: None, max_failures=1, backoff_s=0.0,
        )


def test_elastic_remesh_factorizations():
    assert best_mesh_shape(512, 16) == (32, 16)
    assert best_mesh_shape(448, 16) == (28, 16)
    assert best_mesh_shape(100, 16) == (10, 10)
    assert best_mesh_shape(7, 16) == (1, 7)


def test_elastic_remesh_resharding():
    from jax.sharding import PartitionSpec as P

    state = {"w": np.ones((8, 4), np.float32)}
    mesh, sharded = elastic_remesh(state, lambda leaf: P(), model_parallel=1)
    np.testing.assert_array_equal(np.asarray(sharded["w"]), state["w"])
