"""Tests for the paper's technique integrated into the model families:
int8 KV cache (LM decode) and int8 embedding tables (recsys)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.preserve import recall_at_k
from repro.data import lm_data
from repro.models import transformer as TF
from repro.models.recsys import embedding as E
from repro.models.recsys import retrieval as RT
from repro.quantized import qkv_cache as QC


def _tiny_cfg():
    return TF.LMConfig(
        name="t", n_layers=4, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, dtype="float32", block_q=8, block_kv=8,
        attn_softcap=50.0, final_softcap=30.0,
    )


def test_q8_cache_preserves_next_token_ranking():
    cfg = _tiny_cfg()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    toks = lm_data.lm_batch(jax.random.PRNGKey(1), 4, 24, cfg.vocab)["tokens"]
    _lg, caches = TF.prefill(params, toks[:, :16], cfg)

    kc, vc = TF.make_cache(cfg, 4, 24, dtype=jnp.float32)
    kc = TF.write_prefix(kc, caches[0])
    vc = TF.write_prefix(vc, caches[1])
    lg_fp, _ = TF.decode_step(params, (kc, vc), toks[:, 16:17], jnp.int32(16), cfg)

    qcache = QC.quantize_cache(caches[0], caches[1], max_len=24)
    lg_q8, _ = QC.decode_step_q8(params, qcache, toks[:, 16:17], jnp.int32(16), cfg)

    # Definition 2 on attention logits -> next-token ranking survives
    top_fp = np.argsort(-np.asarray(lg_fp), -1)[:, :5]
    top_q8 = np.argsort(-np.asarray(lg_q8), -1)[:, :5]
    agree = np.mean([len(set(a) & set(b)) / 5 for a, b in zip(top_fp, top_q8)])
    assert agree >= 0.8, agree
    # argmax (greedy token) agreement
    assert (top_fp[:, 0] == top_q8[:, 0]).mean() >= 0.75


def test_q8_cache_memory_halves_vs_bf16():
    cfg = _tiny_cfg()
    assert QC.cache_memory_bytes(cfg, 8, 1024, quantized=True) < (
        0.6 * QC.cache_memory_bytes(cfg, 8, 1024, quantized=False)
    )


def test_q8_cache_multi_step_decode_stays_finite():
    cfg = _tiny_cfg()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    toks = lm_data.lm_batch(jax.random.PRNGKey(1), 2, 32, cfg.vocab)["tokens"]
    _lg, caches = TF.prefill(params, toks[:, :8], cfg)
    qcache = QC.quantize_cache(caches[0], caches[1], max_len=32)
    tok = toks[:, 8:9]
    for step in range(8):
        lg, qcache = QC.decode_step_q8(params, qcache, tok, jnp.int32(8 + step), cfg)
        assert np.isfinite(np.asarray(lg[:, : cfg.vocab])).all()
        tok = jnp.argmax(lg, -1)[:, None]


def test_quantized_table_lookup_close_to_dense():
    table = jax.random.normal(jax.random.PRNGKey(0), (512, 32)) * 0.1
    qt = E.QuantizedTable.from_dense(table)
    ids = jnp.array([0, 5, 100, 511])
    dense = np.asarray(table[ids])
    deq = np.asarray(qt.lookup(ids))
    assert np.abs(dense - deq).max() < 0.01
    assert qt.memory_bytes() < 0.3 * table.nbytes


def test_quantized_retrieval_recall():
    cands = jax.random.normal(jax.random.PRNGKey(2), (20_000, 32)) * 0.05
    queries = jax.random.normal(jax.random.PRNGKey(3), (8, 32)) * 0.05
    qt = E.QuantizedTable.from_dense(cands)
    _s, gt = RT.retrieve_fp32(queries, cands, k=100)
    _s, ids = RT.retrieve_quantized(queries, qt.codes, qt.params, k=100,
                                    use_pallas=False)
    # iid gaussian is the worst case for abs-max int8 (no narrow band to
    # exploit); structured corpora reach ~0.98 (tests/test_system.py)
    assert float(recall_at_k(gt, ids)) > 0.8
