"""Kernel autotuner + measured dispatch tables (DESIGN.md §13): tuning-
space legality and pruning, tuner determinism under the injected
cost-model timer, JSON and saved-index round-trips, stamp-mismatch
adoption (parked, counted, never raised), tuned-vs-untuned dispatch
bit-parity, tile-query routing, plan-time table pinning, and the
maintenance scheduler's low-priority re-tune trigger."""

import numpy as np
import pytest

from repro import engine
from repro.data import synthetic
from repro.knn import make_index
from repro.knn.registry import load_index
from repro.runtime import MaintenanceScheduler
from repro.runtime import profile as rtprofile
from repro.tune import autotuner as AT
from repro.tune import space as S
from repro.tune import table as T
from repro.tune.table import TuneConfig, TuneTable

K = 10


@pytest.fixture(autouse=True)
def clean_table_state():
    """Every test starts and ends with no installed/pending table (the
    registered fallback rows are process state and stay)."""
    T.clear()
    yield
    T.clear()


@pytest.fixture(scope="module")
def corpus():
    c, _q, _m = synthetic.load("product", 3000, 8)
    return np.asarray(c[:, :16])


@pytest.fixture(scope="module")
def queries():
    _c, q, _m = synthetic.load("product", 64, 8)
    return np.asarray(q[:8, :16])


def _foreign_stamp() -> dict:
    """A stamp from a machine this process is not."""
    return {**T.live_stamp(), "backend": "tpu", "device_kind": "TPU v5e"}


def _tiny_table(entries=None) -> TuneTable:
    t = TuneTable(stamp=T.live_stamp())
    for (kernel, metric, bits, q, n, d), cfg in (entries or {}).items():
        t.put(kernel, metric, bits, q, n, d, cfg)
    return t


# --------------------------------------------------------------------------
# tuning space
# --------------------------------------------------------------------------

class TestSpace:
    def test_bucket_powers_of_two(self):
        assert [T.bucket(x) for x in (1, 2, 3, 8, 9, 20480)] == [
            1, 2, 4, 8, 16, 32768]
        # same bucket -> same key; different bucket -> different key
        k = lambda n: T.key_for("cpu", "cpu", "scan", "ip", 8, 8, n, 16)
        assert k(20000) == k(32768) != k(32769)

    def test_fused_candidates_are_legal(self):
        w = S.Workload("fused_topk", "ip", 8, q=64, n=8192, d=64)
        cands = S.candidates(w)
        fused = [c for c in cands if c.impl == "fused"]
        assert fused, "fused family must enumerate fused tiles"
        for c in fused:
            assert c.bq % S.SUBLANE == 0 and c.bn % S.SUBLANE == 0
            assert S.working_set_bytes(w, c) <= S.VMEM_BUDGET
        # the scan crossover is part of every fused family's space
        assert any(c.impl == "scan" for c in cands)

    def test_scan_family_has_no_fused_candidates(self):
        w = S.Workload("scan", "angular", 8, q=8, n=20480, d=32)
        cands = S.candidates(w)
        assert cands and all(c.impl == "scan" for c in cands)
        # the exact-fit chunk (the pad-waste killer for awkward n) is in
        assert any(c.chunk == S.round_up(20480, S.SUBLANE) for c in cands)

    def test_prune_keeps_default_and_drops_losers(self):
        w = S.Workload("scan", "angular", 8, q=8, n=20480, d=32)
        cands = S.candidates(w)
        keep = TuneConfig("scan", chunk=S.DEFAULT_CHUNK)
        pruned = S.prune(w, cands, keep=keep)
        assert keep in pruned
        assert set(pruned) <= set(cands) | {keep}
        best = min(S.estimate(w, c) for c in cands)
        for c in pruned:
            if c != keep:
                assert S.estimate(w, c) <= 4.0 * best

    def test_vmem_budget_excludes_oversize_tiles(self):
        # a huge-d fused tile cannot fit: no fused candidate survives
        w = S.Workload("fused_topk", "ip", 8, q=64, n=8192, d=65536)
        assert all(c.impl == "scan" for c in S.candidates(w))


# --------------------------------------------------------------------------
# tuner determinism + persistence round-trips
# --------------------------------------------------------------------------

WORKLOADS = (S.Workload("scan", "angular", 8, q=4, n=3000, d=16, k=K),)


class TestTunerAndRoundTrips:
    def test_tuner_is_deterministic(self):
        """Same backend + seed + timer ⇒ bit-identical tables."""
        a = AT.autotune(WORKLOADS, seed=0, timer=AT.estimate_timer)
        b = AT.autotune(WORKLOADS, seed=0, timer=AT.estimate_timer)
        assert a.to_dict() == b.to_dict()
        assert a.table_hash() == b.table_hash()

    def test_json_round_trip_bit_exact(self, tmp_path):
        table = AT.autotune(WORKLOADS, seed=0, timer=AT.estimate_timer)
        p = tmp_path / "TUNE.json"
        table.to_json(p)
        back = TuneTable.from_json(p)
        assert back.to_dict() == table.to_dict()
        assert back.table_hash() == table.table_hash()

    def test_json_version_gate(self):
        doc = _tiny_table().to_dict()
        doc["version"] = 99
        with pytest.raises(ValueError, match="version"):
            TuneTable.from_dict(doc)

    def test_hash_ignores_timings_but_not_dispatch(self):
        key = ("scan", "angular", 8, 8, 3000, 16)
        a = _tiny_table({key: TuneConfig("scan", chunk=1024,
                                           measured_us=1.0)})
        b = _tiny_table({key: TuneConfig("scan", chunk=1024,
                                           measured_us=99.0)})
        c = _tiny_table({key: TuneConfig("scan", chunk=2048,
                                           measured_us=1.0)})
        assert a.table_hash() == b.table_hash() != c.table_hash()

    def test_npz_round_trip_via_saved_index(self, tmp_path, corpus):
        table = _tiny_table({("scan", "ip", 8, 8, 3000, 16):
                               TuneConfig("scan", chunk=1024,
                                          measured_us=12.5)})
        T.install(table)
        idx = make_index("flat,lpq8", corpus, metric="ip")
        path = tmp_path / "idx.npz"
        idx.save(str(path))

        T.clear()
        assert T.active() is None
        before = T.COUNTERS["tune_adopted"]
        idx2 = load_index(str(path))
        assert T.COUNTERS["tune_adopted"] == before + 1
        assert T.active() is not None
        assert T.active().to_dict() == table.to_dict()
        assert idx2.n == idx.n

    def test_stamp_mismatch_parks_not_crashes(self, tmp_path, corpus):
        """A table measured on a foreign backend is parked for the
        maintenance re-tune trigger; dispatch keeps its configs."""
        foreign = TuneTable(stamp=_foreign_stamp())
        foreign.put("scan", "ip", 8, 8, 3000, 16,
                    TuneConfig("scan", chunk=1024))
        before = T.COUNTERS["tune_adopt_mismatch"]
        assert T.adopt(foreign) is False
        assert T.COUNTERS["tune_adopt_mismatch"] == before + 1
        assert T.active() is None                      # not installed
        assert T.pending_mismatch() is foreign         # parked

        # the same protocol through a saved index
        T.clear()
        T.install(foreign)      # force the foreign table into the save
        idx = make_index("flat,lpq8", corpus, metric="ip")
        path = tmp_path / "idx.npz"
        idx.save(str(path))
        T.clear()
        load_index(str(path))
        assert T.active() is None
        assert T.pending_mismatch() is not None

    def test_stamp_integration(self):
        """runtime.profile.stamp() reports the active table's hash (the
        trend.py comparability key)."""
        assert rtprofile.stamp()["tune_table"] is None
        table = _tiny_table({("scan", "ip", 8, 8, 3000, 16):
                               TuneConfig("scan", chunk=1024)})
        T.install(table)
        assert rtprofile.stamp()["tune_table"] == table.table_hash()


# --------------------------------------------------------------------------
# dispatch integration
# --------------------------------------------------------------------------

class TestDispatch:
    def test_tuned_scan_is_bit_identical(self, corpus, queries):
        idx = make_index("flat,lpq8", corpus, metric="ip")
        s0, i0, st0 = engine.topk(jnp_q := np.asarray(queries),
                                  idx.store, K, "ip")
        assert st0["tuned"] is False

        table = _tiny_table({("fused_topk", "ip", 8, len(queries),
                                idx.store.n, 16):
                               TuneConfig("scan", chunk=1024)})
        with T.pinned(table):
            s1, i1, st1 = engine.topk(jnp_q, idx.store, K, "ip")
        assert st1["tuned"] is True
        assert st1["chunks"] > st0["chunks"]           # config really used
        np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))

    def test_tile_query_routing(self):
        from repro.kernels import ops as Kops

        fb = T.fallback("fused_topk").bq
        assert Kops.fused_query_tile() == fb
        table = _tiny_table({("fused_topk", "ip", 8, 64, 8192, 64):
                               TuneConfig("fused", bq=64, bn=256)})
        T.install(table)
        assert Kops.fused_query_tile(64, 8192, 64, metric="ip",
                                     bits=8) == 64
        # a bucket the table never measured -> fallback constants
        assert Kops.fused_query_tile(64, 8192, 128, metric="ip",
                                     bits=8) == fb

    def test_lookup_counters(self):
        table = _tiny_table({("scan", "ip", 8, 8, 3000, 16):
                               TuneConfig("scan", chunk=1024)})
        T.install(table)
        hits, misses = (T.COUNTERS["tune_lookup_hit"],
                        T.COUNTERS["tune_lookup_miss"])
        assert T.lookup("scan", "ip", 8, 8, 3000, 16) is not None
        assert T.lookup("scan", "l2", 8, 8, 3000, 16) is None
        assert T.COUNTERS["tune_lookup_hit"] == hits + 1
        assert T.COUNTERS["tune_lookup_miss"] == misses + 1

    def test_searcher_pins_table_at_plan_time(self, corpus, queries):
        """A plan freezes the table active at construction; installing
        or clearing afterwards cannot change its compiled shapes."""
        idx = make_index("flat,lpq8", corpus, metric="ip")
        table = _tiny_table({("fused_topk", "ip", 8,
                                T.bucket(len(queries)), idx.store.n, 16):
                               TuneConfig("scan", chunk=1024)})
        T.install(table)
        searcher = idx.searcher(K, batch_sizes=(len(queries),))
        T.clear()                                      # after plan time
        res = searcher(queries)
        assert res.stats["tuned"] is True

        # and the inverse: a plan made untuned stays untuned
        untuned = idx.searcher(K, batch_sizes=(len(queries),))
        T.install(table)
        res2 = untuned(queries)
        assert res2.stats["tuned"] is False
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(res2.ids))


# --------------------------------------------------------------------------
# maintenance re-tune trigger
# --------------------------------------------------------------------------

class TestMaintenanceRetune:
    def test_pending_mismatch_triggers_retune(self, corpus):
        idx = make_index("stream(flat,lpq8)", corpus, metric="ip")
        fresh = _tiny_table({("scan", "ip", 8, 8, 3000, 16):
                               TuneConfig("scan", chunk=1024)})
        sched = MaintenanceScheduler(idx, retune_fn=lambda: fresh)

        assert sched.run_once() == {"ran": False}      # nothing pending

        T.adopt(TuneTable(stamp=_foreign_stamp()))     # parks
        out = sched.run_once()
        assert out["trigger"] == "tune" and out["swapped"] is True
        assert out["table_hash"] == fresh.table_hash()
        assert sched.counters["maintenance_retunes"] == 1
        assert T.active() is fresh                     # re-tune installed
        assert T.pending_mismatch() is None            # pending consumed
        assert sched.run_once() == {"ran": False}      # trigger cleared

    def test_no_retune_fn_means_no_trigger(self, corpus):
        idx = make_index("stream(flat,lpq8)", corpus, metric="ip")
        sched = MaintenanceScheduler(idx)
        T.adopt(TuneTable(stamp=_foreign_stamp()))
        assert sched.run_once() == {"ran": False}
