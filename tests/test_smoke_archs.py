"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + finite values (no NaNs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get, ASSIGNED
from repro.data import graph_data, lm_data, recsys_data

LM_ARCHS = [a for a in ASSIGNED if get(a).FAMILY == "lm"]
RECSYS_ARCHS = [a for a in ASSIGNED if get(a).FAMILY == "recsys"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as TF

    cfg = get(arch).reduced_config()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    batch = lm_data.lm_batch(jax.random.PRNGKey(1), 2, 32, cfg.vocab)

    logits, aux = TF.forward(params, batch["tokens"], cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), "NaN/inf in logits"

    loss, _ = TF.lm_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    # one train step
    grads = jax.grad(lambda p: TF.lm_loss(p, batch, cfg)[0])(params)
    gn = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gn)) and float(gn) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_smoke(arch):
    from repro.models import transformer as TF

    cfg = get(arch).reduced_config()
    params = TF.init_params(jax.random.PRNGKey(0), cfg)
    toks = lm_data.lm_batch(jax.random.PRNGKey(1), 2, 16, cfg.vocab)["tokens"]
    _, caches = TF.prefill(params, toks[:, :8], cfg)
    kc, vc = TF.make_cache(cfg, 2, 16, dtype=jnp.float32)
    kc = TF.write_prefix(kc, caches[0])
    vc = TF.write_prefix(vc, caches[1])
    logits, _ = TF.decode_step(params, (kc, vc), toks[:, 8:9], jnp.int32(8), cfg)
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits[:, : cfg.vocab])).all()


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    from repro.models.recsys import models as RM

    cfg = get(arch).reduced_config()
    params = RM.init_params(jax.random.PRNGKey(0), cfg)
    batch = recsys_data.ctr_batch(
        jax.random.PRNGKey(1), 16, cfg.n_dense, cfg.vocab_sizes, seq_len=cfg.seq_len
    )
    logit = RM.forward(params, batch, cfg)
    assert logit.shape == (16,)
    assert np.isfinite(np.asarray(logit)).all()

    loss, _ = RM.bce_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: RM.bce_loss(p, batch, cfg)[0])(params)
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))

    probs = RM.serve(params, batch, cfg)
    assert ((np.asarray(probs) >= 0) & (np.asarray(probs) <= 1)).all()


def test_schnet_molecule_smoke():
    from repro.models.gnn import schnet as S

    cfg = get("schnet").reduced_config("molecule")
    params = S.init_params(jax.random.PRNGKey(0), cfg)
    mol = graph_data.random_molecules(4, 6, 12)
    gids = jnp.repeat(jnp.arange(4), 6)
    loss = S.energy_loss(params, cfg, mol, gids, 4)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: S.energy_loss(p, cfg, mol, gids, 4))(params)
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))


def test_schnet_feature_graph_smoke():
    from repro.models.gnn import schnet as S

    cfg = get("schnet").reduced_config("full_graph_sm")
    params = S.init_params(jax.random.PRNGKey(0), cfg)
    g = graph_data.random_graph(64, 256, 24)
    g.labels = jnp.clip(g.labels, 0, cfg.n_classes - 1)
    loss = S.node_class_loss(params, cfg, g)
    assert np.isfinite(float(loss))
    out = S.forward(
        params, cfg, senders=g.senders, receivers=g.receivers,
        edge_mask=g.edge_mask, n_nodes=g.n_nodes, node_feat=g.node_feat,
    )
    assert out.shape == (64, cfg.n_classes)


def test_schnet_minibatch_sampler_smoke():
    """The minibatch_lg regime: sampler -> padded subgraph -> train step."""
    from repro.models.gnn import schnet as S

    cfg = get("schnet").reduced_config("full_graph_sm")
    g = graph_data.random_graph(500, 4000, 24)
    sampler = graph_data.NeighborSampler(
        np.asarray(g.senders), np.asarray(g.receivers), 500
    )
    nodes, layers = sampler.sample(
        np.arange(8), fanouts=(5, 3), rng=np.random.default_rng(0)
    )
    # flatten sampled layers into one edge list over local node ids
    s = np.concatenate([l[0] for l in layers])
    r = np.concatenate([l[1] for l in layers])
    m = np.concatenate([l[2] for l in layers])
    params = S.init_params(jax.random.PRNGKey(0), cfg)
    out = S.forward(
        params, cfg,
        senders=jnp.asarray(s), receivers=jnp.asarray(r),
        edge_mask=jnp.asarray(m), n_nodes=len(nodes),
        node_feat=g.node_feat[jnp.asarray(nodes)],
    )
    assert out.shape == (len(nodes), cfg.n_classes)
    assert np.isfinite(np.asarray(out)).all()


def test_registry_covers_assignment():
    assert len(ASSIGNED) == 10
    from repro.configs import cells

    cs = cells()
    assert len(cs) == 40, f"expected 40 cells, got {len(cs)}"
    skips = [(a, s) for a, s, reason in cs if reason]
    assert ("gemma-2b", "long_500k") in skips
    assert ("minicpm-2b", "long_500k") in skips
    assert len(skips) == 2


def test_exact_assigned_hyperparams():
    """Full configs carry the exact published hyperparameters."""
    from repro.models.transformer import LMConfig

    g2b: LMConfig = get("gemma-2b").config()
    assert (g2b.n_layers, g2b.d_model, g2b.n_heads, g2b.n_kv) == (18, 2048, 8, 1)
    assert (g2b.head_dim, g2b.d_ff, g2b.vocab) == (256, 16384, 256000)

    g9: LMConfig = get("gemma2-9b").config()
    assert (g9.n_layers, g9.d_model, g9.n_heads, g9.n_kv) == (42, 3584, 16, 8)
    assert (g9.d_ff, g9.vocab, g9.attn_softcap) == (14336, 256000, 50.0)
    assert g9.layer_pattern == "lg"

    mc: LMConfig = get("minicpm-2b").config()
    assert (mc.n_layers, mc.d_model, mc.n_heads, mc.n_kv) == (40, 2304, 36, 36)
    assert (mc.d_ff, mc.vocab) == (5760, 122753)

    for arch, n_exp in [("llama4-scout-17b-16e", 16), ("llama4-maverick-400b-17b", 128)]:
        l4: LMConfig = get(arch).config()
        assert (l4.n_layers, l4.d_model, l4.n_heads, l4.n_kv) == (48, 5120, 40, 8)
        assert (l4.d_ff, l4.vocab) == (8192, 202048)
        assert l4.moe.n_experts == n_exp and l4.moe.top_k == 1

    sn = get("schnet").config("molecule")
    assert (sn.n_interactions, sn.d_hidden, sn.n_rbf, sn.cutoff) == (3, 64, 300, 10.0)

    ai = get("autoint").config()
    assert (ai.n_sparse, ai.embed_dim, ai.n_attn_layers, ai.n_heads, ai.d_attn) == (
        39, 16, 3, 2, 32,
    )

    dl = get("dlrm-mlperf").config()
    assert (dl.n_dense, dl.n_sparse, dl.embed_dim) == (13, 26, 128)
    assert dl.bot_mlp == (512, 256, 128) and dl.top_mlp == (1024, 1024, 512, 256, 1)

    di = get("dien").config()
    assert (di.embed_dim, di.seq_len, di.gru_dim, di.mlp) == (18, 100, 108, (200, 80))

    dc = get("dcn-v2").config()
    assert (dc.n_dense, dc.n_sparse, dc.embed_dim, dc.n_cross_layers) == (13, 26, 16, 3)
    assert dc.mlp == (1024, 1024, 512)
