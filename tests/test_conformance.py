"""Registry-wide conformance suite: one contract matrix over every
registered factory string.

Every kind the registry can build — at every storage width, with and
without quantization, rerank stores, and the stream wrapper — must honor
the same contracts: SearchResult shape/dtype/id-validity, bit-exact
save -> load -> search round-trips, ``searcher()`` parity with the
one-shot ``Index.search`` path, the uniform stats-key schema of the
scoring engine, and positive honest memory accounting.  Adding a factory
arm to ``FACTORIES`` is all a future kind needs to inherit this coverage
— no per-kind test files.
"""

import jax
import numpy as np
import pytest

from repro.knn import SearchParams, kinds, load_index, make_index, parse_factory

K = 10
N, D = 384, 32

#: factory string -> build overrides; every registered kind appears at
#: least once, quantized arms ride next to their fp32 siblings
FACTORIES = {
    "flat": {},
    "flat,lpq8@gaussian:3": {},
    "flat,lpq4+r32": {},
    "ivf8,lpq8@gaussian:3": {"kmeans_iters": 4},
    "hnsw8,lpq8@gaussian:3": {"ef_construction": 40, "batch_size": 128},
    "graph16,lpq8@gaussian:3": {"n_seeds": 16},
    "pq16": {"kmeans_iters": 4},
    "pq16+lpq": {"kmeans_iters": 4},
    # the l2 arms guard batch-composition independence: a zero pad query's
    # negated-L2 LUT is large, so a batch-global quantization scale would
    # break padded-searcher-vs-eager parity (the scale is per query)
    "pq16+lpq,l2": {"kmeans_iters": 4},
    "pq16x4": {"kmeans_iters": 4},
    "pq16x4,lpq8": {"kmeans_iters": 4},
    "pq16x4,lpq8,l2": {"kmeans_iters": 4},
    "pq16x4+lpq,r32": {"kmeans_iters": 4},
    "stream(flat,lpq4)+r32": {"seal_threshold": 128},
    "stream(pq16x4,lpq8)+r32": {"seal_threshold": 128, "kmeans_iters": 4},
    # the cascade subsystem (DESIGN.md §14): multi-stage refinement ...
    "cascade(flat,lpq4|r32)": {},
    "cascade(pq16x4|lpq8|r32)": {"kmeans_iters": 4},
    # ... including as a stream inner (each sealed segment is a cascade)
    "stream(cascade(flat,lpq8|r32))": {"seal_threshold": 128},
    # ... and density-aware per-region Eq. 1 constants on every
    # partitioned kind
    "ivf8,lpq8,regions": {"kmeans_iters": 4},
    "hnsw8,lpq8,regions": {"ef_construction": 40, "batch_size": 128},
    "graph16,lpq4,regions": {"n_seeds": 16},
}

#: stats keys every search result must carry (the PR 2 engine schema);
#: non-stream kinds also report the storage-width keys
CORE_STATS = ("kind", "candidates", "chunks", "bytes_read")
WIDTH_STATS = ("bits", "packed")
SEARCHER_STATS = ("bucket", "padded_q", "shards", "reranked")


@pytest.fixture(scope="module")
def corpus_queries():
    corpus = jax.random.normal(jax.random.PRNGKey(0), (N, D)) * 0.05
    queries = jax.random.normal(jax.random.PRNGKey(1), (8, D)) * 0.05
    return corpus, queries


@pytest.fixture(scope="module")
def built(corpus_queries):
    corpus, _q = corpus_queries
    return {
        factory: make_index(factory, corpus, key=jax.random.PRNGKey(0), **over)
        for factory, over in FACTORIES.items()
    }


def test_matrix_covers_every_registered_kind():
    covered = {parse_factory(f).kind for f in FACTORIES}
    covered |= {
        parse_factory(parse_factory(f).params["inner"]).kind
        for f in FACTORIES
        if parse_factory(f).kind == "stream"
    }
    assert covered == set(kinds()), (
        "every registered kind must appear in the conformance matrix "
        f"(missing: {set(kinds()) - covered})"
    )


@pytest.mark.parametrize("factory", sorted(FACTORIES))
def test_search_contract(factory, corpus_queries, built):
    """Shape, dtype and id-validity of the uniform SearchResult."""
    _corpus, queries = corpus_queries
    res = built[factory].search(queries, K, SearchParams(nprobe=8, ef_search=40))
    assert res.scores.shape == (queries.shape[0], K)
    assert res.ids.shape == (queries.shape[0], K)
    assert str(res.scores.dtype) == "float32"
    assert str(res.ids.dtype) == "int32"
    ids = np.asarray(res.ids)
    assert ids.min() >= -1 and ids.max() < N, factory
    # a corpus larger than k must fill every slot with a real row
    assert (ids >= 0).all(), factory


@pytest.mark.parametrize("factory", sorted(FACTORIES))
def test_stats_schema(factory, corpus_queries, built):
    """The uniform engine accounting block rides on every result."""
    _corpus, queries = corpus_queries
    res = built[factory].search(queries, K, SearchParams(nprobe=8, ef_search=40))
    for key in CORE_STATS:
        assert key in res.stats, (factory, key)
    assert res.stats["kind"] == parse_factory(factory).kind
    if parse_factory(factory).kind != "stream":
        for key in WIDTH_STATS:
            assert key in res.stats, (factory, key)
    assert res.stats["bytes_read"] >= 0


@pytest.mark.parametrize("factory", sorted(FACTORIES))
def test_save_load_search_bit_parity(factory, corpus_queries, built, tmp_path):
    _corpus, queries = corpus_queries
    idx = built[factory]
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    restored = load_index(path)
    sp = SearchParams(nprobe=8, ef_search=40)
    a = idx.search(queries, K, sp)
    b = restored.search(queries, K, sp)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    assert restored.memory_bytes() == idx.memory_bytes()


@pytest.mark.parametrize("factory", sorted(FACTORIES))
def test_searcher_matches_one_shot(factory, corpus_queries, built):
    """A planned (bucketed, padded) session returns exactly what the
    eager one-shot path returns, and reports the session schema."""
    _corpus, queries = corpus_queries
    idx = built[factory]
    sp = SearchParams(nprobe=8, ef_search=40)
    eager = idx.search(queries, K, sp)
    planned = idx.searcher(K, sp, batch_sizes=(4, 16))(queries)
    np.testing.assert_array_equal(np.asarray(eager.ids),
                                  np.asarray(planned.ids))
    np.testing.assert_array_equal(np.asarray(eager.scores),
                                  np.asarray(planned.scores))
    for key in SEARCHER_STATS:
        assert key in planned.stats, (factory, key)


@pytest.mark.parametrize("factory", sorted(FACTORIES))
def test_memory_bytes_positive(factory, built):
    assert built[factory].memory_bytes() > 0


def test_pq16x4_is_half_the_code_bytes_of_pq16x8(corpus_queries, built):
    """The acceptance property: 4-bit codewords pack two per byte, so the
    code matrix is exactly half the 8-bit arm's (and the 16-entry
    codebooks are 16x smaller, so total memory drops too)."""
    x4 = built["pq16x4"].store
    x8 = built["pq16"].store
    assert x4.code_bytes * 2 == x8.code_bytes
    assert built["pq16x4"].memory_bytes() < built["pq16"].memory_bytes()


def test_stream_pq16x4_mutates_and_roundtrips(corpus_queries, built, tmp_path):
    """The acceptance arm end-to-end: stream(pq16x4,lpq8)+r32 survives
    upsert/delete, a searcher session, and a save/load round-trip."""
    corpus, queries = corpus_queries
    idx = make_index("stream(pq16x4,lpq8)+r32", corpus, seal_threshold=128,
                     kmeans_iters=4, key=jax.random.PRNGKey(0))
    idx.upsert(np.arange(N, N + 64),
               np.asarray(jax.random.normal(jax.random.PRNGKey(2), (64, D)))
               * 0.05)
    idx.delete(np.arange(16))
    path = str(tmp_path / "stream_pq.npz")
    idx.save(path)
    restored = load_index(path)
    a = idx.searcher(K)(queries)
    b = restored.searcher(K)(queries)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    ids = np.asarray(a.ids)
    assert (ids >= 0).all() and ids.max() < N + 64
    assert not np.isin(ids, np.arange(16)).any(), "deleted rows resurfaced"


# --------------------------------------------------------------------------
# sharded parity matrix (DESIGN.md §15): every kind, 2- and 4-device meshes
# --------------------------------------------------------------------------

#: representative arm per registered kind (plus regional / packed / l2
#: variants) for the sharded-vs-unsharded bit-parity matrix
SHARDED_ARMS = {
    "flat,lpq4": {},
    "ivf8,lpq8": {"kmeans_iters": 4},
    "ivf8,lpq8,regions": {"kmeans_iters": 4},
    "pq16x4,lpq8": {"kmeans_iters": 4},
    "pq16+lpq,l2": {"kmeans_iters": 4},
    "hnsw8,lpq8,regions": {"ef_construction": 40, "batch_size": 128},
    "graph16,lpq4,regions": {"n_seeds": 16},
    "stream(ivf8,lpq8)+r32": {"seal_threshold": 128, "kmeans_iters": 4},
    "cascade(flat,lpq4|r32)": {},
}


@pytest.mark.slow
def test_sharded_parity_matrix_subprocess():
    """Every registry kind bit-matches its unsharded twin under 2- and
    4-virtual-device meshes (one subprocess: the in-process backend is
    already pinned to this host's device count)."""
    import os
    import subprocess
    import sys
    import textwrap

    covered = {parse_factory(f).kind for f in SHARDED_ARMS}
    covered |= {
        parse_factory(parse_factory(f).params["inner"]).kind
        for f in SHARDED_ARMS
        if parse_factory(f).kind == "stream"
    }
    assert covered == set(kinds()), (
        f"sharded parity matrix must cover every kind "
        f"(missing: {set(kinds()) - covered})"
    )

    prog = textwrap.dedent(f"""
        import jax, numpy as np
        from repro.knn import SearchParams, make_index
        assert len(jax.devices()) == 4, jax.devices()
        ARMS = {SHARDED_ARMS!r}
        corpus = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (384, 32))) * 0.05
        queries = np.asarray(
            jax.random.normal(jax.random.PRNGKey(1), (8, 32))) * 0.05
        sp = SearchParams(nprobe=8, ef_search=40)
        for factory, over in ARMS.items():
            idx = make_index(factory, corpus, key=jax.random.PRNGKey(0), **over)
            un = idx.searcher(10, sp)(queries)
            for s in (2, 4):
                mesh = jax.make_mesh((s,), ("data",))
                sh = idx.searcher(10, sp, shards=mesh)(queries)
                np.testing.assert_array_equal(
                    np.asarray(un.ids), np.asarray(sh.ids),
                    err_msg=f"{{factory}} ids @ {{s}} shards")
                np.testing.assert_array_equal(
                    np.asarray(un.scores), np.asarray(sh.scores),
                    err_msg=f"{{factory}} scores @ {{s}} shards")
                assert sh.stats["shards"] == s
                assert "placement" in sh.stats, factory
        print("PARITY-OK")
    """)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", "")}
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=1200, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PARITY-OK" in out.stdout


# --------------------------------------------------------------------------
# filtered search matrix (DESIGN.md §16): every factory arm, three
# selectivities, oracle-verified on ids AND scores
# --------------------------------------------------------------------------

#: filter densities the matrix runs at: survivors < k (0.02 on N=384
#: leaves ~8 rows), a mid-band filter, and a nearly-transparent one
SELECTIVITIES = (0.02, 0.25, 0.9)

NEG = float(np.finfo(np.float32).min)


def _filter_for(sel: float):
    from repro.filter import Filter

    rng = np.random.default_rng(int(sel * 1000) + 7)
    mask = rng.random(N) < sel
    if not mask.any():
        mask[0] = True
    return Filter.from_mask(mask)


def _depth_searcher(idx, k, sp):
    """A one-shot searcher whose rerank/settling depth is forced to the
    full corpus, so every arm that owns a re-scoring stage ranks ALL its
    candidates — the exhaustive configuration the oracle comparison
    needs (approximation error would otherwise alias as filter error)."""
    kw = {}
    if getattr(idx, "handles_rerank", False) or \
            getattr(idx, "rerank_store", None) is not None:
        kw["rerank"] = N
    return idx.searcher(k, sp, batch_sizes=None, strict=False, **kw)


def _post_filter(scores, ids, allow, k):
    """The brute-force oracle: the arm's own full ranking (k = N, every
    candidate scored in the arm's final scoring space), post-filtered to
    the allowed rows and cut to k — ``scores_among`` over survivors."""
    Q = scores.shape[0]
    out_s = np.full((Q, k), NEG, np.float32)
    out_i = np.full((Q, k), -1, np.int32)
    for r in range(Q):
        j = 0
        for s, i in zip(scores[r], ids[r]):
            if j == k:
                break
            if i >= 0 and allow[i]:
                out_s[r, j] = s
                out_i[r, j] = i
                j += 1
    return out_s, out_i


def _assert_oracle_match(scores, ids, oscores, oids, msg):
    """Bit-match on scores; ids must agree exactly up to permutation
    within equal-score tie groups (quantized scores tie legitimately,
    and candidate enumeration order inside a tie is not part of the
    contract)."""
    np.testing.assert_array_equal(scores, oscores, err_msg=msg)
    for r in range(scores.shape[0]):
        s = scores[r]
        start = 0
        while start < len(s):
            stop = start
            while stop < len(s) and s[stop] == s[start]:
                stop += 1
            assert sorted(ids[r][start:stop].tolist()) == \
                sorted(oids[r][start:stop].tolist()), \
                f"{msg}: tie-group ids diverge at row {r} cols " \
                f"[{start}:{stop}]"
            start = stop


def test_filtered_matrix_covers_every_registered_kind():
    """The filtered matrix runs over FACTORIES, which must enumerate the
    full registry — a new kind cannot dodge filter conformance."""
    covered = {parse_factory(f).kind for f in FACTORIES}
    covered |= {
        parse_factory(parse_factory(f).params["inner"]).kind
        for f in FACTORIES
        if parse_factory(f).kind == "stream"
    }
    assert covered == set(kinds()), (
        f"filtered conformance must cover every kind "
        f"(missing: {set(kinds()) - covered})"
    )


@pytest.mark.parametrize("sel", SELECTIVITIES)
@pytest.mark.parametrize("factory", sorted(FACTORIES))
def test_filtered_search_matches_post_filter_oracle(
        factory, sel, corpus_queries, built):
    """Filtered search == the arm's own exhaustive ranking post-filtered
    to survivors, bit-exact on scores and (tie-robustly) on ids.  ef is
    pinned to N so walk kinds enumerate their whole component and the
    filter acts as a pure id-mask on the candidate stream; cascade
    budgets are pinned wide so no stage prunes an allowed candidate."""
    _corpus, queries = corpus_queries
    idx = built[factory]
    filt = _filter_for(sel)
    allow = np.asarray(filt.mask)
    budgets = None
    if parse_factory(factory).kind == "cascade":
        n_stages = len(getattr(idx, "stage_stores"))
        budgets = (N,) * n_stages
    sp_plain = SearchParams(nprobe=8, ef_search=N, budgets=budgets)
    sp_filt = SearchParams(nprobe=8, ef_search=N, budgets=budgets,
                           filter=filt)

    full = _depth_searcher(idx, N, sp_plain)(queries)
    oscores, oids = _post_filter(np.asarray(full.scores),
                                 np.asarray(full.ids), allow, K)
    res = _depth_searcher(idx, K, sp_filt)(queries)
    scores, ids = np.asarray(res.scores), np.asarray(res.ids)

    live = ids >= 0
    assert allow[ids[live]].all(), f"{factory}@{sel}: disallowed id returned"
    assert res.stats.get("filter_selectivity") is not None, factory
    _assert_oracle_match(scores, ids, oscores, oids, f"{factory}@{sel}")


@pytest.mark.slow
def test_filtered_sharded_parity_matrix_subprocess():
    """Every SHARDED_ARMS arm, filtered at each selectivity, bit-matches
    its unsharded filtered twin on 2- and 4-device meshes."""
    import os
    import subprocess
    import sys
    import textwrap

    prog = textwrap.dedent(f"""
        import jax, numpy as np
        from repro.filter import Filter
        from repro.knn import SearchParams, make_index
        assert len(jax.devices()) == 4, jax.devices()
        ARMS = {SHARDED_ARMS!r}
        SELS = {SELECTIVITIES!r}
        N = {N}
        corpus = np.asarray(
            jax.random.normal(jax.random.PRNGKey(0), (N, {D}))) * 0.05
        queries = np.asarray(
            jax.random.normal(jax.random.PRNGKey(1), (8, {D}))) * 0.05
        for factory, over in ARMS.items():
            idx = make_index(factory, corpus, key=jax.random.PRNGKey(0), **over)
            for sel in SELS:
                rng = np.random.default_rng(int(sel * 1000) + 7)
                mask = rng.random(N) < sel
                if not mask.any():
                    mask[0] = True
                sp = SearchParams(nprobe=8, ef_search=40,
                                  filter=Filter.from_mask(mask))
                un = idx.searcher(10, sp)(queries)
                ids = np.asarray(un.ids)
                live = ids >= 0
                assert mask[ids[live]].all(), (factory, sel)
                for s in (2, 4):
                    mesh = jax.make_mesh((s,), ("data",))
                    sh = idx.searcher(10, sp, shards=mesh)(queries)
                    np.testing.assert_array_equal(
                        np.asarray(un.ids), np.asarray(sh.ids),
                        err_msg=f"{{factory}}@{{sel}} ids @ {{s}} shards")
                    np.testing.assert_array_equal(
                        np.asarray(un.scores), np.asarray(sh.scores),
                        err_msg=f"{{factory}}@{{sel}} scores @ {{s}} shards")
        print("FILTER-PARITY-OK")
    """)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4",
           "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", "")}
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=1800, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FILTER-PARITY-OK" in out.stdout
