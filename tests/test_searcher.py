"""Searcher query-plan API (DESIGN.md §9): plan-once/execute-many parity
with eager search across every kind and mixed batch sizes, compilation
bucketing (trace counts), plan-time validation, the rerank tail's recall
recovery, sharded-vs-unsharded id parity, the ``+rN`` factory suffix, and
the save/load -> searcher round-trip."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import (
    Rerank,
    SearchParams,
    Searcher,
    load_index,
    make_index,
    parse_factory,
)

K = 10

# per-kind factory string + build overrides kept small for CI; the lpq4
# arms exercise packed stores through the plan path
CASES = {
    "flat": ("flat,lpq4+r32", {}),
    "ivf": ("ivf8,lpq4", {"kmeans_iters": 4}),
    "hnsw": ("hnsw8,lpq8@gaussian:3", {"ef_construction": 40, "batch_size": 128}),
    "graph": ("graph16,lpq8@gaussian:3", {"n_seeds": 16}),
    "pq": ("pq16+lpq,r32", {"kmeans_iters": 4}),
}

SP = SearchParams(nprobe=8, ef_search=40, chunk=256)


@pytest.fixture(scope="module")
def corpus_queries():
    corpus = jax.random.normal(jax.random.PRNGKey(0), (512, 32)) * 0.05
    queries = jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * 0.05
    return corpus, queries


@pytest.fixture(scope="module")
def built(corpus_queries):
    corpus, _q = corpus_queries
    return {
        kind: make_index(factory, corpus, key=jax.random.PRNGKey(0), **over)
        for kind, (factory, over) in CASES.items()
    }


# --------------------------------------------------------------------------
# plan/execute parity + bucketing
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(CASES))
def test_one_plan_serves_mixed_batches(kind, corpus_queries, built):
    """The acceptance property: a plan built once serves batch sizes
    1 / 7 / 32 with ids identical to eager ``index.search``."""
    _corpus, queries = corpus_queries
    idx = built[kind]
    searcher = idx.searcher(K, SP, batch_sizes=(1, 8, 32))
    for q in (queries[:1], queries[:7], queries):
        eager = idx.search(q, K, SP)
        planned = searcher(q)
        np.testing.assert_array_equal(
            np.asarray(eager.ids), np.asarray(planned.ids)
        )
        np.testing.assert_allclose(
            np.asarray(eager.scores), np.asarray(planned.scores), rtol=1e-6
        )
        # the Searcher accounting block rides on every result
        for field in ("bucket", "padded_q", "shards", "reranked"):
            assert field in planned.stats, (kind, field)
    # 7 queries pad into the 8-bucket
    assert searcher(queries[:7]).stats["bucket"] == 8
    assert searcher(queries[:7]).stats["padded_q"] == 1


def test_same_bucket_calls_do_not_retrace(corpus_queries, built):
    """Repeated same-bucket requests reuse the compiled executable; a new
    bucket compiles exactly one more."""
    _corpus, queries = corpus_queries
    searcher = built["flat"].searcher(K, SP, batch_sizes=(8, 32))
    for _ in range(4):
        searcher(queries[:5])                    # all pad into bucket 8
    assert searcher.trace_counts == {8: 1}
    searcher(queries[:20])                       # bucket 32: one new trace
    searcher(queries[:32])
    assert searcher.trace_counts == {8: 1, 32: 1}


def test_oversized_requests_run_in_max_bucket_slices(corpus_queries, built):
    _corpus, queries = corpus_queries
    idx = built["flat"]
    searcher = idx.searcher(K, SP, batch_sizes=(1, 8))
    res = searcher(queries[:27])                 # 8+8+8+(3 padded to 8)
    assert res.ids.shape == (27, K)
    assert searcher.trace_counts == {8: 1}       # every slice hit one bucket
    np.testing.assert_array_equal(
        np.asarray(res.ids), np.asarray(idx.search(queries[:27], K, SP).ids)
    )
    assert res.stats["padded_q"] == 5


# --------------------------------------------------------------------------
# plan-time validation
# --------------------------------------------------------------------------

def test_plan_time_validation(corpus_queries, built):
    _corpus, queries = corpus_queries
    idx = built["flat"]
    with pytest.raises(ValueError, match="k must be a positive int"):
        idx.searcher(0)
    with pytest.raises(ValueError, match="k must be a positive int"):
        idx.searcher(-3)
    with pytest.raises(ValueError, match="exceeds the corpus size"):
        idx.searcher(idx.n + 1)
    with pytest.raises(ValueError, match="chunk must be a positive int"):
        idx.searcher(K, SearchParams(chunk=0))
    with pytest.raises(ValueError, match="nprobe must be a positive int"):
        idx.searcher(K, SearchParams(nprobe=-1))
    with pytest.raises(ValueError, match="ef_search must be a positive int"):
        idx.searcher(K, SearchParams(ef_search=0))
    with pytest.raises(ValueError, match="batch_sizes"):
        idx.searcher(K, batch_sizes=())
    searcher = idx.searcher(K, SP)
    with pytest.raises(ValueError, match="empty query batch"):
        searcher(np.zeros((0, 32), np.float32))
    with pytest.raises(ValueError, match="query dim"):
        searcher(np.zeros((4, 16), np.float32))
    with pytest.raises(ValueError, match=r"queries must be \[Q, d\]"):
        searcher(np.zeros((32,), np.float32))


def test_rerank_argument_validation(corpus_queries, built):
    corpus, _q = corpus_queries
    plain = make_index("flat,lpq8@gaussian:3", corpus)
    with pytest.raises(ValueError, match="no rerank store"):
        plain.searcher(K, rerank=64)
    with pytest.raises(ValueError, match="no rerank store"):
        plain.searcher(K, rerank=True)
    from repro.engine import CodeStore

    with pytest.raises(ValueError, match="id space"):
        plain.searcher(K, rerank=Rerank(64, CodeStore.dense(corpus[:100])))
    # explicit Rerank over a matching store works without a +rN build
    s = plain.searcher(K, rerank=Rerank(64, CodeStore.dense(corpus)))
    assert s.rerank is not None and s.rerank.depth == 64


# --------------------------------------------------------------------------
# rerank: §3.4 recall recovery
# --------------------------------------------------------------------------

def test_rerank_strictly_improves_lpq4_recall():
    """``flat,lpq4+r32`` > ``flat,lpq4`` recall@10 on the synthetic
    benchmark corpus (the acceptance criterion)."""
    corpus, queries, metric = synthetic.load("product", 2000, 64)
    corpus, queries = corpus[:, :64], queries[:64, :64]
    gt = np.asarray(make_index("flat", corpus, metric=metric).search(queries, K).ids)
    plain = make_index("flat,lpq4", corpus, metric=metric)
    rer = make_index("flat,lpq4+r32", corpus, metric=metric)
    r_plain = float(recall_at_k(gt, plain.searcher(K)(queries).ids))
    r_rer = float(recall_at_k(gt, rer.searcher(K)(queries).ids))
    assert r_rer > r_plain, (r_plain, r_rer)
    # the tail reports its accounting
    stats = rer.searcher(K)(queries[:8]).stats
    assert stats["reranked"] > 0 and stats["rerank_bits"] == 32


def test_full_depth_rerank_equals_exact_search(corpus_queries):
    """Rerank over the whole corpus == the fp32 exhaustive scan: the
    quantized stage only selects candidates, the fp32 stage orders them."""
    corpus, queries = corpus_queries
    gt = make_index("flat", corpus).search(queries, K)
    rer = make_index("flat,lpq8@gaussian:3,r32", corpus)
    res = rer.searcher(K, rerank=rer.n)(queries)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(gt.ids))
    np.testing.assert_allclose(
        np.asarray(res.scores), np.asarray(gt.scores), rtol=1e-5
    )


def test_rerank_composes_with_every_kind(corpus_queries, built):
    """hnsw/graph walk + compiled rerank tail; ivf probe + tail; pq ADC +
    tail — the tail must keep ids within the walked candidate set and
    never lose recall against ground truth."""
    corpus, queries = corpus_queries
    gt = np.asarray(make_index("flat", corpus).search(queries, K).ids)
    from repro.engine import CodeStore

    store = CodeStore.dense(corpus)
    for kind, idx in built.items():
        base = idx.searcher(K, SP, rerank=False)(queries)
        rer = idx.searcher(K, SP, rerank=Rerank(4 * K, store))(queries)
        r_base = float(recall_at_k(gt, base.ids))
        r_rer = float(recall_at_k(gt, rer.ids))
        assert r_rer >= r_base - 1e-6, (kind, r_base, r_rer)


# --------------------------------------------------------------------------
# sharding
# --------------------------------------------------------------------------

def test_sharded_plan_matches_unsharded(corpus_queries):
    """Row-sharded flat plan == unsharded ids/scores over the devices this
    host exposes (1-device mesh degenerates to the same merge path)."""
    corpus, queries = corpus_queries
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for factory in ("flat", "flat,lpq8@gaussian:3", "flat,lpq4+r32"):
        idx = make_index(factory, corpus)
        un = idx.searcher(K, SP)(queries)
        sh = idx.searcher(K, SP, shards=mesh)(queries)
        np.testing.assert_array_equal(np.asarray(un.ids), np.asarray(sh.ids))
        np.testing.assert_allclose(
            np.asarray(un.scores), np.asarray(sh.scores), rtol=1e-6
        )
        assert sh.stats["shards"] == len(jax.devices())


def test_sharded_plan_every_kind_matches_unsharded(corpus_queries, built):
    """Every registry kind now shards (lists / rows / replicated fan-out)
    and must bit-match its unsharded twin — ids AND scores."""
    corpus, queries = corpus_queries
    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    for kind, idx in built.items():
        un = idx.searcher(K, SP)(queries)
        sh = idx.searcher(K, SP, shards=mesh)(queries)
        np.testing.assert_array_equal(
            np.asarray(un.ids), np.asarray(sh.ids), err_msg=kind
        )
        np.testing.assert_array_equal(
            np.asarray(un.scores), np.asarray(sh.scores), err_msg=kind
        )
        assert sh.stats["placement"] in (
            "rows", "lists", "segments", "replicated"
        ), kind


def test_sharded_plan_rejects_mismatched_placement(corpus_queries, built):
    """A pinned placement must match the index's shard unit — an ivf plan
    refuses a row placement, a graph walk refuses anything non-replicated."""
    from repro.dist.placement import Placement

    mesh = jax.make_mesh((len(jax.devices()),), ("data",))
    n_dev = len(jax.devices())
    with pytest.raises(ValueError, match="place whole lists"):
        built["ivf"].plan(K, SP, mesh=mesh,
                          placement=Placement.rows(built["ivf"].n, n_dev))
    with pytest.raises(ValueError, match="only replicates"):
        built["graph"].plan(K, SP, mesh=mesh,
                            placement=Placement.rows(built["graph"].n, n_dev))


@pytest.mark.slow
def test_sharded_plan_multihost_subprocess():
    """≥2-way host mesh: forces XLA_FLAGS device multiplication in a
    subprocess (the in-process backend is already initialized 1-device)."""
    prog = textwrap.dedent("""
        import jax, numpy as np
        from repro.knn import make_index, SearchParams
        assert len(jax.devices()) == 2, jax.devices()
        corpus = np.random.RandomState(0).randn(300, 16).astype("float32")
        queries = np.random.RandomState(1).randn(9, 16).astype("float32")
        mesh = jax.make_mesh((2,), ("data",))
        # chunk=128 over 150-row shards forces tile padding whose gids
        # alias the next shard's rows (regression: they must be id-masked
        # locally); the int4 arm makes unmasked zero rows actually score
        for factory in ("flat,lpq8@gaussian:3", "flat,lpq4"):
            idx = make_index(factory, corpus)
            un = idx.searcher(20, SearchParams(chunk=128))(queries)
            sh = idx.searcher(20, SearchParams(chunk=128), shards=mesh)(queries)
            np.testing.assert_array_equal(np.asarray(un.ids), np.asarray(sh.ids))
            assert sh.stats["shards"] == 2
        print("OK")
    """)
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
           "PYTHONPATH": "src" + os.pathsep + os.environ.get("PYTHONPATH", "")}
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# --------------------------------------------------------------------------
# factory suffix + save/load round-trip
# --------------------------------------------------------------------------

def test_rerank_factory_fragment_parses_and_roundtrips():
    spec = parse_factory("flat,lpq4+r32")
    assert spec.rerank_bits == 32 and spec.quant.bits == 4
    assert spec.to_factory() == "flat,lpq4+r32"
    spec = parse_factory("ivf64,lpq8+r8,l2")
    assert spec.rerank_bits == 8 and spec.metric == "l2"
    assert parse_factory(spec.to_factory()) == spec
    spec = parse_factory("pq16+lpq,r32")
    assert spec.rerank_bits == 32 and spec.params["lpq_tables"]
    assert parse_factory(spec.to_factory()) == spec
    assert parse_factory("flat,lpq8").rerank_bits is None


@pytest.mark.parametrize("bad", ["flat,lpq4+r16", "flat,r0", "flat,r32,r8",
                                 "flat,lpq4+r32,r8"])
def test_rerank_factory_rejects_garbage(bad):
    with pytest.raises(ValueError):
        parse_factory(bad)


def test_rerank_store_counted_in_memory(corpus_queries):
    corpus, _q = corpus_queries
    plain = make_index("flat,lpq4", corpus)
    rer = make_index("flat,lpq4+r32", corpus)
    # honest accounting: +r32 carries the fp32 corpus on top of the codes
    assert rer.memory_bytes() >= plain.memory_bytes() + corpus.size * 4


@pytest.mark.parametrize("kind", sorted(CASES))
def test_save_load_searcher_roundtrip(kind, corpus_queries, built, tmp_path):
    """Every registered kind: save -> load_index -> plan on the loaded
    copy -> ids/scores identical to the pre-save plan (incl. packed lpq4
    stores and +rN rerank stores)."""
    _corpus, queries = corpus_queries
    idx = built[kind]
    path = str(tmp_path / f"{kind}.npz")
    idx.save(path)
    restored = load_index(path)
    assert restored.kind == kind
    assert (restored.rerank_store is None) == (idx.rerank_store is None)
    a = idx.searcher(K, SP, batch_sizes=(8, 32))(queries)
    b = restored.searcher(K, SP, batch_sizes=(8, 32))(queries)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_allclose(np.asarray(a.scores), np.asarray(b.scores),
                               rtol=1e-6)
    assert restored.memory_bytes() == idx.memory_bytes()


# --------------------------------------------------------------------------
# serving loop (in-process smoke: the queue/percentile/aggregation path)
# --------------------------------------------------------------------------

def test_serve_main_runs_mixed(capsys):
    from repro.launch import serve

    serve.main(["--index", "flat,lpq4+r32", "--n", "1024", "--d", "32",
                "--batch", "8", "--requests", "6", "--mixed"])
    out = capsys.readouterr().out
    assert "QPS" in out
    assert "p95" in out and "p99" in out
    assert "stats/request mean" in out
    # mixed traffic pads 1-query and 2-query requests into buckets
    assert "padded_q=" in out
