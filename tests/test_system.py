"""End-to-end behaviour tests — the paper's claims at reduced scale.

Each test mirrors one paper artifact: Table 2 (exact-search recall
parity), Fig 2 (QPS/recall vs EFS tradeoff shape), Table 1 (memory
ratio), plus the serving loop and quickstart example."""

import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import FlatIndex, HNSWIndex


@pytest.fixture(scope="module")
def product():
    corpus, queries, metric = synthetic.load("product", 3000, 64)
    return corpus, queries[:64], metric


def test_exact_recall_parity(product):
    """Table 2: int8 exhaustive recall within a few % of fp32 on every
    metric family."""
    schemes = {"sift": ("global_minmax", 1.0), "glove": ("global_absmax", 1.0),
               "product": ("gaussian", 3.0)}
    floors = {"sift": 0.95, "glove": 0.93, "product": 0.95}
    for name in ("sift", "glove", "product"):
        scheme, sigmas = schemes[name]
        corpus, queries, metric = synthetic.load(name, 3000, 64)
        queries = queries[:64]
        gt = FlatIndex.build(corpus, metric=metric).search(queries, 100)[1]
        q8 = FlatIndex.build(corpus, metric=metric, quantized=True,
                             scheme=scheme, sigmas=sigmas)
        ids = q8.search(queries, 100)[1]
        rec = float(recall_at_k(gt, ids))
        assert rec > floors[name], f"{name}: {rec}"


def test_memory_reduction_claim(product):
    """Paper: ~60%+ memory reduction (75% for raw vectors; less once the
    graph's native pointers are included — exactly the paper's caveat)."""
    corpus, _q, metric = product
    flat_fp = FlatIndex.build(corpus, metric=metric)
    flat_q8 = FlatIndex.build(corpus, metric=metric, quantized=True, sigmas=3.0)
    assert flat_q8.memory_bytes() < 0.3 * flat_fp.memory_bytes()

    h_fp = HNSWIndex.build(corpus, m=8, ef_construction=40, metric=metric,
                           batch_size=512)
    h_q8 = HNSWIndex.build(corpus, m=8, ef_construction=40, metric=metric,
                           quantized=True, sigmas=3.0, batch_size=512)
    ratio = h_q8.memory_bytes() / h_fp.memory_bytes()
    assert ratio < 0.75  # vector part shrinks 4x; graph pointers don't
    assert h_q8.memory_bytes() > 0.2 * h_fp.memory_bytes()


def test_fig2_recall_tradeoff(product):
    """Fig 2: for the int8 index, recall increases with EFS."""
    corpus, queries, metric = product
    _s, gt = exact_topk(corpus, queries, 10, metric)
    h = HNSWIndex.build(corpus, m=12, ef_construction=80, metric=metric,
                        quantized=True, sigmas=3.0, batch_size=512)
    recalls = [
        float(recall_at_k(gt, h.search(queries, 10, ef_search=efs)[1]))
        for efs in (20, 80, 160)
    ]
    assert recalls[-1] >= recalls[0]
    assert recalls[-1] > 0.8


def test_serving_loop_runs():
    """The batched ANN serving entrypoint executes end to end."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--n", "2048", "--d", "32", "--batch", "8", "--requests", "3"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "QPS" in out.stdout
