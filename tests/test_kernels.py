"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run in interpret mode on CPU (the TPU lowering is exercised
structurally by the dry-run).  Integer-output kernels must match the oracle
EXACTLY — there is no tolerance to hide behind.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref


def _codes(key, shape, bits=8):
    lo = -(2 ** (bits - 1))
    hi = 2 ** (bits - 1)
    return jax.random.randint(key, shape, lo, hi, dtype=jnp.int8)


QN_SHAPES = [
    (1, 1, 8),        # degenerate
    (1, 1000, 64),    # single query (retrieval_cand shape family)
    (7, 333, 100),    # ragged everything (glove100 d)
    (37, 1000, 96),
    (128, 512, 128),  # exactly one tile (SIFT d)
    (130, 700, 128),  # just over one tile
    (256, 2048, 256), # multiple tiles (product-embedding d)
]


@pytest.mark.parametrize("q_rows,n_rows,d", QN_SHAPES)
def test_qmip_matches_ref(q_rows, n_rows, d):
    kq, kx = jax.random.split(jax.random.PRNGKey(q_rows * 7 + n_rows))
    q = _codes(kq, (q_rows, d))
    x = _codes(kx, (n_rows, d))
    got = ops.qmip(q, x)
    want = ref.qmip_ref(q, x)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("q_rows,n_rows,d", QN_SHAPES)
def test_ql2_matches_ref(q_rows, n_rows, d):
    kq, kx = jax.random.split(jax.random.PRNGKey(q_rows * 13 + n_rows))
    q = _codes(kq, (q_rows, d))
    x = _codes(kx, (n_rows, d))
    got = ops.ql2(q, x)
    want = ref.ql2_ref(q, x)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_rows,d", [(1, 8), (9, 100), (1024, 128), (1500, 256)])
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_matches_ref(n_rows, d, bits):
    key = jax.random.PRNGKey(n_rows + bits)
    x = jax.random.normal(key, (n_rows, d)) * 0.05
    lo = jnp.full((d,), -0.04)
    hi = jnp.full((d,), 0.06)
    zero = jnp.full((d,), 0.01)
    got = ops.quantize(x, lo, hi, zero, bits=bits)
    want = ref.quantize_ref(x, lo, hi, zero, bits=bits)
    assert got.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantize_clamps_to_storable_range():
    x = jnp.array([[-1e9, 1e9, 0.0, 0.05]], dtype=jnp.float32)
    lo = jnp.full((4,), -0.05)
    hi = jnp.full((4,), 0.05)
    zero = jnp.zeros((4,))
    got = np.asarray(ops.quantize(x, lo, hi, zero, bits=8))[0]
    assert got[0] == -128       # below range -> -2^(B-1)
    assert got[1] == 127        # above range -> clipped +2^(B-1)
    assert got[2] == 0
    assert got[3] == 127        # S_e maps to the clipped top code


def test_qmip_against_core_distances():
    # The kernel and the core library (XLA path) must agree bit-for-bit.
    from repro.core import distances as D

    kq, kx = jax.random.split(jax.random.PRNGKey(3))
    q = _codes(kq, (16, 64))
    x = _codes(kx, (200, 64))
    np.testing.assert_array_equal(
        np.asarray(ops.qmip(q, x)), np.asarray(D.qip_scores(q, x))
    )
    np.testing.assert_array_equal(
        np.asarray(ops.ql2(q, x)), np.asarray(D.ql2_scores(q, x))
    )


def test_int32_accumulation_no_overflow_at_max_codes():
    # worst case: all codes at +-128/127, d=2048 -> |dot| <= 2048*128*128 < 2^31
    d = 2048
    q = jnp.full((8, d), -128, jnp.int8)
    x = jnp.full((16, d), -128, jnp.int8)
    got = np.asarray(ops.qmip(q, x))
    assert (got == d * 128 * 128).all()
    assert got.dtype == np.int32
