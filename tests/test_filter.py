"""Property suite for the filter subsystem (DESIGN.md §16):
``repro.filter.Filter`` bitmap algebra, the pad-sentinel contract when
survivors < k, degenerate filters, filter ∘ tombstone composition under
stream churn, and save -> load -> filtered-search parity."""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # no hypothesis on this container: see pyproject [test]
    from _hypothesis_compat import given, settings, strategies as st

from repro.filter import Filter, overfetch
from repro.knn import SearchParams, load_index, make_index

NEG = float(np.finfo(np.float32).min)


def _mask(seed: int, n: int, sel: float) -> np.ndarray:
    return np.random.default_rng(seed).random(n) < sel


# --------------------------------------------------------------------------
# bitmap algebra
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 512),
       sel=st.floats(0.0, 1.0))
def test_bitmap_round_trip(seed, n, sel):
    """from_mask -> mask / ids() round-trips; from_ids(ids()) rebuilds
    an equal filter (digest equality == content equality)."""
    m = _mask(seed, n, sel)
    f = Filter.from_mask(m)
    np.testing.assert_array_equal(np.asarray(f.mask), m)
    assert f.n == n and f.count == int(m.sum())
    g = Filter.from_ids(f.ids(), n)
    assert g == f and hash(g) == hash(f)
    np.testing.assert_array_equal(g.ids(), np.flatnonzero(m))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 512),
       sa=st.floats(0.0, 1.0), sb=st.floats(0.0, 1.0))
def test_bitmap_and_or_invert_composition(seed, n, sa, sb):
    ma, mb = _mask(seed, n, sa), _mask(seed + 1, n, sb)
    fa, fb = Filter.from_mask(ma), Filter.from_mask(mb)
    np.testing.assert_array_equal(np.asarray((fa & fb).mask), ma & mb)
    np.testing.assert_array_equal(np.asarray((fa | fb).mask), ma | mb)
    np.testing.assert_array_equal(np.asarray((~fa).mask), ~ma)
    assert (fa & fb) == (fb & fa)
    # AND can only shrink, OR can only grow
    assert (fa & fb).count <= min(fa.count, fb.count)
    assert (fa | fb).count >= max(fa.count, fb.count)


def test_bitmap_n_mismatch_rejected():
    with pytest.raises(ValueError, match="compose"):
        Filter.from_mask(np.ones(4, bool)) & Filter.from_mask(np.ones(5, bool))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 256),
       m=st.integers(1, 256), sel=st.floats(0.0, 1.0))
def test_aligned_pads_allowed_and_truncates(seed, n, m, sel):
    """aligned(m): rows beyond the filter's horizon default to ALLOWED
    (the filter constrains only what it describes), shrinking truncates."""
    f = Filter.from_mask(_mask(seed, n, sel))
    a = np.asarray(f.aligned(m))
    assert a.shape == (m,)
    k = min(n, m)
    np.testing.assert_array_equal(a[:k], np.asarray(f.mask)[:k])
    assert a[k:].all()


@settings(max_examples=25, deadline=None)
@given(k=st.integers(1, 64), sel=st.floats(0.0, 1.0),
       n=st.integers(1, 100000))
def test_overfetch_bounds(k, sel, n):
    of = overfetch(k, sel, n)
    assert k <= of + max(0, k - n)     # >= k unless the corpus is smaller
    assert of <= max(n, k) and of >= min(k, n)
    if sel > 0:
        assert of >= min(n, int(np.ceil(k / max(sel, 1e-9))))
    assert overfetch(k, 0.0, n) == n   # unknown selectivity -> everything


def test_from_column_and_predicate():
    col = np.array([0, 1, 2, 1, 0, 2, 1])
    np.testing.assert_array_equal(
        Filter.from_column(col, 1).ids(), [1, 3, 6])
    np.testing.assert_array_equal(
        Filter.from_column(col, {0, 2}).ids(), [0, 2, 4, 5])
    np.testing.assert_array_equal(
        Filter.from_predicate(col, lambda c: c >= 1).ids(), [1, 2, 3, 5, 6])
    assert Filter.from_column(col, 1) == Filter.from_column(col, [1])


# --------------------------------------------------------------------------
# search contracts: pad sentinel, degenerate filters
# --------------------------------------------------------------------------

N, D, K = 200, 16, 10


@pytest.fixture(scope="module")
def corpus_queries():
    corpus = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (N, D))) * 0.1
    queries = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (6, D))) * 0.1
    return corpus, queries


@pytest.mark.parametrize("factory", ["flat", "flat,lpq4", "ivf8,lpq8",
                                     "stream(flat,lpq8)"])
def test_survivors_below_k_pad_sentinel(factory, corpus_queries):
    """A filter with fewer survivors than k fills the tail with the
    exact pad sentinel: id -1, score float32-min."""
    corpus, queries = corpus_queries
    idx = make_index(factory, corpus, key=jax.random.PRNGKey(0))
    keep = np.array([3, 17, 42])
    sp = SearchParams(filter=Filter.from_ids(keep, N), nprobe=8)
    res = idx.search(queries, K, sp)
    ids, scores = np.asarray(res.ids), np.asarray(res.scores)
    assert sorted(set(ids[ids >= 0].tolist())) == sorted(keep.tolist())
    assert (ids[:, len(keep):] == -1).all(), factory
    assert (scores[:, len(keep):] == NEG).all(), factory


@pytest.mark.parametrize("factory", ["flat,lpq8", "ivf8", "hnsw8",
                                     "stream(flat,lpq4)"])
def test_filter_none_and_all(factory, corpus_queries):
    """filter-all-allowed == no filter (bit-exact); filter-none returns
    only pad sentinels."""
    corpus, queries = corpus_queries
    idx = make_index(factory, corpus, key=jax.random.PRNGKey(0))
    plain = idx.search(queries, K, SearchParams(nprobe=8))
    allf = idx.search(
        queries, K, SearchParams(nprobe=8,
                                 filter=Filter.from_mask(np.ones(N, bool))))
    np.testing.assert_array_equal(np.asarray(plain.ids), np.asarray(allf.ids))
    np.testing.assert_array_equal(np.asarray(plain.scores),
                                  np.asarray(allf.scores))
    none = idx.search(
        queries, K, SearchParams(nprobe=8,
                                 filter=Filter.from_mask(np.zeros(N, bool))))
    assert (np.asarray(none.ids) == -1).all()
    assert (np.asarray(none.scores) == NEG).all()


def test_filter_hash_rides_search_params():
    """Equal-content filters hash equal (compiled-plan cache keys);
    different bitmaps do not collide on n."""
    a = SearchParams(filter=Filter.from_ids([1, 2], 10))
    b = SearchParams(filter=Filter.from_ids([1, 2], 10))
    c = SearchParams(filter=Filter.from_ids([1, 3], 10))
    assert hash(a) == hash(b) and a == b
    assert a != c
    with pytest.raises(ValueError, match="filter"):
        SearchParams(filter="not a filter").validate()


# --------------------------------------------------------------------------
# filter ∘ tombstone under churn + disk round-trip
# --------------------------------------------------------------------------

def _stream_oracle(idx, queries, allow_of, k):
    """Brute force over live_items() ∩ filter in fp32 (the stream merge
    re-scores against raw payloads, so fp32 is its scoring space)."""
    ext, vecs = idx.live_items()
    keep = np.array([allow_of(e) for e in ext], bool)
    ext, vecs = ext[keep], vecs[keep]
    s = queries @ vecs.T
    order = np.argsort(-s, axis=1, kind="stable")[:, :k]
    return np.take_along_axis(s, order, 1).astype(np.float32), ext[order]


def test_filtered_search_after_churn_matches_live_oracle(corpus_queries):
    """Upsert/delete churn, then filtered search == oracle over
    live_items() ∩ filter (ids and scores; fp32 merge space)."""
    corpus, queries = corpus_queries
    idx = make_index("stream(flat)+r32", corpus, seal_threshold=64,
                     key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    # churn: delete some originals, upsert new ids and replacements
    idx.delete(rng.choice(N, 40, replace=False))
    new_ids = np.arange(N, N + 90)
    idx.upsert(new_ids, rng.standard_normal((90, D)).astype(np.float32) * 0.1)
    idx.delete(new_ids[::7])
    idx.upsert(np.arange(10, 30),
               rng.standard_normal((20, D)).astype(np.float32) * 0.1)

    # predicate over EXTERNAL ids: even ids allowed
    horizon = N + 90
    allow = (np.arange(horizon) % 2) == 0
    sp = SearchParams(filter=Filter.from_mask(allow))
    res = idx.searcher(K, sp, rerank=idx.n)(queries)
    ids, scores = np.asarray(res.ids), np.asarray(res.scores)

    oscores, oids = _stream_oracle(idx, queries, lambda e: allow[e], K)
    np.testing.assert_array_equal(ids, oids)
    np.testing.assert_allclose(scores, oscores, rtol=1e-6)
    assert (ids % 2 == 0).all()

    # churn continues: filtered results track the next plan's snapshot
    idx.delete(ids[0, 0:1])
    res2 = idx.searcher(K, sp, rerank=idx.n)(queries)
    assert int(ids[0, 0]) not in np.asarray(res2.ids)[0].tolist()


def test_save_load_filtered_search_parity(corpus_queries, tmp_path):
    corpus, queries = corpus_queries
    idx = make_index("stream(ivf8,lpq8)+r32", corpus, seal_threshold=64,
                     kmeans_iters=4, key=jax.random.PRNGKey(0))
    idx.delete(np.arange(0, 30))
    sp = SearchParams(nprobe=8,
                      filter=Filter.from_mask(_mask(11, N, 0.5)))
    path = str(tmp_path / "filtered.npz")
    idx.save(path)
    restored = load_index(path)
    a = idx.search(queries, K, sp)
    b = restored.search(queries, K, sp)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))


# --------------------------------------------------------------------------
# the over-fetch starvation regression (multi-source merge)
# --------------------------------------------------------------------------

def test_segment_overfetch_survives_selective_filter():
    """Regression: per-segment over-fetch must inflate by masked rows
    (tombstones AND filtered-out), not dead count alone — otherwise a
    selective filter starves the merge of survivors a brute-force oracle
    still finds.  n=97 rows sealed in 10-row chunks."""
    n, d, k = 97, 8, 5
    rng = np.random.default_rng(3)
    vecs = rng.standard_normal((n, d)).astype(np.float32)
    queries = rng.standard_normal((4, d)).astype(np.float32)
    # adversarial: DISALLOWED rows score strictly higher than allowed
    # ones, so under dead-count-only inflation every segment's top-k is
    # 100% filtered-out rows and the merge starves
    allow = _mask(9, n, 0.25)
    allow[:3] = True                       # keep it non-degenerate
    boost = queries.mean(axis=0)
    boost /= np.linalg.norm(boost)
    vecs[~allow] += 4.0 * boost
    idx = make_index("stream(flat)", np.zeros((0, d), np.float32),
                     seal_threshold=10, max_segments=64, auto_compact=False)
    for start in range(0, n, 10):
        stop = min(start + 10, n)
        idx.upsert(np.arange(start, stop), vecs[start:stop])
    idx.seal()
    assert idx.stats()["segments"] >= 9    # the multi-segment shape

    sp = SearchParams(filter=Filter.from_mask(allow))
    # no rerank depth: sources fetch at k + masked — exactly the
    # inflation under test (a forced deep rerank would hide starvation)
    res = idx.searcher(k, sp)(queries)
    ids = np.asarray(res.ids)

    s = queries @ vecs[allow].T
    order = np.argsort(-s, axis=1, kind="stable")[:, :k]
    oids = np.flatnonzero(allow)[order]
    np.testing.assert_array_equal(
        ids, oids,
        err_msg="selective filter starved the multi-source merge "
                "(per-segment over-fetch ignored filtered-out rows)")
