"""Property-based tests (hypothesis) for the quantization family's
invariants — Definition 2 and the structural guarantees of Eq. 1."""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # no hypothesis on this container: see pyproject [test]
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import quant as Qz
from repro.core import distances as D
from repro.core import preserve
from repro.core.stats import corpus_stats, merge_stats


def _corpus(seed, n, d, scale):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d)) * scale


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(16, 128),
    d=st.integers(2, 32),
    bits=st.sampled_from([4, 8]),
    scheme=st.sampled_from(["gaussian", "absmax", "minmax", "global_minmax"]),
)
def test_codes_within_storable_range(seed, n, d, bits, scheme):
    x = _corpus(seed, n, d, 0.1)
    params = Qz.learn_params(x, bits=bits, scheme=scheme, sigmas=2.0)
    codes = np.asarray(Qz.quantize(x, params))
    assert codes.min() >= -(2 ** (bits - 1))
    assert codes.max() <= 2 ** (bits - 1) - 1


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), d=st.integers(2, 24))
def test_monotonic_1d_order_preservation(seed, d):
    """Eq. 1 is monotone per dimension: x <= y implies Q(x) <= Q(y)."""
    x = _corpus(seed, 64, d, 0.2)
    params = Qz.learn_params(x, bits=8, scheme="gaussian", sigmas=2.0)
    sorted_col = jnp.sort(x[:, 0])
    col = jnp.broadcast_to(sorted_col[:, None], (64, d))
    codes = np.asarray(Qz.quantize(col, params))[:, 0]
    assert (np.diff(codes) >= 0).all()


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    metric=st.sampled_from(["ip", "l2", "angular"]),
)
def test_definition2_on_narrow_band(seed, metric):
    """Strict-order agreement stays high on Fig-1-style corpora."""
    corpus = _corpus(seed, 256, 16, 0.05)
    queries = _corpus(seed + 1, 32, 16, 0.05)
    params = Qz.learn_params(corpus, bits=8, scheme="gaussian", sigmas=3.0)
    agree = float(
        preserve.order_agreement(corpus, queries, params, metric, n_triples=512)
    )
    assert agree > 0.9, f"{metric}: {agree}"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_agreement_improves_with_margin(seed):
    """The paper's aliasing claim: near-ties account for the disagreements,
    so restricting to larger original gaps raises agreement."""
    corpus = _corpus(seed, 256, 16, 0.05)
    queries = _corpus(seed + 1, 16, 16, 0.05)
    params = Qz.learn_params(corpus, bits=4, scheme="gaussian", sigmas=2.0)
    base = float(preserve.order_agreement(corpus, queries, params, "ip", 512))
    wide = float(
        preserve.order_agreement(
            corpus, queries, params, "ip", 512, margin_quantile=0.5
        )
    )
    assert wide >= base - 1e-6


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n=st.integers(8, 200),
    split=st.floats(0.1, 0.9),
)
def test_streaming_stats_merge_associative(seed, n, split):
    x = _corpus(seed, max(n, 8), 8, 1.0)
    k = max(1, min(int(n * split), x.shape[0] - 1))
    merged = merge_stats(corpus_stats(x[:k]), corpus_stats(x[k:]))
    full = corpus_stats(x)
    np.testing.assert_allclose(np.asarray(merged.mean), np.asarray(full.mean),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(merged.std), np.asarray(full.std),
                               rtol=1e-3, atol=1e-6)
    np.testing.assert_allclose(np.asarray(merged.amax), np.asarray(full.amax))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), bits=st.sampled_from([4, 8]))
def test_global_scheme_is_single_affine_map(seed, bits):
    """GLOBAL_* schemes apply one affine map to every dim, so quantized L2
    ordering equals exact L2 ordering up to rounding ties."""
    x = _corpus(seed, 128, 8, 1.0) * jnp.arange(1, 9)[None, :]  # uneven dims
    params = Qz.learn_params(x, bits=bits, scheme="global_minmax")
    span = np.asarray(params.hi - params.lo)
    assert np.allclose(span, span[0])
    zero = np.asarray(params.zero)
    assert np.allclose(zero, zero[0])


def test_quantized_distances_exact_int32():
    """Integer-domain distances are exact (no float rounding)."""
    codes_a = jnp.array([[1, -2, 3], [120, -120, 7]], jnp.int8)
    codes_b = jnp.array([[4, 5, -6], [-1, 0, 2]], jnp.int8)
    ip = np.asarray(D.qip_scores(codes_a, codes_b))
    assert ip[0, 0] == 1 * 4 + (-2) * 5 + 3 * (-6)
    assert ip[1, 0] == 120 * 4 + (-120) * 5 + 7 * (-6)
    l2 = np.asarray(D.ql2_scores(codes_a, codes_b))
    assert l2[0, 0] == -((1 - 4) ** 2 + (-2 - 5) ** 2 + (3 + 6) ** 2)
