"""Cascade subsystem tests (DESIGN.md §14): the multi-stage grammar and
budget validation, per-stage stats, the final-fp32 exactness guarantee,
density-aware per-region constants, and the satellite runtime hooks
(profile files, semantic cache keys, background rerank refresh, degraded
cascade budgets)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.knn import SearchParams, load_index, make_index, parse_factory

K = 10
N, D = 384, 32


@pytest.fixture(scope="module")
def corpus_queries():
    corpus = np.asarray(
        jax.random.normal(jax.random.PRNGKey(0), (N, D))) * 0.05
    # density contrast: the first block concentrates, so per-region
    # constants actually differ from the global fit
    corpus[: N // 3] *= 0.2
    queries = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (8, D))) * 0.05
    return corpus, queries


# ---------------------------------------------------------------------------
# grammar
# ---------------------------------------------------------------------------

def test_cascade_factory_round_trip():
    for factory in ("cascade(pq16x4|lpq8|r32)", "cascade(flat,lpq4|r32)",
                    "cascade(ivf8,lpq8|lpq8|r8)"):
        spec = parse_factory(factory)
        assert spec.kind == "cascade"
        assert parse_factory(spec.to_factory()) == spec


def test_regions_factory_round_trip():
    for factory in ("ivf8,lpq8,regions", "hnsw8,lpq4,regions",
                    "graph16,lpq8@absmax,regions"):
        spec = parse_factory(factory)
        assert spec.params.get("regions") is True
        assert parse_factory(spec.to_factory()) == spec


def test_cascade_needs_two_stages():
    with pytest.raises(ValueError, match="stage"):
        parse_factory("cascade(flat,lpq8)")


def test_cascade_rejects_plus_r_suffix():
    with pytest.raises(ValueError, match="cascade"):
        parse_factory("cascade(flat,lpq4|lpq8)+r32")
    # the final stage IS the rerank — the suffix spelling gets a pointed
    # redirect, not a generic cannot-parse fallthrough
    with pytest.raises(ValueError, match="final stage IS the rerank"):
        parse_factory("cascade(flat,lpq4|r32)+r8")


def test_regions_need_quant_fragment():
    with pytest.raises(ValueError, match="lpq"):
        parse_factory("ivf8,regions")


def test_regions_rejected_for_unpartitioned_kinds():
    for factory in ("flat,lpq8,regions", "pq16,regions"):
        with pytest.raises(ValueError):
            parse_factory(factory)


# ---------------------------------------------------------------------------
# budgets + per-stage stats
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cascade_idx(corpus_queries):
    corpus, _ = corpus_queries
    return make_index("cascade(pq16x4|lpq8|r32)", corpus,
                      key=jax.random.PRNGKey(0), kmeans_iters=4)


def test_non_monotone_budgets_raise_pointed_error(cascade_idx, corpus_queries):
    _, queries = corpus_queries
    with pytest.raises(ValueError, match="never invent them"):
        cascade_idx.search(queries, K,
                           SearchParams(budgets=(32, 128)))
    # a budget below k trips the same monotonicity rule (k rides as the
    # final element of the checked sequence)
    with pytest.raises(ValueError, match="never invent them"):
        cascade_idx.search(queries, K, SearchParams(budgets=(64, K - 1)))


def test_budget_arity_mismatch_raises(cascade_idx, corpus_queries):
    _, queries = corpus_queries
    with pytest.raises(ValueError, match="one fetch depth per"):
        cascade_idx.search(queries, K, SearchParams(budgets=(64,)))


def test_per_stage_stats_ride_on_results(cascade_idx, corpus_queries):
    _, queries = corpus_queries
    res = cascade_idx.search(queries, K, SearchParams(budgets=(128, 32)))
    stages = res.stats["stages"]
    assert res.stats["kind"] == "cascade"
    assert res.stats["cascade_stages"] == 3 == len(stages)
    labels = [row[0] for row in stages]
    assert labels[0].startswith("head:") and labels[1:] == ["lpq8", "r32"]
    cands = [row[1] for row in stages]
    assert cands == [128, 128, 32]          # stage i receives budgets[i]
    bits = [row[3] for row in stages]
    assert bits == [4, 8, 32]
    # total bytes_read is exactly the per-stage sum
    assert res.stats["bytes_read"] == sum(row[2] for row in stages)


def test_budgets_ride_in_searcher_plans(cascade_idx, corpus_queries):
    _, queries = corpus_queries
    sp = SearchParams(budgets=(128, 32))
    eager = cascade_idx.search(queries, K, sp)
    planned = cascade_idx.searcher(K, sp, batch_sizes=(4, 16))(queries)
    np.testing.assert_array_equal(np.asarray(eager.ids),
                                  np.asarray(planned.ids))
    np.testing.assert_array_equal(np.asarray(eager.scores),
                                  np.asarray(planned.scores))


def test_final_fp32_stage_at_full_depth_is_exact(corpus_queries):
    """cascade(...|r32) with the final budget = n == the exact fp32
    search: ids exactly, scores to float tolerance (the same standard
    the +r32 full-depth rerank test holds the Searcher to)."""
    corpus, queries = corpus_queries
    exact = make_index("flat", corpus).search(queries, K)
    idx = make_index("cascade(flat,lpq4|r32)", corpus)
    res = idx.search(queries, K, SearchParams(budgets=(N,)))
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(exact.ids))
    np.testing.assert_allclose(np.asarray(res.scores),
                               np.asarray(exact.scores), rtol=1e-5)


def test_cascade_save_load_keeps_stage_structure(cascade_idx, corpus_queries,
                                                 tmp_path):
    _, queries = corpus_queries
    path = str(tmp_path / "cascade.npz")
    cascade_idx.save(path)
    restored = load_index(path)
    assert restored.stages == cascade_idx.stages
    sp = SearchParams(budgets=(128, 32))
    a = cascade_idx.search(queries, K, sp)
    b = restored.search(queries, K, sp)
    np.testing.assert_array_equal(np.asarray(a.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    assert a.stats["stages"] == b.stats["stages"]


# ---------------------------------------------------------------------------
# per-region constants
# ---------------------------------------------------------------------------

def test_density_scales_widen_sparse_tighten_dense():
    from repro.cascade import density_scales

    scales = density_scales(np.array([1000, 10, 0]))
    assert scales[0] < 1.0 < scales[1]       # dense tightens, sparse widens
    lo, hi = 0.5, 2.0
    assert (scales >= lo).all() and (scales <= hi).all()


@pytest.mark.parametrize("factory,overrides", [
    ("ivf8,lpq8,regions", {"kmeans_iters": 4}),
    ("graph16,lpq8,regions", {"n_seeds": 16}),
    ("hnsw8,lpq8,regions", {"ef_construction": 40, "batch_size": 128}),
])
def test_region_round_trip_and_drift(factory, overrides, corpus_queries,
                                     tmp_path):
    corpus, queries = corpus_queries
    idx = make_index(factory, corpus, key=jax.random.PRNGKey(0), **overrides)
    assert idx.regions is not None
    res = idx.search(queries, K, SearchParams(nprobe=8, ef_search=40))
    assert res.stats["regional"] is True

    path = str(tmp_path / "regions.npz")
    idx.save(path)
    restored = load_index(path)
    assert restored.regions is not None
    b = restored.search(queries, K, SearchParams(nprobe=8, ef_search=40))
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(res.scores),
                                  np.asarray(b.scores))

    # drift: the build corpus assigns identically -> exactly 0 everywhere
    # a region is populated; a shifted corpus drifts in every live region
    dr = restored.region_drift(corpus)
    finite = np.isfinite(dr)
    assert finite.any()
    np.testing.assert_array_equal(dr[finite], 0.0)
    dr2 = restored.region_drift(corpus + 0.5)
    assert (dr2[np.isfinite(dr2)] > 0).all()


def test_region_constants_differ_across_regions(corpus_queries):
    corpus, _ = corpus_queries
    idx = make_index("ivf8,lpq8,regions", corpus, key=jax.random.PRNGKey(0),
                     kmeans_iters=4)
    scale = np.asarray(idx.regions.scale)
    counts = np.bincount(np.asarray(idx.regions.assign), minlength=8)
    live = counts > 1
    assert live.sum() >= 2
    # distinct distributions -> distinct LSB sizes
    assert np.ptp(scale[live].mean(axis=1)) > 0


def test_global_build_degrades_gracefully(corpus_queries, tmp_path):
    """No 'regions' fragment -> the exact pre-region global path: no
    regions attached, no regional stats key, bit-exact round-trip."""
    corpus, queries = corpus_queries
    idx = make_index("ivf8,lpq8", corpus, key=jax.random.PRNGKey(0),
                     kmeans_iters=4)
    assert idx.regions is None
    res = idx.search(queries, K, SearchParams(nprobe=8))
    assert "regional" not in res.stats
    with pytest.raises(ValueError, match="regions"):
        idx.region_drift(corpus)
    path = str(tmp_path / "global.npz")
    idx.save(path)
    restored = load_index(path)
    assert restored.regions is None
    b = restored.search(queries, K, SearchParams(nprobe=8))
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(b.ids))
    np.testing.assert_array_equal(np.asarray(res.scores),
                                  np.asarray(b.scores))


def test_regions_rejected_at_spec_construction_for_flat_and_pq():
    """The rejection fires as early as possible — already at IndexSpec
    validation, before any build machinery runs."""
    with pytest.raises(ValueError, match="partitioned"):
        dataclasses.replace(parse_factory("flat,lpq8"),
                            params={"regions": True})
    with pytest.raises(ValueError, match="partitioned"):
        dataclasses.replace(
            parse_factory("pq16"),
            params={**parse_factory("pq16").params, "regions": True},
        )


# ---------------------------------------------------------------------------
# satellites: runtime hooks
# ---------------------------------------------------------------------------

def test_profile_file_round_trip(tmp_path):
    from repro.runtime import profile as rtprofile

    prof = rtprofile.RuntimeProfile(
        name="test-file-prof", platform="cpu", host_device_count=2,
        xla_flags=("--xla_foo=1",), seed=7, deterministic=False,
    )
    path = str(tmp_path / "prof.json")
    rtprofile.to_file(prof, path)
    loaded = rtprofile.from_file(path)
    assert loaded == prof
    assert rtprofile.PROFILES["test-file-prof"] == prof
    del rtprofile.PROFILES["test-file-prof"]


def test_profile_file_rejects_unknown_and_nameless(tmp_path):
    import json

    from repro.runtime import profile as rtprofile

    bad = str(tmp_path / "bad.json")
    with open(bad, "w") as f:
        json.dump({"name": "x", "platfrm": "cpu"}, f)
    with pytest.raises(ValueError, match="platfrm"):
        rtprofile.from_file(bad)
    nameless = str(tmp_path / "nameless.json")
    with open(nameless, "w") as f:
        json.dump({"platform": "cpu"}, f)
    with pytest.raises(ValueError, match="name"):
        rtprofile.from_file(nameless)


def test_semantic_cache_keys_unify_query_representations(corpus_queries):
    """A float64 copy and a strided fp32 view of the same batch must hit
    the entry the canonical batch populated, and the hit must be
    bit-identical to the original miss."""
    from repro.runtime import CachedSearcher, TTLLRUCache

    corpus, queries = corpus_queries
    idx = make_index("flat,lpq8", corpus)
    cached = CachedSearcher(idx.searcher(K), TTLLRUCache(capacity=8))

    miss = cached(queries)
    assert miss.stats["cache"] == "miss"

    as_f64 = np.asarray(queries, np.float64)
    hit = cached(as_f64)
    assert hit.stats["cache"] == "hit"
    np.testing.assert_array_equal(np.asarray(miss.ids), np.asarray(hit.ids))
    np.testing.assert_array_equal(np.asarray(miss.scores),
                                  np.asarray(hit.scores))

    strided = np.ascontiguousarray(
        np.stack([queries, queries], axis=1))[:, 0, :]
    assert not strided.flags["C_CONTIGUOUS"]
    hit2 = cached(strided)
    assert hit2.stats["cache"] == "hit"
    assert cached.cache.counters["misses"] == 1
    assert cached.cache.counters["hits"] == 2


def test_maintenance_refreshes_rerank_store_after_swap(corpus_queries):
    from repro.runtime import MaintenanceScheduler

    corpus, queries = corpus_queries
    idx = make_index("stream(flat,lpq8)+r32", corpus, seal_threshold=64,
                     auto_compact=False, key=jax.random.PRNGKey(0))
    idx.searcher(K)(queries)                       # warm the merge cache
    warm_refreshes = idx.counters["rerank_refreshes"]
    assert warm_refreshes >= 1

    sched = MaintenanceScheduler(idx, interval_s=10.0)
    out = sched.run_once(force_full=True)
    assert out["swapped"] is True
    assert out["rerank_refreshed"] is True
    assert sched.counters["rerank_refreshes"] == 1
    assert idx.counters["rerank_refreshes"] == warm_refreshes + 1
    # the scheduler pre-paid the rebuild: the next plan is cache-hot
    idx.searcher(K)(queries)
    assert idx.counters["rerank_refreshes"] == warm_refreshes + 1


def test_merge_store_cache_invalidates_on_writes(corpus_queries):
    corpus, queries = corpus_queries
    idx = make_index("stream(flat,lpq8)+r32", corpus, seal_threshold=64,
                     auto_compact=False, key=jax.random.PRNGKey(0))
    idx.searcher(K)(queries)
    base = idx.counters["rerank_refreshes"]
    idx.searcher(K)(queries)                       # same epoch -> cache hit
    assert idx.counters["rerank_refreshes"] == base
    idx.upsert(np.arange(N, N + 4),
               np.asarray(jax.random.normal(jax.random.PRNGKey(3), (4, D)))
               * 0.05)
    idx.searcher(K)(queries)                       # upsert -> rebuild
    assert idx.counters["rerank_refreshes"] == base + 1


def test_degrade_policy_shrinks_cascade_budgets(corpus_queries):
    from repro.runtime import DegradePolicy

    policy = DegradePolicy()                       # budget_scale = 0.5
    assert policy.budgets((128, 32), K) == (64, 16)
    assert policy.budgets((16, 12), K) == (K, K)   # floor at k, stays valid
    assert policy.budgets(None, K) is None

    # the degraded schedule actually plans and searches
    corpus, queries = corpus_queries
    idx = make_index("cascade(flat,lpq4|r32)", corpus)
    sp = policy.params(SearchParams(budgets=(128,)), K)
    assert sp.budgets == (64,)
    res = idx.search(queries, K, sp)
    assert res.stats["stages"][-1][1] == 64
