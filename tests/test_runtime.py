"""Production runtime subsystem tests (DESIGN.md §12): profile
resolution/round-trip, cache tiers (bit-parity with uncached search),
the admission shed ladder under synthetic overload, structured
telemetry, background compaction's atomic-swap exact-parity invariant,
the rebuilt serve loop, and the bench trend gate."""

import io
import json
import warnings

import jax
import numpy as np
import pytest

from repro import engine
from repro.data import synthetic
from repro.knn import SearchParams, make_index
from repro.runtime import (
    ADMIT,
    DEGRADE,
    MISS,
    SHED,
    AdmissionController,
    CachedSearcher,
    DegradePolicy,
    LUTCache,
    MaintenanceScheduler,
    RuntimeProfile,
    Telemetry,
    TTLLRUCache,
    fingerprint,
)
from repro.runtime import profile as rtprofile

K = 10
D = 24


@pytest.fixture(scope="module")
def corpus():
    c, _q, _m = synthetic.load("product", 600, 8)
    return np.asarray(c[:, :D])


@pytest.fixture(scope="module")
def extra():
    c, _q, _m = synthetic.load("product", 400, 8, key=jax.random.PRNGKey(3))
    return np.asarray(c[:, :D])


@pytest.fixture(scope="module")
def queries(corpus):
    _c, q, _m = synthetic.load("product", 64, 8)
    return np.asarray(q[:, :D])


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# profiles


class TestRuntimeProfile:
    def test_resolve_default_and_explicit(self):
        assert rtprofile.resolve().name == "default"
        assert rtprofile.resolve("ci-cpu").host_device_count == 1
        assert rtprofile.resolve("cpu-mesh4").host_device_count == 4

    def test_resolve_env(self, monkeypatch):
        monkeypatch.setenv(rtprofile.ENV_VAR, "cpu-dev")
        assert rtprofile.resolve().name == "cpu-dev"
        # explicit name wins over the env var
        assert rtprofile.resolve("default").name == "default"

    def test_resolve_unknown_lists_registry(self):
        with pytest.raises(ValueError, match="ci-cpu"):
            rtprofile.resolve("nope")

    def test_round_trip(self):
        p = RuntimeProfile(name="x", platform="cpu", host_device_count=2,
                           xla_flags=("--flag=1",), seed=7,
                           deterministic=False)
        assert RuntimeProfile.from_dict(p.to_dict()) == p
        with pytest.raises(ValueError, match="unknown"):
            RuntimeProfile.from_dict({"name": "x", "bogus": 1})

    def test_stamp_keys(self):
        s = rtprofile.stamp(rtprofile.resolve("default"))
        for key in ("profile", "backend", "device_kind", "interpret",
                    "jax_version", "seed", "deterministic", "n_devices"):
            assert key in s
        assert s["profile"] == "default"
        # this container is CPU: every Pallas number is interpret-mode
        assert s["interpret"] == (jax.default_backend() != "tpu")

    def test_apply_idempotent_and_sticky(self):
        rtprofile._reset_for_tests()
        try:
            p = rtprofile.apply(rtprofile.resolve("default"))
            assert rtprofile.active() is p
            assert rtprofile.apply(p) is p          # same profile: no-op
            with warnings.catch_warnings(record=True) as w:
                warnings.simplefilter("always")
                got = rtprofile.apply(rtprofile.resolve("cpu-dev"))
            assert got.name == "default"            # first apply wins
            assert any("already applied" in str(x.message) for x in w)
            assert rtprofile.stamp()["applied"] is True
        finally:
            rtprofile._reset_for_tests()

    def test_key_is_seeded(self):
        k7 = rtprofile.key(RuntimeProfile(name="s7", seed=7))
        assert np.array_equal(np.asarray(k7),
                              np.asarray(jax.random.PRNGKey(7)))

    def test_register(self):
        p = rtprofile.register(RuntimeProfile(name="_test_prof", seed=3))
        try:
            assert rtprofile.resolve("_test_prof") is p
        finally:
            rtprofile.PROFILES.pop("_test_prof")


# ---------------------------------------------------------------------------
# cache tiers


class TestTTLLRUCache:
    def test_hit_miss_and_lru_eviction(self):
        c = TTLLRUCache(capacity=2)
        assert c.get("a") is MISS
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1                  # refreshes a's recency
        c.put("c", 3)                           # evicts b (LRU)
        assert c.get("b") is MISS
        assert c.get("a") == 1 and c.get("c") == 3
        st = c.stats()
        assert (st["hits"], st["misses"], st["evictions"]) == (3, 2, 1)
        assert st["entries"] == 2

    def test_ttl_expiry(self):
        clk = FakeClock()
        c = TTLLRUCache(capacity=4, ttl_s=1.0, clock=clk)
        c.put("a", 1)
        clk.advance(0.5)
        assert c.get("a") == 1
        clk.advance(0.6)                        # 1.1s since put
        assert c.get("a") is MISS
        assert c.counters["expirations"] == 1

    def test_get_or_build(self):
        c = TTLLRUCache(capacity=2)
        calls = []
        build = lambda: calls.append(1) or "v"  # noqa: E731
        assert c.get_or_build("k", build) == "v"
        assert c.get_or_build("k", build) == "v"
        assert len(calls) == 1

    def test_validation(self):
        with pytest.raises(ValueError):
            TTLLRUCache(capacity=0)
        with pytest.raises(ValueError):
            TTLLRUCache(capacity=1, ttl_s=0.0)


class TestFingerprint:
    def test_array_identity_and_sensitivity(self):
        a = np.arange(12, dtype=np.float32)
        assert fingerprint(a) == fingerprint(a.copy())
        assert fingerprint(a) != fingerprint(a.astype(np.float64))
        assert fingerprint(a) != fingerprint(a.reshape(3, 4))
        b = a.copy()
        b[3] += 1e-3
        assert fingerprint(a) != fingerprint(b)

    def test_mixed_parts(self):
        a = np.zeros(3, np.float32)
        assert fingerprint(a, 10, "l2") == fingerprint(a, 10, "l2")
        assert fingerprint(a, 10, "l2") != fingerprint(a, 11, "l2")


class TestCachedSearcher:
    def test_hit_is_bit_identical(self, corpus, queries):
        idx = make_index("flat,lpq8", corpus)
        s = idx.searcher(K, SearchParams(), batch_sizes=(8,))
        cs = CachedSearcher(s, TTLLRUCache(capacity=8))
        q = queries[:8]
        r1 = cs(q)
        assert r1.stats["cache"] == "miss"
        r2 = cs(q)
        assert r2.stats["cache"] == "hit"
        assert np.array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
        assert np.array_equal(np.asarray(r1.scores), np.asarray(r2.scores))
        # a hit reads nothing
        assert r2.stats["bytes_read"] == 0 and r2.stats["chunks"] == 0
        # parity with the raw searcher
        r0 = s(q)
        assert np.array_equal(np.asarray(r0.ids), np.asarray(r2.ids))

    def test_version_invalidates(self, corpus, queries):
        idx = make_index("flat,lpq8", corpus)
        s = idx.searcher(K, SearchParams(), batch_sizes=(8,))
        cache = TTLLRUCache(capacity=8)
        gen = [0]
        cs = CachedSearcher(s, cache, version=lambda: gen[0])
        q = queries[:8]
        cs(q)
        assert cs(q).stats["cache"] == "hit"
        gen[0] += 1                              # simulated re-plan
        assert cs(q).stats["cache"] == "miss"
        assert cache.counters["misses"] == 2

    def test_proxies_plan_surface(self, corpus):
        idx = make_index("flat,lpq8", corpus)
        s = idx.searcher(K, SearchParams(), batch_sizes=(8,))
        cs = CachedSearcher(s, TTLLRUCache(capacity=2))
        assert cs.n_shards == s.n_shards
        assert cs.rerank is s.rerank
        assert cs.buckets_for(5) == s.buckets_for(5)


class TestLUTCacheTier:
    def test_eager_pq_search_hits_and_matches(self, corpus, queries):
        idx = make_index("pq4x4+lpq", corpus, kmeans_iters=2,
                         key=jax.random.PRNGKey(0))
        q = queries[:8]
        baseline = idx.search(q, K)              # uncached
        cache = LUTCache(capacity=4)
        engine.set_lut_cache(cache)
        try:
            r1 = idx.search(q, K)
            r2 = idx.search(q, K)
        finally:
            engine.set_lut_cache(None)
        assert cache.counters["misses"] == 1
        assert cache.counters["hits"] == 1
        for r in (r1, r2):
            assert np.array_equal(np.asarray(baseline.ids), np.asarray(r.ids))
            assert np.array_equal(np.asarray(baseline.scores),
                                  np.asarray(r.scores))

    def test_jitted_searcher_bypasses_cache(self, corpus, queries):
        # inside a compiled Searcher bucket queries are tracers: the
        # engine hook must stand aside (caching a tracer would poison
        # every later batch)
        idx = make_index("pq4x4+lpq", corpus, kmeans_iters=2,
                         key=jax.random.PRNGKey(0))
        s = idx.searcher(K, SearchParams(), batch_sizes=(8,))
        cache = LUTCache(capacity=4)
        engine.set_lut_cache(cache)
        try:
            r = s(queries[:8])
        finally:
            engine.set_lut_cache(None)
        assert np.asarray(r.ids).shape == (8, K)
        assert len(cache) == 0                   # nothing cached under jit


# ---------------------------------------------------------------------------
# admission


class TestAdmission:
    def _ctrl(self, **kw):
        clk = FakeClock()
        kw.setdefault("rate_qps", 10.0)
        kw.setdefault("burst", 8.0)
        kw.setdefault("max_queue", 4)
        kw.setdefault("degrade_queue", 2)
        return AdmissionController(clock=clk, **kw), clk

    def test_ladder_under_overload(self):
        ctrl, _clk = self._ctrl()
        d1 = ctrl.admit(4, queue_depth=0)
        d2 = ctrl.admit(4, queue_depth=0)
        assert (d1.action, d2.action) == (ADMIT, ADMIT)   # burst covers 8
        d3 = ctrl.admit(4, queue_depth=0)                  # bucket empty
        assert (d3.action, d3.reason) == (SHED, "budget")
        assert ctrl.counters["admission_shed_queries"] == 4

    def test_degrade_on_budget_and_watermark(self):
        ctrl, _clk = self._ctrl(burst=5.0)
        assert ctrl.admit(4, queue_depth=0).action == ADMIT   # 1 token left
        d = ctrl.admit(4, queue_depth=0)       # full cost 4 > 1, 0.25*4=1 ok
        assert (d.action, d.reason) == (DEGRADE, "budget")
        ctrl2, _ = self._ctrl()
        d = ctrl2.admit(4, queue_depth=2)      # at the degrade watermark
        assert (d.action, d.reason) == (DEGRADE, "queue")

    def test_hard_queue_bound_and_refill(self):
        ctrl, clk = self._ctrl()
        d = ctrl.admit(1, queue_depth=4)
        assert (d.action, d.reason) == (SHED, "queue")
        ctrl.admit(8, queue_depth=0)                      # drain the bucket
        assert ctrl.admit(8, queue_depth=0).action == SHED
        clk.advance(1.0)                                  # +10 tokens
        assert ctrl.admit(8, queue_depth=0).action == ADMIT

    def test_deadline_at_arrival_and_recheck(self):
        ctrl, clk = self._ctrl()
        assert ctrl.admit(1, 0, deadline=clk() - 0.1).action == SHED
        d = ctrl.admit(1, 0, deadline=clk() + 1.0)
        assert d.action == ADMIT
        # queue aging past the deadline sheds at dequeue
        clk.advance(2.0)
        assert ctrl.recheck(d, deadline=clk() - 1.0).action == SHED
        # remaining budget below the latency EMA degrades
        d = ctrl.admit(1, 0, deadline=clk() + 0.05)
        ctrl.observe(0.2)
        out = ctrl.recheck(d, deadline=clk() + 0.05)
        assert (out.action, out.reason) == (DEGRADE, "deadline")
        assert ctrl.counters["admission_rechecks"] == 2

    def test_degrade_policy_scaling(self):
        pol = DegradePolicy()
        sp = pol.params(SearchParams(nprobe=8, ef_search=100))
        assert (sp.nprobe, sp.ef_search) == (4, 50)
        assert pol.params(SearchParams(nprobe=1, ef_search=1)).nprobe == 1
        assert pol.rerank_depth(40, k=10) == 10
        assert pol.rerank_depth(100, k=10) == 25
        assert pol.rerank_depth(0, k=10) == 0     # no tail stays no tail
        assert pol.rerank_depth(12, k=10) == 10   # never below k

    def test_ema(self):
        ctrl, _ = self._ctrl()
        ctrl.observe(0.1)
        assert ctrl.ema_latency == pytest.approx(0.1)
        ctrl.observe(0.2)
        assert ctrl.ema_latency == pytest.approx(0.125)


# ---------------------------------------------------------------------------
# telemetry


class TestTelemetry:
    def test_request_trace_and_summary(self):
        clk = FakeClock()
        t = Telemetry(clock=clk, meta={"runtime": {"profile": "default"}})
        tr = t.request(0)
        with tr.span("execute"):
            clk.advance(0.010)
        tr.phase("queue_wait", 0.005)
        tr.annotate(outcome="served", bucket=8)
        tr.finish()
        tr.finish()                              # idempotent
        assert t.counters["requests"] == 1
        assert len(t.events) == 1
        ev = t.events[0]
        assert ev["execute_s"] == pytest.approx(0.010)
        assert ev["queue_wait_s"] == pytest.approx(0.005)
        assert ev["outcome"] == "served"
        assert t.summary()["execute"]["count"] == 1
        assert t.percentiles("execute")["p50_ms"] == pytest.approx(10.0)

    def test_adhoc_span_and_events(self):
        clk = FakeClock()
        t = Telemetry(clock=clk)
        with t.span("maintenance/compact", trigger="drift"):
            clk.advance(0.5)
        t.event("write", op="delete", rows=4)
        kinds = [e["type"] for e in t.events]
        assert kinds == ["span", "write"]
        assert t.events[0]["dur_s"] == pytest.approx(0.5)

    def test_to_json_round_trip(self):
        t = Telemetry(meta={"runtime": {"profile": "ci-cpu"}})
        t.counters["queries_served"] += np.int64(8)      # numpy survives
        t.event("shed", reason="queue", queries=np.int32(4))
        buf = io.StringIO()
        payload = t.to_json(buf)
        parsed = json.loads(buf.getvalue())
        assert parsed["meta"]["runtime"]["profile"] == "ci-cpu"
        assert parsed["counters"]["queries_served"] == 8
        assert parsed["events"][0]["queries"] == 4
        assert payload["counters"] == parsed["counters"]

    def test_to_json_path(self, tmp_path):
        t = Telemetry()
        out = tmp_path / "tel.json"
        t.to_json(out)
        assert set(json.loads(out.read_text())) == {
            "meta", "counters", "summary", "events"}


# ---------------------------------------------------------------------------
# background compaction + maintenance


def _map_ids(scratch_ids: np.ndarray, ext_ids: np.ndarray) -> np.ndarray:
    return np.asarray(ext_ids)[np.asarray(scratch_ids)]


class TestBackgroundCompaction:
    def _make(self, corpus, extra, n_extra=200):
        idx = make_index("stream(flat,lpq4)", corpus, seal_threshold=100,
                         auto_compact=False)
        idx.upsert(np.arange(2000, 2000 + n_extra), extra[:n_extra])
        idx.delete(np.arange(0, 8))
        return idx

    def test_full_snapshot_parity_with_from_scratch(self, corpus, extra,
                                                    queries):
        idx = self._make(corpus, extra)
        pending = idx.compact_snapshot(full=True)
        assert pending is not None and pending.recalibrated
        assert idx.apply_compaction(pending)
        st = idx.stats()
        assert st["segments"] == 1 and st["tombstones"] == 0
        # the exact-parity invariant through the background path: the
        # swapped-in segment scores bit-identically to a from-scratch
        # build on the surviving rows
        ext_ids, vecs = idx.live_items()
        ref = make_index("flat,lpq4", vecs)
        res_ref = ref.search(queries, K)
        res = idx.search(queries, K)
        np.testing.assert_array_equal(
            _map_ids(np.asarray(res_ref.ids), ext_ids), np.asarray(res.ids))
        np.testing.assert_allclose(np.asarray(res_ref.scores),
                                   np.asarray(res.scores))

    def test_background_matches_synchronous_compact(self, corpus, extra,
                                                    queries):
        idx_a = self._make(corpus, extra)
        idx_b = self._make(corpus, extra)
        pending = idx_a.compact_snapshot(full=True)
        assert idx_a.apply_compaction(pending)
        idx_b.compact(full=True)
        ra, rb = idx_a.search(queries, K), idx_b.search(queries, K)
        assert np.array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
        assert np.array_equal(np.asarray(ra.scores), np.asarray(rb.scores))

    def test_concurrent_delete_survives_swap(self, corpus, extra):
        # rows deleted while the merge builds off-lock must stay dead
        # after the swap (the snapshot re-applies them as tombstones)
        idx = self._make(corpus, extra)
        pending = idx.compact_snapshot(full=True)
        killed = idx.delete(np.arange(20, 24))
        assert killed == 4
        n_before = idx.n
        assert idx.apply_compaction(pending)
        assert idx.n == n_before
        ext_ids, _vecs = idx.live_items()
        assert not np.isin(np.arange(20, 24), ext_ids).any()

    def test_competing_swap_is_dropped(self, corpus, extra):
        idx = self._make(corpus, extra)
        p1 = idx.compact_snapshot(full=True)
        p2 = idx.compact_snapshot(full=True)
        assert idx.apply_compaction(p1)
        assert not idx.apply_compaction(p2)      # group no longer current
        assert idx.counters["swap_conflicts"] == 1

    def test_epoch_tracks_structural_change(self, corpus, extra):
        idx = make_index("stream(flat,lpq4)", corpus, seal_threshold=100,
                         auto_compact=False)
        e0 = idx.epoch
        idx.upsert(np.arange(2000, 2010), extra[:10])    # memtable-only
        assert idx.epoch == e0
        idx.delete([999_999])                            # no-op delete
        assert idx.epoch == e0
        idx.delete([3])                                  # real tombstone
        assert idx.epoch > e0


class TestMaintenanceScheduler:
    def test_rejects_immutable_index(self, corpus):
        with pytest.raises(TypeError, match="mutable"):
            MaintenanceScheduler(make_index("flat,lpq8", corpus))

    def test_run_once_idle_and_forced(self, corpus, extra):
        idx = make_index("stream(flat,lpq4)", corpus, seal_threshold=100,
                         auto_compact=False)
        idx.upsert(np.arange(2000, 2200), extra[:200])
        t = Telemetry()
        sched = MaintenanceScheduler(idx, telemetry=t)
        out = sched.run_once(force_full=True)
        assert out["swapped"] and out["trigger"] == "forced"
        assert idx.stats()["segments"] == 1
        assert t.counters["maintenance_swaps"] == 1
        # nothing left to do
        assert sched.run_once() == {"ran": False}

    def test_segment_trigger_and_thread(self, corpus, extra):
        idx = make_index("stream(flat,lpq4)", corpus, seal_threshold=50,
                         auto_compact=False, max_segments=2)
        for i in range(4):                       # one sealed segment each
            idx.upsert(np.arange(2000 + i * 50, 2050 + i * 50),
                       extra[i * 50:(i + 1) * 50])
        assert idx.stats()["segments"] > 2
        with MaintenanceScheduler(idx, interval_s=0.01) as sched:
            deadline = 200
            while (idx.compactor.should_compact(idx.manifest.segments)
                   and deadline):
                deadline -= 1
                import time
                time.sleep(0.01)
        assert sched.counters["maintenance_swaps"] >= 1
        assert idx.stats()["segments"] <= 2


# ---------------------------------------------------------------------------
# serve loop (rebuilt on the subsystem)


class TestServeLoop:
    def test_smoke_cache_mutate_telemetry(self, tmp_path):
        from repro.launch import serve

        out = tmp_path / "tel.json"
        serve.main([
            "--index", "stream(flat,lpq4)", "--n", "500", "--d", "24",
            "--requests", "6", "--batch", "8", "--mutate",
            "--cache", "16", "--hot-repeat", "2",
            "--telemetry-out", str(out),
        ])
        tel = json.loads(out.read_text())
        c = tel["counters"]
        assert tel["meta"]["runtime"]["profile"]
        # memtable-only upsert skipped its re-plan; the real delete did not
        assert c["replans_avoided"] >= 1
        assert c["replans"] >= 1
        assert c.get("cache_hits", 0) or any(
            e.get("cache") == "hit" for e in tel["events"]
            if e["type"] == "request")
        assert c["queries_served"] > 0

    def test_overload_degrades_and_sheds_cleanly(self, tmp_path):
        from repro.launch import serve

        out = tmp_path / "tel.json"
        serve.main([
            "--index", "flat,lpq8", "--n", "500", "--d", "24",
            "--requests", "8", "--batch", "8",
            "--admission", "--rate", "64", "--burst", "20",
            "--max-queue", "4",
            "--telemetry-out", str(out),
        ])
        tel = json.loads(out.read_text())
        c = tel["counters"]
        # the ladder engaged: some degraded, some shed, none crashed
        assert c["admission_shed"] >= 1
        assert c["admission_degrade"] >= 1
        assert c["admission_shed_queries"] >= 8
        sheds = [e for e in tel["events"] if e["type"] == "shed"]
        assert len(sheds) == c["admission_shed"]
        # served + shed covers every query request issued
        assert c["queries_served"] + c["admission_shed_queries"] == 64


# ---------------------------------------------------------------------------
# trend gate


class TestTrendGate:
    def _doc(self):
        return {
            "meta": {"smoke": True, "backend": "cpu",
                     "runtime": {"profile": "ci-cpu", "backend": "cpu",
                                 "interpret": True, "deterministic": True}},
            "cells": {"flat,lpq8": {"qps": 1000.0, "recall_at_10": 0.95,
                                    "p95_ms": 3.0}},
        }

    def test_walk_classifies_metrics(self):
        trend = pytest.importorskip("benchmarks.trend")
        got = {p: kind for p, kind, _v in trend.walk_metrics(self._doc())}
        assert got == {"cells/flat,lpq8/qps": "qps",
                       "cells/flat,lpq8/recall_at_10": "recall"}

    def test_gate_trips_on_injected_regression(self, tmp_path):
        trend = pytest.importorskip("benchmarks.trend")
        base_dir = tmp_path / "baseline"
        base_dir.mkdir()
        doc = self._doc()
        (base_dir / "BENCH_x.json").write_text(json.dumps(doc))
        fresh = tmp_path / "BENCH_x.json"

        fresh.write_text(json.dumps(doc))
        (r,) = trend.run_gate([str(fresh)], str(base_dir))
        assert r["status"] == "compared" and not r["regressions"]

        doc["cells"]["flat,lpq8"]["qps"] = 700.0
        doc["cells"]["flat,lpq8"]["recall_at_10"] = 0.93
        fresh.write_text(json.dumps(doc))
        (r,) = trend.run_gate([str(fresh)], str(base_dir))
        assert sorted(g["kind"] for g in r["regressions"]) == [
            "qps", "recall"]

    def test_gate_refuses_cross_backend(self, tmp_path):
        trend = pytest.importorskip("benchmarks.trend")
        base_dir = tmp_path / "baseline"
        base_dir.mkdir()
        doc = self._doc()
        (base_dir / "BENCH_x.json").write_text(json.dumps(doc))
        doc["meta"]["runtime"]["interpret"] = False
        doc["cells"]["flat,lpq8"]["qps"] = 1.0   # huge "regression"...
        fresh = tmp_path / "BENCH_x.json"
        fresh.write_text(json.dumps(doc))
        (r,) = trend.run_gate([str(fresh)], str(base_dir))
        assert r["status"] == "skipped"          # ...refused, not failed

    def test_self_test(self, capsys):
        trend = pytest.importorskip("benchmarks.trend")
        trend._self_test()
        assert "self-test OK" in capsys.readouterr().out
