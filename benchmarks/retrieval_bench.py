"""Quantized retrieval scoring (the recsys retrieval_cand cell, reduced):
fp32 vs int8 candidate scoring parity + memory — the paper's technique on
its most direct production surface.  A third arm serves the same corpus
through the registry's flat index (factory string) to keep the serving
path and the raw scoring path honest against each other."""

from __future__ import annotations

import jax

from benchmarks.common import emit, sized, timeit
from repro.core.preserve import recall_at_k
from repro.knn import make_index
from repro.models.recsys import embedding as E
from repro.models.recsys import retrieval as RT


def main() -> None:
    n = sized(100_000)
    d = 64
    k = 100
    key = jax.random.PRNGKey(0)
    cands = jax.random.normal(key, (n, d)) * 0.05
    queries = jax.random.normal(jax.random.PRNGKey(1), (8, d)) * 0.05

    qt = E.QuantizedTable.from_dense(cands)
    s_fp, i_fp = RT.retrieve_fp32(queries, cands, k=k)
    sec_fp = timeit(lambda: RT.retrieve_fp32(queries, cands, k=k))
    sec_q8 = timeit(lambda: RT.retrieve_quantized(queries, qt.codes, qt.params, k=k, use_pallas=False))
    _s, i_q8 = RT.retrieve_quantized(queries, qt.codes, qt.params, k=k, use_pallas=False)
    rec = float(recall_at_k(i_fp, i_q8))
    mem_fp = n * d * 4
    emit("retrieval/fp32", sec_fp, f"mem={mem_fp}B")
    emit(
        "retrieval/int8", sec_q8,
        f"recall={rec:.4f} mem={qt.memory_bytes()}B ratio={qt.memory_bytes()/mem_fp:.3f}",
    )

    # the same corpus through the unified index API (registry serving path)
    idx = make_index("flat,lpq8@absmax", cands)
    sec_idx = timeit(lambda: idx.search(queries, k))
    i_idx = idx.search(queries, k).ids
    rec_idx = float(recall_at_k(i_fp, i_idx))
    emit(
        "retrieval/flat_factory", sec_idx,
        f"recall={rec_idx:.4f} mem={idx.memory_bytes()}B",
    )


if __name__ == "__main__":
    main()
