"""Mutable-index benchmark: churn throughput, exact-parity, and
recall-under-drift with compaction/recalibration — writes
``BENCH_stream.json`` (plus the harness CSV rows).

Two scenarios over ``stream(flat,<quant>)`` (DESIGN.md §10):

**churn** — a bulk build absorbs a mixed upsert/delete/query workload
through the Searcher (snapshot plans; writes re-plan).  Records query
latency before and after churn, write+replan latency, and the
acceptance exact-parity check: after ``compact(full=True)`` the stream
index must return *bit-identical* ids/scores to a from-scratch
``flat,<quant>`` build on the surviving rows in arrival order.

**drift** — the live distribution diverges from the bulk segment's
calibration (offset cluster retired by deletes while a shifted insert
stream lands), then three arms search the same live set at the same
k/rerank budget:

    never-compact         multi-segment: the fp32 merge re-score keeps
                          recall high, but tombstoned segments over-fetch
                          (depth + dead rows), so the per-query rescore
                          cost explodes — the LSM "tombstone debt"
    compact, stale        constants reused from the drifted calibration:
                          recall craters (saturated codes — §3.2's
                          data-driven fit is load-bearing)
    compact, recalibrate  constants re-learned from the surviving rows:
                          recall recovered at the compacted budget

    PYTHONPATH=src python -m benchmarks.bench_stream           # full
    PYTHONPATH=src python -m benchmarks.bench_stream --smoke   # CI
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from benchmarks.common import emit, runtime_meta, sized
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import make_index

K_TOP = 10


def _perturbed_queries(vecs: np.ndarray, n_q: int, rng) -> np.ndarray:
    rows = vecs[rng.choice(vecs.shape[0], n_q, replace=False)]
    return (rows + rng.normal(size=rows.shape).astype(np.float32) * 0.005
            ).astype(np.float32)


def _exact_gt(vecs: np.ndarray, ext_ids: np.ndarray, queries, metric: str):
    gt = np.asarray(make_index(f"flat,{metric}", vecs).search(queries, K_TOP).ids)
    return np.where(gt >= 0, ext_ids[gt], -1)


def churn_scenario(quant: str, n: int, d: int, n_q: int) -> dict:
    metric = "l2"
    rng = np.random.default_rng(0)
    corpus = np.asarray(synthetic.load("product", n, 8)[0][:, :d])
    extra = np.asarray(
        synthetic.load("product", n, 8, key=jax.random.PRNGKey(3))[0][:, :d]
    )

    idx = make_index(f"stream(flat,{quant},{metric})", corpus,
                     seal_threshold=max(64, n // 8))
    queries = _perturbed_queries(corpus, n_q, rng)

    searcher = idx.searcher(K_TOP)
    jax.block_until_ready(searcher(queries).ids)
    t0 = time.perf_counter()
    jax.block_until_ready(searcher(queries).ids)
    fresh_s = time.perf_counter() - t0

    # mixed churn: insert half a corpus, delete a third, replace a slice
    t0 = time.perf_counter()
    idx.upsert(np.arange(n, n + n // 2), extra[: n // 2])
    idx.delete(np.arange(0, n, 3))
    idx.upsert(np.arange(100, 100 + n // 10),
               extra[n // 2 : n // 2 + n // 10])
    write_s = time.perf_counter() - t0

    searcher = idx.searcher(K_TOP)            # snapshot plan: re-plan
    jax.block_until_ready(searcher(queries).ids)
    t0 = time.perf_counter()
    res = searcher(queries)
    jax.block_until_ready(res.ids)
    churned_s = time.perf_counter() - t0

    ext_ids, vecs = idx.live_items()
    gt = _exact_gt(vecs, ext_ids, queries, metric)
    rec_churned = float(recall_at_k(gt, np.asarray(res.ids)))

    # acceptance: full compaction == from-scratch build, bit-for-bit
    idx.compact(full=True)
    a = idx.search(queries, K_TOP)
    scratch = make_index(f"flat,{quant},{metric}", vecs)
    b = scratch.search(queries, K_TOP)
    b_ids = np.asarray(b.ids)
    parity = bool(
        np.array_equal(np.asarray(a.ids),
                       np.where(b_ids >= 0, ext_ids[b_ids], -1))
        and np.allclose(np.asarray(a.scores), np.asarray(b.scores))
    )
    st = idx.stats()
    return {
        "quant": quant, "n": n, "rows_live": int(idx.n),
        "query_ms_fresh": fresh_s * 1e3,
        "query_ms_churned": churned_s * 1e3,
        "write_s": write_s,
        "recall_churned": rec_churned,
        "exact_parity_after_compact": parity,
        "segments_after": st["segments"], "seals": st["seals"],
        "compactions": st["compactions"],
    }


def drift_scenario(quant: str, n: int, d: int, n_q: int) -> dict:
    metric = "l2"
    rng = np.random.default_rng(7)
    base = np.asarray(synthetic.load("product", n, 8)[0][:, :d])
    wide = base[rng.permutation(n)] + 0.4          # offset cluster
    corpus = np.concatenate([base, wide]).astype(np.float32)
    fresh = np.asarray(
        synthetic.load("product", n // 2, 8, key=jax.random.PRNGKey(3))[0][
            : n // 2, :d
        ]
    ) * 0.97

    def build():
        idx = make_index(f"stream(flat,{quant},{metric})+r32", corpus,
                         seal_threshold=10 ** 9, auto_compact=False)
        idx.delete(np.arange(n, 2 * n))            # retire the old cluster
        idx.upsert(np.arange(2 * n, 2 * n + n // 2), fresh)  # shifted stream
        idx.seal()
        return idx

    idx = build()
    ext_ids, vecs = idx.live_items()
    queries = _perturbed_queries(vecs, n_q, rng)
    gt = _exact_gt(vecs, ext_ids, queries, metric)

    def arm(ix):
        res = ix.searcher(K_TOP)(queries)         # +r32, default depth 4k
        return (float(recall_at_k(gt, np.asarray(res.ids))),
                int(res.stats.get("reranked", 0)))

    drift_before = idx.stats()["max_drift"]
    r_never, c_never = arm(idx)

    stale = build()
    stale.compact(full=True, recalibrate=False)
    r_stale, c_stale = arm(stale)

    recal = build()
    recal.compact(full=True)                      # recalibrate=True default
    r_recal, c_recal = arm(recal)

    return {
        "quant": quant, "n_live": int(recal.n), "max_drift": drift_before,
        "recall_never_compact": r_never, "rescored_never_compact": c_never,
        "recall_compact_stale": r_stale, "rescored_compact_stale": c_stale,
        "recall_compact_recalibrated": r_recal,
        "rescored_compact_recalibrated": c_recal,
        "recalibration_recall_gain": r_recal - r_stale,
        "rescore_cost_ratio_never_over_recal": c_never / max(c_recal, 1),
    }


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--out", default="BENCH_stream.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + lpq4-only (the CI check)")
    args = ap.parse_args(argv)

    n = 1024 if args.smoke else sized(args.n)
    n_q = 64 if args.smoke else args.queries
    quants = ("lpq4",) if args.smoke else ("lpq4", "lpq8")

    results = {
        "meta": {
            "n": n, "d": args.d, "k": K_TOP, "queries": n_q,
            "backend": jax.default_backend(),
            "platform": platform.platform(), "smoke": bool(args.smoke),
            "runtime": runtime_meta(),
        },
        "churn": {}, "drift": {},
    }
    for quant in quants:
        c = churn_scenario(quant, n, args.d, n_q)
        results["churn"][quant] = c
        emit(f"bench_stream/churn/{quant}", c["query_ms_churned"] / 1e3 / n_q,
             f"recall={c['recall_churned']:.4f} "
             f"parity={int(c['exact_parity_after_compact'])} "
             f"segments={c['segments_after']}")
        if not c["exact_parity_after_compact"]:
            raise SystemExit(
                f"exact-parity violation: stream(flat,{quant}) after "
                "churn + full compaction != from-scratch build"
            )

        dr = drift_scenario(quant, n, args.d, n_q)
        results["drift"][quant] = dr
        emit(
            f"bench_stream/drift/{quant}", 0.0,
            f"never={dr['recall_never_compact']:.4f}"
            f"@{dr['rescored_never_compact']} "
            f"stale={dr['recall_compact_stale']:.4f} "
            f"recal={dr['recall_compact_recalibrated']:.4f}"
            f"@{dr['rescored_compact_recalibrated']} "
            f"drift={dr['max_drift']:.2f}",
        )

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_stream] wrote {args.out}")


if __name__ == "__main__":
    main()
