"""§Roofline: compute / memory / collective terms per (arch x shape) cell.

Methodology (full discussion in EXPERIMENTS.md §Roofline):

  * XLA's ``cost_analysis()`` on this container counts while-loop BODIES
    ONCE (verified: a 10-iteration scanned matmul reports 1 matmul), so
    compiled numbers are lower bounds with loop-depth-dependent error.
    We therefore use ANALYTIC workload models for all three terms — the
    same napkin math the perf loop optimizes — and keep the raw HLO
    numbers (flops, per-collective byte counts) as structural evidence
    of the schedule (which collectives exist, at what tile sizes).

  * Terms (TPU v5e, per 256-chip pod):
      compute    = FLOPs / (256 · 197e12 bf16  [394e12 for int8 cells])
      memory     = HBM bytes / (256 · 819e9)
      collective = per-device wire bytes / 50e9 (one ICI link, worst case)

Usage: PYTHONPATH=src python -m benchmarks.roofline
"""

from __future__ import annotations

import argparse
import glob
import json
import os

# hardware peaks live with the tuning space (repro/tune/space.py): the
# autotuner's candidate pruning and this table must price a byte/flop
# identically, so there is exactly one copy of the constants
from repro.tune.space import HBM_BW, ICI_BW, PEAK_BF16, PEAK_INT8

CHIPS = 256
DP, TP = 16, 16   # single-pod mesh factors


def _ring(nbytes: float) -> float:
    """Ring all-reduce wire bytes per device ~ 2x payload."""
    return 2.0 * nbytes


# --------------------------------------------------------------------------
# analytic workload models
# --------------------------------------------------------------------------

def lm_analytics(arch_id: str, shape: dict) -> dict:
    from repro.configs import get

    mod = get(arch_id)
    cfg = mod.config()
    micro = getattr(mod, "TRAIN_MICROBATCHES", 4)
    N = cfg.param_count()
    Na = cfg.active_param_count()
    B, S = shape["global_batch"], shape["seq_len"]
    kind = shape["kind"]
    d = cfg.d_model
    L = cfg.n_layers

    pat = (cfg.layer_pattern * L)[:L]

    def s_eff(c):
        return min(S, cfg.window if c == "l" else cfg.chunk if c == "c" else S)

    attn_fwd = sum(
        4 * B * S * s_eff(c) * cfg.n_heads * cfg.head_dim * 0.5 for c in pat
    )

    if kind == "train":
        flops = 6 * Na * B * S + 3 * attn_fwd
        bytes_hbm = (
            micro * 2 * Na                      # weights streamed per microbatch
            + 16 * N                            # f32 moments r/w + grads
            + 4 * B * S * d * L * 2 * 2         # remat carries r/w (bf16)
        )
        # TP activation all-reduces: 2/layer fwd + 2/layer bwd, [B_mb_loc,S,d] bf16
        b_loc = B / DP / micro
        tp = L * micro * 4 * _ring(b_loc * S * d * 2)
        # ZeRO grad reduce-scatter + param all-gather over data: ~2 x f32 grads/TP
        dp_sync = 2 * _ring(4 * N / TP)
        # MoE all-to-all: 2 x tokens in+out per MoE layer per microbatch
        a2a = 0.0
        if cfg.moe is not None:
            n_moe = L // cfg.block_layers if cfg.moe_every > 1 else L
            a2a = n_moe * micro * 2 * 2 * (B / DP / micro) * S * d * 2
        coll = tp + dp_sync + a2a
    elif kind == "prefill":
        flops = 2 * Na * B * S + attn_fwd
        bytes_hbm = 2 * Na + 2 * B * S * cfg.n_kv * cfg.head_dim * L * 2 * 2
        b_loc = B / DP
        coll = L * 2 * _ring(b_loc * S * d * 2)
        if cfg.moe is not None:
            n_moe = L // cfg.block_layers if cfg.moe_every > 1 else L
            coll += n_moe * 2 * 2 * b_loc * S * d * 2
    else:  # decode
        flops = 2 * Na * B + sum(
            4 * B * min(S, s_eff(c)) * cfg.n_heads * cfg.head_dim for c in pat
        )
        kv_bytes = 2 * B * S * cfg.n_kv * cfg.head_dim * L * 2
        bytes_hbm = 2 * Na + kv_bytes
        b_loc = max(B / DP, 1)
        # TP act all-reduce [B_loc, 1, d] x2/layer + S-sharded softmax psums
        coll = L * 2 * _ring(b_loc * 1 * d * 2) + L * 3 * _ring(
            b_loc * cfg.n_heads * 4
        )
    return dict(flops=flops, bytes=bytes_hbm, coll=coll, peak=PEAK_BF16)


def recsys_analytics(arch_id: str, shape: dict) -> dict:
    from repro.configs import get

    cfg = get(arch_id).config()
    kind = shape["kind"]
    d = cfg.embed_dim

    def mlp_flops(dims, b):
        f, prev = 0, dims[0]
        for h in dims[1:]:
            f += 2 * b * prev * h
            prev = h
        return f

    if kind == "retrieval":
        N = shape["n_candidates"]
        flops = 2 * shape["batch"] * N * d
        bytes_hbm = N * d * 1 + shape["batch"] * d * 4   # int8 table
        # distributed top-k: k-sized all-gather per shard
        coll = _ring(CHIPS * 100 * 8)
        return dict(flops=flops, bytes=bytes_hbm, coll=coll, peak=PEAK_INT8)

    B = shape["batch"]
    F = cfg.n_sparse
    lookup_bytes = B * F * d * 4

    if cfg.kind == "dlrm":
        nf = F + 1
        flops = (
            mlp_flops((cfg.n_dense, *cfg.bot_mlp), B)
            + 2 * B * nf * nf * d
            + mlp_flops((cfg.bot_mlp[-1] + nf * (nf - 1) // 2, *cfg.top_mlp), B)
        )
    elif cfg.kind == "autoint":
        da = cfg.n_heads * cfg.d_attn
        flops = cfg.n_attn_layers * (
            2 * B * F * d * da * 3 + 2 * B * F * F * da * 2 + 2 * B * F * d * da
        ) + 2 * B * F * da
    elif cfg.kind == "dien":
        flops = 2 * B * cfg.seq_len * (d + cfg.gru_dim) * 3 * cfg.gru_dim * 2
        flops += mlp_flops((d * cfg.n_sparse + cfg.gru_dim, *cfg.mlp, 1), B)
    else:  # dcnv2
        d_in = cfg.n_dense + F * d
        flops = cfg.n_cross_layers * 2 * B * d_in * d_in + mlp_flops((d_in, *cfg.mlp), B)

    # embedding exchange: gathered rows cross the mesh (tables row-sharded
    # over data x model; batch over data) — in + grad-scatter back
    coll = _ring(lookup_bytes / DP) * (2 if kind == "train" else 1)
    if kind == "train":
        flops *= 3
        bytes_hbm = 5 * lookup_bytes + 0.0  # touched rows r/w + dense mlps
    else:
        bytes_hbm = lookup_bytes + B * 64
    return dict(flops=flops, bytes=bytes_hbm, coll=coll, peak=PEAK_BF16)


def gnn_analytics(arch_id: str, shape: dict) -> dict:
    from repro.configs import get

    cfg = get(arch_id).config()
    h, rbf = cfg.d_hidden, cfg.n_rbf
    kind = shape["kind"]
    if kind == "molecule":
        n_nodes = shape["batch"] * shape["n_nodes"]
        n_edges = shape["batch"] * shape["n_edges"]
    elif kind == "minibatch":
        n_nodes, n_edges = shape["pad_nodes"], shape["pad_edges"]
    else:
        n_nodes, n_edges = shape["n_nodes"], shape["n_edges"]

    per_inter = (
        2 * n_edges * rbf * h + 2 * n_edges * h * h
        + n_edges * h + 2 * n_nodes * h * h * 2
    )
    flops = 3 * (cfg.n_interactions * per_inter + 2 * n_edges * rbf)  # train
    bytes_hbm = cfg.n_interactions * (n_edges * h * 4 * 3 + n_nodes * h * 4 * 2)
    # edge-parallel scatter: psum of [n_nodes, h] f32 per interaction,
    # fwd + bwd
    coll = cfg.n_interactions * 2 * _ring(n_nodes * h * 4)
    return dict(flops=flops, bytes=bytes_hbm, coll=coll, peak=PEAK_BF16)


def analytics_for(arch_id: str, shape_key: str) -> dict:
    from repro.configs import get

    mod = get(arch_id)
    shape = dict(mod.SHAPES[shape_key])
    if mod.FAMILY == "lm":
        return lm_analytics(arch_id, shape)
    if mod.FAMILY == "recsys":
        return recsys_analytics(arch_id, shape)
    return gnn_analytics(arch_id, shape)


# --------------------------------------------------------------------------
# table assembly
# --------------------------------------------------------------------------

def cell_rows(dryrun_dir: str, suffix: str = "__pod.json"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, f"*{suffix}"))):
        rec = json.load(open(path))
        arch, shape_key = rec["arch"], rec["shape"]
        if "skipped" in rec:
            rows.append({"arch": arch, "shape": shape_key, "skipped": rec["skipped"]})
            continue
        ana = analytics_for(arch, shape_key)
        t_compute = ana["flops"] / (CHIPS * ana["peak"])
        t_memory = ana["bytes"] / (CHIPS * HBM_BW)
        t_coll = ana["coll"] / ICI_BW
        terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        rows.append(
            {
                "arch": arch,
                "shape": shape_key,
                "t_compute_s": t_compute,
                "t_memory_s": t_memory,
                "t_collective_s": t_coll,
                "dominant": dominant,
                "roofline_fraction": t_compute / max(max(terms.values()), 1e-30),
                "model_flops": ana["flops"],
                "hlo_flops_raw_per_device": rec["flops"],
                "hlo_collectives": rec["collectives"],
                "memory_analysis": rec["memory_analysis"],
            }
        )
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()

    rows = cell_rows(args.dryrun_dir)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=2)

    hdr = (f"{'arch':26s} {'shape':14s} {'compute':>10s} {'memory':>10s} "
           f"{'collect.':>10s}  dominant    frac")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "skipped" in r:
            print(f"{r['arch']:26s} {r['shape']:14s} SKIP ({r['skipped'][:50]}...)")
            continue
        print(
            f"{r['arch']:26s} {r['shape']:14s} "
            f"{r['t_compute_s']:10.2e} {r['t_memory_s']:10.2e} "
            f"{r['t_collective_s']:10.2e}  {r['dominant']:10s} "
            f"{r['roofline_fraction']:5.2f}"
        )


if __name__ == "__main__":
    main()
