"""Perf-trajectory trend gate: diff fresh ``BENCH_*.json`` against the
previous run and fail CI on a real regression.

Every bench suite writes a JSON trajectory file whose ``meta`` carries
the runtime-profile stamp (``benchmarks.common.runtime_meta``).  This
gate walks the metric tree of each fresh file, finds the comparable
leaf metrics, and compares them against the same path in the baseline
copy of the same file:

  * keys containing ``qps`` — throughput, higher is better; a drop of
    more than ``--qps-drop`` (default 15%) is a regression;
  * keys starting with ``recall`` — paper-metric quality, higher is
    better; an absolute drop of more than ``--recall-drop`` (default
    0.01 — the recall@10 budget) is a regression.

Everything else (latency, memory, ratios) is trajectory data, not a
gate: wall-clock noise on shared CI runners would page people for
nothing, while QPS-over-15% and recall-over-0.01 are the two motions
the paper's claims actually live on.

Comparisons are refused (skipped with a note, never failed) when the
two runs are not comparable by construction:

  * no baseline copy of the file exists (first run, new suite);
  * ``meta["smoke"]`` differs (smoke shapes vs full shapes);
  * the backend / interpret-mode / profile stamp differs (CPU-interpret
    numbers vs hardware numbers — the "honest perf story" rule);
  * the TuneTable dispatch hash differs (``runtime.tune_table``): a run
    served through measured tile tables is not the same machine as an
    untuned or differently-tuned run;
  * either run's profile is marked non-deterministic.

    python -m benchmarks.trend --baseline-dir .bench-baseline BENCH_*.json
    python -m benchmarks.trend --self-test

Exit status: 0 clean (or only skips), 1 with a regression table on any
gated drop.  ``--self-test`` builds a synthetic baseline, checks a
clean copy passes, injects a QPS and a recall regression, and asserts
the gate trips — run in CI so the gate itself is tested.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
from typing import Iterator, Optional

DEFAULT_QPS_DROP = 0.15
DEFAULT_RECALL_DROP = 0.01

#: meta keys that must match for two runs to be comparable at all
_META_KEYS = ("smoke", "backend")
#: runtime-stamp keys that must match (profile/interpret/backend, plus
#: the TuneTable dispatch hash — two runs dispatching through different
#: measured tunings are different machines as far as QPS is concerned —
#: and the device topology: a 4-virtual-device mesh run must never gate
#: against a 1-device baseline; old baselines without a key compare as
#: None == None)
_RUNTIME_KEYS = ("profile", "backend", "interpret", "tune_table",
                 "n_devices")


def walk_metrics(node, path: str = "") -> Iterator[tuple[str, str, float]]:
    """Yield ``(path, kind, value)`` for every gated leaf metric.

    kind is ``"qps"`` (relative gate) or ``"recall"`` (absolute gate);
    classification is by the leaf key name, lowercased: containing
    "qps" / starting with "recall".  ``meta`` subtrees are never
    metrics.
    """
    if isinstance(node, dict):
        for k, v in node.items():
            sub = f"{path}/{k}" if path else str(k)
            if path == "" and k == "meta":
                continue
            if isinstance(v, (dict, list)):
                yield from walk_metrics(v, sub)
            elif isinstance(v, (int, float)) and not isinstance(v, bool):
                lk = str(k).lower()
                if "qps" in lk:
                    yield sub, "qps", float(v)
                elif lk.startswith("recall"):
                    yield sub, "recall", float(v)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from walk_metrics(v, f"{path}[{i}]")


def _comparable(fresh_meta: dict, base_meta: dict) -> Optional[str]:
    """None if the two runs may be compared, else the skip reason."""
    for k in _META_KEYS:
        if fresh_meta.get(k) != base_meta.get(k):
            return (f"meta.{k} differs "
                    f"({base_meta.get(k)!r} -> {fresh_meta.get(k)!r})")
    fr = fresh_meta.get("runtime") or {}
    br = base_meta.get("runtime") or {}
    for k in _RUNTIME_KEYS:
        if fr.get(k) != br.get(k):
            return (f"runtime.{k} differs "
                    f"({br.get(k)!r} -> {fr.get(k)!r})")
    if fr.get("deterministic") is False or br.get("deterministic") is False:
        return "non-deterministic profile (runs are expected to differ)"
    return None


def compare_file(fresh_path: str, baseline_path: str, *,
                 qps_drop: float, recall_drop: float) -> dict:
    """Compare one trajectory file against its baseline copy.

    Returns ``{"file", "status": "compared"|"skipped", "note",
    "regressions": [...], "checked": int}``; a regression entry is
    ``{"path", "kind", "base", "fresh", "delta"}``.
    """
    name = os.path.basename(fresh_path)
    if not os.path.exists(baseline_path):
        return {"file": name, "status": "skipped", "regressions": [],
                "checked": 0, "note": "no baseline copy (first run?)"}
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        base = json.load(f)
    reason = _comparable(fresh.get("meta", {}), base.get("meta", {}))
    if reason is not None:
        return {"file": name, "status": "skipped", "regressions": [],
                "checked": 0, "note": reason}

    base_metrics = {p: (kind, v) for p, kind, v in walk_metrics(base)}
    regressions, checked = [], 0
    for p, kind, v in walk_metrics(fresh):
        if p not in base_metrics:
            continue                     # new metric: no history yet
        _, bv = base_metrics[p]
        checked += 1
        if kind == "qps":
            bad = bv > 0 and v < bv * (1.0 - qps_drop)
            delta = (v - bv) / bv if bv else 0.0
        else:
            bad = v < bv - recall_drop
            delta = v - bv
        if bad:
            regressions.append({"path": p, "kind": kind, "base": bv,
                                "fresh": v, "delta": delta})
    return {"file": name, "status": "compared", "regressions": regressions,
            "checked": checked, "note": ""}


def run_gate(fresh_files: list[str], baseline_dir: str, *,
             qps_drop: float = DEFAULT_QPS_DROP,
             recall_drop: float = DEFAULT_RECALL_DROP) -> list[dict]:
    return [
        compare_file(f, os.path.join(baseline_dir, os.path.basename(f)),
                     qps_drop=qps_drop, recall_drop=recall_drop)
        for f in fresh_files
    ]


def _report(results: list[dict]) -> int:
    n_reg = 0
    for r in results:
        if r["status"] == "skipped":
            print(f"[trend] {r['file']}: SKIP — {r['note']}")
            continue
        if not r["regressions"]:
            print(f"[trend] {r['file']}: OK ({r['checked']} metrics)")
            continue
        n_reg += len(r["regressions"])
        print(f"[trend] {r['file']}: {len(r['regressions'])} regression(s) "
              f"of {r['checked']} metrics")
        for g in r["regressions"]:
            if g["kind"] == "qps":
                print(f"[trend]   {g['path']}: {g['base']:.1f} -> "
                      f"{g['fresh']:.1f} QPS ({g['delta'] * 100:+.1f}%)")
            else:
                print(f"[trend]   {g['path']}: {g['base']:.4f} -> "
                      f"{g['fresh']:.4f} recall ({g['delta']:+.4f})")
    return n_reg


def _self_test() -> None:
    """The gate gating itself: clean copy passes, injected QPS/recall
    regressions and a cross-backend mismatch behave as documented."""
    doc = {
        "meta": {"smoke": True, "backend": "cpu",
                 "runtime": {"profile": "ci-cpu", "backend": "cpu",
                             "interpret": True, "deterministic": True}},
        "cells": {
            "flat,lpq8": {"qps": 1000.0, "recall_at_10": 0.95,
                          "p95_ms": 3.0},
            "ivf64,lpq4+r32": {"qps": 4000.0, "recall_at_10": 0.91},
        },
        "ratios": [{"qps_ratio": 2.5}],
    }
    with tempfile.TemporaryDirectory() as td:
        base_dir = os.path.join(td, "baseline")
        os.mkdir(base_dir)
        bp = os.path.join(base_dir, "BENCH_x.json")
        fp = os.path.join(td, "BENCH_x.json")
        with open(bp, "w") as f:
            json.dump(doc, f)

        # 1. clean copy: compared, zero regressions
        with open(fp, "w") as f:
            json.dump(doc, f)
        (r,) = run_gate([fp], base_dir)
        assert r["status"] == "compared" and not r["regressions"], r
        assert r["checked"] == 5, r      # 3 qps-ish + 2 recall leaves

        # 2. tolerated noise: -10% qps, -0.005 recall — still clean
        noisy = json.loads(json.dumps(doc))
        noisy["cells"]["flat,lpq8"]["qps"] = 900.0
        noisy["cells"]["flat,lpq8"]["recall_at_10"] = 0.945
        with open(fp, "w") as f:
            json.dump(noisy, f)
        (r,) = run_gate([fp], base_dir)
        assert not r["regressions"], r

        # 3. injected regressions: -30% qps, -0.05 recall — both trip
        bad = json.loads(json.dumps(doc))
        bad["cells"]["flat,lpq8"]["qps"] = 700.0
        bad["cells"]["ivf64,lpq4+r32"]["recall_at_10"] = 0.86
        with open(fp, "w") as f:
            json.dump(bad, f)
        (r,) = run_gate([fp], base_dir)
        kinds = sorted(g["kind"] for g in r["regressions"])
        assert kinds == ["qps", "recall"], r

        # 4. backend flip: refused, not failed
        other = json.loads(json.dumps(bad))
        other["meta"]["runtime"]["interpret"] = False
        other["meta"]["backend"] = "tpu"
        with open(fp, "w") as f:
            json.dump(other, f)
        (r,) = run_gate([fp], base_dir)
        assert r["status"] == "skipped", r

        # 5. tuning flip: a run dispatching through a measured TuneTable
        # must never be trended against an untuned (or differently
        # tuned) baseline — refused on the dispatch hash, not failed
        tuned = json.loads(json.dumps(bad))
        tuned["meta"]["runtime"]["tune_table"] = "833e7be25e72d995"
        with open(fp, "w") as f:
            json.dump(tuned, f)
        (r,) = run_gate([fp], base_dir)
        assert r["status"] == "skipped" and "tune_table" in r["note"], r

        # 6. topology flip: a 4-virtual-device mesh run is not the same
        # machine as the 1-device baseline — refused, not failed
        wide = json.loads(json.dumps(bad))
        wide["meta"]["runtime"]["n_devices"] = 4
        with open(bp) as f:
            narrow = json.load(f)
        narrow["meta"]["runtime"]["n_devices"] = 1
        with open(bp, "w") as f:
            json.dump(narrow, f)
        with open(fp, "w") as f:
            json.dump(wide, f)
        (r,) = run_gate([fp], base_dir)
        assert r["status"] == "skipped" and "n_devices" in r["note"], r
        with open(bp, "w") as f:
            json.dump(doc, f)

        # 7. missing baseline: skipped with a note
        (r,) = run_gate([fp], os.path.join(td, "nowhere"))
        assert r["status"] == "skipped" and "no baseline" in r["note"], r
    print("[trend] self-test OK (clean pass, noise tolerated, injected "
          "QPS+recall regressions tripped, backend, tuning and topology "
          "flips refused)")


def main(argv: Optional[list[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*", help="fresh BENCH_*.json files")
    ap.add_argument("--baseline-dir", default=".bench-baseline",
                    help="directory holding the previous run's copies")
    ap.add_argument("--qps-drop", type=float, default=DEFAULT_QPS_DROP,
                    help="relative QPS drop that fails the gate")
    ap.add_argument("--recall-drop", type=float, default=DEFAULT_RECALL_DROP,
                    help="absolute recall drop that fails the gate")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate trips on injected regressions")
    args = ap.parse_args(argv)

    if args.self_test:
        _self_test()
        return
    if not args.files:
        raise SystemExit("no fresh BENCH_*.json files given")
    results = run_gate(args.files, args.baseline_dir,
                       qps_drop=args.qps_drop, recall_drop=args.recall_drop)
    n_reg = _report(results)
    if n_reg:
        raise SystemExit(f"trend gate: {n_reg} regression(s) vs "
                         f"{args.baseline_dir}")
    print(f"[trend] gate clean ({len(results)} file(s))")


if __name__ == "__main__":
    main()
