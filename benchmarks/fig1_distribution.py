"""Paper Figure 1: the narrow-band value distribution of product
embeddings — verifies the synthetic corpus reproduces the paper's
premise: all values in (-.125, .125), ~50% within +-(.08, .125)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, sized
from repro.data import synthetic


def main() -> None:
    corpus, _q, _m = synthetic.load("product", sized(20000), 16)
    x = np.asarray(corpus).ravel()
    in_range = float(np.mean((x > -0.125) & (x < 0.125)))
    band = float(np.mean((np.abs(x) >= 0.08) & (np.abs(x) <= 0.125)))
    emit("fig1/value_range", 0.0, f"inside(.125)={in_range:.4f} band(.08-.125)={band:.3f}")
    for q in (0.01, 0.25, 0.5, 0.75, 0.99):
        emit(f"fig1/quantile_{q}", 0.0, f"{np.quantile(x, q):.4f}")


if __name__ == "__main__":
    main()
