"""Paper Table 1: HNSW build time and memory, fp32 vs int8, over the
(EFC, M) grid.  Reduced scale (PRODUCT60M -> synthetic narrow-band corpus);
the paper's claims under test: int8 memory ~ 0.45x fp32 (incl. graph
overhead) and build-time reduction from cheaper distance evaluations.

Arms are factory strings (``hnsw<M>`` vs ``hnsw<M>,lpq8``) built through
the registry."""

from __future__ import annotations

import jax

from benchmarks.common import emit, sized
from repro.data import synthetic
from repro.knn import make_index


def main() -> None:
    n = sized(3000)
    corpus, _queries, metric = synthetic.load("product", n, 16)

    grid = [(40, 8), (80, 8)]  # (EFC, M) — reduced grid of §5.2's 300..700 x {32,48}
    for efc, m in grid:
        idx_fp = make_index(
            f"hnsw{m}", corpus, metric=metric,
            ef_construction=efc, batch_size=256, key=jax.random.PRNGKey(0),
        )
        idx_q8 = make_index(
            f"hnsw{m},lpq8@gaussian:3", corpus, metric=metric,
            ef_construction=efc, batch_size=256, key=jax.random.PRNGKey(0),
        )
        mem_fp = idx_fp.memory_bytes()
        mem_q8 = idx_q8.memory_bytes()
        emit(
            f"table1/build_fp32_efc{efc}_m{m}",
            idx_fp.build_seconds,
            f"mem={mem_fp}B",
        )
        emit(
            f"table1/build_int8_efc{efc}_m{m}",
            idx_q8.build_seconds,
            f"mem={mem_q8}B ratio={mem_q8 / mem_fp:.3f}",
        )


if __name__ == "__main__":
    main()
