"""Tuned-vs-default dispatch benchmark → ``BENCH_autotune.json``.

Runs the measured autotuner (``repro.tune``) on this backend, then
drives ``engine.topk`` through each kernel family twice — once with no
table installed (today's hardcoded constants) and once with the fresh
``TuneTable`` pinned — and reports the QPS ratio, the chosen config, and
the fused-vs-scan crossover decision per arm:

    fused_topk   int8 flat scan, ip
    packed       int4 packed flat scan, l2
    fused_adc    pq8x8+lpq ADC, ip
    scan         angular (never fusable — pure chunk tuning; the smoke
                 corpus is deliberately an awkward n=20480, where the
                 default 16384 chunk pads to 32768 scored rows and the
                 tuned chunk eliminates the waste)

**Gate**: the tuned arm must be >= 1.0x default QPS on every arm.  By
construction that holds when the tuner's hysteresis kept the default
config (same config ⇒ same executable ⇒ ratio reported as exactly 1.0);
when the tuner picked a different config, the pair is measured (and
re-measured once on a sub-1.0 reading — shared-runner noise, not a real
inversion, is the common cause) and a persistent sub-``--min-ratio``
reading fails the run.  Both arms must also agree bitwise on the top-k
*scores* (ids may legally permute within tied scores across different
chunkings — score equality is the engine's cross-path invariant).

On CPU all fused-kernel timings are interpret-mode parity signals
(README "Autotuning"); the measured crossover therefore lands on the
XLA scan, which is exactly the honest answer for this backend.

    PYTHONPATH=src python -m benchmarks.bench_autotune            # full
    PYTHONPATH=src python -m benchmarks.bench_autotune --smoke    # CI
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit, runtime_meta, timeit
from repro import engine
from repro.knn import make_index
from repro.tune import autotuner as AT
from repro.tune import space as S
from repro.tune import table as T

K_TOP = 10


def _arms(smoke: bool):
    """(name, workload, factory spec) per benchmarked family — shapes
    mirror ``autotuner.default_workloads`` so every arm's dispatch lookup
    lands in a bucket the fresh table actually measured."""
    ws = AT.default_workloads(smoke)
    by_kernel = {w.kernel: w for w in ws}
    out = []
    for name, w in by_kernel.items():
        if w.kernel == "fused_adc":
            spec = f"pq{w.d}x{w.bits}+lpq"
        elif w.bits == 4:
            spec = "flat,lpq4"
        else:
            spec = "flat,lpq8"
        out.append((name, w, spec))
    return out


def _build(w, spec):
    dim = w.d * AT.ADC_DS if w.kernel == "fused_adc" else w.d
    corpus = jax.random.normal(jax.random.PRNGKey(0), (w.n, dim)) * 0.1
    queries = jax.random.normal(jax.random.PRNGKey(1), (w.q, dim)) * 0.1
    kwargs = ({"kmeans_iters": 2, "key": jax.random.PRNGKey(2)}
              if w.kernel == "fused_adc" else {})
    idx = make_index(spec, corpus, metric=w.metric, **kwargs)
    return idx.store, queries


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_autotune.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI shapes (small fused corpora, awkward scan n)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="timing repeats when tuned != default config")
    ap.add_argument("--min-ratio", type=float, default=1.0,
                    help="tuned/default QPS floor that fails the run")
    args = ap.parse_args(argv)

    T.clear()                            # measure from a clean slate
    table = AT.autotune(smoke=args.smoke, verbose=True)

    results = {
        "meta": {
            "k": K_TOP,
            "backend": jax.default_backend(),
            "interpret": jax.default_backend() != "tpu",
            "smoke": bool(args.smoke),
            "table_hash": table.table_hash(),
            "runtime": runtime_meta(),   # pre-install: untuned stamp
        },
        "cells": {},
        "crossover": {},
    }

    failures, diverged = [], []
    for name, w, spec in _arms(args.smoke):
        store, queries = _build(w, spec)
        entry = table.get(w.kernel, w.metric, w.bits, w.q, w.n, w.d)
        assert entry is not None, f"tuner produced no entry for {w}"
        default_cfg = S.default_config(w)
        same = entry.dispatch_dict() == default_cfg.dispatch_dict()

        def run(table_or_none):
            with T.pinned(table_or_none):
                return engine.topk(queries, store, K_TOP, w.metric)

        s_def, i_def, st_def = run(None)
        s_tun, i_tun, st_tun = run(table)
        assert st_def["tuned"] is False and st_tun["tuned"] is True, (
            f"{name}: dispatch did not consult the pinned table "
            f"(stats {st_def.get('tuned')}/{st_tun.get('tuned')})"
        )
        if not np.array_equal(np.asarray(s_def), np.asarray(s_tun)):
            diverged.append(name)

        if same:
            # identical dispatch ⇒ identical executable; one measurement,
            # ratio exactly 1.0 (timing the same code twice only reports
            # runner noise as a fake speedup/regression)
            t_def = t_tun = timeit(lambda: run(None)[1],
                                   repeats=max(1, args.repeats - 2))
            ratio = 1.0
        else:
            for attempt in range(2):
                t_def = timeit(lambda: run(None)[1], repeats=args.repeats)
                t_tun = timeit(lambda: run(table)[1], repeats=args.repeats)
                ratio = t_def / max(t_tun, 1e-12)
                if ratio >= args.min_ratio:
                    break
            if ratio < args.min_ratio:
                failures.append((name, ratio))

        results["cells"][name] = {
            "workload": {"metric": w.metric, "bits": w.bits, "q": w.q,
                         "n": w.n, "d": w.d, "spec": spec},
            "default_us": t_def * 1e6,
            "tuned_us": t_tun * 1e6,
            # deliberately NOT named *qps*: the ratio is this run's
            # gate (below), not a trend.py-gated trajectory metric —
            # which tuned config wins can legitimately differ run to run
            "speedup_tuned_over_default": ratio,
            "tuned_config": entry.dispatch_dict(),
            "default_config": default_cfg.dispatch_dict(),
            "config_changed": not same,
        }
        results["crossover"][name] = {
            "chosen_impl": entry.impl,
            "fused_candidates_exist": w.kernel != "scan",
            "tuner_measured_us": entry.measured_us,
            "tuner_default_us": entry.default_us,
        }
        emit(f"bench_autotune/{name}", t_tun,
             f"ratio={ratio:.3f} impl={entry.impl} changed={not same}")

    results["parity"] = {"diverged": diverged}
    results["gate"] = {
        "min_ratio": args.min_ratio,
        "failed_arms": [n for n, _ in failures],
        "any_strict_win": any(
            c["speedup_tuned_over_default"] > 1.0
            for c in results["cells"].values()
        ),
    }

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_autotune] wrote {args.out} "
          f"({len(results['cells'])} arms, table {table.table_hash()})")

    if diverged:
        raise SystemExit(
            f"tuned-vs-default score divergence in {diverged}: a tuned "
            "config changed the exact top-k scores"
        )
    if failures:
        raise SystemExit(
            "tuned config slower than default on "
            + ", ".join(f"{n} ({r:.3f}x)" for n, r in failures)
        )


if __name__ == "__main__":
    main()
