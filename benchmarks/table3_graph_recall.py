"""Paper Table 3: NGT (neighborhood graph + tree) recall@100, fp32 vs
int8 — via the NGT-equivalent GraphIndex (kNN graph + centroid seeding;
DESIGN.md §7).  Claims under test: small (2-6%) recall drop at int8 with
memory/runtime reduction."""

from __future__ import annotations

from benchmarks.common import emit, sized, timeit
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import GraphIndex


def main() -> None:
    k = 10
    schemes = {"sift": ("global_minmax", 1.0), "glove": ("global_absmax", 1.0),
               "product": ("gaussian", 3.0)}
    for name in ("sift", "glove", "product"):
        scheme, sigmas = schemes[name]
        n = sized(3000)
        corpus, queries, metric = synthetic.load(name, n, 64)
        queries = queries[:64]
        _s, gt = exact_topk(corpus, queries, k, metric)

        idx_fp = GraphIndex.build(corpus, degree=24, metric=metric)
        idx_q8 = GraphIndex.build(corpus, degree=24, metric=metric,
                                  quantized=True, scheme=scheme, sigmas=sigmas)

        for arm, idx in (("fp32", idx_fp), ("int8", idx_q8)):
            sec = timeit(lambda i=idx: i.search(queries, k, ef_search=80))
            _ss, ids = idx.search(queries, k, ef_search=80)
            rec = float(recall_at_k(gt, ids))
            emit(
                f"table3/{name}_{arm}", sec,
                f"recall={rec:.4f} mem={idx.memory_bytes()}B",
            )


if __name__ == "__main__":
    main()
