"""Paper Table 3: NGT (neighborhood graph + tree) recall@100, fp32 vs
int8 — via the NGT-equivalent GraphIndex (kNN graph + centroid seeding;
DESIGN.md §7).  Claims under test: small (2-6%) recall drop at int8 with
memory/runtime reduction.

Arms are registry factory strings: ``graph24`` vs ``graph24,lpq8@...``."""

from __future__ import annotations

from benchmarks.common import emit, sized, timeit
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import SearchParams, make_index

QUANT_FRAGMENT = {
    "sift": "lpq8@global_minmax",
    "glove": "lpq8@global_absmax",
    "product": "lpq8@gaussian:3",
}


def main() -> None:
    k = 10
    for name, fragment in QUANT_FRAGMENT.items():
        n = sized(3000)
        corpus, queries, metric = synthetic.load(name, n, 64)
        queries = queries[:64]
        _s, gt = exact_topk(corpus, queries, k, metric)

        idx_fp = make_index("graph24", corpus, metric=metric)
        idx_q8 = make_index(f"graph24,{fragment}", corpus, metric=metric)

        sp = SearchParams(ef_search=80)
        for arm, idx in (("fp32", idx_fp), ("int8", idx_q8)):
            sec = timeit(lambda i=idx: i.search(queries, k, sp))
            ids = idx.search(queries, k, sp).ids
            rec = float(recall_at_k(gt, ids))
            emit(
                f"table3/{name}_{arm}", sec,
                f"recall={rec:.4f} mem={idx.memory_bytes()}B",
            )


if __name__ == "__main__":
    main()
