"""Searcher-based serving benchmark: kind × quant × rerank-depth →
QPS + p95 latency, writing the perf-trajectory file ``BENCH_serve.json``
(plus the harness CSV rows).

Every arm builds through the factory registry, plans one
``index.searcher(k, params)`` session, and drains a fixed request queue
through the compiled buckets — the exact serving path of
``launch/serve.py``, measured.  The paper's headline (quantized scans
buy QPS; §3.4 rerank buys the recall back) shows up as the
lpq8/lpq4-vs-fp32 QPS ratios and the rerank arms' recall column.  On
this CPU container absolute numbers are structural; the file's value is
the trajectory (same shapes, same arms, every CI run).

    PYTHONPATH=src python -m benchmarks.bench_serve            # full
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from benchmarks.common import emit, runtime_meta, sized
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import SearchParams, make_index

K_TOP = 10

#: (kind fragment, build overrides) — one cheap structure per index family
KINDS = {
    "flat": ("flat", {}),
    "ivf": ("ivf64", {"kmeans_iters": 4}),
}

#: quant fragment per arm ("" = fp32)
QUANTS = {"fp32": "", "lpq8": "lpq8@gaussian:3", "lpq4": "lpq4"}

#: rerank candidate depths (0 = no rerank tail)
RERANK_DEPTHS = (0, 50)

#: factories served sharded under ``--mesh S`` (DESIGN.md §15): one
#: single-index arm and one stream arm, both quantized scans
MESH_ARMS = {
    "flat/lpq8": "flat,lpq8@gaussian:3",
    "stream/ivf64,lpq8": "stream(ivf64,lpq8)",
}


def _factory(kind_frag: str, quant_frag: str, depth: int) -> str:
    parts = [kind_frag]
    if quant_frag:
        parts.append(quant_frag + ("+r32" if depth else ""))
    elif depth:
        parts.append("r32")
    return ",".join(parts)


def _mesh_main(args) -> None:
    """``--mesh S``: the multi-device serving arm (DESIGN.md §15).

    Each MESH_ARMS factory is built once, parity-gated (sharded ids AND
    scores bit-equal to the unsharded searcher — a hard failure, never a
    trajectory point), then drained under a mixed-size request load for
    p50/p95/p99.  The cell also records the simulated per-device budget
    (total index bytes / S * 1.2): for S >= 2 the whole index is past
    one device's budget, so the arm only serves because placement splits
    it.  Trend gating stays honest via ``runtime.n_devices`` — a mesh
    run never compares against a single-device baseline.
    """
    S = args.mesh
    if len(jax.devices()) < S:
        raise SystemExit(
            f"--mesh {S} needs {S} devices, found {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count)")
    mesh = jax.make_mesh((S,), ("data",))

    n = 2048 if args.smoke else sized(args.n)
    requests = 4 if args.smoke else args.requests
    corpus, queries, metric = synthetic.load("product", n, args.batch * requests)
    corpus = corpus[:, : args.d]
    queries = queries[:, : args.d]
    gt = np.asarray(
        make_index("flat", corpus, metric=metric).search(queries, K_TOP).ids)
    sp = SearchParams(nprobe=8, ef_search=100)
    small = max(1, args.batch // 4)

    results = {
        "meta": {
            "n": n, "d": args.d, "batch": args.batch, "k": K_TOP,
            "requests": requests, "backend": jax.default_backend(),
            "platform": platform.platform(), "smoke": bool(args.smoke),
            "mesh": S, "runtime": runtime_meta(),
        },
        "cells": {},
    }

    for name, factory in MESH_ARMS.items():
        index = make_index(factory, corpus, metric=metric,
                           key=jax.random.PRNGKey(0))
        # parity gate first: a sharded plan that is not bit-identical to
        # the unsharded one produces no number worth tracking
        un = index.searcher(K_TOP, sp, batch_sizes=(args.batch,))
        sh = index.searcher(K_TOP, sp, batch_sizes=(args.batch, small),
                            shards=mesh)
        ur, sr = un(queries[: args.batch]), sh(queries[: args.batch])
        np.testing.assert_array_equal(np.asarray(ur.ids), np.asarray(sr.ids))
        np.testing.assert_array_equal(np.asarray(ur.scores),
                                      np.asarray(sr.scores))

        total = index.memory_bytes()
        budget = int(total / S * 1.2)
        cell = {
            "factory": factory, "memory_mb": total / 1e6,
            "device_budget_mb": budget / 1e6,
            "fits_one_device": bool(total <= budget),
            "shards": sr.stats.get("shards"),
            "placement": sr.stats.get("placement"),
        }

        # mixed-size drain: every 4th request is a small batch, latency
        # percentiles over the whole stream
        lat, all_ids, served = [], [], 0
        jax.block_until_ready(sh(queries[:small]).ids)
        for r in range(requests):
            step = small if r % 4 == 3 else args.batch
            q = queries[served : served + step]
            if not len(q):
                break
            t0 = time.perf_counter()
            res = sh(q)
            jax.block_until_ready(res.ids)
            lat.append(time.perf_counter() - t0)
            all_ids.append(np.asarray(res.ids))
            served += len(q)
        ids = np.concatenate(all_ids)
        rec = float(recall_at_k(gt[: len(ids)], ids))
        p50, p95, p99 = (float(np.percentile(lat, p)) for p in (50, 95, 99))
        cell.update({
            "qps": served / sum(lat), "recall_at_10": rec,
            "p50_ms": p50 * 1e3, "p95_ms": p95 * 1e3, "p99_ms": p99 * 1e3,
        })
        results["cells"][f"mesh{S}/{name}"] = cell
        emit(f"bench_serve/mesh{S}/{name}", sum(lat) / len(lat),
             f"qps={cell['qps']:.1f} p99_ms={p99 * 1e3:.2f} recall={rec:.4f}")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_serve] wrote {args.out} "
          f"({len(results['cells'])} mesh cells, parity OK)")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + flat-only (the CI interpret-mode check)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="serve the MESH_ARMS sharded over an S-device mesh "
                         "instead of the single-device matrix (needs "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=S "
                         "on CPU); write to a topology-specific --out")
    args = ap.parse_args(argv)

    if args.mesh > 1:
        _mesh_main(args)
        return

    n = 2048 if args.smoke else sized(args.n)
    requests = 4 if args.smoke else args.requests
    kinds = {"flat": KINDS["flat"]} if args.smoke else KINDS
    depths = (0, 50) if not args.smoke else (0, 32)

    corpus, queries, metric = synthetic.load("product", n, args.batch * requests)
    corpus = corpus[:, : args.d]
    queries = queries[:, : args.d]
    gt = np.asarray(
        make_index("flat", corpus, metric=metric).search(queries, K_TOP).ids
    )
    sp = SearchParams(nprobe=8, ef_search=100)

    results = {
        "meta": {
            "n": n, "d": args.d, "batch": args.batch, "k": K_TOP,
            "requests": requests, "backend": jax.default_backend(),
            "platform": platform.platform(), "smoke": bool(args.smoke),
            "runtime": runtime_meta(),
        },
        "cells": {},
    }

    for kname, (kind_frag, over) in kinds.items():
        for qname, quant_frag in QUANTS.items():
            for depth in depths:
                if qname == "fp32" and depth:
                    continue                 # nothing to recover for fp32
                factory = _factory(kind_frag, quant_frag, depth)
                name = f"{kname}/{qname}/r{depth}"
                index = make_index(factory, corpus, metric=metric,
                                   key=jax.random.PRNGKey(0), **over)
                searcher = index.searcher(
                    K_TOP, sp, batch_sizes=(args.batch,),
                    rerank=depth or False,
                )
                jax.block_until_ready(searcher(queries[: args.batch]).ids)

                lat, all_ids = [], []
                for r in range(requests):
                    q = queries[r * args.batch : (r + 1) * args.batch]
                    t0 = time.perf_counter()
                    res = searcher(q)
                    jax.block_until_ready(res.ids)
                    lat.append(time.perf_counter() - t0)
                    all_ids.append(np.asarray(res.ids))
                qps = args.batch * requests / sum(lat)
                p95 = float(np.percentile(lat, 95))
                rec = float(recall_at_k(gt, np.concatenate(all_ids)))
                results["cells"][name] = {
                    "factory": factory, "qps": qps, "p95_ms": p95 * 1e3,
                    "recall_at_10": rec,
                    "memory_mb": index.memory_bytes() / 1e6,
                }
                emit(f"bench_serve/{name}", sum(lat) / requests,
                     f"qps={qps:.1f} p95_ms={p95 * 1e3:.2f} recall={rec:.4f}")

    # headline ratios: quantized-scan QPS gain and what rerank costs/buys
    cells = results["cells"]
    ratios = {}
    for kname in kinds:
        fp = cells.get(f"{kname}/fp32/r0")
        for qname in ("lpq8", "lpq4"):
            c = cells.get(f"{kname}/{qname}/r0")
            if fp and c:
                ratios[f"{kname}/{qname}_qps_over_fp32"] = c["qps"] / max(fp["qps"], 1e-9)
        d = depths[-1]
        base = cells.get(f"{kname}/lpq4/r0")
        rr = cells.get(f"{kname}/lpq4/r{d}")
        if base and rr:
            ratios[f"{kname}/lpq4_rerank_recall_gain"] = (
                rr["recall_at_10"] - base["recall_at_10"]
            )
    results["ratios"] = ratios

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_serve] wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
