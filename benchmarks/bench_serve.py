"""Searcher-based serving benchmark: kind × quant × rerank-depth →
QPS + p95 latency, writing the perf-trajectory file ``BENCH_serve.json``
(plus the harness CSV rows).

Every arm builds through the factory registry, plans one
``index.searcher(k, params)`` session, and drains a fixed request queue
through the compiled buckets — the exact serving path of
``launch/serve.py``, measured.  The paper's headline (quantized scans
buy QPS; §3.4 rerank buys the recall back) shows up as the
lpq8/lpq4-vs-fp32 QPS ratios and the rerank arms' recall column.  On
this CPU container absolute numbers are structural; the file's value is
the trajectory (same shapes, same arms, every CI run).

    PYTHONPATH=src python -m benchmarks.bench_serve            # full
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from benchmarks.common import emit, runtime_meta, sized
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import SearchParams, make_index

K_TOP = 10

#: (kind fragment, build overrides) — one cheap structure per index family
KINDS = {
    "flat": ("flat", {}),
    "ivf": ("ivf64", {"kmeans_iters": 4}),
}

#: quant fragment per arm ("" = fp32)
QUANTS = {"fp32": "", "lpq8": "lpq8@gaussian:3", "lpq4": "lpq4"}

#: rerank candidate depths (0 = no rerank tail)
RERANK_DEPTHS = (0, 50)


def _factory(kind_frag: str, quant_frag: str, depth: int) -> str:
    parts = [kind_frag]
    if quant_frag:
        parts.append(quant_frag + ("+r32" if depth else ""))
    elif depth:
        parts.append("r32")
    return ",".join(parts)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + flat-only (the CI interpret-mode check)")
    args = ap.parse_args(argv)

    n = 2048 if args.smoke else sized(args.n)
    requests = 4 if args.smoke else args.requests
    kinds = {"flat": KINDS["flat"]} if args.smoke else KINDS
    depths = (0, 50) if not args.smoke else (0, 32)

    corpus, queries, metric = synthetic.load("product", n, args.batch * requests)
    corpus = corpus[:, : args.d]
    queries = queries[:, : args.d]
    gt = np.asarray(
        make_index("flat", corpus, metric=metric).search(queries, K_TOP).ids
    )
    sp = SearchParams(nprobe=8, ef_search=100)

    results = {
        "meta": {
            "n": n, "d": args.d, "batch": args.batch, "k": K_TOP,
            "requests": requests, "backend": jax.default_backend(),
            "platform": platform.platform(), "smoke": bool(args.smoke),
            "runtime": runtime_meta(),
        },
        "cells": {},
    }

    for kname, (kind_frag, over) in kinds.items():
        for qname, quant_frag in QUANTS.items():
            for depth in depths:
                if qname == "fp32" and depth:
                    continue                 # nothing to recover for fp32
                factory = _factory(kind_frag, quant_frag, depth)
                name = f"{kname}/{qname}/r{depth}"
                index = make_index(factory, corpus, metric=metric,
                                   key=jax.random.PRNGKey(0), **over)
                searcher = index.searcher(
                    K_TOP, sp, batch_sizes=(args.batch,),
                    rerank=depth or False,
                )
                jax.block_until_ready(searcher(queries[: args.batch]).ids)

                lat, all_ids = [], []
                for r in range(requests):
                    q = queries[r * args.batch : (r + 1) * args.batch]
                    t0 = time.perf_counter()
                    res = searcher(q)
                    jax.block_until_ready(res.ids)
                    lat.append(time.perf_counter() - t0)
                    all_ids.append(np.asarray(res.ids))
                qps = args.batch * requests / sum(lat)
                p95 = float(np.percentile(lat, 95))
                rec = float(recall_at_k(gt, np.concatenate(all_ids)))
                results["cells"][name] = {
                    "factory": factory, "qps": qps, "p95_ms": p95 * 1e3,
                    "recall_at_10": rec,
                    "memory_mb": index.memory_bytes() / 1e6,
                }
                emit(f"bench_serve/{name}", sum(lat) / requests,
                     f"qps={qps:.1f} p95_ms={p95 * 1e3:.2f} recall={rec:.4f}")

    # headline ratios: quantized-scan QPS gain and what rerank costs/buys
    cells = results["cells"]
    ratios = {}
    for kname in kinds:
        fp = cells.get(f"{kname}/fp32/r0")
        for qname in ("lpq8", "lpq4"):
            c = cells.get(f"{kname}/{qname}/r0")
            if fp and c:
                ratios[f"{kname}/{qname}_qps_over_fp32"] = c["qps"] / max(fp["qps"], 1e-9)
        d = depths[-1]
        base = cells.get(f"{kname}/lpq4/r0")
        rr = cells.get(f"{kname}/lpq4/r{d}")
        if base and rr:
            ratios[f"{kname}/lpq4_rerank_recall_gain"] = (
                rr["recall_at_10"] - base["recall_at_10"]
            )
    results["ratios"] = ratios

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_serve] wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
