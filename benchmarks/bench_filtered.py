"""Filtered-search benchmark + acceptance gate: predicate bitmaps through
the kernel id-masking path (DESIGN.md §16) → QPS and recall vs the
*filtered* oracle across a selectivity sweep, written to
``BENCH_filtered.json``.

The claim under test is the filter subsystem's reason to exist: a filter
costs a mask, not a rescan.  Because the bitmap ANDs into the same
pad/tombstone id fence every kernel already evaluates, filtered search
must stay within a constant factor of unfiltered throughput — the gate
pins ``filtered QPS >= 0.5x unfiltered`` at 0.25 selectivity for every
arm.  Correctness rides along: the exact arm (``flat``) must reproduce
the brute-force filtered oracle bit-for-bit (recall == 1.0 at every
selectivity), so a masking bug can never hide behind an approximation
budget.

The filtered oracle is computed by slicing the corpus to the allowed
rows and running ``exact_topk`` there (ids mapped back through the
allowed-id table) — the same post-filter definition the conformance
matrix enforces per kind.

    PYTHONPATH=src python -m benchmarks.bench_filtered            # full
    PYTHONPATH=src python -m benchmarks.bench_filtered --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import platform

import jax
import numpy as np

from benchmarks.common import emit, runtime_meta, sized, timeit
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.filter import Filter
from repro.knn import SearchParams, make_index

K_TOP = 10

#: the sweep arms: the exact scan (correctness anchor), a quantized scan
#: (pure mask path), an IVF arm (mask + list-level skip), and a stream
#: composition (filter ∧ tombstone across segments)
ARMS = ("flat", "flat,lpq4", "ivf64,lpq8", "stream(ivf64,lpq8)")

SELECTIVITIES_FULL = (0.02, 0.25, 0.9)
SELECTIVITIES_SMOKE = (0.25,)

#: arms whose scoring space is fp32-exact: recall vs the filtered oracle
#: must be 1.0 — any drop is a masking bug, not an approximation
EXACT_ARMS = ("flat",)

#: the throughput gate's selectivity point and floor.  The gate covers
#: the static arms, where the bitmap rides the in-kernel id fence and the
#: cost model is pure mask; ``stream`` re-plans per search (snapshot
#: semantics), so its ratio also carries host-side live∧filter bitmap
#: composition — reported for attribution, not gated.
GATE_SELECTIVITY = 0.25
GATE_QPS_RATIO = 0.5
GATE_QPS_ARMS = ("flat", "flat,lpq4", "ivf64,lpq8")


def filtered_oracle(corpus, queries, mask, k, metric):
    """Brute-force top-k over the allowed rows only, ids in corpus space."""
    allowed = np.where(mask)[0]
    _s, ids = exact_topk(corpus[allowed], queries, min(k, allowed.size),
                         metric)
    return allowed[np.asarray(ids)]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--q", type=int, default=64)
    ap.add_argument("--out", default="BENCH_filtered.json")
    ap.add_argument("--smoke", action="store_true",
                    help="smaller shapes (the CI gate)")
    args = ap.parse_args(argv)

    n, q_rows = (2048, 16) if args.smoke else (sized(args.n), args.q)
    # the gate is a ratio of two timings of ~ms-scale calls: a 1-repeat
    # smoke median is a single noisy sample and flakes the 0.5x floor,
    # so this bench keeps 5 repeats even in smoke (still < 10 s)
    repeats = 5
    sels = SELECTIVITIES_SMOKE if args.smoke else SELECTIVITIES_FULL

    corpus, queries, metric = synthetic.load("product", n, q_rows)
    queries = queries[:q_rows]
    corpus_np = np.asarray(corpus)

    rng = np.random.default_rng(7)
    masks = {}
    for sel in sels:
        m = rng.random(n) < sel
        if not m.any():
            m[0] = True
        masks[sel] = m

    results = {
        "meta": {
            "n": n, "d": int(corpus.shape[1]), "q": q_rows, "k": K_TOP,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "smoke": bool(args.smoke),
            "selectivities": list(sels),
            "runtime": runtime_meta(),
        },
        "cells": {},
    }

    for factory in ARMS:
        idx = make_index(factory, corpus, metric=metric, kmeans_iters=4,
                         key=jax.random.PRNGKey(0))
        sp_plain = SearchParams(nprobe=16)
        sec0 = timeit(lambda i=idx, p=sp_plain: i.search(queries, K_TOP, p),
                      repeats=repeats, warmup=1)
        cell = {
            "unfiltered": {
                "us_per_call": sec0 * 1e6,
                "qps": q_rows / max(sec0, 1e-12),
            },
            "filtered": {},
        }
        for sel in sels:
            mask = masks[sel]
            filt = Filter.from_mask(mask)
            sp = SearchParams(nprobe=16, filter=filt)
            sec = timeit(lambda i=idx, p=sp: i.search(queries, K_TOP, p),
                         repeats=repeats, warmup=1)
            res = idx.search(queries, K_TOP, sp)
            ids = np.asarray(res.ids)
            live = ids[ids >= 0]
            assert mask[live].all(), (
                f"{factory} @ sel={sel}: returned a disallowed id"
            )
            gt = filtered_oracle(corpus_np, queries, mask, K_TOP, metric)
            rec = float(recall_at_k(gt, ids[:, :gt.shape[1]]))
            cell["filtered"][str(sel)] = {
                "us_per_call": sec * 1e6,
                "qps": q_rows / max(sec, 1e-12),
                # key deliberately avoids the "qps" substring: trend.py would
                # auto-gate it at 15%, and a quotient of two ms-scale medians
                # is noisier than that — the in-bench floor gates it instead
                "ratio_vs_unfiltered": (q_rows / max(sec, 1e-12))
                / cell["unfiltered"]["qps"],
                "recall_vs_filtered_oracle": rec,
                "selectivity": float(np.mean(mask)),
            }
            emit(f"bench_filtered/{factory}@{sel}", sec,
                 f"recall={rec:.4f} "
                 f"qps_ratio={cell['filtered'][str(sel)]['ratio_vs_unfiltered']:.2f}")
        results["cells"][factory] = cell

    cells = results["cells"]
    gate_sel = str(GATE_SELECTIVITY)
    failures = []
    for factory in GATE_QPS_ARMS:
        f = cells[factory]["filtered"].get(gate_sel)
        if f is not None and f["ratio_vs_unfiltered"] < GATE_QPS_RATIO:
            failures.append(
                f"{factory}: filtered QPS {f['ratio_vs_unfiltered']:.2f}x "
                f"unfiltered at sel={gate_sel} (floor {GATE_QPS_RATIO}x)"
            )
    for factory in EXACT_ARMS:
        for sel, f in cells[factory]["filtered"].items():
            if f["recall_vs_filtered_oracle"] < 1.0:
                failures.append(
                    f"{factory}@{sel}: recall vs filtered oracle "
                    f"{f['recall_vs_filtered_oracle']:.4f} != 1.0"
                )
    results["gate"] = {
        "qps_ratio_floor": GATE_QPS_RATIO,
        "gate_selectivity": GATE_SELECTIVITY,
        "qps_arms": list(GATE_QPS_ARMS),
        "exact_arms": list(EXACT_ARMS),
        "failures": failures,
        "ok": not failures,
    }

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_filtered] wrote {args.out} ({len(cells)} arms x "
          f"{len(sels)} selectivities), gate "
          f"{'OK' if not failures else 'FAILED'}")

    if failures:
        raise SystemExit(
            "filtered-search acceptance failed:\n  " + "\n  ".join(failures)
        )


if __name__ == "__main__":
    main()
