"""Kernel-level microbenchmark for the scoring engine's dispatch table:
qmip / ql2 x {fp32, int8, int4-packed} x {fused, unfused}, writing the
perf-trajectory file ``BENCH_kernels.json`` (plus the harness CSV rows).

"Unfused" scores the full [Q, N] matrix then top-ks it (the historical
hot path); "fused" streams corpus tiles through the running-top-k Pallas
kernel, never materializing [Q, N].  On this CPU container kernels run in
interpret mode, so absolute numbers are structural — the file's value is
the *trajectory* (same shapes, same arms, every CI run) and the
fused-vs-unfused / packed-vs-int8 ratios.

    PYTHONPATH=src python -m benchmarks.bench_kernels            # full
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import platform

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import distances as D
from repro.core import pack as PK
from repro.kernels import ops as K

K_TOP = 10


def _arms(n: int, d: int, q_rows: int):
    """(name, fused_fn, unfused_fn) per metric x precision cell."""
    kq, kx = jax.random.split(jax.random.PRNGKey(0))
    qf = jax.random.normal(kq, (q_rows, d), jnp.float32)
    xf = jax.random.normal(kx, (n, d), jnp.float32)
    q8 = jax.random.randint(kq, (q_rows, d), -128, 128, dtype=jnp.int8)
    x8 = jax.random.randint(kx, (n, d), -128, 128, dtype=jnp.int8)
    q4 = jax.random.randint(kq, (q_rows, d), -8, 8, dtype=jnp.int8)
    x4p = PK.pack_int4(jax.random.randint(kx, (n, d), -8, 8, dtype=jnp.int8))

    def unfused(score):
        return lambda: jax.lax.top_k(score().astype(jnp.float32), K_TOP)

    cells = []
    for metric in ("ip", "l2"):
        fp_score = (lambda m=metric: D.scores(qf, xf, m))
        i8_score = (lambda m=metric:
                    K.qmip(q8, x8) if m == "ip" else K.ql2(q8, x8))
        i4_score = (lambda m=metric:
                    K.qmip4(q4, x4p) if m == "ip" else K.ql24(q4, x4p))
        cells += [
            (f"{metric}/fp32/unfused", unfused(fp_score)),
            (f"{metric}/fp32/fused",
             lambda m=metric: K.fused_topk(qf, xf, K_TOP, m)),
            (f"{metric}/int8/unfused", unfused(i8_score)),
            (f"{metric}/int8/fused",
             lambda m=metric: K.fused_topk(q8, x8, K_TOP, m)),
            (f"{metric}/int4_packed/unfused", unfused(i4_score)),
            (f"{metric}/int4_packed/fused",
             lambda m=metric: K.fused_topk(q4, x4p, K_TOP, m, packed=True)),
        ]
    return cells


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--q", type=int, default=32)
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 1 repeat (the CI interpret-mode check)")
    args = ap.parse_args(argv)

    n, d, q_rows = (1024, 64, 8) if args.smoke else (args.n, args.d, args.q)
    repeats = 1 if args.smoke else 3

    results = {
        "meta": {
            "n": n, "d": d, "q": q_rows, "k": K_TOP,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "interpret": jax.default_backend() != "tpu",
            "smoke": bool(args.smoke),
        },
        "cells": {},
    }
    for name, fn in _arms(n, d, q_rows):
        sec = timeit(fn, repeats=repeats, warmup=1)
        results["cells"][name] = {"us_per_call": sec * 1e6}
        emit(f"bench_kernels/{name}", sec, f"n={n} d={d} q={q_rows}")

    # headline ratios the engine refactor is accountable for (kept apart
    # from cells so every cell has the same us_per_call schema)
    cells = results["cells"]
    results["ratios"] = {
        f"{metric}/int8/fused_over_unfused":
            cells[f"{metric}/int8/fused"]["us_per_call"]
            / max(cells[f"{metric}/int8/unfused"]["us_per_call"], 1e-9)
        for metric in ("ip", "l2")
    }

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_kernels] wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
