"""Kernel-level microbenchmark for the scoring engine's dispatch table:
qmip / ql2 x {fp32, int8, int4-packed} x {fused, unfused}, plus the
Eq. 1 ``quantize`` compression kernel and the recsys retrieval parity
arm (fp32 vs int8 scoring through ``models.recsys`` — recall + memory
ratio), writing the perf-trajectory file ``BENCH_kernels.json`` (plus
the harness CSV rows).  The quantize and retrieval cells absorb the
pre-PR-2 ``kernel_bench.py`` / ``retrieval_bench.py`` modules, whose
scoring arms this file already covered.

"Unfused" scores the full [Q, N] matrix then top-ks it (the historical
hot path); "fused" streams corpus tiles through the running-top-k Pallas
kernel, never materializing [Q, N].  On this CPU container kernels run in
interpret mode, so absolute numbers are structural — the file's value is
the *trajectory* (same shapes, same arms, every CI run) and the
fused-vs-unfused / packed-vs-int8 ratios.

    PYTHONPATH=src python -m benchmarks.bench_kernels            # full
    PYTHONPATH=src python -m benchmarks.bench_kernels --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import platform

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, runtime_meta, timeit
from repro.core import distances as D
from repro.core import pack as PK
from repro.kernels import ops as K

K_TOP = 10


def _arms(n: int, d: int, q_rows: int):
    """(name, fused_fn, unfused_fn) per metric x precision cell."""
    kq, kx = jax.random.split(jax.random.PRNGKey(0))
    qf = jax.random.normal(kq, (q_rows, d), jnp.float32)
    xf = jax.random.normal(kx, (n, d), jnp.float32)
    q8 = jax.random.randint(kq, (q_rows, d), -128, 128, dtype=jnp.int8)
    x8 = jax.random.randint(kx, (n, d), -128, 128, dtype=jnp.int8)
    q4 = jax.random.randint(kq, (q_rows, d), -8, 8, dtype=jnp.int8)
    x4p = PK.pack_int4(jax.random.randint(kx, (n, d), -8, 8, dtype=jnp.int8))

    def unfused(score):
        return lambda: jax.lax.top_k(score().astype(jnp.float32), K_TOP)

    cells = []
    for metric in ("ip", "l2"):
        fp_score = (lambda m=metric: D.scores(qf, xf, m))
        i8_score = (lambda m=metric:
                    K.qmip(q8, x8) if m == "ip" else K.ql2(q8, x8))
        i4_score = (lambda m=metric:
                    K.qmip4(q4, x4p) if m == "ip" else K.ql24(q4, x4p))
        cells += [
            (f"{metric}/fp32/unfused", unfused(fp_score)),
            (f"{metric}/fp32/fused",
             lambda m=metric: K.fused_topk(qf, xf, K_TOP, m)),
            (f"{metric}/int8/unfused", unfused(i8_score)),
            (f"{metric}/int8/fused",
             lambda m=metric: K.fused_topk(q8, x8, K_TOP, m)),
            (f"{metric}/int4_packed/unfused", unfused(i4_score)),
            (f"{metric}/int4_packed/fused",
             lambda m=metric: K.fused_topk(q4, x4p, K_TOP, m, packed=True)),
        ]
    return cells


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--q", type=int, default=32)
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 1 repeat (the CI interpret-mode check)")
    args = ap.parse_args(argv)

    n, d, q_rows = (1024, 64, 8) if args.smoke else (args.n, args.d, args.q)
    repeats = 1 if args.smoke else 3

    results = {
        "meta": {
            "n": n, "d": d, "q": q_rows, "k": K_TOP,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "interpret": jax.default_backend() != "tpu",
            "smoke": bool(args.smoke),
            "runtime": runtime_meta(),
        },
        "cells": {},
    }
    for name, fn in _arms(n, d, q_rows):
        sec = timeit(fn, repeats=repeats, warmup=1)
        results["cells"][name] = {"us_per_call": sec * 1e6}
        emit(f"bench_kernels/{name}", sec, f"n={n} d={d} q={q_rows}")

    # Eq. 1 compression kernel (ported from the legacy kernel_bench)
    xf = jax.random.normal(jax.random.PRNGKey(2), (n, d), jnp.float32)
    lo = jnp.full((d,), -127.0)
    hi = jnp.full((d,), 127.0)
    zero = jnp.zeros((d,))
    for impl, use_pallas in (("xla", False), ("pallas", True)):
        sec = timeit(lambda up=use_pallas: K.quantize(xf, lo, hi, zero,
                                                      use_pallas=up),
                     repeats=repeats, warmup=1)
        results["cells"][f"quantize/{impl}"] = {"us_per_call": sec * 1e6}
        emit(f"bench_kernels/quantize/{impl}", sec, f"n={n} d={d}")

    # recsys retrieval parity (ported from the legacy retrieval_bench):
    # the paper's technique on its most direct production surface —
    # fp32 vs int8 candidate scoring, recall + memory ratio
    from repro.core.preserve import recall_at_k
    from repro.models.recsys import embedding as E
    from repro.models.recsys import retrieval as RT

    cands = jax.random.normal(jax.random.PRNGKey(3), (n, d)) * 0.05
    rq = jax.random.normal(jax.random.PRNGKey(4), (q_rows, d)) * 0.05
    qt = E.QuantizedTable.from_dense(cands)
    _s, i_fp = RT.retrieve_fp32(rq, cands, k=K_TOP)
    sec_fp = timeit(lambda: RT.retrieve_fp32(rq, cands, k=K_TOP),
                    repeats=repeats, warmup=1)
    sec_q8 = timeit(lambda: RT.retrieve_quantized(rq, qt.codes, qt.params,
                                                  k=K_TOP, use_pallas=False),
                    repeats=repeats, warmup=1)
    _s, i_q8 = RT.retrieve_quantized(rq, qt.codes, qt.params, k=K_TOP,
                                     use_pallas=False)
    rec = float(recall_at_k(np.asarray(i_fp), np.asarray(i_q8)))
    mem_fp = n * d * 4
    results["cells"]["retrieval/fp32"] = {
        "us_per_call": sec_fp * 1e6, "memory_bytes": mem_fp,
    }
    results["cells"]["retrieval/int8"] = {
        "us_per_call": sec_q8 * 1e6, "memory_bytes": qt.memory_bytes(),
        "recall_at_10": rec, "memory_ratio": qt.memory_bytes() / mem_fp,
    }
    emit("bench_kernels/retrieval/fp32", sec_fp, f"mem={mem_fp}B")
    emit("bench_kernels/retrieval/int8", sec_q8,
         f"recall={rec:.4f} mem={qt.memory_bytes()}B "
         f"ratio={qt.memory_bytes() / mem_fp:.3f}")

    # headline ratios the engine refactor is accountable for (kept apart
    # from cells so every cell has the same us_per_call schema)
    cells = results["cells"]
    results["ratios"] = {
        f"{metric}/int8/fused_over_unfused":
            cells[f"{metric}/int8/fused"]["us_per_call"]
            / max(cells[f"{metric}/int8/unfused"]["us_per_call"], 1e-9)
        for metric in ("ip", "l2")
    }

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_kernels] wrote {args.out} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
