"""Fused-ADC benchmark + parity gate: pq{8,16} x {x4, x8} x {fused, ref}
→ QPS, memory, recall@10, written to ``BENCH_adc.json``.

Every arm builds a ``pq<M>x<b>+lpq`` index (int8 ADC tables — the fused
kernel's storage contract) and drives ``engine.topk`` over its
``PQStore`` twice: the reference streaming gather-sum scan
(``use_pallas=False``) and the fused Pallas kernel (interpret mode on
CPU, so absolute numbers are structural — the file's value is the
trajectory and the x4-vs-x8 memory/recall trade).  **The fused and
reference paths must be bit-identical**: any divergence raises, so the
CI step running this bench is the kernel's standing parity gate.

    PYTHONPATH=src python -m benchmarks.bench_adc            # full
    PYTHONPATH=src python -m benchmarks.bench_adc --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import platform

import jax
import numpy as np

from benchmarks.common import emit, runtime_meta, sized, timeit
from repro import engine
from repro.core.preserve import recall_at_k
from repro.knn import make_index

K_TOP = 10


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--q", type=int, default=16)
    ap.add_argument("--out", default="BENCH_adc.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 1 repeat (the CI parity gate)")
    args = ap.parse_args(argv)

    n, q_rows = (1024, 8) if args.smoke else (sized(args.n), args.q)
    repeats = 1 if args.smoke else 3
    d = args.d

    corpus = jax.random.normal(jax.random.PRNGKey(0), (n, d)) * 0.1
    queries = jax.random.normal(jax.random.PRNGKey(1), (q_rows, d)) * 0.1
    gt = np.asarray(make_index("flat", corpus).search(queries, K_TOP).ids)

    results = {
        "meta": {
            "n": n, "d": d, "q": q_rows, "k": K_TOP,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "interpret": jax.default_backend() != "tpu",
            "smoke": bool(args.smoke),
            "runtime": runtime_meta(),
        },
        "cells": {},
    }
    diverged = []
    for m in (8, 16):
        for bits in (4, 8):
            idx = make_index(f"pq{m}x{bits}+lpq", corpus, kmeans_iters=4,
                             key=jax.random.PRNGKey(0))
            store = idx.store
            # off-TPU the fused path must be forced into interpret mode;
            # on TPU, interpret=None lets the real compiled kernel run
            # (so the trajectory and the parity gate measure the actual
            # lowering, and meta["interpret"] stays truthful)
            interp = True if jax.default_backend() != "tpu" else None
            arms = {
                "ref": lambda s=store: engine.topk(
                    queries, s, K_TOP, "ip", use_pallas=False),
                "fused": lambda s=store: engine.topk(
                    queries, s, K_TOP, "ip", interpret=interp),
            }
            ids = {}
            for impl, fn in arms.items():
                sec = timeit(lambda: fn()[1], repeats=repeats, warmup=1)
                s_arr, i_arr, _stats = fn()
                ids[impl] = (np.asarray(s_arr), np.asarray(i_arr))
                rec = float(recall_at_k(gt, np.asarray(i_arr)))
                name = f"pq{m}x{bits}/{impl}"
                results["cells"][name] = {
                    "us_per_call": sec * 1e6,
                    "qps": q_rows / max(sec, 1e-12),
                    "recall_at_10": rec,
                    "code_bytes": store.code_bytes,
                    "memory_bytes": store.memory_bytes(),
                }
                emit(f"bench_adc/{name}", sec,
                     f"recall={rec:.4f} code_bytes={store.code_bytes}")
            # the parity gate: fused and reference ADC are one algorithm
            if not (np.array_equal(ids["fused"][0], ids["ref"][0])
                    and np.array_equal(ids["fused"][1], ids["ref"][1])):
                diverged.append(f"pq{m}x{bits}")

    cells = results["cells"]
    results["ratios"] = {
        f"pq{m}/x4_code_bytes_over_x8":
            cells[f"pq{m}x4/ref"]["code_bytes"]
            / max(cells[f"pq{m}x8/ref"]["code_bytes"], 1)
        for m in (8, 16)
    }
    results["ratios"].update({
        f"pq{m}/x4_recall_delta_vs_x8":
            cells[f"pq{m}x4/ref"]["recall_at_10"]
            - cells[f"pq{m}x8/ref"]["recall_at_10"]
        for m in (8, 16)
    })
    results["parity"] = {"diverged": diverged}

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_adc] wrote {args.out} ({len(cells)} cells)")

    if diverged:
        raise SystemExit(
            f"fused-vs-reference ADC divergence in {diverged}: the Pallas "
            "kernel no longer bit-matches the ref.py oracle"
        )


if __name__ == "__main__":
    main()
