"""Paper Figure 2: QPS and recall versus the EFS search parameter, fp32 vs
int8 HNSW.  The paper's claims under test: int8 QPS > fp32 QPS at matched
EFS, recall gap ~2%, and recall increasing in EFS for both arms.

Both arms are built from factory strings through the unified registry API.
"""

from __future__ import annotations

from benchmarks.common import emit, sized, timeit
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import SearchParams, make_index


def main() -> None:
    n = sized(3000)
    k = 10
    corpus, queries, metric = synthetic.load("product", n, 64)
    queries = queries[:64]
    _gt_s, gt_i = exact_topk(corpus, queries, k, metric)

    builds = {
        arm: make_index(factory, corpus, metric=metric,
                        ef_construction=80, batch_size=256)
        for arm, factory in (("fp32", "hnsw8"), ("int8", "hnsw8,lpq8@gaussian:3"))
    }

    for efs in (40, 80, 160):
        sp = SearchParams(ef_search=efs)
        for arm, idx in builds.items():
            sec = timeit(lambda i=idx, p=sp: i.search(queries, k, p))
            ids = idx.search(queries, k, sp).ids
            rec = float(recall_at_k(gt_i, ids))
            qps = queries.shape[0] / sec
            emit(f"fig2/{arm}_efs{efs}", sec, f"qps={qps:.1f} recall={rec:.4f}")


if __name__ == "__main__":
    main()
