"""Paper Figure 2: QPS and recall versus the EFS search parameter, fp32 vs
int8 HNSW.  The paper's claims under test: int8 QPS > fp32 QPS at matched
EFS, recall gap ~2%, and recall increasing in EFS for both arms."""

from __future__ import annotations

import jax

from benchmarks.common import emit, sized, timeit
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import HNSWIndex


def main() -> None:
    n = sized(3000)
    k = 10
    corpus, queries, metric = synthetic.load("product", n, 64)
    queries = queries[:64]
    _gt_s, gt_i = exact_topk(corpus, queries, k, metric)

    builds = {
        "fp32": HNSWIndex.build(corpus, m=8, ef_construction=80, metric=metric,
                                batch_size=256),
        "int8": HNSWIndex.build(corpus, m=8, ef_construction=80, metric=metric,
                                quantized=True, sigmas=3.0, batch_size=256),
    }
    from repro.core.preserve import recall_at_k

    for efs in (40, 80, 160):
        for arm, idx in builds.items():
            sec = timeit(lambda i=idx, e=efs: i.search(queries, k, ef_search=e))
            _s, ids = idx.search(queries, k, ef_search=efs)
            rec = float(recall_at_k(gt_i, ids))
            qps = queries.shape[0] / sec
            emit(f"fig2/{arm}_efs{efs}", sec, f"qps={qps:.1f} recall={rec:.4f}")


if __name__ == "__main__":
    main()
