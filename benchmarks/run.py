"""Benchmark orchestrator — one module per paper table/figure plus the
engine/serving/stream/ADC benches and the roofline derivation.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig1 table2
"""

from __future__ import annotations

import sys
import traceback

from repro.runtime import profile as rtprofile

# the env-resolved runtime profile ($REPRO_RUNTIME_PROFILE, default
# "default") is applied before any suite touches jax, so every
# BENCH_*.json written by one orchestrator run carries the same stamp
rtprofile.apply(rtprofile.resolve())

from benchmarks import (  # noqa: E402 — profile must precede jax init
    bench_adc,
    bench_autotune,
    bench_cascade,
    bench_filtered,
    bench_kernels,
    bench_serve,
    bench_stream,
    fig1_distribution,
    fig2_qps_recall,
    table1_build_memory,
    table2_exact_recall,
    table3_graph_recall,
)

SUITES = {
    "fig1": fig1_distribution.main,
    "table2": table2_exact_recall.main,
    # engine dispatch-table / Searcher serving / mutable-index / fused-ADC
    # benches (smoke shapes when run via the orchestrator; invoke the
    # modules directly for full sizes).  bench_kernels absorbed the
    # legacy kernel_bench + retrieval_bench arms (quantize, recsys
    # retrieval parity); bench_adc doubles as the fused-vs-ref parity
    # gate for the ADC kernel.
    "bench_kernels": lambda: bench_kernels.main(["--smoke"]),
    "bench_serve": lambda: bench_serve.main(["--smoke"]),
    "bench_stream": lambda: bench_stream.main(["--smoke"]),
    "bench_adc": lambda: bench_adc.main(["--smoke"]),
    # multi-stage cascade vs single-stage ancestors (recall/bytes gate)
    "bench_cascade": lambda: bench_cascade.main(["--smoke"]),
    # predicate bitmaps through the id-masking path (QPS/oracle gate)
    "bench_filtered": lambda: bench_filtered.main(["--smoke"]),
    # tuned-vs-default dispatch (runs the measured autotuner first)
    "bench_autotune": lambda: bench_autotune.main(["--smoke"]),
    "table3": table3_graph_recall.main,
    "table1": table1_build_memory.main,
    "fig2": fig2_qps_recall.main,
}


def main() -> None:
    wanted = sys.argv[1:] or list(SUITES)
    failed = []
    for name in wanted:
        try:
            SUITES[name]()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0.0,{e!r}")
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark suites failed: {failed}")


if __name__ == '__main__':
    main()
