"""Kernel microbenchmarks: qmip / ql2 / quantize wrappers vs the fp32 XLA
dot baseline (CPU interpret numbers are structural, not TPU wall-time —
the TPU claim lives in §Roofline's int8-vs-bf16 peak ratio)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, sized, timeit
from repro.core import distances as D
from repro.kernels import ops as K


def main() -> None:
    n = sized(20_000)
    d = 128
    kq, kx = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.randint(kq, (32, d), -128, 128, dtype=jnp.int8)
    x = jax.random.randint(kx, (n, d), -128, 128, dtype=jnp.int8)
    qf = q.astype(jnp.float32)
    xf = x.astype(jnp.float32)

    emit("kernel/qmip_xla_int8", timeit(lambda: K.qmip(q, x, use_pallas=False)),
         f"n={n} d={d}")
    emit("kernel/fp32_dot", timeit(lambda: D.ip_scores(qf, xf)), f"n={n} d={d}")
    lo = jnp.full((d,), -127.0)
    hi = jnp.full((d,), 127.0)
    zero = jnp.zeros((d,))
    emit("kernel/quantize_xla", timeit(lambda: K.quantize(xf, lo, hi, zero,
                                                           use_pallas=False)),
         f"n={n} d={d}")


if __name__ == "__main__":
    main()
