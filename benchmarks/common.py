"""Shared benchmark utilities: timing, CSV emission, dataset sizing.

Benchmarks run REDUCED corpus sizes on this CPU container (the paper's
60M-row corpus is exercised structurally via the dry-run); every table
keeps the paper's comparison structure (fp32 arm vs int8 arm) so the
claims — memory ratio, build-time ratio, QPS ratio, recall delta — are
measured, just at smaller N.  Set REPRO_BENCH_SCALE to grow corpora.
"""

from __future__ import annotations

import os
import time

import jax

SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))


def sized(n: int) -> int:
    return max(64, int(n * SCALE))


def timeit(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, seconds: float, derived: str = "") -> None:
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def runtime_meta() -> dict:
    """The active runtime-profile stamp (repro.runtime.profile) every
    ``BENCH_*.json`` embeds under ``meta["runtime"]``: profile name,
    backend, device kind, interpret-mode flag, seed policy.  The trend
    gate (benchmarks/trend.py) keys comparability on it — CPU-interpret
    trend points never get compared against hardware points."""
    from repro.runtime import profile as rtprofile

    return rtprofile.stamp()
