"""Paper Table 2: FAISS-style exhaustive search recall@100, fp32 vs int8,
on SIFT (L2) / Glove100 (angular) / PRODUCT (IP).  The claims under test:
recall drops of ~0.97/0.94/0.98 respectively at int8.

Per-dataset quantization schemes are carried in the factory string's
quant fragment (``lpq8@<scheme>[:<sigmas>]``)."""

from __future__ import annotations

from benchmarks.common import emit, sized, timeit
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.knn import make_index

FACTORIES = {
    "sift": "flat,lpq8@global_minmax",
    "glove": "flat,lpq8@global_absmax",
    "product": "flat,lpq8@gaussian:3",
}


def main() -> None:
    k = 100
    for name, factory in FACTORIES.items():
        n = sized(8000)
        corpus, queries, metric = synthetic.load(name, n, 128)
        queries = queries[:128]

        idx_fp = make_index("flat", corpus, metric=metric)
        idx_q8 = make_index(factory, corpus, metric=metric)

        gt = idx_fp.search(queries, k).ids
        sec_fp = timeit(lambda: idx_fp.search(queries, k))
        sec_q8 = timeit(lambda: idx_q8.search(queries, k))
        ids = idx_q8.search(queries, k).ids
        rec = float(recall_at_k(gt, ids))
        ratio = idx_q8.memory_bytes() / idx_fp.memory_bytes()
        emit(f"table2/{name}_fp32", sec_fp, "recall=1.0000")
        emit(f"table2/{name}_int8", sec_q8, f"recall={rec:.4f} memratio={ratio:.3f}")


if __name__ == "__main__":
    main()
