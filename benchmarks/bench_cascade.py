"""Cascade benchmark + acceptance gate: multi-stage pipelines vs their
single-stage ancestors on the fig2 grid → QPS, recall@10, bytes read per
query (with the per-stage breakdown), written to ``BENCH_cascade.json``.

The claim under test is the cascade subsystem's reason to exist: a
coarse-head pipeline (``cascade(pq16x4|lpq8|r32)``) should reach the
recall of the int8 single-stage scan (``flat,lpq8``) while reading no
more bytes per query than the int4 single-stage scan (``flat,lpq4``) —
precision where it matters, bandwidth where it doesn't.  The gate
enforces exactly that; every cascade cell also records its measured
per-stage ``(label, candidates, bytes, bits)`` rows so a regression is
attributable to a stage, not just an arm.

Bytes accounting: the engine's ``stats["bytes_read"]`` amortizes a full
scan over the query batch (the code matrix is streamed once per pass),
while refinement gathers are inherently per query.  The gate therefore
compares ``model_bytes_per_query`` — the bytes ONE query must touch with
no cross-query amortization: ``n * row_bytes`` for a scan stage plus
``budget * row_bytes`` per refinement stage.  The measured whole-batch
numbers ride along in each cell for attribution.

    PYTHONPATH=src python -m benchmarks.bench_cascade            # full
    PYTHONPATH=src python -m benchmarks.bench_cascade --smoke    # CI gate
"""

from __future__ import annotations

import argparse
import json
import platform

import jax
import numpy as np

from benchmarks.common import emit, runtime_meta, sized, timeit
from repro.core.preserve import recall_at_k
from repro.data import synthetic
from repro.data.groundtruth import exact_topk
from repro.knn import SearchParams, make_index

K_TOP = 10

#: arm -> cascade stage budgets (None for single-stage arms).  Budgets
#: are the plan-time schedule a served cascade would run with — wide
#: enough for the coarse head's candidate list to cover the true top-k,
#: narrow enough that the refinement gathers stay under the int4 scan's
#: per-query byte ceiling.  Smoke shapes get a proportionally narrower
#: schedule (the head covers a 2048-row corpus with a shallower fetch).
ARMS_FULL: dict[str, tuple[int, ...] | None] = {
    "flat,lpq8": None,
    "flat,lpq4": None,
    "pq16x4": None,
    "cascade(pq16x4|lpq8|r32)": (768, 96),
    "cascade(flat,lpq4|r32)": (64,),
}
ARMS_SMOKE: dict[str, tuple[int, ...] | None] = {
    **ARMS_FULL,
    "cascade(pq16x4|lpq8|r32)": (512, 64),
}

#: the acceptance baselines: recall floor and per-query byte ceiling
RECALL_FLOOR_ARM = "flat,lpq8"
BYTES_CEIL_ARM = "flat,lpq4"


def model_bytes_per_query(idx, budgets) -> int:
    """Bytes one query touches, no cross-query amortization.

    A scan stage streams every stored row (``n * row_bytes``); a cascade
    adds one gathered row per surviving candidate per refinement stage
    (``budget * row_bytes``).
    """
    if hasattr(idx, "stage_stores"):  # cascade: head scan + budgeted gathers
        head = model_bytes_per_query(idx.head, None)
        return head + sum(
            int(b) * st.row_bytes for b, st in zip(budgets, idx.stage_stores)
        )
    return int(idx.store.n) * int(idx.store.row_bytes)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--q", type=int, default=64)
    ap.add_argument("--out", default="BENCH_cascade.json")
    ap.add_argument("--smoke", action="store_true",
                    help="smaller shapes + 1 repeat (the CI gate)")
    args = ap.parse_args(argv)

    n, q_rows = (2048, 16) if args.smoke else (sized(args.n), args.q)
    repeats = 1 if args.smoke else 3
    arms = ARMS_SMOKE if args.smoke else ARMS_FULL

    corpus, queries, metric = synthetic.load("product", n, q_rows)
    queries = queries[:q_rows]
    _gt_s, gt_i = exact_topk(corpus, queries, K_TOP, metric)

    results = {
        "meta": {
            "n": n, "d": int(corpus.shape[1]), "q": q_rows, "k": K_TOP,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "smoke": bool(args.smoke),
            "runtime": runtime_meta(),
        },
        "cells": {},
    }

    for factory, budgets in arms.items():
        idx = make_index(factory, corpus, metric=metric, kmeans_iters=4,
                         key=jax.random.PRNGKey(0))
        sp = SearchParams(budgets=budgets)
        sec = timeit(lambda i=idx, p=sp: i.search(queries, K_TOP, p),
                     repeats=repeats, warmup=1)
        res = idx.search(queries, K_TOP, sp)
        rec = float(recall_at_k(gt_i, np.asarray(res.ids)))
        per_q = model_bytes_per_query(idx, budgets)
        cell = {
            "us_per_call": sec * 1e6,
            "qps": q_rows / max(sec, 1e-12),
            "recall_at_10": rec,
            "bytes_read_per_query": per_q,
            "batch_bytes_read": int(res.stats["bytes_read"]),
            "memory_bytes": idx.memory_bytes(),
        }
        if "stages" in res.stats:
            # measured (label, candidates, whole-batch bytes, bits) per
            # stage — the attribution rows the gate's postmortem needs
            cell["stages"] = [
                {"label": s[0], "candidates": int(s[1]),
                 "bytes_read": int(s[2]), "bits": int(s[3])}
                for s in res.stats["stages"]
            ]
            cell["budgets"] = list(budgets)
        results["cells"][factory] = cell
        emit(f"bench_cascade/{factory}", sec,
             f"recall={rec:.4f} bytes_per_q={per_q}")

    cells = results["cells"]
    floor = cells[RECALL_FLOOR_ARM]["recall_at_10"]
    ceiling = cells[BYTES_CEIL_ARM]["bytes_read_per_query"]
    passing = [
        name for name, cell in cells.items()
        if "stages" in cell
        and cell["recall_at_10"] >= floor
        and cell["bytes_read_per_query"] <= ceiling
    ]
    results["gate"] = {
        "recall_floor": floor,
        "bytes_ceiling": ceiling,
        "passing_arms": passing,
        "ok": bool(passing),
    }

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"[bench_cascade] wrote {args.out} ({len(cells)} cells), "
          f"gate passing: {passing or 'NONE'}")

    if not passing:
        detail = {
            name: (round(cell["recall_at_10"], 4),
                   cell["bytes_read_per_query"])
            for name, cell in cells.items() if "stages" in cell
        }
        raise SystemExit(
            "cascade acceptance failed: no cascade arm reaches recall@10 "
            f">= {floor:.4f} ({RECALL_FLOOR_ARM}) within {ceiling} "
            f"bytes/query ({BYTES_CEIL_ARM}); cascade cells "
            f"(recall, bytes/q): {detail}"
        )


if __name__ == "__main__":
    main()
