# The scoring engine (DESIGN.md §8): CodeStore/PQStore own corpus storage
# at any precision (fp32 / int8 / bit-packed int4 / PQ codewords) with
# honest memory accounting; the Scorer owns the whole query hot path —
# metric x bits kernel dispatch, chunking, padding, invalid-id masking and
# streaming top-k — so index classes hold structure and call
# ``engine.topk`` / ``topk_among`` / ``make_score_set`` and nothing else.
from repro.engine.scorer import (
    make_score_set,
    merge_topk,
    pad_rows,
    rerank_among,
    search_stats,
    topk,
    topk_among,
)
from repro.engine.store import CodeStore, PQStore

__all__ = [
    "CodeStore",
    "PQStore",
    "topk",
    "topk_among",
    "rerank_among",
    "make_score_set",
    "search_stats",
    "merge_topk",
    "pad_rows",
]
