# The scoring engine (DESIGN.md §8): CodeStore/PQStore own corpus storage
# at any precision (fp32 / int8 / bit-packed int4 / PQ codewords) with
# honest memory accounting; the Scorer owns the whole query hot path —
# metric x bits kernel dispatch, chunking, padding, invalid-id masking and
# streaming top-k — so index classes hold structure and call
# ``engine.topk`` / ``topk_among`` / ``make_score_set`` and nothing else.
# Every top-k implementation lives here: the fused Pallas kernels, the
# streaming scan core, the generic score-fn ``chunked_topk``, the
# cross-shard ``distributed_topk`` merge, and the ``remap_ids`` gather the
# stream layer uses to map internal rows back to external ids.
from repro.engine.scorer import (
    build_pq_lut,
    chunked_topk,
    distributed_topk,
    get_lut_cache,
    make_score_set,
    merge_topk,
    pad_rows,
    quantize_pq_lut,
    refine_among,
    regional_stats,
    remap_ids,
    rerank_among,
    search_stats,
    set_lut_cache,
    topk,
    topk_among,
    topk_among_regional,
)
from repro.engine.store import PQ_CODE_BITS, CodeStore, PQStore

__all__ = [
    "CodeStore",
    "PQStore",
    "PQ_CODE_BITS",
    "build_pq_lut",
    "quantize_pq_lut",
    "topk",
    "topk_among",
    "topk_among_regional",
    "refine_among",
    "regional_stats",
    "rerank_among",
    "make_score_set",
    "search_stats",
    "merge_topk",
    "pad_rows",
    "chunked_topk",
    "distributed_topk",
    "remap_ids",
    "set_lut_cache",
    "get_lut_cache",
]
