"""Corpus storage for the scoring engine: ``CodeStore`` and ``PQStore``.

A ``CodeStore`` owns one corpus payload at any precision the paper's Eq. 1
family supports — fp32 vectors, int8 codes, or bit-packed int4 codes
(two per byte, via :mod:`repro.core.pack`) — plus the quantization
constants and a row-id ``base`` so shard-local stores rebase their ids for
the distributed merge.  Every byte the index holds for *vector* data lives
here, so ``memory_bytes()`` is the honest Table-1/2 accounting for every
index kind (the 4-bit arm really is half the int8 arm).

``PQStore`` is the product-quantization counterpart: 1-byte codewords plus
the per-subspace codebooks the ADC scan gathers from.

Stores are frozen dataclass-pytrees: jit/vmap-safe, and their static
fields (n, d, bits, packed, base) ride in the treedef so jitted engine
entry points specialize per storage layout.

Odd dimensions under packing: int4 packing needs an even dim, so the
store pads codes with one zero-code column before packing and
``encode_queries`` appends the matching zero column — code 0 x code 0
contributes 0 to IP and L2 alike, so scores are unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack as PK
from repro.core import quant as Qz


def _params_equal(a: Optional[Qz.QuantParams], b: Optional[Qz.QuantParams]) -> bool:
    """Exact (bit-level) equality of two quantization-constant sets."""
    if a is None or b is None:
        return a is None and b is None
    return (
        a.bits == b.bits
        and a.scheme == b.scheme
        and np.array_equal(np.asarray(a.lo), np.asarray(b.lo))
        and np.array_equal(np.asarray(a.hi), np.asarray(b.hi))
        and np.array_equal(np.asarray(a.zero), np.asarray(b.zero))
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CodeStore:
    """One corpus, one precision, one id space."""

    n: int = dataclasses.field(metadata=dict(static=True))
    d: int = dataclasses.field(metadata=dict(static=True))       # logical dim
    bits: int = dataclasses.field(metadata=dict(static=True))    # 32 == fp32
    packed: bool = dataclasses.field(metadata=dict(static=True))
    data: jax.Array           # [N, d] f32 | [N, d_eff] int | [N, d_eff/2] u8
    params: Optional[Qz.QuantParams]
    base: int = dataclasses.field(default=0, metadata=dict(static=True))

    # -- construction ------------------------------------------------------
    @staticmethod
    def dense(vectors: jax.Array, base: int = 0) -> "CodeStore":
        """fp32 storage (the unquantized arm)."""
        vectors = jnp.asarray(vectors, jnp.float32)
        n, d = vectors.shape
        return CodeStore(n=n, d=d, bits=32, packed=False,
                         data=vectors, params=None, base=base)

    @staticmethod
    def from_codes(
        codes: jax.Array,
        params: Qz.QuantParams,
        *,
        pack: bool = False,
        base: int = 0,
    ) -> "CodeStore":
        """Wrap already-encoded integer codes; optionally bit-pack int4."""
        n, d = codes.shape
        if pack:
            assert params.bits == 4, "packing is the 4-bit storage layout"
            if d % 2:
                codes = jnp.pad(codes, ((0, 0), (0, 1)))   # zero-code column
            codes = PK.pack_int4(codes)
        return CodeStore(n=n, d=d, bits=params.bits, packed=pack,
                         data=codes, params=params, base=base)

    @staticmethod
    def concat(stores: "list[CodeStore]", base: int = 0) -> "CodeStore":
        """Row-concatenate layout-compatible stores into one id space.

        The stream layer's segment-merge primitive: every input must agree
        on (d, bits, packed) and — for quantized stores — on the exact
        Eq. 1 constants, because a single store has a single code space;
        mixing differently-calibrated codes would silently mis-score.
        Input ``base`` offsets are discarded (rows are renumbered
        0..sum(n)-1 under the new ``base``).
        """
        if not stores:
            raise ValueError("CodeStore.concat of zero stores")
        head = stores[0]
        for s in stores[1:]:
            if (s.d, s.bits, s.packed) != (head.d, head.bits, head.packed):
                raise ValueError(
                    "concat of layout-incompatible stores: "
                    f"{(s.d, s.bits, s.packed)} vs {(head.d, head.bits, head.packed)}"
                )
            if not _params_equal(s.params, head.params):
                raise ValueError(
                    "concat of stores with different quantization constants "
                    "— one store has one code space; re-encode first "
                    "(stream compaction re-quantizes from raw payloads)"
                )
        data = jnp.concatenate([s.data for s in stores], axis=0)
        return CodeStore(n=sum(s.n for s in stores), d=head.d, bits=head.bits,
                         packed=head.packed, data=data, params=head.params,
                         base=base)

    def append(self, vectors: jax.Array) -> "CodeStore":
        """A new store with fp32 ``vectors`` encoded into this store's code
        space and appended (rows keep their order; ids extend n..n+m-1):
        grow a store under its existing constants without re-learning.
        """
        vectors = jnp.asarray(vectors, jnp.float32)
        if vectors.shape[1] != self.d:
            raise ValueError(f"append dim {vectors.shape[1]} != store d {self.d}")
        if not self.quantized:
            extra = CodeStore.dense(vectors)
        else:
            from repro.kernels import ops as K

            p = self.params
            codes = K.quantize(vectors, p.lo, p.hi, p.zero, bits=p.bits)
            extra = CodeStore.from_codes(codes, p, pack=self.packed)
        return CodeStore.concat([self, extra], base=self.base)

    # -- shape/metadata ----------------------------------------------------
    @property
    def quantized(self) -> bool:
        return self.bits < 32

    @property
    def d_eff(self) -> int:
        """Code width after the even-dim pad (== d unless packed odd-d)."""
        return self.data.shape[1] * 2 if self.packed else self.data.shape[1]

    @property
    def row_bytes(self) -> int:
        """Bytes of payload read to score one corpus row."""
        return int(self.data.shape[1]) * self.data.dtype.itemsize

    def memory_bytes(self) -> int:
        """Payload + Eq. 1 constants — the Table 1/2 memory column."""
        total = int(self.data.size) * self.data.dtype.itemsize
        if self.params is not None:
            total += 3 * self.d * 4                        # lo / hi / zero f32
        return total

    # -- views -------------------------------------------------------------
    def encode_queries(self, queries: jax.Array) -> jax.Array:
        """h(q) of Definition 2: map queries into the store's code space."""
        from repro.kernels import ops as K

        if not self.quantized:
            return jnp.asarray(queries, jnp.float32)
        p = self.params
        q = K.quantize(queries, p.lo, p.hi, p.zero, bits=p.bits)
        if self.packed and self.d_eff != self.d:
            q = jnp.pad(q, ((0, 0), (0, self.d_eff - self.d)))
        return q

    def unpacked(self) -> jax.Array:
        """Full-width payload view ([N, d_eff]); unpacks int4 on the fly."""
        return PK.unpack_int4(self.data) if self.packed else self.data

    def take(self, ids: jax.Array) -> jax.Array:
        """Gather rows by id, returned at full width (graph-walk path:
        gather the *packed* rows, then shift-mask only what was touched)."""
        rows = self.data[ids]
        return PK.unpack_int4(rows) if self.packed else rows

    # -- disk round-trip fragments ----------------------------------------
    def state(self, prefix: str = "") -> tuple[dict[str, Any], dict[str, Any]]:
        """Serializable (arrays, meta) fragments.

        ``prefix`` namespaces the array keys and the meta record
        (``{prefix}store``) so one npz can carry several stores — an
        index's scan store plus its rerank store (``prefix="rr_"``).
        """
        arrays: dict[str, Any] = {f"{prefix}data": self.data}
        meta: dict[str, Any] = {
            f"{prefix}store": {"n": self.n, "d": self.d, "bits": self.bits,
                               "packed": self.packed, "base": self.base,
                               "quant": None},
        }
        if self.params is not None:
            arrays.update({f"{prefix}q_lo": self.params.lo,
                           f"{prefix}q_hi": self.params.hi,
                           f"{prefix}q_zero": self.params.zero})
            meta[f"{prefix}store"]["quant"] = {"bits": self.params.bits,
                                               "scheme": self.params.scheme}
        return arrays, meta

    @staticmethod
    def from_state(
        arrays: dict[str, Any], meta: dict[str, Any], prefix: str = ""
    ) -> "CodeStore":
        sm = meta[f"{prefix}store"]
        params = None
        if sm["quant"] is not None:
            params = Qz.QuantParams(
                lo=jnp.asarray(arrays[f"{prefix}q_lo"]),
                hi=jnp.asarray(arrays[f"{prefix}q_hi"]),
                zero=jnp.asarray(arrays[f"{prefix}q_zero"]),
                bits=int(sm["quant"]["bits"]),
                scheme=str(sm["quant"]["scheme"]),
            )
        return CodeStore(
            n=int(sm["n"]), d=int(sm["d"]), bits=int(sm["bits"]),
            packed=bool(sm["packed"]), data=jnp.asarray(arrays[f"{prefix}data"]),
            params=params, base=int(sm["base"]),
        )


#: codeword index widths PQStore supports: 4-bit (16-codeword codebooks,
#: codes packed two per byte) or 8-bit (256 codewords, one byte per code)
PQ_CODE_BITS = (4, 8)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQStore:
    """Product-quantization storage: codewords + per-subspace codebooks.

    ``bits`` is the codeword index width.  At 8 bits, ``codes`` is
    [N, M] uint8 into 256-codeword codebooks; at 4 bits, codebooks hold
    16 codewords and codes are bit-packed two per byte —
    [N, ceil(M/2)] uint8 via :func:`repro.core.pack.pack_uint4` (odd M
    pads a zero-code column; the ADC side pads its LUT with a zero
    subspace slice, so scores are unchanged) — which is why
    ``pq16x4`` reports exactly half the code bytes of ``pq16x8``.
    """

    n: int = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))       # subspaces
    lpq_tables: bool = dataclasses.field(metadata=dict(static=True))
    codes: jax.Array          # [N, M] uint8 | [N, ceil(M/2)] uint8 packed
    codebooks: jax.Array      # [M, 2^bits, d/M] f32
    bits: int = dataclasses.field(default=8, metadata=dict(static=True))

    def __post_init__(self):
        if self.bits not in PQ_CODE_BITS:
            raise ValueError(
                f"PQ codeword width must be one of {PQ_CODE_BITS} bits "
                f"(16- or 256-codeword codebooks), got {self.bits}"
            )

    @property
    def packed(self) -> bool:
        """Whether codes are stored two-per-byte (the 4-bit layout)."""
        return self.bits == 4

    @property
    def n_codewords(self) -> int:
        return 2 ** self.bits

    def unpacked_codes(self) -> jax.Array:
        """[N, M] codeword-index view; unpacks the 4-bit layout on the fly."""
        if not self.packed:
            return self.codes
        return PK.unpack_uint4(self.codes)[:, : self.m]

    @property
    def row_bytes(self) -> int:
        """Bytes of code payload read to score one corpus row."""
        return int(self.codes.shape[1])

    @property
    def code_bytes(self) -> int:
        """Bytes of the code matrix alone (the Table-1 codes column)."""
        return int(self.codes.size)

    def memory_bytes(self) -> int:
        return self.code_bytes + int(self.codebooks.size) * 4

    def state(self) -> tuple[dict[str, Any], dict[str, Any]]:
        arrays = {"codes": self.codes, "codebooks": self.codebooks}
        meta = {"store": {"n": self.n, "m": self.m, "bits": self.bits,
                          "lpq_tables": self.lpq_tables}}
        return arrays, meta

    @staticmethod
    def from_state(arrays: dict[str, Any], meta: dict[str, Any]) -> "PQStore":
        sm = meta["store"]
        return PQStore(
            n=int(sm["n"]), m=int(sm["m"]), lpq_tables=bool(sm["lpq_tables"]),
            codes=jnp.asarray(arrays["codes"]),
            codebooks=jnp.asarray(arrays["codebooks"]),
            bits=int(sm.get("bits", 8)),       # pre-PR-5 saves: 8-bit codes
        )
