"""The scoring engine: every index's query hot path in one place.

``topk`` / ``topk_among`` / ``make_score_set`` own metric x bits dispatch,
chunking, corpus padding, invalid-id masking and streaming top-k, so index
classes hold *structure* (lists, graphs, codebooks) and delegate every
score to the engine.  Padding is id-masked here, centrally — the L2
zero-sentinel hazard (a zero pad row out-scoring real rows under negated
L2) cannot reach callers, because no caller sees pad rows at all.

Kernel dispatch table (metric x storage):

    storage          ip               l2               angular
    fp32             fused_topk       fused_topk       scan + angular
    int8             fused_topk       fused_topk       scan + qangular
    int4 packed      fused_topk4      fused_topk4      scan + unpack + qangular
    pq + int8 LUT    fused_adc_topk   fused_adc_topk   (unsupported)
    pq + fp32 LUT    ADC LUT scan     ADC LUT scan     (unsupported)

`fused_topk*` / `fused_adc_topk` are the streaming Pallas kernels (score
tiles + running top-k carried in VMEM, no [Q, N] matrix in HBM; the ADC
kernel additionally keeps the int8 LUT block VMEM-resident and unpacks
4-bit packed codewords in-kernel); the scan paths stream `lax.scan`
chunks through ``merge_topk`` with the same masking contract.

Row-id bases: shard-local stores carry ``base`` and the engine rebases
returned ids, so the distributed merge (``distributed_topk``, below)
composes without per-caller offset arithmetic.  ``remap_ids`` is the
id-remap gather segmented indexes use to turn internal row ids back into
caller-visible external ids.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import pack as PK
from repro.engine.store import CodeStore, PQStore
from repro.kernels import ops as K
from repro.tune import table as T

NEG = float(jnp.finfo(jnp.float32).min)

#: corpus rows per fused-kernel tile — the *fallback* when no TuneTable
#: entry matches (dispatch precedence: tuned table > these constants;
#: the kernel may still shrink the tile for small corpora)
FUSED_TILE = 512


ScoreSet = Callable[[jax.Array, jax.Array], jax.Array]


# --------------------------------------------------------------------------
# generic streaming machinery (canonical home; knn.topk is a shim)
# --------------------------------------------------------------------------

def merge_topk(
    scores_a: jax.Array,
    ids_a: jax.Array,
    scores_b: jax.Array,
    ids_b: jax.Array,
    k: int,
):
    """Merge two [Q, ka]/[Q, kb] candidate sets into the best k."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    top_s, pos = jax.lax.top_k(s, k)
    top_i = jnp.take_along_axis(i, pos, axis=-1)
    return top_s, top_i


def pad_rows(a: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """Zero-pad rows to a multiple; engine paths id-mask the pad rows."""
    n = a.shape[0]
    target = ((n + multiple - 1) // multiple) * multiple
    if target == n:
        return a, n
    return jnp.pad(a, ((0, target - n), (0, 0))), n


def remap_ids(ids: jax.Array, id_map: jax.Array) -> jax.Array:
    """Gather ``id_map[ids]`` with -1 (no hit) passed through.

    The id-remap helper behind segmented/mutable indexes: engine paths
    return *internal* row ids (segment base + local row); the stream
    layer's plans map them to the caller's external ids through one
    gather — tombstoned / empty slots stay -1.
    """
    safe = jnp.clip(ids, 0, id_map.shape[0] - 1)
    return jnp.where(ids >= 0, id_map[safe].astype(jnp.int32), -1)


def _stream_topk(q, data, k, chunk, n_valid, tile_scores, mask=None):
    """THE streaming top-k loop: every scan-shaped top-k routes here.

    Scores ``data`` in ``chunk``-row tiles through ``tile_scores(q, tile)``
    with a running [Q, k] best set (``merge_topk``), id-masking rows
    >= ``n_valid`` at the source.  An optional [n] predicate ``mask``
    (True = allowed) ANDs into the same fence — the filter dataflow of
    DESIGN.md §16: filtered rows die exactly like pad rows, inside the
    tile the scan was reading anyway, so ``bytes_read`` is unchanged.
    Callers wrap it in their own jit (``_scan_topk`` specializes on the
    store pytree, ``chunked_topk`` on a static score_fn) so there is
    exactly one implementation of the chunked-merge formulation.
    """
    Q = q.shape[0]
    n = data.shape[0]

    if n <= chunk:
        s = tile_scores(q, data)
        gid = jnp.arange(n, dtype=jnp.int32)[None, :]
        ok = gid < n_valid
        if mask is not None:
            ok = ok & mask.astype(bool)[None, :]
        s = jnp.where(ok, s, NEG)
        ids = jnp.where(ok, jnp.broadcast_to(gid, s.shape), -1)
        return merge_topk(
            jnp.full((Q, k), NEG, jnp.float32), jnp.full((Q, k), -1, jnp.int32),
            s, ids, k,
        )

    padded, _ = pad_rows(data, chunk)
    n_chunks = padded.shape[0] // chunk
    tiles = padded.reshape(n_chunks, chunk, padded.shape[-1])

    init = (jnp.full((Q, k), NEG, jnp.float32), jnp.full((Q, k), -1, jnp.int32))

    if mask is not None:
        mtiles = jnp.pad(
            mask.astype(bool), (0, padded.shape[0] - n)
        ).reshape(n_chunks, chunk)

        def step_masked(carry, inp):
            best_s, best_i = carry
            tile, tile_idx, mrow = inp
            s = tile_scores(q, tile)
            gid = tile_idx * chunk + jnp.arange(chunk, dtype=jnp.int32)[None, :]
            ok = (gid < n_valid) & mrow[None, :]
            s = jnp.where(ok, s, NEG)
            ids = jnp.where(ok, jnp.broadcast_to(gid, s.shape), -1)
            return merge_topk(best_s, best_i, s, ids, k), None

        (best_s, best_i), _ = jax.lax.scan(
            step_masked, init,
            (tiles, jnp.arange(n_chunks, dtype=jnp.int32), mtiles),
        )
        return best_s, best_i

    def step(carry, inp):
        best_s, best_i = carry
        tile, tile_idx = inp
        s = tile_scores(q, tile)
        gid = tile_idx * chunk + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        ok = gid < n_valid                             # id-mask at the source
        s = jnp.where(ok, s, NEG)
        ids = jnp.where(ok, jnp.broadcast_to(gid, s.shape), -1)
        return merge_topk(best_s, best_i, s, ids, k), None

    (best_s, best_i), _ = jax.lax.scan(
        step, init, (tiles, jnp.arange(n_chunks, dtype=jnp.int32))
    )
    return best_s, best_i


@partial(jax.jit, static_argnames=("k", "score_fn", "chunk", "n_valid"))
def chunked_topk(
    queries: jax.Array,
    corpus: jax.Array,
    k: int,
    score_fn: Callable[[jax.Array, jax.Array], jax.Array],
    chunk: int = 16384,
    n_valid: int | None = None,
    mask: jax.Array | None = None,
):
    """Exact top-k of score_fn(queries, corpus) without materializing [Q, N].

    The generic score-fn entry point over ``_stream_topk`` (the index hot
    path uses ``engine.topk`` and the fused Pallas kernels instead).  Any
    corpus length works — rows are padded to the chunk internally and
    rows >= ``n_valid`` (default: all real rows valid) are id-masked at
    the source, so callers no longer pre-pad or post-mask.  ``score_fn``
    must be a stable (hashable) callable: it is a static jit argument.
    """
    n_valid = corpus.shape[0] if n_valid is None else n_valid

    def tile_scores(q, tile):
        return score_fn(q, tile).astype(jnp.float32)

    return _stream_topk(queries, corpus, k, chunk, n_valid, tile_scores,
                        mask=mask)


# --------------------------------------------------------------------------
# stats: uniform per-search accounting for SearchResult.stats
# --------------------------------------------------------------------------

def search_stats(store, *, candidates: int, chunks: int, rows_read: int) -> dict[str, Any]:
    """The uniform accounting block every kind reports.

    candidates  rows scored per query (an upper bound for graph walks,
                whose while-loops stop early on convergence)
    chunks      corpus tiles / scan chunks touched
    bytes_read  payload bytes gathered or streamed for the whole batch
    """
    return {
        "candidates": int(candidates),
        "chunks": int(chunks),
        "bytes_read": int(rows_read) * store.row_bytes,
        "bits": int(getattr(store, "bits", 8)),
        "packed": bool(getattr(store, "packed", False)),
    }


# --------------------------------------------------------------------------
# score-set closures (graph walks gather rows by id)
# --------------------------------------------------------------------------

def make_score_set(store: CodeStore, metric: str) -> ScoreSet:
    """(query [d], ids [m]) -> larger-is-closer [m] f32 over store rows."""

    def score_set(q: jax.Array, ids: jax.Array) -> jax.Array:
        vecs = store.take(ids)
        return D.scores(
            q[None], vecs, metric, quantized=store.quantized
        )[0].astype(jnp.float32)

    return score_set


# --------------------------------------------------------------------------
# full-corpus streaming top-k
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "metric", "chunk"))
def _scan_topk(q: jax.Array, store: CodeStore, k: int, metric: str, chunk: int,
               mask: jax.Array | None = None):
    """Unfused fallback: ``_stream_topk`` over the store's tiles.

    Used for metrics the fused kernel does not cover (angular needs the
    per-row norm rescale).  Packed tiles are unpacked chunk-by-chunk — the
    full-width corpus never materializes.
    """

    def tile_scores(qq, tile):
        rows = PK.unpack_int4(tile) if store.packed else tile
        return D.scores(qq, rows, metric, quantized=store.quantized).astype(
            jnp.float32
        )

    return _stream_topk(q, store.data, k, chunk, store.n, tile_scores,
                        mask=mask)


def topk(
    queries: jax.Array,
    store: "CodeStore | PQStore",
    k: int,
    metric: str,
    *,
    chunk: int = 16384,
    prepared: bool = False,
    use_pallas: bool = True,
    interpret: bool | None = None,
    mask: jax.Array | None = None,
):
    """Exact top-k of the whole store: (scores [Q, k] f32, ids, stats).

    When k > n the tail is padded with (-inf, -1) — the uniform
    ``SearchResult`` contract.  ``prepared=True`` means ``queries`` are
    already in the store's code space (skip ``encode_queries``).
    ``chunk`` sizes the scan chunks on the unfused path and caps the
    fused kernel's corpus tile (the working-set bound either way).
    An optional [n] ``mask`` (True = allowed; store-local row space,
    before ``base`` rebasing) rides the id-masking fence on every path —
    filtered rows cost nothing extra to skip, so stats are unchanged.

    Dispatch consults the installed TuneTable first (``repro.tune``):
    a matching entry decides fused-vs-scan and the tile/chunk shapes;
    on a miss, today's constants apply unchanged.  ``stats["tuned"]``
    records which happened.
    """
    if isinstance(store, PQStore):
        if metric == "angular":
            raise ValueError(
                "PQ/ADC scoring supports ip and l2 only (see the dispatch "
                "table in this module's docstring)"
            )
        cfg = T.lookup("fused_adc", metric, store.bits,
                       jnp.shape(queries)[0], store.n, store.m)
        s, i = _topk_pq(queries, store, k, metric, chunk,
                        use_pallas=use_pallas, interpret=interpret, cfg=cfg,
                        mask=mask)
        if s.shape[1] < k:               # uniform [Q, k] contract: -1 pads
            s = jnp.pad(s, ((0, 0), (0, k - s.shape[1])), constant_values=NEG)
            i = jnp.pad(i, ((0, 0), (0, k - i.shape[1])), constant_values=-1)
        fused, tile, chunk_eff = _pq_fused(store, metric, chunk,
                                           use_pallas, interpret, cfg)
        if fused:
            n_chunks = -(-store.n // tile)
            # like the CodeStore kernel, the fused grid re-streams the
            # code matrix once per query tile (the LUT block is what
            # stays VMEM-resident, not the codes)
            bq = (cfg.bq if cfg is not None and cfg.bq is not None
                  else K.fused_adc_query_tile())
            passes = max(1, -(-jnp.shape(queries)[0] // bq))
        else:
            n_chunks = max(1, -(-store.n // chunk_eff))
            passes = 1
        stats = search_stats(store, candidates=store.n, chunks=n_chunks,
                             rows_read=store.n * passes)
        stats["tuned"] = cfg is not None
        return s, i, stats

    q = queries if prepared else store.encode_queries(queries)
    k_eff = min(k, store.n)

    kernel = "packed" if store.packed else "fused_topk"
    cfg = T.lookup(kernel if metric in ("ip", "l2") else "scan",
                   metric, store.bits, jnp.shape(q)[0], store.n,
                   jnp.shape(q)[1])
    tile = min(FUSED_TILE, max(8, chunk))
    chunk_eff = chunk
    bq = None
    if cfg is not None:
        if cfg.impl == "fused":
            tile = cfg.bn or tile
            bq = cfg.bq
        else:                            # measured crossover says scan
            chunk_eff = max(8, cfg.chunk or chunk)
    # The fused Pallas kernel is the TPU hot path (or forced via
    # interpret=True for CI wiring tests).  Off-TPU, interpret mode is a
    # parity tool, not a serving path — the XLA streaming scan is ~20x
    # faster there and keeps the same O(Q * (k + chunk)) working set.
    # Corpora that fit one tile (IVF centroids, graph seeds) also skip
    # the kernel: there is nothing to stream.
    fused = (
        metric in ("ip", "l2")
        and use_pallas
        and store.n > tile
        and (cfg is None or cfg.impl == "fused")
        and (bool(interpret) or jax.default_backend() == "tpu")
    )
    if fused:
        s, i = K.fused_topk(
            q, store.data, k_eff, metric, packed=store.packed,
            bq=bq, bn=tile, interpret=interpret, mask=mask,
        )
        chunks = -(-store.n // tile)
        # the fused grid re-streams the corpus once per bq-row query tile
        # (queries are VMEM-resident within a tile, not across tiles)
        passes = max(1, -(-q.shape[0] // (bq or K.fused_query_tile())))
    else:
        s, i = _scan_topk(q, store, k_eff, metric, chunk_eff, mask)
        chunks = max(1, -(-store.n // chunk_eff))
        passes = 1                       # one scan, all queries resident

    if k_eff < k:                        # uniform [Q, k] contract: -1 pads
        s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
        i = jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1)
    if store.base:
        i = jnp.where(i >= 0, i + store.base, -1)
    stats = search_stats(store, candidates=store.n, chunks=chunks,
                         rows_read=store.n * passes)
    stats["tuned"] = cfg is not None
    return s, i, stats


# --------------------------------------------------------------------------
# candidate-set top-k (IVF fine scoring and friends)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "metric"))
def topk_among(
    q_codes: jax.Array,
    store: CodeStore,
    cand_ids: jax.Array,
    k: int,
    metric: str,
    mask: jax.Array | None = None,
):
    """Top-k restricted to per-query candidate lists.

    q_codes [Q, d_eff] prepared queries; cand_ids [Q, L] (-1 = empty
    slot).  Gathers store rows (unpacking int4 only for what was
    gathered), scores, masks empties, returns ([Q, k], [Q, k]).
    An optional [n] predicate ``mask`` over store rows (True = allowed,
    same row space as ``cand_ids``) ANDs into the empty-slot fence.

    Scoring is the batched ``D.scores_among`` (einsum over the gathered
    [Q, L, d] block) rather than a vmapped per-query dot: the batched
    form lowers identically inside ``shard_map``, which is what lets a
    sharded IVF plan reproduce this function's scores bit-exactly
    (DESIGN.md §15).
    """
    L = cand_ids.shape[1]
    k_eff = min(k, L)

    ok = cand_ids >= 0
    safe = jnp.where(ok, cand_ids, 0)
    if mask is not None:
        ok = ok & mask.astype(bool)[safe]
    rows = store.take(safe)                              # [Q, L, d]
    s = D.scores_among(q_codes, rows, metric, quantized=store.quantized)
    s = jnp.where(ok, s.astype(jnp.float32), NEG)
    s, pos = jax.lax.top_k(s, k_eff)
    i = jnp.where(
        s > NEG, jnp.take_along_axis(cand_ids, pos, axis=1), -1
    ).astype(jnp.int32)
    if k_eff < k:
        s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
        i = jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1)
    if store.base:
        i = jnp.where(i >= 0, i + store.base, -1)
    return s, i


# --------------------------------------------------------------------------
# rerank tail (Searcher §3.4 recall recovery: quantized scan -> exact pass)
# --------------------------------------------------------------------------

def rerank_among(
    queries: jax.Array,
    store: CodeStore,
    cand_ids: jax.Array,
    k: int,
    metric: str,
    mask: jax.Array | None = None,
):
    """Re-score candidate ids against a higher-precision store.

    The Searcher's rerank tail: ``cand_ids`` [Q, depth] come from a
    quantized scan (-1 = empty slot); rows are gathered from the fp32 /
    int8 ``store`` and re-scored by exact distance, returning the best k.
    Runs inside the caller's jit (``topk_among`` is the compiled body), so
    scan → rerank → merge is one executable.  Returns (scores, ids, stats
    delta) — ``bytes_read`` counts the gathered rerank payload.
    """
    q = store.encode_queries(jnp.asarray(queries, jnp.float32))
    s, i = topk_among(q, store, cand_ids, k, metric, mask)
    depth = int(cand_ids.shape[1])
    stats = {
        "reranked": depth,
        "rerank_bits": int(store.bits),
        "rerank_bytes": int(cand_ids.shape[0]) * depth * store.row_bytes,
    }
    return s, i, stats


# --------------------------------------------------------------------------
# cascade stages (DESIGN.md §14): budgeted refinement + per-region lookup
# --------------------------------------------------------------------------

def refine_among(
    queries: jax.Array,
    store: CodeStore,
    cand_ids: jax.Array,
    out_k: int,
    metric: str,
    mask: jax.Array | None = None,
):
    """One cascade refinement stage: re-score the surviving candidates at
    this store's precision and keep the best ``out_k``.

    Same compiled body as the rerank tail (``topk_among``) — a cascade's
    final fp32 stage is therefore bit-identical to the ``+r32`` tail at
    the same depth — but reports the stage-stat names the cascade
    aggregates: its own fetch budget (``candidates`` = the incoming
    candidate-list width), gathered payload bytes, and code width.
    """
    q = store.encode_queries(jnp.asarray(queries, jnp.float32))
    s, i = topk_among(q, store, cand_ids, out_k, metric, mask)
    depth = int(cand_ids.shape[1])
    stats = {
        "candidates": depth,
        "bytes_read": int(cand_ids.shape[0]) * depth * store.row_bytes,
        "bits": int(store.bits),
    }
    return s, i, stats


@partial(jax.jit, static_argnames=("k", "metric"))
def topk_among_regional(
    queries: jax.Array,
    store: CodeStore,
    region_scale: jax.Array,
    region_zero: jax.Array,
    assign: jax.Array,
    cand_ids: jax.Array,
    k: int,
    metric: str,
    mask: jax.Array | None = None,
):
    """Candidate top-k with per-region Eq. 1 constant lookup.

    Codes quantized under different regions' constants are not comparable
    in integer space, so the regional path scores fp32 ``queries``
    against *dequantized* rows: each gathered candidate's region id
    (``assign [N]``) selects its own ``region_scale`` / ``region_zero``
    rows ([R, d]) and the code is mapped back to fp32 before the metric.
    Everything else (empty-slot masking, -1 pads, base rebasing, the
    optional row-space ``mask``) matches ``topk_among``.
    """
    L = cand_ids.shape[1]
    k_eff = min(k, L)

    ok = cand_ids >= 0
    safe = jnp.where(ok, cand_ids, 0)
    if mask is not None:
        ok = ok & mask.astype(bool)[safe]
    codes = store.take(safe).astype(jnp.float32)         # [Q, L, d]
    reg = assign[safe]                                   # [Q, L]
    x = codes * region_scale[reg] + region_zero[reg]
    s = D.scores_among(queries, x, metric, quantized=False)
    s = jnp.where(ok, s.astype(jnp.float32), NEG)
    s, pos = jax.lax.top_k(s, k_eff)
    i = jnp.where(
        s > NEG, jnp.take_along_axis(cand_ids, pos, axis=1), -1
    ).astype(jnp.int32)
    if k_eff < k:
        s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
        i = jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1)
    if store.base:
        i = jnp.where(i >= 0, i + store.base, -1)
    return s, i


def regional_stats(store, cand_ids) -> dict[str, Any]:
    """Stats delta of one ``topk_among_regional`` call: the gathered code
    payload plus the per-row constant lookup (scale + zero, fp32 [d])."""
    depth = int(cand_ids.shape[1])
    const_bytes = 2 * 4 * int(store.d)
    return {
        "candidates": depth,
        "bytes_read": int(cand_ids.shape[0]) * depth * (store.row_bytes + const_bytes),
        "bits": int(store.bits),
        "packed": bool(store.packed),
        "regional": True,
    }


# --------------------------------------------------------------------------
# Distributed merge (corpus row-sharded over one or more mesh axes)
# --------------------------------------------------------------------------

def distributed_topk(
    local_scores: jax.Array,
    local_ids: jax.Array,
    k: int,
    axis_name: str | tuple[str, ...],
    shard_offset: jax.Array,
    *,
    tie_break: str = "order",
):
    """Merge per-shard top-k into a global top-k, inside ``shard_map``.

    Each shard holds [Q, k] candidates with *local* ids; ``shard_offset``
    (scalar, per shard) rebases them to global row ids.  One all_gather of
    k entries per query per shard — O(shards * Q * k) bytes, independent of
    corpus size N.  (A butterfly collective_permute halves wire bytes at
    log-depth; see EXPERIMENTS.md §Perf for why all_gather wins at k=100.)

    Shard-local stores built with ``CodeStore(base=offset)`` already
    return rebased ids from the engine — pass ``shard_offset=0`` there.

    ``tie_break`` decides which of several equal-score candidates wins —
    the thing that makes sharded results *bit-identical* to unsharded
    ones, not merely score-identical (quantized scores tie constantly):

      * ``"order"`` — ``lax.top_k``'s stable gather order: lower shard
        first, then local rank.  Correct when shard order matches global
        id order (contiguous row blocks: flat/pq/stream scans).
      * ``"id"`` — lexicographic (score desc, id asc) via a two-key
        sort.  Correct when shards interleave the id space (IVF list
        placement merges on candidate *positions*, reproducing
        ``topk_among``'s canonical per-query ``top_k`` order).
        Masked entries (NEG score) sort last regardless of id.
    """
    if tie_break not in ("order", "id"):
        raise ValueError(f"tie_break must be 'order' or 'id', got {tie_break!r}")
    gids = jnp.where(local_ids >= 0, local_ids + shard_offset, -1)
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    s, i = local_scores, gids
    for name in names:
        s = jax.lax.all_gather(s, name, axis=0)   # [S, Q, k]
        i = jax.lax.all_gather(i, name, axis=0)
        S, Q, kk = s.shape
        s = jnp.moveaxis(s, 0, 1).reshape(Q, S * kk)
        i = jnp.moveaxis(i, 0, 1).reshape(Q, S * kk)
        if tie_break == "id":
            # ascending lexicographic sort on (-score, id): score desc,
            # id asc among ties; NEG-masked rows (-NEG = fp32 max) last
            ns, i = jax.lax.sort((-s, i), num_keys=2)
            s, i = (-ns)[:, :k], i[:, :k]
        else:
            s, pos = jax.lax.top_k(s, k)
            i = jnp.take_along_axis(i, pos, axis=-1)
    return s, i


# --------------------------------------------------------------------------
# PQ: ADC — fused Pallas kernel or streaming LUT gather-sum scan
# --------------------------------------------------------------------------

def build_pq_lut(queries: jax.Array, store: PQStore, metric: str) -> jax.Array:
    """Per-query ADC lookup table [Q, M, K] f32 of query-to-codeword
    scores (K = ``store.n_codewords``)."""
    q = jnp.asarray(queries, jnp.float32)
    Q, d = q.shape
    ds = d // store.m
    qs = q.reshape(Q, store.m, ds)
    if metric == "ip":
        return jnp.einsum("qmd,mkd->qmk", qs, store.codebooks)
    diff = qs[:, :, None, :] - store.codebooks[None]    # l2 (negated)
    return -jnp.sum(diff * diff, -1)


def quantize_pq_lut(lut: jax.Array) -> jax.Array:
    """The paper's after-the-codebook composition (``lpq_tables``): Eq. 1
    abs-max quantization of the LUT entries to int8, one scale **per
    query** (over that query's [M, K] table).  Per-query scaling keeps
    the M subspace entries that sum into one score on a common scale —
    the only comparability ADC needs, since top-k ranks within a query —
    while making each query's quantized LUT independent of batch
    composition: a Searcher pad row (whose negated-L2 table against the
    codebooks is large) cannot perturb a real query's scale, so padded
    planned execution is bit-identical to the eager path."""
    amax = jnp.maximum(jnp.max(jnp.abs(lut), axis=(1, 2), keepdims=True),
                       1e-12)
    return jnp.clip(jnp.round(lut / amax * 127.0), -128, 127).astype(jnp.int8)


def _pq_fused(store: PQStore, metric: str, chunk: int,
              use_pallas: bool, interpret,
              cfg=None) -> tuple[bool, int, int]:
    """Fused-vs-reference dispatch for the ADC scan: (fused, fused tile,
    scan chunk).

    The fused Pallas kernel needs integer LUTs (``lpq_tables``: int8
    entries it holds VMEM-resident and accumulates in int32); fp32-LUT
    stores take the streaming gather-sum scan.  Backend gating matches
    the CodeStore path: TPU hot path, ``interpret=True`` for CI wiring,
    single-tile corpora skip the kernel.  A TuneTable entry (``cfg``)
    overrides the tile/chunk shapes and can force the measured
    crossover's scan choice; the gating conditions still apply.
    """
    tile = min(FUSED_TILE, max(8, chunk))
    chunk_eff = chunk
    if cfg is not None:
        if cfg.impl == "fused":
            tile = cfg.bn or tile
        else:
            chunk_eff = max(8, cfg.chunk or chunk)
    fused = (
        metric in ("ip", "l2")
        and store.lpq_tables
        and use_pallas
        and store.n > tile
        and (cfg is None or cfg.impl == "fused")
        and (bool(interpret) or jax.default_backend() == "tpu")
    )
    return fused, tile, chunk_eff


#: optional runtime LUT-block cache (repro.runtime.cache.LUTCache) — the
#: hook only fires on *concrete* query batches (eager / one-shot search);
#: inside a jitted Searcher bucket queries are tracers and the LUT is
#: already fused into the compiled executable, so there is nothing to cache
_LUT_CACHE = None


def set_lut_cache(cache) -> None:
    """Install (or, with None, remove) the process-wide PQ LUT cache."""
    global _LUT_CACHE
    _LUT_CACHE = cache


def get_lut_cache():
    return _LUT_CACHE


@partial(jax.jit, static_argnames=("metric",))
def _prepare_pq_lut(queries: jax.Array, store: PQStore, metric: str):
    """The per-batch ADC table build: ``build_pq_lut`` einsum plus — for
    ``lpq_tables`` stores — the paper's Eq. 1 int8 quantization.  This is
    exactly the work the runtime LUT cache elides for repeated batches."""
    lut = build_pq_lut(queries, store, metric)
    return quantize_pq_lut(lut) if store.lpq_tables else lut


def _topk_pq(
    queries: jax.Array,
    store: PQStore,
    k: int,
    metric: str,
    chunk: int,
    use_pallas: bool = True,
    interpret: bool | None = None,
    cfg=None,
    mask: jax.Array | None = None,
):
    """Asymmetric distance computation over the code matrix.

    Per-query LUT of query-to-codeword scores (served from the runtime
    LUT cache when one is installed and the batch is concrete), then
    either the **fused Pallas ADC kernel** (``kernels/adc.py``: int8 LUT
    VMEM-resident, 4-bit codes unpacked from their packed nibbles
    in-kernel, int32 accumulation, running top-k — the [Q, N] ADC matrix
    never exists) or the **reference streaming scan** (``_stream_topk``
    over code chunks with a gather-sum tile, unpacking 4-bit codes chunk
    by chunk).  Dispatch is ``_pq_fused``; both paths are bit-identical.
    """
    cache = _LUT_CACHE
    if cache is not None and not isinstance(queries, jax.core.Tracer):
        key = cache.key_for(queries, store.codebooks, metric,
                            store.lpq_tables)
        lut = cache.get_or_build(
            key, lambda: jax.block_until_ready(
                _prepare_pq_lut(queries, store, metric))
        )
    else:
        lut = _prepare_pq_lut(queries, store, metric)
    return _topk_pq_from_lut(lut, store, k, metric, chunk,
                             use_pallas=use_pallas, interpret=interpret,
                             cfg=cfg, mask=mask)


@partial(jax.jit, static_argnames=("k", "metric", "chunk", "use_pallas",
                                   "interpret", "cfg"))
def _topk_pq_from_lut(
    lut: jax.Array,
    store: PQStore,
    k: int,
    metric: str,
    chunk: int,
    use_pallas: bool = True,
    interpret: bool | None = None,
    cfg=None,
    mask: jax.Array | None = None,
):
    n = store.n
    k_eff = min(k, n)

    fused, tile, chunk = _pq_fused(store, metric, chunk, use_pallas,
                                   interpret, cfg)
    if fused:
        return K.fused_adc_topk(lut, store.codes, k_eff,
                                packed=store.packed,
                                bq=(cfg.bq if cfg is not None else None),
                                bn=tile, interpret=interpret, mask=mask)

    ilut = lut.astype(jnp.int32) if store.lpq_tables else lut

    def tile_scores(lt, tile_codes):                    # [c, Mb] -> [Q, c]
        rows = (PK.unpack_uint4(tile_codes)[:, : store.m]
                if store.packed else tile_codes)
        idx = rows.T[None].astype(jnp.int32)            # [1, M, c]
        return jnp.sum(
            jnp.take_along_axis(lt, idx, axis=2), axis=1
        ).astype(jnp.float32)

    return _stream_topk(ilut, store.codes, k_eff, chunk, n, tile_scores,
                        mask=mask)
