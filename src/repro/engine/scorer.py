"""The scoring engine: every index's query hot path in one place.

``topk`` / ``topk_among`` / ``make_score_set`` own metric x bits dispatch,
chunking, corpus padding, invalid-id masking and streaming top-k, so index
classes hold *structure* (lists, graphs, codebooks) and delegate every
score to the engine.  Padding is id-masked here, centrally — the L2
zero-sentinel hazard (a zero pad row out-scoring real rows under negated
L2) cannot reach callers, because no caller sees pad rows at all.

Kernel dispatch table (metric x storage):

    storage          ip               l2               angular
    fp32             fused_topk       fused_topk       scan + angular
    int8             fused_topk       fused_topk       scan + qangular
    int4 packed      fused_topk4      fused_topk4      scan + unpack + qangular
    pq codes         ADC LUT scan     ADC LUT scan     (unsupported)

`fused_topk*` are the streaming Pallas kernels (score tiles + running
top-k carried in VMEM, no [Q, N] matrix in HBM); the scan paths stream
`lax.scan` chunks through ``merge_topk`` with the same masking contract.

Row-id bases: shard-local stores carry ``base`` and the engine rebases
returned ids, so the distributed merge (`knn.topk.distributed_topk`)
composes without per-caller offset arithmetic.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import pack as PK
from repro.engine.store import CodeStore, PQStore
from repro.kernels import ops as K

NEG = float(jnp.finfo(jnp.float32).min)

#: corpus rows per fused-kernel tile (reporting; the kernel may shrink it
#: for small corpora)
FUSED_TILE = 512


ScoreSet = Callable[[jax.Array, jax.Array], jax.Array]


# --------------------------------------------------------------------------
# generic streaming machinery (canonical home; knn.topk re-exports)
# --------------------------------------------------------------------------

def merge_topk(
    scores_a: jax.Array,
    ids_a: jax.Array,
    scores_b: jax.Array,
    ids_b: jax.Array,
    k: int,
):
    """Merge two [Q, ka]/[Q, kb] candidate sets into the best k."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    top_s, pos = jax.lax.top_k(s, k)
    top_i = jnp.take_along_axis(i, pos, axis=-1)
    return top_s, top_i


def pad_rows(a: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """Zero-pad rows to a multiple; engine paths id-mask the pad rows."""
    n = a.shape[0]
    target = ((n + multiple - 1) // multiple) * multiple
    if target == n:
        return a, n
    return jnp.pad(a, ((0, target - n), (0, 0))), n


# --------------------------------------------------------------------------
# stats: uniform per-search accounting for SearchResult.stats
# --------------------------------------------------------------------------

def search_stats(store, *, candidates: int, chunks: int, rows_read: int) -> dict[str, Any]:
    """The uniform accounting block every kind reports.

    candidates  rows scored per query (an upper bound for graph walks,
                whose while-loops stop early on convergence)
    chunks      corpus tiles / scan chunks touched
    bytes_read  payload bytes gathered or streamed for the whole batch
    """
    return {
        "candidates": int(candidates),
        "chunks": int(chunks),
        "bytes_read": int(rows_read) * store.row_bytes,
        "bits": int(getattr(store, "bits", 8)),
        "packed": bool(getattr(store, "packed", False)),
    }


# --------------------------------------------------------------------------
# score-set closures (graph walks gather rows by id)
# --------------------------------------------------------------------------

def make_score_set(store: CodeStore, metric: str) -> ScoreSet:
    """(query [d], ids [m]) -> larger-is-closer [m] f32 over store rows."""

    def score_set(q: jax.Array, ids: jax.Array) -> jax.Array:
        vecs = store.take(ids)
        return D.scores(
            q[None], vecs, metric, quantized=store.quantized
        )[0].astype(jnp.float32)

    return score_set


# --------------------------------------------------------------------------
# full-corpus streaming top-k
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "metric", "chunk"))
def _scan_topk(q: jax.Array, store: CodeStore, k: int, metric: str, chunk: int):
    """Unfused fallback: lax.scan over corpus chunks + merge_topk.

    Used for metrics the fused kernel does not cover (angular needs the
    per-row norm rescale).  Packed tiles are unpacked chunk-by-chunk — the
    full-width corpus never materializes.
    """
    n = store.n
    Q = q.shape[0]

    def tile_scores(tile):
        rows = PK.unpack_int4(tile) if store.packed else tile
        return D.scores(q, rows, metric, quantized=store.quantized).astype(
            jnp.float32
        )

    if n <= chunk:
        s = tile_scores(store.data)
        ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], s.shape)
        return merge_topk(
            jnp.full((Q, k), NEG, jnp.float32), jnp.full((Q, k), -1, jnp.int32),
            s, ids, k,
        )

    padded, _ = pad_rows(store.data, chunk)
    n_chunks = padded.shape[0] // chunk
    tiles = padded.reshape(n_chunks, chunk, padded.shape[-1])

    init = (jnp.full((Q, k), NEG, jnp.float32), jnp.full((Q, k), -1, jnp.int32))

    def step(carry, inp):
        best_s, best_i = carry
        tile, tile_idx = inp
        s = tile_scores(tile)
        gid = tile_idx * chunk + jnp.arange(chunk, dtype=jnp.int32)[None, :]
        ok = gid < n                                   # id-mask at the source
        s = jnp.where(ok, s, NEG)
        ids = jnp.where(ok, jnp.broadcast_to(gid, s.shape), -1)
        return merge_topk(best_s, best_i, s, ids, k), None

    (best_s, best_i), _ = jax.lax.scan(
        step, init, (tiles, jnp.arange(n_chunks, dtype=jnp.int32))
    )
    return best_s, best_i


def topk(
    queries: jax.Array,
    store: "CodeStore | PQStore",
    k: int,
    metric: str,
    *,
    chunk: int = 16384,
    prepared: bool = False,
    use_pallas: bool = True,
    interpret: bool | None = None,
):
    """Exact top-k of the whole store: (scores [Q, k] f32, ids, stats).

    When k > n the tail is padded with (-inf, -1) — the uniform
    ``SearchResult`` contract.  ``prepared=True`` means ``queries`` are
    already in the store's code space (skip ``encode_queries``).
    ``chunk`` sizes the scan chunks on the unfused path and caps the
    fused kernel's corpus tile (the working-set bound either way).
    """
    if isinstance(store, PQStore):
        if metric == "angular":
            raise ValueError(
                "PQ/ADC scoring supports ip and l2 only (see the dispatch "
                "table in this module's docstring)"
            )
        s, i = _topk_pq(queries, store, k, metric, chunk)
        if s.shape[1] < k:               # uniform [Q, k] contract: -1 pads
            s = jnp.pad(s, ((0, 0), (0, k - s.shape[1])), constant_values=NEG)
            i = jnp.pad(i, ((0, 0), (0, k - i.shape[1])), constant_values=-1)
        n_chunks = max(1, -(-store.n // chunk))
        stats = search_stats(store, candidates=store.n, chunks=n_chunks,
                             rows_read=store.n)
        return s, i, stats

    q = queries if prepared else store.encode_queries(queries)
    k_eff = min(k, store.n)

    tile = min(FUSED_TILE, max(8, chunk))
    # The fused Pallas kernel is the TPU hot path (or forced via
    # interpret=True for CI wiring tests).  Off-TPU, interpret mode is a
    # parity tool, not a serving path — the XLA streaming scan is ~20x
    # faster there and keeps the same O(Q * (k + chunk)) working set.
    # Corpora that fit one tile (IVF centroids, graph seeds) also skip
    # the kernel: there is nothing to stream.
    fused = (
        metric in ("ip", "l2")
        and use_pallas
        and store.n > tile
        and (bool(interpret) or jax.default_backend() == "tpu")
    )
    if fused:
        s, i = K.fused_topk(
            q, store.data, k_eff, metric, packed=store.packed, bn=tile,
            interpret=interpret,
        )
        chunks = -(-store.n // tile)
        # the fused grid re-streams the corpus once per BQ-row query tile
        # (queries are VMEM-resident within a tile, not across tiles)
        passes = max(1, -(-q.shape[0] // K.fused_query_tile()))
    else:
        s, i = _scan_topk(q, store, k_eff, metric, chunk)
        chunks = max(1, -(-store.n // chunk))
        passes = 1                       # one scan, all queries resident

    if k_eff < k:                        # uniform [Q, k] contract: -1 pads
        s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
        i = jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1)
    if store.base:
        i = jnp.where(i >= 0, i + store.base, -1)
    stats = search_stats(store, candidates=store.n, chunks=chunks,
                         rows_read=store.n * passes)
    return s, i, stats


# --------------------------------------------------------------------------
# candidate-set top-k (IVF fine scoring and friends)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "metric"))
def topk_among(
    q_codes: jax.Array,
    store: CodeStore,
    cand_ids: jax.Array,
    k: int,
    metric: str,
):
    """Top-k restricted to per-query candidate lists.

    q_codes [Q, d_eff] prepared queries; cand_ids [Q, L] (-1 = empty
    slot).  Gathers store rows (unpacking int4 only for what was
    gathered), scores, masks empties, returns ([Q, k], [Q, k]).
    """
    L = cand_ids.shape[1]
    k_eff = min(k, L)

    def per_query(qv, ids):
        ok = ids >= 0
        rows = store.take(jnp.where(ok, ids, 0))
        s = D.scores(qv[None], rows, metric, quantized=store.quantized)[0]
        s = jnp.where(ok, s.astype(jnp.float32), NEG)
        top_s, pos = jax.lax.top_k(s, k_eff)
        top_i = jnp.where(top_s > NEG, ids[pos], -1).astype(jnp.int32)
        return top_s, top_i

    s, i = jax.vmap(per_query)(q_codes, cand_ids)
    if k_eff < k:
        s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
        i = jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1)
    if store.base:
        i = jnp.where(i >= 0, i + store.base, -1)
    return s, i


# --------------------------------------------------------------------------
# rerank tail (Searcher §3.4 recall recovery: quantized scan -> exact pass)
# --------------------------------------------------------------------------

def rerank_among(
    queries: jax.Array,
    store: CodeStore,
    cand_ids: jax.Array,
    k: int,
    metric: str,
):
    """Re-score candidate ids against a higher-precision store.

    The Searcher's rerank tail: ``cand_ids`` [Q, depth] come from a
    quantized scan (-1 = empty slot); rows are gathered from the fp32 /
    int8 ``store`` and re-scored by exact distance, returning the best k.
    Runs inside the caller's jit (``topk_among`` is the compiled body), so
    scan → rerank → merge is one executable.  Returns (scores, ids, stats
    delta) — ``bytes_read`` counts the gathered rerank payload.
    """
    q = store.encode_queries(jnp.asarray(queries, jnp.float32))
    s, i = topk_among(q, store, cand_ids, k, metric)
    depth = int(cand_ids.shape[1])
    stats = {
        "reranked": depth,
        "rerank_bits": int(store.bits),
        "rerank_bytes": int(cand_ids.shape[0]) * depth * store.row_bytes,
    }
    return s, i, stats


# --------------------------------------------------------------------------
# PQ: ADC LUT streaming scan
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("k", "metric", "chunk"))
def _topk_pq(queries: jax.Array, store: PQStore, k: int, metric: str, chunk: int):
    """Asymmetric distance computation with a streaming code scan.

    Per-query LUT of query-to-codeword scores, then a gather-sum over the
    code matrix — chunked with a running top-k, so the [Q, N] ADC score
    matrix is never materialized for large N.  ``lpq_tables`` is the
    paper's composition: the LUT entries themselves are int8-quantized
    (Eq. 1, per-table abs-max) and the scan accumulates integers.
    """
    q = jnp.asarray(queries, jnp.float32)
    Q, d = q.shape
    ds = d // store.m
    qs = q.reshape(Q, store.m, ds)
    if metric == "ip":
        lut = jnp.einsum("qmd,mkd->qmk", qs, store.codebooks)
    else:                                               # l2 (negated)
        diff = qs[:, :, None, :] - store.codebooks[None]
        lut = -jnp.sum(diff * diff, -1)

    if store.lpq_tables:
        amax = jnp.maximum(jnp.max(jnp.abs(lut)), 1e-12)
        lut = jnp.clip(jnp.round(lut / amax * 127.0), -128, 127)
        lut = lut.astype(jnp.int32)                     # int8-valued

    n = store.n
    k_eff = min(k, n)

    def adc(tile):                                      # [c, M] -> [Q, c]
        idx = tile.T[None].astype(jnp.int32)            # [1, M, c]
        return jnp.sum(
            jnp.take_along_axis(lut, idx, axis=2), axis=1
        ).astype(jnp.float32)

    if n <= chunk:
        s = adc(store.codes)
        ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None, :], s.shape)
        best = merge_topk(
            jnp.full((Q, k_eff), NEG, jnp.float32),
            jnp.full((Q, k_eff), -1, jnp.int32), s, ids, k_eff,
        )
    else:
        padded, _ = pad_rows(store.codes, chunk)
        n_chunks = padded.shape[0] // chunk
        tiles = padded.reshape(n_chunks, chunk, store.m)

        def step(carry, inp):
            tile, tile_idx = inp
            s = adc(tile)
            gid = tile_idx * chunk + jnp.arange(chunk, dtype=jnp.int32)[None, :]
            ok = gid < n
            s = jnp.where(ok, s, NEG)
            ids = jnp.where(ok, jnp.broadcast_to(gid, s.shape), -1)
            return merge_topk(*carry, s, ids, k_eff), None

        best, _ = jax.lax.scan(
            step,
            (jnp.full((Q, k_eff), NEG, jnp.float32),
             jnp.full((Q, k_eff), -1, jnp.int32)),
            (tiles, jnp.arange(n_chunks, dtype=jnp.int32)),
        )

    return best
