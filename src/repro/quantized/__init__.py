# The paper's technique integrated as first-class features:
#   qkv_cache — int8 KV cache decode attention (LM family)
#   embedding.QuantizedTable (models.recsys) — int8 embedding tables
#   knn.* quantized index options — the paper's own evaluation targets
from repro.quantized.qkv_cache import (
    QuantizedCache,
    cache_memory_bytes,
    decode_step_q8,
    make_quantized_cache,
    quantize_cache,
    quantized_decode_attention,
)

__all__ = [
    "QuantizedCache",
    "cache_memory_bytes",
    "decode_step_q8",
    "make_quantized_cache",
    "quantize_cache",
    "quantized_decode_attention",
]
