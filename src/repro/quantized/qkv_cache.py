"""int8 KV cache — the paper's quantization applied to LM decode.

Decode attention logits are inner products q·K over the cache: exactly the
paper's MIP problem, with the cache as the corpus and Definition 2
guaranteeing top-k (i.e. attention-weight ordering) preservation.  We
apply Eq. 1 per (layer, kv-head, head-dim) with abs-max constants (§4.2 —
K/V activations are low-variance per dim after RoPE), storing codes int8:

    K ≈ scale_k ⊙ K_codes        V ≈ scale_v ⊙ V_codes

Scoring never dequantizes the O(S)-sized cache: the per-dim scale folds
into the single query vector (q' = q ⊙ scale_k), so the hot loop is an
int8 gather + dot over codes — 4x less HBM traffic than fp32 and 2x less
than bf16, on the decode path whose roofline is *pure* HBM bandwidth
(see EXPERIMENTS.md §Roofline: decode_32k is memory-term dominated).
V applies its scale to the O(1)-sized attention output the same way.

At 500k context this is the difference between a 90 GB and a 22 GB cache
(gemma2-9b), i.e. whether the long_500k cell fits per-pod HBM at batch 1.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models.transformer import LMConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedCache:
    """int8 KV cache with per (layer, kv-head, dim) scales.

    Block-major layout matching transformer.cache_shape:
    codes [n_blocks, block_layers, B, S, Hkv, hd], scales [nb, bl, Hkv, hd].
    """

    k_codes: jax.Array
    v_codes: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array

    @property
    def max_len(self) -> int:
        return self.k_codes.shape[3]


def make_quantized_cache(cfg: LMConfig, batch: int, max_len: int) -> QuantizedCache:
    from repro.models.transformer import cache_shape

    shape = cache_shape(cfg, batch, max_len)
    sshape = (cfg.n_blocks, cfg.block_layers, cfg.n_kv, cfg.head_dim)
    return QuantizedCache(
        k_codes=jnp.zeros(shape, jnp.int8),
        v_codes=jnp.zeros(shape, jnp.int8),
        k_scale=jnp.ones(sshape, jnp.float32),
        v_scale=jnp.ones(sshape, jnp.float32),
    )


def _absmax_scale(x: jax.Array) -> jax.Array:
    """abs-max per (block, sub, kv-head, dim) over batch and sequence."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(2, 3))  # [nb, bl, Hkv, hd]
    return jnp.maximum(amax, 1e-8) / 127.0


def _enc(x: jax.Array, scale: jax.Array) -> jax.Array:
    """fp -> int8 codes. x: [nb, bl, B, S, Hkv, hd]; scale [nb, bl, Hkv, hd]."""
    q = jnp.round(x.astype(jnp.float32) / scale[:, :, None, None, :, :])
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def quantize_cache(
    k: jax.Array, v: jax.Array, max_len: int
) -> QuantizedCache:
    """Compress a prefill fp cache [nb, bl, B, S, Hkv, hd] into codes+scales.

    This is the 'learn constants from the corpus' step of the paper, with
    the prefill cache as the corpus; decode steps reuse the constants.
    """
    k_scale = _absmax_scale(k)
    v_scale = _absmax_scale(v)
    kc = _enc(k, k_scale)
    vc = _enc(v, v_scale)
    pad = max_len - kc.shape[3]
    if pad > 0:
        padw = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        kc = jnp.pad(kc, padw)
        vc = jnp.pad(vc, padw)
    return QuantizedCache(k_codes=kc, v_codes=vc, k_scale=k_scale, v_scale=v_scale)


def quantized_decode_attention(
    q: jax.Array,          # [B, 1, H, hd] fp
    k_codes: jax.Array,    # [B, S, Hkv, hd] int8
    v_codes: jax.Array,
    k_scale: jax.Array,    # [Hkv, hd]
    v_scale: jax.Array,
    cur_len: jax.Array,
    window=A.GLOBAL,
    chunk=A.GLOBAL,
    cap: float | None = None,
):
    """Decode attention over int8 codes; scales fold into q / output."""
    B, _, H, hd = q.shape
    S, Hkv = k_codes.shape[1], k_codes.shape[2]
    g = H // Hkv
    scale = hd ** -0.5

    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, hd)
    q_folded = qf * k_scale[None, :, None, :]              # fold k scale into q
    s = jnp.einsum("bhgd,bkhd->bhgk", q_folded, k_codes.astype(jnp.float32))
    s = L.softcap(s, cap)

    kpos = jnp.arange(S)
    i = (jnp.broadcast_to(jnp.asarray(cur_len), (B,)) - 1)[:, None]
    valid = (kpos[None, :] <= i) & ((i - kpos[None, :]) < window) & (
        (i // chunk) == (kpos[None, :] // chunk)
    )
    s = jnp.where(valid[:, None, None, :], s, A.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_codes.astype(jnp.float32))
    out = out * v_scale[None, :, None, :]                  # fold v scale into output
    return out.reshape(B, 1, H, hd).astype(q.dtype)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def decode_step_q8(
    params, qcache: QuantizedCache, token: jax.Array, cur_len: jax.Array, cfg: LMConfig
):
    """One decode step over the int8 cache (mirror of transformer.decode_step)."""
    from repro.models.transformer import _mask_padded_logits

    B = token.shape[0]
    x = L.embed(params["embed"], token).astype(cfg.jdtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.jdtype)
    win_arr, chk_arr = cfg.layer_locality()      # [n_blocks, block_layers]
    pos2d = jnp.broadcast_to(jnp.asarray(cur_len)[None, None], (B, 1))

    bl = cfg.block_layers

    def sub(x, lp, kc, vc, ks, vs, window, chunk, j):
        a_in = L.rmsnorm(lp["ln1"], x)
        q = L.dense(lp["attn"]["wq"], a_in).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = L.dense(lp["attn"]["wk"], a_in).reshape(B, 1, cfg.n_kv, cfg.head_dim)
        v = L.dense(lp["attn"]["wv"], a_in).reshape(B, 1, cfg.n_kv, cfg.head_dim)
        q = L.rope(q, pos2d, cfg.rope_base)
        k = L.rope(k, pos2d, cfg.rope_base)

        # quantize the incoming token with the cache's constants
        k_new = jnp.clip(
            jnp.round(k.astype(jnp.float32) / ks[None, None]), -128, 127
        ).astype(jnp.int8)
        v_new = jnp.clip(
            jnp.round(v.astype(jnp.float32) / vs[None, None]), -128, 127
        ).astype(jnp.int8)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k_new, cur_len, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v_new, cur_len, axis=1)

        o = quantized_decode_attention(
            q, kc, vc, ks, vs, cur_len + 1,
            window=window, chunk=chunk, cap=cfg.attn_softcap,
        )
        x = x + L.dense(lp["attn"]["wo"], o.reshape(B, 1, cfg.n_heads * cfg.head_dim))
        m_in = L.rmsnorm(lp["ln2"], x)
        if cfg.sub_uses_moe(j):
            mo, _ = M.moe_apply(lp["moe"], m_in, cfg.moe, act=cfg.act)
            x = x + mo
        else:
            x = x + L.glu_mlp(lp["mlp"], m_in, act=cfg.act)
        return x, kc, vc

    def body(x, per_block):
        bp, kc_b, vc_b, ks_b, vs_b, windows, chunks = per_block
        new_k, new_v = [], []
        for j in range(bl):
            x, kc, vc = sub(
                x, bp[f"sub{j}"], kc_b[j], vc_b[j], ks_b[j], vs_b[j],
                windows[j], chunks[j], j,
            )
            new_k.append(kc)
            new_v.append(vc)
        return x, (jnp.stack(new_k), jnp.stack(new_v))

    x, (k_new, v_new) = jax.lax.scan(
        body,
        x,
        (
            params["layers"],
            qcache.k_codes, qcache.v_codes,
            qcache.k_scale, qcache.v_scale,
            win_arr, chk_arr,
        ),
    )
    x = L.rmsnorm(params["final_norm"], x)
    logits = jnp.dot(
        x, params["embed"]["table"].T.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = L.softcap(logits, cfg.final_softcap)
    logits = _mask_padded_logits(logits, cfg)[:, 0]
    new_cache = dataclasses.replace(qcache, k_codes=k_new, v_codes=v_new)
    return logits, new_cache


def cache_memory_bytes(cfg: LMConfig, batch: int, max_len: int, quantized: bool) -> int:
    per = cfg.n_layers * batch * max_len * cfg.n_kv * cfg.head_dim
    if quantized:
        return 2 * per + 2 * cfg.n_layers * cfg.n_kv * cfg.head_dim * 4
    return 2 * per * 2  # bf16
