"""Blockwise (flash-style) attention in pure JAX with a custom VJP.

Why custom_vjp: differentiating nested scans saves every per-step
residual — for attention that is the full O(S^2) score matrix, which is
exactly what blockwise attention exists to avoid.  The custom backward
recomputes score tiles blockwise (FlashAttention-2 structure: one pass
accumulating dQ over KV blocks, one pass accumulating dK/dV over Q
blocks), so training memory is O(S * block) and the residuals are just
(out, lse).

Supports: GQA (kv-head groups), causal masking, sliding-window and
chunked-local masks carried as traced scalars, gemma-2 logit soft-cap
(tanh derivative handled in backward), and fp32 accumulation throughout.

Hardware note: this is the XLA/TPU-native formulation — the MXU consumes
the per-tile einsums; tiles never round-trip to HBM.  On GPU the same
role is played by a fused CUDA kernel; here the fusion is expressed
structurally (scan + tiles) and XLA fuses the elementwise chain.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _mask_tile(qpos, kpos, window, chunk, n_valid_k):
    i = qpos[:, None]
    j = kpos[None, :]
    m = j <= i
    m &= (i - j) < window
    m &= (i // chunk) == (j // chunk)
    m &= (kpos < n_valid_k)[None, :]
    return m


def _softcap_fwd(u, cap):
    if cap is None:
        return u
    return cap * jnp.tanh(u / cap)


def _softcap_grad(u, cap):
    """d softcap(u) / du given the RAW logits u."""
    if cap is None:
        return jnp.ones_like(u)
    t = jnp.tanh(u / cap)
    return 1.0 - t * t


@partial(
    jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8)
)
def flash_attention(
    q: jax.Array,        # [B, Sq, H, hd]
    k: jax.Array,        # [B, Sk, Hkv, hd]
    v: jax.Array,        # [B, Sk, Hkv, hd]
    qpos: jax.Array,     # [Sq] absolute positions
    locality: jax.Array, # [2] (window, chunk) int32 scalars packed
    cap: float | None,
    block_q: int,
    block_kv: int,
    n_valid_k: int,
):
    out, _lse = _flash_fwd_impl(
        q, k, v, qpos, locality, cap, block_q, block_kv, n_valid_k
    )
    return out


def _flash_fwd_impl(q, k, v, qpos, locality, cap, block_q, block_kv, n_valid_k):
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    window, chunk = locality[0], locality[1]
    scale = hd ** -0.5

    nq = Sq // block_q
    nk = Sk // block_kv
    qf = (q.astype(jnp.float32) * scale).reshape(B, nq, block_q, Hkv, g, hd)
    qpos_b = qpos.reshape(nq, block_q)
    kb = k.reshape(B, nk, block_kv, Hkv, hd)
    vb = v.reshape(B, nk, block_kv, Hkv, hd)

    def q_step(_, q_in):
        qi, qp = q_in                                  # [B, bq, Hkv, g, hd], [bq]

        def kv_step(carry, kv_in):
            acc, m, l = carry
            kt, vt, blk = kv_in
            kpos = blk * block_kv + jnp.arange(block_kv)
            s_raw = jnp.einsum("bqhgd,bkhd->bqhgk", qi, kt.astype(jnp.float32))
            s = _softcap_fwd(s_raw, cap)
            mask = _mask_tile(qp, kpos, window, chunk, n_valid_k)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vt.astype(jnp.float32))
            return (acc * corr[..., None] + pv, m_new, l_new), None

        acc0 = jnp.zeros((B, block_q, Hkv, g, hd), jnp.float32)
        m0 = jnp.full((B, block_q, Hkv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, block_q, Hkv, g), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)),
        )
        out_blk = acc / jnp.maximum(l[..., None], 1e-30)
        lse_blk = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out_blk, lse_blk)

    _, (out_b, lse_b) = jax.lax.scan(
        q_step, None, (jnp.moveaxis(qf, 1, 0), qpos_b)
    )
    # out_b: [nq, B, bq, Hkv, g, hd] -> [B, Sq, H, hd]
    out = jnp.moveaxis(out_b, 0, 1).reshape(B, Sq, H, hd).astype(q.dtype)
    lse = jnp.moveaxis(lse_b, 0, 1).reshape(B, Sq, Hkv, g)
    return out, lse


def _flash_vjp_fwd(q, k, v, qpos, locality, cap, block_q, block_kv, n_valid_k):
    out, lse = _flash_fwd_impl(
        q, k, v, qpos, locality, cap, block_q, block_kv, n_valid_k
    )
    return out, (q, k, v, qpos, locality, out, lse)


def _flash_vjp_bwd(cap, block_q, block_kv, n_valid_k, res, dout):
    q, k, v, qpos, locality, out, lse = res
    B, Sq, H, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    window, chunk = locality[0], locality[1]
    scale = hd ** -0.5

    nq = Sq // block_q
    nk = Sk // block_kv

    qf = (q.astype(jnp.float32) * scale).reshape(B, nq, block_q, Hkv, g, hd)
    kb = k.astype(jnp.float32).reshape(B, nk, block_kv, Hkv, hd)
    vb = v.astype(jnp.float32).reshape(B, nk, block_kv, Hkv, hd)
    dout_b = dout.astype(jnp.float32).reshape(B, nq, block_q, Hkv, g, hd)
    out_b = out.astype(jnp.float32).reshape(B, nq, block_q, Hkv, g, hd)
    lse_b = lse.reshape(B, nq, block_q, Hkv, g)
    qpos_b = qpos.reshape(nq, block_q)

    # D = rowsum(dout * out)  [B, nq, bq, Hkv, g]
    delta = jnp.sum(dout_b * out_b, axis=-1)

    def tile(qi, qp, kt, blk):
        """Recompute (p, dsoftcap) for one (q-block, kv-block) tile."""
        kpos = blk * block_kv + jnp.arange(block_kv)
        s_raw = jnp.einsum("bqhgd,bkhd->bqhgk", qi, kt)
        s = _softcap_fwd(s_raw, cap)
        mask = _mask_tile(qp, kpos, window, chunk, n_valid_k)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        return s_raw, s, mask

    # ---- pass 1: dQ (scan q blocks; inner scan kv blocks) -----------------
    def dq_q_step(_, q_in):
        qi, qp, do, lse_i, dl = q_in

        def kv_step(dq_acc, kv_in):
            kt, vt, blk = kv_in
            s_raw, s, mask = tile(qi, qp, kt, blk)
            p = jnp.exp(s - lse_i[..., None])                       # [B,bq,Hkv,g,bk]
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", do, vt)
            ds = p * (dp - dl[..., None])
            ds = ds * _softcap_grad(s_raw, cap)
            ds = jnp.where(mask[None, :, None, None, :], ds, 0.0)
            dq_acc = dq_acc + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kt)
            return dq_acc, None

        dq0 = jnp.zeros((B, block_q, Hkv, g, hd), jnp.float32)
        dq_blk, _ = jax.lax.scan(
            kv_step, dq0,
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)),
        )
        return None, dq_blk * scale

    _, dq_b = jax.lax.scan(
        dq_q_step, None,
        (
            jnp.moveaxis(qf, 1, 0), qpos_b,
            jnp.moveaxis(dout_b, 1, 0),
            jnp.moveaxis(lse_b, 1, 0),
            jnp.moveaxis(delta, 1, 0),
        ),
    )
    dq = jnp.moveaxis(dq_b, 0, 1).reshape(B, Sq, H, hd).astype(q.dtype)

    # ---- pass 2: dK, dV (scan kv blocks; inner scan q blocks) -------------
    def dkv_kv_step(_, kv_in):
        kt, vt, blk = kv_in

        def q_step(carry, q_in):
            dk_acc, dv_acc = carry
            qi, qp, do, lse_i, dl = q_in
            s_raw, s, mask = tile(qi, qp, kt, blk)
            p = jnp.exp(s - lse_i[..., None])
            dv_acc = dv_acc + jnp.einsum("bqhgk,bqhgd->bkhd", p, do)
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", do, vt)
            ds = p * (dp - dl[..., None])
            ds = ds * _softcap_grad(s_raw, cap)
            ds = jnp.where(mask[None, :, None, None, :], ds, 0.0)
            dk_acc = dk_acc + jnp.einsum("bqhgk,bqhgd->bkhd", ds, qi)
            return (dk_acc, dv_acc), None

        zeros = jnp.zeros((B, block_kv, Hkv, hd), jnp.float32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            q_step, (zeros, zeros),
            (
                jnp.moveaxis(qf, 1, 0), qpos_b,
                jnp.moveaxis(dout_b, 1, 0),
                jnp.moveaxis(lse_b, 1, 0),
                jnp.moveaxis(delta, 1, 0),
            ),
        )
        # qf already carries the 1/sqrt(hd) factor, so dk needs no rescale
        return None, (dk_blk, dv_blk)

    _, (dk_b, dv_b) = jax.lax.scan(
        dkv_kv_step, None,
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)),
    )
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(B, Sk, Hkv, hd).astype(k.dtype)
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(B, Sk, Hkv, hd).astype(v.dtype)

    return dq, dk, dv, None, None


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)
