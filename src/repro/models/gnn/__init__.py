from repro.models.gnn import schnet

__all__ = ["schnet"]
