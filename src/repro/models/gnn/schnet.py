"""SchNet (Schütt et al., arXiv:1706.08566) — continuous-filter
convolution GNN, n_interactions=3, d_hidden=64, 300 RBFs, cutoff 10 Å.

Message passing is the JAX-native scatter formulation: gather sender
features along the edge list, modulate with the RBF-generated continuous
filter, and ``jax.ops.segment_sum`` into receivers — JAX has no CSR SpMM,
so the edge-index scatter IS the kernel regime here (kernel_taxonomy §GNN,
triplet/gather family).

Two input regimes (DESIGN.md §5):
  * molecular — atomic numbers [N] + positions [N, 3]; edge lengths are
    real interatomic distances.  The radius graph itself is built with the
    paper's quantized L2 (knn.graph_utils.radius_graph) — that is where
    the LPQ technique applies to this architecture.
  * feature graphs (cora / ogbn-products cells) — no geometry, so edge
    "distances" are L2 gaps in a learned projection of node features;
    the cfconv structure is unchanged.  Documented adaptation.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    max_z: int = 100                  # atomic-number vocabulary
    d_feat: Optional[int] = None      # set for feature-graph regime
    n_classes: Optional[int] = None   # node classification head
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def rbf_expand(dist: jax.Array, n_rbf: int, cutoff: float):
    """Gaussian radial basis: exp(-gamma (d - mu_k)^2), mu on [0, cutoff]."""
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 1.0 / ((cutoff / n_rbf) ** 2)
    return jnp.exp(-gamma * (dist[:, None] - mu[None, :]) ** 2)


def init_params(key, cfg: SchNetConfig):
    keys = jax.random.split(key, 4 + cfg.n_interactions)
    h = cfg.d_hidden
    if cfg.d_feat is None:
        embed = L.embed_init(keys[0], cfg.max_z, h, cfg.jdtype)
    else:
        embed = L.dense_init(keys[0], cfg.d_feat, h, cfg.jdtype)

    def interaction_init(k):
        k1, k2, k3, k4, k5 = jax.random.split(k, 5)
        return {
            "in_proj": L.dense_init(k1, h, h, cfg.jdtype),
            "filter1": {**L.dense_init(k2, cfg.n_rbf, h, cfg.jdtype), "b": jnp.zeros((h,), cfg.jdtype)},
            "filter2": {**L.dense_init(k3, h, h, cfg.jdtype), "b": jnp.zeros((h,), cfg.jdtype)},
            "out1": {**L.dense_init(k4, h, h, cfg.jdtype), "b": jnp.zeros((h,), cfg.jdtype)},
            "out2": {**L.dense_init(k5, h, h, cfg.jdtype), "b": jnp.zeros((h,), cfg.jdtype)},
        }

    inter = jax.vmap(interaction_init)(
        jax.random.split(keys[1], cfg.n_interactions)
    )  # stacked [I, ...]

    head_out = cfg.n_classes if cfg.n_classes else 1
    params = {
        "embed": embed,
        "interactions": inter,
        "head1": {**L.dense_init(keys[2], h, h // 2, cfg.jdtype), "b": jnp.zeros((h // 2,), cfg.jdtype)},
        "head2": {**L.dense_init(keys[3], h // 2, head_out, cfg.jdtype), "b": jnp.zeros((head_out,), cfg.jdtype)},
    }
    if cfg.d_feat is not None:
        params["dist_proj"] = L.dense_init(keys[-1], cfg.d_feat, 8, cfg.jdtype)
    return params


def _affine(p, x):
    return jnp.dot(x, p["w"], preferred_element_type=jnp.float32).astype(x.dtype) + p["b"]


def _interaction(ip, x, w_filter, senders, receivers, edge_mask, n_nodes):
    """One cfconv + atomwise block.  x: [N, h], w_filter: [E, h]."""
    msg_src = L.dense(ip["in_proj"], x)[senders]          # gather [E, h]
    msg = msg_src * w_filter
    msg = jnp.where(edge_mask[:, None], msg, 0.0)
    agg = jax.ops.segment_sum(msg, receivers, num_segments=n_nodes)
    y = _affine(ip["out1"], agg)
    y = L.shifted_softplus(y)
    y = _affine(ip["out2"], y)
    return x + y


@partial(jax.jit, static_argnames=("cfg", "n_nodes"))
def forward(
    params,
    cfg: SchNetConfig,
    senders: jax.Array,
    receivers: jax.Array,
    edge_mask: jax.Array,
    n_nodes: int,
    z: jax.Array | None = None,           # [N] atomic numbers (molecular)
    positions: jax.Array | None = None,   # [N, 3]
    node_feat: jax.Array | None = None,   # [N, F] (feature-graph regime)
):
    """Returns per-node representations' head output [N, n_out]."""
    if cfg.d_feat is None:
        x = L.embed(params["embed"], z)
        dist = jnp.linalg.norm(
            positions[senders] - positions[receivers] + 1e-12, axis=-1
        )
    else:
        x = L.dense(params["embed"], node_feat)
        proj = L.dense(params["dist_proj"], node_feat)    # [N, 8]
        dist = jnp.linalg.norm(proj[senders] - proj[receivers] + 1e-12, axis=-1)

    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff).astype(x.dtype)   # [E, n_rbf]

    def body(x, ip):
        w = _affine(ip["filter1"], rbf)
        w = L.shifted_softplus(w)
        w = _affine(ip["filter2"], w)
        return _interaction(ip, x, w, senders, receivers, edge_mask, n_nodes), None

    x, _ = jax.lax.scan(body, x, params["interactions"])

    y = _affine(params["head1"], x)
    y = L.shifted_softplus(y)
    return _affine(params["head2"], y)                     # [N, n_out]


def energy_loss(params, cfg, graph, graph_ids, n_graphs: int):
    """Molecular regression: sum-pool node outputs per molecule, MSE."""
    out = forward(
        params, cfg,
        senders=graph.senders, receivers=graph.receivers,
        edge_mask=graph.edge_mask, n_nodes=graph.n_nodes,
        z=graph.node_feat, positions=graph.positions,
    )[:, 0]
    energies = jax.ops.segment_sum(out, graph_ids, num_segments=n_graphs)
    return jnp.mean((energies - graph.labels) ** 2)


def node_class_loss(params, cfg, graph):
    """Full-graph node classification: softmax CE over all nodes."""
    logits = forward(
        params, cfg,
        senders=graph.senders, receivers=graph.receivers,
        edge_mask=graph.edge_mask, n_nodes=graph.n_nodes,
        node_feat=graph.node_feat,
    )
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, graph.labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)
