"""Decoder-only LM covering all five assigned transformer architectures
through one scanned layer body.

Architecture features expressed as config data (not code forks):
  * GQA/MQA (n_kv), explicit head_dim (gemma's 256 ≠ d_model / n_heads),
  * GeGLU / SwiGLU MLPs, embedding scaling by sqrt(d_model),
  * attention/final logit soft-capping (gemma-2),
  * per-layer locality pattern: 'g' global, 'l' sliding-window,
    'c' chunked-local (llama4 iRoPE-style) — carried as per-layer int
    scalars through one ``lax.scan``, so the HLO stays one-layer-sized
    regardless of depth (48-layer graphs compile like 1-layer graphs),
  * optional MoE FFN (llama4: 16/128 experts, top-1 + shared), with
    ``moe_every=2`` interleaving dense and MoE layers (llama4-maverick's
    actual 400B layout) via a scan over homogeneous layer *blocks*,
  * vocabulary padding to a shard-friendly multiple (e.g. minicpm's
    122753 -> 122880); padded logit columns are masked to -inf.

Layer params are stacked along a leading [L_blocks, ...] axis; forward is
``lax.scan`` over blocks with ``jax.checkpoint`` on the body (remat).
Attention is flash-style blockwise with a custom VJP (repro.models.flash).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "gelu"                      # geglu -> "gelu", swiglu -> "silu"
    rope_base: float = 10000.0
    layer_pattern: str = "g"               # tiled to n_layers: g/l/c
    window: int = 4096                     # for 'l' layers
    chunk: int = 8192                      # for 'c' layers
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    scale_embed: bool = True
    moe: Optional[M.MoEConfig] = None
    moe_every: int = 1                     # 2 = dense/MoE interleave (maverick)
    dense_d_ff: Optional[int] = None       # dense-layer d_ff in interleave mode
    dtype: str = "bfloat16"
    block_q: int = 1024
    block_kv: int = 1024
    remat: bool = True
    pad_vocab_multiple: int = 256
    # paper integration: store decode KV cache as int8 codes
    quantized_kv: bool = False

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def block_layers(self) -> int:
        """Layers per scan step (1 unless MoE interleaving)."""
        return self.moe_every if self.moe is not None else 1

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.block_layers == 0
        return self.n_layers // self.block_layers

    def sub_uses_moe(self, j: int) -> bool:
        """Does sub-layer j of a block use the MoE FFN?"""
        return self.moe is not None and j == self.block_layers - 1

    def sub_d_ff(self, j: int) -> int:
        if self.moe is not None and not self.sub_uses_moe(j):
            return self.dense_d_ff or self.d_ff
        return self.d_ff

    def layer_locality(self):
        """Per-layer (window, chunk) int32 arrays from the pattern string."""
        pat = (self.layer_pattern * self.n_layers)[: self.n_layers]
        win = [self.window if c == "l" else int(A.GLOBAL) for c in pat]
        chk = [self.chunk if c == "c" else int(A.GLOBAL) for c in pat]
        shape = (self.n_blocks, self.block_layers)
        return (
            jnp.asarray(win, jnp.int32).reshape(shape),
            jnp.asarray(chk, jnp.int32).reshape(shape),
        )

    def param_count(self) -> int:
        d, v = self.d_model, self.vocab
        attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv * self.head_dim * 2
        total = v * d + d
        for i in range(self.n_layers):
            j = i % self.block_layers
            total += attn + 2 * d
            if self.sub_uses_moe(j):
                total += 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
                if self.moe.shared_expert:
                    total += 3 * d * self.moe.d_ff
            else:
                total += 3 * d * self.sub_d_ff(j)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts + shared)."""
        if self.moe is None:
            return self.param_count()
        d, v = self.d_model, self.vocab
        attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv * self.head_dim * 2
        total = v * d + d
        for i in range(self.n_layers):
            j = i % self.block_layers
            total += attn + 2 * d
            if self.sub_uses_moe(j):
                total += 3 * d * self.moe.d_ff * self.moe.top_k + d * self.moe.n_experts
                if self.moe.shared_expert:
                    total += 3 * d * self.moe.d_ff
            else:
                total += 3 * d * self.sub_d_ff(j)
        return total


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _sub_layer_init(key, cfg: LMConfig, j: int):
    ka, km, _k1, _k2 = jax.random.split(key, 4)
    p = {
        "attn": A.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim, cfg.jdtype),
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.jdtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.jdtype),
    }
    if cfg.sub_uses_moe(j):
        p["moe"] = M.moe_init(km, cfg.d_model, cfg.moe, cfg.jdtype)
    else:
        p["mlp"] = L.glu_mlp_init(km, cfg.d_model, cfg.sub_d_ff(j), cfg.jdtype)
    return p


def _block_init(key, cfg: LMConfig):
    keys = jax.random.split(key, cfg.block_layers)
    return {f"sub{j}": _sub_layer_init(keys[j], cfg, j) for j in range(cfg.block_layers)}


def init_params(key, cfg: LMConfig):
    ke, kl, _kf = jax.random.split(key, 3)
    block_keys = jax.random.split(kl, cfg.n_blocks)
    layers = jax.vmap(lambda k: _block_init(k, cfg))(block_keys)
    return {
        "embed": L.embed_init(ke, cfg.padded_vocab, cfg.d_model, cfg.jdtype),
        "layers": layers,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.jdtype),
    }


def abstract_params(cfg: LMConfig):
    """ShapeDtypeStruct pytree (no allocation) — dry-run currency."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _sub_layer_body(cfg: LMConfig, x, lp, window, chunk, qpos, collect_kv, j):
    a_in = L.rmsnorm(lp["ln1"], x)
    a_out, kv = A.attention_block(
        lp["attn"], a_in, qpos,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, head_dim=cfg.head_dim,
        window=window, chunk=chunk, cap=cfg.attn_softcap,
        rope_base=cfg.rope_base, block_q=cfg.block_q, block_kv=cfg.block_kv,
    )
    x = x + a_out
    m_in = L.rmsnorm(lp["ln2"], x)
    if cfg.sub_uses_moe(j):
        m_out, aux = M.moe_apply(lp["moe"], m_in, cfg.moe, act=cfg.act)
    else:
        m_out = L.glu_mlp(lp["mlp"], m_in, act=cfg.act)
        aux = {"lb_loss": jnp.zeros(()), "z_loss": jnp.zeros(()), "drop_frac": jnp.zeros(())}
    x = x + m_out
    return x, (kv if collect_kv else None), aux


def _mask_padded_logits(logits, cfg: LMConfig):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
    return jnp.where(valid, logits, A.NEG_INF)


@partial(jax.jit, static_argnames=("cfg", "collect_kv", "logits_mode"))
def forward(
    params,
    tokens: jax.Array,
    cfg: LMConfig,
    collect_kv: bool = False,
    logits_mode: str = "full",       # full | last (prefill only needs [:, -1])
):
    """tokens [B, S] -> logits [B, S, padded_vocab] (+ caches, aux)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.jdtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.jdtype)
    qpos = jnp.arange(S)
    win_arr, chk_arr = cfg.layer_locality()    # [n_blocks, block_layers]

    def body(x, per_block):
        bp, windows, chunks = per_block
        kvs, auxs = [], []
        for j in range(cfg.block_layers):
            x, kv, aux = _sub_layer_body(
                cfg, x, bp[f"sub{j}"], windows[j], chunks[j], qpos, collect_kv, j
            )
            kvs.append(kv)
            auxs.append(aux)
        aux = jax.tree.map(lambda *a: jnp.mean(jnp.stack(a)), *auxs)
        if collect_kv:
            kv_out = jax.tree.map(lambda *a: jnp.stack(a), *kvs)
        else:
            kv_out = None
        return x, (kv_out, aux)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (kvs, auxs) = jax.lax.scan(body_fn, x, (params["layers"], win_arr, chk_arr))

    if logits_mode == "last":
        x = x[:, -1:]                # avoid the [B, S, vocab] materialization
    x = L.rmsnorm(params["final_norm"], x)
    logits = jnp.dot(
        x, params["embed"]["table"].T.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = L.softcap(logits, cfg.final_softcap)
    logits = _mask_padded_logits(logits, cfg)
    aux = jax.tree.map(jnp.mean, auxs)
    if collect_kv:
        # kvs: (k, v) each [n_blocks, block_layers, B, S, Hkv, hd] — the
        # canonical cache layout (block-major so decode's scan consumes it
        # without reshape copies; see EXPERIMENTS.md §Perf decode iteration)
        return logits, kvs, aux
    return logits, aux


def lm_loss(params, batch, cfg: LMConfig):
    logits, aux = forward(params, batch["tokens"], cfg)
    logits_f = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits_f, axis=-1)
    tgt = jnp.take_along_axis(logits_f, batch["targets"][..., None], axis=-1)[..., 0]
    nll = lse - tgt
    loss = jnp.sum(nll * batch["mask"]) / jnp.maximum(jnp.sum(batch["mask"]), 1.0)
    if cfg.moe is not None:
        loss = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
    return loss, aux


# --------------------------------------------------------------------------
# prefill + decode (serving)
# --------------------------------------------------------------------------

def prefill(params, tokens: jax.Array, cfg: LMConfig):
    """Run the prompt, return (last-position logits, kv caches)."""
    logits, kvs, _ = forward(params, tokens, cfg, collect_kv=True, logits_mode="last")
    return logits[:, -1], kvs  # kvs: (k [L,B,S,Hkv,hd], v [...])


def _decode_sub(cfg, x, lp, kc, vc, window, chunk, pos2d, cur_len, j, B):
    a_in = L.rmsnorm(lp["ln1"], x)
    q = L.dense(lp["attn"]["wq"], a_in).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k = L.dense(lp["attn"]["wk"], a_in).reshape(B, 1, cfg.n_kv, cfg.head_dim)
    v = L.dense(lp["attn"]["wv"], a_in).reshape(B, 1, cfg.n_kv, cfg.head_dim)
    q = L.rope(q, pos2d, cfg.rope_base)
    k = L.rope(k, pos2d, cfg.rope_base)
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), cur_len, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), cur_len, axis=1)
    o = A.decode_attention(
        q, kc, vc, cur_len + 1, window=window, chunk=chunk, cap=cfg.attn_softcap
    )
    x = x + L.dense(lp["attn"]["wo"], o.reshape(B, 1, cfg.n_heads * cfg.head_dim))
    m_in = L.rmsnorm(lp["ln2"], x)
    if cfg.sub_uses_moe(j):
        mo, _ = M.moe_apply(lp["moe"], m_in, cfg.moe, act=cfg.act)
        x = x + mo
    else:
        x = x + L.glu_mlp(lp["mlp"], m_in, act=cfg.act)
    return x, kc, vc


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1,))
def decode_step(params, caches, token: jax.Array, cur_len: jax.Array, cfg: LMConfig):
    """One decode step.

    caches: (k_cache, v_cache) each [L, B, Smax, Hkv, hd] (fp) — for the
    paper-quantized int8 cache path see repro.quantized.qkv_cache.
    token: [B, 1] int32; cur_len: scalar int32 (tokens already in cache).
    """
    B = token.shape[0]
    x = L.embed(params["embed"], token).astype(cfg.jdtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.jdtype)
    win_arr, chk_arr = cfg.layer_locality()
    kb, vb = caches          # block layout [n_blocks, bl, B, Smax, Hkv, hd]
    bl = cfg.block_layers
    pos2d = jnp.broadcast_to(jnp.asarray(cur_len)[None, None], (B, 1))

    # fori_loop with the caches in the CARRY (not scan xs/ys): carried
    # buffers update in place under donation, so the O(L·B·S) cache is
    # never double-buffered — scan's fresh ys allocation was the decode
    # memory hot spot (EXPERIMENTS.md §Perf decode iteration).
    def body(i, state):
        x, kb, vb = state
        bp = jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
            params["layers"],
        )
        for j in range(bl):
            kc = kb[i, j]
            vc = vb[i, j]
            x, kc, vc = _decode_sub(
                cfg, x, bp[f"sub{j}"], kc, vc,
                win_arr[i, j], chk_arr[i, j], pos2d, cur_len, j, B,
            )
            idx = (i, j) + (0,) * kc.ndim
            kb = jax.lax.dynamic_update_slice(kb, kc[None, None], idx)
            vb = jax.lax.dynamic_update_slice(vb, vc[None, None], idx)
        return (x, kb, vb)

    x, k_new, v_new = jax.lax.fori_loop(0, cfg.n_blocks, body, (x, kb, vb))
    x = L.rmsnorm(params["final_norm"], x)
    logits = jnp.dot(
        x, params["embed"]["table"].T.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    logits = L.softcap(logits, cfg.final_softcap)
    logits = _mask_padded_logits(logits, cfg)[:, 0]
    return logits, (k_new, v_new)


def cache_shape(cfg: LMConfig, batch: int, max_len: int) -> tuple:
    """Canonical (block-major) KV cache shape."""
    return (cfg.n_blocks, cfg.block_layers, batch, max_len, cfg.n_kv, cfg.head_dim)


def make_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """Empty fp KV cache [n_blocks, block_layers, B, Smax, Hkv, hd] x2."""
    dtype = dtype or cfg.jdtype
    shape = cache_shape(cfg, batch, max_len)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def write_prefix(cache: jax.Array, prefix: jax.Array, start: int = 0) -> jax.Array:
    """Write prefill kv (same layout, shorter S at axis 3) into a cache."""
    return jax.lax.dynamic_update_slice_in_dim(
        cache, prefix.astype(cache.dtype), start, axis=3
    )
