"""Attention: GQA/MQA with RoPE, logit soft-cap, and three sparsity
patterns (global causal, sliding window, chunked-local) expressed as
*data* (per-layer window/chunk scalars), so a single scanned layer body
serves gemma-2b (MQA global), gemma2-9b (alternating local/global +
soft-cap), minicpm (GQA global) and llama4 (chunked local, iRoPE-style).

Prefill/train uses a blockwise online-softmax over KV blocks
(``lax.scan``), which keeps the live intermediate at O(S * block_kv) per
head instead of O(S^2) — the difference between 32k-context cells fitting
in 16 GB HBM or not.  Decode attends a 1-token query against the cache
(optionally the paper-quantized int8 cache — see repro.quantized.qkv_cache).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L

NEG_INF = -2.0e38
# sentinel meaning "no locality constraint" for window/chunk scalars
GLOBAL = jnp.int32(2**30)


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(kq, d_model, n_heads * head_dim, dtype),
        "wk": L.dense_init(kk, d_model, n_kv * head_dim, dtype),
        "wv": L.dense_init(kv, d_model, n_kv * head_dim, dtype),
        "wo": L.dense_init(ko, n_heads * head_dim, d_model, dtype),
    }


def _mask(qpos, kpos, window, chunk):
    """Causal + locality mask from position vectors (broadcasts [Sq, Sk])."""
    i = qpos[:, None]
    j = kpos[None, :]
    m = j <= i                                  # causal
    m &= (i - j) < window                       # sliding window
    m &= (i // chunk) == (j // chunk)           # chunked-local (llama4)
    return m


@partial(jax.jit, static_argnames=("cap", "block_q", "block_kv", "n_heads"))
def blockwise_attention(
    q: jax.Array,        # [B, Sq, H, hd]
    k: jax.Array,        # [B, Sk, Hkv, hd]
    v: jax.Array,        # [B, Sk, Hkv, hd]
    qpos: jax.Array,     # [Sq] absolute positions
    window=GLOBAL,       # per-layer scalar (GLOBAL disables)
    chunk=GLOBAL,
    cap: float | None = None,
    block_q: int = 1024,
    block_kv: int = 1024,
    n_heads: int | None = None,
):
    """Flash-style attention: q- and kv-blocked online softmax with a
    custom VJP (repro.models.flash) so neither forward nor backward ever
    materializes more than one [B, block_q, H, block_kv] score tile."""
    from repro.models import flash as F

    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bkv = min(block_kv, Sk)

    n_valid_k = Sk
    if Sk % bkv:
        pad = bkv - Sk % bkv
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_valid_q = Sq
    if Sq % bq:
        padq = bq - Sq % bq
        q = jnp.pad(q, ((0, 0), (0, padq), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, (0, padq))
    locality = jnp.stack([jnp.asarray(window, jnp.int32),
                          jnp.asarray(chunk, jnp.int32)])
    out = F.flash_attention(
        q, k, v, qpos, locality, cap, bq, bkv, n_valid_k
    )
    return out[:, :n_valid_q]


def decode_attention(
    q: jax.Array,        # [B, 1, H, hd]
    k_cache: jax.Array,  # [B, S, Hkv, hd]
    v_cache: jax.Array,  # [B, S, Hkv, hd]
    cur_len: jax.Array,  # [B] or scalar — valid cache length
    window=GLOBAL,
    chunk=GLOBAL,
    cap: float | None = None,
):
    """Single-token decode against a (possibly quantized) KV cache."""
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    Hkv = k_cache.shape[2]
    g = H // Hkv
    scale = hd ** -0.5

    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    s = L.softcap(s, cap)

    kpos = jnp.arange(S)
    qpos = jnp.asarray(cur_len) - 1          # attend up to current position
    qpos = jnp.broadcast_to(qpos, (B,))
    i = qpos[:, None]
    valid = (kpos[None, :] <= i) & ((i - kpos[None, :]) < window) & (
        (i // chunk) == (kpos[None, :] // chunk)
    )
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attention_block(
    params,
    x: jax.Array,          # [B, S, d_model]
    qpos: jax.Array,       # [S]
    n_heads: int,
    n_kv: int,
    head_dim: int,
    window=GLOBAL,
    chunk=GLOBAL,
    cap: float | None = None,
    rope_base: float = 10000.0,
    block_q: int = 1024,
    block_kv: int = 1024,
):
    """Full train/prefill attention block (projections + blockwise attn)."""
    B, S, _ = x.shape
    q = L.dense(params["wq"], x).reshape(B, S, n_heads, head_dim)
    k = L.dense(params["wk"], x).reshape(B, S, n_kv, head_dim)
    v = L.dense(params["wv"], x).reshape(B, S, n_kv, head_dim)
    pos2d = jnp.broadcast_to(qpos[None, :], (B, S))
    q = L.rope(q, pos2d, rope_base)
    k = L.rope(k, pos2d, rope_base)
    o = blockwise_attention(
        q, k, v, qpos, window=window, chunk=chunk, cap=cap,
        block_q=block_q, block_kv=block_kv,
    )
    return L.dense(params["wo"], o.reshape(B, S, n_heads * head_dim)), (k, v)
