# Model zoo: the 10 assigned architectures over three substrates —
# decoder LM transformers (dense + MoE), SchNet GNN, and the recsys
# family over the EmbeddingBag substrate.
from repro.models import attention, layers, moe, transformer
from repro.models.transformer import LMConfig
from repro.models.gnn.schnet import SchNetConfig
from repro.models.recsys.models import RecsysConfig

__all__ = [
    "attention",
    "layers",
    "moe",
    "transformer",
    "LMConfig",
    "SchNetConfig",
    "RecsysConfig",
]
