"""Mixture-of-Experts FFN (llama4-style: top-1 routed experts + shared
expert) with static-shape capacity dispatch.

Dispatch is gather-based, not one-hot-matmul: tokens are ranked within
their expert by a cumsum over the [T, E] assignment one-hot, dropped past
capacity C = ceil(T * cf / E), and gathered into [E, C, d] for batched
per-expert GEMMs — O(T·E) dispatch bookkeeping instead of the O(T·E·C)
dense dispatch tensor.  All shapes static (pjit-friendly); EP shards the
leading E axis of the expert weights over the ``model`` mesh axis.

Router order preservation under the paper's quantization: router logits
are inner products x·W_r, so Definition 2 applies — int8-quantized
activations preserve top-1 expert choice up to equality relaxation
(validated in tests/test_moe.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 1
    d_ff: int = 8192
    capacity_factor: float = 1.25
    shared_expert: bool = True


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E, F = cfg.n_experts, cfg.d_ff
    s = 1.0 / (d_model ** 0.5)
    p = {
        "router": L.dense_init(kr, d_model, E, jnp.float32),
        "gate_w": jax.random.normal(kg, (E, d_model, F), dtype) * s,
        "up_w": jax.random.normal(ku, (E, d_model, F), dtype) * s,
        "down_w": jax.random.normal(kd, (E, F, d_model), dtype) * (1.0 / (F ** 0.5)),
    }
    if cfg.shared_expert:
        p["shared"] = L.glu_mlp_init(ks, d_model, F, dtype)
    return p


def _ambient_axes():
    """Non-'model' axes of the mesh this trace is running under (if any)."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or not m.axis_names:
            return None
        return tuple(a for a in m.axis_names if a != "model")
    except Exception:  # noqa: BLE001 — no ambient mesh: skip constraints
        return None


def _constrain(x, spec):
    try:
        from jax.sharding import PartitionSpec

        return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))
    except Exception:  # noqa: BLE001 — unpartitionable here: leave as-is
        return x


@partial(jax.jit, static_argnames=("cfg", "act"))
def moe_apply(params, x: jax.Array, cfg: MoEConfig, act: str = "silu"):
    """x: [B, S, d] -> ([B, S, d], aux_metrics)."""
    B, S, d = x.shape
    T = B * S
    E = cfg.n_experts
    C = max(8, int(-(-T * cfg.capacity_factor // E)))  # ceil, min 8

    xt = x.reshape(T, d)
    # keep token-major arrays batch-sharded and expert-major arrays
    # expert-sharded through the dispatch — GSPMD otherwise replicates the
    # [T, d] scatter buffers (measured: 39 GB -> ~8 GB on maverick train)
    token_axes = _ambient_axes()
    if token_axes:
        xt = _constrain(xt, (token_axes, None))
    logits = jnp.dot(
        xt.astype(jnp.float32), params["router"]["w"], preferred_element_type=jnp.float32
    )                                                   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    assign = jnp.argmax(logits, axis=-1)                # top-1
    gate = jnp.take_along_axis(probs, assign[:, None], axis=-1)[:, 0]

    # rank within expert + capacity drop
    onehot = jax.nn.one_hot(assign, E, dtype=jnp.int32)            # [T, E]
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0), assign[:, None], 1)[:, 0] - 1
    keep = pos < C

    # [E, C] token index table; sentinel T points at an appended zero row
    idx = jnp.full((E, C), T, jnp.int32)
    idx = idx.at[
        jnp.where(keep, assign, E - 1),
        jnp.where(keep, pos, C - 1),
    ].set(jnp.where(keep, jnp.arange(T, dtype=jnp.int32), T), mode="drop")

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xpad[idx]                                       # [E, C, d]
    if token_axes:
        xe = _constrain(xe, ("model", None, None))       # expert-parallel

    h = jnp.einsum("ecd,edf->ecf", xe, params["gate_w"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) if act == "silu" else jax.nn.gelu(h)).astype(xe.dtype)
    u = jnp.einsum("ecd,edf->ecf", xe, params["up_w"],
                   preferred_element_type=jnp.float32).astype(xe.dtype)
    y = jnp.einsum("ecf,efd->ecd", h * u, params["down_w"],
                   preferred_element_type=jnp.float32).astype(xe.dtype)

    # combine: scatter expert outputs back to token order (top-1: each token
    # written at most once) then apply the router gate
    out = jnp.zeros((T + 1, d), y.dtype).at[idx.reshape(-1)].add(
        y.reshape(E * C, d), mode="drop"
    )[:T]
    if token_axes:
        out = _constrain(out, (token_axes, None))
    out = out * gate[:, None].astype(out.dtype)

    if "shared" in params:
        out = out + L.glu_mlp(params["shared"], xt, act=act)

    # aux: load-balance loss (Switch) + router z-loss
    me = jnp.mean(jax.nn.one_hot(assign, E, dtype=jnp.float32), axis=0)
    ce = jnp.mean(probs, axis=0)
    aux = {
        "lb_loss": E * jnp.sum(me * ce),
        "z_loss": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(B, S, d), aux
