"""Shared model layers — functional (params-as-pytrees) style so every
model jits, shards, and scans cleanly under pjit.

Initializers take explicit keys; all matmuls carry ``preferred_element_type``
so mixed-precision policies stay predictable under bf16 params.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32, scale: float | None = None):
    if scale is None:
        scale = 1.0 / (in_dim ** 0.5)
    return {"w": jax.random.normal(key, (in_dim, out_dim), dtype) * scale}


def dense(params, x):
    return jnp.dot(x, params["w"], preferred_element_type=jnp.float32).astype(x.dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, dim), dtype) * 0.02}


def embed(params, ids):
    return jnp.take(params["table"], ids, axis=0)


def rmsnorm_init(dim: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# -- gated MLPs -------------------------------------------------------------

def glu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def glu_mlp(params, x, act: str = "gelu"):
    g = dense(params["gate"], x)
    g = jax.nn.gelu(g) if act == "gelu" else jax.nn.silu(g)
    return dense(params["down"], g * dense(params["up"], x))


def mlp_init(key, dims: list[int], dtype=jnp.float32):
    """Plain MLP stack (recsys towers): dims = [in, h1, ..., out]."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        f"l{i}": {
            **dense_init(keys[i], dims[i], dims[i + 1], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        }
        for i in range(len(dims) - 1)
    }


def mlp(params, x, act=jax.nn.relu, final_act: bool = False):
    n = len(params)
    for i in range(n):
        p = params[f"l{i}"]
        x = jnp.dot(x, p["w"], preferred_element_type=jnp.float32).astype(x.dtype) + p["b"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


# -- rotary position embeddings ---------------------------------------------

def rope(x: jax.Array, positions: jax.Array, base: float = 10000.0):
    """Apply RoPE. x: [B, S, H, hd], positions: [B, S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def softcap(x: jax.Array, cap: float | None):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def shifted_softplus(x):
    """SchNet's ssp activation: ln(0.5 e^x + 0.5)."""
    return jax.nn.softplus(x) - jnp.log(2.0)
