"""Retrieval-candidate scoring — the `retrieval_cand` cell and the most
direct instantiation of the paper inside the recsys family.

One query embedding scored against 10^6 candidate item embeddings is
exactly the paper's MIP search problem.  The candidate table is stored as
int8 codes (QuantizedTable), the query is quantized with h(q) of
Definition 2, and scoring runs through the qmip Pallas kernel — a batched
MXU matmul, NOT a loop.  fp32 scoring is kept as the baseline arm.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import quant as Qz
from repro.kernels import ops as K


@partial(jax.jit, static_argnames=("k",))
def retrieve_fp32(query_emb: jax.Array, cand_table: jax.Array, k: int = 100):
    """Baseline: [Q, d] x [N, d] fp32 -> top-k (scores, ids)."""
    s = jnp.dot(query_emb, cand_table.T, preferred_element_type=jnp.float32)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i.astype(jnp.int32)


@partial(jax.jit, static_argnames=("k", "use_pallas"))
def retrieve_quantized(
    query_emb: jax.Array,
    cand_codes: jax.Array,
    params: Qz.QuantParams,
    k: int = 100,
    use_pallas: bool = True,
):
    """Paper path: quantize h(q), int8 MIP via qmip kernel, top-k."""
    q_codes = K.quantize(query_emb, params.lo, params.hi, params.zero, bits=params.bits)
    s = K.qmip(q_codes, cand_codes, use_pallas=use_pallas).astype(jnp.float32)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i.astype(jnp.int32)
