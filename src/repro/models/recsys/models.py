"""The four assigned recsys architectures assembled over the shared
embedding + interaction substrate, each exposing loss() for training,
serve() for online/bulk scoring, and (via retrieval.py) the 1M-candidate
MIP scoring step that is literally the paper's search problem.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.recsys import embedding as E
from repro.models.recsys import interactions as I


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                          # autoint | dlrm | dien | dcnv2
    n_dense: int
    vocab_sizes: tuple[int, ...]
    embed_dim: int
    # autoint
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32
    # dlrm
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    # dien
    seq_len: int = 0
    gru_dim: int = 0
    mlp: tuple[int, ...] = ()
    # dcn-v2
    n_cross_layers: int = 0
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    def param_count(self) -> int:
        counts = sum(v * self.embed_dim for v in self.vocab_sizes)
        return counts  # tables dominate; MLPs counted at init if needed


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg: RecsysConfig):
    kt, km = jax.random.split(key)
    p = {"tables": E.multi_table_init(kt, cfg.vocab_sizes, cfg.embed_dim, cfg.jdtype)}
    d = cfg.embed_dim

    if cfg.kind == "autoint":
        keys = jax.random.split(km, cfg.n_attn_layers + 1)
        dims = [d] + [cfg.n_heads * cfg.d_attn] * cfg.n_attn_layers
        p["attn"] = {
            f"a{i}": I.autoint_layer_init(keys[i], dims[i], cfg.n_heads, cfg.d_attn, cfg.jdtype)
            for i in range(cfg.n_attn_layers)
        }
        p["out"] = L.mlp_init(keys[-1], [dims[-1] * cfg.n_sparse, 1], cfg.jdtype)

    elif cfg.kind == "dlrm":
        k1, k2 = jax.random.split(km)
        p["bot"] = L.mlp_init(k1, [cfg.n_dense, *cfg.bot_mlp], cfg.jdtype)
        n_feats = cfg.n_sparse + 1                       # sparse + bottom output
        d_inter = n_feats * (n_feats - 1) // 2
        p["top"] = L.mlp_init(k2, [d_inter + cfg.bot_mlp[-1], *cfg.top_mlp], cfg.jdtype)

    elif cfg.kind == "dien":
        k1, k2, k3, k4 = jax.random.split(km, 4)
        p["gru"] = I.gru_init(k1, d, cfg.gru_dim, cfg.jdtype)
        p["augru"] = I.gru_init(k2, cfg.gru_dim, cfg.gru_dim, cfg.jdtype)
        p["att"] = L.mlp_init(k3, [cfg.gru_dim + d, 36, 1], cfg.jdtype)
        # final MLP over [target_embed, final_interest, other fields]
        d_in = d * cfg.n_sparse + cfg.gru_dim
        p["out"] = L.mlp_init(k4, [d_in, *cfg.mlp, 1], cfg.jdtype)

    elif cfg.kind == "dcnv2":
        k1, k2, k3 = jax.random.split(km, 3)
        d_in = cfg.n_dense + cfg.n_sparse * d
        p["cross"] = I.cross_init(k1, d_in, cfg.n_cross_layers, cfg.jdtype)
        p["deep"] = L.mlp_init(k2, [d_in, *cfg.mlp], cfg.jdtype)
        p["out"] = L.mlp_init(k3, [d_in + cfg.mlp[-1], 1], cfg.jdtype)
    else:
        raise ValueError(cfg.kind)
    return p


def abstract_params(cfg: RecsysConfig):
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------
# forward per kind
# --------------------------------------------------------------------------

def _forward_autoint(params, cfg, batch):
    x = E.multi_lookup(params["tables"], batch["sparse"])       # [B, F, d]
    for i in range(cfg.n_attn_layers):
        x = I.autoint_layer(params["attn"][f"a{i}"], x, cfg.n_heads)
    return L.mlp(params["out"], x.reshape(x.shape[0], -1))[:, 0]


def _forward_dlrm(params, cfg, batch):
    dense_v = L.mlp(params["bot"], batch["dense"], final_act=True)  # [B, d]
    sparse_v = E.multi_lookup(params["tables"], batch["sparse"])    # [B, F, d]
    feats = jnp.concatenate([dense_v[:, None, :], sparse_v], axis=1)
    inter = I.dot_interaction(feats)                                # [B, .]
    top_in = jnp.concatenate([dense_v, inter], axis=-1)
    return L.mlp(params["top"], top_in)[:, 0]


def _forward_dien(params, cfg, batch):
    target = E.lookup(params["tables"]["t0"], batch["sparse"][:, 0])   # [B, d]
    others = E.multi_lookup(params["tables"], batch["sparse"])          # [B, F, d]
    hist = E.lookup(params["tables"]["t0"], batch["hist_ids"])          # [B, T, d]
    mask = batch["hist_mask"]

    states = I.gru_scan(params["gru"], hist, mask)                      # [B, T, g]
    # attention of target on interest states
    tgt = jnp.broadcast_to(target[:, None, :], hist.shape)
    att_in = jnp.concatenate([states, tgt], axis=-1)
    scores = L.mlp(params["att"], att_in)[..., 0]
    scores = jnp.where(mask > 0, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    final = I.augru_scan(params["augru"], states, att, mask)            # [B, g]

    flat = jnp.concatenate([others.reshape(others.shape[0], -1), final], axis=-1)
    return L.mlp(params["out"], flat)[:, 0]


def _forward_dcnv2(params, cfg, batch):
    sparse_v = E.multi_lookup(params["tables"], batch["sparse"])
    x0 = jnp.concatenate(
        [batch["dense"], sparse_v.reshape(sparse_v.shape[0], -1)], axis=-1
    )
    xc = I.cross_apply(params["cross"], x0)
    xd = L.mlp(params["deep"], x0, final_act=True)
    return L.mlp(params["out"], jnp.concatenate([xc, xd], axis=-1))[:, 0]


_FWD = {
    "autoint": _forward_autoint,
    "dlrm": _forward_dlrm,
    "dien": _forward_dien,
    "dcnv2": _forward_dcnv2,
}


@partial(jax.jit, static_argnames=("cfg",))
def forward(params, batch, cfg: RecsysConfig) -> jax.Array:
    """CTR logit [B]."""
    return _FWD[cfg.kind](params, cfg, batch)


def bce_loss(params, batch, cfg: RecsysConfig):
    logit = forward(params, batch, cfg)
    y = batch["label"]
    loss = jnp.mean(
        jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )
    return loss, {"logit_mean": jnp.mean(logit)}


@partial(jax.jit, static_argnames=("cfg",))
def serve(params, batch, cfg: RecsysConfig) -> jax.Array:
    """Online scoring: sigmoid CTR probability [B]."""
    return jax.nn.sigmoid(forward(params, batch, cfg))
