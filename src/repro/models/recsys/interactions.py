"""Feature-interaction operators: dot (DLRM), cross-net v2 (DCN-v2),
field self-attention (AutoInt), and GRU/AUGRU (DIEN).

Under the paper's quantization all of these reduce to inner products over
(possibly int8) embeddings, which is why Definition-2 order preservation
carries CTR model quality (validated in tests/test_recsys.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


# -- DLRM dot interaction ---------------------------------------------------

def dot_interaction(feats: jax.Array, keep_diag: bool = False) -> jax.Array:
    """feats [B, F, d] -> upper-triangle pairwise dots [B, F*(F-1)/2]."""
    B, F, _ = feats.shape
    gram = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(F, k=0 if keep_diag else 1)
    return gram[:, iu, ju]


# -- DCN-v2 cross network ---------------------------------------------------

def cross_init(key, dim: int, n_layers: int, dtype=jnp.float32):
    keys = jax.random.split(key, n_layers)
    return {
        f"c{i}": {
            "w": jax.random.normal(keys[i], (dim, dim), dtype) * (dim ** -0.5),
            "b": jnp.zeros((dim,), dtype),
        }
        for i in range(n_layers)
    }


def cross_apply(params, x0: jax.Array) -> jax.Array:
    """x_{l+1} = x0 * (W x_l + b) + x_l   (full-rank DCN-v2)."""
    x = x0
    for i in range(len(params)):
        p = params[f"c{i}"]
        x = x0 * (jnp.dot(x, p["w"], preferred_element_type=jnp.float32).astype(x.dtype) + p["b"]) + x
    return x


# -- AutoInt field self-attention -------------------------------------------

def autoint_layer_init(key, d_in: int, n_heads: int, d_head: int, dtype=jnp.float32):
    kq, kk, kv, kr = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(kq, d_in, n_heads * d_head, dtype),
        "wk": L.dense_init(kk, d_in, n_heads * d_head, dtype),
        "wv": L.dense_init(kv, d_in, n_heads * d_head, dtype),
        "wres": L.dense_init(kr, d_in, n_heads * d_head, dtype),
    }


def autoint_layer(params, x: jax.Array, n_heads: int) -> jax.Array:
    """Interacting layer: softmax self-attn over the field axis.
    x: [B, F, d_in] -> [B, F, n_heads * d_head], ReLU(residual + attn)."""
    B, F, _ = x.shape
    q = L.dense(params["wq"], x).reshape(B, F, n_heads, -1)
    k = L.dense(params["wk"], x).reshape(B, F, n_heads, -1)
    v = L.dense(params["wv"], x).reshape(B, F, n_heads, -1)
    s = jnp.einsum("bfhd,bghd->bhfg", q, k)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhfg,bghd->bfhd", p, v).reshape(B, F, -1)
    return jax.nn.relu(o + L.dense(params["wres"], x))


# -- GRU + AUGRU (DIEN) -----------------------------------------------------

def gru_init(key, d_in: int, d_hidden: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    s = (d_in + d_hidden) ** -0.5
    return {
        "wx": jax.random.normal(k1, (d_in, 3 * d_hidden), dtype) * s,
        "wh": jax.random.normal(k2, (d_hidden, 3 * d_hidden), dtype) * s,
        "b": jnp.zeros((3 * d_hidden,), dtype),
    }


def _gru_cell(p, h, x, att=None):
    """Standard GRU cell: h~ = tanh(Wx x + r * (Wh h)); AUGRU gates z by att."""
    xg = jnp.dot(x, p["wx"])
    hg = jnp.dot(h, p["wh"])
    xz, xr, xh = jnp.split(xg, 3, axis=-1)
    hz, hr2, hh2 = jnp.split(hg, 3, axis=-1)
    bz, br, bh = jnp.split(p["b"], 3)
    z = jax.nn.sigmoid(xz + hz + bz)
    r = jax.nn.sigmoid(xr + hr2 + br)
    hh = jnp.tanh(xh + r * hh2 + bh)
    if att is not None:
        z = z * att[:, None]          # AUGRU: attention scales the update gate
    return (1.0 - z) * h + z * hh


def gru_scan(p, xs: jax.Array, mask: jax.Array):
    """xs [B, T, d_in], mask [B, T] -> hidden states [B, T, d_hidden]."""
    B = xs.shape[0]
    d_hidden = p["wh"].shape[0]
    h0 = jnp.zeros((B, d_hidden), xs.dtype)

    def step(h, inp):
        x, m = inp
        h_new = _gru_cell(p, h, x)
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, h

    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(mask, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def augru_scan(p, xs: jax.Array, att: jax.Array, mask: jax.Array):
    """Interest-evolution pass: attention-gated GRU. Returns final state [B, d]."""
    B = xs.shape[0]
    d_hidden = p["wh"].shape[0]
    h0 = jnp.zeros((B, d_hidden), xs.dtype)

    def step(h, inp):
        x, a, m = inp
        h_new = _gru_cell(p, h, x, att=a)
        h = jnp.where(m[:, None] > 0, h_new, h)
        return h, None

    h, _ = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(att, 1, 0), jnp.moveaxis(mask, 1, 0)),
    )
    return h
