"""Sparse embedding substrate for the recsys family.

JAX has no native EmbeddingBag and no CSR sparse — per the assignment this
layer IS part of the system: lookups are ``jnp.take`` gathers; ragged bags
reduce with ``jax.ops.segment_sum``.  Tables row-shard over the ``model``
mesh axis (DLRM hybrid parallelism) — see repro.dist.sharding.

The paper's technique lands here as :class:`QuantizedTable`: int8 codes +
per-dim Eq. 1 constants.  At 10^8-row MLPerf scale the table is the
memory; int8 cuts table HBM 4x vs fp32 (the paper's ~60%+ claim at
datacenter scale), and retrieval scoring against int8 candidate tables
runs on the MXU int8 path via kernels.qmip.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import quant as Qz


def table_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, dim), dtype) * (dim ** -0.5)}


def multi_table_init(key, vocab_sizes: Sequence[int], dim: int, dtype=jnp.float32):
    keys = jax.random.split(key, len(vocab_sizes))
    return {f"t{i}": table_init(keys[i], v, dim, dtype) for i, v in enumerate(vocab_sizes)}


def lookup(table_params, ids: jax.Array) -> jax.Array:
    """Gather: ids [...] -> [..., dim].

    Dispatches on table format: dense {'table': f32 [V, d]} or the
    paper-quantized {'codes': int8 [V, d], 'scale': [d], 'zero': [d]} —
    the int8 gather moves 4x fewer bytes through HBM *and* across the
    mesh (rows are exchanged as codes, dequantized after the collective).
    """
    if "codes" in table_params:
        rows = jnp.take(table_params["codes"], ids, axis=0)
        return rows.astype(jnp.float32) * table_params["scale"] + table_params["zero"]
    return jnp.take(table_params["table"], ids, axis=0)


def multi_lookup(tables, sparse_ids: jax.Array) -> jax.Array:
    """sparse_ids [B, F] over F per-field tables -> [B, F, dim]."""
    cols = [lookup(tables[f"t{f}"], sparse_ids[:, f]) for f in range(sparse_ids.shape[1])]
    return jnp.stack(cols, axis=1)


def quantize_tables(tables, bits: int = 8):
    """Convert every dense per-field table to the int8 format in place
    (paper Eq. 1, abs-max constants) — the serving-time compression step."""
    out = {}
    for name, tp in tables.items():
        table = tp["table"]
        p = Qz.learn_params(table, bits=bits, scheme=Qz.Scheme.ABSMAX)
        out[name] = {
            "codes": Qz.quantize(table, p),
            "scale": p.scale.astype(jnp.float32),
            "zero": p.zero.astype(jnp.float32),
        }
    return out


def embedding_bag(
    table_params,
    flat_ids: jax.Array,       # [T] gathered ids of all bags
    segment_ids: jax.Array,    # [T] bag index per id
    n_bags: int,
    weights: Optional[jax.Array] = None,
    combiner: str = "sum",
) -> jax.Array:
    """Ragged EmbeddingBag: gather + segment-reduce. Returns [n_bags, dim]."""
    rows = jnp.take(table_params["table"], flat_ids, axis=0)   # [T, dim]
    if weights is not None:
        rows = rows * weights[:, None]
    summed = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if combiner == "sum":
        return summed
    counts = jax.ops.segment_sum(
        jnp.ones_like(flat_ids, dtype=rows.dtype), segment_ids, num_segments=n_bags
    )
    if combiner == "mean":
        return summed / jnp.maximum(counts[:, None], 1.0)
    raise ValueError(combiner)


# --------------------------------------------------------------------------
# Quantized tables — the paper applied to embedding storage
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantizedTable:
    codes: jax.Array                  # [vocab, dim] int8
    params: Qz.QuantParams

    @staticmethod
    def from_dense(table: jax.Array, bits: int = 8,
                   scheme=Qz.Scheme.ABSMAX, sigmas: float = 1.0) -> "QuantizedTable":
        p = Qz.learn_params(table, bits=bits, scheme=scheme, sigmas=sigmas)
        return QuantizedTable(codes=Qz.quantize(table, p), params=p)

    def lookup(self, ids: jax.Array) -> jax.Array:
        """Dequantizing gather: int8 rows -> f32 embeddings."""
        rows = jnp.take(self.codes, ids, axis=0)
        return Qz.dequantize(rows, self.params)

    def lookup_codes(self, ids: jax.Array) -> jax.Array:
        """Integer-domain gather (for quantized scoring paths)."""
        return jnp.take(self.codes, ids, axis=0)

    def memory_bytes(self) -> int:
        return int(self.codes.size) + 3 * int(self.codes.shape[1]) * 4
