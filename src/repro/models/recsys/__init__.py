from repro.models.recsys import embedding, interactions, models, retrieval
from repro.models.recsys.models import RecsysConfig

__all__ = ["embedding", "interactions", "models", "retrieval", "RecsysConfig"]
