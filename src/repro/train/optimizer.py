"""Optimizers + LR schedules, dependency-free (no optax in this image).

AdamW with decoupled weight decay and global-norm clipping; schedules:
linear-warmup cosine, constant, and WSD (warmup-stable-decay — the
minicpm-2b schedule, arXiv:2404.06395).

State is a params-shaped pytree, so it shards exactly like params under
pjit (ZeRO-style optimizer sharding falls out of NamedSharding on the
same axes).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | const
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1         # WSD: final fraction of steps that decay
    min_lr_ratio: float = 0.1


def lr_at(step: jax.Array, cfg: OptConfig) -> jax.Array:
    """Schedule value at ``step`` (traced-friendly)."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "const":
        return cfg.lr * warm
    if cfg.schedule == "cosine":
        t = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
    if cfg.schedule == "wsd":
        decay_steps = int(cfg.total_steps * cfg.decay_frac)
        stable_end = cfg.total_steps - decay_steps
        t = jnp.clip((step - stable_end) / max(decay_steps, 1), 0.0, 1.0)
        decay = 1.0 - (1.0 - cfg.min_lr_ratio) * t
        return cfg.lr * warm * jnp.where(step < stable_end, 1.0, decay)
    raise ValueError(cfg.schedule)


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {
        "mu": zeros,
        "nu": jax.tree.map(jnp.zeros_like, zeros),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


@partial(jax.jit, static_argnames=("cfg",))
def adamw_update(grads, state, params, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state["mu"], grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g, state["nu"], grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = lr_at(step, cfg)

    def upd(p, m, n):
        update = (m / bc1) / (jnp.sqrt(n / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gn, "lr": lr}
