"""Atomic, digest-verified checkpointing — the fault-tolerance substrate.

Layout: ``<dir>/step_<N>/`` containing ``arrays.npz`` (flattened pytree
leaves) + ``meta.msgpack`` (treedef paths, shapes, dtypes, step, user
metadata, content digest).  Writes go to ``<dir>/.tmp_step_<N>`` and are
``os.rename``d into place — a crashed writer can never leave a
half-checkpoint that restore would read (rename is atomic on POSIX).

``restore_latest`` walks checkpoints newest-first and skips any that fail
digest verification, so a corrupted latest step falls back to the
previous one instead of killing the job — the restart-after-preemption
path at cluster scale.
"""

from __future__ import annotations

import hashlib
import os
import shutil
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _digest(arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes())
    return h.hexdigest()


def save(directory: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    """Atomically write a checkpoint; returns its final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = os.path.join(directory, f".tmp_step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "paths": paths,
        "digest": _digest(arrays),
        "user": metadata or {},
    }
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _load_one(path: str, tree_template: Any):
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    if _digest(arrays) != meta["digest"]:
        raise IOError(f"digest mismatch in {path}")
    leaves = [arrays[f"a{i}"] for i in range(len(arrays))]
    treedef = jax.tree_util.tree_structure(tree_template)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    return restored, meta


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def restore_latest(directory: str, tree_template: Any):
    """(tree, meta) from the newest verifiable checkpoint, or (None, None).

    Corrupt checkpoints are skipped (with a warning) — restart resilience.
    """
    for step in reversed(list_steps(directory)):
        path = os.path.join(directory, f"step_{step:010d}")
        try:
            return _load_one(path, tree_template)
        except Exception as e:  # noqa: BLE001 — any corruption -> try older
            print(f"[checkpoint] skipping corrupt {path}: {e}")
    return None, None


def retain(directory: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` checkpoints."""
    steps = list_steps(directory)
    for step in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{step:010d}"), ignore_errors=True)
