"""Fault-tolerance beyond checkpoint/restart: crash-resilient execution
and elastic re-meshing when the device pool changes size.

At 1000+ nodes the failure model is: (a) preemption (SIGTERM, handled in
train_loop.PreemptionGuard), (b) hard node loss mid-step (XLA raises —
handled here by restore-and-retry), (c) degraded-but-alive stragglers
(watchdog in train_loop; the synchronous-SPMD remedy is to restart the
slow host, not to desynchronize), and (d) resume on a *different* device
count — handled by ``elastic_remesh``: NamedSharding is recomputed from
the live topology and checkpointed host arrays are device_put onto the
new mesh (works because checkpoints are device-layout-agnostic numpy).
"""

from __future__ import annotations

import time
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def run_with_retries(
    fn: Callable[[], Any],
    restore: Callable[[], None],
    max_failures: int = 3,
    backoff_s: float = 1.0,
):
    """Execute ``fn``; on failure call ``restore`` and retry.

    ``fn`` is expected to be a resumable closure (e.g. a train() call that
    restores from its own checkpoint dir), so a retry continues from the
    last checkpoint rather than from scratch.
    """
    failures = 0
    while True:
        try:
            return fn()
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any device/runtime fault
            failures += 1
            if failures > max_failures:
                raise
            print(f"[ft] failure {failures}/{max_failures}: {e!r}; restoring")
            restore()
            time.sleep(backoff_s * failures)


def best_mesh_shape(n_devices: int, model_parallel: int) -> tuple[int, int]:
    """(data, model) factorization for an arbitrary live device count.

    Shrinks model parallelism if the pool no longer supports the requested
    width — elasticity means the job keeps running at reduced size.
    """
    mp = min(model_parallel, n_devices)
    while n_devices % mp:
        mp -= 1
    return n_devices // mp, mp


def elastic_remesh(
    host_state: Any,
    spec_fn: Callable[[Any], P],
    model_parallel: int = 1,
    devices=None,
):
    """Build a mesh from the live device pool and shard host state onto it.

    host_state: numpy pytree (e.g. from checkpoint.restore_latest).
    spec_fn: leaf -> PartitionSpec (the same logical rules used at launch;
    axes that no longer exist in the new mesh are dropped).
    """
    devices = devices if devices is not None else jax.devices()
    dp, mp = best_mesh_shape(len(devices), model_parallel)
    mesh = Mesh(np.asarray(devices).reshape(dp, mp), ("data", "model"))

    def put(leaf):
        spec = spec_fn(leaf)
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return mesh, jax.tree.map(put, host_state)
