# Training runtime: dependency-free AdamW + schedules (incl. minicpm's
# WSD), grad-accumulation step factory, atomic digest-verified
# checkpointing, preemption/straggler/elastic fault tolerance.
from repro.train import checkpoint, fault_tolerance, optimizer, train_loop
from repro.train.optimizer import OptConfig, adamw_init, adamw_update, lr_at
from repro.train.train_loop import TrainConfig, make_train_step, train

__all__ = [
    "checkpoint",
    "fault_tolerance",
    "optimizer",
    "train_loop",
    "OptConfig",
    "adamw_init",
    "adamw_update",
    "lr_at",
    "TrainConfig",
    "make_train_step",
    "train",
]
