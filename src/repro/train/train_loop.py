"""Training loop: jitted step factory (grad accumulation via scan),
periodic atomic checkpointing, automatic resume, preemption handling, and
a step-time straggler watchdog.

The loop is loss-function-agnostic: every model family plugs in a
``loss_fn(params, batch) -> (loss, aux)``.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from functools import partial
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.train import checkpoint as CKPT
from repro.train import optimizer as OPT


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    microbatches: int = 1             # grad accumulation factor
    straggler_factor: float = 3.0     # watchdog: step > factor * median -> warn


def make_train_step(
    loss_fn: Callable,
    opt_cfg: OPT.OptConfig,
    microbatches: int = 1,
    donate: bool = True,
):
    """Build the jitted (params, opt_state, batch) -> (params, state, metrics).

    With microbatches > 1, the leading batch axis is split and gradients
    are accumulated with a ``lax.scan`` — same memory as one microbatch.
    """

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, grads

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, aux, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:]),
                batch,
            )

            def accum(carry, mb):
                loss_c, grads_c = carry
                loss_i, _, grads_i = grads_of(params, mb)
                return (
                    loss_c + loss_i / microbatches,
                    jax.tree.map(lambda a, b: a + b / microbatches, grads_c, grads_i),
                ), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zero_grads), micro)
            aux = {}
        params, opt_state, om = OPT.adamw_update(grads, opt_state, params, opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag; the loop checkpoints and exits cleanly.

    This is the cooperative-preemption contract on managed clusters
    (maintenance events deliver SIGTERM with a grace window).
    """

    def __init__(self):
        self.preempted = False
        self._installed = False

    def install(self):
        if self._installed:
            return
        try:
            signal.signal(signal.SIGTERM, self._handler)
            self._installed = True
        except ValueError:
            pass  # non-main thread (tests) — watchdog only

    def _handler(self, signum, frame):  # noqa: ARG002
        self.preempted = True


def train(
    loss_fn: Callable,
    params: Any,
    data_iter: Iterator,
    opt_cfg: OPT.OptConfig,
    cfg: TrainConfig,
    opt_state: Any = None,
    start_step: int = 0,
    hooks: Optional[list[Callable[[int, dict], None]]] = None,
):
    """Run the loop; returns (params, opt_state, history).

    Resume: if ``cfg.ckpt_dir`` holds a valid checkpoint, training state
    (params + optimizer + step) restores from it and the data iterator is
    expected to be positioned via its own ``start_step`` (see
    data.*.batch_iterator) — together they make restarts exact.
    """
    if opt_state is None:
        opt_state = OPT.adamw_init(params)

    step0 = start_step
    if cfg.ckpt_dir:
        restored, meta = CKPT.restore_latest(
            cfg.ckpt_dir, {"params": params, "opt": opt_state}
        )
        if restored is not None:
            params = restored["params"]
            opt_state = restored["opt"]
            step0 = meta["step"]
            print(f"[train] resumed from step {step0}")

    train_step = make_train_step(loss_fn, opt_cfg, cfg.microbatches)
    guard = PreemptionGuard()
    guard.install()

    history = []
    step_times = []
    for step in range(step0, cfg.steps):
        t0 = time.perf_counter()
        batch = next(data_iter)
        params, opt_state, metrics = train_step(params, opt_state, batch)
        dt = time.perf_counter() - t0
        step_times.append(dt)

        # straggler watchdog: flag anomalously slow steps
        if len(step_times) >= 8:
            med = sorted(step_times[-32:])[len(step_times[-32:]) // 2]
            if dt > cfg.straggler_factor * med:
                print(f"[train] straggler step {step}: {dt:.3f}s vs median {med:.3f}s")

        if step % cfg.log_every == 0 or step == cfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m, "sec": dt})
            for h in hooks or []:
                h(step, m)

        must_ckpt = cfg.ckpt_dir and (
            (step + 1) % cfg.ckpt_every == 0 or step == cfg.steps - 1 or guard.preempted
        )
        if must_ckpt:
            CKPT.save(cfg.ckpt_dir, step + 1, {"params": params, "opt": opt_state})
            CKPT.retain(cfg.ckpt_dir, cfg.keep_ckpts)
        if guard.preempted:
            print(f"[train] preempted at step {step}; checkpointed and exiting")
            break

    return params, opt_state, history
