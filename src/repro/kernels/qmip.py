"""Pallas TPU kernel: fused int8 maximum-inner-product scoring.

The paper's hot path — scoring a batch of quantized queries against a tile
of the quantized corpus — mapped onto the TPU MXU:

  * corpus codes stream HBM -> VMEM in (BN, d) int8 tiles,
  * query codes sit VMEM-resident in (BQ, d) int8 tiles,
  * one ``dot_general`` with ``preferred_element_type=int32`` per tile pair
    drives the MXU's native int8 x int8 -> int32 path (~2x bf16 peak on
    TPU v5e),
  * the int32 score tile (BQ, BN) is written straight out — no fp32
    intermediates ever touch HBM.

Tiling rationale (v5e): the MXU is 128x128; int8 VREG lanes are 128 wide.
BQ=128 aligns the output sublane dim, BN=512 amortizes corpus-tile DMA
against 4 MXU passes, and d is carried whole per tile (embedding dims here
are <= 4096, so a (512, 4096) int8 corpus tile is 2 MiB — comfortably
inside a ~16 MiB VMEM budget together with the query tile and the int32
accumulator tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes — overridable from ops.py for the shape sweep tests.
BQ = 128   # query rows per tile (MXU sublane-aligned)
BN = 512   # corpus rows per tile
LANE = 128 # last-dim alignment unit


def _qmip_kernel(q_ref, x_ref, o_ref):
    """One (BQ, BN) output tile: int8 dot int8 -> int32 on the MXU."""
    q = q_ref[...]                      # (BQ, d) int8
    x = x_ref[...]                      # (BN, d) int8
    o_ref[...] = jax.lax.dot_general(
        q,
        x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def qmip_pallas(
    q_codes: jax.Array,
    x_codes: jax.Array,
    *,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """[Q, d] int8 x [N, d] int8 -> [Q, N] int32 scores.

    Q must be a multiple of ``bq`` and N of ``bn`` (ops.py pads).  d is
    carried un-tiled: per-tile VMEM = bq*d + bn*d (int8) + bq*bn*4 bytes.
    """
    Q, d = q_codes.shape
    N, d2 = x_codes.shape
    assert d == d2, (d, d2)
    assert Q % bq == 0 and N % bn == 0, (Q, N, bq, bn)

    grid = (Q // bq, N // bn)
    return pl.pallas_call(
        _qmip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.int32),
        interpret=interpret,
    )(q_codes, x_codes)
