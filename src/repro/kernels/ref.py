"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (exactly, for
integer outputs) across the shape/dtype sweeps in tests/test_kernels_*.py.
They deliberately share no code with the kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qmip_ref(q_codes: jax.Array, x_codes: jax.Array) -> jax.Array:
    """[Q, d] int x [N, d] int -> [Q, N] int32 inner products."""
    return jnp.dot(
        q_codes.astype(jnp.int32), x_codes.astype(jnp.int32).T
    ).astype(jnp.int32)


def ql2_ref(q_codes: jax.Array, x_codes: jax.Array) -> jax.Array:
    """[Q, d] int x [N, d] int -> [Q, N] int32 negated squared L2."""
    qi = q_codes.astype(jnp.int32)
    xi = x_codes.astype(jnp.int32)
    diff = qi[:, None, :] - xi[None, :, :]
    return -jnp.sum(diff * diff, axis=-1).astype(jnp.int32)


def quantize_ref(
    x: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    zero: jax.Array,
    bits: int = 8,
) -> jax.Array:
    """Eq. 1 clamped linear quantization, elementwise oracle."""
    span = jnp.maximum(hi - lo, 1e-12)
    q = jnp.round((2.0**bits) * (x.astype(jnp.float32) - zero) / span)
    return jnp.clip(q, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1).astype(jnp.int8)
