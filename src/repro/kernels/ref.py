"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (exactly, for
integer outputs) across the shape/dtype sweeps in tests/test_kernels_*.py.
They deliberately share no code with the kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def qmip_ref(q_codes: jax.Array, x_codes: jax.Array) -> jax.Array:
    """[Q, d] int x [N, d] int -> [Q, N] int32 inner products."""
    return jnp.dot(
        q_codes.astype(jnp.int32), x_codes.astype(jnp.int32).T
    ).astype(jnp.int32)


def ql2_ref(q_codes: jax.Array, x_codes: jax.Array) -> jax.Array:
    """[Q, d] int x [N, d] int -> [Q, N] int32 negated squared L2."""
    qi = q_codes.astype(jnp.int32)
    xi = x_codes.astype(jnp.int32)
    diff = qi[:, None, :] - xi[None, :, :]
    return -jnp.sum(diff * diff, axis=-1).astype(jnp.int32)


def _unpack_int4_ref(packed: jax.Array) -> jax.Array:
    """[N, d/2] uint8 -> [N, d] int32 nibbles in [-8, 7] (oracle-local)."""
    lo = (packed & 0x0F).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0x0F).astype(jnp.int32) - 8
    n, half = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(n, half * 2)


def qmip4_ref(q_codes: jax.Array, packed: jax.Array) -> jax.Array:
    """[Q, d] int x [N, d/2] packed uint8 -> [Q, N] int32 inner products."""
    return qmip_ref(q_codes, _unpack_int4_ref(packed))


def ql24_ref(q_codes: jax.Array, packed: jax.Array) -> jax.Array:
    """[Q, d] int x [N, d/2] packed uint8 -> [Q, N] int32 negated sq-L2."""
    return ql2_ref(q_codes, _unpack_int4_ref(packed))


def _unpack_uint4_ref(packed: jax.Array) -> jax.Array:
    """[N, m/2] uint8 -> [N, m] int32 unsigned nibbles in [0, 15]."""
    lo = (packed & 0x0F).astype(jnp.int32)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int32)
    n, half = packed.shape
    return jnp.stack([lo, hi], axis=-1).reshape(n, half * 2)


def adc_ref(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """[Q, M, K] int LUT x [N, M] uint8 codewords -> [Q, N] int32 ADC.

    The asymmetric-distance oracle: gather each row's per-subspace LUT
    entry and sum — ``s[q, n] = sum_m lut[q, m, codes[n, m]]``.
    """
    idx = codes.T[None].astype(jnp.int32)               # [1, M, N]
    return jnp.sum(
        jnp.take_along_axis(lut.astype(jnp.int32), idx, axis=2), axis=1
    ).astype(jnp.int32)


def adc4_ref(lut: jax.Array, packed: jax.Array) -> jax.Array:
    """[Q, M, K] int LUT x [N, M/2] packed uint8 nibbles -> [Q, N] int32.

    ``lut``'s subspace axis must already cover the unpacked (even) width;
    a zero LUT slice for an odd-m pad column keeps the sum unchanged.
    """
    return adc_ref(lut, _unpack_uint4_ref(packed))


def topk_ref(scores: jax.Array, k: int, n_valid: int | None = None):
    """Exact top-k oracle over a full [Q, N] score matrix.

    Masks columns >= n_valid (padding) by id before selection, returning
    (-inf, -1) for slots with no valid candidate — the same contract the
    fused kernel honors.
    """
    s = scores.astype(jnp.float32)
    if n_valid is not None and n_valid < s.shape[1]:
        col = jnp.arange(s.shape[1])[None, :]
        s = jnp.where(col < n_valid, s, jnp.finfo(jnp.float32).min)
    top_s, top_i = jax.lax.top_k(s, k)
    top_i = jnp.where(top_s > jnp.finfo(jnp.float32).min, top_i, -1)
    return top_s, top_i.astype(jnp.int32)


def quantize_ref(
    x: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    zero: jax.Array,
    bits: int = 8,
) -> jax.Array:
    """Eq. 1 clamped linear quantization, elementwise oracle."""
    span = jnp.maximum(hi - lo, 1e-12)
    q = jnp.round((2.0**bits) * (x.astype(jnp.float32) - zero) / span)
    return jnp.clip(q, -(2 ** (bits - 1)), 2 ** (bits - 1) - 1).astype(jnp.int8)
