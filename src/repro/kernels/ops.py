"""Public jit'd wrappers for the Pallas kernels.

Responsibilities:
  * pad ragged (Q, N) up to tile multiples and slice the result back,
  * pick sane tile sizes for small inputs,
  * run ``interpret=True`` automatically off-TPU (this container is CPU) so
    the same call sites work everywhere,
  * expose a ``use_pallas=False`` escape hatch that routes to the pure-jnp
    reference (used under ``shard_map`` cells where the XLA int8 dot is
    already optimal and for the dry-run, where kernel lowering to the host
    platform is not the point).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import adc as _adc
from repro.kernels import fused_topk as _fused
from repro.kernels import packed as _packed
from repro.kernels import qmip as _qmip
from repro.kernels import ql2 as _ql2
from repro.kernels import quantize as _quantize
from repro.kernels import ref as _ref
from repro.tune import table as _tune


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pick_tile(n: int, pref: int, unit: int = 8) -> int:
    """Largest tile <= pref that keeps padding waste small for tiny n.

    ``pref`` is rounded up to the unit first — a tuned (or caller-passed)
    tile that is off-unit would otherwise leak an illegal block shape
    into the kernel grid.
    """
    pref = max(unit, _round_up(pref, unit))
    if n >= pref:
        return pref
    return max(unit, _round_up(n, unit))


# -- registered fallback rows: today's constants, the dispatch floor -------
# (dispatch precedence is tuned table > these rows; DESIGN.md §13)
_tune.register_fallback("fused_topk", _tune.TuneConfig(
    "fused", bq=_fused.BQ, bn=_fused.BN, chunk=16384))
_tune.register_fallback("packed", _tune.TuneConfig(
    "fused", bq=_packed.BQ, bn=_packed.BN, chunk=16384))
_tune.register_fallback("fused_adc", _tune.TuneConfig(
    "fused", bq=_adc.BQ, bn=_adc.BN, chunk=16384))
_tune.register_fallback("scan", _tune.TuneConfig("scan", chunk=16384))


def _pad_rows(a: jax.Array, rows: int) -> jax.Array:
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def qmip(
    q_codes: jax.Array,
    x_codes: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """int8 MIP scores [Q, N] int32 — fused MXU kernel with padding."""
    if not use_pallas:
        return _ref.qmip_ref(q_codes, x_codes)
    interp = (not _on_tpu()) if interpret is None else interpret
    Q, _ = q_codes.shape
    N, _ = x_codes.shape
    bq = _pick_tile(Q, _qmip.BQ)
    bn = _pick_tile(N, _qmip.BN)
    qp = _pad_rows(q_codes, _round_up(Q, bq))
    xp = _pad_rows(x_codes, _round_up(N, bn))
    out = _qmip.qmip_pallas(qp, xp, bq=bq, bn=bn, interpret=interp)
    return out[:Q, :N]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ql2(
    q_codes: jax.Array,
    x_codes: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """int8 negated squared-L2 scores [Q, N] int32."""
    if not use_pallas:
        return _ref.ql2_ref(q_codes, x_codes)
    interp = (not _on_tpu()) if interpret is None else interpret
    Q, _ = q_codes.shape
    N, _ = x_codes.shape
    bq = _pick_tile(Q, _ql2.BQ)
    bn = _pick_tile(N, _ql2.BN)
    qp = _pad_rows(q_codes, _round_up(Q, bq))
    xp = _pad_rows(x_codes, _round_up(N, bn))
    out = _ql2.ql2_pallas(qp, xp, bq=bq, bn=bn, interpret=interp)
    return out[:Q, :N]


def fused_query_tile(
    q: int | None = None,
    n: int | None = None,
    d: int | None = None,
    *,
    metric: str = "ip",
    bits: int = 8,
    packed: bool = False,
) -> int:
    """Query rows per fused-kernel tile — the corpus re-stream granularity
    (engine stats derive bytes_read from it; one source of truth).

    With a workload shape, the installed TuneTable is consulted first
    (the entry's ``bq``); without one — or on a table miss — the kernel
    family's registered fallback constant answers, exactly as before.
    """
    kernel = "packed" if packed else "fused_topk"
    if q is not None and n is not None and d is not None:
        cfg = _tune.lookup(kernel, metric, bits, q, n, d)
        if cfg is not None and cfg.bq is not None:
            return cfg.bq
    return _tune.fallback(kernel).bq


def fused_adc_query_tile(
    q: int | None = None,
    n: int | None = None,
    m: int | None = None,
    *,
    metric: str = "ip",
    bits: int = 8,
) -> int:
    """Query rows per fused-ADC tile (each carries its LUT block) —
    table-first, registered constant as the fallback row."""
    if q is not None and n is not None and m is not None:
        cfg = _tune.lookup("fused_adc", metric, bits, q, n, m)
        if cfg is not None and cfg.bq is not None:
            return cfg.bq
    return _tune.fallback("fused_adc").bq


def _split_nibble_queries(q_codes: jax.Array):
    """[Q, d] int4-valued codes -> the (even, odd) dim halves [Q, d/2]."""
    assert q_codes.shape[1] % 2 == 0, q_codes.shape
    return q_codes[:, 0::2], q_codes[:, 1::2]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def qmip4(
    q_codes: jax.Array,
    packed: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """int4 MIP scores [Q, N] int32 over bit-packed corpus codes.

    ``q_codes`` are full-width [Q, d] int4-valued int8 (queries stay
    unpacked — they are tiny); ``packed`` is [N, d/2] uint8.
    """
    if not use_pallas:
        return _ref.qmip4_ref(q_codes, packed)
    interp = (not _on_tpu()) if interpret is None else interpret
    Q = q_codes.shape[0]
    N = packed.shape[0]
    qe, qo = _split_nibble_queries(q_codes)
    bq = _pick_tile(Q, _packed.BQ)
    bn = _pick_tile(N, _packed.BN)
    qe = _pad_rows(qe, _round_up(Q, bq))
    qo = _pad_rows(qo, _round_up(Q, bq))
    xp = _pad_rows(packed, _round_up(N, bn))
    out = _packed.qmip4_pallas(qe, qo, xp, bq=bq, bn=bn, interpret=interp)
    return out[:Q, :N]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ql24(
    q_codes: jax.Array,
    packed: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """int4 negated squared-L2 scores [Q, N] int32 over packed codes."""
    if not use_pallas:
        return _ref.ql24_ref(q_codes, packed)
    interp = (not _on_tpu()) if interpret is None else interpret
    Q = q_codes.shape[0]
    N = packed.shape[0]
    qe, qo = _split_nibble_queries(q_codes)
    bq = _pick_tile(Q, _packed.BQ)
    bn = _pick_tile(N, _packed.BN)
    qe = _pad_rows(qe, _round_up(Q, bq))
    qo = _pad_rows(qo, _round_up(Q, bq))
    xp = _pad_rows(packed, _round_up(N, bn))
    out = _packed.ql24_pallas(qe, qo, xp, bq=bq, bn=bn, interpret=interp)
    return out[:Q, :N]


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "packed", "bq", "bn", "use_pallas",
                     "interpret"),
)
def fused_topk(
    q: jax.Array,
    x: jax.Array,
    k: int,
    metric: str,
    *,
    packed: bool = False,
    bq: int | None = None,
    bn: int | None = None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    mask: jax.Array | None = None,
):
    """Streaming fused score + top-k: ([Q, k] f32 scores, [Q, k] i32 ids).

    ``metric`` is ``ip`` or ``l2`` (angular needs norm rescale — engine
    routes it to the unfused scan).  With ``packed=True``, ``x`` is
    [N, d/2] uint8 int4 codes and ``q`` full-width [Q, d] int4-valued
    int8.  ``bq`` overrides the query tile and ``bn`` caps the corpus
    tile (the VMEM working-set knobs — tuned dispatch threads the
    TuneTable entry through both; bare calls keep the family constants).
    An optional [N] ``mask`` (nonzero = allowed) ANDs into the kernels'
    pad fence — filtered rows die like pad rows, at no extra bytes read.
    The [Q, N] score matrix never reaches HBM on the Pallas path;
    ``use_pallas=False`` is the XLA reference (materializes scores, used
    for parity tests and as the shard_map cell fallback).
    """
    assert metric in ("ip", "l2"), metric
    Q = q.shape[0]
    N = x.shape[0]
    k = min(k, N)
    if not use_pallas:
        if packed:
            s = _ref.qmip4_ref(q, x) if metric == "ip" else _ref.ql24_ref(q, x)
        elif jnp.issubdtype(q.dtype, jnp.integer):
            s = _ref.qmip_ref(q, x) if metric == "ip" else _ref.ql2_ref(q, x)
        else:
            from repro.core import distances as D

            s = D.scores(q, x, metric)
        if mask is not None:
            # the NEG sentinel topk_ref already turns into id -1
            s = jnp.where(mask.astype(bool)[None, :], s.astype(jnp.float32),
                          jnp.finfo(jnp.float32).min)
        return _ref.topk_ref(s, k, N)
    interp = (not _on_tpu()) if interpret is None else interpret
    bq = _pick_tile(Q, bq or _fused.BQ)
    # an explicit bn is honored (tuned tiles may exceed the constant —
    # the tuning space owns the VMEM bound); bare calls keep the constant
    bn = _pick_tile(N, bn or _fused.BN)
    mp = (None if mask is None else
          jnp.pad(mask.astype(jnp.int8), (0, _round_up(N, bn) - N)))
    if packed:
        qe, qo = _split_nibble_queries(q)
        qe = _pad_rows(qe, _round_up(Q, bq))
        qo = _pad_rows(qo, _round_up(Q, bq))
        xp = _pad_rows(x, _round_up(N, bn))
        s, i = _fused.fused_topk4_pallas(
            qe, qo, xp, k=k, metric=metric, n_valid=N,
            bq=bq, bn=bn, interpret=interp, mask=mp,
        )
    else:
        qp = _pad_rows(q, _round_up(Q, bq))
        xp = _pad_rows(x, _round_up(N, bn))
        s, i = _fused.fused_topk_pallas(
            qp, xp, k=k, metric=metric, n_valid=N,
            bq=bq, bn=bn, interpret=interp, mask=mp,
        )
    return s[:Q], i[:Q]


@functools.partial(
    jax.jit,
    static_argnames=("k", "packed", "bq", "bn", "use_pallas", "interpret"),
)
def fused_adc_topk(
    lut: jax.Array,
    codes: jax.Array,
    k: int,
    *,
    packed: bool = False,
    bq: int | None = None,
    bn: int | None = None,
    use_pallas: bool = True,
    interpret: bool | None = None,
    mask: jax.Array | None = None,
):
    """Streaming fused ADC + top-k: ([Q, k] f32 scores, [Q, k] i32 ids).

    ``lut`` is the [Q, M, K] int8-quantized lookup table (K = codewords
    per subspace); ``codes`` is [N, M] uint8, or — with ``packed=True`` —
    [N, ceil(M/2)] uint8 two-nibbles-per-byte (an odd logical M was
    padded with a zero-code column at pack time; the LUT grows a matching
    zero subspace slice here, so the pad contributes nothing).  The
    [Q, N] ADC matrix never reaches HBM on the Pallas path;
    ``use_pallas=False`` materializes it via the ref.py oracle (parity
    tests, XLA fallback).
    """
    Q, m, n_codewords = lut.shape
    N = codes.shape[0]
    k = min(k, N)
    if packed and m < 2 * codes.shape[1]:      # odd-M zero-code pad column
        lut = jnp.pad(lut, ((0, 0), (0, 2 * codes.shape[1] - m), (0, 0)))
    if not use_pallas:
        s = _ref.adc4_ref(lut, codes) if packed else _ref.adc_ref(lut, codes)
        if mask is not None:
            s = jnp.where(mask.astype(bool)[None, :], s.astype(jnp.float32),
                          jnp.finfo(jnp.float32).min)
        return _ref.topk_ref(s, k, N)
    interp = (not _on_tpu()) if interpret is None else interpret
    bq = _pick_tile(Q, bq or _adc.BQ)
    bn = _pick_tile(N, bn or _adc.BN)
    cp = _pad_rows(codes, _round_up(N, bn))
    mp = (None if mask is None else
          jnp.pad(mask.astype(jnp.int8), (0, _round_up(N, bn) - N)))
    if packed:
        le = lut[:, 0::2, :].reshape(Q, -1)
        lo = lut[:, 1::2, :].reshape(Q, -1)
        le = _pad_rows(le, _round_up(Q, bq))
        lo = _pad_rows(lo, _round_up(Q, bq))
        s, i = _adc.fused_adc4_pallas(
            le, lo, cp, k=k, n_codewords=n_codewords, n_valid=N,
            bq=bq, bn=bn, interpret=interp, mask=mp,
        )
    else:
        l2d = _pad_rows(lut.reshape(Q, -1), _round_up(Q, bq))
        s, i = _adc.fused_adc_pallas(
            l2d, cp, k=k, n_codewords=n_codewords, n_valid=N,
            bq=bq, bn=bn, interpret=interp, mask=mp,
        )
    return s[:Q], i[:Q]


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas", "interpret"))
def quantize(
    x: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    zero: jax.Array,
    *,
    bits: int = 8,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Eq. 1 corpus compression [N, d] f32 -> int8."""
    if not use_pallas:
        return _ref.quantize_ref(x, lo, hi, zero, bits=bits)
    interp = (not _on_tpu()) if interpret is None else interpret
    N, _ = x.shape
    bn = _pick_tile(N, _quantize.BN, unit=8)
    xp = _pad_rows(x, _round_up(N, bn))
    out = _quantize.quantize_pallas(
        xp, lo, hi, zero, bits=bits, bn=bn, interpret=interp
    )
    return out[:N]
