"""Public jit'd wrappers for the Pallas kernels.

Responsibilities:
  * pad ragged (Q, N) up to tile multiples and slice the result back,
  * pick sane tile sizes for small inputs,
  * run ``interpret=True`` automatically off-TPU (this container is CPU) so
    the same call sites work everywhere,
  * expose a ``use_pallas=False`` escape hatch that routes to the pure-jnp
    reference (used under ``shard_map`` cells where the XLA int8 dot is
    already optimal and for the dry-run, where kernel lowering to the host
    platform is not the point).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import qmip as _qmip
from repro.kernels import ql2 as _ql2
from repro.kernels import quantize as _quantize
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _pick_tile(n: int, pref: int, unit: int = 8) -> int:
    """Largest tile <= pref that keeps padding waste small for tiny n."""
    if n >= pref:
        return pref
    return max(unit, _round_up(n, unit))


def _pad_rows(a: jax.Array, rows: int) -> jax.Array:
    pad = rows - a.shape[0]
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def qmip(
    q_codes: jax.Array,
    x_codes: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """int8 MIP scores [Q, N] int32 — fused MXU kernel with padding."""
    if not use_pallas:
        return _ref.qmip_ref(q_codes, x_codes)
    interp = (not _on_tpu()) if interpret is None else interpret
    Q, _ = q_codes.shape
    N, _ = x_codes.shape
    bq = _pick_tile(Q, _qmip.BQ)
    bn = _pick_tile(N, _qmip.BN)
    qp = _pad_rows(q_codes, _round_up(Q, bq))
    xp = _pad_rows(x_codes, _round_up(N, bn))
    out = _qmip.qmip_pallas(qp, xp, bq=bq, bn=bn, interpret=interp)
    return out[:Q, :N]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def ql2(
    q_codes: jax.Array,
    x_codes: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """int8 negated squared-L2 scores [Q, N] int32."""
    if not use_pallas:
        return _ref.ql2_ref(q_codes, x_codes)
    interp = (not _on_tpu()) if interpret is None else interpret
    Q, _ = q_codes.shape
    N, _ = x_codes.shape
    bq = _pick_tile(Q, _ql2.BQ)
    bn = _pick_tile(N, _ql2.BN)
    qp = _pad_rows(q_codes, _round_up(Q, bq))
    xp = _pad_rows(x_codes, _round_up(N, bn))
    out = _ql2.ql2_pallas(qp, xp, bq=bq, bn=bn, interpret=interp)
    return out[:Q, :N]


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas", "interpret"))
def quantize(
    x: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    zero: jax.Array,
    *,
    bits: int = 8,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Eq. 1 corpus compression [N, d] f32 -> int8."""
    if not use_pallas:
        return _ref.quantize_ref(x, lo, hi, zero, bits=bits)
    interp = (not _on_tpu()) if interpret is None else interpret
    N, _ = x.shape
    bn = _pick_tile(N, _quantize.BN, unit=8)
    xp = _pad_rows(x, _round_up(N, bn))
    out = _quantize.quantize_pallas(
        xp, lo, hi, zero, bits=bits, bn=bn, interpret=interp
    )
    return out[:N]
