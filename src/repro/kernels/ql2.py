"""Pallas TPU kernel: fused int8 (negated) squared-L2 scoring.

Same tiling story as :mod:`repro.kernels.qmip` — the O(Q*N*d) term is the
int8 MXU matmul; the per-row squared norms are recomputed in-kernel per
tile (O((BQ+BN)*d) int work, negligible against the BQ*BN*d matmul) which
keeps the kernel single-pass and avoids a second HBM-resident norm array.

    out[i, j] = -( ||q_i||^2 + ||x_j||^2 - 2 q_i . x_j )   (int32)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BN = 512


def _ql2_kernel(q_ref, x_ref, o_ref):
    q = q_ref[...].astype(jnp.int32)    # (BQ, d)
    x = x_ref[...].astype(jnp.int32)    # (BN, d)
    dot = jax.lax.dot_general(
        q_ref[...],
        x_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )                                    # (BQ, BN)
    qq = jnp.sum(q * q, axis=-1, keepdims=True)      # (BQ, 1)
    xx = jnp.sum(x * x, axis=-1)[None, :]            # (1, BN)
    o_ref[...] = -(qq + xx - 2 * dot)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def ql2_pallas(
    q_codes: jax.Array,
    x_codes: jax.Array,
    *,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """[Q, d] int8 x [N, d] int8 -> [Q, N] int32 negated squared L2."""
    Q, d = q_codes.shape
    N, d2 = x_codes.shape
    assert d == d2, (d, d2)
    assert Q % bq == 0 and N % bn == 0, (Q, N, bq, bn)

    grid = (Q // bq, N // bn)
    return pl.pallas_call(
        _ql2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.int32),
        interpret=interpret,
    )(q_codes, x_codes)
