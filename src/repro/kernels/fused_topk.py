"""Pallas TPU kernel: fused corpus scan + running top-k.

The unfused hot path writes a [Q, chunk] score tile to memory for every
corpus chunk and merges it with `lax.top_k` afterwards — the score matrix
round-trips HBM even though only k survivors per query matter.  This
kernel fuses the reduction into the scan: the grid walks corpus tiles
sequentially (grid = (Q/bq, N/bn), corpus axis innermost) while the
output block — the [bq, k] best (scores, ids) set — stays VMEM-resident
across every tile of a query row (its index map is constant in the
corpus axis, the standard Pallas accumulation pattern).  The [Q, N]
score matrix never exists in HBM.

Per tile the merge is a k-step select-and-mask sweep over the
concatenated [bq, k + bn] candidates: max + argmax + one-hot mask, all
dense VPU ops (no sorts, no dynamic stores), O(k (k + bn)) per tile
against the tile's O(bn d) MXU score work.  Padding rows are id-masked
*inside* the kernel (score -> -inf, id -> -1), so zero-padding can never
win under L2 — callers get only valid ids back, no sentinel hazard.

Supported score tiles (dispatch in ops.fused_topk):
  * f32 / int8 codes, metric ip or l2 (one dot per tile),
  * bit-packed int4 codes with the unpack-in-kernel nibble planes of
    :mod:`repro.kernels.packed` (queries pre-split even/odd).
Angular stays on the unfused path (needs per-row norm rescale, see
engine.scorer's dispatch table).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.packed import qmip4_tile, ql24_tile

BQ = 128    # query rows per tile
BN = 512    # corpus rows per tile

NEG = float(jnp.finfo(jnp.float32).min)


# --------------------------------------------------------------------------
# tile score functions (values in, values out — shared with interpret mode)
# --------------------------------------------------------------------------

def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def _ip_tile(q: jax.Array, x: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        q, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=_acc_dtype(q.dtype),
    )


def _l2_tile(q: jax.Array, x: jax.Array) -> jax.Array:
    acc = _acc_dtype(q.dtype)
    dot = _ip_tile(q, x)
    qa = q.astype(acc)
    xa = x.astype(acc)
    qq = jnp.sum(qa * qa, axis=-1, keepdims=True)
    xx = jnp.sum(xa * xa, axis=-1)[None, :]
    return -(qq + xx - 2 * dot)


# packed-int4 tile math is shared with kernels/packed.py (one copy of the
# nibble-unpack + two-MXU-pass scoring)
_TILE_FNS = {("ip", False): _ip_tile, ("l2", False): _l2_tile,
             ("ip", True): qmip4_tile, ("l2", True): ql24_tile}


# --------------------------------------------------------------------------
# in-kernel running top-k merge
# --------------------------------------------------------------------------

def _merge_tile(best_s, best_i, s, ids, k: int):
    """Merge a [bq, bn] score tile into the running [bq, k] best set.

    k-step select-and-mask: each step extracts the row max of the
    concatenated candidates and one-hot-masks it out — everything stays a
    dense 2-D op (argmax ties resolve to the first position, so the
    result is deterministic and sorted best-first).
    """
    cs = jnp.concatenate([best_s, s], axis=1)              # [bq, k + bn]
    ci = jnp.concatenate([best_i, ids], axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, cs.shape, 1)
    kcols = jax.lax.broadcasted_iota(jnp.int32, best_s.shape, 1)

    def step(j, carry):
        cs, out_s, out_i = carry
        m = jnp.max(cs, axis=1, keepdims=True)             # [bq, 1]
        p = jnp.argmax(cs, axis=1)[:, None]                # [bq, 1]
        onehot = cols == p
        sel = jnp.sum(jnp.where(onehot, ci, 0), axis=1, keepdims=True)
        out_s = jnp.where(kcols == j, m, out_s)
        out_i = jnp.where(kcols == j, sel, out_i)
        return jnp.where(onehot, NEG, cs), out_s, out_i

    _, out_s, out_i = jax.lax.fori_loop(
        0, k, step,
        (cs, jnp.full_like(best_s, NEG), jnp.full_like(best_i, -1)),
    )
    return out_s, out_i


def _make_kernel(score_tile, k: int, bn: int, n_valid: int,
                 with_mask: bool = False):
    def kernel(*refs):
        *in_refs, os_ref, oi_ref = refs
        if with_mask:
            *in_refs, m_ref = in_refs
        j = pl.program_id(1)                               # corpus-tile index

        @pl.when(j == 0)
        def _init():
            os_ref[...] = jnp.full(os_ref.shape, NEG, jnp.float32)
            oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

        s = score_tile(*[r[...] for r in in_refs]).astype(jnp.float32)
        gid = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = gid < n_valid
        if with_mask:
            # predicate bitmap rides the corpus grid axis as an [bn, 1]
            # int8 column — the filter ANDs into the same pad fence, so
            # a filtered row dies exactly like a pad row (DESIGN.md §16)
            ok = ok & (m_ref[...][:, 0] != 0)[None, :]
        s = jnp.where(ok, s, NEG)
        ids = jnp.where(ok, gid, -1)
        bs, bi = _merge_tile(os_ref[...], oi_ref[...], s, ids, k)
        os_ref[...] = bs
        oi_ref[...] = bi

    return kernel


def _fused_call(score_tile, inputs, corpus, *, k, n_valid, bq, bn, interpret,
                mask=None):
    Q = inputs[0].shape[0]
    N = corpus.shape[0]
    assert Q % bq == 0 and N % bn == 0, (Q, N, bq, bn)
    q_specs = [
        pl.BlockSpec((bq, a.shape[1]), lambda i, j: (i, 0)) for a in inputs
    ]
    x_spec = pl.BlockSpec((bn, corpus.shape[1]), lambda i, j: (j, 0))
    operands = list(inputs) + [corpus]
    in_specs = q_specs + [x_spec]
    if mask is not None:
        assert mask.shape[0] == N, (mask.shape, N)
        operands.append(mask.reshape(N, 1).astype(jnp.int8))
        in_specs.append(pl.BlockSpec((bn, 1), lambda i, j: (j, 0)))
    out_spec = pl.BlockSpec((bq, k), lambda i, j: (i, 0))
    return pl.pallas_call(
        _make_kernel(score_tile, k, bn, n_valid, with_mask=mask is not None),
        grid=(Q // bq, N // bn),
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "n_valid", "bq", "bn", "interpret")
)
def fused_topk_pallas(
    q: jax.Array,
    x: jax.Array,
    *,
    k: int,
    metric: str,
    n_valid: int,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
    mask: jax.Array | None = None,
):
    """[Q, d] x [N, d] -> ([Q, k] f32 scores, [Q, k] i32 ids), streaming.

    Rows with global id >= n_valid (padding) are masked in-kernel; an
    optional [N] ``mask`` (nonzero = allowed) ANDs into the same fence.
    """
    return _fused_call(_TILE_FNS[(metric, False)], [q], x,
                       k=k, n_valid=n_valid, bq=bq, bn=bn, interpret=interpret,
                       mask=mask)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "n_valid", "bq", "bn", "interpret")
)
def fused_topk4_pallas(
    q_even: jax.Array,
    q_odd: jax.Array,
    packed: jax.Array,
    *,
    k: int,
    metric: str,
    n_valid: int,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
    mask: jax.Array | None = None,
):
    """Packed-int4 variant: [Q, d/2] (x2) vs [N, d/2] uint8 -> top-k."""
    return _fused_call(_TILE_FNS[(metric, True)], [q_even, q_odd], packed,
                       k=k, n_valid=n_valid, bq=bq, bn=bn, interpret=interpret,
                       mask=mask)
