"""Pallas TPU kernel: fused ADC — in-kernel LUT scoring over PQ codes
with the running top-k of :mod:`repro.kernels.fused_topk`.

Asymmetric distance computation is ``s[q, n] = sum_m lut[q, m, c[n, m]]``
— a per-row gather the MXU cannot run directly.  With
``onehot(c)[n, m*K + j] = (c[n, m] == j)`` the same sum is one int8
contraction over the (m, j)-flattened axis:

    s = lut2d . onehot(c)^T          # [bq, M*K] x [bn, M*K] -> [bq, bn]

Bolt / Quick-ADC's gather-in-register discipline recast as a matmul: the
int8-quantized LUT block ([bq, M*K]; Eq. 1 abs-max per query's table —
see ``engine.quantize_pq_lut``) stays VMEM-resident across
every corpus tile of a query row (its index map is constant in the
corpus grid axis), the one-hot is a VPU compare over the streamed codes,
and accumulation is exact int32.

4-bit codebooks (K = 16) stream *packed* — two codewords per byte — and
are shift-masked into nibble planes in-kernel.  The (even, odd) subspace
split of :mod:`repro.kernels.packed` applies unchanged: lo nibbles hold
even subspaces, hi nibbles odd ones, so the two planes contract against
the even/odd LUT halves with no in-kernel interleave:

    s = lut_even . onehot(lo)^T + lut_odd . onehot(hi)^T

The scored tile feeds the k-step select-and-mask merge of
``fused_topk`` (the [bq, k] best set rides in the output block), so the
[Q, N] ADC matrix never exists in HBM.  Pure-jnp oracles live in
:mod:`repro.kernels.ref` (``adc_ref`` / ``adc4_ref``) and deliberately
share no code with this module.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_topk import _fused_call

BQ = 64    # query rows per tile (each carries an M*K-entry LUT block)
BN = 512   # corpus code rows per tile


def _onehot_codes(codes: jax.Array, n_codewords: int) -> jax.Array:
    """[bn, M] uint codewords -> [bn, M*K] int8 one-hot, m-major flatten."""
    c = codes.astype(jnp.int32)[:, :, None]
    j = jax.lax.broadcasted_iota(
        jnp.int32, (codes.shape[0], codes.shape[1], n_codewords), 2
    )
    return (c == j).astype(jnp.int8).reshape(codes.shape[0], -1)


def _dot_i32(lut2d: jax.Array, onehot: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        lut2d, onehot,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def make_adc_tile(n_codewords: int):
    """(lut2d [bq, M*K] int8, codes [bn, M] uint8) -> [bq, bn] int32."""

    def tile(lut2d: jax.Array, codes: jax.Array) -> jax.Array:
        return _dot_i32(lut2d, _onehot_codes(codes, n_codewords))

    return tile


def make_adc4_tile(n_codewords: int):
    """Packed variant: (lut_even, lut_odd [bq, (M/2)*K] int8,
    packed [bn, M/2] uint8) -> [bq, bn] int32."""

    def tile(lut_even: jax.Array, lut_odd: jax.Array,
             packed: jax.Array) -> jax.Array:
        lo = packed & 0x0F
        hi = (packed >> 4) & 0x0F
        return (_dot_i32(lut_even, _onehot_codes(lo, n_codewords))
                + _dot_i32(lut_odd, _onehot_codes(hi, n_codewords)))

    return tile


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_codewords", "n_valid", "bq", "bn", "interpret"),
)
def fused_adc_pallas(
    lut2d: jax.Array,
    codes: jax.Array,
    *,
    k: int,
    n_codewords: int,
    n_valid: int,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
    mask: jax.Array | None = None,
):
    """[Q, M*K] int8 LUT x [N, M] uint8 codes -> ([Q, k] f32, [Q, k] i32).

    Streaming fused ADC + top-k; rows with id >= ``n_valid`` (padding)
    are masked in-kernel, as is an optional [N] predicate ``mask``.
    """
    return _fused_call(make_adc_tile(n_codewords), [lut2d], codes,
                       k=k, n_valid=n_valid, bq=bq, bn=bn,
                       interpret=interpret, mask=mask)


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_codewords", "n_valid", "bq", "bn", "interpret"),
)
def fused_adc4_pallas(
    lut_even: jax.Array,
    lut_odd: jax.Array,
    packed: jax.Array,
    *,
    k: int,
    n_codewords: int,
    n_valid: int,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
    mask: jax.Array | None = None,
):
    """Packed-nibble variant: [Q, (M/2)*K] int8 LUT planes x [N, M/2]
    uint8 packed codes -> top-k, unpacking two-codewords-per-byte
    in-kernel."""
    return _fused_call(make_adc4_tile(n_codewords), [lut_even, lut_odd],
                       packed, k=k, n_valid=n_valid, bq=bq, bn=bn,
                       interpret=interpret, mask=mask)
