"""Pallas TPU kernels: int4 *unpack-in-kernel* scoring over bit-packed codes.

The paper's B=4 arm stored at honest width: two 4-bit codes per byte
(`core.pack`), unpacked with a VPU shift-mask *inside* the kernel so the
packed corpus streams HBM -> VMEM at half the int8 byte volume and the
full-width codes never exist in HBM at all (Quick-ADC / Bolt's
unpack-in-register discipline).

Layout trick: a packed byte holds dims (2t, 2t+1) as (lo, hi) nibbles, so

    q . unpack(x)  =  q_even . lo  +  q_odd . hi

The wrapper (ops.qmip4 / ops.ql24) pre-splits the *query* codes into the
even/odd halves once per batch; the kernel then runs two (BQ, d/2) x
(BN, d/2) int8 MXU passes per tile instead of materializing the
interleaved (BN, d) tile — no in-kernel shuffle, just mask/shift/sub on
the streamed bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128   # query rows per tile
BN = 512   # corpus rows per tile


def unpack_nibbles(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """uint8 tile -> (lo, hi) int8 nibble planes in [-8, 7] (VPU shift-mask)."""
    lo = (x & 0x0F).astype(jnp.int8) - 8
    hi = ((x >> 4) & 0x0F).astype(jnp.int8) - 8
    return lo, hi


def _dot_i32(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a, b,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def qmip4_tile(qe: jax.Array, qo: jax.Array, x: jax.Array) -> jax.Array:
    """(BQ, d/2) int8 query halves x (BN, d/2) uint8 packed -> (BQ, BN)
    int32 MIP.  Values in, values out — shared by the score-matrix kernel
    here and the fused score+top-k kernel."""
    lo, hi = unpack_nibbles(x)
    return _dot_i32(qe, lo) + _dot_i32(qo, hi)


def ql24_tile(qe: jax.Array, qo: jax.Array, x: jax.Array) -> jax.Array:
    """Packed-int4 negated-squared-L2 tile (see :func:`qmip4_tile`)."""
    lo, hi = unpack_nibbles(x)
    dot = _dot_i32(qe, lo) + _dot_i32(qo, hi)
    qe32 = qe.astype(jnp.int32)
    qo32 = qo.astype(jnp.int32)
    qq = jnp.sum(qe32 * qe32 + qo32 * qo32, axis=-1, keepdims=True)  # (BQ, 1)
    lo32 = lo.astype(jnp.int32)
    hi32 = hi.astype(jnp.int32)
    xx = jnp.sum(lo32 * lo32 + hi32 * hi32, axis=-1)[None, :]        # (1, BN)
    return -(qq + xx - 2 * dot)


def _qmip4_kernel(qe_ref, qo_ref, x_ref, o_ref):
    """One (BQ, BN) int32 MIP tile over packed int4 corpus codes."""
    o_ref[...] = qmip4_tile(qe_ref[...], qo_ref[...], x_ref[...])


def _ql24_kernel(qe_ref, qo_ref, x_ref, o_ref):
    """One (BQ, BN) int32 negated-squared-L2 tile over packed int4 codes."""
    o_ref[...] = ql24_tile(qe_ref[...], qo_ref[...], x_ref[...])


def _packed_call(kernel, q_even, q_odd, packed, *, bq, bn, interpret):
    Q, half = q_even.shape
    N, half2 = packed.shape
    assert half == half2, (half, half2)
    assert Q % bq == 0 and N % bn == 0, (Q, N, bq, bn)
    grid = (Q // bq, N // bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, half), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, half), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, half), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Q, N), jnp.int32),
        interpret=interpret,
    )(q_even, q_odd, packed)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def qmip4_pallas(
    q_even: jax.Array,
    q_odd: jax.Array,
    packed: jax.Array,
    *,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """[Q, d/2] int8 (x2) vs [N, d/2] uint8 packed -> [Q, N] int32 MIP."""
    return _packed_call(_qmip4_kernel, q_even, q_odd, packed,
                        bq=bq, bn=bn, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bq", "bn", "interpret"))
def ql24_pallas(
    q_even: jax.Array,
    q_odd: jax.Array,
    packed: jax.Array,
    *,
    bq: int = BQ,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """[Q, d/2] int8 (x2) vs [N, d/2] uint8 packed -> [Q, N] int32 neg-L2."""
    return _packed_call(_ql24_kernel, q_even, q_odd, packed,
                        bq=bq, bn=bn, interpret=interpret)
