"""Pallas TPU kernel: Eq. 1 clamped-linear quantization, fp32 -> int8.

Corpus compression is a pure streaming elementwise pass: each (BN, d) fp32
tile is read HBM -> VMEM once, mapped through

    q = clip(round(2^B * (x - k) / (S_e - S_b)), -2^(B-1), 2^(B-1)-1)

with the per-dimension constants (k, S_b, S_e) held VMEM-resident across
the whole grid (their BlockSpec index_map is constant), and written back as
int8 — a 4x reduction in bytes written vs bytes read, perfectly
memory-bound, so the only tiling concern is using full-lane (*, d) tiles to
keep the VPU busy between DMAs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 1024  # rows per tile — elementwise, so just big enough to hide DMA.


def _quantize_kernel(x_ref, lo_ref, hi_ref, zero_ref, o_ref, *, bits: int):
    x = x_ref[...]                       # (BN, d) f32
    lo = lo_ref[...]                     # (1, d) f32
    hi = hi_ref[...]
    zero = zero_ref[...]
    span = jnp.maximum(hi - lo, 1e-12)
    q = jnp.round((2.0**bits) * (x - zero) / span)
    qmin = -(2 ** (bits - 1))
    qmax = 2 ** (bits - 1) - 1
    o_ref[...] = jnp.clip(q, qmin, qmax).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "bn", "interpret"))
def quantize_pallas(
    x: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    zero: jax.Array,
    *,
    bits: int = 8,
    bn: int = BN,
    interpret: bool = False,
) -> jax.Array:
    """[N, d] f32 + per-dim constants -> [N, d] int8 codes (Eq. 1)."""
    N, d = x.shape
    assert N % bn == 0, (N, bn)
    assert bits <= 8, "this kernel stores int8; use core.quant for wider codes"

    # Params ride along as (1, d) so they get a proper 2-D BlockSpec.
    lo2, hi2, zero2 = (a.reshape(1, d).astype(jnp.float32) for a in (lo, hi, zero))

    grid = (N // bn,)
    const_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            const_spec,
            const_spec,
            const_spec,
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), jnp.int8),
        interpret=interpret,
    )(x.astype(jnp.float32), lo2, hi2, zero2)
