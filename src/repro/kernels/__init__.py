# Pallas TPU kernels for the paper's compute hot-spots:
#   qmip/ql2       — fused int8 MIP / negated-L2 scoring (the query hot path)
#   qmip4/ql24     — int4 unpack-in-kernel variants over bit-packed codes
#   fused_topk     — streaming corpus scan + running top-k (no [Q, N] in HBM)
#   fused_adc_topk — streaming ADC over PQ codes: in-kernel LUT scoring
#                    (one-hot MXU contraction, packed-nibble unpack) + top-k
#   quantize       — Eq. 1 clamped-linear fp32 -> int8/int4 corpus compression
# Each has a pure-jnp oracle in ref.py; ops.py is the public jit'd surface.
from repro.kernels.ops import (
    fused_adc_topk,
    fused_topk,
    qmip,
    qmip4,
    ql2,
    ql24,
    quantize,
)

__all__ = ["qmip", "qmip4", "ql2", "ql24", "fused_topk", "fused_adc_topk",
           "quantize"]
