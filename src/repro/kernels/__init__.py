# Pallas TPU kernels for the paper's compute hot-spots:
#   qmip     — fused int8 maximum-inner-product scoring (the query hot path)
#   ql2      — fused int8 negated squared-L2 scoring
#   quantize — Eq. 1 clamped-linear fp32 -> int8 corpus compression
# Each has a pure-jnp oracle in ref.py; ops.py is the public jit'd surface.
from repro.kernels.ops import qmip, ql2, quantize

__all__ = ["qmip", "ql2", "quantize"]
