# Mutable segmented indexes (DESIGN.md §10): an LSM-style wrapper that
# puts upsert/delete behind every registered index kind.  A fp32 Memtable
# absorbs writes; sealing builds an immutable quantized Segment (an inner
# index instance with its own row-id base and per-segment Eq. 1
# constants); the Manifest tracks segments + tombstones and drives
# save/load; the Compactor merges small segments, drops tombstoned rows
# and re-quantizes when the live distribution has drifted from a
# segment's calibration (core.stats.calibration_drift over the
# StreamingStats insert tracker).  MutableIndex ties it together and is
# registered as factory prefix ``stream(<inner factory>)[+rN]``.
from repro.stream.compactor import CompactionPolicy, Compactor
from repro.stream.manifest import Manifest
from repro.stream.memtable import Memtable
from repro.stream.mutable import MutableIndex
from repro.stream.segment import Segment

__all__ = [
    "Memtable",
    "Segment",
    "Manifest",
    "Compactor",
    "CompactionPolicy",
    "MutableIndex",
]
