"""The manifest: the authoritative record of a mutable index's segments.

LSM bookkeeping in one place: the ordered segment list (order fixes the
internal id space — segment j's rows live at ``base_j .. base_j+n_j-1``
with ``base_j = sum(n_i, i<j)``), the tombstone totals, an ``epoch``
counter bumped on every structural change (seal / compact / load) so
planned Searchers can tell they are stale, and the (arrays, meta)
assembly that drives save/load.  Deletes fan out to every segment's
tombstone bitmap through here.
"""

from __future__ import annotations

from typing import Any, Iterable

import numpy as np

from repro.stream.segment import Segment


class Manifest:
    def __init__(self, segments: Iterable[Segment] = ()):
        self.segments: list[Segment] = list(segments)
        self.epoch = 0

    def bump(self) -> None:
        self.epoch += 1

    # -- id space ----------------------------------------------------------
    def bases(self) -> list[int]:
        out, base = [], 0
        for seg in self.segments:
            out.append(base)
            base += seg.n
        return out

    @property
    def total_rows(self) -> int:
        return sum(seg.n for seg in self.segments)

    @property
    def live_rows(self) -> int:
        return sum(seg.live_count for seg in self.segments)

    @property
    def tombstones(self) -> int:
        return sum(seg.dead_count for seg in self.segments)

    def memory_bytes(self) -> int:
        return sum(seg.memory_bytes() for seg in self.segments)

    # -- mutation ----------------------------------------------------------
    def add(self, segment: Segment) -> None:
        self.segments.append(segment)
        self.bump()

    def replace(self, old: list[Segment], new: list[Segment]) -> None:
        """Swap a compacted group for its merged result, preserving the
        position of the group's first member (id-space order stays the
        arrival order of the surviving rows)."""
        if not old:
            raise ValueError("empty compaction group")
        at = self.segments.index(old[0])
        keep = [s for s in self.segments if s not in old]
        self.segments = keep[:at] + list(new) + keep[at:]
        self.bump()

    def delete(self, ids) -> int:
        """Tombstone ``ids`` in every segment; returns rows killed."""
        hit = 0
        for seg in self.segments:
            hit += seg.delete(ids)
        if hit:
            self.bump()
        return hit

    # -- concatenated segment-side views (search-plan assembly) ------------
    def id_map(self) -> np.ndarray:
        if not self.segments:
            return np.empty((0,), np.int64)
        return np.concatenate([seg.ext_ids for seg in self.segments])

    def live_map(self) -> np.ndarray:
        if not self.segments:
            return np.empty((0,), bool)
        return np.concatenate([seg.live for seg in self.segments])

    def raw_concat(self) -> np.ndarray:
        """All segment payloads stacked in id-space order (merge store)."""
        return np.concatenate([seg.raw for seg in self.segments])

    # -- disk round-trip ---------------------------------------------------
    def state(self) -> tuple[dict[str, Any], dict[str, Any]]:
        arrays: dict[str, Any] = {}
        meta: dict[str, Any] = {"n_segments": len(self.segments)}
        for i, seg in enumerate(self.segments):
            a, m = seg.state(f"seg{i}_")
            arrays.update(a)
            meta.update(m)
        return arrays, meta

    @staticmethod
    def from_state(arrays, meta) -> "Manifest":
        return Manifest(
            Segment.from_state(arrays, meta, f"seg{i}_")
            for i in range(int(meta["n_segments"]))
        )
