"""A sealed, immutable run of the mutable index.

A ``Segment`` is one inner-index instance (any registered kind, built
through the ordinary registry path so ``stream(hnsw32,lpq8)`` really is
an HNSW per segment) over a frozen batch of rows, plus everything the
stream layer needs around it:

  * ``raw``       the fp32 source payload — the LSM source of truth.
                  Kept so compaction can *re-quantize* (Eq. 1 constants
                  are data-driven; codes cannot be re-calibrated without
                  the originals) and so the merge/rerank stage has an
                  exact store to re-score candidates against.
  * ``ext_ids``   external id per row (internal ids are positional; the
                  manifest assigns each segment a row-id base).
  * ``live``      the tombstone bitmap: deletes and shadowing upserts
                  flip rows dead; rows only physically disappear at
                  compaction.
  * ``calib``     ``DimStats`` of the rows the quantizer was fit on —
                  what ``calibration_drift`` compares against the live
                  insert distribution to decide re-quantization.
"""

from __future__ import annotations

import io
from typing import Any, Optional

import jax
import numpy as np

from repro.core import stats as St
from repro.stream.memtable import as_id_array


class Segment:
    """Immutable rows + inner index; only the tombstone bitmap mutates."""

    def __init__(
        self,
        index: Any,
        raw: np.ndarray,
        ext_ids: np.ndarray,
        calib: St.DimStats,
        live: Optional[np.ndarray] = None,
    ):
        self.index = index
        self.raw = np.asarray(raw, np.float32)
        self.ext_ids = as_id_array(ext_ids)
        self.live = (np.ones(self.raw.shape[0], bool)
                     if live is None else np.asarray(live, bool).copy())
        self.calib = calib
        if not (self.raw.shape[0] == self.ext_ids.shape[0] == self.live.shape[0]
                == index.n):
            raise ValueError(
                f"segment row mismatch: raw={self.raw.shape[0]} "
                f"ids={self.ext_ids.shape[0]} live={self.live.shape[0]} "
                f"index.n={index.n}"
            )

    # -- construction ------------------------------------------------------
    @staticmethod
    def seal(
        vectors: np.ndarray,
        ext_ids: np.ndarray,
        inner_spec,
        *,
        key: jax.Array,
        calib: Optional[St.DimStats] = None,
    ) -> "Segment":
        """Freeze a row batch into a segment: build the inner index (which
        learns this segment's own Eq. 1 constants unless ``inner_spec``
        carries pre-learned ones) and record the calibration stats."""
        from repro.knn import registry

        vectors = np.asarray(vectors, np.float32)
        index = registry.make_index(inner_spec, vectors, key=key)
        if calib is None:
            calib = St.corpus_stats(vectors)
        return Segment(index, vectors, ext_ids, calib)

    # -- accounting --------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.raw.shape[0])

    @property
    def live_count(self) -> int:
        return int(self.live.sum())

    @property
    def dead_count(self) -> int:
        return self.n - self.live_count

    def drift(self, live_stats: St.DimStats) -> float:
        """How far the live insert distribution has moved since this
        segment's quantizer was calibrated."""
        return St.calibration_drift(self.calib, live_stats)

    def memory_bytes(self) -> int:
        return int(self.index.memory_bytes()) + int(
            self.raw.nbytes + self.ext_ids.nbytes + self.live.nbytes
        )

    # -- mutation (tombstones only) ---------------------------------------
    def delete(self, ids) -> int:
        """Tombstone rows whose external id is in ``ids``; returns how
        many rows were newly killed."""
        mask = np.isin(self.ext_ids, as_id_array(ids)) & self.live
        self.live[mask] = False
        return int(mask.sum())

    def survivors(self) -> tuple[np.ndarray, np.ndarray]:
        """(vectors, ext_ids) of live rows, in segment row order."""
        return self.raw[self.live].copy(), self.ext_ids[self.live].copy()

    # -- disk round-trip fragments ----------------------------------------
    def state(self, prefix: str) -> tuple[dict[str, Any], dict[str, Any]]:
        """(arrays, meta) fragments for the manifest npz: the inner index
        is embedded as its own npz byte-blob (save/load compose through
        file-like objects), the stream-side arrays ride alongside."""
        buf = io.BytesIO()
        self.index.save(buf)
        arrays = {
            f"{prefix}blob": np.frombuffer(buf.getvalue(), np.uint8),
            f"{prefix}raw": self.raw,
            f"{prefix}ids": self.ext_ids,
            f"{prefix}live": self.live,
        }
        arrays.update(_stats_arrays(f"{prefix}cal_", self.calib))
        return arrays, {f"{prefix}seg": {"kind": self.index.kind, "n": self.n}}

    @staticmethod
    def from_state(arrays, meta, prefix: str) -> "Segment":
        from repro.knn import registry

        sm = meta[f"{prefix}seg"]
        blob = io.BytesIO(np.asarray(arrays[f"{prefix}blob"]).tobytes())
        index = registry.get_impl(sm["kind"]).load(blob)
        return Segment(
            index,
            np.asarray(arrays[f"{prefix}raw"], np.float32),
            np.asarray(arrays[f"{prefix}ids"]),
            _stats_from_arrays(f"{prefix}cal_", arrays),
            live=np.asarray(arrays[f"{prefix}live"], bool),
        )


# -- DimStats <-> npz fragments (shared with the manifest's live stats) ----
# The canonical helpers moved to ``core.stats`` when the cascade
# subsystem's per-region constants adopted the same representation;
# these aliases keep the stream-internal import surface stable.

_STATS_FIELDS = St.STATS_FIELDS
_stats_arrays = St.stats_arrays
_stats_from_arrays = St.stats_from_arrays
