"""The write buffer of the mutable index: a host-side fp32 memtable.

Writes land here first (LSM style): ``upsert`` appends rows and
shadow-kills any previous row with the same external id, ``delete``
kills in place.  Rows live in insertion order — the order sealing and
compaction preserve, which is what makes the exact-parity property
(compact-everything == from-scratch build on the surviving rows in
arrival order) well-defined.

The memtable is deliberately plain numpy: it is the *mutable* half of
the subsystem, touched on every write, and never enters a jit — search
snapshots its live rows into an ``engine.CodeStore`` at plan time
(DESIGN.md §10).
"""

from __future__ import annotations

from typing import Iterable

import numpy as np

_INT32_MAX = np.iinfo(np.int32).max


def as_id_array(ids: Iterable[int]) -> np.ndarray:
    """Validate external ids: 1-D, non-negative, int32-representable
    (device id maps are int32; -1 is the engine's no-hit sentinel)."""
    out = np.asarray(ids, dtype=np.int64).reshape(-1)
    if out.size and (out.min() < 0 or out.max() > _INT32_MAX):
        raise ValueError(
            "external ids must be in [0, 2^31); -1 is reserved as the "
            f"no-hit sentinel (got range [{out.min()}, {out.max()}])"
        )
    return out


class Memtable:
    """Append-only fp32 row buffer with shadow-kill upsert semantics."""

    def __init__(self, d: int, threshold: int = 4096):
        if threshold <= 0:
            raise ValueError(f"seal threshold must be positive, got {threshold}")
        self.d = int(d)
        self.threshold = int(threshold)
        self.clear()

    def clear(self) -> None:
        self._vecs = np.empty((0, self.d), np.float32)
        self._ids = np.empty((0,), np.int64)
        self._live = np.empty((0,), bool)
        self._pos: dict[int, int] = {}          # ext id -> live row

    # -- accounting --------------------------------------------------------
    @property
    def rows(self) -> int:
        """Buffered rows including shadow-killed ones."""
        return int(self._ids.shape[0])

    @property
    def live_count(self) -> int:
        return len(self._pos)

    @property
    def full(self) -> bool:
        """Seal trigger: *buffered* rows, not live rows — a replace-heavy
        workload (hot keys upserted over and over) keeps live_count tiny
        while shadow-killed rows pile up, and the buffer budget is what
        bounds host memory.  Sealing drops the shadowed rows."""
        return self.rows >= self.threshold

    def memory_bytes(self) -> int:
        return int(self._vecs.nbytes + self._ids.nbytes + self._live.nbytes)

    def __contains__(self, ext_id: int) -> bool:
        return int(ext_id) in self._pos

    # -- writes ------------------------------------------------------------
    def upsert(self, ids, vectors) -> np.ndarray:
        """Append (id, vector) rows, shadow-killing any older memtable row
        with the same id.  Returns the validated id batch; tombstoning
        copies of these ids that live in *sealed segments* is the
        caller's job (MutableIndex.upsert does both)."""
        ids = as_id_array(ids)
        vectors = np.asarray(vectors, np.float32)
        if vectors.ndim != 2 or vectors.shape[1] != self.d:
            raise ValueError(
                f"vectors must be [m, {self.d}], got {tuple(vectors.shape)}"
            )
        if ids.shape[0] != vectors.shape[0]:
            raise ValueError(
                f"{ids.shape[0]} ids for {vectors.shape[0]} vectors"
            )
        if np.unique(ids).size != ids.size:
            raise ValueError("duplicate ids within one upsert batch")
        start = self.rows
        self._vecs = np.concatenate([self._vecs, vectors])
        self._ids = np.concatenate([self._ids, ids])
        self._live = np.concatenate([self._live, np.ones(ids.size, bool)])
        for off, ext in enumerate(ids.tolist()):
            old = self._pos.get(ext)
            if old is not None:                 # shadow-kill the old row
                self._live[old] = False
            self._pos[ext] = start + off
        return ids

    def delete(self, ids) -> int:
        """Kill live memtable rows for these ids; returns how many hit."""
        hit = 0
        for ext in as_id_array(ids).tolist():
            row = self._pos.pop(ext, None)
            if row is not None:
                self._live[row] = False
                hit += 1
        return hit

    # -- reads -------------------------------------------------------------
    def snapshot(self) -> tuple[np.ndarray, np.ndarray]:
        """(vectors [m, d] f32, ext_ids [m] i64) of live rows, insertion
        order — the seal/compaction/search view."""
        mask = self._live
        return self._vecs[mask].copy(), self._ids[mask].copy()
