"""Live compaction: merge small segments, drop tombstones, re-quantize
on drift.

The paper's quantization is data-driven (§3.2: per-dimension Gaussian
fit -> Eq. 1 constants), so a mutating corpus decays the
metric-preserving property: a segment sealed long ago was calibrated on
a distribution the insert stream may have left behind.  The compactor is
where that is repaired — it rewrites groups of segments into one, and
chooses between two quantization paths:

  * **reuse** — every input segment carries bit-identical Eq. 1 constants
    and none has drifted past the policy threshold: the merged segment is
    rebuilt under those same constants (cheap: no re-learn; codes for
    surviving rows are numerically identical to the inputs').
  * **recalibrate** — constants differ across inputs, or
    ``calibration_drift`` (core.stats) between a segment's calibration
    and the drift-tracked ``StreamingStats`` of the insert stream exceeds
    ``drift_threshold``: fresh constants are learned from the merged
    surviving rows (the from-scratch build path, which is exactly why
    compact-everything gives bit-parity with a from-scratch index).

Tombstoned rows are physically dropped either way; surviving rows keep
arrival order, so the internal id space stays a stable arrival log.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np

from repro.core import stats as St
from repro.stream.segment import Segment


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When to compact and when to re-quantize.

    max_segments     structural trigger: auto-compaction runs when the
                     manifest holds more than this many segments
    small_rows       segments with fewer live rows are "small" and get
                     merged first (default: the index's seal threshold)
    drift_threshold  ``calibration_drift`` above which a segment's codes
                     are considered stale and the merge re-learns Eq. 1
                     constants (~= sigmas of mean shift; see core.stats)
    """

    max_segments: int = 8
    small_rows: Optional[int] = None
    drift_threshold: float = 0.5


class Compactor:
    """Merges segment groups for a fixed inner spec (one per MutableIndex)."""

    def __init__(self, inner_factory: str, metric: str,
                 policy: CompactionPolicy,
                 inner_overrides: Optional[dict] = None):
        self.inner_factory = inner_factory
        self.metric = metric
        self.policy = policy
        self.inner_overrides = dict(inner_overrides or {})

    # -- policy ------------------------------------------------------------
    def pick_group(self, segments: list[Segment]) -> list[Segment]:
        """The next group to merge: the longest *contiguous* run of small
        segments (contiguity keeps the id space an arrival log), falling
        back to the two smallest neighbors when every segment is large.
        Empty list = nothing to do."""
        if len(segments) < 2:
            return []
        small = self.policy.small_rows or 0
        best: list[Segment] = []
        run: list[Segment] = []
        for seg in segments:
            if seg.live_count < small or seg.dead_count > 0:
                run.append(seg)
            else:
                best, run = max(best, run, key=len), []
        best = max(best, run, key=len)
        if len(best) >= 2:
            return best
        # all segments large and clean: merge the adjacent pair with the
        # fewest combined live rows
        pairs = list(zip(segments, segments[1:]))
        a, b = min(pairs, key=lambda p: p[0].live_count + p[1].live_count)
        return [a, b]

    def should_compact(self, segments: list[Segment]) -> bool:
        return len(segments) > self.policy.max_segments

    # -- mechanism ---------------------------------------------------------
    def needs_recalibration(
        self, group: list[Segment], live_stats: St.DimStats
    ) -> bool:
        params = [getattr(seg.index, "params", None) for seg in group]
        from repro.engine.store import _params_equal

        if not all(_params_equal(p, params[0]) for p in params):
            return True
        if float(live_stats.count) == 0.0:
            return False                      # no insert signal yet
        return any(
            seg.drift(live_stats) > self.policy.drift_threshold
            for seg in group
        )

    def freeze(
        self,
        group: list[Segment],
        *,
        live_stats: St.DimStats,
        recalibrate: Optional[bool] = None,
    ) -> "FrozenMerge | None":
        """Snapshot everything a merge needs from the (mutable) group:
        surviving rows, external ids, the recalibrate verdict, and — on
        the reuse path — the frozen constants + pooled calibration.

        This is the cheap, copy-only half of :meth:`merge`.  The caller
        holds the index's write lock across ``freeze`` and releases it
        before the expensive :meth:`build`, which is how background
        compaction stays off the request path (DESIGN.md §12): after
        ``freeze`` the merge is a pure function of the snapshot, immune
        to concurrent tombstones (those are re-applied at swap time).
        """
        from repro.knn.spec import parse_factory

        if recalibrate is None:
            recalibrate = self.needs_recalibration(group, live_stats)

        vecs = [v for v, _ in (seg.survivors() for seg in group)]
        ids = [seg.ext_ids[seg.live] for seg in group]
        vectors = np.concatenate(vecs)
        ext_ids = np.concatenate(ids)
        if vectors.shape[0] == 0:
            return None

        spec = parse_factory(self.inner_factory, metric=self.metric)
        if self.inner_overrides:
            spec = spec.with_overrides(**self.inner_overrides)
        calib = None
        if not recalibrate:
            params = getattr(group[0].index, "params", None)
            if params is not None:
                if spec.quant is None:
                    raise ValueError("quantized segments under an fp32 spec")
                spec = dataclasses.replace(
                    spec, quant=spec.quant.with_params(params)
                )
            # constants unchanged -> the calibration provenance is the
            # pooled calibration of the inputs, not the merged rows
            calib = group[0].calib
            for seg in group[1:]:
                calib = St.merge_stats(calib, seg.calib)
        return FrozenMerge(vectors, ext_ids, spec, calib, bool(recalibrate))

    @staticmethod
    def build(frozen: "FrozenMerge", *, key: jax.Array) -> Segment:
        """The expensive half: seal the frozen rows into the merged
        segment (inner-index build, possibly re-learning Eq. 1 constants).
        Pure w.r.t. the live index — safe to run off the write lock."""
        return Segment.seal(frozen.vectors, frozen.ext_ids, frozen.spec,
                            key=key, calib=frozen.calib)

    def merge(
        self,
        group: list[Segment],
        *,
        live_stats: St.DimStats,
        key: jax.Array,
        recalibrate: Optional[bool] = None,
    ) -> tuple[Optional[Segment], bool]:
        """Merge a segment group into one (None if nothing survives).

        Returns (segment, recalibrated).  ``recalibrate=None`` lets the
        drift policy decide (reuse only happens when the group shares
        bit-identical constants and nothing drifted); True forces a
        fresh fit (the full-compaction / exact-parity path); False
        forces reuse of ``group[0]``'s constants even across a
        mixed-constant group — deliberately unchecked, it is the
        stale-compaction arm ``bench_stream`` measures recall decay on.

        ``merge`` == ``freeze`` + ``build`` done synchronously; the
        background path calls the halves separately.
        """
        if recalibrate is None:
            recalibrate = self.needs_recalibration(group, live_stats)
        frozen = self.freeze(group, live_stats=live_stats,
                             recalibrate=recalibrate)
        if frozen is None:
            return None, bool(recalibrate)
        return self.build(frozen, key=key), frozen.recalibrated


@dataclasses.dataclass(frozen=True)
class FrozenMerge:
    """The lock-free snapshot a merge is built from (see ``freeze``)."""

    vectors: np.ndarray
    ext_ids: np.ndarray
    spec: Any
    calib: Optional[St.DimStats]
    recalibrated: bool
