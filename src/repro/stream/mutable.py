"""``MutableIndex`` — LSM-style upsert/delete behind every index kind.

Registered as kind ``"stream"`` with factory grammar
``stream(<inner factory>)[+rN]``: the inner factory names the kind each
sealed segment is built as (``stream(flat,lpq4)``, ``stream(ivf256,lpq8)``,
``stream(hnsw32,lpq8)+r32`` ...).  Writes go to a fp32 ``Memtable``;
reaching the seal threshold freezes the buffered rows into an immutable
``Segment`` (an inner-index instance with its own row-id base and
per-segment Eq. 1 constants); deletes tombstone rows wherever they live;
the ``Compactor`` merges small segments, drops tombstones and
re-quantizes when ``calibration_drift`` against the ``StreamingStats``
insert tracker exceeds the policy threshold (DESIGN.md §10).

Search is a ``multi_source_plan`` (knn/searcher.py): every segment's own
plan plus a brute-force memtable scan run inside one compiled function,
tombstones are masked at merge level, candidates from
differently-calibrated segments are re-scored in a common space against
the raw payloads (which is also the ``+rN`` rerank tail), and internal
row ids are mapped back to external ids.  **A plan — and therefore a
``Searcher`` — snapshots the index at plan time** (LSM readers pin a
manifest version); mutations become visible to the *next* plan, which is
how ``Index.search``'s one-shot path always sees fresh state.

Exact-parity invariant (the acceptance property): surviving rows keep
arrival order through seal and compaction, and full compaction re-learns
constants from exactly those rows — so ``compact(full=True)`` leaves one
segment that is bit-identical to a from-scratch inner build on
``live_items()``, and single-source search passes the inner plan's
scores/ids straight through.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import stats as St
from repro.knn import base as B
from repro.knn import registry
from repro.knn.spec import IndexSpec, QuantSpec, parse_factory, resolve_build_spec
from repro.stream.compactor import CompactionPolicy, Compactor
from repro.stream.manifest import Manifest
from repro.stream.memtable import Memtable, as_id_array
from repro.stream.segment import Segment, _stats_arrays, _stats_from_arrays

DEFAULT_SEAL_THRESHOLD = 4096


@dataclasses.dataclass
class PendingCompaction:
    """A compaction prepared off-lock, awaiting its atomic swap.

    ``group`` holds the *identity* of the input segments (the swap
    refuses to apply if any has since been replaced by a competing
    compaction), ``live_snapshot`` their tombstone bitmaps at snapshot
    time (deletes that land during the background build are re-applied
    to ``merged`` at swap time, so nothing resurrects), ``merged`` the
    built replacement (None = everything was dead), ``epoch`` the
    manifest epoch the snapshot was taken at (reporting/debugging).
    """

    group: list
    live_snapshot: list[np.ndarray]
    merged: Optional[Segment]
    recalibrated: bool
    epoch: int
    full: bool = False


@registry.register("stream")
class MutableIndex:
    """A mutable, segmented wrapper around any registered index kind."""

    #: the Searcher resolves rerank to a depth and passes it to ``plan``;
    #: the multi-source merge re-scores against the manifest's raw
    #: payloads itself (searcher.Rerank with store=None)
    handles_rerank = True

    def __init__(
        self,
        *,
        d: int,
        metric: str,
        inner_factory: str,
        seal_threshold: int = DEFAULT_SEAL_THRESHOLD,
        rerank_bits: Optional[int] = None,
        policy: Optional[CompactionPolicy] = None,
        auto_compact: bool = True,
        key: Optional[jax.Array] = None,
        manifest: Optional[Manifest] = None,
        memtable: Optional[Memtable] = None,
        live_stats: Optional[St.StreamingStats] = None,
        inner_overrides: Optional[dict] = None,
    ):
        inner = parse_factory(inner_factory, metric=metric)
        if inner.kind == "stream":
            raise ValueError("stream cannot wrap stream")
        if inner.rerank_bits is not None:
            raise ValueError(
                "per-segment rerank stores are redundant — the wrapper "
                "keeps raw payloads; put +rN on the stream spec"
            )
        self.d = int(d)
        self.metric = inner.metric
        self.inner_factory = inner.to_factory()
        self.inner_overrides = dict(inner_overrides or {})
        self.seal_threshold = int(seal_threshold)
        self.rerank_bits = rerank_bits
        self.policy = policy or CompactionPolicy(small_rows=seal_threshold)
        self.auto_compact = bool(auto_compact)
        self.manifest = manifest or Manifest()
        self.memtable = memtable or Memtable(d, seal_threshold)
        self.live_stats = live_stats or St.StreamingStats(d)
        self.compactor = Compactor(self.inner_factory, self.metric,
                                   self.policy, self.inner_overrides)
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self.counters = {"seals": 0, "compactions": 0, "recalibrations": 0,
                         "upserts": 0, "deletes": 0, "swap_conflicts": 0,
                         "rerank_refreshes": 0}
        # (key, CodeStore) memo of the merge re-score store.  The payload
        # only changes when the segment set swaps (manifest epoch) or the
        # memtable ingests (upsert counter): deletes flip live bitmaps,
        # not raw rows, so the cached codes stay valid across them.
        self._merge_cache: Optional[tuple[tuple[int, int], engine.CodeStore]] = None
        # serializes writes/seals/compaction swaps against each other and
        # against plan-time snapshot assembly; reentrant because compact
        # -> _seal -> maybe_compact nests.  The expensive background
        # merge *build* runs outside this lock (compact_snapshot /
        # apply_compaction) — that is the off-request-path contract.
        self._lock = threading.RLock()

    # -- construction ------------------------------------------------------
    @staticmethod
    def build(
        corpus,
        spec: IndexSpec | str | None = None,
        *,
        key: jax.Array | None = None,
        metric: str = "ip",
    ) -> "MutableIndex":
        """Bulk-load ``corpus`` (external ids 0..n-1) into one sealed
        segment — so a fresh ``stream(X)`` build scores exactly like a
        plain ``X`` build until the first mutation.

        Build params (via spec/overrides): ``inner`` (inner factory,
        default ``"flat"``), ``seal_threshold``, ``max_segments``,
        ``drift_threshold``, ``auto_compact``.
        """
        spec, p = resolve_build_spec(
            "stream", spec, metric=metric, inner="flat",
            seal_threshold=DEFAULT_SEAL_THRESHOLD, max_segments=8,
            drift_threshold=0.5, auto_compact=True,
        )
        corpus = np.asarray(corpus, np.float32)
        seal_threshold = int(p["seal_threshold"])
        own = {"inner", "seal_threshold", "max_segments", "drift_threshold",
               "auto_compact", "small_rows"}
        idx = MutableIndex(
            d=corpus.shape[1],
            metric=spec.metric,
            inner_factory=p["inner"],
            seal_threshold=seal_threshold,
            rerank_bits=spec.rerank_bits,
            policy=CompactionPolicy(
                max_segments=int(p["max_segments"]),
                small_rows=int(p.get("small_rows") or seal_threshold),
                drift_threshold=float(p["drift_threshold"]),
            ),
            auto_compact=bool(p["auto_compact"]),
            key=key,
            # everything else (kmeans_iters, ef_construction, ...) rides
            # through to every inner segment build
            inner_overrides={k: v for k, v in p.items() if k not in own},
        )
        if corpus.shape[0]:
            idx.live_stats.update(jnp.asarray(corpus))
            idx.manifest.add(
                Segment.seal(corpus, np.arange(corpus.shape[0]),
                             idx._inner_spec(), key=idx._next_key())
            )
            idx.counters["seals"] += 1
        return idx

    def _inner_spec(self, params=None) -> IndexSpec:
        spec = parse_factory(self.inner_factory, metric=self.metric)
        if self.inner_overrides:
            spec = spec.with_overrides(**self.inner_overrides)
        if params is not None:
            spec = dataclasses.replace(spec,
                                       quant=spec.quant.with_params(params))
        return spec

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # -- accounting --------------------------------------------------------
    @property
    def n(self) -> int:
        """Live (searchable) rows."""
        return self.manifest.live_rows + self.memtable.live_count

    @property
    def epoch(self) -> int:
        """Manifest epoch: bumps on every structural change (seal /
        compaction swap / segment-hitting delete).  Serve's write path
        skips the session re-plan when a mutation leaves it unchanged."""
        return self.manifest.epoch

    @property
    def quantized(self) -> bool:
        return "lpq" in self.inner_factory

    @property
    def params(self):
        """Legacy view: the first segment's Eq. 1 constants (per-segment
        constants are the point of the subsystem — use ``stats()``)."""
        segs = self.manifest.segments
        return getattr(segs[0].index, "params", None) if segs else None

    @property
    def data(self):
        """Legacy view: the first segment's code payload."""
        segs = self.manifest.segments
        if not segs:
            return None
        store = getattr(segs[0].index, "store", None)
        return store.data if store is not None else None

    @property
    def codes(self):
        return self.data if self.quantized else None

    def memory_bytes(self) -> int:
        return self.manifest.memory_bytes() + self.memtable.memory_bytes()

    def stats(self) -> dict:
        """Manifest-level accounting incl. the per-segment drift metric."""
        live = self.live_stats.stats
        drifts = [seg.drift(live) for seg in self.manifest.segments]
        finite = [x for x in drifts if np.isfinite(x)]
        return {
            "kind": "stream",
            "inner": self.inner_factory,
            "segments": len(self.manifest.segments),
            "segment_rows": [seg.n for seg in self.manifest.segments],
            "rows": self.manifest.total_rows + self.memtable.live_count,
            "live": self.n,
            "tombstones": self.manifest.tombstones,
            "memtable_rows": self.memtable.live_count,
            "epoch": self.manifest.epoch,
            "drift": drifts,
            "max_drift": max(finite) if finite else 0.0,
            **self.counters,
        }

    # -- writes ------------------------------------------------------------
    def upsert(self, ids, vectors) -> int:
        """Insert-or-replace rows by external id; returns rows written.
        Replaced copies in sealed segments become tombstones; the new
        rows are searchable from the next plan."""
        with self._lock:
            vectors = np.asarray(vectors, np.float32)
            ids = self.memtable.upsert(ids, vectors)
            self.manifest.delete(ids)            # shadow sealed copies
            self.live_stats.update(jnp.asarray(vectors))
            self.counters["upserts"] += int(ids.size)
            while self.memtable.full:
                self._seal()
            return int(ids.size)

    def delete(self, ids) -> int:
        """Tombstone rows by external id wherever they live; returns how
        many live rows were deleted."""
        with self._lock:
            ids = as_id_array(ids)
            hit = self.memtable.delete(ids) + self.manifest.delete(ids)
            self.counters["deletes"] += hit
            return hit

    def _seal(self) -> None:
        with self._lock:
            vecs, ids = self.memtable.snapshot()
            self.memtable.clear()
            if not vecs.shape[0]:
                return
            self.manifest.add(
                Segment.seal(vecs, ids, self._inner_spec(),
                             key=self._next_key())
            )
            self.counters["seals"] += 1
            if self.auto_compact:
                self.maybe_compact()

    # -- compaction --------------------------------------------------------
    def seal(self) -> None:
        """Flush the memtable into a segment now (below-threshold seal)."""
        self._seal()

    def maybe_compact(self) -> bool:
        """One policy-driven compaction round, if the manifest calls for
        it (> max_segments).  Returns whether a merge ran."""
        if not self.compactor.should_compact(self.manifest.segments):
            return False
        return self.compact()

    def compact(self, full: bool = False,
                recalibrate: Optional[bool] = None) -> bool:
        """Merge segments: the picked group (policy), or — with ``full``
        — the memtable plus *every* segment into one.

        ``recalibrate`` None lets the drift policy decide (full
        compaction defaults to True: re-learn Eq. 1 constants from
        exactly the surviving rows — the from-scratch-parity path);
        False forces constant reuse (the stale arm bench_stream measures
        against).  Returns whether anything changed.

        This is the synchronous (caller-blocking) path; the serving loop
        uses :meth:`compact_snapshot` + :meth:`apply_compaction` to run
        the merge build off the request path."""
        with self._lock:
            if full:
                self._seal()
                group = list(self.manifest.segments)
                if not group:
                    return False
                merged, recal = self.compactor.merge(
                    group, live_stats=self.live_stats.stats,
                    key=self._next_key(),
                    recalibrate=True if recalibrate is None else recalibrate,
                )
            else:
                group = self.compactor.pick_group(self.manifest.segments)
                if not group:
                    return False
                merged, recal = self.compactor.merge(
                    group, live_stats=self.live_stats.stats,
                    key=self._next_key(), recalibrate=recalibrate,
                )
            self.manifest.replace(group, [merged] if merged else [])
            self.counters["compactions"] += 1
            self.counters["recalibrations"] += int(recal)
            return True

    # -- background compaction (snapshot -> build off-lock -> atomic swap) -
    def compact_snapshot(
        self, full: bool = False, recalibrate: Optional[bool] = None
    ) -> Optional[PendingCompaction]:
        """Phase 1+2 of background compaction: under the write lock,
        pick the group and freeze its surviving rows (+ the recalibrate
        verdict, tombstone bitmaps and epoch); then — **lock released**
        — run the expensive merge build on the frozen snapshot.

        Returns a :class:`PendingCompaction` to hand to
        :meth:`apply_compaction`, or None when there is nothing to do.
        Request-path impact is the lock hold of the copy-only freeze,
        not the inner-index build (DESIGN.md §12)."""
        with self._lock:
            if full:
                self._seal()
                group = list(self.manifest.segments)
                recal = True if recalibrate is None else recalibrate
            else:
                group = self.compactor.pick_group(self.manifest.segments)
                recal = recalibrate
            if not group:
                return None
            live_snapshot = [seg.live.copy() for seg in group]
            frozen = self.compactor.freeze(
                group, live_stats=self.live_stats.stats, recalibrate=recal
            )
            epoch = self.manifest.epoch
            key = self._next_key()
        # -- off-lock: the expensive part (inner build / Eq. 1 re-fit) ----
        if frozen is None:
            merged, recalibrated = None, bool(recal)
        else:
            merged = self.compactor.build(frozen, key=key)
            recalibrated = frozen.recalibrated
        return PendingCompaction(group=group, live_snapshot=live_snapshot,
                                 merged=merged, recalibrated=recalibrated,
                                 epoch=epoch, full=bool(full))

    def apply_compaction(self, pending: PendingCompaction) -> bool:
        """Phase 3: the atomic manifest swap.  Under the write lock,
        verify every input segment is still present (a competing
        compaction invalidates the snapshot -> False, counted as a
        ``swap_conflict``), re-apply tombstones that landed during the
        build (snapshot-live rows now dead are deleted from the merged
        segment, so concurrent deletes never resurrect), then swap the
        group for the merged segment in one ``manifest.replace``.

        Readers are never torn: a Searcher planned before the swap keeps
        serving its pinned snapshot; the next plan sees the new manifest
        (and its bumped epoch)."""
        with self._lock:
            current = self.manifest.segments
            if any(seg not in current for seg in pending.group):
                self.counters["swap_conflicts"] += 1
                return False
            merged = pending.merged
            if merged is not None:
                newly_dead = [
                    seg.ext_ids[snap & ~seg.live]
                    for seg, snap in zip(pending.group, pending.live_snapshot)
                ]
                dead_ids = np.concatenate(newly_dead) if newly_dead else None
                if dead_ids is not None and dead_ids.size:
                    merged.delete(dead_ids)
            self.manifest.replace(pending.group, [merged] if merged else [])
            self.counters["compactions"] += 1
            self.counters["recalibrations"] += int(pending.recalibrated)
            return True

    def live_items(self) -> tuple[np.ndarray, np.ndarray]:
        """(ext_ids [n], vectors [n, d]) of every live row in internal
        id-space (arrival) order — the corpus an equivalent from-scratch
        build would be given."""
        parts_v, parts_i = [], []
        for seg in self.manifest.segments:
            v, i = seg.survivors()
            parts_v.append(v)
            parts_i.append(i)
        mv, mi = self.memtable.snapshot()
        parts_v.append(mv)
        parts_i.append(mi)
        return np.concatenate(parts_i), np.concatenate(parts_v)

    # -- merge re-score store (cached) --------------------------------------
    def _merge_store_key(self) -> tuple[int, int]:
        return (int(self.manifest.epoch), int(self.counters["upserts"]))

    def _build_merge_store(self, mvecs, m: int) -> engine.CodeStore:
        """Materialize the merge re-score store over every raw payload
        (sealed segments + memtable tail).  Caller holds the lock."""
        if self.rerank_bits == 8:
            # int8 merge codes need constants learned over the union
            parts = ([self.manifest.raw_concat()]
                     if self.manifest.segments else [])
            if m:
                parts.append(mvecs)
            return QuantSpec(bits=8).build_store(
                jnp.asarray(np.concatenate(parts))
            )
        # None / 32 -> exact fp32
        return engine.CodeStore.concat(
            [engine.CodeStore.dense(jnp.asarray(seg.raw))
             for seg in self.manifest.segments]
            + ([engine.CodeStore.dense(jnp.asarray(mvecs))] if m else [])
        )

    def _merge_store_cached(self, mvecs, m: int) -> engine.CodeStore:
        key = self._merge_store_key()
        if self._merge_cache is not None and self._merge_cache[0] == key:
            return self._merge_cache[1]
        store = self._build_merge_store(mvecs, m)
        self._merge_cache = (key, store)
        self.counters["rerank_refreshes"] += 1
        return store

    def refresh_rerank_store(self) -> bool:
        """Eagerly rebuild the merge re-score store if stale (the
        maintenance scheduler calls this after a compaction swap, so the
        rebuild cost lands in the background pass, not the next query's
        plan).  Returns True when a rebuild actually happened."""
        with self._lock:
            key = self._merge_store_key()
            if self._merge_cache is not None and self._merge_cache[0] == key:
                return False
            mvecs, _mids = self.memtable.snapshot()
            m = int(mvecs.shape[0])
            if not self.manifest.segments and not m:
                return False
            self._merge_cache = (key, self._build_merge_store(mvecs, m))
            self.counters["rerank_refreshes"] += 1
            return True

    # -- query -------------------------------------------------------------
    def placement(self, n_shards: int):
        """Segments are the natural shard unit of a stream index: each
        carries its own row-id base, so assigning whole segments to
        shards keeps the gid arithmetic local.  The memtable (when
        non-empty) rides along as one more unit."""
        from repro.dist.placement import Placement

        with self._lock:
            rows = [int(seg.n) for seg in self.manifest.segments]
            mvecs, _ = self.memtable.snapshot()
            if int(mvecs.shape[0]):
                rows.append(int(mvecs.shape[0]))
        if not rows:
            rows = [0]
        return Placement.segments(rows, n_shards)

    def plan(
        self,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        mesh=None,
        placement=None,
        rerank_depth: Optional[int] = None,
    ):
        """Snapshot the manifest + memtable into a multi-source runner.

        Each sealed segment contributes its inner kind's own plan at
        depth ``(rerank_depth or k) + dead(segment)`` (over-fetch covers
        tombstone masking), the memtable a flat fp32 scan; the merge
        re-scores candidates against the raw payloads at ``rerank_bits``
        precision whenever there is more than one source or an explicit
        rerank depth (see ``knn.searcher.multi_source_plan``).

        Under a mesh every source plans against the full mesh (each
        segment's inner kind shards its own rows/lists), and the merge +
        rescore stay replicated inside the same jit — no host round-trip
        between a shard scan and the cross-source merge.
        """
        from repro.knn.flat import FlatIndex
        from repro.knn.searcher import multi_source_plan

        if placement is not None and placement.kind != "segments":
            raise ValueError(
                "stream shards place whole segments; got a "
                f"{placement.kind!r} placement")
        sp = params or B.SearchParams()
        depth = rerank_depth or k
        # the whole snapshot assembly holds the write lock: a background
        # compaction swap must never interleave between reading the
        # segment list and the concatenated id/live/raw views
        with self._lock:
            # manifest-side concatenated views + the memtable tail (all
            # np.concatenate copies: a frozen snapshot of the bitmaps)
            mvecs, mids = self.memtable.snapshot()
            m = int(mvecs.shape[0])
            id_map_np = self.manifest.id_map()
            live_np = self.manifest.live_map()
            if m:
                id_map_np = np.concatenate([id_map_np, mids])
                live_np = np.concatenate([live_np, np.ones(m, bool)])

            # filter (DESIGN.md §16): the predicate is over EXTERNAL ids,
            # but segment-local plans speak segment-local rows — so the
            # filter is stripped from the inner plans and composed with
            # the tombstone bitmap at merge level instead (filter ∧ live,
            # one internal-space bitmap: a filtered row is masked exactly
            # like a dead one)
            fstats = {}
            if sp.filter is not None:
                horizon = (int(id_map_np.max()) + 1 if id_map_np.size else 0)
                ext_mask = np.asarray(sp.filter.aligned(horizon))
                if id_map_np.size:
                    live_np = live_np & ext_mask[id_map_np]
                fstats = {"filter_selectivity":
                          round(sp.filter.selectivity, 6)}
                sp_inner = dataclasses.replace(sp, filter=None)
            else:
                sp_inner = sp

            sources = []
            for seg, base in zip(self.manifest.segments, self.manifest.bases()):
                # over-fetch by this segment's masked rows — tombstones
                # AND filtered-out rows — so k surviving rows always
                # reach the merge on exact sources (a dead-count-only
                # inflation starves the merge under a selective filter)
                masked = int(seg.n - live_np[base:base + seg.n].sum())
                kj = min(seg.n, depth + masked)
                sources.append((seg.index.plan(kj, sp_inner, mesh=mesh),
                                base, kj))
            if m:
                base_m = self.manifest.total_rows
                masked_m = int(m - live_np[base_m:base_m + m].sum())
                k_mem = min(m, depth + masked_m)
                mem_index = FlatIndex(
                    metric=self.metric,
                    store=engine.CodeStore.dense(jnp.asarray(mvecs)),
                )
                sources.append(
                    (mem_index.plan(k_mem, sp_inner, mesh=mesh),
                     base_m, k_mem)
                )

            rescore = len(sources) > 1 or rerank_depth is not None
            merge_store = None
            if rescore and sources:
                merge_store = self._merge_store_cached(mvecs, m)

            live = self.live_stats.stats
            drifts = [seg.drift(live) for seg in self.manifest.segments]
            finite = [x for x in drifts if np.isfinite(x)]
            stats_extra = {
                "segments": len(self.manifest.segments),
                "memtable_rows": m,
                "tombstones": self.manifest.tombstones,
                "epoch": self.manifest.epoch,
                "max_drift": max(finite) if finite else 0.0,
                **fstats,
            }
        return multi_source_plan(
            sources,
            k=k,
            metric=self.metric,
            id_map=jnp.asarray(id_map_np.astype(np.int32)),
            live=jnp.asarray(live_np),
            merge_store=merge_store,
            rescore=rescore and merge_store is not None,
            stats_extra=stats_extra,
            mesh=mesh,
            placement=placement,
        )

    def searcher(self, k: int, params: Optional[B.SearchParams] = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries,
        k: int,
        params: Optional[B.SearchParams] = None,
    ) -> B.SearchResult:
        """One-shot plan-and-run over the *current* state (scores [Q, k]
        f32, external ids [Q, k] i32, -1 = no hit)."""
        from repro.knn import searcher as S

        return S.one_shot(self, queries, k, params)

    # -- disk round-trip ---------------------------------------------------
    def save(self, path) -> None:
        arrays, meta = self.manifest.state()
        mvecs, mids = self.memtable.snapshot()
        arrays.update({"mem_vecs": mvecs, "mem_ids": mids})
        arrays.update(_stats_arrays("ls_", self.live_stats.stats))
        kd = self._key
        if jnp.issubdtype(kd.dtype, jax.dtypes.prng_key):
            kd = jax.random.key_data(kd)
        arrays["rng_key"] = np.asarray(kd)
        B.save_state(path, arrays, {
            "kind": "stream",
            "metric": self.metric,
            "inner": self.inner_factory,
            "d": self.d,
            "n": self.n,
            "seal_threshold": self.seal_threshold,
            "rerank_bits": self.rerank_bits,
            "auto_compact": self.auto_compact,
            "policy": dataclasses.asdict(self.policy),
            "counters": self.counters,
            "inner_overrides": self.inner_overrides,
            **meta,
        })

    @staticmethod
    def load(path) -> "MutableIndex":
        arrays, meta = B.load_state(path)
        idx = MutableIndex(
            d=int(meta["d"]),
            metric=meta["metric"],
            inner_factory=meta["inner"],
            seal_threshold=int(meta["seal_threshold"]),
            rerank_bits=meta["rerank_bits"],
            policy=CompactionPolicy(**meta["policy"]),
            auto_compact=bool(meta["auto_compact"]),
            key=jnp.asarray(arrays["rng_key"], jnp.uint32),
            manifest=Manifest.from_state(arrays, meta),
            live_stats=St.StreamingStats(int(meta["d"])).merge(
                _stats_from_arrays("ls_", arrays)
            ),
            inner_overrides=meta.get("inner_overrides") or {},
        )
        mvecs = np.asarray(arrays["mem_vecs"], np.float32)
        if mvecs.shape[0]:
            idx.memtable.upsert(np.asarray(arrays["mem_ids"]), mvecs)
        idx.counters.update(meta["counters"])
        return idx
