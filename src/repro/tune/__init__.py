"""Kernel autotuning: measured dispatch tables instead of hardcoded
tile shapes (DESIGN.md §13).

    table      TuneConfig/TuneTable, the process-wide lookup point every
               kernel dispatch consults, fallback-constant registry,
               adoption of persisted tables (import-light: safe from
               engine/kernels/knn without cycles)
    space      per-family candidate enumeration + roofline pruning
    autotuner  the measured search itself (imports engine — load lazily)

CLI: ``python -m repro.tune --smoke --out TUNE_cpu.json``.
"""

from repro.tune.table import (  # noqa: F401
    COUNTERS,
    TuneConfig,
    TuneTable,
    active,
    active_hash,
    adopt,
    adopt_from_meta,
    clear,
    clear_pending,
    fallback,
    install,
    lookup,
    pending_mismatch,
    pinned,
    register_fallback,
    snapshot_for_plan,
)


def autotune(*args, **kwargs):
    """Lazy forward to :func:`repro.tune.autotuner.autotune` (that module
    imports the engine — eager import here would cycle)."""
    from repro.tune.autotuner import autotune as _autotune

    return _autotune(*args, **kwargs)
