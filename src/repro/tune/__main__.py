"""CLI: measure a TuneTable on the live backend and write it to JSON.

    PYTHONPATH=src python -m repro.tune --smoke --out TUNE_cpu.json

The emitted file carries the measuring process's runtime-profile stamp;
``launch/serve.py --tune TUNE_cpu.json`` adopts it (stamp-checked) and
``trend.py`` refuses to compare artifacts across different table hashes.
"""

from __future__ import annotations

import argparse

from repro.runtime import profile as rtprofile


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI shapes (parity-first, minutes not hours)")
    ap.add_argument("--out", default="TUNE.json")
    ap.add_argument("--profile", default=None,
                    help="runtime profile to apply before measuring")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=None,
                    help="override the profile's seed policy")
    args = ap.parse_args(argv)

    rtprofile.apply(rtprofile.resolve(args.profile))
    from repro.tune.autotuner import autotune

    table = autotune(smoke=args.smoke, seed=args.seed, repeats=args.repeats,
                     verbose=True)
    table.to_json(args.out)
    print(f"[tune] wrote {args.out}: {len(table.entries)} entries, "
          f"hash {table.table_hash()}, "
          f"backend {table.stamp['backend']}/{table.stamp['device_kind']}")


if __name__ == "__main__":
    main()
