"""The measured autotuner: time surviving candidates on the live
backend, assert bit-parity for every one, emit a ``TuneTable``.

Per workload (DESIGN.md §13):

    1. build a deterministic store from the runtime profile's seed
       (``fold_in`` per workload — same backend + seed ⇒ same data),
    2. enumerate the family's legal candidates (:mod:`repro.tune.space`)
       and prune them with the roofline model,
    3. for each survivor: run it once, assert **bit-parity** against the
       reference-oracle score matrix (a candidate that cannot reproduce
       the oracle's top-k scores exactly is a bug, not a slow config —
       the tuner raises), then time it (warm-up + median-of-n),
    4. pick with hysteresis: keep the default-dispatch config unless a
       candidate beats it by more than ``margin`` — measurement noise
       must not flap the table between equivalent configs,
    5. record the choice (with its measured and default medians) under
       the workload's bucket key.

Parity is tie-robust: the candidate's top-k *scores* must bit-match
``lax.top_k`` of the full oracle matrix, and every returned id must
point at a row whose oracle score equals the returned score — int8 score
ties make id-level equality fragile across chunkings, score-level
equality is the invariant all engine paths actually guarantee.

``timer`` is injectable (same pattern as the runtime cache's clock): the
determinism tests swap in a cost-model-based fake so table construction
is a pure function of (backend, seed); parity always runs on the real
executions regardless of the timer.

Off-TPU, fused candidates run in interpret mode — their timings are
parity-only signals (README "Autotuning") and the scan baseline wins the
crossover on merit; the hysteresis rule then keeps the table honest.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import scorer
from repro.kernels import ops as K
from repro.kernels import ref as R
from repro.runtime import profile as rtprofile
from repro.tune import space as S
from repro.tune.table import TuneConfig, TuneTable, live_stamp

#: PQ subspace width the ADC tuning workloads use (dim = M * ADC_DS)
ADC_DS = 8


def default_workloads(smoke: bool = False) -> tuple[S.Workload, ...]:
    """The shapes a ``python -m repro.tune`` run measures.

    Smoke keeps fused-capable corpora small (interpret-mode fused
    candidates are 5–30× slower than the scan on CPU — the parity check
    is the point there, not the wall time) and gives the scan family an
    awkward ``n`` (20480: not a multiple of the 16384 default chunk, so
    the default scan pads to 32768 rows and the exact-fit candidate has
    a structural 1.6× less work to do).
    """
    if smoke:
        return (
            S.Workload("fused_topk", "ip", 8, 8, 1536, 32),
            S.Workload("packed", "l2", 4, 8, 1536, 32),
            S.Workload("fused_adc", "ip", 8, 8, 1536, 8),
            S.Workload("scan", "angular", 8, 8, 20480, 32),
        )
    return (
        S.Workload("fused_topk", "ip", 8, 16, 8192, 64),
        S.Workload("fused_topk", "l2", 8, 16, 8192, 64),
        S.Workload("packed", "ip", 4, 16, 8192, 64),
        S.Workload("fused_adc", "ip", 8, 16, 8192, 16),
        S.Workload("fused_adc", "ip", 4, 16, 8192, 16),
        S.Workload("scan", "angular", 8, 16, 20480, 64),
    )


def wall_timer(fn: Callable, *, cfg: TuneConfig, workload: S.Workload,
               repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds with block_until_ready (the default timer)."""
    del cfg, workload
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def estimate_timer(fn: Callable, *, cfg: TuneConfig, workload: S.Workload,
                   repeats: int = 3, warmup: int = 1) -> float:
    """Deterministic fake timer: the roofline estimate stands in for the
    wall clock (the determinism tests' injection; never the default)."""
    del fn, repeats, warmup
    return S.estimate(workload, cfg)


@dataclasses.dataclass
class _Ctx:
    """One workload's measured fixtures: the store, prepared queries (or
    the int8 ADC LUT), and the full oracle score matrix."""

    store: object
    q: Optional[jax.Array]
    lut: Optional[jax.Array]
    full: np.ndarray


def _build_ctx(w: S.Workload, key: jax.Array) -> _Ctx:
    from repro.knn import make_index

    kc, kq, kb = jax.random.split(key, 3)
    if w.kernel == "fused_adc":
        dim = w.d * ADC_DS
        corpus = jax.random.normal(kc, (w.n, dim)) * 0.1
        queries = jax.random.normal(kq, (w.q, dim)) * 0.1
        idx = make_index(f"pq{w.d}x{w.bits}+lpq", corpus, metric=w.metric,
                         kmeans_iters=2, key=kb)
        store = idx.store
        lut = jax.block_until_ready(
            scorer._prepare_pq_lut(queries, store, w.metric))
        full = (R.adc4_ref(lut, store.codes) if store.packed
                else R.adc_ref(lut, store.codes))
        return _Ctx(store, None, lut, np.asarray(full, np.float32))

    spec = "flat,lpq4" if w.bits == 4 else "flat,lpq8"
    corpus = jax.random.normal(kc, (w.n, w.d)) * 0.1
    queries = jax.random.normal(kq, (w.q, w.d)) * 0.1
    store = make_index(spec, corpus, metric=w.metric).store
    qc = store.encode_queries(queries)
    if w.metric == "ip":
        full = (R.qmip4_ref(qc, store.data) if store.packed
                else R.qmip_ref(qc, store.data))
    elif w.metric == "l2":
        full = (R.ql24_ref(qc, store.data) if store.packed
                else R.ql2_ref(qc, store.data))
    else:
        from repro.core import distances as D
        from repro.core import pack as PK

        rows = PK.unpack_int4(store.data) if store.packed else store.data
        full = D.scores(qc, rows, w.metric, quantized=store.quantized)
    return _Ctx(store, qc, None, np.asarray(full, np.float32))


def _make_runner(w: S.Workload, ctx: _Ctx, cfg: TuneConfig,
                 interp) -> Callable:
    """A zero-arg (scores, ids) thunk executing ``cfg`` on the live
    backend — exactly the executable dispatch would run for this entry."""
    k = min(w.k, w.n)
    if cfg.impl == "scan":
        chunk = cfg.chunk or S.DEFAULT_CHUNK
        if w.kernel == "fused_adc":
            return lambda: scorer._topk_pq_from_lut(
                ctx.lut, ctx.store, k, w.metric, chunk, use_pallas=False)
        return lambda: scorer._scan_topk(ctx.q, ctx.store, k, w.metric, chunk)
    if w.kernel == "fused_adc":
        return lambda: K.fused_adc_topk(
            ctx.lut, ctx.store.codes, k, packed=ctx.store.packed,
            bq=cfg.bq, bn=cfg.bn, interpret=interp)
    return lambda: K.fused_topk(
        ctx.q, ctx.store.data, k, w.metric, packed=ctx.store.packed,
        bq=cfg.bq, bn=cfg.bn, interpret=interp)


def _parity_ok(full: np.ndarray, s, i, k: int) -> bool:
    """Tie-robust bit-parity vs the oracle matrix (see module docstring)."""
    exp_s = np.asarray(jax.lax.top_k(jnp.asarray(full), k)[0])
    s = np.asarray(s)
    i = np.asarray(i)
    if not np.array_equal(s, exp_s):
        return False
    if (i < 0).any() or (i >= full.shape[1]).any():
        return False
    return np.array_equal(np.take_along_axis(full, i, axis=1), s)


def autotune(
    workloads: Optional[Sequence[S.Workload]] = None,
    *,
    smoke: bool = False,
    seed: Optional[int] = None,
    repeats: int = 3,
    warmup: int = 1,
    margin: float = 0.03,
    max_candidates: int = 10,
    prune_ratio: float = 4.0,
    timer: Optional[Callable] = None,
    verbose: bool = False,
) -> TuneTable:
    """Measure the workloads and return the resulting ``TuneTable``.

    The table is NOT installed — callers decide (``table.install`` for
    this process, ``to_json`` / ``save_state`` for persistence).
    """
    prof = rtprofile.active()
    seed = prof.seed if seed is None else int(seed)
    timer = timer or wall_timer
    workloads = (default_workloads(smoke) if workloads is None
                 else tuple(workloads))
    backend = jax.default_backend()
    interp = True if backend != "tpu" else None
    table = TuneTable(stamp=live_stamp())
    base_key = jax.random.PRNGKey(seed)

    for wi, w in enumerate(workloads):
        ctx = _build_ctx(w, jax.random.fold_in(base_key, wi))
        default_cfg = S.default_config(w, backend)
        cands = S.prune(w, S.candidates(w), ratio=prune_ratio,
                        keep=default_cfg)
        cands = sorted(cands, key=lambda c: (S.estimate(w, c), repr(c)))
        cands = cands[:max_candidates]
        if default_cfg not in cands:
            cands.append(default_cfg)

        timed: list[tuple[float, TuneConfig]] = []
        for cfg in cands:
            fn = _make_runner(w, ctx, cfg, interp)
            s, i = fn()
            if not _parity_ok(ctx.full, s, i, min(w.k, w.n)):
                raise AssertionError(
                    f"tuner candidate {cfg} failed bit-parity against the "
                    f"reference oracle on {w}"
                )
            timed.append((timer(fn, cfg=cfg, workload=w, repeats=repeats,
                                warmup=warmup), cfg))

        default_t = next(t for t, c in timed if c == default_cfg)
        best_t, best_cfg = min(timed, key=lambda tc: tc[0])
        chosen, chosen_t = default_cfg, default_t
        # hysteresis: a candidate must *clearly* beat the default — noise
        # must not flap the table (or the bench's >= 1.0 gate)
        if best_cfg != default_cfg and best_t < default_t * (1.0 - margin):
            chosen, chosen_t = best_cfg, best_t
        entry = dataclasses.replace(chosen, measured_us=chosen_t * 1e6,
                                    default_us=default_t * 1e6)
        key = table.put(w.kernel, w.metric, w.bits, w.q, w.n, w.d, entry)
        if verbose:
            print(f"[tune] {key} -> {entry.impl} bq={entry.bq} "
                  f"bn={entry.bn} chunk={entry.chunk} "
                  f"({len(timed)} candidates, chosen {chosen_t * 1e6:.0f}us "
                  f"vs default {default_t * 1e6:.0f}us)")
    return table
