"""Tuning spaces: legal tile/chunk candidates per kernel family, plus
the roofline cost model that prunes them before anything is timed.

One space per kernel family (DESIGN.md §13):

    fused_topk   streaming fused score+top-k over int8 codes
    packed       the same kernel over bit-packed int4 codes
    fused_adc    fused PQ ADC (int8 LUT block VMEM-resident)
    scan         the XLA streaming-scan formulation (the only legal
                 family for metrics the fused kernels do not cover)

Candidates come from shape constraints, not guesses: fused tiles must
land on sublane units (``SUBLANE``), the per-tile working set (query
block + corpus block + score tile + top-k carry — for ADC, the LUT block)
must fit the VMEM budget, and int8 products accumulated over ``d`` must
stay inside int32.  The fused families also enumerate ``scan`` candidates
— the fused-vs-``_stream_topk`` crossover is part of the space, so the
autotuner *measures* the decision today's dispatch hardcodes as a
backend ``if``.  Scan chunks include ``round_up(n, SUBLANE)`` alongside
the power-of-two ladder: ``_stream_topk`` pads the corpus to a chunk
multiple, so for an awkward ``n`` the exact-fit chunk eliminates pad
rows the default chunk would score and throw away.

``estimate`` is the same napkin math as ``benchmarks/roofline.py``
(which imports its hardware constants from here — one source of truth):
max(compute term, memory term) per device, with the fused re-stream
(one corpus pass per ``bq`` query rows) and the scan's pad waste both
counted as real bytes.  ``prune`` keeps candidates within ``ratio``× the
best estimate — the model is only trusted to rule out order-of-magnitude
losers; measurement decides the rest.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.tune.table import TuneConfig

#: TPU tiling units (second-to-last / last dim register granularity)
SUBLANE = 8
LANE = 128

#: hardware peaks (TPU v5e) — benchmarks/roofline.py imports these
PEAK_BF16 = 197e12
PEAK_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9

#: per-core VMEM we allow one fused tile's working set to occupy
VMEM_BUDGET = 8 * 1024 * 1024

#: kernel families a TuneTable may carry entries for
KERNELS = ("fused_topk", "packed", "fused_adc", "scan")

#: the candidate ladders (filtered by legality per workload)
BQ_CANDIDATES = (32, 64, 128, 256)
BN_CANDIDATES = (128, 256, 512, 1024, 2048)
CHUNK_CANDIDATES = (2048, 4096, 8192, 16384, 32768, 65536)

#: today's hardcoded scan chunk (SearchParams.chunk default)
DEFAULT_CHUNK = 16384
#: today's hardcoded fused corpus-tile cap (engine.scorer.FUSED_TILE)
DEFAULT_FUSED_TILE = 512

INT32_MAX = 2**31 - 1


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class Workload:
    """One tuning cell: the shape/dtype facts dispatch keys on.

    For ``fused_adc``, ``d`` is the number of PQ subspaces M (the LUT's
    middle axis) and ``bits`` the code width {4, 8} — matching how the
    dispatch lookup keys ADC workloads.
    """

    kernel: str
    metric: str
    bits: int
    q: int
    n: int
    d: int
    k: int = 10

    def __post_init__(self):
        if self.kernel not in KERNELS:
            raise ValueError(f"kernel must be one of {KERNELS}, "
                             f"got {self.kernel!r}")
        for name in ("bits", "q", "n", "d", "k"):
            v = getattr(self, name)
            if not isinstance(v, int) or v <= 0:
                raise ValueError(f"Workload.{name} must be a positive int, "
                                 f"got {v!r}")


def row_bytes(w: Workload) -> int:
    """Streamed bytes per corpus row (codes for ADC, codes for flat)."""
    if w.kernel == "fused_adc":
        return -(-w.d // 2) if w.bits == 4 else w.d
    if w.bits == 4:
        return -(-w.d // 2)
    if w.bits == 8:
        return w.d
    return 4 * w.d


def working_set_bytes(w: Workload, cfg: TuneConfig) -> int:
    """The VMEM bytes one fused grid step holds live."""
    bq, bn = cfg.bq or SUBLANE, cfg.bn or SUBLANE
    score_tile = bq * bn * 4                       # int32 accumulator tile
    carry = bq * max(w.k, SUBLANE) * 8             # running top-k (f32+i32)
    if w.kernel == "fused_adc":
        lut_block = bq * w.d * (2 ** w.bits)       # int8 LUT, VMEM-resident
        return lut_block + bn * row_bytes(w) + score_tile + carry
    q_block = bq * w.d                             # queries stay full-width
    return q_block + bn * row_bytes(w) + score_tile + carry


def accum_bound_ok(w: Workload) -> bool:
    """int32 accumulation: worst-case |sum of products| must fit."""
    if w.kernel == "fused_adc":
        return 127 * w.d < INT32_MAX               # sum of M int8 entries
    c = 2 ** (w.bits - 1) - 1
    return c * c * w.d < INT32_MAX


def legal(w: Workload, cfg: TuneConfig) -> bool:
    if cfg.impl == "scan":
        return cfg.chunk is not None and cfg.chunk % SUBLANE == 0
    if w.kernel == "scan":
        return False                               # no fused form exists
    if w.metric not in ("ip", "l2"):
        return False
    if cfg.bq is None or cfg.bn is None:
        return False
    if cfg.bq % SUBLANE or cfg.bn % SUBLANE:
        return False
    if not accum_bound_ok(w):
        return False
    return working_set_bytes(w, cfg) <= VMEM_BUDGET


def scan_chunks(w: Workload) -> tuple[int, ...]:
    """Chunk ladder for this corpus: every chunk >= n scores identical
    rows (the single-tile path), so the exact-fit ``round_up(n)`` stands
    in for all of them — and is the pad-waste killer for awkward n."""
    ladder = [c for c in CHUNK_CANDIDATES if c < w.n]
    return tuple(sorted(set(ladder + [round_up(w.n, SUBLANE)])))


def candidates(w: Workload) -> list[TuneConfig]:
    """Every legal candidate for the workload (fused grid + scan ladder
    for fused families; scan ladder only for the scan family)."""
    out: list[TuneConfig] = []
    if w.kernel != "scan":
        for bq in BQ_CANDIDATES:
            for bn in BN_CANDIDATES:
                cfg = TuneConfig("fused", bq=bq, bn=bn)
                if legal(w, cfg):
                    out.append(cfg)
    for c in scan_chunks(w):
        cfg = TuneConfig("scan", chunk=c)
        if legal(w, cfg):
            out.append(cfg)
    return out


def estimate(w: Workload, cfg: TuneConfig) -> float:
    """Roofline seconds: max(compute, memory) per device, counting the
    fused re-stream (ceil(Q/bq) corpus passes) and scan pad waste."""
    flops = 2.0 * w.q * w.n * w.d
    peak = PEAK_INT8 if w.bits <= 8 else PEAK_BF16
    if cfg.impl == "fused":
        bq = cfg.bq or SUBLANE
        bn = cfg.bn or SUBLANE
        passes = -(-w.q // bq)
        n_rows = round_up(w.n, bn) * passes
    else:
        chunk = cfg.chunk or DEFAULT_CHUNK
        n_rows = w.n if w.n <= chunk else round_up(w.n, chunk)
    mem_bytes = n_rows * row_bytes(w) + w.q * w.d
    return max(flops / peak, mem_bytes / HBM_BW)


def prune(w: Workload, cands: Sequence[TuneConfig], *, ratio: float = 4.0,
          keep: Optional[TuneConfig] = None) -> list[TuneConfig]:
    """Drop candidates the cost model says are > ``ratio``× the best
    estimate; ``keep`` (the default-dispatch config) always survives."""
    if not cands:
        return [keep] if keep is not None else []
    best = min(estimate(w, c) for c in cands)
    out = [c for c in cands if estimate(w, c) <= ratio * best]
    if keep is not None and keep not in out:
        out.append(keep)
    return out


def default_config(w: Workload, backend: Optional[str] = None) -> TuneConfig:
    """What today's table-less dispatch would run for this workload —
    the honest baseline every measured speedup is reported against.

    Mirrors ``engine.scorer``: fused on TPU for fusable metrics when the
    corpus exceeds one tile; the 16384-chunk streaming scan otherwise.
    """
    if backend is None:
        import jax

        backend = jax.default_backend()
    fusable = w.kernel != "scan" and w.metric in ("ip", "l2")
    tile = min(DEFAULT_FUSED_TILE, max(SUBLANE, DEFAULT_CHUNK))
    if fusable and backend == "tpu" and w.n > tile:
        from repro.tune import table as T

        fb = T.fallback(w.kernel)
        return TuneConfig("fused", bq=fb.bq, bn=tile)
    return TuneConfig("scan", chunk=DEFAULT_CHUNK)
