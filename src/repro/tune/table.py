"""Measured dispatch tables: ``TuneTable`` + the process-wide lookup
point every kernel dispatch consults (DESIGN.md §13).

Every tile shape in the hot path so far was a hardcoded guess
(``fused_topk.BQ = 128``, ``FUSED_TILE = 512``, ``chunk = 16384``) that
no measurement ever revisited — and the fused-vs-scan decision was a
backend ``if``, not a measured crossover.  A ``TuneTable`` replaces both
with *measured facts*: a mapping from

    (backend, device_kind, kernel, metric, bits, Q-bucket, N-bucket,
     d-bucket)  ->  TuneConfig(impl, bq, bn, chunk)

produced by :mod:`repro.tune.autotuner` on the live backend, where every
candidate was bit-parity-checked against the reference oracle before it
was timed.  Dispatch precedence is **tuned > fallback constants**: when
no entry matches (or no table is installed, or the table was measured on
a different backend), callers fall back to the registered default rows —
exactly today's constants — and the miss is counted, never raised.

Shape buckets are powers of two (``bucket(40960) == 65536``): a table
tuned at one shape per bucket serves every shape in the bucket, and the
bucket boundaries align with the jit specialization callers already pay.

Tables persist two ways: standalone JSON (``to_json``/``from_json``,
stamped with the runtime-profile facts of the machine that measured
them) and embedded in the npz of saved indexes (``knn.base.save_state``
attaches the active table; ``registry.load_index`` re-adopts it).  An
adopted table whose stamp does not match the serving backend is *not*
installed — it is parked as the pending-mismatch table (a counter, not a
crash) for the maintenance scheduler's low-priority re-tune trigger.

``table_hash`` covers the dispatch-relevant content only (stamp backend
facts + per-entry impl/tile choices, **not** the measured timings), so
two tunings that dispatch identically hash identically — this is the
hash ``runtime.profile.stamp()`` exposes and ``benchmarks/trend.py``
keys comparability on.

Thread-safety: installation and ``pinned`` mutate one module-level slot;
lookups happen at trace time on the serving thread.  The maintenance
thread only ever *installs* a freshly built table (atomic rebind).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import hashlib
import json
from typing import Any, Optional

#: dispatch implementations a table entry can choose between
IMPLS = ("fused", "scan")

#: stamp keys two tables/backends must agree on to be interchangeable
STAMP_KEYS = ("backend", "device_kind", "interpret")

TABLE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """One chosen kernel configuration.

    impl         "fused" (Pallas streaming kernel) or "scan" (the XLA
                 streaming-scan formulation)
    bq / bn      fused query/corpus tile rows (None = family fallback)
    chunk        scan chunk rows (None = caller's / fallback chunk)
    measured_us  median wall time the autotuner measured for this config
    default_us   median wall time of the default-dispatch config on the
                 same workload (the honest speedup denominator)

    Frozen + primitive-typed so a config can ride through ``jax.jit`` as
    a static argument.
    """

    impl: str
    bq: Optional[int] = None
    bn: Optional[int] = None
    chunk: Optional[int] = None
    measured_us: Optional[float] = None
    default_us: Optional[float] = None

    def __post_init__(self):
        if self.impl not in IMPLS:
            raise ValueError(f"impl must be one of {IMPLS}, got {self.impl!r}")
        for name in ("bq", "bn", "chunk"):
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v <= 0):
                raise ValueError(f"TuneConfig.{name} must be a positive int "
                                 f"or None, got {v!r}")

    def dispatch_dict(self) -> dict[str, Any]:
        """The hash-relevant subset: what the config *does*, not how
        fast it measured."""
        return {"impl": self.impl, "bq": self.bq, "bn": self.bn,
                "chunk": self.chunk}

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "TuneConfig":
        known = {f.name for f in dataclasses.fields(TuneConfig)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown TuneConfig fields: {sorted(unknown)}")
        return TuneConfig(**d)


def bucket(x: int) -> int:
    """Power-of-two shape bucket: the smallest 2**i >= x (min 1)."""
    x = max(1, int(x))
    return 1 << (x - 1).bit_length()


def key_for(backend: str, device_kind: str, kernel: str, metric: str,
            bits: int, q: int, n: int, d: int) -> str:
    """The canonical entry key — backend facts + kernel family + metric +
    storage width + bucketed shape."""
    return (f"{backend}|{device_kind}|{kernel}|{metric}|{bits}"
            f"|q{bucket(q)}|n{bucket(n)}|d{bucket(d)}")


def live_stamp() -> dict[str, Any]:
    """The backend facts of *this* process, in TuneTable stamp form."""
    from repro.runtime import profile as rtprofile

    s = rtprofile.stamp()
    return {k: s[k] for k in
            ("profile", "backend", "device_kind", "interpret",
             "jax_version", "seed")}


@dataclasses.dataclass
class TuneTable:
    """A measured dispatch table: stamp (who measured it, where) plus
    the entry mapping.  ``stamp`` must carry the :data:`STAMP_KEYS`."""

    stamp: dict[str, Any]
    entries: dict[str, TuneConfig] = dataclasses.field(default_factory=dict)
    version: int = TABLE_VERSION

    def __post_init__(self):
        missing = [k for k in STAMP_KEYS if k not in self.stamp]
        if missing:
            raise ValueError(f"TuneTable stamp is missing {missing}")

    # -- entry access ------------------------------------------------------
    def _key(self, kernel: str, metric: str, bits: int,
             q: int, n: int, d: int) -> str:
        return key_for(self.stamp["backend"], self.stamp["device_kind"],
                       kernel, metric, bits, q, n, d)

    def put(self, kernel: str, metric: str, bits: int, q: int, n: int,
            d: int, cfg: TuneConfig) -> str:
        key = self._key(kernel, metric, bits, q, n, d)
        self.entries[key] = cfg
        return key

    def get(self, kernel: str, metric: str, bits: int,
            q: int, n: int, d: int) -> Optional[TuneConfig]:
        return self.entries.get(self._key(kernel, metric, bits, q, n, d))

    def matches(self, stamp: Optional[dict] = None) -> bool:
        """Was this table measured on the backend ``stamp`` describes
        (default: the live process)?"""
        other = stamp if stamp is not None else live_stamp()
        return all(self.stamp.get(k) == other.get(k) for k in STAMP_KEYS)

    # -- identity ----------------------------------------------------------
    def table_hash(self) -> str:
        """Stable hash of the dispatch-relevant content (backend facts +
        per-entry choices; measured timings excluded, so re-measuring the
        same choices keeps the hash)."""
        doc = {
            "version": self.version,
            "stamp": {k: self.stamp.get(k) for k in STAMP_KEYS},
            "entries": {k: self.entries[k].dispatch_dict()
                        for k in sorted(self.entries)},
        }
        blob = json.dumps(doc, sort_keys=True).encode("utf-8")
        return hashlib.blake2b(blob, digest_size=8).hexdigest()

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        return {
            "version": self.version,
            "stamp": dict(self.stamp),
            "entries": {k: self.entries[k].to_dict()
                        for k in sorted(self.entries)},
        }

    @staticmethod
    def from_dict(d: dict[str, Any]) -> "TuneTable":
        if int(d.get("version", 0)) != TABLE_VERSION:
            raise ValueError(
                f"unsupported TuneTable version {d.get('version')!r} "
                f"(this build reads version {TABLE_VERSION})"
            )
        return TuneTable(
            stamp=dict(d["stamp"]),
            entries={k: TuneConfig.from_dict(v)
                     for k, v in d.get("entries", {}).items()},
            version=TABLE_VERSION,
        )

    def to_json(self, path) -> None:
        doc = json.dumps(self.to_dict(), indent=2, sort_keys=True)
        if hasattr(path, "write"):
            path.write(doc)
            return
        with open(path, "w") as f:
            f.write(doc)

    @staticmethod
    def from_json(path) -> "TuneTable":
        if hasattr(path, "read"):
            return TuneTable.from_dict(json.loads(path.read()))
        with open(path) as f:
            return TuneTable.from_dict(json.load(f))


# --------------------------------------------------------------------------
# the process-wide dispatch point
# --------------------------------------------------------------------------

#: the installed table every dispatch consults (None = fallback constants)
_ACTIVE: Optional[TuneTable] = None
#: a table adopted from a saved index whose stamp did NOT match this
#: backend — parked for the maintenance re-tune trigger, never served
_PENDING_MISMATCH: Optional[TuneTable] = None

#: lookup / adoption accounting (tests and serve reports read these)
COUNTERS: collections.Counter = collections.Counter()

#: kernel family -> the registered fallback row (today's constants);
#: kernels/ops.py registers these at import time
_FALLBACKS: dict[str, TuneConfig] = {}


def register_fallback(kernel: str, cfg: TuneConfig) -> TuneConfig:
    """Register the default-constants row dispatch falls back to when no
    table entry matches."""
    _FALLBACKS[kernel] = cfg
    return cfg


def fallback(kernel: str) -> TuneConfig:
    """The registered fallback row for a kernel family."""
    try:
        return _FALLBACKS[kernel]
    except KeyError:
        raise KeyError(
            f"no fallback row registered for kernel {kernel!r}; "
            f"registered: {sorted(_FALLBACKS)}"
        ) from None


def fallback_kernels() -> tuple[str, ...]:
    return tuple(sorted(_FALLBACKS))


def install(table: Optional[TuneTable]) -> Optional[TuneTable]:
    """Install ``table`` as the process-wide dispatch table (None clears)."""
    global _ACTIVE
    _ACTIVE = table
    return table


def active() -> Optional[TuneTable]:
    return _ACTIVE


def active_hash() -> Optional[str]:
    """The installed table's dispatch hash (None = constants only) — the
    value ``runtime.profile.stamp()`` reports and trend.py compares."""
    return _ACTIVE.table_hash() if _ACTIVE is not None else None


def clear() -> None:
    """Forget the installed and pending tables (tests)."""
    global _ACTIVE, _PENDING_MISMATCH
    _ACTIVE = None
    _PENDING_MISMATCH = None


@contextlib.contextmanager
def pinned(table: Optional[TuneTable]):
    """Temporarily make ``table`` (which may be None) the active table.

    The Searcher's plan-time resolution: a plan snapshots the active
    table at construction and traces its bucket executables under
    ``pinned(snapshot)``, so a table installed *after* plan time cannot
    change shapes the plan already compiled.
    """
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = table
    try:
        yield table
    finally:
        _ACTIVE = prev


def snapshot_for_plan() -> Optional[TuneTable]:
    """The table a new plan should freeze: the active table if it was
    measured on this backend, else None (counted, never raised)."""
    t = _ACTIVE
    if t is None:
        return None
    if not t.matches():
        COUNTERS["tune_stamp_mismatch"] += 1
        return None
    return t


def lookup(kernel: str, metric: str, bits: int, q: int, n: int,
           d: int) -> Optional[TuneConfig]:
    """The dispatch query: the active table's entry for this workload
    bucket, or None (fall back to the registered constants).

    Misses and stamp mismatches are counted; a lookup never raises.
    """
    t = _ACTIVE
    if t is None:
        return None
    if not t.matches():
        COUNTERS["tune_stamp_mismatch"] += 1
        return None
    cfg = t.get(kernel, metric, bits, q, n, d)
    COUNTERS["tune_lookup_hit" if cfg is not None else
             "tune_lookup_miss"] += 1
    return cfg


# -- adoption (saved-index / JSON tables entering a serving process) -------

def adopt(table: TuneTable) -> bool:
    """Install ``table`` if it was measured on this backend.

    On a stamp mismatch the table is parked as the pending-mismatch
    table (the maintenance scheduler's re-tune trigger) and dispatch
    keeps using whatever was active — a counter, not a crash.
    """
    global _PENDING_MISMATCH
    if table.matches():
        install(table)
        COUNTERS["tune_adopted"] += 1
        return True
    _PENDING_MISMATCH = table
    COUNTERS["tune_adopt_mismatch"] += 1
    return False


def adopt_from_meta(meta: dict) -> Optional[bool]:
    """Adopt the table embedded in a saved index's meta record (the
    ``"tune"`` key ``knn.base.save_state`` writes).  Returns None when
    the record carries no table."""
    doc = meta.get("tune")
    if doc is None:
        return None
    return adopt(TuneTable.from_dict(doc))


def pending_mismatch() -> Optional[TuneTable]:
    """The adopted-but-mismatched table awaiting a re-tune (or None)."""
    return _PENDING_MISMATCH


def clear_pending() -> None:
    global _PENDING_MISMATCH
    _PENDING_MISMATCH = None
