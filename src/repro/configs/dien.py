"""dien [arXiv:1809.03672; unverified]: embed_dim 18, behaviour sequence
length 100, GRU + AUGRU interest evolution with gru_dim 108, final MLP
200-80, AUGRU interaction.  Field 0 is the item table (also used for the
behaviour history); amazon-books-scale vocabularies."""

from repro.configs.base import RECSYS_SHAPES
from repro.models.recsys.models import RecsysConfig

ARCH_ID = "dien"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        kind="dien",
        n_dense=0,
        vocab_sizes=(63_001, 801, 192_403),   # item, category, user
        embed_dim=18,
        seq_len=100,
        gru_dim=108,
        mlp=(200, 80),
    )


def reduced_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke",
        kind="dien",
        n_dense=0,
        vocab_sizes=(500, 50, 300),
        embed_dim=8,
        seq_len=12,
        gru_dim=16,
        mlp=(24, 12),
    )
