"""schnet [arXiv:1706.08566]: 3 interaction blocks, d_hidden 64, 300
Gaussian RBFs, cutoff 10 Å.  Molecular cells use real 3-D distances (with
radius graphs built via the paper's quantized L2); feature-graph cells
(cora / ogbn-products) derive edge lengths from a learned node-feature
projection (DESIGN.md §5)."""

from repro.configs.base import GNN_SHAPES
from repro.models.gnn.schnet import SchNetConfig

ARCH_ID = "schnet"
FAMILY = "gnn"
SHAPES = GNN_SHAPES
SKIP = {}


def config(shape: str = "molecule") -> SchNetConfig:
    spec = GNN_SHAPES[shape]
    if spec["kind"] == "molecule":
        return SchNetConfig(
            name=ARCH_ID, n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0
        )
    return SchNetConfig(
        name=ARCH_ID,
        n_interactions=3,
        d_hidden=64,
        n_rbf=300,
        cutoff=10.0,
        d_feat=spec["d_feat"],
        n_classes=spec["n_classes"],
    )


def reduced_config(shape: str = "molecule") -> SchNetConfig:
    if GNN_SHAPES[shape]["kind"] == "molecule":
        return SchNetConfig(
            name=ARCH_ID + "-smoke", n_interactions=2, d_hidden=16, n_rbf=20, cutoff=5.0
        )
    return SchNetConfig(
        name=ARCH_ID + "-smoke",
        n_interactions=2,
        d_hidden=16,
        n_rbf=20,
        cutoff=5.0,
        d_feat=24,
        n_classes=7,
    )
