"""autoint [arXiv:1810.11921]: 39 sparse fields (criteo: 13 bucketized
dense + 26 categorical), embed_dim 16, 3 interacting self-attention
layers with 2 heads of d_attn 32."""

from repro.configs.base import CRITEO_DENSE_BUCKETS, CRITEO_VOCABS, RECSYS_SHAPES
from repro.models.recsys.models import RecsysConfig

ARCH_ID = "autoint"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        kind="autoint",
        n_dense=0,
        vocab_sizes=CRITEO_DENSE_BUCKETS + CRITEO_VOCABS,   # 39 fields
        embed_dim=16,
        n_attn_layers=3,
        n_heads=2,
        d_attn=32,
    )


def reduced_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke",
        kind="autoint",
        n_dense=0,
        vocab_sizes=(64,) * 6 + (500, 300),
        embed_dim=8,
        n_attn_layers=2,
        n_heads=2,
        d_attn=8,
    )
