"""dlrm-mlperf [arXiv:1906.00091]: the MLPerf DLRM benchmark config
(Criteo 1TB) — 13 dense features through bottom MLP 512-256-128, 26
categorical features with embed_dim 128 over the Criteo hash sizes
(~187M rows total), dot interaction, top MLP 1024-1024-512-256-1."""

from repro.configs.base import CRITEO_VOCABS, RECSYS_SHAPES
from repro.models.recsys.models import RecsysConfig

ARCH_ID = "dlrm-mlperf"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        kind="dlrm",
        n_dense=13,
        vocab_sizes=CRITEO_VOCABS,
        embed_dim=128,
        bot_mlp=(512, 256, 128),
        top_mlp=(1024, 1024, 512, 256, 1),
    )


def reduced_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke",
        kind="dlrm",
        n_dense=13,
        vocab_sizes=(500, 100, 50, 2000),
        embed_dim=16,
        bot_mlp=(32, 16),
        top_mlp=(32, 16, 1),
    )
