"""dcn-v2 [arXiv:2008.13535]: 13 dense + 26 sparse (embed_dim 16, Criteo
hash sizes), 3 full-rank cross layers, deep tower 1024-1024-512."""

from repro.configs.base import CRITEO_VOCABS, RECSYS_SHAPES
from repro.models.recsys.models import RecsysConfig

ARCH_ID = "dcn-v2"
FAMILY = "recsys"
SHAPES = RECSYS_SHAPES
SKIP = {}


def config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID,
        kind="dcnv2",
        n_dense=13,
        vocab_sizes=CRITEO_VOCABS,
        embed_dim=16,
        n_cross_layers=3,
        mlp=(1024, 1024, 512),
    )


def reduced_config() -> RecsysConfig:
    return RecsysConfig(
        name=ARCH_ID + "-smoke",
        kind="dcnv2",
        n_dense=13,
        vocab_sizes=(500, 100, 50, 2000),
        embed_dim=8,
        n_cross_layers=2,
        mlp=(32, 16),
    )
