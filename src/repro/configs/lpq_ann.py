"""The paper's own evaluation configs: HNSW/FAISS-flat/NGT-equivalent
indexes over PRODUCT-style, SIFT-like and GloVe-like corpora, fp32 vs
int8 arms, HNSW hyperparameter grid from §5.2 (EFC 300..700, M {32,48},
EFS 300..800)."""

import dataclasses

ARCH_ID = "lpq-ann"
FAMILY = "ann"
SKIP = {}


@dataclasses.dataclass(frozen=True)
class ANNConfig:
    dataset: str = "product"        # product | sift | glove
    n: int = 60_000_000             # PRODUCT60M scale (reduced in benches)
    n_queries: int = 1000
    k: int = 100                    # paper fixes k=100
    bits: int = 8
    scheme: str = "gaussian"
    sigmas: float = 3.0             # clamp width (paper: 1.0; see EXPERIMENTS)
    # unified-API factory string (the paper's primary arm); benchmarks and
    # the serving loop build through repro.knn.make_index(index)
    index: str = "hnsw32,lpq8@gaussian:3"
    # HNSW grid (paper §5.2)
    m_grid: tuple = (32, 48)
    efc_grid: tuple = (300, 400, 600, 700)
    efs_grid: tuple = (300, 400, 500, 600, 700, 800)

    def index_spec(self):
        """Parsed IndexSpec for the configured factory string (lazy imports:
        configs must stay importable without touching jax)."""
        from repro.data.synthetic import METRIC_FOR
        from repro.knn.spec import parse_factory

        return parse_factory(self.index, metric=METRIC_FOR[self.dataset])


def config() -> ANNConfig:
    return ANNConfig()


def reduced_config() -> ANNConfig:
    return ANNConfig(
        n=4000, n_queries=32, k=10, index="hnsw8,lpq8@gaussian:3",
        m_grid=(8,), efc_grid=(40,), efs_grid=(40, 80),
    )


SHAPES = {
    "product60m": dict(kind="ann", dataset="product", metric="ip"),
    "sift1m": dict(kind="ann", dataset="sift", metric="l2"),
    "glove100": dict(kind="ann", dataset="glove", metric="angular"),
}
