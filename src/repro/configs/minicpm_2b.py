"""minicpm-2b [arXiv:2404.06395]: 40L, d_model 2304, 36 heads MHA
(kv=36), head_dim 64, d_ff 5760 (SwiGLU, llama-like), vocab 122753.
Trains with the WSD schedule (repro.train.optimizer schedule="wsd")."""

from repro.configs.base import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "minicpm-2b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_MICROBATCHES = 8
SKIP = {
    "long_500k": "pure global full attention; no sub-quadratic path "
    "(DESIGN.md §6)",
}

OPTIMIZER_SCHEDULE = "wsd"           # the arch's signature training recipe


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv=36,                     # full MHA
        head_dim=64,
        d_ff=5760,
        vocab=122_753,
        act="silu",                  # llama-like SwiGLU
        layer_pattern="g",
        scale_embed=True,            # minicpm scales embeddings (mu-param)
        dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=72,
        n_heads=6,
        n_kv=6,
        head_dim=12,
        d_ff=144,
        vocab=512,
        act="silu",
        layer_pattern="g",
        dtype="float32",
        block_kv=16,
        remat=False,
    )
