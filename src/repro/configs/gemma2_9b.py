"""gemma2-9b [arXiv:2408.00118]: 42L, d_model 3584, 16 heads GQA kv=8,
head_dim 256, d_ff 14336, vocab 256000 — alternating local(4096)/global
attention with attention (50.0) and final (30.0) logit soft-caps."""

from repro.configs.base import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "gemma2-9b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_MICROBATCHES = 8
SKIP = {}  # local+global alternating -> long_500k runs (DESIGN.md §6)


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv=8,
        head_dim=256,
        d_ff=14336,
        vocab=256_000,
        act="gelu",
        layer_pattern="lg",          # local, global, local, global, ...
        window=4096,
        attn_softcap=50.0,
        final_softcap=30.0,
        scale_embed=True,
        dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        act="gelu",
        layer_pattern="lg",
        window=8,
        attn_softcap=50.0,
        final_softcap=30.0,
        dtype="float32",
        block_kv=16,
        remat=False,
    )
