"""Architecture registry: ``--arch <id>`` resolution for the launcher,
dry-run and benchmarks."""

from __future__ import annotations

from repro.configs import (
    autoint,
    dcn_v2,
    dien,
    dlrm_mlperf,
    gemma2_9b,
    gemma_2b,
    llama4_maverick,
    llama4_scout,
    lpq_ann,
    minicpm_2b,
    schnet,
)

_MODULES = (
    gemma_2b,
    gemma2_9b,
    minicpm_2b,
    llama4_scout,
    llama4_maverick,
    schnet,
    autoint,
    dlrm_mlperf,
    dien,
    dcn_v2,
    lpq_ann,
)

ARCHS = {m.ARCH_ID: m for m in _MODULES}
ASSIGNED = [m.ARCH_ID for m in _MODULES if m is not lpq_ann]


def get(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def cells():
    """All (arch_id, shape_name, skip_reason|None) dry-run cells."""
    out = []
    for arch_id in ASSIGNED:
        mod = ARCHS[arch_id]
        for shape in mod.SHAPES:
            out.append((arch_id, shape, mod.SKIP.get(shape)))
    return out
