from repro.configs.registry import ARCHS, ASSIGNED, cells, get

__all__ = ["ARCHS", "ASSIGNED", "cells", "get"]
