"""Shared shape-cell definitions for the assigned architecture pool.

Every architecture config module exposes:
  ARCH_ID, FAMILY ("lm" | "gnn" | "recsys"), config(), reduced_config(),
  SHAPES (its own cell dict), SKIP (cell -> reason, documented skips).
"""

from __future__ import annotations

# -- LM transformers: seq_len x global_batch --------------------------------
LM_SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# -- GNN (schnet) ------------------------------------------------------------
GNN_SHAPES = {
    "full_graph_sm": dict(
        kind="full_graph", n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7
    ),
    "minibatch_lg": dict(
        kind="minibatch", n_nodes=232_965, n_edges=114_615_892,
        batch_nodes=1024, fanout=(15, 10), d_feat=602, n_classes=41,
        # padded compiled-step sizes from seeds x fanout closure
        pad_nodes=1024 * (1 + 15 + 15 * 10), pad_edges=1024 * (15 + 150),
    ),
    "ogb_products": dict(
        kind="full_graph", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
        n_classes=47,
    ),
    "molecule": dict(kind="molecule", n_nodes=30, n_edges=64, batch=128),
}

# -- RecSys -------------------------------------------------------------------
RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

# Criteo-1TB (MLPerf DLRM) per-field hash sizes — the standard 26-table set.
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)
# 13 bucketized dense fields (AutoInt treats everything as categorical)
CRITEO_DENSE_BUCKETS = (64,) * 13
