"""gemma-2b [arXiv:2403.08295]: 18L, d_model 2048, 8 heads with MQA
(kv=1), head_dim 256, d_ff 16384 (GeGLU), vocab 256000."""

from repro.configs.base import LM_SHAPES
from repro.models.transformer import LMConfig

ARCH_ID = "gemma-2b"
FAMILY = "lm"
SHAPES = LM_SHAPES
SKIP = {
    "long_500k": "pure global full attention; no sub-quadratic path "
    "(DESIGN.md §6)",
}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv=1,                      # MQA on 2b
        head_dim=256,
        d_ff=16384,
        vocab=256_000,
        act="gelu",                  # GeGLU
        layer_pattern="g",
        scale_embed=True,
        dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=1,
        head_dim=16,
        d_ff=128,
        vocab=512,
        act="gelu",
        layer_pattern="g",
        dtype="float32",
        block_kv=16,
        remat=False,
    )
