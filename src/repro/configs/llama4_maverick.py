"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-Maverick; unverified]:
same trunk as scout (48L, d_model 5120, 40H GQA kv=8, vocab 202048) with
MoE 128 experts top-1 + shared expert — ~400B total, ~17B active.

The "400b" total is only consistent with the hf config's
interleave_moe_layer_step=2: MoE on every second layer (24 MoE + 24 dense
layers, dense FFN d_ff 16384).  All-48-MoE would be ~780B.  We model the
interleave with moe_every=2 (DESIGN.md §Arch notes)."""

from repro.configs.base import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "llama4-maverick-400b-17b"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_MICROBATCHES = 16
SKIP = {}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv=8,
        head_dim=128,
        d_ff=8192,
        vocab=202_048,
        act="silu",
        layer_pattern="cccg",
        chunk=8192,
        scale_embed=False,
        moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192, shared_expert=True),
        moe_every=2,
        dense_d_ff=16384,
        dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        act="silu",
        layer_pattern="cccg",
        chunk=8,
        scale_embed=False,
        moe=MoEConfig(n_experts=8, top_k=1, d_ff=64, shared_expert=True),
        moe_every=2,
        dense_d_ff=128,
        dtype="float32",
        block_q=16,
        block_kv=16,
        remat=False,
    )
