"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]:
48L, d_model 5120, 40 heads GQA kv=8, head_dim 128, vocab 202048, MoE 16
experts top-1 routed + shared expert (d_ff 8192 per expert), iRoPE-style
chunked-local attention on 3 of every 4 layers (chunk 8192) — which is
what makes the long_500k cell sub-quadratic for this arch."""

from repro.configs.base import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig

ARCH_ID = "llama4-scout-17b-16e"
FAMILY = "lm"
SHAPES = LM_SHAPES
TRAIN_MICROBATCHES = 16
SKIP = {}


def config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv=8,
        head_dim=128,
        d_ff=8192,
        vocab=202_048,
        act="silu",
        layer_pattern="cccg",        # chunked x3, global x1 (iRoPE)
        chunk=8192,
        scale_embed=False,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, shared_expert=True),
        dtype="bfloat16",
    )


def reduced_config() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        act="silu",
        layer_pattern="cccg",
        chunk=8,
        scale_embed=False,
        moe=MoEConfig(n_experts=4, top_k=1, d_ff=128, shared_expert=True),
        dtype="float32",
        block_kv=16,
        remat=False,
    )
