"""Step-function builders shared by the launcher, the serving loop and the
multi-pod dry-run.  Each returns a pure function of abstract-shardable
arguments (params/opt/batch pytrees) with all configs closed over
statically — the exact callables that get pjit'd.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as TF
from repro.models.gnn import schnet as S
from repro.models.recsys import models as RM
from repro.models.recsys import retrieval as RT
from repro.quantized import qkv_cache as QC
from repro.train import optimizer as OPT


# --------------------------------------------------------------------------
# LM
# --------------------------------------------------------------------------

def make_lm_train_step(
    cfg: TF.LMConfig,
    opt_cfg: OPT.OptConfig,
    microbatches: int = 1,
    batch_axes: tuple[str, ...] | None = None,
    grad_specs=None,
) -> Callable:
    """Train step with in-step gradient accumulation.

    microbatches > 1 scans over batch slices so the [B_micro, S, vocab]
    logits (the activation-memory hot spot at 256k vocab) never exceed
    one microbatch — the standard large-batch memory discipline.

    batch_axes: mesh axes the batch dim is sharded over.  The microbatch
    reshape [B, ...] -> [micro, B/micro, ...] otherwise loses the batch
    sharding under GSPMD propagation (the split dim no longer divides the
    axis), silently replicating the global batch on every device; an
    explicit with_sharding_constraint on dim 1 keeps the slices sharded.
    """
    from jax.sharding import PartitionSpec as P

    def grads_of(params, batch):
        (loss, _aux), grads = jax.value_and_grad(TF.lm_loss, has_aux=True)(
            params, batch, cfg
        )
        return loss, grads

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                ),
                batch,
            )
            if batch_axes:
                micro = jax.tree.map(
                    lambda x: jax.lax.with_sharding_constraint(
                        x, P(None, batch_axes, *([None] * (x.ndim - 2)))
                    ),
                    micro,
                )

            def constrain_grads(g):
                # ZeRO: keep the f32 accumulators in the (data x model)
                # layout so each microbatch's grads reduce-scatter into a
                # 1/256 slice instead of living replicated over 'data'
                if grad_specs is None:
                    return g
                return jax.tree.map(
                    lambda a, s: jax.lax.with_sharding_constraint(a, s),
                    g, grad_specs,
                )

            def accum(carry, mb):
                loss_c, grads_c = carry
                loss_i, grads_i = grads_of(params, mb)
                grads_c = jax.tree.map(
                    lambda a, b: a + b / microbatches, grads_c, grads_i
                )
                return (loss_c + loss_i / microbatches, constrain_grads(grads_c)), None

            zero = constrain_grads(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (loss, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zero), micro)
        params, opt_state, om = OPT.adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return step


def make_lm_prefill(cfg: TF.LMConfig) -> Callable:
    def step(params, tokens):
        return TF.prefill(params, tokens, cfg)

    return step


def make_lm_decode(cfg: TF.LMConfig) -> Callable:
    def step(params, caches, token, cur_len):
        return TF.decode_step(params, caches, token, cur_len, cfg)

    return step


def make_lm_decode_q8(cfg: TF.LMConfig) -> Callable:
    """Paper-quantized int8-KV decode (the beyond-baseline arm)."""

    def step(params, qcache, token, cur_len):
        return QC.decode_step_q8(params, qcache, token, cur_len, cfg)

    return step


# --------------------------------------------------------------------------
# RecSys
# --------------------------------------------------------------------------

def make_recsys_train_step(cfg: RM.RecsysConfig, opt_cfg: OPT.OptConfig) -> Callable:
    def step(params, opt_state, batch):
        (loss, _aux), grads = jax.value_and_grad(RM.bce_loss, has_aux=True)(
            params, batch, cfg
        )
        params, opt_state, om = OPT.adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return step


def make_recsys_serve(cfg: RM.RecsysConfig) -> Callable:
    def step(params, batch):
        return RM.serve(params, batch, cfg)

    return step


def make_retrieval_sharded(
    mesh, n_local: int, k: int = 100, quantized: bool = True
) -> Callable:
    """Distributed exhaustive MIP search: shard-local scoring + local
    top-k inside shard_map, then a k-sized merge — O(Q·(N_loc+k)) temp
    and O(shards·Q·k) wire, versus the naive jit formulation whose
    lax.top_k over the sharded N axis makes GSPMD materialize and
    all-gather the FULL [Q, N] score matrix (measured: 480 GB temp /
    240 GB wire at PRODUCT60M scale — EXPERIMENTS.md §Perf C2).

    This is the *abstract-argument* variant the multi-pod dry-run
    compiles (params arrive as pjit inputs).  The serving path no longer
    routes through here: ``index.searcher(k, params, shards=mesh)``
    builds the same shard-local-topk + k-sized-merge plan over the
    index's own CodeStore — fp32 / int8 / packed int4 alike — and fuses
    it with bucketing and the rerank tail (DESIGN.md §9,
    ``knn/searcher.sharded_scan_plan``)."""
    from jax.sharding import PartitionSpec as P

    from repro.core import distances as D
    from repro.dist.sharding import shard_map
    from repro import engine

    axes = tuple(a for a in mesh.axis_names if a in ("data", "model"))

    def local_search(q_codes, shard_codes, shard_idx):
        s = D.scores(q_codes, shard_codes, "ip", quantized=quantized)
        s = s.astype(jnp.float32)
        loc_s, loc_i = jax.lax.top_k(s, k)
        return engine.distributed_topk(
            loc_s, loc_i.astype(jnp.int32), k, axes, shard_idx[0] * n_local
        )

    inner = shard_map(
        local_search,
        mesh=mesh,
        in_specs=(P(), P(axes, None), P(axes)),
        out_specs=(P(), P()),
        check_vma=False,
    )

    if quantized:
        def step(query_emb, cand_codes, lo, hi, zero, shard_idx):
            from repro.core.quant import QuantParams
            from repro.kernels import ops as K

            params = QuantParams(lo=lo, hi=hi, zero=zero, bits=8, scheme="absmax")
            q_codes = K.quantize(query_emb, params.lo, params.hi, params.zero)
            return inner(q_codes, cand_codes, shard_idx)

        return step

    def step(query_emb, cand_table, shard_idx):
        return inner(query_emb, cand_table, shard_idx)

    return step


def make_retrieval(quantized: bool, k: int = 100, use_pallas: bool = False) -> Callable:
    """1-query x n_candidates MIP scoring (the paper's search problem).

    use_pallas=False routes through the XLA int8 dot (the dry-run path —
    the Pallas kernel is TPU-target and validated separately in interpret
    mode); on real TPU hardware flip it on.
    """
    if quantized:
        def step(query_emb, cand_codes, lo, hi, zero):
            from repro.core.quant import QuantParams

            params = QuantParams(lo=lo, hi=hi, zero=zero, bits=8, scheme="absmax")
            return RT.retrieve_quantized(
                query_emb, cand_codes, params, k=k, use_pallas=use_pallas
            )

        return step

    def step(query_emb, cand_table):
        return RT.retrieve_fp32(query_emb, cand_table, k=k)

    return step


# --------------------------------------------------------------------------
# GNN (SchNet)
# --------------------------------------------------------------------------

def _schnet_molecule_loss(params, batch, cfg: S.SchNetConfig, n_nodes: int, n_graphs: int):
    out = S.forward(
        params, cfg,
        senders=batch["senders"], receivers=batch["receivers"],
        edge_mask=batch["edge_mask"], n_nodes=n_nodes,
        z=batch["z"], positions=batch["positions"],
    )[:, 0]
    energies = jax.ops.segment_sum(out, batch["graph_ids"], num_segments=n_graphs)
    return jnp.mean((energies - batch["labels"]) ** 2)


def _schnet_node_loss(params, batch, cfg: S.SchNetConfig, n_nodes: int):
    logits = S.forward(
        params, cfg,
        senders=batch["senders"], receivers=batch["receivers"],
        edge_mask=batch["edge_mask"], n_nodes=n_nodes,
        node_feat=batch["node_feat"],
    )
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def make_gnn_train_step(
    cfg: S.SchNetConfig,
    kind: str,
    opt_cfg: OPT.OptConfig,
    n_nodes: int,
    n_graphs: int = 0,
) -> Callable:
    if kind == "molecule":
        loss_fn = partial(
            _schnet_molecule_loss, cfg=cfg, n_nodes=n_nodes, n_graphs=n_graphs
        )
    else:
        loss_fn = partial(_schnet_node_loss, cfg=cfg, n_nodes=n_nodes)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, om = OPT.adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, **om}

    return step
