"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "BEFORE importing jax (launch/dryrun.py does this)."
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist — tests and local runs."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))
