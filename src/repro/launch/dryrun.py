import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks at first init).

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape
x mesh) cell on the production meshes, record memory_analysis +
cost_analysis + the HLO collective schedule for §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod both --out experiments/dryrun
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, cells
from repro.dist import sharding as SH
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as TF
from repro.train import optimizer as OPT


# --------------------------------------------------------------------------
# collective-byte accounting from the partitioned HLO
# --------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    for prefix, size in _DTYPE_BYTES.items():
        if dtype.startswith(prefix):
            return n * size
    return n * 4


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the partitioned module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in _COLLECTIVES:
            # result shapes appear between "=" and "<coll>(" on the
            # defining line:  %name = f32[..]{..} all-reduce(...)
            marker = f" {coll}("
            alt = f" {coll}-start("
            pos = stripped.find(marker)
            if pos < 0:
                pos = stripped.find(alt)
            eq = stripped.find(" = ")
            if pos > 0 and 0 < eq < pos:
                lhs = stripped[eq:pos]
                total = sum(
                    _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(lhs)
                )
                out[coll] += total
                counts[coll] += 1
                break
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


# --------------------------------------------------------------------------
# cell builders: (fn, abstract_args) per (arch, shape)
# --------------------------------------------------------------------------

def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _pad_to(n: int, m: int) -> int:
    """Round n up to a multiple of m (shard-boundary padding — standard
    practice for vocabularies / tables / edge lists on SPMD meshes)."""
    return ((n + m - 1) // m) * m


def _with_sharding(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda a, s: _sds(a.shape, a.dtype, s), abstract_tree, sharding_tree
    )


def _abstract_opt(abstract_params):
    return jax.eval_shape(OPT.adamw_init, abstract_params)


def build_lm_cell(arch_id: str, shape_name: str, mesh, variant: str = "baseline"):
    mod = get(arch_id)
    cfg: TF.LMConfig = mod.config()
    spec = mod.SHAPES[shape_name]
    B, S = spec["global_batch"], spec["seq_len"]
    dp = SH.dp_axes(mesh)

    aparams = TF.abstract_params(cfg)
    p_shard = SH.lm_params_sharding(mesh, aparams)
    params_in = _with_sharding(aparams, p_shard)

    if spec["kind"] == "train":
        opt_cfg = OPT.OptConfig(
            schedule=getattr(mod, "OPTIMIZER_SCHEDULE", "cosine"), total_steps=10000
        )
        aopt = _abstract_opt(aparams)
        o_shard = SH.lm_opt_sharding(mesh, aopt)
        opt_in = _with_sharding(aopt, o_shard)
        batch = {
            "tokens": _sds((B, S), jnp.int32, SH.named(mesh, SH.P(dp, None))),
            "targets": _sds((B, S), jnp.int32, SH.named(mesh, SH.P(dp, None))),
            "mask": _sds((B, S), jnp.float32, SH.named(mesh, SH.P(dp, None))),
        }
        # grad accumulation keeps the [B_micro, S, vocab] logits inside the
        # 16 GB/chip envelope at 256k vocab (see EXPERIMENTS.md §Dry-run)
        micro = getattr(mod, "TRAIN_MICROBATCHES", 4)
        fn = ST.make_lm_train_step(
            cfg, opt_cfg, microbatches=micro, batch_axes=dp,
            grad_specs=SH.lm_grad_specs(aparams),
        )
        return fn, (params_in, opt_in, batch)

    if spec["kind"] == "prefill":
        tokens = _sds((B, S), jnp.int32, SH.named(mesh, SH.P(dp, None)))
        c_shard = SH.lm_cache_spec(mesh, B)
        out_shardings = (None, (c_shard, c_shard))   # logits, (k, v) caches
        return ST.make_lm_prefill(cfg), (params_in, tokens), out_shardings

    # decode: one new token against an S-long cache (block-major layout)
    cache_shape = TF.cache_shape(cfg, B, S)
    c_shard = SH.lm_cache_spec(mesh, B)
    tok_spec = SH.P(dp, None) if B > 1 else SH.P(None, None)
    token = _sds((B, 1), jnp.int32, SH.named(mesh, tok_spec))
    cur_len = _sds((), jnp.int32, SH.named(mesh, SH.P()))

    if variant == "int8kv":
        # the paper-quantized cache: int8 codes + per (block, sub, Hkv, hd)
        # scales — 2x less HBM than the bf16 baseline cache
        from repro.quantized.qkv_cache import QuantizedCache

        sshape = (cfg.n_blocks, cfg.block_layers, cfg.n_kv, cfg.head_dim)
        s_shard = SH.named(mesh, SH.P(None, None, None, None))
        qcache = QuantizedCache(
            k_codes=_sds(cache_shape, jnp.int8, c_shard),
            v_codes=_sds(cache_shape, jnp.int8, c_shard),
            k_scale=_sds(sshape, jnp.float32, s_shard),
            v_scale=_sds(sshape, jnp.float32, s_shard),
        )
        return ST.make_lm_decode_q8(cfg), (params_in, qcache, token, cur_len)

    caches = (
        _sds(cache_shape, cfg.jdtype, c_shard),
        _sds(cache_shape, cfg.jdtype, c_shard),
    )
    return ST.make_lm_decode(cfg), (params_in, caches, token, cur_len)


def build_recsys_cell(arch_id: str, shape_name: str, mesh, variant: str = "fp32"):
    import dataclasses as _dc

    from repro.models.recsys import models as RM

    mod = get(arch_id)
    cfg: RM.RecsysConfig = mod.config()
    spec = mod.SHAPES[shape_name]
    dp = SH.dp_axes(mesh)
    table_shards = mesh.shape.get("data", 1) * mesh.shape["model"]

    # pad sharded tables to the shard boundary (replicated small tables keep
    # their exact size — recsys_param_spec's threshold)
    padded_vocabs = tuple(
        _pad_to(v, table_shards) if v >= max(table_shards, 4096) else v
        for v in cfg.vocab_sizes
    )
    cfg = _dc.replace(cfg, vocab_sizes=padded_vocabs)

    if spec["kind"] == "retrieval":
        d = cfg.embed_dim
        N = _pad_to(spec["n_candidates"], table_shards)
        Q = spec["batch"]
        cand_shard = SH.named(mesh, SH.P(("data", "model"), None))
        q_in = _sds((Q, d), jnp.float32, SH.named(mesh, SH.P(None, None)))
        if variant == "int8":
            cand = _sds((N, d), jnp.int8, cand_shard)
            const = _sds((d,), jnp.float32, SH.named(mesh, SH.P(None)))
            return ST.make_retrieval(True), (q_in, cand, const, const, const)
        cand = _sds((N, d), jnp.float32, cand_shard)
        return ST.make_retrieval(False), (q_in, cand)

    aparams = RM.abstract_params(cfg)
    if variant == "int8" and spec["kind"] == "serve":
        # paper-quantized serving tables: codes int8 + per-dim constants —
        # gathered rows cross HBM and the mesh at 1/4 the bytes
        qt = {}
        for name, tp in aparams["tables"].items():
            v, d_ = tp["table"].shape
            qt[name] = {
                "codes": jax.ShapeDtypeStruct((v, d_), jnp.int8),
                "scale": jax.ShapeDtypeStruct((d_,), jnp.float32),
                "zero": jax.ShapeDtypeStruct((d_,), jnp.float32),
            }
        aparams = dict(aparams)
        aparams["tables"] = qt
    p_shard = SH.recsys_params_sharding(mesh, aparams)
    params_in = _with_sharding(aparams, p_shard)

    B = spec["batch"]
    batch = {
        "dense": _sds((B, cfg.n_dense), jnp.float32, SH.named(mesh, SH.P(dp, None))),
        "sparse": _sds((B, cfg.n_sparse), jnp.int32, SH.named(mesh, SH.P(dp, None))),
        "label": _sds((B,), jnp.float32, SH.named(mesh, SH.P(dp))),
    }
    if cfg.seq_len:
        batch["hist_ids"] = _sds((B, cfg.seq_len), jnp.int32, SH.named(mesh, SH.P(dp, None)))
        batch["hist_mask"] = _sds((B, cfg.seq_len), jnp.float32, SH.named(mesh, SH.P(dp, None)))

    if spec["kind"] == "train":
        opt_cfg = OPT.OptConfig()
        aopt = _abstract_opt(aparams)
        opt_in = _with_sharding(aopt, SH.recsys_opt_sharding(mesh, aopt))
        return ST.make_recsys_train_step(cfg, opt_cfg), (params_in, opt_in, batch)

    return ST.make_recsys_serve(cfg), (params_in, batch)


def build_gnn_cell(arch_id: str, shape_name: str, mesh):
    from repro.models.gnn import schnet as S

    mod = get(arch_id)
    spec = mod.SHAPES[shape_name]
    cfg: S.SchNetConfig = mod.config(shape_name)
    opt_cfg = OPT.OptConfig()

    e_shard = SH.gnn_edge_sharding(mesh)
    rep = lambda nd: SH.named(mesh, SH.P(*([None] * nd)))

    aparams = jax.eval_shape(lambda: S.init_params(jax.random.PRNGKey(0), cfg))
    params_in = _with_sharding(aparams, SH.gnn_params_sharding(mesh, aparams))
    aopt = _abstract_opt(aparams)
    opt_in = _with_sharding(aopt, SH.replicated(mesh, aopt))

    n_mesh = int(np.prod(list(mesh.shape.values())))
    if spec["kind"] == "molecule":
        n_nodes = spec["batch"] * spec["n_nodes"]
        n_edges = _pad_to(spec["batch"] * spec["n_edges"], n_mesh)
        batch = {
            "z": _sds((n_nodes,), jnp.int32, rep(1)),
            "positions": _sds((n_nodes, 3), jnp.float32, rep(2)),
            "senders": _sds((n_edges,), jnp.int32, e_shard),
            "receivers": _sds((n_edges,), jnp.int32, e_shard),
            "edge_mask": _sds((n_edges,), jnp.bool_, e_shard),
            "graph_ids": _sds((n_nodes,), jnp.int32, rep(1)),
            "labels": _sds((spec["batch"],), jnp.float32, rep(1)),
        }
        fn = ST.make_gnn_train_step(
            cfg, "molecule", opt_cfg, n_nodes=n_nodes, n_graphs=spec["batch"]
        )
        return fn, (params_in, opt_in, batch)

    if spec["kind"] == "minibatch":
        n_nodes, n_edges = spec["pad_nodes"], spec["pad_edges"]
    else:
        n_nodes, n_edges = spec["n_nodes"], spec["n_edges"]
    n_edges = _pad_to(n_edges, n_mesh)   # edge lists pad to the mesh size
    batch = {
        "node_feat": _sds((n_nodes, spec["d_feat"]), jnp.float32, rep(2)),
        "senders": _sds((n_edges,), jnp.int32, e_shard),
        "receivers": _sds((n_edges,), jnp.int32, e_shard),
        "edge_mask": _sds((n_edges,), jnp.bool_, e_shard),
        "labels": _sds((n_nodes,), jnp.int32, rep(1)),
    }
    fn = ST.make_gnn_train_step(cfg, spec["kind"], opt_cfg, n_nodes=n_nodes)
    return fn, (params_in, opt_in, batch)


def build_ann_cell(shape_name: str, mesh, variant: str = "baseline"):
    """The paper's own system at FULL scale: PRODUCT60M (60M x 256) /
    SIFT1M / Glove100 exhaustive quantized MIP search, corpus row-sharded
    over the production mesh, 1000-query batch (the paper's test-set
    size), k=100 (the paper's §5.1 fixed k)."""
    spec = {
        "product60m": dict(n=60_000_000, d=256),
        "sift1m": dict(n=1_000_000, d=128),
        "glove100": dict(n=1_183_514, d=100),
    }[shape_name]
    n_shards = mesh.shape.get("data", 1) * mesh.shape["model"]
    N = _pad_to(spec["n"], n_shards)
    d = spec["d"]
    Q = 1000
    cand_shard = SH.named(mesh, SH.P(("data", "model"), None))
    q_in = _sds((Q, d), jnp.float32, SH.named(mesh, SH.P(None, None)))
    shard_idx = _sds((n_shards,), jnp.int32, SH.named(mesh, SH.P(("data", "model"))))
    n_local = N // n_shards
    if variant == "naive":
        # the plain-jit formulation kept as the measured regression arm
        cand = _sds((N, d), jnp.int8, cand_shard)
        const = _sds((d,), jnp.float32, SH.named(mesh, SH.P(None)))
        return ST.make_retrieval(True, k=100), (q_in, cand, const, const, const)
    if variant != "fp32":  # int8 is the paper's arm and the default here
        cand = _sds((N, d), jnp.int8, cand_shard)
        const = _sds((d,), jnp.float32, SH.named(mesh, SH.P(None)))
        fn = ST.make_retrieval_sharded(mesh, n_local, k=100, quantized=True)
        return fn, (q_in, cand, const, const, const, shard_idx)
    cand = _sds((N, d), jnp.float32, cand_shard)
    fn = ST.make_retrieval_sharded(mesh, n_local, k=100, quantized=False)
    return fn, (q_in, cand, shard_idx)


def build_cell(arch_id: str, shape_name: str, mesh, variant: str = "baseline"):
    family = get(arch_id).FAMILY
    if family == "ann":
        out = build_ann_cell(shape_name, mesh, variant=variant)
    elif family == "lm":
        out = build_lm_cell(arch_id, shape_name, mesh, variant=variant)
    elif family == "recsys":
        v = "int8" if variant == "int8" else "fp32"
        out = build_recsys_cell(arch_id, shape_name, mesh, variant=v)
    elif family == "gnn":
        out = build_gnn_cell(arch_id, shape_name, mesh)
    else:
        raise ValueError(family)
    if len(out) == 2:
        return out[0], out[1], None
    return out


# --------------------------------------------------------------------------
# run + record
# --------------------------------------------------------------------------

def run_cell(arch_id: str, shape_name: str, multi_pod: bool, variant: str = "baseline"):
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    fn, args, out_shardings = build_cell(arch_id, shape_name, mesh, variant)
    # production aliasing: train steps donate (params, opt); decode donates
    # the KV cache — halves the apparent temp footprint and matches how the
    # launcher actually runs these steps.
    kind = get(arch_id).SHAPES[shape_name].get("kind", "")
    donate = {"train": (0, 1), "full_graph": (0, 1), "minibatch": (0, 1),
              "molecule": (0, 1), "decode": (1,)}.get(kind, ())
    jit_kwargs = dict(donate_argnums=donate)
    if out_shardings is not None:
        jit_kwargs["out_shardings"] = out_shardings
    with mesh:
        lowered = jax.jit(fn, **jit_kwargs).lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)

    record = {
        "arch": arch_id,
        "shape": shape_name,
        "variant": variant,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "compile_seconds": round(time.perf_counter() - t0, 1),
        "flops": float(cost.get("flops", -1.0)) if cost else -1.0,
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        "collectives": colls,
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
    }
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", choices=["no", "yes", "both"], default="both")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    pods = {"no": [False], "yes": [True], "both": [False, True]}[args.multi_pod]

    todo = []
    pool = list(cells())
    if args.arch == "lpq-ann":
        pool = [("lpq-ann", s, None) for s in get("lpq-ann").SHAPES]
    for arch_id, shape, skip in pool:
        if args.arch and arch_id != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        todo.append((arch_id, shape, skip))

    n_ok = n_skip = n_fail = 0
    for arch_id, shape, skip in todo:
        for mp in pods:
            tag = f"{arch_id}__{shape}__{'multipod' if mp else 'pod'}"
            if args.variant != "baseline":
                tag += f"__{args.variant}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] SKIP (exists) {tag}")
                continue
            if skip:
                with open(path, "w") as f:
                    json.dump({"arch": arch_id, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "skipped": skip}, f, indent=2)
                print(f"[dryrun] SKIP {tag}: {skip}")
                n_skip += 1
                continue
            try:
                rec = run_cell(arch_id, shape, mp, args.variant)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                print(
                    f"[dryrun] OK {tag}: {rec['compile_seconds']}s, "
                    f"flops={rec['flops']:.3e}, "
                    f"coll={rec['collectives']['total_bytes']:.3e}B"
                )
                n_ok += 1
            except Exception as e:  # noqa: BLE001
                n_fail += 1
                print(f"[dryrun] FAIL {tag}: {e}")
                traceback.print_exc()
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
