"""ANN serving loop, rebuilt on the Searcher query-plan API (DESIGN.md §9).

The index is chosen by a FAISS-style factory string and built through
``repro.knn.make_index``; the serving session is a single
``index.searcher(k, params, batch_sizes=...)`` plan — compiled once per
batch-size bucket — that a request queue drains.  Every request is padded
to its bucket inside the Searcher, so mixed request sizes hit a small,
fixed set of compiled executables; rerank-capable builds (``+r32`` /
``+r8`` factory suffix) run quantized-scan → exact-rerank inside the same
compiled function; ``--shards`` row-shards the flat scan over a host mesh.

Reporting: QPS, p50/p95/p99 request latency, and per-search engine stats
*aggregated across the whole session* (per-request means + totals — not
the last request's dict).

Mutable (``stream(...)``) indexes serve writes too: ``--mutate``
interleaves an upsert and a delete into the request mix.  A Searcher is
a snapshot plan (LSM readers pin a manifest version, DESIGN.md §10), so
each write op applies the mutation and re-plans the session; the report
separates query latency from write+replan latency.

    PYTHONPATH=src python -m repro.launch.serve --index flat,lpq4+r32 \
        --requests 4
    PYTHONPATH=src python -m repro.launch.serve --index hnsw32,lpq8 \
        --n 20000 --d 64 --batch 32 --mixed
    PYTHONPATH=src python -m repro.launch.serve --index flat,lpq8 --shards 2
    PYTHONPATH=src python -m repro.launch.serve \
        --index "stream(flat,lpq4)+r32" --requests 6 --mutate
"""

from __future__ import annotations

import argparse
import collections
import time

import jax
import numpy as np

from repro.data import synthetic
from repro.knn import SearchParams, make_index

#: stats keys summed across requests and reported as per-request means
_AGG_KEYS = ("candidates", "bytes_read", "chunks", "padded_q", "reranked")


def _request_sizes(n_requests: int, batch: int, mixed: bool) -> list[int]:
    """Per-request query counts: fixed ``batch``, or a mixed cycle that
    exercises several buckets (the realistic open-loop traffic shape)."""
    if not mixed:
        return [batch] * n_requests
    cycle = [1, max(1, batch // 4), batch]
    return [cycle[i % len(cycle)] for i in range(n_requests)]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", default="flat,lpq8@gaussian:3",
                    help="factory string, e.g. flat,lpq4+r32 / ivf64,lpq8 / "
                         "hnsw32,lpq8 / graph24,lpq8 / pq8+lpq")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--ef-search", type=int, default=100)
    ap.add_argument("--chunk", type=int, default=16384)
    ap.add_argument("--batch-sizes", default=None,
                    help="comma-separated compile buckets (default 1,8,32,256 "
                         "clipped to --batch)")
    ap.add_argument("--shards", type=int, default=0,
                    help="row-shard the (flat) scan over this many host "
                         "devices (0 = unsharded)")
    ap.add_argument("--rerank-depth", type=int, default=0,
                    help="override the rerank candidate depth (0 = the "
                         "index's default when built with +rN)")
    ap.add_argument("--mixed", action="store_true",
                    help="cycle request sizes through several buckets")
    ap.add_argument("--mutate", action="store_true",
                    help="interleave an upsert and a delete request into "
                         "the traffic (stream(...) indexes only)")
    args = ap.parse_args(argv)

    sizes = _request_sizes(args.requests, args.batch, args.mixed)
    n_extra = 8 if args.mutate else 0
    corpus, queries, _metric = synthetic.load(
        "product", args.n + n_extra, sum(sizes)
    )
    corpus = corpus[:, : args.d]
    queries = queries[:, : args.d]
    corpus, extra_rows = corpus[: args.n], corpus[args.n:]

    t0 = time.perf_counter()
    index = make_index(args.index, corpus, key=jax.random.PRNGKey(0))
    build_s = time.perf_counter() - t0

    sp = SearchParams(chunk=args.chunk, nprobe=args.nprobe,
                      ef_search=args.ef_search)
    if args.batch_sizes:
        buckets = tuple(sorted(int(b) for b in args.batch_sizes.split(",")))
    else:
        buckets = tuple(b for b in (1, 8, 32, 256) if b <= args.batch) or (args.batch,)
        if buckets[-1] < args.batch:
            buckets = buckets + (args.batch,)

    mesh = None
    if args.shards > 1:
        n_dev = len(jax.devices())
        if args.shards > n_dev:
            print(f"[serve] --shards {args.shards} > {n_dev} devices; "
                  f"using {n_dev} (set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N for more)")
        if min(args.shards, n_dev) > 1:
            mesh = jax.make_mesh((min(args.shards, n_dev),), ("data",))
        else:
            print("[serve] 1 device available — serving unsharded (a "
                  "1-shard mesh would be the degenerate merge formulation)")

    if args.mutate and not hasattr(index, "upsert"):
        raise SystemExit(
            f"--mutate needs a mutable index; {args.index!r} is {index.kind!r}"
            " — wrap it: stream(" + args.index + ")"
        )

    def make_searcher():
        return index.searcher(
            args.k, sp, batch_sizes=buckets, shards=mesh,
            rerank=args.rerank_depth or None,
        )

    searcher = make_searcher()
    print(f"[serve] index={args.index} kind={index.kind} build={build_s:.2f}s "
          f"memory={index.memory_bytes() / 1e6:.1f}MB buckets={buckets} "
          f"shards={searcher.n_shards} "
          f"rerank={searcher.rerank.depth if searcher.rerank else 0}")

    # request queue (open loop: all arrivals enqueued up front); with
    # --mutate an upsert lands a third of the way in and a delete two
    # thirds in, between query requests (clamped so both ops always fire
    # even at --requests 1)
    up_at = min(max(1, len(sizes) // 3), len(sizes) - 1)
    del_at = min(max(2, (2 * len(sizes)) // 3), len(sizes) - 1)
    queue: collections.deque = collections.deque()
    off = 0
    for i, sz in enumerate(sizes):
        if args.mutate and i == up_at:
            queue.append(("upsert",
                          np.arange(args.n, args.n + extra_rows.shape[0]),
                          extra_rows))
        if args.mutate and i == del_at:
            queue.append(("delete", np.arange(0, 4), None))
        queue.append(("query", queries[off : off + sz], None))
        off += sz

    # warmup: run every distinct request size once — this compiles each
    # bucket executable the traffic will hit (incl. remainder-slice
    # buckets of oversize requests, cf. Searcher.buckets_for) AND the
    # per-shape pad/slice glue, so the timed percentiles measure serving
    for sz in sorted(set(sizes)):
        jax.block_until_ready(searcher(queries[:sz]).ids)

    latencies = []
    write_latencies = []
    totals: collections.Counter = collections.Counter()
    served = 0
    writes = 0
    t0 = time.perf_counter()
    while queue:
        op, payload, vecs = queue.popleft()
        t_req = time.perf_counter()
        if op == "query":
            res = searcher(payload)
            jax.block_until_ready(res.ids)
            latencies.append(time.perf_counter() - t_req)
            served += int(payload.shape[0])
            for key in _AGG_KEYS:
                totals[key] += int(res.stats.get(key, 0))
        else:
            # write op: apply, then re-plan — a Searcher is a snapshot
            # (manifest-pinned) session, so writes cost a plan rebuild
            if op == "upsert":
                index.upsert(payload, vecs)
            else:
                index.delete(payload)
            searcher = make_searcher()
            # warm every distinct request size, as at startup — a cold
            # bucket after the re-plan would pollute the query p95/p99
            for sz in sorted(set(sizes)):
                jax.block_until_ready(searcher(queries[:sz]).ids)
            write_latencies.append(time.perf_counter() - t_req)
            writes += len(payload)
    dt = time.perf_counter() - t0

    n_req = len(latencies)
    p50, p95, p99 = (float(np.percentile(latencies, p)) for p in (50, 95, 99))
    # query throughput excludes write ops' apply+replan+re-warm time —
    # that cost is reported separately below
    query_dt = max(dt - sum(write_latencies), 1e-9)
    print(f"[serve] {served} queries / {n_req} requests in {dt:.3f}s -> "
          f"{served / query_dt:.1f} QPS (k={args.k}, corpus={index.n}, "
          f"kind={index.kind})")
    print(f"[serve] latency p50={p50 * 1e3:.2f}ms p95={p95 * 1e3:.2f}ms "
          f"p99={p99 * 1e3:.2f}ms")
    if write_latencies:
        print(f"[serve] writes: {writes} rows / {len(write_latencies)} ops, "
              f"apply+replan p50="
              f"{float(np.percentile(write_latencies, 50)) * 1e3:.2f}ms; "
              f"index now n={index.n} "
              f"segments={index.stats()['segments']} "
              f"tombstones={index.stats()['tombstones']}")
    # per-search engine accounting aggregated over the session (uniform
    # across kinds; DESIGN.md §8/§9) — means per request, plus totals for
    # the batch-cumulative keys (candidates/chunks/reranked are per-query
    # quantities and only meaningful as means)
    means = {key: totals[key] / max(n_req, 1) for key in _AGG_KEYS}
    print("[serve] stats/request mean: "
          + " ".join(f"{key}={means[key]:.1f}" for key in _AGG_KEYS))
    print(f"[serve] stats/session totals: "
          f"bytes_read={totals['bytes_read']} padded_q={totals['padded_q']}")


if __name__ == "__main__":
    main()
