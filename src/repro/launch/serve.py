"""ANN serving loop, registry-driven: serve ANY registered index kind.

The index is chosen by a FAISS-style factory string (DESIGN.md §3) and
built through ``repro.knn.make_index``; the request loop only speaks the
unified ``Index`` protocol — ``search(queries, k, SearchParams)`` — so
there are no index-specific branches here.  Sharded multi-device serving
(corpus row-sharded over the mesh, shard-local top-k + one k-sized merge;
DESIGN.md §4) lives in ``repro.launch.steps.make_retrieval_sharded`` and
composes with the flat kind at production scale.

    PYTHONPATH=src python -m repro.launch.serve --index hnsw32,lpq8 \
        --n 20000 --d 64 --batch 32
    PYTHONPATH=src python -m repro.launch.serve --index ivf64,lpq8 --nprobe 8
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.data import synthetic
from repro.knn import SearchParams, make_index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", default="flat,lpq8@gaussian:3",
                    help="factory string, e.g. flat,lpq8 / ivf64,lpq8 / "
                         "hnsw32,lpq8 / graph24,lpq8 / pq8+lpq")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--ef-search", type=int, default=100)
    ap.add_argument("--chunk", type=int, default=16384)
    args = ap.parse_args()

    corpus, queries, _metric = synthetic.load(
        "product", args.n, args.batch * args.requests
    )
    corpus = corpus[:, : args.d]
    queries = queries[:, : args.d]

    t0 = time.perf_counter()
    index = make_index(args.index, corpus, key=jax.random.PRNGKey(0))
    build_s = time.perf_counter() - t0
    print(f"[serve] index={args.index} kind={index.kind} "
          f"build={build_s:.2f}s memory={index.memory_bytes() / 1e6:.1f}MB")

    sp = SearchParams(chunk=args.chunk, nprobe=args.nprobe,
                      ef_search=args.ef_search)

    # warmup (compile) + serve
    jax.block_until_ready(index.search(queries[: args.batch], args.k, sp).ids)
    t0 = time.perf_counter()
    served = 0
    stats = {}
    total_bytes = 0
    for r in range(args.requests):
        q = queries[r * args.batch : (r + 1) * args.batch]
        res = index.search(q, args.k, sp)
        jax.block_until_ready(res.ids)
        served += int(q.shape[0])
        stats = res.stats
        total_bytes += int(stats.get("bytes_read", 0))
    dt = time.perf_counter() - t0
    print(f"[serve] {served} queries in {dt:.3f}s -> {served / dt:.1f} QPS "
          f"(k={args.k}, corpus={index.n}, kind={index.kind})")
    # per-search engine accounting (uniform across kinds): candidates
    # scored, chunks scanned, payload bytes read — see DESIGN.md §8
    print(f"[serve] stats/request={stats} "
          f"bytes_read/session={total_bytes}")


if __name__ == "__main__":
    main()
