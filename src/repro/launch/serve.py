"""ANN serving loop: batched quantized MIP search over a (sharded) corpus.

The production layout (DESIGN.md §4): corpus row-sharded over the mesh,
queries replicated, shard-local int8 scoring + local top-k inside
``shard_map``, one k-sized all_gather merge.  On this container the same
code serves from a host mesh.

    PYTHONPATH=src python -m repro.launch.serve --n 20000 --d 64 --batch 32
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core import distances as D
from repro.core import quant as Qz
from repro.data import synthetic
from repro.knn import topk as T


def make_sharded_searcher(mesh: Mesh, n_local: int, k: int, metric: str = "ip"):
    """Build the shard_map'd search step over a row-sharded code corpus."""
    axis = mesh.axis_names

    def local_search(q_codes, shard_codes, shard_idx):
        s = D.scores(q_codes, shard_codes, metric, quantized=True).astype(jnp.float32)
        loc_s, loc_i = jax.lax.top_k(s, k)
        return T.distributed_topk(
            loc_s, loc_i.astype(jnp.int32), k, axis, shard_idx[0] * n_local
        )

    return shard_map(
        local_search,
        mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis)),
        out_specs=(P(), P()),
        check_vma=False,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    corpus, queries, metric = synthetic.load("product", args.n, args.batch * args.requests)

    codes, params = Qz.quantize_corpus(corpus, scheme="gaussian", sigmas=3.0)
    n_local = args.n // n_dev
    codes = jax.device_put(
        codes[: n_local * n_dev], NamedSharding(mesh, P(("data",), None))
    )
    shard_idx = jax.device_put(
        jnp.arange(n_dev, dtype=jnp.int32), NamedSharding(mesh, P(("data",)))
    )

    searcher = jax.jit(make_sharded_searcher(mesh, n_local, args.k, metric))
    qfn = partial(Qz.quantize, params=params)

    # warmup + serve
    q0 = qfn(queries[: args.batch])
    jax.block_until_ready(searcher(q0, codes, shard_idx))
    t0 = time.perf_counter()
    served = 0
    for r in range(args.requests):
        q = qfn(queries[r * args.batch : (r + 1) * args.batch])
        s, ids = searcher(q, codes, shard_idx)
        jax.block_until_ready(ids)
        served += args.batch
    dt = time.perf_counter() - t0
    print(f"[serve] {served} queries in {dt:.3f}s -> {served / dt:.1f} QPS "
          f"(k={args.k}, corpus={n_local * n_dev}, devices={n_dev})")


if __name__ == "__main__":
    main()
