"""ANN serving loop, rebuilt on the production runtime subsystem
(DESIGN.md §9 request path, §12 runtime architecture).

The index is chosen by a FAISS-style factory string and built through
``repro.knn.make_index``; the serving session is a single
``index.searcher(k, params, batch_sizes=...)`` plan — compiled once per
batch-size bucket — that a request queue drains.  Around that compiled
core, ``repro.runtime`` supplies the production machinery:

  * ``--profile`` — a named :mod:`repro.runtime.profile` resolved and
    applied at process start (platform, XLA flags, host-core pinning,
    NaN debug, deterministic seed) and stamped into the report/telemetry.
  * ``--cache`` — the hot-path result tier: repeated query batches are
    served bit-identically from an LRU+TTL cache keyed on query
    fingerprint + replan generation (``--hot-repeat`` replays the first
    request every Nth request to exercise it).
  * ``--admission`` — token-bucket admission with a bounded queue and
    the degrade/shed ladder: over-budget requests run a **degraded
    plan** (shallower rerank, smaller nprobe/ef) before being shed;
    ``--deadline-ms`` propagates per-request deadlines that are
    re-checked at dequeue against the observed latency EMA.
  * ``--maintenance`` — a background scheduler runs stream-index
    compaction and drift recalibration off the request path
    (snapshot -> off-lock build -> atomic manifest swap), so a
    ``compact()`` never blocks a query.
  * ``--telemetry-out`` — the structured event log (per-request
    queue-wait/execute spans, shared cache/admission counters) as JSON.
  * ``--tune`` / ``--index-path`` / ``--save-index`` — measured-dispatch
    plumbing (DESIGN.md §13): adopt a standalone TuneTable JSON, load a
    saved index (its embedded table adopted, stamp-checked), or save the
    served index with the active table embedded.  The runtime stamp is
    taken *after* adoption so the report/telemetry records the tuning
    hash the session actually dispatched through; a foreign-backend
    table parks as a pending mismatch that the maintenance scheduler's
    lowest-priority trigger re-measures off the request path.

Mutable (``stream(...)``) indexes serve writes too: ``--mutate``
interleaves an upsert and a delete into the request mix.  A Searcher is
a snapshot plan (LSM readers pin a manifest version, DESIGN.md §10), so
a write re-plans the session — **unless the mutation left the manifest
epoch unchanged** (no-op delete, memtable-only upsert below the seal
threshold): those skip the re-plan and are counted as
``replans_avoided``; under snapshot semantics the write simply becomes
visible at the next structural re-plan.

    PYTHONPATH=src python -m repro.launch.serve --index flat,lpq4+r32 \
        --requests 4
    PYTHONPATH=src python -m repro.launch.serve --index flat,lpq8 \
        --profile ci-cpu --cache 64 --hot-repeat 2
    PYTHONPATH=src python -m repro.launch.serve \
        --index "stream(flat,lpq4)+r32" --requests 8 --mutate \
        --admission --max-queue 6 --maintenance \
        --telemetry-out TELEMETRY_serve.json
"""

from __future__ import annotations

import argparse
import collections
import time

import numpy as np

from repro.runtime import profile as rtprofile

#: stats keys summed across requests and reported as per-request means
_AGG_KEYS = ("candidates", "bytes_read", "chunks", "padded_q", "reranked",
             "merge_wire_bytes")


def _request_sizes(n_requests: int, batch: int, mixed: bool) -> list[int]:
    """Per-request query counts: fixed ``batch``, or a mixed cycle that
    exercises several buckets (the realistic open-loop traffic shape)."""
    if not mixed:
        return [batch] * n_requests
    cycle = [1, max(1, batch // 4), batch]
    return [cycle[i % len(cycle)] for i in range(n_requests)]


def _parse_args(argv):
    ap = argparse.ArgumentParser()
    ap.add_argument("--index", default="flat,lpq8@gaussian:3",
                    help="factory string, e.g. flat,lpq4+r32 / ivf64,lpq8 / "
                         "hnsw32,lpq8 / graph24,lpq8 / pq8+lpq")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--ef-search", type=int, default=100)
    ap.add_argument("--chunk", type=int, default=16384)
    ap.add_argument("--batch-sizes", default=None,
                    help="comma-separated compile buckets (default 1,8,32,256 "
                         "clipped to --batch)")
    ap.add_argument("--shards", type=int, default=0,
                    help="shard every plan kind over this many host devices "
                         "(rows/lists/segments placement; 0 = unsharded)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="data-parallel serving replicas behind per-replica "
                         "queues; with --shards the host's devices split "
                         "into this many disjoint sub-meshes "
                         "(dist.submeshes), one per replica")
    ap.add_argument("--rerank-depth", type=int, default=0,
                    help="override the rerank candidate depth (0 = the "
                         "index's default when built with +rN)")
    ap.add_argument("--filter-col", default=None,
                    help="serve every query under a metadata predicate "
                         "(DESIGN.md §16): synthesize a per-row integer "
                         "column with this name and keep only rows whose "
                         "value matches --filter-value")
    ap.add_argument("--filter-value", default="0",
                    help="allowed value(s) for --filter-col, "
                         "comma-separated (e.g. '3' or '1,4,6')")
    ap.add_argument("--filter-cats", type=int, default=8,
                    help="cardinality of the synthesized --filter-col "
                         "column (selectivity = |values| / cats)")
    ap.add_argument("--mixed", action="store_true",
                    help="cycle request sizes through several buckets")
    ap.add_argument("--mutate", action="store_true",
                    help="interleave an upsert and a delete request into "
                         "the traffic (stream(...) indexes only)")
    # -- runtime subsystem flags (DESIGN.md §12) ---------------------------
    ap.add_argument("--profile", default=None,
                    help="named runtime profile (default: "
                         "$REPRO_RUNTIME_PROFILE or 'default'); see "
                         "repro.runtime.profile.PROFILES")
    ap.add_argument("--profile-file", default=None,
                    help="load the runtime profile from a JSON file "
                         "(RuntimeProfile.to_dict() format) instead of "
                         "the named registry; overrides --profile")
    ap.add_argument("--budgets", default=None,
                    help="explicit cascade stage budgets, comma-separated "
                         "(e.g. '128,32' for cascade(pq16x4|lpq8|r32)); "
                         "cascade indexes only — validated at plan time")
    ap.add_argument("--cache", type=int, default=0,
                    help="result-cache capacity in entries (0 = off)")
    ap.add_argument("--cache-ttl", type=float, default=0.0,
                    help="result-cache TTL seconds (0 = no TTL)")
    ap.add_argument("--hot-repeat", type=int, default=0,
                    help="replay the first request every Nth request "
                         "(hot-query traffic shape; exercises the cache)")
    ap.add_argument("--admission", action="store_true",
                    help="enable token-bucket admission control with the "
                         "degrade/shed ladder")
    ap.add_argument("--rate", type=float, default=256.0,
                    help="admission token rate, tokens(=queries)/s")
    ap.add_argument("--burst", type=float, default=0.0,
                    help="admission bucket burst (default 8 * batch)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="hard backlog bound; arrivals beyond it are shed")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request deadline budget (0 = none); blown "
                         "deadlines shed, tight ones degrade")
    ap.add_argument("--maintenance", action="store_true",
                    help="run stream compaction/recalibration on a "
                         "background scheduler (off the request path)")
    ap.add_argument("--maintenance-interval", type=float, default=0.05,
                    help="background maintenance poll interval, seconds")
    ap.add_argument("--telemetry-out", default=None,
                    help="write the structured telemetry JSON here")
    # -- measured-dispatch (TuneTable) flags (DESIGN.md §13) ---------------
    ap.add_argument("--tune", default=None,
                    help="adopt a standalone TuneTable JSON (e.g. "
                         "TUNE_cpu.json) before planning; stamp-checked — "
                         "a foreign-backend table is parked for the "
                         "maintenance re-tune trigger, not crashed on")
    ap.add_argument("--index-path", default=None,
                    help="load a saved .npz index instead of building "
                         "(--index/--n/--d then come from the file; an "
                         "embedded TuneTable is adopted, stamp-checked)")
    ap.add_argument("--save-index", default=None,
                    help="save the served index to this .npz after build "
                         "(the active TuneTable rides along embedded)")
    return ap.parse_args(argv)


def _index_dim(index) -> int | None:
    """Logical query dimension of a loaded index (any kind)."""
    store = getattr(index, "store", None)
    if store is None:
        return None
    if hasattr(store, "d"):
        return int(store.d)
    # PQStore: m subspaces x ds dims per codebook
    return int(store.m * store.codebooks.shape[-1])


def main(argv: list[str] | None = None) -> None:
    args = _parse_args(argv)

    # profile first: platform/XLA/core-pinning are process-start state
    prof = rtprofile.apply(
        rtprofile.from_file(args.profile_file) if args.profile_file
        else rtprofile.resolve(args.profile)
    )

    import jax

    from repro.data import synthetic
    from repro.knn import SearchParams, make_index
    from repro.runtime import (
        SHED,
        AdmissionController,
        CachedSearcher,
        MaintenanceScheduler,
        Telemetry,
        TTLLRUCache,
    )

    # -- measured dispatch: adopt tables BEFORE stamping, so the stamp
    # (and therefore the telemetry report + trend comparability key)
    # records the tuning the session actually serves through
    from repro.knn import registry as knn_registry
    from repro.tune import table as tunetable

    if args.tune:
        tunetable.adopt(tunetable.TuneTable.from_json(args.tune))

    index = None
    build_s = 0.0
    if args.index_path:
        t0 = time.perf_counter()
        index = knn_registry.load_index(args.index_path)  # adopts any
        build_s = time.perf_counter() - t0                # embedded table
        args.index = f"loaded:{args.index_path}"
        args.n = index.n
        args.d = _index_dim(index) or args.d

    stamp = rtprofile.stamp(prof)
    telemetry = Telemetry(meta={
        "runtime": stamp,
        "index": args.index, "n": args.n, "d": args.d, "k": args.k,
        "batch": args.batch, "requests": args.requests,
        "mutate": bool(args.mutate), "admission": bool(args.admission),
        "cache": args.cache, "maintenance": bool(args.maintenance),
    })
    print(f"[serve] profile={prof.name} backend={stamp['backend']} "
          f"device={stamp['device_kind']} x{stamp['n_devices']} "
          f"interpret={stamp['interpret']} seed={prof.seed}")
    pend = tunetable.pending_mismatch()
    print(f"[serve] tune: table={stamp['tune_table'] or 'none'}"
          + (f" pending_mismatch={pend.table_hash()}" if pend is not None
             else ""))

    sizes = _request_sizes(args.requests, args.batch, args.mixed)
    n_extra = 8 if args.mutate else 0
    corpus, queries, _metric = synthetic.load(
        "product", args.n + n_extra, sum(sizes)
    )
    corpus = corpus[:, : args.d]
    queries = queries[:, : args.d]
    corpus, extra_rows = corpus[: args.n], corpus[args.n:]

    if index is None:
        t0 = time.perf_counter()
        index = make_index(args.index, corpus, key=rtprofile.key(prof))
        build_s = time.perf_counter() - t0
    if args.save_index:
        index.save(args.save_index)   # active TuneTable embeds via save_state
        print(f"[serve] saved index -> {args.save_index} "
              f"(tune={tunetable.active_hash() or 'none'})")

    # metadata predicate (DESIGN.md §16): a deterministic synthetic
    # column stands in for real per-row metadata; the bitmap rides
    # SearchParams into every plan (external-id space, so stream upserts
    # beyond the horizon pass until the column is extended)
    filt = None
    if args.filter_col:
        import zlib

        from repro.filter import Filter

        col = np.random.default_rng(
            zlib.crc32(args.filter_col.encode())
        ).integers(0, args.filter_cats, args.n)
        vals = sorted({int(v) for v in args.filter_value.split(",")})
        filt = Filter.from_column(col, vals)
        telemetry.counters["filter_allowed_rows"] = int(filt.count)
        telemetry.counters["filter_selectivity_permille"] = int(
            round(filt.selectivity * 1000)
        )
        telemetry.meta["filter"] = {
            "col": args.filter_col, "values": vals,
            "cats": args.filter_cats,
            "selectivity": round(filt.selectivity, 6),
        }
        print(f"[serve] filter: col={args.filter_col} values={vals} "
              f"selectivity={filt.selectivity:.3f} "
              f"({filt.count}/{args.n} rows allowed)")

    budgets = (tuple(int(b) for b in args.budgets.split(","))
               if args.budgets else None)
    sp = SearchParams(chunk=args.chunk, nprobe=args.nprobe,
                      ef_search=args.ef_search, budgets=budgets,
                      filter=filt)
    if args.batch_sizes:
        buckets = tuple(sorted(int(b) for b in args.batch_sizes.split(",")))
    else:
        buckets = tuple(b for b in (1, 8, 32, 256) if b <= args.batch) or (args.batch,)
        if buckets[-1] < args.batch:
            buckets = buckets + (args.batch,)

    mesh = None
    replica_meshes = None
    n_replicas = max(1, args.replicas)
    if n_replicas > 1:
        if args.shards > 1 and len(jax.devices()) > 1:
            # each replica shards over its own disjoint sub-mesh, so
            # R x S never oversubscribes a device
            from repro.dist.replica import submeshes

            groups = submeshes(n_replicas)
            n_replicas = len(groups)
            per = int(groups[0].devices.size)
            if per > 1:
                if args.shards > per:
                    print(f"[serve] --shards {args.shards} > {per} devices "
                          f"per replica group; using {per}")
                replica_meshes = groups
            else:
                print(f"[serve] {per} device per replica group — each "
                      "replica serves unsharded")
                replica_meshes = [None] * n_replicas
        else:
            # CPU-thread replicas sharing the device pool
            replica_meshes = [None] * n_replicas
    elif args.shards > 1:
        n_dev = len(jax.devices())
        if args.shards > n_dev:
            print(f"[serve] --shards {args.shards} > {n_dev} devices; "
                  f"using {n_dev} (pick a profile with host_device_count, "
                  "e.g. --profile cpu-mesh4, for more)")
        if min(args.shards, n_dev) > 1:
            mesh = jax.make_mesh((min(args.shards, n_dev),), ("data",))
        else:
            print("[serve] 1 device available — serving unsharded (a "
                  "1-shard mesh would be the degenerate merge formulation)")

    if args.mutate and not hasattr(index, "upsert"):
        raise SystemExit(
            f"--mutate needs a mutable index; {args.index!r} is {index.kind!r}"
            " — wrap it: stream(" + args.index + ")"
        )
    if args.maintenance and not hasattr(index, "compact_snapshot"):
        raise SystemExit(
            f"--maintenance needs a mutable (stream) index; {args.index!r} "
            f"is {index.kind!r}"
        )

    # -- admission + degrade ladder ---------------------------------------
    ctrl = None
    if args.admission:
        ctrl = AdmissionController(
            rate_qps=args.rate,
            burst=args.burst or 8.0 * args.batch,
            max_queue=args.max_queue,
            counters=telemetry.counters,
        )

    def make_searchers(shard_mesh=mesh):
        primary = index.searcher(
            args.k, sp, batch_sizes=buckets, shards=shard_mesh,
            rerank=args.rerank_depth or None,
        )
        degraded = None
        if ctrl is not None:
            d_depth = ctrl.policy.rerank_depth(
                primary.rerank.depth if primary.rerank else 0, args.k
            )
            # params(sp, k) also shrinks cascade stage budgets (floor k)
            degraded = index.searcher(
                args.k, ctrl.policy.params(sp, args.k), batch_sizes=buckets,
                shards=shard_mesh, rerank=(d_depth or False),
            )
        return primary, degraded

    # -- result cache tier -------------------------------------------------
    cache = None
    replan_gen = [0]                 # replan generation feeds cache keys
    if args.cache:
        cache = TTLLRUCache(args.cache, ttl_s=args.cache_ttl or None)

    def wrap(s, c=None):
        c = cache if c is None else c
        if s is None or c is None:
            return s
        return CachedSearcher(s, c, version=lambda: replan_gen[0])

    # -- replica group (dist.replica): R independent serving replicas ------
    replicas = None
    searcher = searcher_deg = serve_primary = serve_deg = None
    if n_replicas > 1:
        from repro.dist.replica import ReplicaSet

        replica_primaries: dict = {}

        def make_replica(r):
            primary, degraded = make_searchers(replica_meshes[r])
            replica_primaries[r] = primary
            # the result cache is per replica (TTLLRUCache is not
            # thread-safe; replica workers are threads)
            rc = (TTLLRUCache(args.cache, ttl_s=args.cache_ttl or None)
                  if args.cache else None)
            sx_p, sx_d = wrap(primary, rc), wrap(degraded, rc)
            # warm every bucket inside the build so worker threads never
            # compile on the request path
            for sz in sorted(set(sizes)):
                jax.block_until_ready(primary(queries[:sz]).ids)
                if degraded is not None:
                    jax.block_until_ready(degraded(queries[:sz]).ids)

            def run(item):
                payload, use_deg = item
                res = (sx_d if use_deg else sx_p)(payload)
                jax.block_until_ready(res.ids)
                return res

            return run

        replicas = ReplicaSet(make_replica, n_replicas,
                              max_queue=args.max_queue, telemetry=telemetry)
        head = replica_primaries[0]
    else:
        searcher, searcher_deg = make_searchers()
        serve_primary, serve_deg = wrap(searcher), wrap(searcher_deg)
        head = searcher

    print(f"[serve] index={args.index} kind={index.kind} build={build_s:.2f}s "
          f"memory={index.memory_bytes() / 1e6:.1f}MB buckets={buckets} "
          f"shards={head.n_shards} replicas={n_replicas} "
          f"rerank={head.rerank.depth if head.rerank else 0}"
          + (f" degraded_rerank="
             f"{searcher_deg.rerank.depth if searcher_deg and searcher_deg.rerank else 0}"
             if searcher_deg else ""))

    # placement accounting (DESIGN.md §15): what each shard holds
    if head.placement is not None:
        psum = head.placement.summary()
        row_bytes = getattr(getattr(index, "store", None), "row_bytes", None)
        if row_bytes:
            psum["shard_bytes"] = list(head.placement.shard_bytes(row_bytes))
        telemetry.meta["placement"] = psum
        print(f"[serve] placement: kind={psum['kind']} "
              f"shards={psum['n_shards']} units={psum['n_units']} "
              f"balance={psum['balance']}"
              + (f" shard_bytes={psum['shard_bytes']}"
                 if "shard_bytes" in psum else ""))

    # request queue (open loop: all arrivals enqueued up front); with
    # --mutate an upsert lands a third of the way in and a delete two
    # thirds in, between query requests (clamped so both ops always fire
    # even at --requests 1).  Admission runs at the door: shed arrivals
    # never enqueue; --hot-repeat replays the first payload every Nth
    # request (the hot-query traffic the cache tier exists for).
    up_at = min(max(1, len(sizes) // 3), len(sizes) - 1)
    del_at = min(max(2, (2 * len(sizes)) // 3), len(sizes) - 1)
    queue: collections.deque = collections.deque()
    off = 0
    first_payload = None
    for i, sz in enumerate(sizes):
        if args.mutate and i == up_at:
            queue.append(("upsert",
                          np.arange(args.n, args.n + extra_rows.shape[0]),
                          extra_rows, None, None))
        if args.mutate and i == del_at:
            queue.append(("delete", np.arange(0, 4), None, None, None))
        payload = queries[off : off + sz]
        off += sz
        if first_payload is None:
            first_payload = payload
        elif args.hot_repeat and i % args.hot_repeat == 0:
            payload = first_payload
        now = time.perf_counter()
        deadline = now + args.deadline_ms / 1e3 if args.deadline_ms else None
        decision = None
        if ctrl is not None:
            decision = ctrl.admit(int(payload.shape[0]), len(queue), deadline)
            if decision.action == SHED:
                telemetry.event("shed", request=i, reason=decision.reason,
                                queries=int(payload.shape[0]))
                continue
        queue.append(("query", payload, None, (now, deadline), decision))

    # warmup: run every distinct request size once through both plans —
    # this compiles each bucket executable the traffic will hit (incl.
    # remainder-slice buckets of oversize requests, cf.
    # Searcher.buckets_for) AND the per-shape pad/slice glue, so the
    # timed percentiles measure serving.  Warmup goes through the raw
    # searchers: the cache must not be pre-populated.
    def warm(primary, degraded):
        for sz in sorted(set(sizes)):
            jax.block_until_ready(primary(queries[:sz]).ids)
            if degraded is not None:
                jax.block_until_ready(degraded(queries[:sz]).ids)

    if replicas is None:
        warm(searcher, searcher_deg)   # replicas warm inside make_replica

    maint = None
    if args.maintenance:
        # lowest-priority trigger: a loaded index carried a TuneTable
        # measured on a foreign backend — re-measure here, off the
        # request path (only fires when pending_mismatch() is set)
        def retune_fn():
            from repro.tune import autotune

            return autotune(smoke=True)

        maint = MaintenanceScheduler(
            index, interval_s=args.maintenance_interval, telemetry=telemetry,
            retune_fn=retune_fn,
        ).start()

    latencies = []
    write_latencies = []
    totals: collections.Counter = collections.Counter()
    served = 0
    writes = 0
    seq = 0
    t0 = time.perf_counter()
    pending = []       # replica mode: (future, n_queries, degraded)
    while queue:
        op, payload, vecs, timing, decision = queue.popleft()
        t_req = time.perf_counter()
        if op == "query" and replicas is not None:
            # async path: route to the least-loaded replica; workers
            # record the per-request telemetry (queue_wait/execute)
            _t_enq, deadline = timing
            if ctrl is not None and decision is not None:
                decision = ctrl.recheck(decision, deadline)
                if decision.action == SHED:
                    telemetry.event("shed", reason=decision.reason,
                                    queries=int(payload.shape[0]))
                    continue
            degraded = decision.degraded if decision is not None else False
            fut = replicas.submit((payload, degraded),
                                  queries=int(payload.shape[0]))
            if fut is None:          # per-replica admission: queue full
                telemetry.event("shed", reason="replica_queue",
                                queries=int(payload.shape[0]))
                continue
            t_sub = time.perf_counter()
            fut.add_done_callback(
                lambda _f, t=t_sub: latencies.append(time.perf_counter() - t)
            )
            pending.append((fut, int(payload.shape[0]), degraded))
            continue
        if op == "query":
            t_enq, deadline = timing
            tr = telemetry.request(seq)
            seq += 1
            tr.phase("queue_wait", t_req - t_enq)
            if ctrl is not None and decision is not None:
                decision = ctrl.recheck(decision, deadline)
                if decision.action == SHED:
                    tr.annotate(outcome="shed", reason=decision.reason)
                    tr.finish()
                    continue
            degraded = decision.degraded if decision is not None else False
            sx = serve_deg if degraded else serve_primary
            with tr.span("execute"):
                res = sx(payload)
                jax.block_until_ready(res.ids)
            dt_req = time.perf_counter() - t_req
            latencies.append(dt_req)
            if ctrl is not None:
                ctrl.observe(dt_req)
            served += int(payload.shape[0])
            for key in _AGG_KEYS:
                totals[key] += int(res.stats.get(key, 0))
            hit = res.stats.get("cache") == "hit"
            telemetry.counters["queries_served"] += int(payload.shape[0])
            if degraded:
                telemetry.counters["requests_degraded"] += 1
            if filt is not None:
                telemetry.counters["filtered_requests"] += 1
                telemetry.counters["filtered_queries"] += int(
                    payload.shape[0])
                tr.annotate(
                    filter_selectivity=res.stats.get("filter_selectivity"))
            tr.annotate(outcome="served", degraded=degraded,
                        cache=res.stats.get("cache", "off"),
                        bucket=res.stats.get("bucket"),
                        padded_q=res.stats.get("padded_q"),
                        reranked=res.stats.get("reranked"),
                        queries=int(payload.shape[0]), cache_hit=hit)
            tr.finish()
        else:
            # write op: apply, then re-plan — a Searcher is a snapshot
            # (manifest-pinned) session.  If the mutation left the
            # manifest epoch unchanged (no-op delete, memtable-only
            # upsert below the seal threshold) the pinned snapshot is
            # still the authoritative sealed state and the re-plan is
            # skipped (counted; the write surfaces at the next
            # structural re-plan under LSM snapshot semantics).
            epoch_before = getattr(index, "epoch", None)
            if replicas is not None:
                replicas.drain()     # write barrier: no in-flight queries
            if op == "upsert":
                index.upsert(payload, vecs)
            else:
                index.delete(payload)
            replanned = epoch_before is None or index.epoch != epoch_before
            if replanned:
                replan_gen[0] += 1
                if replicas is not None:
                    # every replica re-plans (and re-warms) against the
                    # new manifest epoch before traffic resumes
                    replicas.rebuild()
                else:
                    searcher, searcher_deg = make_searchers()
                    serve_primary, serve_deg = wrap(searcher), wrap(searcher_deg)
                    # warm every distinct request size, as at startup — a
                    # cold bucket after the re-plan would pollute the query
                    # p95/p99
                    warm(searcher, searcher_deg)
                telemetry.counters["replans"] += 1
            else:
                telemetry.counters["replans_avoided"] += 1
            write_latencies.append(time.perf_counter() - t_req)
            writes += len(payload)
            telemetry.event("write", op=op, rows=int(len(payload)),
                            replanned=replanned, epoch=index.epoch
                            if epoch_before is not None else None)
    if replicas is not None:
        replicas.drain()
        for fut, nq, degraded in pending:
            res = fut.result()
            served += nq
            for key in _AGG_KEYS:
                totals[key] += int(res.stats.get(key, 0))
            telemetry.counters["queries_served"] += nq
            if degraded:
                telemetry.counters["requests_degraded"] += 1
            if filt is not None:
                telemetry.counters["filtered_requests"] += 1
                telemetry.counters["filtered_queries"] += nq
    dt = time.perf_counter() - t0

    # per-shard scan-bytes counters (placement accounting: each shard's
    # share of the session's scanned payload)
    if head.placement is not None and totals["bytes_read"]:
        p = head.placement
        rows_all = sum(p.shard_rows(s) for s in range(p.n_shards)) or 1
        for s in range(p.n_shards):
            telemetry.counters[f"shard{s}_scan_bytes"] = int(
                totals["bytes_read"] * p.shard_rows(s) / rows_all
            )

    if maint is not None:
        maint.stop()

    n_req = len(latencies)
    # query throughput excludes write ops' apply+replan+re-warm time —
    # that cost is reported separately below
    query_dt = max(dt - sum(write_latencies), 1e-9)
    print(f"[serve] {served} queries / {n_req} requests in {dt:.3f}s -> "
          f"{served / query_dt:.1f} QPS (k={args.k}, corpus={index.n}, "
          f"kind={index.kind})")
    if latencies:
        p50, p95, p99 = (float(np.percentile(latencies, p))
                         for p in (50, 95, 99))
        print(f"[serve] latency p50={p50 * 1e3:.2f}ms p95={p95 * 1e3:.2f}ms "
              f"p99={p99 * 1e3:.2f}ms")
    if write_latencies:
        print(f"[serve] writes: {writes} rows / {len(write_latencies)} ops, "
              f"apply+replan p50="
              f"{float(np.percentile(write_latencies, 50)) * 1e3:.2f}ms "
              f"replans={telemetry.counters['replans']} "
              f"avoided={telemetry.counters['replans_avoided']}; "
              f"index now n={index.n} "
              f"segments={index.stats()['segments']} "
              f"tombstones={index.stats()['tombstones']}")
    if cache is not None:
        cs = cache.stats()
        print(f"[serve] cache: hits={cs['hits']} misses={cs['misses']} "
              f"evictions={cs['evictions']} entries={cs['entries']}"
              + (f" ttl={cs['ttl_s']}s" if cs["ttl_s"] else ""))
    if ctrl is not None:
        c = telemetry.counters
        print(f"[serve] admission: admit={c['admission_admit']} "
              f"degrade={c['admission_degrade']} shed={c['admission_shed']} "
              f"(queue={c['admission_shed_queue']} "
              f"budget={c['admission_shed_budget']} "
              f"deadline={c['admission_shed_deadline']}) "
              f"shed_queries={c['admission_shed_queries']}")
    if replicas is not None:
        c = telemetry.counters
        per = " ".join(
            f"r{r}:req={c[f'replica{r}_requests']}"
            f"/peak={c[f'replica{r}_queue_peak']}"
            for r in range(n_replicas)
        )
        print(f"[serve] replicas: {n_replicas} shed={c['replica_shed']} {per}")
        replicas.close()
    if head.placement is not None:
        c = telemetry.counters
        print("[serve] shard scan bytes: "
              + " ".join(f"s{s}={c[f'shard{s}_scan_bytes']}"
                         for s in range(head.placement.n_shards)))
    if maint is not None:
        c = telemetry.counters
        print(f"[serve] maintenance: rounds={c['maintenance_rounds']} "
              f"swaps={c['maintenance_swaps']} "
              f"conflicts={c['maintenance_conflicts']} "
              f"retunes={c['maintenance_retunes']} "
              f"errors={c['maintenance_errors']}")
    # per-search engine accounting aggregated over the session (uniform
    # across kinds; DESIGN.md §8/§9) — means per request, plus totals for
    # the batch-cumulative keys (candidates/chunks/reranked are per-query
    # quantities and only meaningful as means)
    means = {key: totals[key] / max(n_req, 1) for key in _AGG_KEYS}
    print("[serve] stats/request mean: "
          + " ".join(f"{key}={means[key]:.1f}" for key in _AGG_KEYS))
    print(f"[serve] stats/session totals: "
          f"bytes_read={totals['bytes_read']} padded_q={totals['padded_q']}")

    if args.telemetry_out:
        telemetry.meta["report"] = {
            "qps": served / query_dt, "served": served, "requests": n_req,
            "writes": writes, **{f"mean_{k}": means[k] for k in _AGG_KEYS},
        }
        telemetry.to_json(args.telemetry_out)
        print(f"[serve] telemetry -> {args.telemetry_out} "
              f"({len(telemetry.events)} events)")


if __name__ == "__main__":
    main()
