# Launch surface: mesh construction, step builders, the multi-pod
# dry-run, the training launcher and the ANN serving loop.
# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and
# must be the process entrypoint.
from repro.launch.mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
