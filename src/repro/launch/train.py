"""Production training launcher: ``--arch <id>`` + mesh + fault-tolerant
loop.  On this CPU container it runs reduced configs end-to-end; on a TPU
fleet the same entrypoint shards the full config over the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --steps 200 --batch 8 --seq-len 128 --reduced --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
from functools import partial

import jax

from repro.configs import get
from repro.data import lm_data, recsys_data
from repro.train import OptConfig, TrainConfig, train
from repro.train.fault_tolerance import run_with_retries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--max-failures", type=int, default=3)
    args = ap.parse_args()

    mod = get(args.arch)
    schedule = getattr(mod, "OPTIMIZER_SCHEDULE", "cosine")
    opt_cfg = OptConfig(lr=args.lr, schedule=schedule,
                        warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps)
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                       microbatches=args.microbatches)

    if mod.FAMILY == "lm":
        from repro.models import transformer as TF

        cfg = mod.reduced_config() if args.reduced else mod.config()
        params = TF.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = partial(TF.lm_loss, cfg=cfg)
        data = lm_data.batch_iterator(args.batch, args.seq_len, cfg.vocab)
    elif mod.FAMILY == "recsys":
        from repro.models.recsys import models as RM

        cfg = mod.reduced_config() if args.reduced else mod.config()
        params = RM.init_params(jax.random.PRNGKey(0), cfg)
        loss_fn = partial(RM.bce_loss, cfg=cfg)
        data = recsys_data.batch_iterator(
            args.batch, cfg.n_dense, cfg.vocab_sizes, seq_len=cfg.seq_len
        )
    else:
        raise SystemExit(f"use examples/ for family {mod.FAMILY!r}")

    def job():
        return train(lambda p, b: loss_fn(p, b), params, data, opt_cfg, tcfg)

    # restart-from-checkpoint is inside train(); retries make crashes resumable
    _params, _opt, history = run_with_retries(
        job, restore=lambda: None, max_failures=args.max_failures
    )
    print(f"[launch/train] {args.arch}: final loss {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
