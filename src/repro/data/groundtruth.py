"""Exact ground-truth computation for recall measurement (paper §5.3)."""

from __future__ import annotations

import jax

from repro.knn.flat import FlatIndex


def exact_topk(corpus: jax.Array, queries: jax.Array, k: int, metric: str):
    """fp32 exhaustive top-k — S_E of the paper's recall definition."""
    return FlatIndex.build(corpus, metric=metric).search(queries, k)
