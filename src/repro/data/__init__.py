# Data substrate: hermetic synthetic generators for every corpus the
# paper and the assigned architectures touch — ANN corpora (narrow-band
# product embeddings / SIFT-like / GloVe-like), LM token streams,
# criteo-style CTR batches, graphs + a real fan-out neighbor sampler —
# plus exact ground-truth computation for recall.
from repro.data import graph_data, lm_data, recsys_data, synthetic
from repro.data.groundtruth import exact_topk

__all__ = ["graph_data", "lm_data", "recsys_data", "synthetic", "exact_topk"]
