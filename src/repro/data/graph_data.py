"""Graph data substrate: synthetic graphs in the four assigned shape
regimes plus a real GraphSAGE-style fan-out neighbor sampler (required by
the ``minibatch_lg`` cell — "needs a real neighbor sampler").

Graphs are (node_feat [N, F], senders [E], receivers [E], mask [E])
flat-padded edge lists — the segment_sum-ready layout used across the GNN
stack (JAX has no CSR; scatter over edge indices IS the system here).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Graph:
    node_feat: jax.Array          # [N, F] (or positions [N, 3] for molecules)
    senders: jax.Array            # [E] i32
    receivers: jax.Array          # [E] i32
    edge_mask: jax.Array          # [E] bool
    n_nodes: int
    positions: Optional[jax.Array] = None   # [N, 3] for molecular graphs
    labels: Optional[jax.Array] = None


def random_graph(
    n_nodes: int, n_edges: int, d_feat: int, key: jax.Array | None = None
) -> Graph:
    """Erdos-Renyi-ish graph with power-law-ish degree (preferential hubs)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    # hub-biased endpoints: square a uniform to concentrate on low ids
    s = (jax.random.uniform(k1, (n_edges,)) ** 2 * n_nodes).astype(jnp.int32)
    r = jax.random.randint(k2, (n_edges,), 0, n_nodes, dtype=jnp.int32)
    feat = jax.random.normal(k3, (n_nodes, d_feat)) * 0.5
    labels = jax.random.randint(k4, (n_nodes,), 0, 16, dtype=jnp.int32)
    return Graph(
        node_feat=feat,
        senders=jnp.clip(s, 0, n_nodes - 1),
        receivers=r,
        edge_mask=jnp.ones((n_edges,), jnp.bool_),
        n_nodes=n_nodes,
        labels=labels,
    )


def random_molecules(
    batch: int, n_atoms: int, n_edges_per: int, key: jax.Array | None = None
) -> Graph:
    """Batched small molecules (the ``molecule`` cell): one disjoint-union
    graph with block-diagonal connectivity and 3-D positions for SchNet."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    n = batch * n_atoms
    pos = jax.random.normal(k1, (batch, n_atoms, 3)) * 2.0
    z = jax.random.randint(k2, (batch, n_atoms), 1, 10, dtype=jnp.int32)  # atomic numbers

    s = jax.random.randint(k3, (batch, n_edges_per), 0, n_atoms, dtype=jnp.int32)
    r = jax.random.randint(k4, (batch, n_edges_per), 0, n_atoms, dtype=jnp.int32)
    offs = (jnp.arange(batch, dtype=jnp.int32) * n_atoms)[:, None]
    return Graph(
        node_feat=z.reshape(-1),                       # atomic numbers [N]
        senders=(s + offs).reshape(-1),
        receivers=(r + offs).reshape(-1),
        edge_mask=jnp.ones((batch * n_edges_per,), jnp.bool_),
        n_nodes=n,
        positions=pos.reshape(-1, 3),
        labels=jax.random.normal(k1, (batch,)),        # per-mol energy target
    )


# --------------------------------------------------------------------------
# Neighbor sampler (GraphSAGE fan-out) — host-side, numpy CSR
# --------------------------------------------------------------------------

class NeighborSampler:
    """Uniform fan-out sampler over a static graph.

    Builds a CSR adjacency once (numpy), then ``sample(seed_nodes,
    fanouts)`` returns a fixed-shape padded subgraph: layered gather ids
    and edge lists compatible with the segment_sum message passing.  This
    is the real sampler the ``minibatch_lg`` cell requires.
    """

    def __init__(self, senders: np.ndarray, receivers: np.ndarray, n_nodes: int):
        order = np.argsort(receivers, kind="stable")
        self.dst_sorted_src = senders[order]
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        np.add.at(self.indptr, receivers + 1, 1)
        self.indptr = np.cumsum(self.indptr)
        self.n_nodes = n_nodes

    def sample(
        self, seeds: np.ndarray, fanouts: tuple[int, ...], rng: np.random.Generator
    ):
        """Returns (all_nodes [M], layers) where each layer has
        (senders_local, receivers_local, mask) into all_nodes."""
        frontier = np.unique(seeds)
        all_nodes = [frontier]
        layers = []
        for fan in fanouts:
            src_list, dst_list = [], []
            for v in frontier:
                lo, hi = self.indptr[v], self.indptr[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = rng.integers(0, deg, size=fan)
                nbrs = self.dst_sorted_src[lo + take]
                src_list.append(nbrs)
                dst_list.append(np.full(fan, v, np.int64))
            if src_list:
                src = np.concatenate(src_list)
                dst = np.concatenate(dst_list)
            else:
                src = np.zeros(0, np.int64)
                dst = np.zeros(0, np.int64)
            layers.append((src, dst))
            frontier = np.unique(src)
            all_nodes.append(frontier)

        nodes = np.unique(np.concatenate(all_nodes))
        remap = {int(g): i for i, g in enumerate(nodes)}
        out_layers = []
        for src, dst in layers:
            pad = max(len(src), 1)
            s_l = np.zeros(pad, np.int32)
            r_l = np.zeros(pad, np.int32)
            m_l = np.zeros(pad, bool)
            for i, (a, b) in enumerate(zip(src, dst)):
                s_l[i] = remap[int(a)]
                r_l[i] = remap[int(b)]
                m_l[i] = True
            out_layers.append((s_l, r_l, m_l))
        return nodes.astype(np.int64), out_layers
