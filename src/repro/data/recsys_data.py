"""Criteo-style synthetic recsys batches: dense floats + multi-hot sparse
categorical ids with a power-law id distribution (the regime that makes
embedding-table sharding and the paper's int8 tables interesting).
"""

from __future__ import annotations

from typing import Iterator, Sequence

import jax
import jax.numpy as jnp


def _powerlaw_ids(key: jax.Array, shape, vocab: int) -> jax.Array:
    """Zipf-ish ids: heavy head, long tail — like real ctr logs."""
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
    # inverse-CDF of p(i) ~ 1/(i+1): i = (vocab^u - 1)
    ids = jnp.expm1(u * jnp.log(float(vocab))).astype(jnp.int32)
    return jnp.clip(ids, 0, vocab - 1)


def ctr_batch(
    key: jax.Array,
    batch: int,
    n_dense: int,
    vocab_sizes: Sequence[int],
    seq_len: int = 0,
) -> dict[str, jax.Array]:
    """One CTR batch.

    Returns dense [B, n_dense] f32, sparse ids [B, F] i32, label [B] f32,
    and optionally a behaviour-sequence hist_ids [B, seq_len] (DIEN).
    """
    keys = jax.random.split(key, 4 + len(vocab_sizes))
    dense = jax.random.normal(keys[0], (batch, n_dense)) if n_dense else jnp.zeros((batch, 0))
    sparse = jnp.stack(
        [
            _powerlaw_ids(keys[2 + f], (batch,), v)
            for f, v in enumerate(vocab_sizes)
        ],
        axis=1,
    )
    label = (jax.random.uniform(keys[1], (batch,)) < 0.25).astype(jnp.float32)
    out = {"dense": dense.astype(jnp.float32), "sparse": sparse, "label": label}
    if seq_len:
        out["hist_ids"] = _powerlaw_ids(keys[-1], (batch, seq_len), int(vocab_sizes[0]))
        out["hist_mask"] = jnp.ones((batch, seq_len), jnp.float32)
    return out


def batch_iterator(
    batch: int,
    n_dense: int,
    vocab_sizes: Sequence[int],
    seq_len: int = 0,
    seed: int = 0,
    sharding=None,
    start_step: int = 0,
) -> Iterator[dict[str, jax.Array]]:
    step = start_step
    while True:
        b = ctr_batch(
            jax.random.fold_in(jax.random.PRNGKey(seed), step),
            batch, n_dense, vocab_sizes, seq_len,
        )
        if sharding is not None:
            b = jax.device_put(b, sharding)
        yield b
        step += 1
