"""Synthetic LM token pipeline: deterministic, shardable, infinite.

Real deployments swap in a tokenized corpus reader; the interface —
``batch_iterator`` yielding {tokens, targets, mask} pytrees with
device_put to a NamedSharding — is what the train loop consumes, and the
synthetic generator makes every test/benchmark hermetic.
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp


def lm_batch(
    key: jax.Array, batch: int, seq_len: int, vocab: int
) -> dict[str, jax.Array]:
    """One causal-LM batch: tokens + next-token targets + loss mask."""
    toks = jax.random.randint(key, (batch, seq_len + 1), 0, vocab, dtype=jnp.int32)
    return {
        "tokens": toks[:, :-1],
        "targets": toks[:, 1:],
        "mask": jnp.ones((batch, seq_len), jnp.float32),
    }


def batch_iterator(
    batch: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    sharding=None,
    start_step: int = 0,
) -> Iterator[dict[str, jax.Array]]:
    """Infinite deterministic batch stream.

    ``start_step`` makes the stream resumable after checkpoint restore —
    data order is a pure function of (seed, step), a fault-tolerance
    requirement at scale (restart must not replay or skip data).
    """
    step = start_step
    while True:
        b = lm_batch(jax.random.fold_in(jax.random.PRNGKey(seed), step), batch, seq_len, vocab)
        if sharding is not None:
            b = jax.device_put(b, sharding)
        yield b
        step += 1
