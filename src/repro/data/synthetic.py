"""Synthetic dataset generators matching the paper's evaluation corpora.

The paper evaluates on three families:
  * PRODUCT60M — product embeddings whose values cluster in a very narrow
    band (Fig 1: values exclusively in (-.125, .125), 50% within
    +-(.08, .125)).  ``product_embeddings`` reproduces that distribution:
    a heavy-centre Gaussian mixture clipped to the band, constant across
    dimensions (the paper's §4.1 interdimensional-uniformity regime).
  * SIFT — 128-dim local image descriptors, non-negative, heavy-tailed,
    L2 metric.  ``sift_like`` mimics the value profile (gamma-distributed
    magnitudes, integer-ish grid) at configurable scale.
  * Glove100 — 100-dim word embeddings, roughly Gaussian per dim with
    per-dimension spread, angular metric.  ``glove_like``.

All generators return (corpus [N, d] f32, queries [Q, d] f32).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def product_embeddings(
    n: int,
    d: int = 256,
    n_queries: int = 1000,
    key: jax.Array | None = None,
):
    """Narrow-band e-commerce-style embeddings (paper Fig 1)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def _draw(kk, rows):
        ka, kb, kc = jax.random.split(kk, 3)
        # mixture: 50% in +-(.08, .125) band tails, rest tight at centre
        centre = jax.random.normal(ka, (rows, d)) * 0.04
        band_sign = jnp.sign(jax.random.normal(kb, (rows, d)))
        band = band_sign * jax.random.uniform(kc, (rows, d), minval=0.08, maxval=0.125)
        pick = jax.random.uniform(kk, (rows, d)) < 0.5
        x = jnp.where(pick, band, centre)
        return jnp.clip(x, -0.12499, 0.12499)

    corpus = _draw(k1, n)
    # queries live in the same semantic space (paper: 1000 search queries)
    queries = _draw(k2, n_queries)
    del k3, k4
    return corpus, queries


def sift_like(n: int, d: int = 128, n_queries: int = 1000, key: jax.Array | None = None):
    """SIFT-style descriptors: non-negative, gamma-ish, L2 metric."""
    if key is None:
        key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)

    def _draw(kk, rows):
        mag = jax.random.gamma(kk, 2.0, (rows, d)) * 18.0
        return jnp.floor(jnp.clip(mag, 0.0, 218.0))  # SIFT's uint8-ish grid

    return _draw(k1, n), _draw(k2, n_queries)


def glove_like(n: int, d: int = 100, n_queries: int = 1000, key: jax.Array | None = None):
    """GloVe-style word embeddings: per-dim Gaussian, angular metric."""
    if key is None:
        key = jax.random.PRNGKey(2)
    k1, k2, k3 = jax.random.split(key, 3)
    # per-dimension scale spread (glove dims are not iso-scaled)
    dim_scale = 0.3 + jax.random.uniform(k3, (d,)) * 0.5

    def _draw(kk, rows):
        return jax.random.normal(kk, (rows, d)) * dim_scale[None, :]

    return _draw(k1, n), _draw(k2, n_queries)


DATASETS = {
    "product": product_embeddings,
    "sift": sift_like,
    "glove": glove_like,
}

METRIC_FOR = {"product": "ip", "sift": "l2", "glove": "angular"}


def load(name: str, n: int, n_queries: int = 1000, key: jax.Array | None = None):
    """(corpus, queries, metric) for a named paper dataset family."""
    corpus, queries = DATASETS[name](n, n_queries=n_queries, key=key)
    return corpus, queries, METRIC_FOR[name]
