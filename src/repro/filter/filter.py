"""Predicate filters over an index's external id space (DESIGN.md §16).

A :class:`Filter` is an immutable boolean bitmap aligned with the
external ids of an index — ``mask[ext_id]`` says whether that row may be
returned.  It is deliberately *below* the index layer: every kind pushes
the bitmap into the engine's existing pad/tombstone id-masking (the
``ok = gid < n_valid`` fence in ``_stream_topk`` and the fused Pallas
kernels), so a filter costs one mask AND per scored tile, never a
[Q, N] rescan and never extra ``bytes_read``.

Filters are declared at plan time through ``SearchParams(filter=...)``
and therefore ride inside compiled-plan and result-cache keys — which is
why a Filter hashes and compares by a content digest of its bitmap, not
by object identity: two plans over equal bitmaps share one executable.

Construction mirrors the metadata-column reality of production filtering:

    f = Filter.from_mask(mask)                 # you already have the bitmap
    f = Filter.from_ids([3, 17, 99], n)        # allow-list of external ids
    f = Filter.from_column(cats, 7)            # cats[i] == 7
    f = Filter.from_column(cats, {2, 7})       # cats[i] ∈ {2, 7}
    f = Filter.from_predicate(prices, lambda p: p < 30.0, n)

and composes as a boolean algebra: ``f & g``, ``f | g``, ``~f``.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Iterable

import numpy as np


def _freeze(mask: np.ndarray) -> np.ndarray:
    out = np.ascontiguousarray(np.asarray(mask, dtype=bool))
    if out.ndim != 1:
        raise ValueError(f"filter mask must be 1-D, got shape {out.shape}")
    out.setflags(write=False)
    return out


def _digest(mask: np.ndarray) -> str:
    h = hashlib.blake2b(digest_size=16)
    h.update(np.int64(mask.shape[0]).tobytes())
    h.update(np.packbits(mask).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Filter:
    """Immutable allow-bitmap over external row ids.

    ``mask[i]`` is True iff external id ``i`` may appear in results.
    Equality and hashing go through ``digest`` (content, not identity),
    so a Filter is a valid member of frozen ``SearchParams`` and of
    compiled-plan / result-cache keys.
    """

    mask: np.ndarray
    digest: str

    # -- constructors ------------------------------------------------------

    @staticmethod
    def from_mask(mask) -> "Filter":
        m = _freeze(mask)
        return Filter(m, _digest(m))

    @staticmethod
    def from_ids(ids: Iterable[int], n: int) -> "Filter":
        """Allow-list: only these external ids survive."""
        m = np.zeros(int(n), dtype=bool)
        idx = np.asarray(list(ids), dtype=np.int64)
        if idx.size:
            if idx.min() < 0 or idx.max() >= n:
                raise ValueError(
                    f"filter ids must lie in [0, {n}), got range "
                    f"[{idx.min()}, {idx.max()}]"
                )
            m[idx] = True
        return Filter.from_mask(m)

    @staticmethod
    def from_column(column, value: Any) -> "Filter":
        """Equality / membership over a per-row metadata column.

        ``value`` may be a scalar (``column == value``) or a
        set/list/tuple/array (``column ∈ value``).
        """
        col = np.asarray(column)
        if col.ndim != 1:
            raise ValueError(
                f"metadata column must be 1-D, got shape {col.shape}"
            )
        if isinstance(value, (set, frozenset, list, tuple, np.ndarray)):
            vals = np.asarray(sorted(value) if isinstance(
                value, (set, frozenset)) else value)
            return Filter.from_mask(np.isin(col, vals))
        return Filter.from_mask(col == value)

    @staticmethod
    def from_predicate(column, pred: Callable[[np.ndarray], np.ndarray],
                       n: int | None = None) -> "Filter":
        """Arbitrary vectorized predicate over a metadata column."""
        col = np.asarray(column)
        m = np.asarray(pred(col), dtype=bool)
        if m.shape != col.shape:
            raise ValueError(
                f"predicate must return one bool per row: column "
                f"{col.shape} -> mask {m.shape}"
            )
        if n is not None and m.shape[0] != n:
            raise ValueError(
                f"filter covers {m.shape[0]} rows but index has {n}"
            )
        return Filter.from_mask(m)

    # -- interrogation -----------------------------------------------------

    @property
    def n(self) -> int:
        return int(self.mask.shape[0])

    @property
    def count(self) -> int:
        """Number of surviving (allowed) rows."""
        return int(self.mask.sum())

    @property
    def selectivity(self) -> float:
        """Fraction of rows that survive (1.0 = filter-none)."""
        return self.count / self.n if self.n else 1.0

    def ids(self) -> np.ndarray:
        """The surviving external ids, ascending."""
        return np.flatnonzero(self.mask)

    def aligned(self, n: int) -> np.ndarray:
        """The bitmap resized to an index of ``n`` rows.

        Rows the filter never saw (appended after it was built, e.g.
        stream upserts past the bitmap's horizon) default to *allowed* —
        a filter constrains what it describes, it does not veto unknown
        rows.  Shrinking just truncates.
        """
        if n == self.n:
            return self.mask
        if n < self.n:
            return self.mask[:n]
        return np.concatenate(
            [self.mask, np.ones(n - self.n, dtype=bool)]
        )

    # -- boolean algebra ---------------------------------------------------

    def _binop(self, other: "Filter", op) -> "Filter":
        if not isinstance(other, Filter):
            return NotImplemented
        if other.n != self.n:
            raise ValueError(
                f"cannot compose filters over different id spaces "
                f"({self.n} vs {other.n} rows)"
            )
        return Filter.from_mask(op(self.mask, other.mask))

    def __and__(self, other: "Filter") -> "Filter":
        return self._binop(other, np.logical_and)

    def __or__(self, other: "Filter") -> "Filter":
        return self._binop(other, np.logical_or)

    def __invert__(self) -> "Filter":
        return Filter.from_mask(~self.mask)

    # -- identity ----------------------------------------------------------

    def __hash__(self) -> int:
        return hash((self.n, self.digest))

    def __eq__(self, other) -> bool:
        if not isinstance(other, Filter):
            return NotImplemented
        return self.n == other.n and self.digest == other.digest

    def __repr__(self) -> str:
        return (f"Filter(n={self.n}, count={self.count}, "
                f"selectivity={self.selectivity:.3f}, "
                f"digest={self.digest[:8]})")


def overfetch(k: int, selectivity: float, n: int) -> int:
    """Candidate depth to request so ~k survivors remain post-filter.

    The engine masks *inside* the scan, so exact kinds don't need this —
    they see every row.  It exists for the candidate-generating kinds
    (graph walks, per-segment over-fetch): to keep k survivors when only
    a ``selectivity`` fraction of candidates pass, fetch ``k/selectivity``
    plus a safety margin, clamped to the corpus.  Selectivity 0 (filter-
    all) clamps to n: the oracle answer is "all pad", reached by scanning
    everything and finding no survivor.
    """
    if selectivity >= 1.0:
        return min(k, n) if n else k
    sel = max(float(selectivity), 1e-9)
    want = int(np.ceil(k / sel)) + 8
    return max(k, min(want, n))
