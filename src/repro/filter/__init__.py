"""Filtered search: predicate bitmaps in the engine's id-masking path."""

from repro.filter.filter import Filter, overfetch

__all__ = ["Filter", "overfetch"]
