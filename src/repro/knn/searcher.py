"""The Searcher: compiled, sharded, rerank-capable search sessions
(DESIGN.md §9) — the query-plan API behind every index kind.

The paper's throughput claim is a *serving-time* claim, but an eager
``index.search()`` re-resolves dispatch and re-pads shapes on every
request.  ``index.searcher(k, params, ...)`` separates plan time from
query time, the way PR 1 separated build time:

  * **plan once** — kind/metric/bits/packed dispatch is resolved and
    ``SearchParams`` frozen into a pure runner (``index.plan(k, sp)``);
    invalid plans (k <= 0, k > n, chunk <= 0, nprobe <= 0) fail here with
    ``ValueError``s, not kernel-shape errors mid-trace.
  * **compile per bucket** — the runner is jitted once per padded
    batch-size bucket (default 1/8/32/256), so arbitrary request sizes
    hit a small, fixed set of compiled shapes; ``trace_counts`` exposes
    the compilation ledger the tests assert on.
  * **shard natively** — given a mesh, the flat scan row-shards its
    ``CodeStore`` over every mesh axis (``dist.sharding.corpus_shards``)
    and fuses shard-local top-k with one k-sized cross-shard merge
    *inside* the compiled function (O(shards·Q·k) wire, DESIGN.md §4).
  * **rerank** — an optional ``Rerank(depth, store)`` tail re-scores the
    quantized top-``depth`` candidates against an fp32/int8 store by
    gathered-row exact distance in the same jit (the paper's §3.4 recall
    recovery; ``"flat,lpq4+r32"`` builds the store at index time).
  * **account** — every result's stats carry the engine block plus
    ``{bucket, padded_q, shards, reranked}``.

``Index.search`` is a thin one-shot searcher (``one_shot``), so every
pre-plan call site keeps working unchanged.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro import engine
from repro.knn import base as B
from repro.tune import table as tunetable

__all__ = ["Searcher", "Rerank", "one_shot", "sharded_scan_plan",
           "multi_source_plan", "DEFAULT_BATCH_SIZES", "DEFAULT_RERANK_DEPTH"]

#: padded batch-size buckets a plan compiles for (smallest covering
#: bucket is picked per request; oversize requests run in max-bucket
#: slices)
DEFAULT_BATCH_SIZES = (1, 8, 32, 256)

NEG = float(jnp.finfo(jnp.float32).min)

PlanFn = Callable[[jax.Array], B.SearchResult]


def DEFAULT_RERANK_DEPTH(k: int, n: int) -> int:
    """Candidate depth when a rerank store exists but no depth is given:
    4k (clamped to [k, n]) — deep enough that the exact pass can repair
    low-bit scan inversions, shallow enough that the gather stays O(Q·k)."""
    return max(k, min(n, 4 * k))


@dataclasses.dataclass(frozen=True)
class Rerank:
    """Rerank stage config: re-score the quantized top-``depth`` against
    ``store`` (an fp32 or int8 ``engine.CodeStore``) by exact distance.

    ``store`` is None for indexes that own their rerank stage
    (``handles_rerank = True``, e.g. the stream kind, whose multi-segment
    merge re-scores against the manifest's raw payloads inside its own
    plan) — the Searcher then only resolves the depth and passes it down.
    """

    depth: int
    store: Optional[engine.CodeStore]


def _query_dim(index) -> Optional[int]:
    """Expected query width, for plan-time shape validation."""
    store = getattr(index, "store", None)
    if isinstance(store, engine.CodeStore):
        # the graph kind's MIP->L2 augmentation adds one internal column
        return store.d - 1 if getattr(index, "aug", False) else store.d
    if isinstance(store, engine.PQStore):
        return int(store.codebooks.shape[0] * store.codebooks.shape[2])
    d = getattr(index, "d", None)           # store-less kinds (stream)
    return int(d) if d is not None else None


def _resolve_rerank(index, k: int, n: int, rerank) -> Optional[Rerank]:
    """Normalize the ``rerank=`` argument against the index's own store.

    None  -> the index's ``+rN`` store at default depth (or no rerank)
    False -> explicitly off, even for a ``+rN`` index
    int   -> depth override over the index's ``+rN`` store
    Rerank -> fully explicit (store must cover the same id space)

    Indexes with ``handles_rerank = True`` resolve to a store-less
    ``Rerank(depth, None)``: the depth is passed to ``index.plan`` and the
    index's own runner re-scores (the Searcher runs no tail of its own).
    """
    if rerank is False:
        return None
    if getattr(index, "handles_rerank", False):
        if rerank is None:
            if getattr(index, "rerank_bits", None) is None:
                return None
            return Rerank(DEFAULT_RERANK_DEPTH(k, n), None)
        if rerank is True:
            return Rerank(DEFAULT_RERANK_DEPTH(k, n), None)
        if isinstance(rerank, bool) or not isinstance(rerank, int):
            raise TypeError(
                f"{index.kind!r} owns its rerank stage; pass None / False / "
                f"an int depth, not {type(rerank)!r}"
            )
        if rerank <= 0:
            raise ValueError(f"rerank depth must be positive, got {rerank}")
        return Rerank(max(k, min(int(rerank), max(n, k))), None)
    own = getattr(index, "rerank_store", None)
    if rerank is None or rerank is True:
        if own is None:
            if rerank is True:
                raise ValueError(
                    "rerank=True but the index holds no rerank store — "
                    "build with a '+r32'/'+r8' factory suffix or pass "
                    "Rerank(depth, store)"
                )
            return None
        return Rerank(DEFAULT_RERANK_DEPTH(k, n), own)
    if isinstance(rerank, int):
        if own is None:
            raise ValueError(
                f"rerank depth {rerank} given but the index holds no rerank "
                "store — build with a '+r32'/'+r8' factory suffix or pass "
                "Rerank(depth, store)"
            )
        rerank = Rerank(int(rerank), own)
    if not isinstance(rerank, Rerank):
        raise TypeError(
            f"rerank must be None/False/int depth/Rerank, got {type(rerank)!r}"
        )
    if not isinstance(rerank.store, engine.CodeStore):
        raise TypeError("Rerank.store must be an engine.CodeStore")
    if rerank.store.n != n:
        raise ValueError(
            f"rerank store covers {rerank.store.n} rows but the index holds "
            f"{n} — the stores must share one id space"
        )
    if rerank.depth <= 0:
        raise ValueError(f"rerank depth must be positive, got {rerank.depth}")
    # clamp to the useful band: >= k (the tail must be able to fill the
    # result) and <= n (deeper gathers than the corpus are pure waste)
    return dataclasses.replace(rerank, depth=max(k, min(rerank.depth, n)))


# --------------------------------------------------------------------------
# sharded flat scan: the row-sharded plan body (used by FlatIndex.plan)
# --------------------------------------------------------------------------

def sharded_scan_plan(
    store: engine.CodeStore, metric: str, k: int, mesh, chunk: int = 16384,
    placement=None, mask=None,
) -> PlanFn:
    """Row-shard a ``CodeStore`` scan over a mesh (DESIGN.md §4/§9/§15).

    Queries replicate; corpus rows shard over every mesh axis in the
    contiguous blocks a ``rows`` :class:`~repro.dist.placement.Placement`
    describes; each shard streams its slice in ``chunk``-row tiles
    (unpacking int4 tile by tile) with a running local top-k — the same
    O(Q·(k+chunk)) working set as the unsharded scan, never a [Q, N_loc]
    score matrix.  Pad rows are id-masked at the source with
    globally-unique sentinel gids (``dist.sharding.sentinel_gids`` — a
    tile-pad row's arithmetic gid lands in the NEXT shard's id range, so
    the sentinel is what makes a missed mask an impossible alias instead
    of a silent wrong neighbor), and ``distributed_topk`` merges the
    per-shard candidates with one k-sized all_gather; block order ==
    gid order, so the merge's stable shard-major tie-break reproduces
    the unsharded scan's canonical (score desc, gid asc) order exactly.
    The whole thing is a pure function of the query batch, so the
    Searcher compiles scan -> local top-k -> cross-shard merge
    (-> rerank) as one unit.

    ``mask`` (optional [n] bool, DESIGN.md §16) is a filter bitmap over
    the store's row ids: it shards alongside the data rows and ANDs into
    the *validity* handed to ``sentinel_gids`` — a filtered row gets a
    sentinel gid >= n and dies at the existing ``gid < n`` fence, so the
    filter rides the pad/tombstone masking path with zero extra scans
    and an unchanged merge.
    """
    from repro.core import distances as D
    from repro.core import pack as PK
    from repro.dist.placement import Placement
    from repro.dist.sharding import P, corpus_shards, sentinel_gids, shard_map
    from repro.engine import distributed_topk

    if store.base:
        raise ValueError("sharded plans require a base-0 store (the plan "
                         "owns the global id space)")
    axes, n_shards = corpus_shards(mesh)
    n = store.n
    if placement is None:
        placement = Placement.rows(n, n_shards)
    if placement.n_shards != n_shards:
        raise ValueError(
            f"placement covers {placement.n_shards} shards but the mesh has "
            f"{n_shards}"
        )
    if placement.kind != "rows":
        raise ValueError(
            f"sharded_scan_plan shards contiguous row blocks; got a "
            f"{placement.kind!r} placement"
        )
    rows_per = -(-n // n_shards)
    pad = n_shards * rows_per - n
    k_merge = min(k, n)
    k_local = min(k_merge, rows_per)
    tile_rows = min(chunk, rows_per)
    n_tiles = -(-rows_per // tile_rows)
    padded_rows = n_tiles * tile_rows          # per-shard sentinel band width
    data = jnp.pad(store.data, ((0, pad), (0, 0))) if pad else store.data
    shard_idx = jnp.arange(n_shards, dtype=jnp.int32)
    fmask = None
    if mask is not None:
        fm = jnp.asarray(mask).astype(jnp.int8)
        fmask = jnp.pad(fm, (0, pad)) if pad else fm

    def local(q, shard, mshard, idx):
        gid0 = idx[0] * rows_per
        Q = q.shape[0]
        tile_pad = padded_rows - rows_per
        if tile_pad:
            shard = jnp.pad(shard, ((0, tile_pad), (0, 0)))
        tiles = shard.reshape(n_tiles, tile_rows, shard.shape[-1])
        if mshard is not None:
            if tile_pad:
                mshard = jnp.pad(mshard, (0, tile_pad))
            mtiles = mshard.reshape(n_tiles, tile_rows)
        else:
            mtiles = jnp.zeros((n_tiles, 0), jnp.int8)

        def step(carry, inp):
            tile, mrow, t = inp
            rows = PK.unpack_int4(tile) if store.packed else tile
            s = D.scores(q, rows, metric, quantized=store.quantized)
            s = s.astype(jnp.float32)
            lrow = t * tile_rows + jnp.arange(tile_rows, dtype=jnp.int32)
            # pad rows — the shard's own tile pad (lrow >= rows_per,
            # whose arithmetic gid aliases the NEXT shard) and the
            # global tail pad (gid >= n) — get unique >= n sentinels:
            # validity now travels in the gid itself.  A filtered-out
            # row is treated exactly like a pad row: its sentinel gid
            # dies at the same fence (DESIGN.md §16).
            valid = (lrow < rows_per) & (gid0 + lrow < n)
            if mshard is not None:
                valid = valid & (mrow != 0)
            gid = sentinel_gids(
                gid0 + lrow, valid,
                shard=idx[0], local_rows=lrow, n_total=n,
                padded_rows=padded_rows,
            )
            ok = gid < n
            s = jnp.where(ok[None, :], s, NEG)
            ids = jnp.where(ok[None, :], jnp.broadcast_to(gid[None], s.shape), -1)
            return engine.merge_topk(*carry, s, ids, k_local), None

        init = (jnp.full((Q, k_local), NEG, jnp.float32),
                jnp.full((Q, k_local), -1, jnp.int32))
        (ls, li), _ = jax.lax.scan(
            step, init, (tiles, mtiles, jnp.arange(n_tiles, dtype=jnp.int32))
        )
        return distributed_topk(ls, li, k_merge, axes, 0)

    merge_wire = n_shards * k_merge * 8        # per query: fp32 score + i32 id

    def run(queries: jax.Array) -> B.SearchResult:
        q = store.encode_queries(queries)
        if fmask is None:
            s, i = inner(q, data, shard_idx)
        else:
            s, i = inner(q, data, fmask, shard_idx)
        # belt under the sentinel braces: nothing >= n may leave the plan
        i = jnp.where(i >= n, -1, i)
        if k_merge < k:                  # uniform [Q, k] contract: -1 pads
            s = jnp.pad(s, ((0, 0), (0, k - k_merge)), constant_values=NEG)
            i = jnp.pad(i, ((0, 0), (0, k - k_merge)), constant_values=-1)
        stats = engine.search_stats(store, candidates=n,
                                    chunks=n_shards * n_tiles, rows_read=n)
        return B.SearchResult(s, i, {
            "kind": "flat", **stats, "placement": placement.kind,
            "merge_wire_bytes": int(queries.shape[0]) * merge_wire,
        })

    if fmask is None:
        # keep the unfiltered trace byte-identical to the pre-filter plan
        def local_plain(q, shard, idx):
            return local(q, shard, None, idx)

        inner = shard_map(
            local_plain,
            mesh=mesh,
            in_specs=(P(), P(axes, None), P(axes)),
            out_specs=(P(), P()),
            check_vma=False,
        )
    else:
        inner = shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), P(axes, None), P(axes), P(axes)),
            out_specs=(P(), P()),
            check_vma=False,
        )

    return run


# --------------------------------------------------------------------------
# multi-source plans: segments + memtable behind one runner (stream kind)
# --------------------------------------------------------------------------

def multi_source_plan(
    sources: Sequence[tuple[PlanFn, int, int]],
    *,
    k: int,
    metric: str,
    id_map: jax.Array,
    live: jax.Array,
    merge_store: Optional[engine.CodeStore],
    rescore: bool,
    stats_extra: Optional[dict] = None,
    mesh=None,
    placement=None,
) -> PlanFn:
    """Fuse per-source plans into one runner over a shared internal id
    space (DESIGN.md §10 — the stream kind's search path).

    ``sources`` is a list of ``(runner, base, width)``: each runner is a
    kind's ``plan`` output over one sealed segment (or the memtable's
    flat scan) returning *local* ids; ``base`` rebases them into the
    manifest's internal id space, ``width`` is the candidate count the
    runner returns.  The fused runner:

      1. runs every source, rebases ids, and **tombstone-masks** deleted
         rows through the manifest's ``live`` bitmap (masked at candidate
         level: a dead row can occupy a candidate slot but never a
         result slot — sources over-fetch by their masked count so k
         surviving rows always reach the merge on exact sources).  A
         search-time filter (DESIGN.md §16) composes here too: the
         caller hands ``live ∧ filter`` as one internal-space bitmap, so
         a filtered row dies exactly like a tombstoned one;
      2. merges: with ``rescore``, all candidates are re-scored in one
         common space via ``engine.topk_among`` against ``merge_store``
         (per-segment quantized scores are NOT comparable across
         differently-calibrated segments — the re-score is what makes
         the merge sound, and doubles as the ``+rN`` rerank tail); a
         single source with no re-score requested passes through its own
         score order (the exact-parity path a freshly-compacted stream
         index shares with its from-scratch equivalent);
      3. maps internal ids to external ids via ``engine.remap_ids``.

    Everything is a pure function of the query batch, so the Searcher
    compiles sources -> mask -> merge -> remap as one executable per
    bucket.  Like every plan, the runner snapshots the state it closed
    over — mutations after plan time need a new plan (LSM readers pin a
    manifest version; DESIGN.md §10).

    Under a ``mesh``, the per-source runners handed in are themselves
    sharded plans (each segment's inner kind shards its own rows/lists
    over the full mesh — see DESIGN.md §15) and the merge/rescore above
    them stays replicated inside the same jit; ``placement`` (a
    ``segments`` Placement) is the accounting view, stamped into the
    stats so serve telemetry can report per-shard residency.
    """
    if rescore and merge_store is None:
        raise ValueError("rescoring merge needs a merge_store")
    extra = dict(stats_extra or {})
    if placement is not None:
        extra["placement"] = placement.kind
        extra["placement_balance"] = placement.summary()["balance"]
    total_width = sum(w for _, _, w in sources)

    def run(queries: jax.Array) -> B.SearchResult:
        q = jnp.asarray(queries, jnp.float32)
        Q = q.shape[0]
        if not sources:                       # fully empty index
            return B.SearchResult(
                jnp.full((Q, k), NEG, jnp.float32),
                jnp.full((Q, k), -1, jnp.int32),
                {"kind": "stream", "candidates": 0, "reranked": 0, **extra},
            )

        parts_s, parts_i = [], []
        agg = {"candidates": 0, "bytes_read": 0, "chunks": 0,
               "merge_wire_bytes": 0}
        for runner, base, _w in sources:
            res = runner(q)
            gid = jnp.where(res.ids >= 0, res.ids + base, -1)
            parts_s.append(res.scores)
            parts_i.append(gid)
            for key in agg:
                agg[key] += int(res.stats.get(key, 0))
        s = jnp.concatenate(parts_s, axis=1)
        gids = jnp.concatenate(parts_i, axis=1)

        # tombstone mask: dead rows lose their candidate slot here, at
        # merge level, inside the compiled function
        ok = (gids >= 0) & live[jnp.clip(gids, 0, live.shape[0] - 1)]
        s = jnp.where(ok, s, NEG)
        gids = jnp.where(ok, gids, -1)

        stats = {"kind": "stream", **agg, **extra}
        if rescore:
            qm = merge_store.encode_queries(q)
            s, gids = engine.topk_among(qm, merge_store, gids, k, metric)
            stats.update(
                reranked=total_width,
                rerank_bits=int(merge_store.bits),
                rerank_bytes=int(Q) * total_width * merge_store.row_bytes,
            )
            stats["bytes_read"] += stats["rerank_bytes"]
        else:
            # single-source pass-through: keep the source's own score
            # order (lax.top_k is stable, so dropping dead slots cannot
            # reorder live ties)
            k_eff = min(k, s.shape[1])
            s, pos = jax.lax.top_k(s, k_eff)
            gids = jnp.take_along_axis(gids, pos, axis=-1)
            if k_eff < k:
                s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
                gids = jnp.pad(gids, ((0, 0), (0, k - k_eff)),
                               constant_values=-1)
            stats["reranked"] = 0
        ext = engine.remap_ids(gids, id_map)
        return B.SearchResult(s, ext, stats)

    return run


# --------------------------------------------------------------------------
# the Searcher handle
# --------------------------------------------------------------------------

class Searcher:
    """A planned search session: ``index.searcher(k, params)(queries)``.

    Construction *is* plan time: arguments are validated, the rerank
    stage resolved, the per-kind runner built (``index.plan``) and the
    jit wrapper created.  Calls execute: the request is sliced into
    batch-size buckets, padded, run through the compiled executable for
    that bucket, and stitched back with uniform accounting.

    ``batch_sizes=None`` is the one-shot mode ``Index.search`` uses: no
    padding, no extra jit wrapper — exactly the historical eager call.
    """

    def __init__(
        self,
        index,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        batch_sizes: Optional[Sequence[int]] = DEFAULT_BATCH_SIZES,
        shards=None,
        rerank: Union[None, bool, int, Rerank] = None,
        strict: bool = True,
    ):
        if not isinstance(k, int) or isinstance(k, bool) or k <= 0:
            raise ValueError(f"k must be a positive int, got {k!r}")
        n = int(index.n)
        if strict and k > n:
            raise ValueError(
                f"k={k} exceeds the corpus size n={n}; a plan cannot return "
                "more neighbors than the index holds"
            )
        sp = (params or B.SearchParams()).validate()
        if batch_sizes is not None:
            batch_sizes = tuple(sorted(set(int(b) for b in batch_sizes)))
            if not batch_sizes or batch_sizes[0] <= 0:
                raise ValueError(
                    f"batch_sizes must be positive ints, got {batch_sizes!r}"
                )

        self.index = index
        self.k = k
        self.params = sp
        self.batch_sizes = batch_sizes
        self.mesh = shards
        self.rerank = _resolve_rerank(index, k, n, rerank)
        if self.rerank is not None and sp.filter is not None:
            # filter over-fetch (DESIGN.md §16): widen the candidate
            # depth by the filter's estimated selectivity so ~k allowed
            # rows survive to the settling stage; survivors < k still
            # pad with (-1, NEG) — the exact pad-sentinel contract
            from repro.filter import overfetch

            self.rerank = dataclasses.replace(
                self.rerank,
                depth=max(self.rerank.depth,
                          overfetch(k, sp.filter.selectivity, n)),
            )
        self._qdim = _query_dim(index)
        self._counts: collections.Counter = collections.Counter()

        n_shards = int(shards.devices.size) if shards is not None else 1
        # plan-time table resolution: the active TuneTable (if it matches
        # this backend's stamp) is snapshotted NOW and pinned around every
        # runner execution, so bucketed executables compile with the
        # tuned shapes this plan saw — a table installed later cannot
        # silently retile a compiled plan (DESIGN.md §13)
        self.tune_table = tunetable.snapshot_for_plan()
        # plan-time placement resolution mirrors the table: the unit ->
        # shard assignment is computed NOW from the index's sizes (list
        # sizes / segment rows / row count) and handed to the plan, so a
        # mutation after plan time cannot silently re-place a compiled
        # plan's shards (DESIGN.md §15)
        if shards is not None:
            from repro.dist import placement as dplacement

            self.placement = dplacement.for_index(index, n_shards)
        else:
            self.placement = None
        self._extras = {"shards": n_shards,
                        "tuned": self.tune_table is not None}
        if self.placement is not None:
            self._extras["placement"] = self.placement.kind
            self._extras["placement_balance"] = (
                self.placement.summary()["balance"])

        rr = self.rerank
        if rr is not None and rr.store is None:
            # index-owned rerank (stream): the plan runs scan -> merge ->
            # exact re-score itself; hand it k AND the candidate depth
            inner = index.plan(k, sp, mesh=shards, rerank_depth=rr.depth,
                               placement=self.placement)
            rr = None
        else:
            k_inner = rr.depth if rr is not None else k
            inner = index.plan(k_inner, sp, mesh=shards,
                               placement=self.placement)
        metric = index.metric

        def run(queries: jax.Array) -> B.SearchResult:
            self._counts[int(queries.shape[0])] += 1   # fires once per trace
            with tunetable.pinned(self.tune_table):    # plan-time snapshot
                res = inner(queries)
            stats = dict(res.stats)
            s, i = res.scores, res.ids
            if rr is not None:
                s, i, rstats = engine.rerank_among(
                    queries, rr.store, i, k, metric
                )
                stats.update(rstats)
                stats["bytes_read"] = (
                    stats.get("bytes_read", 0) + rstats["rerank_bytes"]
                )
            else:
                stats.setdefault("reranked", 0)
            return B.SearchResult(s, i, stats)

        self._run = run
        self._jitted = jax.jit(run) if batch_sizes is not None else run

    # -- accounting --------------------------------------------------------
    @property
    def trace_counts(self) -> dict[int, int]:
        """bucket size -> number of times the runner was (re)traced."""
        return dict(self._counts)

    @property
    def n_shards(self) -> int:
        return self._extras["shards"]

    def buckets_for(self, q_len: int) -> tuple[int, ...]:
        """The compile buckets a ``q_len``-query request will execute in
        (one per slice) — callers warm these before timing (serve.py)."""
        if self.batch_sizes is None:
            return (q_len,)
        out = []
        max_b = self.batch_sizes[-1]
        while q_len > 0:
            rows = min(q_len, max_b)
            out.append(next(b for b in self.batch_sizes if b >= rows))
            q_len -= rows
        return tuple(out)

    # -- execution ---------------------------------------------------------
    def _validate_queries(self, queries) -> jax.Array:
        q = jnp.asarray(queries)
        if q.ndim != 2:
            raise ValueError(
                f"queries must be [Q, d], got shape {tuple(q.shape)}"
            )
        if q.shape[0] == 0:
            raise ValueError("empty query batch: queries.shape[0] == 0")
        if self._qdim is not None and int(q.shape[1]) != self._qdim:
            raise ValueError(
                f"query dim {int(q.shape[1])} != index dim {self._qdim}"
            )
        return q

    def __call__(self, queries) -> B.SearchResult:
        q = self._validate_queries(queries)
        if self.batch_sizes is None:                       # one-shot mode
            res = self._run(q)
            return B.SearchResult(res.scores, res.ids, {
                **res.stats, **self._extras,
                "bucket": int(q.shape[0]), "padded_q": 0,
            })

        total = int(q.shape[0])
        max_b = self.batch_sizes[-1]
        parts_s, parts_i = [], []
        padded_q = 0
        # batch-cumulative keys sum across slices; the remaining stats
        # (candidates/chunks/reranked: per-query by the engine contract,
        # identical in every slice) carry over from the last one
        summed = {"bytes_read": 0, "rerank_bytes": 0}
        stats: dict[str, Any] = {}
        bucket = max_b
        start = 0
        while start < total:
            stop = min(start + max_b, total)
            sl = q[start:stop]
            rows = stop - start
            bucket = next(b for b in self.batch_sizes if b >= rows)
            if bucket > rows:
                sl = jnp.pad(sl, ((0, bucket - rows), (0, 0)))
            res = self._jitted(sl)
            parts_s.append(res.scores[:rows])
            parts_i.append(res.ids[:rows])
            padded_q += bucket - rows
            for key in summed:
                summed[key] += int(res.stats.get(key, 0))
            stats = dict(res.stats)
            start = stop

        s = parts_s[0] if len(parts_s) == 1 else jnp.concatenate(parts_s)
        i = parts_i[0] if len(parts_i) == 1 else jnp.concatenate(parts_i)
        stats.update(self._extras)
        stats.update(bucket=bucket, padded_q=padded_q,
                     bytes_read=summed["bytes_read"])
        if summed["rerank_bytes"]:
            stats["rerank_bytes"] = summed["rerank_bytes"]
        return B.SearchResult(s, i, stats)


def one_shot(index, queries, k: int, params: Optional[B.SearchParams]) -> B.SearchResult:
    """The eager path ``Index.search`` delegates to: a non-strict (k > n
    keeps the historical pad-with--1 contract), unbucketed, unsharded
    searcher built and called once."""
    return Searcher(index, k, params, batch_sizes=None, strict=False)(queries)
