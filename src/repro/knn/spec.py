"""Unified index configuration: ``QuantSpec``, ``IndexSpec`` and the
FAISS-style factory-string parser.

The paper's central claim is that low-precision quantization is an
*implementation-level* substitution — "it can be combined with existing
KNN algorithms".  These spec objects make that composition expressible as
one API: a single ``QuantSpec`` describes the (Q, phi) family of Eq. 1
(bits, scheme, clamp width, optionally pre-learned constants) and plugs
unchanged into any index ``kind``; an ``IndexSpec`` adds the per-kind
build parameters.  ``parse_factory`` turns FAISS-style strings into specs:

    "flat"                  exhaustive fp32 scan
    "flat,lpq8"             exhaustive int8 scan (the paper's Table 2 arm)
    "ivf256,lpq8"           IVF, 256 lists, int8 codes
    "hnsw32,lpq8@gaussian:3" HNSW M=32, int8 with 3-sigma Gaussian clamp
    "graph24,lpq8"          NGT-equivalent graph index, degree 24
    "pq64+lpq"              PQ with 64 subspaces, int8 ADC tables
    "pq16x4"                PQ with 16 subspaces and 4-bit codewords:
                            16-entry codebooks, codes bit-packed two per
                            byte (half the code bytes of pq16); "pq64"
                            stays an alias for "pq64x8"
    "pq16x4,lpq8"           the fused-ADC arm: packed 4-bit codes scored
                            in-kernel against int8-quantized LUTs
    "flat,lpq8,l2"          metric override fragment (ip | l2 | angular)
    "flat,lpq4+r32"         packed int4 scan + fp32 rerank tail (§3.4
                            recall recovery; DESIGN.md §9)
    "pq16+lpq,r32"          standalone rerank fragment for kinds whose
                            quant rides elsewhere (PQ ADC tables)
    "stream(ivf256,lpq4)+r32"  mutable LSM-style wrapper around any other
                            kind: memtable + quantized segments +
                            tombstones + live compaction (DESIGN.md §10)
    "cascade(pq16x4|lpq8|r32)"  N-stage scoring cascade (DESIGN.md §14):
                            the head stage (any non-stream factory) prunes
                            the corpus to a per-stage candidate budget,
                            each later stage re-scores the survivors at
                            higher precision (lpq<bits> int codes, r8 int8,
                            r32 fp32), the final stage settles the top-k
    "ivf64,lpq8,regions"    per-region Eq. 1 constants: one constant set
                            per IVF list / graph neighborhood instead of
                            one global set, with density-scaled clipping

Grammar: comma-separated fragments.  Exactly one *kind* fragment
(``flat`` | ``ivf<nlist>`` | ``hnsw<M>`` | ``graph<degree>`` |
``pq<M>[x<b>][+lpq]`` with b in {4, 8}), at most one *quant* fragment
(``lpq<bits>[@<scheme>][:<sigmas>][+r<rbits>]``), at most one *metric*
fragment, at most one *rerank* fragment (``r<rbits>``, rbits in {8, 32} —
the precision of the exact re-scoring store the Searcher's rerank tail
gathers from).  ``to_factory`` is the inverse, up to default elision.

The mutable wrapper is an outer production: ``stream(<factory>)[+r<N>]``,
where ``<factory>`` is any non-stream factory string (the sealed-segment
kind) and the rerank suffix — whether written inside or outside the
parens — names the precision of the cross-segment merge/rerank store.

The cascade is a second outer production: ``cascade(<head>|<stage>|...)``
with ``|``-separated stages.  The head is any non-stream, non-cascade
factory string; every later stage is a precision fragment — ``lpq<bits>``
(its own Eq. 1 constants, learned on the build corpus) or ``r8`` / ``r32``
(the rerank-store precisions).  Stage fetch budgets are *plan-time* knobs
(``SearchParams.budgets``), not grammar, so one built cascade serves any
budget schedule.  ``stream(cascade(...))`` composes; a rerank fragment
inside the head is rejected — write it as a later stage instead.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Mapping, Optional

from repro.core import quant as Qz
from repro.engine.store import PQ_CODE_BITS

METRICS = ("ip", "l2", "angular")

#: kind -> (numeric build-parameter set by the factory fragment, default)
KIND_PARAM = {
    "flat": (None, None),
    "ivf": ("nlist", 64),
    "hnsw": ("m", 16),
    "graph": ("degree", 32),
    "pq": ("m", 8),
    # the mutable LSM wrapper; its "parameter" is a whole inner factory
    # string carried in params["inner"], not a numeric fragment
    "stream": (None, None),
    # the multi-stage scoring cascade; its "parameter" is the normalized
    # "|"-joined stage list carried in params["stages"]
    "cascade": (None, None),
}


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """The paper's quantization family as a reusable configuration.

    ``params`` may carry pre-learned Eq. 1 constants so several index
    components (or several indexes over the same corpus) share one
    learn pass; when absent, ``learn`` fits them on the build corpus.

    ``packed`` selects bit-packed storage (two 4-bit codes per byte).
    ``None`` means automatic: 4-bit codes pack (honest width — the
    ``lpq4`` factory arm), everything else stores at dtype width.  Pass
    ``packed=False`` to keep int4 codes at int8 width (the unpacked
    reference arm the parity tests compare against).
    """

    bits: int = 8
    scheme: str = "gaussian"
    sigmas: float = 1.0
    params: Optional[Qz.QuantParams] = None
    packed: Optional[bool] = None

    @property
    def effective_packed(self) -> bool:
        return self.bits == 4 if self.packed is None else self.packed

    def learn(self, corpus) -> Qz.QuantParams:
        """Resolve Eq. 1 constants: reuse pre-learned params or fit."""
        if self.params is not None:
            return self.params
        return Qz.learn_params(
            corpus, bits=self.bits, scheme=self.scheme, sigmas=self.sigmas
        )

    def encode(self, x, params: Qz.QuantParams):
        """Apply Eq. 1 through the kernel path — the single quantize
        entrypoint every index build/query routes through."""
        from repro.kernels import ops as K

        return K.quantize(x, params.lo, params.hi, params.zero, bits=params.bits)

    def build_store(self, corpus, base: int = 0):
        """learn + encode + (maybe) pack into an ``engine.CodeStore`` —
        how every index build materializes its corpus payload."""
        from repro.engine import CodeStore

        if self.bits > 8:
            raise ValueError(
                f"the scoring engine supports B <= 8 (got bits={self.bits}): "
                "wider codes overflow int32 score accumulation"
            )
        qp = self.learn(corpus)
        codes = self.encode(corpus, qp)
        return CodeStore.from_codes(
            codes, qp, pack=self.effective_packed, base=base
        )

    def with_params(self, params: Qz.QuantParams) -> "QuantSpec":
        return dataclasses.replace(self, params=params)

    def to_fragment(self) -> str:
        frag = f"lpq{self.bits}"
        if self.scheme != "gaussian":
            frag += f"@{self.scheme}"
        if self.sigmas != 1.0:
            frag += f":{self.sigmas:g}"
        return frag


def quant_spec_from_kwargs(
    quantized: bool = False,
    bits: int = 8,
    scheme: str | Qz.Scheme = Qz.Scheme.GAUSSIAN,
    sigmas: float = 1.0,
    params: Optional[Qz.QuantParams] = None,
) -> Optional[QuantSpec]:
    """Adapter from the pre-unification per-index kwargs to a QuantSpec.

    Legacy semantics: ``params`` is only honored when ``quantized=True``
    (an fp32 build ignores it), exactly as the old per-index builds did.
    """
    if not quantized:
        return None
    if params is not None:
        return QuantSpec(
            bits=params.bits, scheme=params.scheme, sigmas=sigmas, params=params
        )
    return QuantSpec(bits=bits, scheme=Qz.Scheme(scheme).value, sigmas=sigmas)


#: precisions a rerank store may hold: fp32 exact or int8 codes
RERANK_BITS = (8, 32)


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """One config object any index, benchmark or serving path accepts.

    ``rerank_bits`` asks the build to keep a second, higher-precision
    ``CodeStore`` of the corpus (32 = fp32, 8 = int8) that the Searcher's
    rerank tail re-scores quantized candidates against — the paper's §3.4
    recall-recovery pattern as a first-class config (``"flat,lpq4+r32"``).
    """

    kind: str = "flat"
    metric: str = "ip"
    quant: Optional[QuantSpec] = None
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    rerank_bits: Optional[int] = None

    def __post_init__(self):
        if self.kind not in KIND_PARAM:
            raise ValueError(
                f"unknown index kind {self.kind!r}; known: {sorted(KIND_PARAM)}"
            )
        if self.metric not in METRICS:
            raise ValueError(f"unknown metric {self.metric!r}; known: {METRICS}")
        if self.rerank_bits is not None and self.rerank_bits not in RERANK_BITS:
            raise ValueError(
                f"rerank_bits must be one of {RERANK_BITS} (got "
                f"{self.rerank_bits!r}): the rerank store is fp32 or int8"
            )
        if self.kind == "stream" and "inner" not in self.params:
            raise ValueError(
                "a stream spec needs params['inner'] — the factory string "
                "of the kind its sealed segments are built as, e.g. "
                "parse_factory('stream(flat,lpq4)')"
            )
        if self.kind == "cascade":
            if "stages" not in self.params:
                raise ValueError(
                    "a cascade spec needs params['stages'] — the "
                    "'|'-joined stage list, e.g. "
                    "parse_factory('cascade(pq16x4|lpq8|r32)')"
                )
            if self.rerank_bits is not None:
                raise ValueError(
                    "a cascade spec takes no rerank fragment: the rerank "
                    "tail is generalized by the stage list — write "
                    "'cascade(...|r32)' instead of '+r32'"
                )
        if self.params.get("regions") and self.kind in ("flat", "pq"):
            raise ValueError(
                f"'regions' needs a partitioned kind (per-IVF-list or "
                f"per-graph-neighborhood constants): {self.kind!r} has no "
                "regions — use ivf/hnsw/graph, e.g. 'ivf64,lpq8,regions'"
            )
        if (self.kind == "pq"
                and self.params.get("bits") not in (None, *PQ_CODE_BITS)):
            raise ValueError(
                f"pq codeword width must be one of {PQ_CODE_BITS} bits "
                f"(16- or 256-codeword codebooks), got "
                f"bits={self.params['bits']!r}"
            )

    def with_overrides(self, **overrides) -> "IndexSpec":
        """Merge extra build parameters (ef_construction, key knobs...)."""
        return dataclasses.replace(self, params={**dict(self.params), **overrides})

    def to_factory(self) -> str:
        """Inverse of ``parse_factory`` (defaults elided)."""
        if self.kind == "stream":
            frag = f"stream({self.params['inner']})"
            if self.rerank_bits is not None:
                frag += f"+r{self.rerank_bits}"
            return frag
        if self.kind == "cascade":
            return f"cascade({self.params['stages']})"
        pname, pdefault = KIND_PARAM[self.kind]
        frag = self.kind
        if pname is not None:
            frag += str(self.params.get(pname, pdefault))
        if self.kind == "pq" and int(self.params.get("bits") or 8) != 8:
            frag += f"x{int(self.params['bits'])}"
        if self.kind == "pq" and self.params.get("lpq_tables"):
            frag += "+lpq"
        parts = [frag]
        if self.quant is not None:
            qfrag = self.quant.to_fragment()
            if self.rerank_bits is not None:
                qfrag += f"+r{self.rerank_bits}"
            parts.append(qfrag)
        elif self.rerank_bits is not None:
            parts.append(f"r{self.rerank_bits}")
        if self.params.get("regions"):
            parts.append("regions")
        if self.metric != "ip":
            parts.append(self.metric)
        return ",".join(parts)


_KIND_RE = re.compile(r"^(flat|ivf|hnsw|graph|pq)(\d+)?(?:x(\d+))?(\+lpq)?$")
_QUANT_RE = re.compile(
    r"^lpq(\d+)(?:@([a-z_0-9]+))?(?::([0-9.]+))?(?:\+r(\d+))?$"
)
_RERANK_RE = re.compile(r"^r(\d+)$")


_STREAM_RE = re.compile(r"^stream\((.+)\)(\+r(\d+))?$", re.IGNORECASE)
_CASCADE_RE = re.compile(r"^cascade\((.+)\)$", re.IGNORECASE)


def _parse_cascade(factory: str, metric: str | None) -> IndexSpec:
    """``cascade(<head>|<stage>|...)`` -> a kind-"cascade" spec.

    The head stage is parsed recursively (any non-stream, non-cascade
    factory) and re-serialized in normalized form; later stages are
    precision fragments (``lpq<bits>[@scheme][:sigmas]`` | ``r8`` |
    ``r32``).  The normalized ``"|"``-joined stage list rides in
    ``params["stages"]`` so the spec stays a plain JSON-able record,
    exactly like stream's ``params["inner"]``.
    """
    m = _CASCADE_RE.match(factory.strip())
    assert m is not None
    stages = [s.strip() for s in m.group(1).split("|")]
    if len(stages) < 2:
        raise ValueError(
            f"cascade needs at least two '|'-separated stages (a head "
            f"index and one refinement), got {factory!r}"
        )
    if _STREAM_RE.match(stages[0]) or _CASCADE_RE.match(stages[0]):
        raise ValueError(
            f"cascade head must be a plain kind, not {stages[0]!r}: "
            "wrap the whole cascade in stream(...) instead of nesting"
        )
    head = parse_factory(stages[0], metric=metric)
    if head.rerank_bits is not None:
        raise ValueError(
            f"cascade head {stages[0]!r} carries a rerank fragment — "
            "write the exact tail as a later stage: "
            "cascade(pq16x4|lpq8|r32), not cascade(pq16x4+r32|lpq8)"
        )
    norm = [head.to_factory()]
    for s in stages[1:]:
        frag = s.lower()
        mq = _QUANT_RE.match(frag)
        if mq:
            if mq.group(4):
                raise ValueError(
                    f"cascade stage {s!r} carries a '+r' suffix — each "
                    "precision is its own stage: write '|lpq8|r32'"
                )
            bits = int(mq.group(1))
            if not 1 <= bits <= 8:
                raise ValueError(
                    f"lpq bits must be in [1, 8], got {bits} in {factory!r}"
                )
            scheme = mq.group(2) or "gaussian"
            Qz.Scheme(scheme)  # validate early
            sigmas = float(mq.group(3)) if mq.group(3) else 1.0
            norm.append(
                QuantSpec(bits=bits, scheme=scheme, sigmas=sigmas).to_fragment()
            )
            continue
        mr = _RERANK_RE.match(frag)
        if mr:
            rbits = int(mr.group(1))
            if rbits not in RERANK_BITS:
                raise ValueError(
                    f"rerank precision must be one of {RERANK_BITS} "
                    f"(fp32 or int8 store), got r{rbits} in {factory!r}"
                )
            norm.append(f"r{rbits}")
            continue
        raise ValueError(
            f"cascade stage {s!r} in {factory!r} must be a precision "
            "fragment: lpq<bits>[@scheme][:sigmas], r8, or r32"
        )
    return IndexSpec(
        kind="cascade",
        metric=head.metric,
        params={"stages": "|".join(norm)},
    )


def _parse_stream(factory: str, metric: str | None) -> IndexSpec:
    """``stream(<inner factory>)[+r<N>]`` -> a kind-"stream" spec.

    The inner factory is parsed recursively (nesting ``stream`` inside
    ``stream`` is rejected) and re-serialized in normalized form into
    ``params["inner"]`` — segment builds call ``parse_factory`` on it
    again, so the spec stays a plain JSON-able record.  A rerank fragment
    written inside the parens is lifted to the outer spec: the rerank /
    merge store belongs to the wrapper (which keeps the raw fp32
    payloads), not to any single sealed segment.
    """
    m = _STREAM_RE.match(factory.strip())
    assert m is not None
    inner_str = m.group(1)
    if _STREAM_RE.match(inner_str.strip()):
        raise ValueError(
            f"nested stream(...) in {factory!r}: the mutable wrapper "
            "already composes with every registered kind"
        )
    inner = parse_factory(inner_str, metric=metric)
    rerank_bits = inner.rerank_bits
    if m.group(3) is not None:
        if rerank_bits is not None:
            raise ValueError(f"duplicate rerank fragment in {factory!r}")
        rerank_bits = int(m.group(3))
        if rerank_bits not in RERANK_BITS:
            raise ValueError(
                f"rerank precision must be one of {RERANK_BITS} "
                f"(fp32 or int8 store), got r{rerank_bits} in {factory!r}"
            )
    inner = dataclasses.replace(inner, rerank_bits=None)
    return IndexSpec(
        kind="stream",
        metric=inner.metric,
        params={"inner": inner.to_factory()},
        rerank_bits=rerank_bits,
    )


def parse_factory(factory: str, metric: str | None = None) -> IndexSpec:
    """Parse a FAISS-style factory string into an ``IndexSpec``.

    ``metric`` provides the default when the string has no metric fragment.
    """
    if _STREAM_RE.match(factory.strip()):
        return _parse_stream(factory, metric)
    if _CASCADE_RE.match(factory.strip()):
        return _parse_cascade(factory, metric)
    if re.match(r"^cascade\(.*\)\+r\d+$", factory.strip(), re.IGNORECASE):
        raise ValueError(
            f"a cascade takes no '+r' suffix ({factory!r}): the final "
            "stage IS the rerank — spell it cascade(...|r32)"
        )
    kind = None
    params: dict[str, Any] = {}
    quant = None
    rerank_bits: Optional[int] = None
    regions = False
    out_metric = metric or "ip"
    metric_seen = False

    def _set_rerank(bits_str: str) -> None:
        nonlocal rerank_bits
        if rerank_bits is not None:
            raise ValueError(f"duplicate rerank fragment in {factory!r}")
        rbits = int(bits_str)
        if rbits not in RERANK_BITS:
            raise ValueError(
                f"rerank precision must be one of {RERANK_BITS} "
                f"(fp32 or int8 store), got r{rbits} in {factory!r}"
            )
        rerank_bits = rbits

    for raw in factory.split(","):
        frag = raw.strip().lower()
        if not frag:
            continue
        if frag in METRICS:
            if metric_seen:
                raise ValueError(f"duplicate metric fragment in {factory!r}")
            metric_seen = True
            out_metric = frag
            continue
        if frag == "regions":
            if regions:
                raise ValueError(f"duplicate regions fragment in {factory!r}")
            regions = True
            continue
        mq = _QUANT_RE.match(frag)
        if mq:
            if quant is not None:
                raise ValueError(f"duplicate quant fragment in {factory!r}")
            bits = int(mq.group(1))
            if not 1 <= bits <= 8:
                # int16 codes overflow the engine's int32 accumulation
                # (d * (2^15)^2 > 2^31 already at d=2) — the paper's
                # low-precision regime is B <= 8
                raise ValueError(
                    f"lpq bits must be in [1, 8], got {bits} in {factory!r}"
                )
            scheme = mq.group(2) or "gaussian"
            Qz.Scheme(scheme)  # validate early
            sigmas = float(mq.group(3)) if mq.group(3) else 1.0
            quant = QuantSpec(bits=bits, scheme=scheme, sigmas=sigmas)
            if mq.group(4):
                _set_rerank(mq.group(4))
            continue
        mr = _RERANK_RE.match(frag)
        if mr:
            _set_rerank(mr.group(1))
            continue
        mk = _KIND_RE.match(frag)
        if mk:
            if kind is not None:
                raise ValueError(f"duplicate kind fragment in {factory!r}")
            kind = mk.group(1)
            pname, pdefault = KIND_PARAM[kind]
            if mk.group(2) is not None:
                if pname is None:
                    raise ValueError(f"{kind!r} takes no numeric parameter")
                params[pname] = int(mk.group(2))
            elif pname is not None:
                params[pname] = pdefault
            if mk.group(3) is not None:
                if kind != "pq":
                    raise ValueError(
                        f"codeword-width suffix 'x{mk.group(3)}' only "
                        f"composes with pq, not {kind!r} (in {factory!r})"
                    )
                cbits = int(mk.group(3))
                if cbits not in PQ_CODE_BITS:
                    raise ValueError(
                        f"pq codeword width must be one of {PQ_CODE_BITS} "
                        f"bits (16- or 256-codeword codebooks), got "
                        f"'x{cbits}' in {factory!r}"
                    )
                if cbits != 8:              # pq<M> stays an alias of x8
                    params["bits"] = cbits
            if mk.group(4):
                if kind != "pq":
                    raise ValueError("'+lpq' only composes with pq")
                params["lpq_tables"] = True
            continue
        raise ValueError(f"cannot parse factory fragment {raw!r} in {factory!r}")

    if kind is None:
        raise ValueError(f"no index kind in factory string {factory!r}")
    if kind == "pq" and quant is not None:
        # the paper's composition: LPQ applied after the codebook mapping
        # step means int8 ADC tables (there is no separate code path for
        # quantizing PQ codes — they are already 1 byte).  Only the
        # default int8 fragment is implemented; reject variants rather
        # than silently substituting int8.
        if quant != QuantSpec(bits=8, scheme="gaussian", sigmas=1.0):
            raise ValueError(
                f"pq only composes with plain 'lpq8' ADC tables, got "
                f"{quant.to_fragment()!r} in {factory!r}"
            )
        params["lpq_tables"] = True
    if regions:
        if quant is None:
            raise ValueError(
                f"'regions' scales per-region Eq. 1 constants — add an "
                f"lpq fragment, e.g. 'ivf64,lpq8,regions' (in {factory!r})"
            )
        params["regions"] = True
    return IndexSpec(kind=kind, metric=out_metric, quant=quant, params=params,
                     rerank_bits=rerank_bits)


def resolve_build_spec(
    kind: str,
    spec: "IndexSpec | str | None",
    *,
    metric: str,
    quant: Optional[QuantSpec] = None,
    **defaults,
) -> tuple[IndexSpec, dict[str, Any]]:
    """Shared entry adapter for every index ``build``.

    ``spec=None`` means the caller used the legacy kwargs: assemble a spec
    from ``metric`` / ``quant`` / ``defaults``.  Otherwise coerce factory
    strings and fill unset per-kind params from ``defaults``.  Returns the
    resolved spec plus the merged build-parameter dict.
    """
    if spec is None:
        spec = IndexSpec(kind=kind, metric=metric, quant=quant,
                         params=dict(defaults))
    else:
        spec = as_spec(spec, metric=metric)
        if spec.kind != kind:
            raise ValueError(f"spec kind {spec.kind!r} routed to {kind!r} build")
    return spec, {**defaults, **dict(spec.params)}


def build_rerank_store(spec: IndexSpec, corpus):
    """Materialize the spec's rerank store (None when not requested).

    fp32 (r32) keeps the corpus verbatim; int8 (r8) learns its own Eq. 1
    constants — the rerank arm's accuracy must not inherit the scan arm's
    aggressive clamp.  Every kind's build calls this after
    ``resolve_build_spec`` so ``"<kind>,lpq4+r32"`` works uniformly.
    """
    if spec.rerank_bits is None:
        return None
    from repro.engine import CodeStore

    if spec.rerank_bits == 32:
        return CodeStore.dense(corpus)
    return QuantSpec(bits=8).build_store(corpus)


def as_spec(spec: "IndexSpec | str", metric: str | None = None) -> IndexSpec:
    """Coerce a factory string or pass through an IndexSpec."""
    if isinstance(spec, IndexSpec):
        return spec
    if isinstance(spec, str):
        return parse_factory(spec, metric=metric)
    raise TypeError(f"expected IndexSpec or factory string, got {type(spec)!r}")
