"""HNSW (Malkov & Yashunin) with the paper's int8 quantization as a
drop-in storage/distance option — the paper's primary evaluation target.

Layout: layer l adjacency is a dense int32 [N, M_max(l)] array (-1 padded),
M_max(0) = 2M, M_max(l>0) = M (HNSWlib convention).  Build is host-
orchestrated (as in HNSWlib, where C++ drives and the distance kernel is
the hot loop): inserts proceed in batches whose candidate searches are
vmapped jitted beam searches over the *current* graph — the stale-reads-
within-a-batch approximation used by batched GPU builders (GGNN) — then
connections are committed on the host with top-M_max pruning.

The quantized index stores only int8 codes; every distance inside both
build and search is the paper's integer-domain phi.  That is precisely the
paper's Table 1 experiment (build time & memory, fp32 vs int8 HNSW).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import quant as Qz
from repro.knn import base as B
from repro.knn import graph as G
from repro.knn import registry
from repro.knn.spec import (
    IndexSpec,
    build_rerank_store,
    quant_spec_from_kwargs,
    resolve_build_spec,
)


@registry.register("hnsw")
@dataclasses.dataclass
class HNSWIndex:
    metric: str
    m: int
    store: engine.CodeStore              # corpus payload at any precision
    layers: list[jax.Array]              # adj per layer, layer 0 first
    levels: np.ndarray                   # [N] int
    entry: int
    build_seconds: float = 0.0
    rerank_store: Optional[engine.CodeStore] = None
    # per-neighborhood Eq. 1 constants ('hnsw,lpq8,regions' — DESIGN.md
    # §14).  The walk store stays single-constant (build-time host pruning
    # compares raw codes, which is only valid inside one code space); the
    # beam's ef candidates are then re-scored through the regional dequant
    # path before the cut to k.  All three fields are None on global builds.
    regions: Optional["RegionQuant"] = None
    region_store: Optional[engine.CodeStore] = None   # regionally-coded corpus
    region_cents: Optional[jax.Array] = None          # [R, d] neighborhood centers

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.store.n

    @property
    def quantized(self) -> bool:
        return self.store.quantized

    @property
    def data(self) -> jax.Array:
        return self.store.data

    @property
    def params(self) -> Optional[Qz.QuantParams]:
        return self.store.params

    def _score_set(self) -> G.ScoreSet:
        return engine.make_score_set(self.store, self.metric)

    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        return self.store.encode_queries(queries)

    # ------------------------------------------------------------------
    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        m: int = 16,
        ef_construction: int = 100,
        metric: str = "ip",
        quantized: bool = False,
        bits: int = 8,
        scheme: str | Qz.Scheme = Qz.Scheme.GAUSSIAN,
        sigmas: float = 1.0,
        key: jax.Array | None = None,
        batch_size: int = 64,
        params: Optional[Qz.QuantParams] = None,
    ) -> "HNSWIndex":
        spec, p = resolve_build_spec(
            "hnsw", spec, metric=metric,
            quant=quant_spec_from_kwargs(quantized, bits, scheme, sigmas, params),
            m=m, ef_construction=ef_construction, batch_size=batch_size,
        )
        m = int(p["m"])
        ef_construction = int(p["ef_construction"])
        batch_size = int(p["batch_size"])
        metric = spec.metric

        t0 = time.perf_counter()
        if key is None:
            key = jax.random.PRNGKey(0)
        corpus = jnp.asarray(corpus, jnp.float32)
        n, d = corpus.shape

        store = (
            engine.CodeStore.dense(corpus)
            if spec.quant is None
            else spec.quant.build_store(corpus)
        )

        # level sampling: floor(-ln U * mL), mL = 1/ln M
        ml = 1.0 / math.log(m)
        u = np.asarray(jax.random.uniform(key, (n,), minval=1e-12, maxval=1.0))
        levels = np.floor(-np.log(u) * ml).astype(np.int32)
        max_level = int(levels.max())

        caps = [2 * m] + [m] * max_level
        adj = [np.full((n, caps[l]), -1, np.int32) for l in range(max_level + 1)]

        score_set = engine.make_score_set(store, metric)

        # ---- seed: first few points fully interconnected --------------
        seed_n = min(m + 1, n)
        for p in range(seed_n):
            for l in range(levels[p] + 1):
                others = [o for o in range(seed_n) if o != p and levels[o] >= l]
                adj[l][p, : min(len(others), caps[l])] = others[: caps[l]]
        entry = int(np.argmax(levels[:seed_n]))

        def _prune(ids: np.ndarray, scores: np.ndarray, cap: int) -> np.ndarray:
            order = np.argsort(-scores)
            return ids[order][:cap]

        qdata = np.asarray(store.unpacked())

        # ---- batched incremental inserts ------------------------------
        for start in range(seed_n, n, batch_size):
            stop = min(start + batch_size, n)
            ids = np.arange(start, stop)
            qs = store.take(jnp.asarray(ids))

            # per layer from the top: descend with greedy, collect efc
            # candidates at layers <= point level
            entry_arr = jnp.full((len(ids), 1), entry, jnp.int32)
            cand_per_layer: dict[int, tuple[np.ndarray, np.ndarray]] = {}
            cur_entry = entry_arr
            for l in range(max_level, -1, -1):
                adj_l = jnp.asarray(adj[l])
                need = levels[ids] >= l
                bs, bi = G.beam_search_batch(
                    qs, adj_l, cur_entry,
                    score_set=score_set,
                    ef=ef_construction if l == 0 else max(1, ef_construction // 4),
                )
                cand_per_layer[l] = (np.asarray(bs), np.asarray(bi))
                # entries for next layer down = best found here
                cur_entry = bi[:, :1]
                del need

            # commit connections on host
            for bi_pos, p in enumerate(ids):
                for l in range(int(levels[p]), -1, -1):
                    if l > max_level:
                        continue
                    scores_l, ids_l = cand_per_layer[l]
                    c_ids = ids_l[bi_pos]
                    c_scores = scores_l[bi_pos]
                    ok = c_ids >= 0
                    c_ids, c_scores = c_ids[ok], c_scores[ok]
                    nbrs = _prune(c_ids, c_scores, m)
                    adj[l][p, : len(nbrs)] = nbrs
                    # back-connections with pruning
                    for nb in nbrs:
                        row = adj[l][nb]
                        slot = np.where(row < 0)[0]
                        if len(slot):
                            adj[l][nb, slot[0]] = p
                        else:
                            # prune to cap by score-to-nb
                            cand = np.concatenate([row, [p]])
                            vecs = qdata[cand].astype(np.float32)
                            target = qdata[nb].astype(np.float32)
                            if metric == "l2":
                                sc = -np.sum((vecs - target) ** 2, -1)
                            else:
                                sc = vecs @ target
                            adj[l][nb] = _prune(cand, sc, caps[l])
                if levels[p] > max_level:
                    pass  # cannot happen: caps sized to max sampled level
                if levels[p] >= max_level and levels[p] > levels[entry]:
                    entry = int(p)

        regions = region_store = region_cents = None
        if spec.params.get("regions"):
            # neighborhoods = ~sqrt(n) kmeans cells over the corpus; a
            # folded key so global builds keep their exact level sampling
            from repro.cascade import RegionQuant
            from repro.core import distances as D
            from repro.knn.ivf import kmeans

            n_regions = max(1, min(64, int(round(math.sqrt(n)))))
            region_cents = kmeans(corpus, n_regions, jax.random.fold_in(key, 1))
            assign = jnp.argmax(D.l2_scores(corpus, region_cents), axis=-1)
            regions = RegionQuant.fit(
                corpus, np.asarray(assign), n_regions,
                bits=spec.quant.bits, scheme=spec.quant.scheme,
                sigmas=spec.quant.sigmas,
            )
            region_store = engine.CodeStore.from_codes(
                regions.encode(corpus), spec.quant.learn(corpus),
                pack=spec.quant.effective_packed,
            )

        layers = [jnp.asarray(a) for a in adj]
        idx = HNSWIndex(
            metric=metric, m=m, store=store,
            layers=layers, levels=levels, entry=entry,
            rerank_store=build_rerank_store(spec, corpus),
            regions=regions, region_store=region_store,
            region_cents=region_cents,
        )
        idx.build_seconds = time.perf_counter() - t0
        return idx

    # ------------------------------------------------------------------
    def placement(self, n_shards: int):
        """The walk is not row-shardable — every shard holds the whole
        graph and queries fan out instead (dist.replica)."""
        from repro.dist.placement import Placement

        return Placement.replicated(self.n, n_shards)

    def plan(
        self,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        mesh=None,
        placement=None,
    ):
        """Freeze (k, ef) into a pure layered-descent + beam runner.

        The graph walk itself is not row-shardable (pointer chasing needs
        the whole adjacency); the Searcher composes a compiled rerank
        tail after the beam instead.  Under a mesh the index replicates
        and the *query batch* shards (``dist.replica``): each shard walks
        its slice with the full graph as a closed-over constant — per
        query independence (the beam is a vmap) makes the fan-out
        bit-exact against the unsharded run.
        """
        if placement is not None and placement.kind != "replicated":
            raise ValueError(
                f"the hnsw walk only replicates; got a {placement.kind!r} "
                "placement"
            )
        sp = params or B.SearchParams()
        ef = max(sp.ef_search, k)
        # filter (DESIGN.md §16): the walk itself stays unfiltered (the
        # graph's connectivity must not see holes), the beam's ef is
        # widened by the filter's estimated selectivity, and the filter
        # is applied at the cut/re-score from ef down to k
        fmask, fstats = None, {}
        if sp.filter is not None:
            from repro.filter import overfetch

            fmask = jnp.asarray(sp.filter.aligned(self.n))
            ef = max(ef, overfetch(k, sp.filter.selectivity, self.n))
            fstats = {"filter_selectivity":
                      round(sp.filter.selectivity, 6)}
        score_set = self._score_set()
        NEG = float(jnp.finfo(jnp.float32).min)

        def core(queries: jax.Array):
            qf = jnp.asarray(queries, jnp.float32)
            q = self.prepare_queries(queries)
            nq = q.shape[0]

            entry = jnp.full((nq,), self.entry, jnp.int32)
            # upper layers: greedy ef=1 descent
            for l in range(len(self.layers) - 1, 0, -1):
                adj_l = self.layers[l]
                entry = jax.vmap(
                    lambda qq, ee: G.greedy_descent(qq, adj_l, ee, score_set)[0]
                )(q, entry)

            scores, ids = G.beam_search_batch(
                q, self.layers[0], entry[:, None], score_set=score_set, ef=ef
            )
            if self.regions is not None:
                # re-score the beam's survivors under each row's own
                # neighborhood constants before the cut to k (the filter
                # rides the re-score's candidate mask)
                scores, ids = engine.topk_among_regional(
                    qf, self.region_store, self.regions.scale,
                    self.regions.zero, self.regions.assign, ids, k,
                    self.metric, mask=fmask,
                )
                return scores, ids
            if fmask is not None:
                ok = (ids >= 0) & fmask[jnp.clip(ids, 0, self.n - 1)]
                scores = jnp.where(ok, scores.astype(jnp.float32), NEG)
                ids = jnp.where(ok, ids, -1)
                scores, pos = jax.lax.top_k(scores, k)   # stable: keeps
                ids = jnp.take_along_axis(ids, pos, -1)  # the beam's order
                return scores, ids
            return scores[:, :k], ids[:, :k]

        if mesh is not None:
            from repro.dist.replica import replicated_query_plan

            exec_core = replicated_query_plan(core, mesh)
        else:
            exec_core = core

        def run(queries: jax.Array) -> B.SearchResult:
            nq = queries.shape[0]
            scores, ids = exec_core(queries)
            # candidate bound: layer-0 beam expands <= 8*ef nodes of degree
            # <= 2m each (graph-walk while-loops stop early on convergence)
            cand_bound = ef + 8 * ef * 2 * self.m
            stats = {"kind": "hnsw", "ef_search": ef,
                     "n_layers": len(self.layers),
                     **engine.search_stats(
                         self.store, candidates=cand_bound,
                         chunks=len(self.layers),
                         rows_read=nq * cand_bound)}
            if self.regions is not None:
                stats.update(
                    regional=True,
                    regional_candidates=ef,
                    bytes_read=stats["bytes_read"] + int(nq) * ef * (
                        self.region_store.row_bytes
                        + 2 * 4 * int(self.region_store.d)),
                )
            if mesh is not None:
                stats["placement"] = "replicated"
            return B.SearchResult(scores, ids, {**stats, **fstats})

        return run

    def searcher(self, k: int, params: Optional[B.SearchParams] = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        ef_search: int | None = None,
    ) -> B.SearchResult:
        """One-shot plan-and-run: layered descent + layer-0 beam."""
        from repro.knn import searcher as S

        sp = (params or B.SearchParams()).merged(ef_search=ef_search)
        return S.one_shot(self, queries, k, sp)

    # ------------------------------------------------------------------
    def memory_bytes(self) -> int:
        graph = sum(int(a.size) * 4 for a in self.layers)  # native pointers
        total = self.store.memory_bytes() + graph
        if self.rerank_store is not None:
            total += self.rerank_store.memory_bytes()
        if self.regions is not None:
            total += self.regions.memory_bytes()
            total += self.region_store.memory_bytes()
            total += int(self.region_cents.size) * 4
        return total

    def region_drift(self, live_corpus):
        """Per-neighborhood calibration drift of a live corpus against the
        fitted constants ([R] floats; +inf marks empty cells).  Live rows
        are assigned by the build-time neighborhood centers."""
        if self.regions is None:
            raise ValueError(
                "region_drift needs a per-region build — construct the "
                "index with an '...,regions' factory (e.g. 'hnsw,lpq8,regions')"
            )
        from repro.core import distances as D

        live = jnp.asarray(live_corpus, jnp.float32)
        live_assign = jnp.argmax(D.l2_scores(live, self.region_cents), axis=-1)
        return self.regions.drift_report(live, live_assign)

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        s_arrays, s_meta = self.store.state()
        if self.rerank_store is not None:
            rr_a, rr_m = self.rerank_store.state(prefix="rr_")
            s_arrays = {**s_arrays, **rr_a}
            s_meta = {**s_meta, **rr_m}
        if self.regions is not None:
            rg_a, rg_m = self.regions.state(prefix="rg_")
            rs_a, rs_m = self.region_store.state(prefix="rgs_")
            s_arrays = {**s_arrays, **rg_a, **rs_a,
                        "rg_cents": np.asarray(self.region_cents)}
            s_meta = {**s_meta, **rg_m, **rs_m}
        arrays = {"levels": self.levels, **s_arrays}
        for l, adj in enumerate(self.layers):
            arrays[f"layer_{l}"] = adj
        B.save_state(
            path, arrays,
            {"kind": "hnsw", "metric": self.metric, "quantized": self.quantized,
             "m": self.m, "entry": self.entry, "n_layers": len(self.layers),
             "build_seconds": self.build_seconds, **s_meta},
        )

    @staticmethod
    def load(path: str) -> "HNSWIndex":
        arrays, meta = B.load_state(path)
        layers = [
            jnp.asarray(arrays[f"layer_{l}"]) for l in range(meta["n_layers"])
        ]
        regions = region_store = region_cents = None
        if "rg_regions" in meta:
            from repro.cascade import RegionQuant

            regions = RegionQuant.from_state(arrays, meta, prefix="rg_")
            region_store = engine.CodeStore.from_state(arrays, meta, prefix="rgs_")
            region_cents = jnp.asarray(arrays["rg_cents"])
        return HNSWIndex(
            metric=meta["metric"], m=meta["m"],
            store=engine.CodeStore.from_state(arrays, meta),
            layers=layers, levels=np.asarray(arrays["levels"]),
            entry=int(meta["entry"]),
            build_seconds=float(meta.get("build_seconds", 0.0)),
            rerank_store=(engine.CodeStore.from_state(arrays, meta, prefix="rr_")
                          if "rr_store" in meta else None),
            regions=regions, region_store=region_store,
            region_cents=region_cents,
        )
