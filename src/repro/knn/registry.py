"""kind -> implementation registry and the ``make_index`` entrypoint.

Index classes self-register at import time::

    @registry.register("ivf")
    class IVFIndex: ...

Consumers never name a class: ``make_index("ivf256,lpq8", corpus)``
builds through the registry, ``load_index(path)`` dispatches on the
``kind`` recorded in the saved state, and the serving loop / benchmarks
iterate ``kinds()``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.knn.spec import IndexSpec, as_spec

_REGISTRY: dict[str, type] = {}


def register(kind: str):
    """Class decorator: register an Index implementation under ``kind``."""

    def deco(cls):
        cls.kind = kind
        _REGISTRY[kind] = cls
        return cls

    return deco


_IMPORTED = False


def _ensure_registered() -> None:
    # the index modules register on import; pull them in on first use so
    # ``registry`` itself stays import-cycle-free.  (Guard on a flag, not
    # on _REGISTRY being non-empty: ``import repro.knn`` already registers
    # the five base kinds as a side effect, and the stream wrapper must
    # still be pulled in on top of them.)
    global _IMPORTED
    if _IMPORTED:
        return
    _IMPORTED = True
    from repro.cascade import index  # noqa: F401  (kind "cascade")
    from repro.knn import flat, graph_index, hnsw, ivf, pq  # noqa: F401
    from repro.stream import mutable  # noqa: F401  (kind "stream")


def kinds() -> tuple[str, ...]:
    _ensure_registered()
    return tuple(sorted(_REGISTRY))


def get_impl(kind: str) -> type:
    _ensure_registered()
    if kind not in _REGISTRY:
        raise KeyError(f"no index registered for kind {kind!r}; have {kinds()}")
    return _REGISTRY[kind]


def make_index(
    spec: IndexSpec | str,
    corpus,
    *,
    metric: Optional[str] = None,
    key=None,
    **overrides,
):
    """Build any registered index from an ``IndexSpec`` or factory string.

    ``overrides`` merge into the spec's per-kind build params (e.g.
    ``ef_construction=80`` for hnsw, ``kmeans_iters=4`` for ivf/pq).
    ``metric`` is the default for factory strings (a metric fragment
    wins) and an explicit override for IndexSpec inputs.
    """
    resolved = as_spec(spec, metric=metric)
    if metric is not None and isinstance(spec, IndexSpec):
        resolved = dataclasses.replace(resolved, metric=metric)
    if overrides:
        resolved = resolved.with_overrides(**overrides)
    return get_impl(resolved.kind).build(corpus, resolved, key=key)


def load_index(path: str, *, adopt_tune: bool = True):
    """Load a saved index, dispatching on the recorded kind.

    A TuneTable embedded by ``save_state`` is adopted into the process's
    dispatch (``adopt_tune=False`` opts out) — stamp-checked: a table
    measured on a different backend is parked for the maintenance
    re-tune trigger (a counter, not a crash), and dispatch keeps its
    current configs.
    """
    from repro.knn import base

    meta = base.load_meta(path)
    idx = get_impl(meta["kind"]).load(path)
    if adopt_tune:
        from repro.tune import table as tunetable

        tunetable.adopt_from_meta(meta)
    return idx
