"""Graph beam search (HNSW SEARCH-LAYER) as a pure-JAX bounded loop.

TPU adaptation: HNSWlib's priority-queue walk is replaced by a fixed-width
beam held in registers/VMEM — per iteration we expand the best unexpanded
beam entry, gather its adjacency row, score the unvisited neighbors
against the query, and fold them into the beam with one ``top_k``.  The
loop is a ``lax.while_loop`` with static bounds, so the whole search jits
and vmaps over queries.

Semantics match HNSW's SEARCH-LAYER: the beam *is* the W set (size ef);
candidates that fall out of the top-ef are dropped, and the walk stops
when every beam entry has been expanded (or at the iteration cap).

Distances: ``score_set`` computes larger-is-closer scores of a gathered id
set against the query — fp32, the paper's int8 integer-domain scoring, or
packed-int4 unpack-on-gather, built by ``engine.make_score_set`` over the
index's ``CodeStore``.  This is exactly where the paper swaps fp32 for
int8 inside HNSW/NGT.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

NEG = jnp.finfo(jnp.float32).min

ScoreSet = Callable[[jax.Array, jax.Array], jax.Array]  # (q [d], ids [m]) -> [m] f32


@partial(jax.jit, static_argnames=("score_set", "ef", "max_iters"))
def beam_search(
    q: jax.Array,
    adj: jax.Array,
    entry_ids: jax.Array,
    score_set: ScoreSet,
    ef: int,
    max_iters: int | None = None,
):
    """Single-query beam search over one graph layer.

    Args:
      q: [d] query (codes or fp32 — whatever score_set expects).
      adj: [N, M] int32 adjacency, -1 padded.
      entry_ids: [E] int32 entry points (-1 padded allowed).
      ef: beam width (W-set size).
      max_iters: expansion cap; defaults to 8 * ef.

    Returns (beam_scores [ef], beam_ids [ef]) sorted best-first.
    """
    n_nodes, m = adj.shape
    if max_iters is None:
        max_iters = 8 * ef
    e = entry_ids.shape[0]

    valid_e = entry_ids >= 0
    e_scores = jnp.where(valid_e, score_set(q, jnp.clip(entry_ids, 0)), NEG)

    pad = max(ef - e, 0)
    beam_ids = jnp.concatenate([entry_ids, jnp.full((pad,), -1, jnp.int32)])[:ef]
    beam_scores = jnp.concatenate([e_scores, jnp.full((pad,), NEG)])[:ef]
    # invalid slots count as already-expanded so they are never picked
    expanded = beam_ids < 0
    if e > ef:
        top_s, pos = jax.lax.top_k(
            jnp.where(valid_e, e_scores, NEG), ef
        )
        beam_ids = jnp.where(top_s > NEG, entry_ids[pos], -1)
        beam_scores = top_s
        expanded = beam_ids < 0

    visited = jnp.zeros((n_nodes,), jnp.bool_)
    visited = visited.at[jnp.clip(entry_ids, 0)].max(valid_e)

    def cond(state):
        it, _, _, expanded, _ = state
        return (it < max_iters) & jnp.any(~expanded)

    def body(state):
        it, b_ids, b_scores, expanded, visited = state
        pick = jnp.where(~expanded, b_scores, NEG)
        pos = jnp.argmax(pick)
        node = b_ids[pos]
        expanded = expanded.at[pos].set(True)

        nbrs = adj[jnp.clip(node, 0)]                           # [M]
        safe = jnp.clip(nbrs, 0)
        fresh = (nbrs >= 0) & (~visited[safe])
        visited = visited.at[safe].max(fresh)

        n_scores = jnp.where(fresh, score_set(q, safe), NEG)
        n_ids = jnp.where(fresh, nbrs, -1)

        all_s = jnp.concatenate([b_scores, n_scores])
        all_i = jnp.concatenate([b_ids, n_ids])
        all_e = jnp.concatenate([expanded, ~fresh])
        top_s, idx = jax.lax.top_k(all_s, ef)
        return (
            it + 1,
            jnp.where(top_s > NEG, all_i[idx], -1),
            top_s,
            jnp.where(top_s > NEG, all_e[idx], True),
            visited,
        )

    _, beam_ids, beam_scores, _, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), beam_ids, beam_scores, expanded, visited)
    )
    return beam_scores, beam_ids


def beam_search_batch(
    queries: jax.Array,
    adj: jax.Array,
    entry_ids: jax.Array,
    score_set: ScoreSet,
    ef: int,
    max_iters: int | None = None,
):
    """vmap of :func:`beam_search` over a [Q, d] query batch.

    ``entry_ids`` is either [E] (shared entries) or [Q, E] (per query).
    """
    if entry_ids.ndim == 1:
        entry_ids = jnp.broadcast_to(entry_ids[None], (queries.shape[0],) + entry_ids.shape)
    fn = partial(beam_search, score_set=score_set, ef=ef, max_iters=max_iters)
    return jax.vmap(lambda qq, ee: fn(qq, adj, ee))(queries, entry_ids)


def greedy_descent(
    q: jax.Array,
    adj: jax.Array,
    entry: jax.Array,
    score_set: ScoreSet,
    max_iters: int = 64,
):
    """ef=1 hill-climb used on HNSW's upper layers: walk to a local max."""
    e_score = score_set(q, entry[None])[0]

    def cond(state):
        it, _, _, improved = state
        return (it < max_iters) & improved

    def body(state):
        it, node, score, _ = state
        nbrs = adj[node]
        safe = jnp.clip(nbrs, 0)
        n_scores = jnp.where(nbrs >= 0, score_set(q, safe), NEG)
        best = jnp.argmax(n_scores)
        better = n_scores[best] > score
        return (
            it + 1,
            jnp.where(better, nbrs[best], node),
            jnp.maximum(n_scores[best], score),
            better,
        )

    _, node, score, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), entry, e_score, jnp.bool_(True))
    )
    return node, score
