"""Product quantization (Jégou et al., the paper's §2 seminal reference)
and its composition with the paper's low-precision scheme.

The paper positions LPQ as *complementary* to PQ: "one can either replace
the original dataset with low-precision quantized vectors or use it after
the codebook mapping step for calculating the distance computations at
query time."  Both modes are implemented:

  * :class:`PQIndex` — classic PQ: split d into M subspaces, k-means a
    2^bits-codeword codebook per subspace (``pq<M>`` = 256 codewords,
    ``pq<M>x4`` = 16 codewords with codes bit-packed two per byte —
    Bolt / Quick-ADC's layout, half the code bytes), store codes in an
    ``engine.PQStore``, score by ADC through ``engine.topk``.
  * ``lpq_tables=True`` — the paper's composition: the ADC lookup tables
    themselves are quantized to int8 with Eq. 1 constants learned over
    the table entries, so the scan accumulates integers (int32) instead
    of f32 — the same implementation-level substitution the paper makes
    inside HNSW, applied after the codebook mapping step.  Integer
    tables are also what the fused Pallas ADC kernel
    (``kernels/adc.py``) holds VMEM-resident: it unpacks the nibble
    codes in-kernel and runs the LUT gather as one int8 MXU
    contraction, streaming a running top-k so the [Q, N] ADC matrix
    never materializes (engine dispatch: ``scorer._pq_fused``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import engine
from repro.knn import base as B
from repro.knn import registry
from repro.knn.ivf import kmeans
from repro.knn.spec import IndexSpec, build_rerank_store, resolve_build_spec


@registry.register("pq")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQIndex:
    metric: str = dataclasses.field(metadata=dict(static=True))
    store: engine.PQStore
    rerank_store: Optional[engine.CodeStore] = None

    # -- legacy views ------------------------------------------------------
    @property
    def m(self) -> int:
        return self.store.m

    @property
    def bits(self) -> int:
        """Codeword index width (4 or 8)."""
        return self.store.bits

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def codes(self) -> jax.Array:
        return self.store.codes

    @property
    def codebooks(self) -> jax.Array:
        return self.store.codebooks

    @property
    def lpq_tables(self) -> bool:
        return self.store.lpq_tables

    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        m: int = 8,
        metric: str = "ip",
        bits: int = 8,
        lpq_tables: bool = False,
        key: jax.Array | None = None,
        kmeans_iters: int = 8,
    ) -> "PQIndex":
        spec, p = resolve_build_spec(
            "pq", spec, metric=metric,
            m=m, bits=bits, lpq_tables=lpq_tables, kmeans_iters=kmeans_iters,
        )
        if p.get("regions"):
            # spec parsing rejects this; guard direct-kwargs construction too
            raise ValueError(
                "per-region Eq. 1 constants need a partitioned kind (ivf / "
                "hnsw / graph) — PQ codebooks already adapt per subspace, "
                "and its codes carry no region assignment"
            )
        m = int(p["m"])
        # codeword-count knob: 2^bits codewords per subspace codebook
        # (``pq16x4`` = 16, ``pq16`` = 256); PQStore validates the width
        bits = int(p["bits"] or 8)
        # "pq64+lpq" / "pq64,lpq8" — the paper's after-the-codebook
        # composition: int8 ADC lookup tables (codes are already <= 1 byte)
        lpq_tables = bool(p["lpq_tables"]) or spec.quant is not None
        kmeans_iters = int(p["kmeans_iters"])
        metric = spec.metric
        if metric == "angular":
            raise ValueError(
                "pq supports ip and l2 only — the ADC lookup tables have "
                "no per-row norm to rescale by (engine dispatch table)"
            )
        if key is None:
            key = jax.random.PRNGKey(0)
        corpus = jnp.asarray(corpus, jnp.float32)
        n, d = corpus.shape
        assert d % m == 0, (d, m)
        ds = d // m
        sub = corpus.reshape(n, m, ds)
        if bits not in engine.PQ_CODE_BITS:
            raise ValueError(
                f"pq codeword width must be one of {engine.PQ_CODE_BITS} "
                f"bits (16- or 256-codeword codebooks), got {bits}"
            )
        n_codewords = 2 ** bits

        books, codes = [], []
        for j in range(m):
            cb = kmeans(sub[:, j], min(n_codewords, n),
                        jax.random.fold_in(key, j), iters=kmeans_iters)
            if cb.shape[0] < n_codewords:   # tiny corpora: pad codebook
                cb = jnp.pad(cb, ((0, n_codewords - cb.shape[0]), (0, 0)))
            d2 = jnp.sum((sub[:, j][:, None, :] - cb[None]) ** 2, -1)
            books.append(cb)
            codes.append(jnp.argmin(d2, -1).astype(jnp.uint8))

        code_mat = jnp.stack(codes, 1)
        if bits == 4:                        # honest width: two per byte
            from repro.core import pack as PK

            code_mat = PK.pack_uint4(code_mat)
        store = engine.PQStore(
            n=n, m=m, bits=bits, lpq_tables=lpq_tables,
            codes=code_mat, codebooks=jnp.stack(books),
        )
        return PQIndex(metric=metric, store=store,
                       rerank_store=build_rerank_store(spec, corpus))

    # ------------------------------------------------------------------
    def placement(self, n_shards: int):
        """Contiguous code-row blocks — ADC scans shard like flat scans."""
        from repro.dist.placement import Placement

        return Placement.rows(self.n, n_shards)

    def plan(
        self,
        k: int,
        params: "B.SearchParams | None" = None,
        *,
        mesh=None,
        placement=None,
    ):
        """Freeze (k, chunk) into a pure ADC-scan runner.  A rerank tail
        over a ``"pq16+lpq,r32"`` build is the classic PQ+refine pattern.

        With a mesh, code rows shard in contiguous blocks: the per-query
        LUT is built (and, for ``lpq_tables``, Eq. 1-quantized)
        replicated — it is O(Q·M·K), the thing ADC exists to keep small —
        and each shard runs the streaming gather-sum scan over its block
        with sentinel-masked pad rows, merged by one ``distributed_topk``
        (block order == gid order, so the stable merge reproduces the
        unsharded scan's canonical tie-break bit-exactly).
        """
        if mesh is not None:
            return self._sharded_plan(k, params, mesh, placement)
        sp = params or B.SearchParams()
        fmask = (None if sp.filter is None
                 else jnp.asarray(sp.filter.aligned(self.n)))
        fstats = ({} if sp.filter is None
                  else {"filter_selectivity": round(sp.filter.selectivity, 6)})

        def run(queries: jax.Array) -> B.SearchResult:
            s, i, stats = engine.topk(
                queries, self.store, k, self.metric, chunk=sp.chunk,
                mask=fmask,
            )
            return B.SearchResult(
                s, i, {"kind": "pq", "m": self.m,
                       "lpq_tables": self.lpq_tables, **stats, **fstats},
            )

        return run

    def _sharded_plan(self, k, params, mesh, placement):
        """Row-block ADC scan under ``shard_map`` (DESIGN.md §15)."""
        from repro.core import pack as PK
        from repro.dist.placement import Placement
        from repro.dist.sharding import (
            P, corpus_shards, sentinel_gids, shard_map,
        )
        from repro.engine import distributed_topk, merge_topk
        from repro.engine.scorer import NEG, _prepare_pq_lut

        sp = params or B.SearchParams()
        axes, n_shards = corpus_shards(mesh)
        store = self.store
        n = store.n
        if placement is None:
            placement = Placement.rows(n, n_shards)
        if placement.kind != "rows" or placement.n_shards != n_shards:
            raise ValueError(
                f"pq plans shard contiguous code-row blocks; got a "
                f"{placement.kind!r} placement over {placement.n_shards} "
                f"shards (mesh has {n_shards})"
            )
        rows_per = -(-n // n_shards)
        pad = n_shards * rows_per - n
        k_eff = min(k, n)
        k_local = min(k_eff, rows_per)
        tile_rows = min(sp.chunk, rows_per)
        n_tiles = -(-rows_per // tile_rows)
        padded_rows = n_tiles * tile_rows
        data = (jnp.pad(store.codes, ((0, pad), (0, 0))) if pad
                else store.codes)
        shard_idx = jnp.arange(n_shards, dtype=jnp.int32)

        def tile_scores(lt, tile_codes):     # same math as _topk_pq_from_lut
            rows = (PK.unpack_uint4(tile_codes)[:, : store.m]
                    if store.packed else tile_codes)
            idx = rows.T[None].astype(jnp.int32)            # [1, M, c]
            return jnp.sum(
                jnp.take_along_axis(lt, idx, axis=2), axis=1
            ).astype(jnp.float32)

        # filter bitmap sliced per shard alongside the code rows: a
        # filtered row's `valid` goes False, sentinel_gids hands it a
        # sentinel >= n, and the existing ok fence + merge kill it —
        # exactly the pad-row dataflow (DESIGN.md §16)
        fmask = None
        if sp.filter is not None:
            fm = jnp.asarray(sp.filter.aligned(n)).astype(jnp.int8)
            fmask = jnp.pad(fm, (0, pad)) if pad else fm

        def local(lt, shard, mshard, idx):
            gid0 = idx[0] * rows_per
            Q = lt.shape[0]
            tile_pad = padded_rows - rows_per
            if tile_pad:
                shard = jnp.pad(shard, ((0, tile_pad), (0, 0)))
                if mshard is not None:
                    mshard = jnp.pad(mshard, (0, tile_pad))
            tiles = shard.reshape(n_tiles, tile_rows, shard.shape[-1])
            mtiles = (jnp.zeros((n_tiles, 0), jnp.int8) if mshard is None
                      else mshard.reshape(n_tiles, tile_rows))

            def step(carry, inp):
                tile, mrow, t = inp
                s = tile_scores(lt, tile)
                lrow = t * tile_rows + jnp.arange(tile_rows, dtype=jnp.int32)
                valid = (lrow < rows_per) & (gid0 + lrow < n)
                if mshard is not None:
                    valid = valid & (mrow != 0)
                gid = sentinel_gids(
                    gid0 + lrow, valid,
                    shard=idx[0], local_rows=lrow, n_total=n,
                    padded_rows=padded_rows,
                )
                ok = gid < n
                s = jnp.where(ok[None, :], s, NEG)
                ids = jnp.where(ok[None, :],
                                jnp.broadcast_to(gid[None], s.shape), -1)
                return merge_topk(*carry, s, ids, k_local), None

            init = (jnp.full((Q, k_local), NEG, jnp.float32),
                    jnp.full((Q, k_local), -1, jnp.int32))
            (ls, li), _ = jax.lax.scan(
                step, init,
                (tiles, mtiles, jnp.arange(n_tiles, dtype=jnp.int32)),
            )
            return distributed_topk(ls, li, k_eff, axes, 0)

        def local_plain(lt, shard, idx):
            return local(lt, shard, None, idx)

        if fmask is None:
            inner_plain = shard_map(
                local_plain,
                mesh=mesh,
                in_specs=(P(), P(axes, None), P(axes)),
                out_specs=(P(), P()),
                check_vma=False,
            )
        else:
            inner_masked = shard_map(
                local,
                mesh=mesh,
                in_specs=(P(), P(axes, None), P(axes), P(axes)),
                out_specs=(P(), P()),
                check_vma=False,
            )

        merge_wire = n_shards * k_eff * 8
        fstats = ({} if sp.filter is None
                  else {"filter_selectivity": round(sp.filter.selectivity, 6)})

        def run(queries: jax.Array) -> B.SearchResult:
            lut = _prepare_pq_lut(queries, store, self.metric)
            ilut = lut.astype(jnp.int32) if store.lpq_tables else lut
            if fmask is None:
                s, i = inner_plain(ilut, data, shard_idx)
            else:
                s, i = inner_masked(ilut, data, fmask, shard_idx)
            i = jnp.where(i >= n, -1, i)     # sentinels never leave the plan
            if k_eff < k:
                s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
                i = jnp.pad(i, ((0, 0), (0, k - k_eff)), constant_values=-1)
            stats = engine.search_stats(store, candidates=n,
                                        chunks=n_shards * n_tiles,
                                        rows_read=n)
            return B.SearchResult(s, i, {
                "kind": "pq", "m": self.m, "lpq_tables": self.lpq_tables,
                **stats, **fstats, "placement": "rows",
                "merge_wire_bytes": int(queries.shape[0]) * merge_wire,
            })

        return run

    def searcher(self, k: int, params: "B.SearchParams | None" = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: "B.SearchParams | None" = None,
    ) -> B.SearchResult:
        """One-shot plan-and-run ADC scan (streaming LUT gather-sum).

        ``SearchParams.chunk`` sizes the scan tiles; PQ has no other
        search-time knob.
        """
        from repro.knn import searcher as S

        return S.one_shot(self, queries, k, params)

    def memory_bytes(self) -> int:
        total = self.store.memory_bytes()
        if self.rerank_store is not None:
            total += self.rerank_store.memory_bytes()
        return total

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        arrays, meta = self.store.state()
        if self.rerank_store is not None:
            rr_a, rr_m = self.rerank_store.state(prefix="rr_")
            arrays = {**arrays, **rr_a}
            meta = {**meta, **rr_m}
        B.save_state(
            path, arrays,
            {"kind": "pq", "metric": self.metric, "m": self.m, "n": self.n,
             "lpq_tables": self.lpq_tables, **meta},
        )

    @staticmethod
    def load(path: str) -> "PQIndex":
        arrays, meta = B.load_state(path)
        return PQIndex(
            metric=meta["metric"],
            store=engine.PQStore.from_state(arrays, meta),
            rerank_store=(engine.CodeStore.from_state(arrays, meta, prefix="rr_")
                          if "rr_store" in meta else None),
        )
