"""Product quantization (Jégou et al., the paper's §2 seminal reference)
and its composition with the paper's low-precision scheme.

The paper positions LPQ as *complementary* to PQ: "one can either replace
the original dataset with low-precision quantized vectors or use it after
the codebook mapping step for calculating the distance computations at
query time."  Both modes are implemented:

  * :class:`PQIndex` — classic PQ: split d into M subspaces, k-means a
    256-codeword codebook per subspace, store 1-byte codes, score by ADC
    (asymmetric distance computation: per-query LUT of query-to-codeword
    distances, then a gather-sum over codes).
  * ``lpq_tables=True`` — the paper's composition: the ADC lookup tables
    themselves are quantized to int8 with Eq. 1 constants learned over
    the table entries, so the scan accumulates integers (int32) instead
    of f32 — the same implementation-level substitution the paper makes
    inside HNSW, applied after the codebook mapping step.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant as Qz
from repro.knn import base as B
from repro.knn import registry
from repro.knn.ivf import kmeans
from repro.knn.spec import IndexSpec, resolve_build_spec


@registry.register("pq")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQIndex:
    metric: str = dataclasses.field(metadata=dict(static=True))
    m: int = dataclasses.field(metadata=dict(static=True))          # subspaces
    n: int = dataclasses.field(metadata=dict(static=True))
    codebooks: jax.Array      # [M, 256, d/M] f32
    codes: jax.Array          # [N, M] uint8
    lpq_tables: bool = dataclasses.field(default=False, metadata=dict(static=True))

    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        m: int = 8,
        metric: str = "ip",
        lpq_tables: bool = False,
        key: jax.Array | None = None,
        kmeans_iters: int = 8,
    ) -> "PQIndex":
        spec, p = resolve_build_spec(
            "pq", spec, metric=metric,
            m=m, lpq_tables=lpq_tables, kmeans_iters=kmeans_iters,
        )
        m = int(p["m"])
        # "pq64+lpq" / "pq64,lpq8" — the paper's after-the-codebook
        # composition: int8 ADC lookup tables (codes are already 1 byte)
        lpq_tables = bool(p["lpq_tables"]) or spec.quant is not None
        kmeans_iters = int(p["kmeans_iters"])
        metric = spec.metric
        if key is None:
            key = jax.random.PRNGKey(0)
        corpus = jnp.asarray(corpus, jnp.float32)
        n, d = corpus.shape
        assert d % m == 0, (d, m)
        ds = d // m
        sub = corpus.reshape(n, m, ds)

        books, codes = [], []
        for j in range(m):
            cb = kmeans(sub[:, j], min(256, n), jax.random.fold_in(key, j),
                        iters=kmeans_iters)
            if cb.shape[0] < 256:   # tiny corpora: pad codebook
                cb = jnp.pad(cb, ((0, 256 - cb.shape[0]), (0, 0)))
            d2 = jnp.sum((sub[:, j][:, None, :] - cb[None]) ** 2, -1)
            books.append(cb)
            codes.append(jnp.argmin(d2, -1).astype(jnp.uint8))

        return PQIndex(
            metric=metric, m=m, n=n,
            codebooks=jnp.stack(books), codes=jnp.stack(codes, 1),
            lpq_tables=lpq_tables,
        )

    # ------------------------------------------------------------------
    def _luts(self, queries: jax.Array):
        """Per-query score tables [Q, M, 256] (larger-is-closer)."""
        q = jnp.asarray(queries, jnp.float32)
        Q, d = q.shape
        ds = d // self.m
        qs = q.reshape(Q, self.m, ds)
        if self.metric == "ip":
            lut = jnp.einsum("qmd,mkd->qmk", qs, self.codebooks)
        else:  # l2 (negated)
            diff = qs[:, :, None, :] - self.codebooks[None]
            lut = -jnp.sum(diff * diff, -1)
        return lut

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: "B.SearchParams | None" = None,
    ) -> B.SearchResult:
        """ADC scan: LUT gather-sum over the code matrix.

        PQ's exhaustive ADC scan has no search-time knob; ``params`` is
        accepted (and ignored) for protocol uniformity.
        """
        del params
        lut = self._luts(queries)                          # [Q, M, 256] f32

        if self.lpq_tables:
            # the paper's composition: quantize the LUT entries (Eq. 1,
            # per-table abs-max) and accumulate integers
            amax = jnp.maximum(jnp.max(jnp.abs(lut)), 1e-12)
            lut_q = jnp.clip(jnp.round(lut / amax * 127.0), -128, 127)
            lut_q = lut_q.astype(jnp.int32)                # int8-valued
            scores = jnp.sum(
                jnp.take_along_axis(
                    lut_q, self.codes.T.astype(jnp.int32)[None], axis=2
                ),
                axis=1,
            )                                              # [Q, N] int32
            scores = scores.astype(jnp.float32)
        else:
            scores = jnp.sum(
                jnp.take_along_axis(
                    lut, self.codes.T.astype(jnp.int32)[None], axis=2
                ),
                axis=1,
            )
        top_s, top_i = jax.lax.top_k(scores, k)
        stats = {"kind": "pq", "m": self.m, "candidates": self.n,
                 "lpq_tables": self.lpq_tables}
        return B.SearchResult(top_s, top_i.astype(jnp.int32), stats)

    def memory_bytes(self) -> int:
        return int(self.codes.size) + int(self.codebooks.size) * 4

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        B.save_state(
            path,
            {"codebooks": self.codebooks, "codes": self.codes},
            {"kind": "pq", "metric": self.metric, "m": self.m, "n": self.n,
             "lpq_tables": self.lpq_tables},
        )

    @staticmethod
    def load(path: str) -> "PQIndex":
        arrays, meta = B.load_state(path)
        return PQIndex(
            metric=meta["metric"], m=meta["m"], n=meta["n"],
            codebooks=jnp.asarray(arrays["codebooks"]),
            codes=jnp.asarray(arrays["codes"]),
            lpq_tables=meta["lpq_tables"],
        )
