"""Product quantization (Jégou et al., the paper's §2 seminal reference)
and its composition with the paper's low-precision scheme.

The paper positions LPQ as *complementary* to PQ: "one can either replace
the original dataset with low-precision quantized vectors or use it after
the codebook mapping step for calculating the distance computations at
query time."  Both modes are implemented:

  * :class:`PQIndex` — classic PQ: split d into M subspaces, k-means a
    2^bits-codeword codebook per subspace (``pq<M>`` = 256 codewords,
    ``pq<M>x4`` = 16 codewords with codes bit-packed two per byte —
    Bolt / Quick-ADC's layout, half the code bytes), store codes in an
    ``engine.PQStore``, score by ADC through ``engine.topk``.
  * ``lpq_tables=True`` — the paper's composition: the ADC lookup tables
    themselves are quantized to int8 with Eq. 1 constants learned over
    the table entries, so the scan accumulates integers (int32) instead
    of f32 — the same implementation-level substitution the paper makes
    inside HNSW, applied after the codebook mapping step.  Integer
    tables are also what the fused Pallas ADC kernel
    (``kernels/adc.py``) holds VMEM-resident: it unpacks the nibble
    codes in-kernel and runs the LUT gather as one int8 MXU
    contraction, streaming a running top-k so the [Q, N] ADC matrix
    never materializes (engine dispatch: ``scorer._pq_fused``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import engine
from repro.knn import base as B
from repro.knn import registry
from repro.knn.ivf import kmeans
from repro.knn.spec import IndexSpec, build_rerank_store, resolve_build_spec


@registry.register("pq")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQIndex:
    metric: str = dataclasses.field(metadata=dict(static=True))
    store: engine.PQStore
    rerank_store: Optional[engine.CodeStore] = None

    # -- legacy views ------------------------------------------------------
    @property
    def m(self) -> int:
        return self.store.m

    @property
    def bits(self) -> int:
        """Codeword index width (4 or 8)."""
        return self.store.bits

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def codes(self) -> jax.Array:
        return self.store.codes

    @property
    def codebooks(self) -> jax.Array:
        return self.store.codebooks

    @property
    def lpq_tables(self) -> bool:
        return self.store.lpq_tables

    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        m: int = 8,
        metric: str = "ip",
        bits: int = 8,
        lpq_tables: bool = False,
        key: jax.Array | None = None,
        kmeans_iters: int = 8,
    ) -> "PQIndex":
        spec, p = resolve_build_spec(
            "pq", spec, metric=metric,
            m=m, bits=bits, lpq_tables=lpq_tables, kmeans_iters=kmeans_iters,
        )
        if p.get("regions"):
            # spec parsing rejects this; guard direct-kwargs construction too
            raise ValueError(
                "per-region Eq. 1 constants need a partitioned kind (ivf / "
                "hnsw / graph) — PQ codebooks already adapt per subspace, "
                "and its codes carry no region assignment"
            )
        m = int(p["m"])
        # codeword-count knob: 2^bits codewords per subspace codebook
        # (``pq16x4`` = 16, ``pq16`` = 256); PQStore validates the width
        bits = int(p["bits"] or 8)
        # "pq64+lpq" / "pq64,lpq8" — the paper's after-the-codebook
        # composition: int8 ADC lookup tables (codes are already <= 1 byte)
        lpq_tables = bool(p["lpq_tables"]) or spec.quant is not None
        kmeans_iters = int(p["kmeans_iters"])
        metric = spec.metric
        if metric == "angular":
            raise ValueError(
                "pq supports ip and l2 only — the ADC lookup tables have "
                "no per-row norm to rescale by (engine dispatch table)"
            )
        if key is None:
            key = jax.random.PRNGKey(0)
        corpus = jnp.asarray(corpus, jnp.float32)
        n, d = corpus.shape
        assert d % m == 0, (d, m)
        ds = d // m
        sub = corpus.reshape(n, m, ds)
        if bits not in engine.PQ_CODE_BITS:
            raise ValueError(
                f"pq codeword width must be one of {engine.PQ_CODE_BITS} "
                f"bits (16- or 256-codeword codebooks), got {bits}"
            )
        n_codewords = 2 ** bits

        books, codes = [], []
        for j in range(m):
            cb = kmeans(sub[:, j], min(n_codewords, n),
                        jax.random.fold_in(key, j), iters=kmeans_iters)
            if cb.shape[0] < n_codewords:   # tiny corpora: pad codebook
                cb = jnp.pad(cb, ((0, n_codewords - cb.shape[0]), (0, 0)))
            d2 = jnp.sum((sub[:, j][:, None, :] - cb[None]) ** 2, -1)
            books.append(cb)
            codes.append(jnp.argmin(d2, -1).astype(jnp.uint8))

        code_mat = jnp.stack(codes, 1)
        if bits == 4:                        # honest width: two per byte
            from repro.core import pack as PK

            code_mat = PK.pack_uint4(code_mat)
        store = engine.PQStore(
            n=n, m=m, bits=bits, lpq_tables=lpq_tables,
            codes=code_mat, codebooks=jnp.stack(books),
        )
        return PQIndex(metric=metric, store=store,
                       rerank_store=build_rerank_store(spec, corpus))

    # ------------------------------------------------------------------
    def plan(
        self,
        k: int,
        params: "B.SearchParams | None" = None,
        *,
        mesh=None,
    ):
        """Freeze (k, chunk) into a pure ADC-scan runner.  A rerank tail
        over a ``"pq16+lpq,r32"`` build is the classic PQ+refine pattern."""
        if mesh is not None:
            raise ValueError(
                "sharded searcher plans are flat-only (row-shardable scan); "
                "shard the pq kind by code rows in a future PR"
            )
        sp = params or B.SearchParams()

        def run(queries: jax.Array) -> B.SearchResult:
            s, i, stats = engine.topk(
                queries, self.store, k, self.metric, chunk=sp.chunk
            )
            return B.SearchResult(
                s, i, {"kind": "pq", "m": self.m,
                       "lpq_tables": self.lpq_tables, **stats},
            )

        return run

    def searcher(self, k: int, params: "B.SearchParams | None" = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: "B.SearchParams | None" = None,
    ) -> B.SearchResult:
        """One-shot plan-and-run ADC scan (streaming LUT gather-sum).

        ``SearchParams.chunk`` sizes the scan tiles; PQ has no other
        search-time knob.
        """
        from repro.knn import searcher as S

        return S.one_shot(self, queries, k, params)

    def memory_bytes(self) -> int:
        total = self.store.memory_bytes()
        if self.rerank_store is not None:
            total += self.rerank_store.memory_bytes()
        return total

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        arrays, meta = self.store.state()
        if self.rerank_store is not None:
            rr_a, rr_m = self.rerank_store.state(prefix="rr_")
            arrays = {**arrays, **rr_a}
            meta = {**meta, **rr_m}
        B.save_state(
            path, arrays,
            {"kind": "pq", "metric": self.metric, "m": self.m, "n": self.n,
             "lpq_tables": self.lpq_tables, **meta},
        )

    @staticmethod
    def load(path: str) -> "PQIndex":
        arrays, meta = B.load_state(path)
        return PQIndex(
            metric=meta["metric"],
            store=engine.PQStore.from_state(arrays, meta),
            rerank_store=(engine.CodeStore.from_state(arrays, meta, prefix="rr_")
                          if "rr_store" in meta else None),
        )
