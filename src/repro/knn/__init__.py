# KNN substrate: the index structures the paper plugs its quantization
# into — exact flat scan (FAISS-flat), IVF (TPU-native), HNSW (the paper's
# primary target), and an NGT-equivalent graph index — plus streaming and
# distributed top-k machinery and graph-construction utilities.
from repro.knn.flat import FlatIndex
from repro.knn.ivf import IVFIndex, kmeans
from repro.knn.hnsw import HNSWIndex
from repro.knn.graph_index import GraphIndex
from repro.knn.topk import chunked_topk, distributed_topk, merge_topk
from repro.knn.graph_utils import knn_graph, radius_graph

__all__ = [
    "FlatIndex",
    "IVFIndex",
    "kmeans",
    "HNSWIndex",
    "GraphIndex",
    "chunked_topk",
    "distributed_topk",
    "merge_topk",
    "knn_graph",
    "radius_graph",
]
