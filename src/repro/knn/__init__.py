# KNN substrate: the index structures the paper plugs its quantization
# into — exact flat scan (FAISS-flat), IVF (TPU-native), HNSW (the paper's
# primary target), an NGT-equivalent graph index and PQ — behind one
# unified API: QuantSpec/IndexSpec configs, a common Index protocol
# (build/search/memory_bytes/save/load), a kind registry with FAISS-style
# factory strings, the Searcher query-plan layer (compiled / sharded /
# rerank-capable search sessions, DESIGN.md §9), plus distributed top-k
# machinery and graph-construction utilities.  Storage and scoring live
# one layer down in ``repro.engine`` (CodeStore/PQStore + the fused
# Pallas score/top-k hot path).
from repro.knn.base import Index, SearchParams, SearchResult
from repro.knn.spec import IndexSpec, QuantSpec, parse_factory
from repro.knn.searcher import Rerank, Searcher
from repro.knn.flat import FlatIndex
from repro.knn.ivf import IVFIndex, kmeans
from repro.knn.hnsw import HNSWIndex
from repro.knn.graph_index import GraphIndex
from repro.knn.pq import PQIndex
from repro.knn.registry import kinds, load_index, make_index
from repro.engine import chunked_topk, distributed_topk, merge_topk
from repro.knn.graph_utils import knn_graph, radius_graph

__all__ = [
    "Index",
    "SearchParams",
    "SearchResult",
    "Searcher",
    "Rerank",
    "IndexSpec",
    "QuantSpec",
    "parse_factory",
    "make_index",
    "load_index",
    "kinds",
    "FlatIndex",
    "IVFIndex",
    "kmeans",
    "HNSWIndex",
    "GraphIndex",
    "PQIndex",
    "MutableIndex",
    "chunked_topk",
    "distributed_topk",
    "merge_topk",
    "knn_graph",
    "radius_graph",
]


def __getattr__(name):
    # the mutable LSM wrapper (repro.stream) is a registered kind like any
    # other, but it imports repro.knn submodules itself — resolve it
    # lazily (PEP 562) so ``import repro.stream`` as the first repro
    # import doesn't hit a half-initialized package in either direction
    if name == "MutableIndex":
        from repro.stream import MutableIndex

        return MutableIndex
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
