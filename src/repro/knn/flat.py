"""Exact (exhaustive) nearest-neighbor search — the FAISS-IndexFlat
equivalent, with the paper's low-precision path as a drop-in storage
option at any width: fp32 vectors, int8 codes (4x smaller), or bit-packed
int4 codes (8x smaller).

This is the reference the paper's Table 2 uses: exhaustive scan, fp32 vs
quantized codes, identical top-k logic.  All storage and every score run
through the engine layer (``engine.CodeStore`` + ``engine.topk``), which
streams the corpus through the fused Pallas score+top-k kernels.

Registered as kind ``"flat"``; factory strings: ``"flat"``,
``"flat,lpq8@gaussian:3"``, ``"flat,lpq4"`` (packed int4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import quant as Qz
from repro.knn import base as B
from repro.knn import registry
from repro.knn.spec import (
    IndexSpec,
    build_rerank_store,
    quant_spec_from_kwargs,
    resolve_build_spec,
)


@registry.register("flat")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlatIndex:
    """Exhaustive index: a metric plus one engine ``CodeStore`` (plus an
    optional higher-precision rerank store for ``+rN`` builds)."""

    metric: str = dataclasses.field(metadata=dict(static=True))
    store: engine.CodeStore
    rerank_store: Optional[engine.CodeStore] = None

    # -- legacy views (pre-engine callers and tests) -----------------------
    @property
    def quantized(self) -> bool:
        return self.store.quantized

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def params(self) -> Optional[Qz.QuantParams]:
        return self.store.params

    @property
    def codes(self) -> Optional[jax.Array]:
        return self.store.data if self.store.quantized else None

    @property
    def vectors(self) -> Optional[jax.Array]:
        return None if self.store.quantized else self.store.data

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        key: jax.Array | None = None,
        metric: str = "ip",
        quantized: bool = False,
        bits: int = 8,
        scheme: str | Qz.Scheme = Qz.Scheme.GAUSSIAN,
        sigmas: float = 1.0,
        params: Optional[Qz.QuantParams] = None,
    ) -> "FlatIndex":
        """Build from an ``IndexSpec``/factory string (unified API) or the
        legacy kwargs, which are adapted into a spec on entry."""
        del key  # deterministic build; accepted for protocol uniformity
        spec, _p = resolve_build_spec(
            "flat", spec, metric=metric,
            quant=quant_spec_from_kwargs(quantized, bits, scheme, sigmas, params),
        )
        if _p.get("regions"):
            # spec parsing rejects this; guard direct-kwargs construction too
            raise ValueError(
                "per-region Eq. 1 constants need a partitioned kind (ivf / "
                "hnsw / graph) — the flat scan has no regions to key them on"
            )
        store = (
            engine.CodeStore.dense(corpus)
            if spec.quant is None
            else spec.quant.build_store(corpus)
        )
        return FlatIndex(metric=spec.metric, store=store,
                         rerank_store=build_rerank_store(spec, corpus))

    @staticmethod
    def from_store(store: engine.CodeStore, metric: str) -> "FlatIndex":
        """Wrap an existing store (shared-payload builds, shard-local
        indexes carrying a row-id base)."""
        return FlatIndex(metric=metric, store=store)

    # -- query ------------------------------------------------------------
    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        """h(q) of Definition 2: queries enter the quantized space too."""
        return self.store.encode_queries(queries)

    def placement(self, n_shards: int):
        """Contiguous row blocks — the flat scan's natural sharding."""
        from repro.dist.placement import Placement

        return Placement.rows(self.n, n_shards)

    def plan(
        self,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        mesh=None,
        placement=None,
    ):
        """Freeze (k, params) into a pure runner (DESIGN.md §9).

        With a mesh, the runner row-shards the store per ``placement``
        (row blocks) and fuses the shard-local top-k with one
        cross-shard merge — the flat kind is the row-shardable scan the
        sharded Searcher compiles.
        """
        sp = params or B.SearchParams()
        # filter bitmap (DESIGN.md §16): external ids == row ids for a
        # direct build, so the bitmap aligns with the store as-is and
        # rides the engine's id-masking fence — no rescan, no extra bytes
        fmask = (None if sp.filter is None
                 else jnp.asarray(sp.filter.aligned(self.n)))
        fstats = ({} if sp.filter is None
                  else {"filter_selectivity": round(sp.filter.selectivity, 6)})
        if mesh is not None:
            from repro.knn.searcher import sharded_scan_plan

            inner = sharded_scan_plan(self.store, self.metric, k, mesh,
                                      chunk=sp.chunk, placement=placement,
                                      mask=fmask)
            if not fstats:
                return inner

            def run_sharded(queries: jax.Array) -> B.SearchResult:
                res = inner(queries)
                return B.SearchResult(res.scores, res.ids,
                                      {**res.stats, **fstats})

            return run_sharded

        def run(queries: jax.Array) -> B.SearchResult:
            q = self.prepare_queries(queries)
            s, i, stats = engine.topk(
                q, self.store, k, self.metric, chunk=sp.chunk, prepared=True,
                mask=fmask,
            )
            return B.SearchResult(s, i, {"kind": "flat", **stats, **fstats})

        return run

    def searcher(self, k: int, params: Optional[B.SearchParams] = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        chunk: int | None = None,
    ) -> B.SearchResult:
        """One-shot plan-and-run (scores [Q, k] f32, ids [Q, k] i32,
        larger-is-closer); ``searcher()`` is the compiled session."""
        from repro.knn import searcher as S

        sp = (params or B.SearchParams()).merged(chunk=chunk)
        return S.one_shot(self, queries, k, sp)

    # -- accounting (paper Table 1/2 memory column) -------------------------
    def memory_bytes(self) -> int:
        total = self.store.memory_bytes()
        if self.rerank_store is not None:
            total += self.rerank_store.memory_bytes()
        return total

    # -- disk round-trip ---------------------------------------------------
    def save(self, path: str) -> None:
        arrays, meta = self.store.state()
        if self.rerank_store is not None:
            rr_a, rr_m = self.rerank_store.state(prefix="rr_")
            arrays.update(rr_a)
            meta.update(rr_m)
        B.save_state(
            path, arrays,
            {"kind": "flat", "metric": self.metric,
             "quantized": self.quantized, "n": self.n, **meta},
        )

    @staticmethod
    def load(path: str) -> "FlatIndex":
        arrays, meta = B.load_state(path)
        rr = (engine.CodeStore.from_state(arrays, meta, prefix="rr_")
              if "rr_store" in meta else None)
        return FlatIndex(
            metric=meta["metric"],
            store=engine.CodeStore.from_state(arrays, meta),
            rerank_store=rr,
        )
