"""Exact (exhaustive) nearest-neighbor search — the FAISS-IndexFlat
equivalent, with the paper's int8 path as a drop-in storage/compute option.

This is the reference the paper's Table 2 uses: exhaustive scan, fp32 vs
int8 codes, identical top-k logic.  The quantized path stores only int8
codes (4x smaller than fp32) and scores through the qmip/ql2 Pallas
kernels (MXU int8 path on TPU, interpret mode on CPU).

Registered as kind ``"flat"``; factory strings: ``"flat"``,
``"flat,lpq8@gaussian:3"``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import quant as Qz
from repro.kernels import ops as K
from repro.knn import base as B
from repro.knn import registry
from repro.knn import topk as T
from repro.knn.spec import IndexSpec, quant_spec_from_kwargs, resolve_build_spec


@registry.register("flat")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FlatIndex:
    """Exhaustive index over either fp32 vectors or int8 codes."""

    metric: str = dataclasses.field(metadata=dict(static=True))
    quantized: bool = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    vectors: Optional[jax.Array]        # [N, d] f32 (None when quantized)
    codes: Optional[jax.Array]          # [N, d] int8 (None when fp32)
    params: Optional[Qz.QuantParams]

    # -- construction -----------------------------------------------------
    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        key: jax.Array | None = None,
        metric: str = "ip",
        quantized: bool = False,
        bits: int = 8,
        scheme: str | Qz.Scheme = Qz.Scheme.GAUSSIAN,
        sigmas: float = 1.0,
        params: Optional[Qz.QuantParams] = None,
    ) -> "FlatIndex":
        """Build from an ``IndexSpec``/factory string (unified API) or the
        legacy kwargs, which are adapted into a spec on entry."""
        del key  # deterministic build; accepted for protocol uniformity
        spec, _p = resolve_build_spec(
            "flat", spec, metric=metric,
            quant=quant_spec_from_kwargs(quantized, bits, scheme, sigmas, params),
        )

        n = int(corpus.shape[0])
        if spec.quant is None:
            return FlatIndex(
                metric=spec.metric, quantized=False, n=n,
                vectors=jnp.asarray(corpus, jnp.float32), codes=None, params=None,
            )
        qp = spec.quant.learn(corpus)
        codes = spec.quant.encode(corpus, qp)
        return FlatIndex(
            metric=spec.metric, quantized=True, n=n,
            vectors=None, codes=codes, params=qp,
        )

    # -- query ------------------------------------------------------------
    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        """h(q) of Definition 2: queries enter the quantized space too."""
        if not self.quantized:
            return jnp.asarray(queries, jnp.float32)
        p = self.params
        return K.quantize(queries, p.lo, p.hi, p.zero, bits=p.bits)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        chunk: int | None = None,
    ) -> B.SearchResult:
        """Exhaustive top-k; streams the corpus in chunks when N > chunk.

        Returns a ``SearchResult`` (scores [Q, k] f32, ids [Q, k] i32),
        larger-is-closer.
        """
        sp = (params or B.SearchParams()).merged(chunk=chunk)
        q = self.prepare_queries(queries)
        data = self.codes if self.quantized else self.vectors

        if self.quantized:
            if self.metric == "ip":
                score_fn = lambda qq, xx: K.qmip(qq, xx)
            elif self.metric == "l2":
                score_fn = lambda qq, xx: K.ql2(qq, xx)
            else:  # angular: int32 dot + f32 norms
                score_fn = D.qangular_scores
        else:
            score_fn = partial(D.scores, metric=self.metric)

        stats = {"kind": "flat", "candidates": self.n}
        if self.n <= sp.chunk:
            s = score_fn(q, data).astype(jnp.float32)
            k_eff = min(k, self.n)
            top_s, top_i = jax.lax.top_k(s, k_eff)
            return B.SearchResult(top_s, top_i.astype(jnp.int32), stats)

        padded, n_valid = T.pad_corpus(data, sp.chunk)
        s, i = T.chunked_topk(q, padded, k, score_fn, chunk=sp.chunk)
        s, i = T.mask_invalid(s, i, n_valid)
        return B.SearchResult(s, i, stats)

    # -- accounting (paper Table 1/2 memory column) -------------------------
    def memory_bytes(self) -> int:
        if self.quantized:
            d = self.codes.shape[1]
            # codes + the d-sized constants
            return self.n * d * 1 + 3 * d * 4
        d = self.vectors.shape[1]
        return self.n * d * 4

    # -- disk round-trip ---------------------------------------------------
    def save(self, path: str) -> None:
        q_arrays, q_meta = B.pack_quant_params(self.params)
        B.save_state(
            path,
            {"vectors": self.vectors, "codes": self.codes, **q_arrays},
            {"kind": "flat", "metric": self.metric,
             "quantized": self.quantized, "n": self.n, **q_meta},
        )

    @staticmethod
    def load(path: str) -> "FlatIndex":
        arrays, meta = B.load_state(path)
        return FlatIndex(
            metric=meta["metric"], quantized=meta["quantized"], n=meta["n"],
            vectors=jnp.asarray(arrays["vectors"]) if "vectors" in arrays else None,
            codes=jnp.asarray(arrays["codes"]) if "codes" in arrays else None,
            params=B.unpack_quant_params(arrays, meta),
        )
