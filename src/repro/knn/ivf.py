"""IVF (inverted-file) index — the TPU-native ANN structure.

HNSW's pointer-chasing traversal is hostile to a systolic machine; the
cluster-prune-then-scan pattern of IVF maps onto exactly two TPU-friendly
ops: a (small) dense matmul against the centroid table, and a gathered
batched matmul over the probed lists.  Both run through the engine layer:
the coarse probe is ``engine.topk`` over a dense centroid store, the fine
scan is ``engine.topk_among`` over the corpus store — fp32, int8 or
bit-packed int4 alike, so the paper's technique composes with IVF the
same way it composes with HNSW in §2 of the paper.

Lists are padded to a fixed length so every shape is static (jit/pjit
friendly); pad slots carry id -1 and are masked by the engine.

Registered as kind ``"ivf"``; factory strings: ``"ivf256"``,
``"ivf256,lpq8"``, ``"ivf256,lpq4"`` (packed int4).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import distances as D
from repro.core import quant as Qz
from repro.knn import base as B
from repro.knn import registry
from repro.knn.spec import (
    IndexSpec,
    build_rerank_store,
    quant_spec_from_kwargs,
    resolve_build_spec,
)


# --------------------------------------------------------------------------
# k-means (Lloyd) — the coarse quantizer
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_clusters", "iters"))
def kmeans(
    x: jax.Array, n_clusters: int, key: jax.Array, iters: int = 10
) -> jax.Array:
    """Plain Lloyd k-means, random init, [N, d] -> [n_clusters, d]."""
    n = x.shape[0]
    x = x.astype(jnp.float32)
    init_ids = jax.random.choice(key, n, (n_clusters,), replace=False)
    cents = x[init_ids]

    def step(cents, _):
        # assign by L2 (larger-is-closer negated L2 scores)
        s = D.l2_scores(x, cents)                     # [N, C]
        assign = jnp.argmax(s, axis=-1)               # [N]
        one_hot = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)
        counts = one_hot.sum(0)                       # [C]
        sums = one_hot.T @ x                          # [C, d]
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep old centroid for empty clusters
        new = jnp.where(counts[:, None] > 0, new, cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


@registry.register("ivf")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    metric: str = dataclasses.field(metadata=dict(static=True))
    nlist: int = dataclasses.field(metadata=dict(static=True))
    max_list: int = dataclasses.field(metadata=dict(static=True))
    centroids: jax.Array                 # [nlist, d] f32
    lists: jax.Array                     # [nlist, max_list] i32, -1 pad
    store: engine.CodeStore              # corpus payload at any precision
    rerank_store: Optional[engine.CodeStore] = None
    # per-list Eq. 1 constants ('ivf64,lpq8,regions' — DESIGN.md §14):
    # the store's codes are encoded under each row's own list constants
    # and fine scoring runs the regional dequant path; None = the global
    # single-constant path, bit-identical to pre-region builds
    regions: Optional["RegionQuant"] = None

    # -- legacy views ------------------------------------------------------
    @property
    def quantized(self) -> bool:
        return self.store.quantized

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def data(self) -> jax.Array:
        return self.store.data

    @property
    def params(self) -> Optional[Qz.QuantParams]:
        return self.store.params

    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        key: jax.Array | None = None,
        nlist: int = 64,
        metric: str = "ip",
        quantized: bool = False,
        bits: int = 8,
        scheme: str | Qz.Scheme = Qz.Scheme.GAUSSIAN,
        sigmas: float = 1.0,
        params: Optional[Qz.QuantParams] = None,
        kmeans_iters: int = 10,
    ) -> "IVFIndex":
        spec, p = resolve_build_spec(
            "ivf", spec, metric=metric,
            quant=quant_spec_from_kwargs(quantized, bits, scheme, sigmas, params),
            nlist=nlist, kmeans_iters=kmeans_iters,
        )
        nlist = int(p["nlist"])
        kmeans_iters = int(p["kmeans_iters"])

        if key is None:
            key = jax.random.PRNGKey(0)
        corpus = jnp.asarray(corpus, jnp.float32)
        cents = kmeans(corpus, nlist, key, iters=kmeans_iters)
        assign = jnp.argmax(D.l2_scores(corpus, cents), axis=-1)

        # bucket ids into fixed-width lists (host-side; build is offline)
        import numpy as np

        assign_np = np.asarray(assign)
        buckets = [np.where(assign_np == c)[0] for c in range(nlist)]
        max_list = max(1, max(len(b) for b in buckets))
        # round up for alignment
        max_list = ((max_list + 127) // 128) * 128
        lists = np.full((nlist, max_list), -1, np.int32)
        for c, b in enumerate(buckets):
            lists[c, : len(b)] = b

        regions = None
        if p.get("regions"):
            # density-aware per-list constants: each row encoded under its
            # own list's Eq. 1 fit (spec validation guarantees quant here)
            from repro.cascade import RegionQuant

            regions = RegionQuant.fit(
                corpus, assign_np, nlist,
                bits=spec.quant.bits, scheme=spec.quant.scheme,
                sigmas=spec.quant.sigmas,
            )
            # the store keeps nominal global constants for persistence /
            # compat, but its codes are regional — only the regional
            # dequant path in plan() may score them
            store = engine.CodeStore.from_codes(
                regions.encode(corpus), spec.quant.learn(corpus),
                pack=spec.quant.effective_packed,
            )
        else:
            store = (
                engine.CodeStore.dense(corpus)
                if spec.quant is None
                else spec.quant.build_store(corpus)
            )
        return IVFIndex(
            metric=spec.metric, nlist=nlist, max_list=max_list,
            centroids=cents, lists=jnp.asarray(lists), store=store,
            rerank_store=build_rerank_store(spec, corpus),
            regions=regions,
        )

    # ------------------------------------------------------------------
    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        return self.store.encode_queries(queries)

    def list_sizes(self):
        """Per-list member counts (host ints) — what placement balances."""
        import numpy as np

        return tuple(int(x) for x in (np.asarray(self.lists) >= 0).sum(axis=1))

    def placement(self, n_shards: int):
        """Whole IVF lists, LPT-balanced by list size (DESIGN.md §15)."""
        from repro.dist.placement import Placement

        return Placement.lists(self.list_sizes(), n_shards)

    def plan(
        self,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        mesh=None,
        placement=None,
    ):
        """Freeze (k, nprobe) into a pure probe-then-fine-score runner.

        With a mesh, lists are *placed*: each shard holds the code rows
        of the lists assigned to it (``Placement.lists``), the coarse
        probe and candidate gather stay replicated (routing metadata is
        tiny — the payload is what is placed), each shard fine-scores
        the candidates it owns, and one ``distributed_topk`` merge with
        id tie-breaking reproduces the unsharded ``topk_among``'s
        canonical (score desc, candidate-position asc) order bit-exactly
        (DESIGN.md §15).
        """
        if mesh is not None:
            return self._sharded_plan(k, params, mesh, placement)
        sp = params or B.SearchParams()
        nprobe = min(sp.nprobe, self.nlist)
        # filter (DESIGN.md §16): candidate-level mask over store rows,
        # plus a list-level skip — lists whose bitmap is empty are masked
        # out of the coarse probe itself, so their probe slots go to
        # lists that can still contribute
        fmask, lmask, fstats = self._filter_masks(sp)

        def run(queries: jax.Array) -> B.SearchResult:
            qf = jnp.asarray(queries, jnp.float32)
            qq = self.prepare_queries(queries)

            # 1) coarse: engine top-k over the (tiny, always-fp32)
            #    centroid store
            _cs, probe, _ = engine.topk(
                qf, engine.CodeStore.dense(self.centroids), nprobe,
                self.metric, mask=lmask,
            )

            # 2) gather candidate ids -> [Q, nprobe * max_list]; a fully
            #    masked-out probe slot (id -1 under the list skip) yields
            #    -1 candidates, dead at the fine-score fence
            if lmask is None:
                cand = self.lists[probe].reshape(qq.shape[0], -1)
            else:
                probe_ok = probe >= 0
                cand = jnp.where(
                    probe_ok[..., None],
                    self.lists[jnp.clip(probe, 0, self.nlist - 1)], -1,
                ).reshape(qq.shape[0], -1)

            # 3) fine scoring + top-k through the engine (gather, unpack-
            #    as-needed, mask empties, select).  Regional builds must
            #    dequantize per row — codes from different lists live in
            #    different integer spaces, so raw-code scoring would
            #    silently compare across constant sets.
            if self.regions is not None:
                scores, ids = engine.topk_among_regional(
                    qf, self.store, self.regions.scale, self.regions.zero,
                    self.regions.assign, cand, k, self.metric, mask=fmask,
                )
                stats = {"kind": "ivf", "nprobe": nprobe, "chunks": nprobe,
                         **engine.regional_stats(self.store, cand)}
            else:
                scores, ids = engine.topk_among(
                    qq, self.store, cand, k, self.metric, mask=fmask
                )
                stats = {"kind": "ivf", "nprobe": nprobe,
                         **engine.search_stats(
                             self.store,
                             candidates=nprobe * self.max_list,
                             chunks=nprobe,
                             rows_read=qq.shape[0] * nprobe * self.max_list)}
            return B.SearchResult(scores, ids, {**stats, **fstats})

        return run

    def _filter_masks(self, sp):
        """(row mask [n] bool | None, probe mask [nlist] bool | None,
        filter stats) for ``sp.filter`` (DESIGN.md §16).  The probe mask
        marks lists with at least one allowed member; an all-dead list
        never earns a probe slot."""
        if sp.filter is None:
            return None, None, {}
        import numpy as np

        m = np.asarray(sp.filter.aligned(self.n))
        lists_np = np.asarray(self.lists)
        memb = lists_np >= 0
        allowed = np.zeros(lists_np.shape, bool)
        allowed[memb] = m[lists_np[memb]]
        lmask = allowed.any(axis=1)
        fstats = {"filter_selectivity": round(sp.filter.selectivity, 6),
                  "filter_lists_skipped": int((~lmask).sum())}
        return jnp.asarray(m), jnp.asarray(lmask), fstats

    def _sharded_plan(self, k, params, mesh, placement):
        """List-placed fine scoring under ``shard_map`` (DESIGN.md §15).

        Plan-time (host): group each shard's list members into a local
        row block ``codes [S, rows_max, width]`` (row permutation is safe
        — packing is per-row) plus replicated ``owner [N]`` / ``local_of
        [N]`` routing maps.  Query-time (one jit): replicated coarse
        probe -> replicated candidate vector ``cand [Q, W]`` -> each
        shard scores the candidate *slots* whose rows it owns (identical
        per-query gather/score shapes to ``topk_among``, so owned slots
        score bit-identically) -> local top-k over slot positions ->
        ``distributed_topk(tie_break="id")`` on (-score, position) ->
        positions map back to gids through the replicated ``cand``.
        Unowned/pad slots carry NEG scores and lose every comparison;
        ids never travel un-masked (positions >= 0 only for real rows).
        """
        import numpy as np

        from repro.dist.placement import Placement
        from repro.dist.sharding import P, corpus_shards, shard_map
        from repro.engine import distributed_topk
        from repro.engine.scorer import NEG
        from repro.core import pack as PK

        sp = params or B.SearchParams()
        nprobe = min(sp.nprobe, self.nlist)
        # filter: same row/list masks as the unsharded plan — the row
        # mask ANDs into each shard's slot-ownership test (a filtered
        # slot is as dead as an unowned one), the list mask skips empty
        # lists at the replicated coarse probe (DESIGN.md §16)
        fmask, lmask, fstats = self._filter_masks(sp)
        axes, n_shards = corpus_shards(mesh)
        if placement is None:
            placement = Placement.lists(self.list_sizes(), n_shards)
        if placement.kind != "lists" or placement.n_units != self.nlist:
            raise ValueError(
                f"ivf plans place whole lists; got a {placement.kind!r} "
                f"placement over {placement.n_units} units (nlist={self.nlist})"
            )
        if placement.n_shards != n_shards:
            raise ValueError(
                f"placement covers {placement.n_shards} shards but the mesh "
                f"has {n_shards}"
            )

        n = self.store.n
        lists_np = np.asarray(self.lists)
        owner = np.zeros(n, np.int32)
        local_of = np.zeros(n, np.int32)
        shard_gids = []
        for s in range(n_shards):
            mine = [lists_np[c][lists_np[c] >= 0]
                    for c in placement.shard_units(s)]
            gids = (np.concatenate(mine).astype(np.int64) if mine
                    else np.zeros(0, np.int64))
            owner[gids] = s
            local_of[gids] = np.arange(gids.size, dtype=np.int32)
            shard_gids.append(gids)
        rows_max = max(1, max(g.size for g in shard_gids))
        data_np = np.asarray(self.store.data)
        codes = np.zeros((n_shards, rows_max) + data_np.shape[1:],
                         data_np.dtype)
        for s, gids in enumerate(shard_gids):
            codes[s, : gids.size] = data_np[gids]
        codes = jnp.asarray(codes)
        owner = jnp.asarray(owner)
        local_of = jnp.asarray(local_of)
        shard_idx = jnp.arange(n_shards, dtype=jnp.int32)

        W = nprobe * self.max_list
        k_eff = min(k, W)
        regional = self.regions is not None
        store = self.store

        def local(q, cand, codes_s, idx):
            codes_s = codes_s[0]                    # [rows_max, width]
            shard = idx[0]
            safe = jnp.clip(cand, 0, n - 1)
            ok = (cand >= 0) & (owner[safe] == shard)
            if fmask is not None:
                ok = ok & fmask[safe]
            rows = codes_s[jnp.where(ok, local_of[safe], 0)]   # [Q, W, w]
            if store.packed:
                rows = PK.unpack_int4(rows)
            if regional:
                reg = self.regions.assign[safe]                # [Q, W]
                x = (rows.astype(jnp.float32) * self.regions.scale[reg]
                     + self.regions.zero[reg])
                s = D.scores_among(q, x, self.metric, quantized=False)
            else:
                s = D.scores_among(q, rows, self.metric,
                                   quantized=store.quantized)
            s = jnp.where(ok, s.astype(jnp.float32), NEG)
            ls, pos = jax.lax.top_k(s, k_eff)
            # merge on candidate POSITIONS — the id space whose ascending
            # tie-break equals topk_among's stable top_k
            li = jnp.where(ls > NEG, pos, -1).astype(jnp.int32)
            return distributed_topk(ls, li, k_eff, axes, 0, tie_break="id")

        inner = shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), P(), P(axes, None, None), P(axes)),
            out_specs=(P(), P()),
            check_vma=False,
        )

        merge_wire = n_shards * k_eff * 8

        def run(queries: jax.Array) -> B.SearchResult:
            qf = jnp.asarray(queries, jnp.float32)
            qq = self.prepare_queries(queries)
            _cs, probe, _ = engine.topk(
                qf, engine.CodeStore.dense(self.centroids), nprobe,
                self.metric, mask=lmask,
            )
            if lmask is None:
                cand = self.lists[probe].reshape(qq.shape[0], -1)   # [Q, W]
            else:
                probe_ok = probe >= 0
                cand = jnp.where(
                    probe_ok[..., None],
                    self.lists[jnp.clip(probe, 0, self.nlist - 1)], -1,
                ).reshape(qq.shape[0], -1)
            s, pos = inner(qf if regional else qq, cand, codes, shard_idx)
            ids = jnp.where(
                pos >= 0,
                jnp.take_along_axis(cand, jnp.clip(pos, 0, W - 1), axis=1),
                -1,
            ).astype(jnp.int32)
            if store.base:
                ids = jnp.where(ids >= 0, ids + store.base, -1)
            if k_eff < k:
                s = jnp.pad(s, ((0, 0), (0, k - k_eff)), constant_values=NEG)
                ids = jnp.pad(ids, ((0, 0), (0, k - k_eff)),
                              constant_values=-1)
            if regional:
                stats = {"kind": "ivf", "nprobe": nprobe, "chunks": nprobe,
                         **engine.regional_stats(store, cand)}
            else:
                stats = {"kind": "ivf", "nprobe": nprobe,
                         **engine.search_stats(
                             store,
                             candidates=W,
                             chunks=nprobe,
                             rows_read=qq.shape[0] * W)}
            stats.update(placement="lists",
                         merge_wire_bytes=int(qq.shape[0]) * merge_wire,
                         **fstats)
            return B.SearchResult(s, ids, stats)

        return run

    def searcher(self, k: int, params: Optional[B.SearchParams] = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        nprobe: int | None = None,
    ) -> B.SearchResult:
        """One-shot plan-and-run: probe the nprobe best lists per query,
        exact-score the members.  Returns ``SearchResult`` [Q, k]."""
        from repro.knn import searcher as S

        sp = (params or B.SearchParams()).merged(nprobe=nprobe)
        return S.one_shot(self, queries, k, sp)

    def memory_bytes(self) -> int:
        base = self.store.memory_bytes()
        base += self.centroids.size * 4 + self.lists.size * 4
        if self.rerank_store is not None:
            base += self.rerank_store.memory_bytes()
        if self.regions is not None:
            base += self.regions.memory_bytes()
        return base

    def region_drift(self, live_corpus):
        """Per-list calibration drift of a live corpus against the fitted
        per-region constants ([nlist] floats; +inf marks stale/empty
        lists).  Live rows are assigned by the build centroids, so the
        report answers 'would this corpus still be well-served by the
        constants each list learned at build time?'."""
        if self.regions is None:
            raise ValueError(
                "region_drift needs a per-region build — construct the "
                "index with an '...,regions' factory (e.g. 'ivf64,lpq8,regions')"
            )
        live = jnp.asarray(live_corpus, jnp.float32)
        live_assign = jnp.argmax(D.l2_scores(live, self.centroids), axis=-1)
        return self.regions.drift_report(live, live_assign)

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        arrays, meta = self.store.state()
        if self.rerank_store is not None:
            rr_a, rr_m = self.rerank_store.state(prefix="rr_")
            arrays.update(rr_a)
            meta.update(rr_m)
        if self.regions is not None:
            rg_a, rg_m = self.regions.state(prefix="rg_")
            arrays.update(rg_a)
            meta.update(rg_m)
        B.save_state(
            path,
            {"centroids": self.centroids, "lists": self.lists, **arrays},
            {"kind": "ivf", "metric": self.metric, "quantized": self.quantized,
             "n": self.n, "nlist": self.nlist, "max_list": self.max_list,
             **meta},
        )

    @staticmethod
    def load(path: str) -> "IVFIndex":
        arrays, meta = B.load_state(path)
        regions = None
        if "rg_regions" in meta:
            from repro.cascade import RegionQuant

            regions = RegionQuant.from_state(arrays, meta, prefix="rg_")
        return IVFIndex(
            metric=meta["metric"], nlist=meta["nlist"],
            max_list=meta["max_list"],
            centroids=jnp.asarray(arrays["centroids"]),
            lists=jnp.asarray(arrays["lists"]),
            store=engine.CodeStore.from_state(arrays, meta),
            rerank_store=(engine.CodeStore.from_state(arrays, meta, prefix="rr_")
                          if "rr_store" in meta else None),
            regions=regions,
        )
