"""IVF (inverted-file) index — the TPU-native ANN structure.

HNSW's pointer-chasing traversal is hostile to a systolic machine; the
cluster-prune-then-scan pattern of IVF maps onto exactly two TPU-friendly
ops: a (small) dense matmul against the centroid table, and a gathered
batched matmul over the probed lists.  Both run on the int8 MXU path when
the index is quantized, so the paper's technique composes with IVF the
same way it composes with HNSW in §2 of the paper ("can be combined with
existing indexing-based KNN frameworks").

Lists are padded to a fixed length so every shape is static (jit/pjit
friendly); the pad id -1 scores -inf.

Registered as kind ``"ivf"``; factory strings: ``"ivf256"``,
``"ivf256,lpq8"``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import quant as Qz
from repro.kernels import ops as K
from repro.knn import base as B
from repro.knn import registry
from repro.knn.spec import IndexSpec, quant_spec_from_kwargs, resolve_build_spec


# --------------------------------------------------------------------------
# k-means (Lloyd) — the coarse quantizer
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_clusters", "iters"))
def kmeans(
    x: jax.Array, n_clusters: int, key: jax.Array, iters: int = 10
) -> jax.Array:
    """Plain Lloyd k-means, random init, [N, d] -> [n_clusters, d]."""
    n = x.shape[0]
    x = x.astype(jnp.float32)
    init_ids = jax.random.choice(key, n, (n_clusters,), replace=False)
    cents = x[init_ids]

    def step(cents, _):
        # assign by L2 (larger-is-closer negated L2 scores)
        s = D.l2_scores(x, cents)                     # [N, C]
        assign = jnp.argmax(s, axis=-1)               # [N]
        one_hot = jax.nn.one_hot(assign, n_clusters, dtype=jnp.float32)
        counts = one_hot.sum(0)                       # [C]
        sums = one_hot.T @ x                          # [C, d]
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # keep old centroid for empty clusters
        new = jnp.where(counts[:, None] > 0, new, cents)
        return new, None

    cents, _ = jax.lax.scan(step, cents, None, length=iters)
    return cents


@registry.register("ivf")
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class IVFIndex:
    metric: str = dataclasses.field(metadata=dict(static=True))
    quantized: bool = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))
    nlist: int = dataclasses.field(metadata=dict(static=True))
    max_list: int = dataclasses.field(metadata=dict(static=True))
    centroids: jax.Array                 # [nlist, d] f32
    lists: jax.Array                     # [nlist, max_list] i32, -1 pad
    data: jax.Array                      # [N, d] f32 or int8 codes
    params: Optional[Qz.QuantParams]

    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        key: jax.Array | None = None,
        nlist: int = 64,
        metric: str = "ip",
        quantized: bool = False,
        bits: int = 8,
        scheme: str | Qz.Scheme = Qz.Scheme.GAUSSIAN,
        sigmas: float = 1.0,
        params: Optional[Qz.QuantParams] = None,
        kmeans_iters: int = 10,
    ) -> "IVFIndex":
        spec, p = resolve_build_spec(
            "ivf", spec, metric=metric,
            quant=quant_spec_from_kwargs(quantized, bits, scheme, sigmas, params),
            nlist=nlist, kmeans_iters=kmeans_iters,
        )
        nlist = int(p["nlist"])
        kmeans_iters = int(p["kmeans_iters"])

        if key is None:
            key = jax.random.PRNGKey(0)
        n = int(corpus.shape[0])
        corpus = jnp.asarray(corpus, jnp.float32)
        cents = kmeans(corpus, nlist, key, iters=kmeans_iters)
        assign = jnp.argmax(D.l2_scores(corpus, cents), axis=-1)

        # bucket ids into fixed-width lists (host-side; build is offline)
        import numpy as np

        assign_np = np.asarray(assign)
        buckets = [np.where(assign_np == c)[0] for c in range(nlist)]
        max_list = max(1, max(len(b) for b in buckets))
        # round up for alignment
        max_list = ((max_list + 127) // 128) * 128
        lists = np.full((nlist, max_list), -1, np.int32)
        for c, b in enumerate(buckets):
            lists[c, : len(b)] = b

        qp = None
        data = corpus
        if spec.quant is not None:
            qp = spec.quant.learn(corpus)
            data = spec.quant.encode(corpus, qp)

        return IVFIndex(
            metric=spec.metric, quantized=spec.quant is not None, n=n,
            nlist=nlist, max_list=max_list, centroids=cents,
            lists=jnp.asarray(lists), data=data, params=qp,
        )

    # ------------------------------------------------------------------
    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        if not self.quantized:
            return jnp.asarray(queries, jnp.float32)
        p = self.params
        return K.quantize(queries, p.lo, p.hi, p.zero, bits=p.bits)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        nprobe: int | None = None,
    ) -> B.SearchResult:
        """Probe the nprobe best lists per query, exact-score the members.

        Returns a ``SearchResult`` (scores [Q, k] f32, ids [Q, k] i32).
        """
        sp = (params or B.SearchParams()).merged(nprobe=nprobe)
        nprobe = min(sp.nprobe, self.nlist)
        qf = jnp.asarray(queries, jnp.float32)
        qq = self.prepare_queries(queries)

        # 1) coarse: score centroids (always fp32 — tiny)
        cent_metric = "l2" if self.metric == "l2" else self.metric
        cs = D.scores(qf, self.centroids, cent_metric)          # [Q, nlist]
        probe = jax.lax.top_k(cs, nprobe)[1]                    # [Q, nprobe]

        # 2) gather candidate ids -> [Q, nprobe * max_list]
        cand = self.lists[probe].reshape(qq.shape[0], -1)
        valid = cand >= 0
        safe = jnp.where(valid, cand, 0)

        # 3) fine scoring, one query at a time (ragged per query)
        def per_query(qv, ids, ok):
            vecs = self.data[ids]                               # [L, d]
            if self.quantized:
                if self.metric == "ip":
                    s = K.qmip(qv[None], vecs)[0]
                elif self.metric == "l2":
                    s = K.ql2(qv[None], vecs)[0]
                else:
                    s = D.qangular_scores(qv[None], vecs)[0]
            else:
                s = D.scores(qv[None], vecs, self.metric)[0]
            s = jnp.where(ok, s.astype(jnp.float32), jnp.finfo(jnp.float32).min)
            top_s, pos = jax.lax.top_k(s, k)
            return top_s, jnp.where(
                top_s > jnp.finfo(jnp.float32).min, ids[pos], -1
            ).astype(jnp.int32)

        scores, ids = jax.vmap(per_query)(qq, safe, valid)
        stats = {"kind": "ivf", "nprobe": nprobe,
                 "candidates": nprobe * self.max_list}
        return B.SearchResult(scores, ids, stats)

    def memory_bytes(self) -> int:
        d = self.data.shape[1]
        itemsize = 1 if self.quantized else 4
        base = self.n * d * itemsize
        base += self.centroids.size * 4 + self.lists.size * 4
        if self.params is not None:
            base += 3 * d * 4
        return base

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        q_arrays, q_meta = B.pack_quant_params(self.params)
        B.save_state(
            path,
            {"centroids": self.centroids, "lists": self.lists,
             "data": self.data, **q_arrays},
            {"kind": "ivf", "metric": self.metric, "quantized": self.quantized,
             "n": self.n, "nlist": self.nlist, "max_list": self.max_list,
             **q_meta},
        )

    @staticmethod
    def load(path: str) -> "IVFIndex":
        arrays, meta = B.load_state(path)
        return IVFIndex(
            metric=meta["metric"], quantized=meta["quantized"], n=meta["n"],
            nlist=meta["nlist"], max_list=meta["max_list"],
            centroids=jnp.asarray(arrays["centroids"]),
            lists=jnp.asarray(arrays["lists"]),
            data=jnp.asarray(arrays["data"]),
            params=B.unpack_quant_params(arrays, meta),
        )
