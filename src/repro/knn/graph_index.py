"""NGT-equivalent: neighborhood-graph + seed-structure index (ONNG-style).

NGT ("Neighborhood Graph and Tree", Iwasaki & Miyazaki) couples a kNN
graph with a VP-tree used only to pick search entry points.  A VP-tree is
a pointer/branch structure with no TPU analogue, so per DESIGN.md we keep
the *role* (cheap entry-point selection) and swap the mechanism: a k-means
centroid table probed through ``engine.topk`` — the same coarse-quantizer
trick IVF uses.  The neighborhood graph itself is the exact kNN graph made
bidirectional and degree-capped (ANNG/ONNG construction), searched with
the same beam walk as HNSW, scoring through the engine's store-aware
score-set (fp32 / int8 / packed int4 alike).

The quantized variant stores integer codes and scores in the integer
domain — the paper's Table 3 experiment.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.core import distances as D
from repro.core import quant as Qz
from repro.knn import base as B
from repro.knn import graph as G
from repro.knn import ivf as IVF
from repro.knn import registry
from repro.knn.flat import FlatIndex
from repro.knn.spec import (
    IndexSpec,
    build_rerank_store,
    quant_spec_from_kwargs,
    resolve_build_spec,
)


@registry.register("graph")
@dataclasses.dataclass
class GraphIndex:
    metric: str
    degree: int
    store: engine.CodeStore
    adj: jax.Array                      # [N, degree] int32, -1 pad
    seeds: jax.Array                    # [n_seeds, d] f32 centroids
    seed_ids: jax.Array                 # [n_seeds] nearest corpus row per centroid
    build_seconds: float = 0.0
    # rerank store lives in the ORIGINAL (un-augmented) space: the walk
    # runs on the internal metric, the rerank tail on the user's metric
    rerank_store: Optional[engine.CodeStore] = None
    # MIP -> L2 reduction (Bachrach et al. [6], cited by the paper): graph
    # walks on inner product suffer hub capture; augmenting vectors with
    # sqrt(M^2 - ||x||^2) makes L2 ordering == IP ordering, and the graph
    # becomes metric.  internal_metric is what the walk actually uses.
    internal_metric: str = "l2"
    aug: bool = False
    # per-seed-neighborhood Eq. 1 constants ('graph,lpq8,regions' —
    # DESIGN.md §14).  Neighborhood r = rows nearest seed r in USER space
    # (the seeds' augmentation column is dropped for assignment, so live
    # corpora assign identically to the build).  The walk store stays
    # single-constant; walked candidates are re-scored through the
    # regional dequant path in the user metric before the cut to k.
    regions: Optional["RegionQuant"] = None
    region_store: Optional[engine.CodeStore] = None   # user-space regional codes

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def quantized(self) -> bool:
        return self.store.quantized

    @property
    def data(self) -> jax.Array:
        return self.store.data

    @property
    def params(self) -> Optional[Qz.QuantParams]:
        return self.store.params

    # ------------------------------------------------------------------
    @staticmethod
    def build(
        corpus: jax.Array,
        spec: IndexSpec | str | None = None,
        *,
        degree: int = 32,
        n_seeds: int = 32,
        metric: str = "ip",
        quantized: bool = False,
        bits: int = 8,
        scheme: str | Qz.Scheme = Qz.Scheme.GAUSSIAN,
        sigmas: float = 1.0,
        key: jax.Array | None = None,
    ) -> "GraphIndex":
        spec, p = resolve_build_spec(
            "graph", spec, metric=metric,
            quant=quant_spec_from_kwargs(quantized, bits, scheme, sigmas),
            degree=degree, n_seeds=n_seeds,
        )
        degree = int(p["degree"])
        n_seeds = int(p["n_seeds"])
        metric = spec.metric

        t0 = time.perf_counter()
        if key is None:
            key = jax.random.PRNGKey(0)
        corpus = jnp.asarray(corpus, jnp.float32)
        user_corpus = corpus                 # pre-augmentation, for rerank

        aug = metric == "ip"
        internal_metric = "l2" if aug else metric
        if aug:
            norms2 = jnp.sum(corpus * corpus, axis=-1)
            extra = jnp.sqrt(jnp.maximum(jnp.max(norms2) - norms2, 0.0))
            corpus = jnp.concatenate([corpus, extra[:, None]], axis=-1)
        n, d = corpus.shape

        if spec.quant is None:
            store = engine.CodeStore.dense(corpus)
        else:
            # constants are learned in the index's own (possibly augmented)
            # space, so pre-learned d-dim params cannot be reused under the
            # MIP->L2 augmentation — drop them and re-fit.
            quant = spec.quant
            if aug and quant.params is not None:
                quant = dataclasses.replace(quant, params=None)
            store = quant.build_store(corpus)

        # exact kNN graph in the *index's own distance domain* (integer
        # codes for the quantized index — build-time speedup is the
        # paper's Table 1 claim), through the engine-backed flat scan
        flat = FlatIndex.from_store(store, internal_metric)
        half = max(degree // 2, 1)
        _, nbr = flat.search(
            corpus if not store.quantized else Qz.dequantize(
                store.unpacked()[:, : store.d], store.params),
            k=half + 1,
        )
        nbr = np.asarray(nbr)[:, 1:]                       # drop self

        # bidirectional + cap (ONNG outdegree adjustment)
        adj = np.full((n, degree), -1, np.int32)
        counts = np.zeros(n, np.int32)
        for i in range(n):
            for j in nbr[i]:
                if j < 0:
                    continue
                if counts[i] < degree:
                    adj[i, counts[i]] = j
                    counts[i] += 1
                if counts[j] < degree:
                    adj[j, counts[j]] = i
                    counts[j] += 1

        # seed structure: k-means centroids + their nearest corpus rows
        cents = IVF.kmeans(corpus, min(n_seeds, n), key)
        seed_ids = jnp.argmax(D.l2_scores(cents, corpus), axis=-1).astype(jnp.int32)

        regions = region_store = None
        if spec.params.get("regions"):
            # seed neighborhoods double as quantization regions: one
            # Eq. 1 constant set per seed, fitted in user space
            from repro.cascade import RegionQuant

            seeds_user = cents[:, : user_corpus.shape[1]]
            r_assign = jnp.argmax(
                D.l2_scores(user_corpus, seeds_user), axis=-1
            )
            regions = RegionQuant.fit(
                user_corpus, np.asarray(r_assign), int(cents.shape[0]),
                bits=spec.quant.bits, scheme=spec.quant.scheme,
                sigmas=spec.quant.sigmas,
            )
            region_store = engine.CodeStore.from_codes(
                regions.encode(user_corpus), spec.quant.learn(user_corpus),
                pack=spec.quant.effective_packed,
            )

        idx = GraphIndex(
            metric=metric, degree=degree, store=store,
            adj=jnp.asarray(adj), seeds=cents, seed_ids=seed_ids,
            internal_metric=internal_metric, aug=aug,
            rerank_store=build_rerank_store(spec, user_corpus),
            regions=regions, region_store=region_store,
        )
        idx.build_seconds = time.perf_counter() - t0
        return idx

    # ------------------------------------------------------------------
    def prepare_queries(self, queries: jax.Array) -> jax.Array:
        """queries must already be in the (possibly augmented) index space."""
        return self.store.encode_queries(queries)

    def placement(self, n_shards: int):
        """The walk is not row-shardable — every shard holds the whole
        adjacency and queries fan out instead (dist.replica)."""
        from repro.dist.placement import Placement

        return Placement.replicated(self.n, n_shards)

    def plan(
        self,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        mesh=None,
        placement=None,
    ):
        """Freeze (k, ef) into a pure seed-probe + beam-walk runner.

        Queries enter in user space; the runner applies the MIP->L2
        augmentation internally, so the Searcher's rerank tail (user
        metric, un-augmented store) composes directly on the walked ids.
        Under a mesh the index replicates and the query batch shards
        (``dist.replica``) — bit-exact, the walk is a per-query vmap.
        """
        if placement is not None and placement.kind != "replicated":
            raise ValueError(
                f"the graph walk only replicates; got a {placement.kind!r} "
                "placement"
            )
        sp = params or B.SearchParams()
        ef = max(sp.ef_search, k)
        # filter (DESIGN.md §16): walk unfiltered, widen ef by estimated
        # selectivity, apply the bitmap at the cut/re-score from ef to k
        fmask, fstats = None, {}
        if sp.filter is not None:
            from repro.filter import overfetch

            fmask = jnp.asarray(sp.filter.aligned(self.n))
            ef = max(ef, overfetch(k, sp.filter.selectivity, self.n))
            fstats = {"filter_selectivity":
                      round(sp.filter.selectivity, 6)}
        NEG = float(jnp.finfo(jnp.float32).min)
        score_set = engine.make_score_set(self.store, self.internal_metric)
        n_entry = min(8, self.seeds.shape[0])

        def core(queries: jax.Array):
            qu = jnp.asarray(queries, jnp.float32)     # user space, for regions
            qf = qu
            if self.aug:
                qf = jnp.concatenate(
                    [qf, jnp.zeros((qf.shape[0], 1), jnp.float32)], axis=-1
                )
            q = self.prepare_queries(qf)

            # entry points: best seeds through the engine (the "tree" role)
            _s, probe, _ = engine.topk(
                qf, engine.CodeStore.dense(self.seeds), n_entry,
                self.internal_metric,
            )
            entry = self.seed_ids[probe]                        # [Q, n_entry]

            scores, ids = G.beam_search_batch(
                q, self.adj, entry, score_set=score_set, ef=ef
            )
            if self.regions is not None:
                # re-score walked candidates under each row's own seed-
                # neighborhood constants, in the USER metric and space
                # (the walk's augmented/internal scores only order)
                scores, ids = engine.topk_among_regional(
                    qu, self.region_store, self.regions.scale,
                    self.regions.zero, self.regions.assign, ids, k,
                    self.metric, mask=fmask,
                )
                return scores, ids
            if fmask is not None:
                ok = (ids >= 0) & fmask[jnp.clip(ids, 0, self.n - 1)]
                scores = jnp.where(ok, scores.astype(jnp.float32), NEG)
                ids = jnp.where(ok, ids, -1)
                scores, pos = jax.lax.top_k(scores, k)   # stable: keeps
                ids = jnp.take_along_axis(ids, pos, -1)  # the walk's order
                return scores, ids
            return scores[:, :k], ids[:, :k]

        if mesh is not None:
            from repro.dist.replica import replicated_query_plan

            exec_core = replicated_query_plan(core, mesh)
        else:
            exec_core = core

        def run(queries: jax.Array) -> B.SearchResult:
            nq = queries.shape[0]
            scores, ids = exec_core(queries)
            cand_bound = n_entry + 8 * ef * self.degree
            stats = {"kind": "graph", "ef_search": ef, "n_entry": n_entry,
                     **engine.search_stats(
                         self.store, candidates=cand_bound, chunks=1,
                         rows_read=nq * cand_bound)}
            if self.regions is not None:
                stats.update(
                    regional=True,
                    regional_candidates=ef,
                    bytes_read=stats["bytes_read"] + int(nq) * ef * (
                        self.region_store.row_bytes
                        + 2 * 4 * int(self.region_store.d)),
                )
            if mesh is not None:
                stats["placement"] = "replicated"
            return B.SearchResult(scores, ids, {**stats, **fstats})

        return run

    def searcher(self, k: int, params: Optional[B.SearchParams] = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries: jax.Array,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        ef_search: int | None = None,
    ) -> B.SearchResult:
        from repro.knn import searcher as S

        sp = (params or B.SearchParams()).merged(ef_search=ef_search)
        return S.one_shot(self, queries, k, sp)

    def memory_bytes(self) -> int:
        graph = int(self.adj.size) * 4
        seeds = int(self.seeds.size) * 4 + int(self.seed_ids.size) * 4
        total = self.store.memory_bytes() + graph + seeds
        if self.rerank_store is not None:
            total += self.rerank_store.memory_bytes()
        if self.regions is not None:
            total += self.regions.memory_bytes()
            total += self.region_store.memory_bytes()
        return total

    def region_drift(self, live_corpus):
        """Per-neighborhood calibration drift of a live corpus against the
        fitted constants ([n_seeds] floats; +inf marks empty cells).  Live
        rows assign by user-space seed proximity — the build's own
        assignment rule, so drift against the build corpus is exactly 0."""
        if self.regions is None:
            raise ValueError(
                "region_drift needs a per-region build — construct the "
                "index with an '...,regions' factory (e.g. 'graph,lpq8,regions')"
            )
        live = jnp.asarray(live_corpus, jnp.float32)
        seeds_user = self.seeds[:, : self.region_store.d]
        live_assign = jnp.argmax(D.l2_scores(live, seeds_user), axis=-1)
        return self.regions.drift_report(live, live_assign)

    # ------------------------------------------------------------------
    def save(self, path: str) -> None:
        arrays, meta = self.store.state()
        if self.rerank_store is not None:
            rr_a, rr_m = self.rerank_store.state(prefix="rr_")
            arrays.update(rr_a)
            meta.update(rr_m)
        if self.regions is not None:
            rg_a, rg_m = self.regions.state(prefix="rg_")
            rs_a, rs_m = self.region_store.state(prefix="rgs_")
            arrays.update({**rg_a, **rs_a})
            meta.update({**rg_m, **rs_m})
        B.save_state(
            path,
            {"adj": self.adj, "seeds": self.seeds,
             "seed_ids": self.seed_ids, **arrays},
            {"kind": "graph", "metric": self.metric,
             "quantized": self.quantized, "degree": self.degree,
             "internal_metric": self.internal_metric, "aug": self.aug,
             "build_seconds": self.build_seconds, **meta},
        )

    @staticmethod
    def load(path: str) -> "GraphIndex":
        arrays, meta = B.load_state(path)
        regions = region_store = None
        if "rg_regions" in meta:
            from repro.cascade import RegionQuant

            regions = RegionQuant.from_state(arrays, meta, prefix="rg_")
            region_store = engine.CodeStore.from_state(arrays, meta, prefix="rgs_")
        return GraphIndex(
            metric=meta["metric"], degree=meta["degree"],
            store=engine.CodeStore.from_state(arrays, meta),
            adj=jnp.asarray(arrays["adj"]),
            seeds=jnp.asarray(arrays["seeds"]),
            seed_ids=jnp.asarray(arrays["seed_ids"]),
            build_seconds=float(meta.get("build_seconds", 0.0)),
            internal_metric=meta["internal_metric"], aug=meta["aug"],
            rerank_store=(engine.CodeStore.from_state(arrays, meta, prefix="rr_")
                          if "rr_store" in meta else None),
            regions=regions, region_store=region_store,
        )
