"""The common ``Index`` protocol: one call shape for every index kind.

Every registered index implements

    build(corpus, spec, *, key=None)          -> Index
    search(queries, k, params=None)           -> SearchResult
    memory_bytes()                            -> int
    save(path) / load(path)                   -> disk round-trip

``SearchParams`` unifies the per-kind search knobs (``chunk`` for the
exhaustive scan, ``nprobe`` for IVF, ``ef_search`` for the graph walks);
each index reads the knobs it understands and ignores the rest, so one
``SearchParams`` drives any kind — the registry-driven serving loop and
benchmarks depend on exactly that property.

``SearchResult`` carries (scores, ids, stats).  It unpacks like the
historical ``(scores, ids)`` pair so pre-unification call sites keep
working: ``scores, ids = index.search(q, k)``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterator, Optional, Protocol, runtime_checkable

import jax
import numpy as np

from repro.knn.spec import IndexSpec


@dataclasses.dataclass(frozen=True)
class SearchParams:
    """Union of every index kind's search-time knobs.

    chunk      exhaustive-scan working-set bound (flat, pq): scan-chunk
               rows on the unfused path, corpus-tile cap for the fused
               kernels
    nprobe     probed lists per query (ivf)
    ef_search  beam width of the graph walk (hnsw, graph)
    budgets    per-stage fetch depths of a cascade index (DESIGN.md §14):
               ``budgets[i]`` is how many candidates refinement stage
               ``i`` receives; must be non-increasing and each >= k
               (validated at plan time).  ``None`` = geometric defaults.
               A tuple (not a list) so SearchParams stays hashable — it
               rides inside compiled-plan and result-cache keys.
    filter     a ``repro.filter.Filter`` predicate bitmap over *external*
               row ids (DESIGN.md §16); every kind pushes it into the
               engine's id-masking path so only allowed rows can be
               returned.  Filters hash by bitmap digest, so SearchParams
               stays a valid compiled-plan / result-cache key member.
    """

    chunk: int = 16384
    nprobe: int = 8
    ef_search: int = 100
    budgets: Optional[tuple[int, ...]] = None
    filter: Optional[Any] = None

    def merged(self, **overrides) -> "SearchParams":
        live = {k: v for k, v in overrides.items() if v is not None}
        return dataclasses.replace(self, **live) if live else self

    def validate(self) -> "SearchParams":
        """Reject nonsense knobs at plan time (clear ``ValueError``s now
        instead of kernel-shape errors deep inside a trace)."""
        for name in ("chunk", "nprobe", "ef_search"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
                raise ValueError(
                    f"SearchParams.{name} must be a positive int, got {v!r}"
                )
        if self.budgets is not None:
            if not isinstance(self.budgets, tuple) or not self.budgets:
                raise ValueError(
                    f"SearchParams.budgets must be a non-empty tuple of "
                    f"positive ints (or None), got {self.budgets!r}"
                )
            for v in self.budgets:
                if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
                    raise ValueError(
                        f"SearchParams.budgets entries must be positive "
                        f"ints, got {v!r} in {self.budgets!r}"
                    )
        if self.filter is not None:
            from repro.filter import Filter

            if not isinstance(self.filter, Filter):
                raise ValueError(
                    f"SearchParams.filter must be a repro.filter.Filter "
                    f"(or None), got {type(self.filter).__name__}"
                )
        return self


@dataclasses.dataclass
class SearchResult:
    """scores [Q, k] f32 (larger-is-closer), ids [Q, k] i32 (-1 = no hit),
    stats: per-search accounting (kind, candidates scored, ...)."""

    scores: jax.Array
    ids: jax.Array
    stats: dict[str, Any] = dataclasses.field(default_factory=dict)

    # legacy pair protocol: ``scores, ids = index.search(...)`` and
    # ``index.search(...)[1]`` predate SearchResult and stay valid.
    def __iter__(self) -> Iterator[jax.Array]:
        return iter((self.scores, self.ids))

    def __getitem__(self, i):
        return (self.scores, self.ids)[i]

    def __len__(self) -> int:
        return 2


# a jax pytree (scores/ids are leaves, stats is static aux data) so jitted
# callers can return it, as they could the old (scores, ids) tuple
jax.tree_util.register_pytree_node(
    SearchResult,
    lambda r: ((r.scores, r.ids), tuple(sorted(r.stats.items()))),
    lambda aux, kids: SearchResult(kids[0], kids[1], dict(aux)),
)


@runtime_checkable
class Index(Protocol):
    """Structural protocol every registered index satisfies.

    The query side is plan-then-execute (DESIGN.md §9): ``plan`` freezes
    k + SearchParams into a pure runner, ``searcher`` wraps that runner in
    the compiled/bucketed/rerank-capable ``Searcher`` handle, and
    ``search`` is sugar — a one-shot searcher call — kept for every
    pre-plan call site.
    """

    kind: str

    @staticmethod
    def build(corpus, spec: IndexSpec | str | None = None, *, key=None) -> "Index":
        ...

    def search(self, queries, k: int, params: Optional[SearchParams] = None) -> SearchResult:
        ...

    def plan(self, k: int, params: Optional[SearchParams] = None, *, mesh=None,
             placement=None):
        ...

    def searcher(self, k: int, params: Optional[SearchParams] = None, **kwargs):
        ...

    def memory_bytes(self) -> int:
        ...

    def save(self, path: str) -> None:
        ...

    @staticmethod
    def load(path: str) -> "Index":
        ...


# --------------------------------------------------------------------------
# Disk round-trip: one .npz per index — arrays plus a JSON meta record.
# --------------------------------------------------------------------------

_META_KEY = "__meta__"


def save_state(path, arrays: dict[str, Any], meta: dict[str, Any]) -> None:
    """Write an index's arrays + static metadata as a single ``.npz``.

    ``meta`` must be JSON-serializable and include ``kind`` so
    ``registry.load_index`` can dispatch without knowing the class.
    ``path`` may be a filesystem path or a binary file-like object — the
    stream manifest embeds each sealed segment's inner-index npz as a
    byte blob inside its own npz, so index save/load must compose through
    in-memory buffers (DESIGN.md §10).

    When a TuneTable is installed (``repro.tune``), it rides along under
    ``meta["tune"]`` so a reloaded index serves with the configs it was
    tuned with (``registry.load_index`` adopts it, stamp-checked).
    """
    from repro.tune import table as tunetable

    active_table = tunetable.active()
    if active_table is not None and "tune" not in meta:
        meta = {**meta, "tune": active_table.to_dict()}
    out = {k: np.asarray(v) for k, v in arrays.items() if v is not None}
    out[_META_KEY] = np.frombuffer(
        json.dumps(meta).encode("utf-8"), dtype=np.uint8
    )
    if hasattr(path, "write"):
        np.savez(path, **out)
        return
    with open(path, "wb") as f:
        np.savez(f, **out)


def load_state(path) -> tuple[dict[str, np.ndarray], dict[str, Any]]:
    if hasattr(path, "seek"):
        path.seek(0)              # compose after load_meta on one buffer
    with np.load(path) as z:
        meta = json.loads(bytes(z[_META_KEY].tobytes()).decode("utf-8"))
        arrays = {k: z[k] for k in z.files if k != _META_KEY}
    return arrays, meta


def load_meta(path) -> dict[str, Any]:
    """Read only the metadata record — npz members load lazily, so this
    never materializes the (possibly huge) index arrays."""
    if hasattr(path, "seek"):
        path.seek(0)
    with np.load(path) as z:
        return json.loads(bytes(z[_META_KEY].tobytes()).decode("utf-8"))


# Quantization-constant (de)serialization lives with the storage layer:
# ``engine.CodeStore.state`` / ``from_state`` — index save/load merges the
# store's fragments into its own npz record.
