"""Graph construction utilities shared by the ANN indexes and the GNN
substrate (SchNet consumes radius/kNN graphs over 3-D points — built here
with the paper's quantized L2 when requested, see DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import quant as Qz


def knn_graph(
    points: jax.Array,
    k: int,
    metric: str = "l2",
    quantized: bool = False,
    bits: int = 8,
):
    """[N, d] -> [N, k] neighbor ids (self excluded).

    With ``quantized=True`` the O(N^2 d) distance pass runs in int8 —
    the paper's technique applied to graph construction.
    """
    n = points.shape[0]
    if quantized:
        codes, params = Qz.quantize_corpus(points, bits=bits, scheme=Qz.Scheme.ABSMAX)
        s = D.scores(codes, codes, metric, quantized=True).astype(jnp.float32)
    else:
        s = D.scores(points, points, metric)
    s = s - jnp.inf * jnp.eye(n, dtype=s.dtype)  # exclude self
    s = jnp.where(jnp.eye(n, dtype=bool), jnp.finfo(jnp.float32).min, s)
    return jax.lax.top_k(s, min(k, n - 1))[1].astype(jnp.int32)


def radius_graph(
    positions: jax.Array,
    cutoff: float,
    max_neighbors: int,
    quantized: bool = False,
    bits: int = 8,
):
    """Edges within ``cutoff`` (L2), capped at ``max_neighbors`` per node.

    Returns (senders [N*max_neighbors], receivers [...], mask [...]) —
    flat padded edge lists ready for segment_sum message passing.
    """
    n = positions.shape[0]
    if quantized:
        codes, _ = Qz.quantize_corpus(positions, bits=bits, scheme=Qz.Scheme.ABSMAX)
        # int32 negated squared L2; rescale to compare against cutoff in
        # the original units via the (uniform) scale factor
        params = Qz.learn_params(positions, bits=bits, scheme=Qz.Scheme.ABSMAX)
        neg_l2 = D.ql2_scores(codes, codes).astype(jnp.float32)
        scale = jnp.mean(params.scale)
        dist2 = -neg_l2 * scale * scale
    else:
        diff = positions[:, None, :] - positions[None, :, :]
        dist2 = jnp.sum(diff * diff, axis=-1)

    self_mask = jnp.eye(n, dtype=bool)
    within = (dist2 <= cutoff * cutoff) & (~self_mask)
    # per receiver: pick up to max_neighbors closest senders
    masked = jnp.where(within, -dist2, jnp.finfo(jnp.float32).min)
    top_s, top_i = jax.lax.top_k(masked, min(max_neighbors, n))
    valid = top_s > jnp.finfo(jnp.float32).min

    receivers = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[:, None], top_i.shape
    ).reshape(-1)
    senders = top_i.astype(jnp.int32).reshape(-1)
    mask = valid.reshape(-1)
    return jnp.where(mask, senders, 0), receivers, mask
