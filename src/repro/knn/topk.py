"""DEPRECATED shim — every top-k implementation lives in ``repro.engine``.

This module used to hold a second copy of the streaming chunked-merge
scan plus the distributed shard-merge.  Those are now canonical in
``repro.engine.scorer`` (one ``_stream_topk`` core behind both the
store-aware ``engine.topk`` path and the generic score-fn
``chunked_topk``), and this module only re-exports the legacy names for
pre-engine callers:

    merge_topk / pad_rows      streaming primitives
    chunked_topk               generic score-fn streaming top-k (now pads
                               and id-masks internally; the historical
                               N % chunk == 0 requirement is gone)
    distributed_topk           cross-shard k-sized merge
    pad_corpus / mask_invalid  the historical pad-then-mask pair callers
                               of the old chunked_topk needed

New code should import from ``repro.engine`` directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.scorer import (  # noqa: F401  (re-exports)
    chunked_topk,
    distributed_topk,
    merge_topk,
    pad_rows,
)

__all__ = [
    "merge_topk",
    "pad_rows",
    "pad_corpus",
    "mask_invalid",
    "chunked_topk",
    "distributed_topk",
]


def pad_corpus(corpus: jax.Array, multiple: int):
    """Back-compat alias of ``engine.pad_rows`` (padded, n_valid).

    ``engine.chunked_topk`` now pads and id-masks internally — callers no
    longer need this except to reproduce the historical two-step contract.
    """
    return pad_rows(corpus, multiple)


def mask_invalid(scores: jax.Array, ids: jax.Array, n_valid: int):
    """Force padded ids out of any subsequent merge (back-compat helper)."""
    bad = ids >= n_valid
    return jnp.where(bad, jnp.finfo(jnp.float32).min, scores), jnp.where(bad, -1, ids)
