"""Top-k machinery: streaming (chunked) top-k over huge corpora and the
distributed shard-merge used when the corpus is row-sharded over a mesh.

Larger-is-closer convention throughout (matches core.distances).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp


def merge_topk(
    scores_a: jax.Array,
    ids_a: jax.Array,
    scores_b: jax.Array,
    ids_b: jax.Array,
    k: int,
):
    """Merge two [Q, ka]/[Q, kb] candidate sets into the best k."""
    s = jnp.concatenate([scores_a, scores_b], axis=-1)
    i = jnp.concatenate([ids_a, ids_b], axis=-1)
    top_s, pos = jax.lax.top_k(s, k)
    top_i = jnp.take_along_axis(i, pos, axis=-1)
    return top_s, top_i


@partial(jax.jit, static_argnames=("k", "chunk", "score_fn"))
def chunked_topk(
    queries: jax.Array,
    corpus: jax.Array,
    k: int,
    score_fn: Callable[[jax.Array, jax.Array], jax.Array],
    chunk: int = 16384,
):
    """Exact top-k of score_fn(queries, corpus) without materializing [Q, N].

    ``lax.scan`` over corpus row-chunks carrying a running (scores, ids)
    top-k — the streaming formulation that keeps the working set at
    O(Q * (k + chunk)) regardless of N.  Requires N % chunk == 0 (callers
    pad with -inf sentinel rows via ``pad_corpus``).
    """
    Q = queries.shape[0]
    N = corpus.shape[0]
    assert N % chunk == 0, (N, chunk)
    n_chunks = N // chunk
    tiles = corpus.reshape(n_chunks, chunk, corpus.shape[-1])

    init_s = jnp.full((Q, k), jnp.finfo(jnp.float32).min, jnp.float32)
    init_i = jnp.full((Q, k), -1, jnp.int32)

    def step(carry, inp):
        best_s, best_i = carry
        tile, tile_idx = inp
        s = score_fn(queries, tile).astype(jnp.float32)        # [Q, chunk]
        ids = (tile_idx * chunk + jnp.arange(chunk, dtype=jnp.int32))[None, :]
        ids = jnp.broadcast_to(ids, s.shape)
        return merge_topk(best_s, best_i, s, ids, k), None

    (best_s, best_i), _ = jax.lax.scan(
        step, (init_s, init_i), (tiles, jnp.arange(n_chunks, dtype=jnp.int32))
    )
    return best_s, best_i


def pad_corpus(corpus: jax.Array, multiple: int):
    """Pad corpus rows to a multiple; returns (padded, n_valid).

    Padding rows are zeros — callers must mask ids >= n_valid or rely on
    sentinel scores (zero vectors score 0 for IP; for L2 they can win, so
    flat search masks by id).
    """
    n = corpus.shape[0]
    target = ((n + multiple - 1) // multiple) * multiple
    if target == n:
        return corpus, n
    return jnp.pad(corpus, ((0, target - n), (0, 0))), n


def mask_invalid(scores: jax.Array, ids: jax.Array, n_valid: int):
    """Force padded ids out of any subsequent merge."""
    bad = ids >= n_valid
    return jnp.where(bad, jnp.finfo(jnp.float32).min, scores), jnp.where(bad, -1, ids)


# --------------------------------------------------------------------------
# Distributed merge (corpus row-sharded over one or more mesh axes)
# --------------------------------------------------------------------------

def distributed_topk(
    local_scores: jax.Array,
    local_ids: jax.Array,
    k: int,
    axis_name: str | tuple[str, ...],
    shard_offset: jax.Array,
):
    """Merge per-shard top-k into a global top-k, inside ``shard_map``.

    Each shard holds [Q, k] candidates with *local* ids; ``shard_offset``
    (scalar, per shard) rebases them to global row ids.  One all_gather of
    k entries per query per shard — O(shards * Q * k) bytes, independent of
    corpus size N.  (A butterfly collective_permute halves wire bytes at
    log-depth; see EXPERIMENTS.md §Perf for why all_gather wins at k=100.)
    """
    gids = jnp.where(local_ids >= 0, local_ids + shard_offset, -1)
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    s, i = local_scores, gids
    for name in names:
        s = jax.lax.all_gather(s, name, axis=0)   # [S, Q, k]
        i = jax.lax.all_gather(i, name, axis=0)
        S, Q, kk = s.shape
        s = jnp.moveaxis(s, 0, 1).reshape(Q, S * kk)
        i = jnp.moveaxis(i, 0, 1).reshape(Q, S * kk)
        s, pos = jax.lax.top_k(s, k)
        i = jnp.take_along_axis(i, pos, axis=-1)
    return s, i
