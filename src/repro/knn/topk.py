"""Top-k machinery: the distributed shard-merge used when the corpus is
row-sharded over a mesh, plus back-compat re-exports of the generic
streaming helpers whose canonical home is now ``repro.engine.scorer``.

Index classes no longer call anything here — the engine owns chunking,
padding and invalid-id masking for every kind (scores are id-masked at
the source, so the historical L2 zero-sentinel hazard — a zero pad row
out-scoring real rows under negated L2 for callers that forgot to mask —
cannot occur).  ``chunked_topk`` remains as a generic utility for
score-fn-shaped callers outside the index layer.

Larger-is-closer convention throughout (matches core.distances).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

# canonical implementations live in the engine; re-exported for callers
# that predate the engine layer
from repro.engine.scorer import merge_topk, pad_rows

__all__ = [
    "merge_topk",
    "pad_rows",
    "pad_corpus",
    "mask_invalid",
    "chunked_topk",
    "distributed_topk",
]


def pad_corpus(corpus: jax.Array, multiple: int):
    """Pad corpus rows to a multiple; returns (padded, n_valid).

    Back-compat alias of ``engine.pad_rows``.  Padding rows are zeros;
    every engine path masks them *by id* before any merge, so pad rows
    can never win — even under L2 where a zero row would otherwise
    out-score distant real rows.  Callers using this helper directly must
    apply ``mask_invalid`` (or id-mask themselves) the same way.
    """
    return pad_rows(corpus, multiple)


def mask_invalid(scores: jax.Array, ids: jax.Array, n_valid: int):
    """Force padded ids out of any subsequent merge."""
    bad = ids >= n_valid
    return jnp.where(bad, jnp.finfo(jnp.float32).min, scores), jnp.where(bad, -1, ids)


@partial(jax.jit, static_argnames=("k", "chunk", "score_fn"))
def chunked_topk(
    queries: jax.Array,
    corpus: jax.Array,
    k: int,
    score_fn: Callable[[jax.Array, jax.Array], jax.Array],
    chunk: int = 16384,
):
    """Exact top-k of score_fn(queries, corpus) without materializing [Q, N].

    ``lax.scan`` over corpus row-chunks carrying a running (scores, ids)
    top-k — the streaming formulation that keeps the working set at
    O(Q * (k + chunk)) regardless of N.  Requires N % chunk == 0 (callers
    pad via ``pad_corpus`` and id-mask the result with ``mask_invalid``).

    Generic score-fn version; the index hot path uses the engine's fused
    Pallas kernels instead (``engine.topk``).
    """
    Q = queries.shape[0]
    N = corpus.shape[0]
    assert N % chunk == 0, (N, chunk)
    n_chunks = N // chunk
    tiles = corpus.reshape(n_chunks, chunk, corpus.shape[-1])

    init_s = jnp.full((Q, k), jnp.finfo(jnp.float32).min, jnp.float32)
    init_i = jnp.full((Q, k), -1, jnp.int32)

    def step(carry, inp):
        best_s, best_i = carry
        tile, tile_idx = inp
        s = score_fn(queries, tile).astype(jnp.float32)        # [Q, chunk]
        ids = (tile_idx * chunk + jnp.arange(chunk, dtype=jnp.int32))[None, :]
        ids = jnp.broadcast_to(ids, s.shape)
        return merge_topk(best_s, best_i, s, ids, k), None

    (best_s, best_i), _ = jax.lax.scan(
        step, (init_s, init_i), (tiles, jnp.arange(n_chunks, dtype=jnp.int32))
    )
    return best_s, best_i


# --------------------------------------------------------------------------
# Distributed merge (corpus row-sharded over one or more mesh axes)
# --------------------------------------------------------------------------

def distributed_topk(
    local_scores: jax.Array,
    local_ids: jax.Array,
    k: int,
    axis_name: str | tuple[str, ...],
    shard_offset: jax.Array,
):
    """Merge per-shard top-k into a global top-k, inside ``shard_map``.

    Each shard holds [Q, k] candidates with *local* ids; ``shard_offset``
    (scalar, per shard) rebases them to global row ids.  One all_gather of
    k entries per query per shard — O(shards * Q * k) bytes, independent of
    corpus size N.  (A butterfly collective_permute halves wire bytes at
    log-depth; see EXPERIMENTS.md §Perf for why all_gather wins at k=100.)

    Shard-local stores built with ``CodeStore(base=offset)`` already
    return rebased ids from the engine — pass ``shard_offset=0`` there.
    """
    gids = jnp.where(local_ids >= 0, local_ids + shard_offset, -1)
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    s, i = local_scores, gids
    for name in names:
        s = jax.lax.all_gather(s, name, axis=0)   # [S, Q, k]
        i = jax.lax.all_gather(i, name, axis=0)
        S, Q, kk = s.shape
        s = jnp.moveaxis(s, 0, 1).reshape(Q, S * kk)
        i = jnp.moveaxis(i, 0, 1).reshape(Q, S * kk)
        s, pos = jax.lax.top_k(s, k)
        i = jnp.take_along_axis(i, pos, axis=-1)
    return s, i
