# Distribution utilities: mesh-sharding rules for every model family plus
# a shard_map compatibility shim (jax moved shard_map out of experimental
# across the versions this repo supports).
from repro.dist.sharding import (
    P,
    dp_axes,
    named,
    replicated,
    shard_map,
)

__all__ = ["P", "dp_axes", "named", "replicated", "shard_map"]
