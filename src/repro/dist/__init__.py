# Distribution utilities: mesh-sharding rules for every model family, a
# shard_map compatibility shim (jax moved shard_map out of experimental
# across the versions this repo supports), placement plans assigning
# rows/lists/segments to mesh shards, and replica-group query fan-out.
from repro.dist import placement
from repro.dist.placement import Placement
from repro.dist.replica import ReplicaSet, replicated_query_plan, submeshes
from repro.dist.sharding import (
    P,
    corpus_shards,
    dp_axes,
    named,
    replicated,
    sentinel_gids,
    shard_map,
)

__all__ = [
    "P",
    "Placement",
    "ReplicaSet",
    "corpus_shards",
    "dp_axes",
    "named",
    "placement",
    "replicated",
    "replicated_query_plan",
    "sentinel_gids",
    "shard_map",
    "submeshes",
]
