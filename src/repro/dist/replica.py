"""Replica groups: query fan-out over data-parallel replicas (DESIGN.md §15).

Two layers, matching the two places replication happens:

  * **inside the jit** — ``replicated_query_plan`` wraps a per-kind
    array function ``(queries) -> (scores, ids)`` in a ``shard_map``
    over the *query* axis: every shard holds a full copy of the index
    (graph walks are not row-shardable) and walks its slice of the
    batch; ``out_specs`` reassemble the full batch with no host
    round-trip.  Per-query independence makes this bit-exact against
    the unsharded run.
  * **outside the jit** — ``ReplicaSet`` is the serving layer: R
    replica searchers (optionally each pinned to its own sub-mesh via
    ``submeshes``), worker threads draining per-replica queues, with
    per-replica admission (bounded queue depth) and per-replica
    telemetry (requests, queue-wait/execute spans, queue-depth peaks)
    flowing into the shared :mod:`repro.runtime.telemetry` registry.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = ["replicated_query_plan", "submeshes", "ReplicaSet"]


def replicated_query_plan(fn, mesh):
    """Fan a query batch out over ``mesh``; the index replicates.

    ``fn`` is a pure array function ``(queries [Q, d]) -> (scores, ids)``
    whose per-row outputs depend only on that row (every walk/scan kind
    satisfies this).  The wrapper pads Q up to a multiple of the mesh
    size, shards the batch over every mesh axis, runs ``fn`` on each
    shard's slice (closed-over index arrays are replicated constants),
    and reassembles — all inside the caller's jit.  Pad queries are
    zeros; their rows are dropped before returning.
    """
    import jax.numpy as jnp

    from repro.dist.sharding import P, corpus_shards, shard_map

    axes, n_shards = corpus_shards(mesh)
    inner = shard_map(
        lambda qs: fn(qs),
        mesh=mesh,
        in_specs=(P(axes, None),),
        out_specs=(P(axes, None), P(axes, None)),
        check_vma=False,
    )

    def run(q):
        Q = q.shape[0]
        pad = (-Q) % n_shards
        if pad:
            q = jnp.pad(q, ((0, pad), (0, 0)))
        s, i = inner(q)
        return s[:Q], i[:Q]

    return run


def submeshes(n_groups: int, devices: Optional[Sequence] = None) -> list:
    """Split the host's devices into ``n_groups`` disjoint 1-axis meshes
    — one per replica, so R replicas x (n_dev // R)-way sharding covers
    the whole host with no device oversubscription.  Groups are
    equal-sized (trailing remainder devices are left unused — replica
    plans must be shape-identical to share compiled executables)."""
    import jax
    from jax.sharding import Mesh

    devs = list(devices if devices is not None else jax.devices())
    n_groups = max(1, min(int(n_groups), len(devs)))
    per = len(devs) // n_groups
    return [Mesh(np.array(devs[g * per:(g + 1) * per]), ("data",))
            for g in range(n_groups)]


class ReplicaSet:
    """R data-parallel serving replicas behind per-replica queues.

    ``make_replica(r)`` builds replica ``r``'s request callable
    (``payload -> result``; serve.py passes a closure over a Searcher +
    ``block_until_ready``).  ``submit`` routes to the least-loaded
    replica (ties to the lowest id), enforcing ``max_queue`` *per
    replica* at the door — a full replica sheds rather than queues
    without bound — and returns a ``Future``.  Workers record one
    telemetry request row per served request (``replica{r}/queue_wait``
    and ``replica{r}/execute`` phases) plus shared counters
    ``replica{r}_requests`` / ``replica{r}_queries`` /
    ``replica{r}_queue_peak`` / ``replica_shed``.

    ``drain()`` blocks until every queued request has executed — the
    write barrier: serve.py drains, applies the mutation, then
    ``rebuild()``s so every replica re-plans against the new manifest
    epoch before traffic resumes.
    """

    _STOP = object()

    def __init__(self, make_replica: Callable[[int], Callable], n_replicas: int,
                 *, max_queue: int = 0, telemetry=None):
        if n_replicas <= 0:
            raise ValueError(f"n_replicas must be positive, got {n_replicas}")
        self._make = make_replica
        self.n_replicas = int(n_replicas)
        self.max_queue = int(max_queue)
        self._telemetry = telemetry
        self._queues = [queue.Queue() for _ in range(self.n_replicas)]
        self._depths = [0] * self.n_replicas
        self._lock = threading.Lock()
        self._seq = 0
        self._replicas = [make_replica(r) for r in range(self.n_replicas)]
        self._workers = [
            threading.Thread(target=self._work, args=(r,), daemon=True)
            for r in range(self.n_replicas)
        ]
        for w in self._workers:
            w.start()

    # -- routing -----------------------------------------------------------
    def submit(self, payload, queries: int = 0) -> Optional[Future]:
        """Enqueue on the least-loaded replica; None == shed (replica
        queues full — per-replica admission)."""
        with self._lock:
            r = min(range(self.n_replicas), key=lambda j: (self._depths[j], j))
            if self.max_queue and self._depths[r] >= self.max_queue:
                if self._telemetry is not None:
                    self._telemetry.counters["replica_shed"] += 1
                return None
            self._depths[r] += 1
            depth = self._depths[r]
            self._seq += 1
            seq = self._seq
        if self._telemetry is not None:
            c = self._telemetry.counters
            c[f"replica{r}_requests"] += 1
            c[f"replica{r}_queries"] += int(queries)
            c[f"replica{r}_queue_peak"] = max(c[f"replica{r}_queue_peak"], depth)
        fut: Future = Future()
        self._queues[r].put((payload, int(queries), fut, seq,
                             time.perf_counter()))
        return fut

    def _work(self, r: int) -> None:
        q = self._queues[r]
        while True:
            item = q.get()
            if item is self._STOP:
                q.task_done()
                return
            payload, nq, fut, seq, t_enq = item
            t0 = time.perf_counter()
            tr = None
            if self._telemetry is not None:
                tr = self._telemetry.request(seq)
                tr.phase(f"replica{r}/queue_wait", t0 - t_enq)
            try:
                res = self._replicas[r](payload)
                fut.set_result(res)
            except BaseException as e:  # surface on the future, keep serving
                fut.set_exception(e)
            if tr is not None:
                tr.phase(f"replica{r}/execute", time.perf_counter() - t0)
                tr.annotate(replica=r, queries=nq, outcome="served")
                tr.finish()
            with self._lock:
                self._depths[r] -= 1
            q.task_done()

    # -- lifecycle ---------------------------------------------------------
    def drain(self) -> None:
        """Block until every enqueued request has finished executing."""
        for q in self._queues:
            q.join()

    def rebuild(self) -> None:
        """Write barrier: drain, then re-plan every replica (serve.py
        calls this after a mutation bumps the manifest epoch)."""
        self.drain()
        self._replicas = [self._make(r) for r in range(self.n_replicas)]

    def close(self) -> None:
        self.drain()
        for q in self._queues:
            q.put(self._STOP)
        for w in self._workers:
            w.join(timeout=10.0)
