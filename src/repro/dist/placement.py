"""Placement plans: which shard owns which rows/lists/segments (DESIGN.md §15).

A ``Placement`` is the host-side half of a sharded search plan: a frozen
assignment of an index's natural shard units to mesh shards, computed at
plan time and pinned by the ``Searcher`` the same way tune tables are.
The unit depends on the kind:

  * ``rows``       — flat / pq scans: contiguous row blocks, one per shard
                     (block order == gid order, so the cross-shard merge's
                     shard-major gather is already in canonical id order).
  * ``lists``      — ivf: whole IVF lists, balanced by list *size* (LPT
                     greedy), so a skewed clustering cannot pile the big
                     lists onto one device.
  * ``segments``   — stream: a sealed segment is a natural shard unit with
                     its own row-id base; the memtable rides as one more
                     unit.  (The compiled plan shards each source over the
                     full mesh — see DESIGN.md §15 — this placement is the
                     accounting view: per-shard bytes, balance, telemetry.)
  * ``replicated`` — graph walks (hnsw/graph): the structure is not
                     row-shardable, so every shard holds a full copy and
                     queries fan out over the mesh instead (dist.replica).

Everything here is plain host Python over ints — no jax — so plans can
be printed, logged, and asserted on without touching a device.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = ["Placement", "balance", "for_index"]


def balance(sizes: Sequence[int], n_shards: int) -> tuple[int, ...]:
    """LPT greedy assignment: units sorted by size (desc) land on the
    currently-least-loaded shard.  Deterministic — ties in size break by
    unit id, ties in load break by shard id — so the same inputs always
    produce the same placement (plans must be reproducible across
    processes to keep replica groups consistent)."""
    if n_shards <= 0:
        raise ValueError(f"n_shards must be positive, got {n_shards}")
    loads = [0] * n_shards
    assign = [0] * len(sizes)
    order = sorted(range(len(sizes)), key=lambda u: (-int(sizes[u]), u))
    for u in order:
        s = min(range(n_shards), key=lambda j: (loads[j], j))
        assign[u] = s
        loads[s] += int(sizes[u])
    return tuple(assign)


@dataclasses.dataclass(frozen=True)
class Placement:
    """A frozen unit -> shard assignment.

    ``assign[u]`` is the shard owning unit ``u``; ``unit_sizes[u]`` is
    that unit's row count.  ``kind`` names the unit type (see module
    docstring).  For ``replicated`` placements ``assign`` is empty —
    every shard holds everything.
    """

    kind: str
    n_shards: int
    assign: tuple[int, ...]
    unit_sizes: tuple[int, ...]
    #: only for ``replicated`` placements, which have no units: the row
    #: count every shard holds a full copy of
    replicated_rows: int = 0

    def __post_init__(self):
        if self.kind not in ("rows", "lists", "segments", "replicated"):
            raise ValueError(f"unknown placement kind {self.kind!r}")
        if len(self.assign) != len(self.unit_sizes):
            raise ValueError("assign and unit_sizes must align")
        if any(not (0 <= s < self.n_shards) for s in self.assign):
            raise ValueError("assign references a shard outside the mesh")

    # -- views -------------------------------------------------------------
    @property
    def n_units(self) -> int:
        return len(self.assign)

    @property
    def n_rows(self) -> int:
        if self.kind == "replicated":
            return self.replicated_rows
        return sum(self.unit_sizes)

    def shard_units(self, shard: int) -> tuple[int, ...]:
        """Unit ids owned by ``shard``, in unit order."""
        return tuple(u for u, s in enumerate(self.assign) if s == shard)

    def shard_rows(self, shard: int) -> int:
        if self.kind == "replicated":
            return self.n_rows
        return sum(self.unit_sizes[u] for u in self.shard_units(shard))

    @property
    def rows_max(self) -> int:
        """Rows on the fullest shard — the padded per-shard extent the
        compiled plan allocates, and the number that must fit one
        device's budget."""
        return max(self.shard_rows(s) for s in range(self.n_shards))

    def shard_bytes(self, row_bytes: int) -> tuple[int, ...]:
        """Per-shard resident code bytes (telemetry: shard scan bytes)."""
        return tuple(self.shard_rows(s) * int(row_bytes)
                     for s in range(self.n_shards))

    def summary(self) -> dict:
        rows = [self.shard_rows(s) for s in range(self.n_shards)]
        total = sum(rows) or 1
        return {
            "kind": self.kind,
            "n_shards": self.n_shards,
            "n_units": self.n_units,
            "rows": rows,
            # balance: fullest shard vs the perfectly-even split (1.0 ==
            # perfect; replicated placements report n_shards by design)
            "balance": round(max(rows) * self.n_shards / total, 4),
        }

    # -- factories ---------------------------------------------------------
    @classmethod
    def rows(cls, n: int, n_shards: int) -> "Placement":
        """Contiguous ceil-sized row blocks, shard s owning rows
        ``[s*rows_per, min((s+1)*rows_per, n))`` — the layout
        ``sharded_scan_plan`` has always used, now written down."""
        rows_per = -(-n // n_shards) if n else 0
        sizes = tuple(max(0, min(n - s * rows_per, rows_per))
                      for s in range(n_shards))
        return cls("rows", n_shards, tuple(range(n_shards)), sizes)

    @classmethod
    def lists(cls, list_sizes: Sequence[int], n_shards: int) -> "Placement":
        sizes = tuple(int(x) for x in list_sizes)
        return cls("lists", n_shards, balance(sizes, n_shards), sizes)

    @classmethod
    def segments(cls, segment_rows: Sequence[int], n_shards: int) -> "Placement":
        sizes = tuple(int(x) for x in segment_rows)
        return cls("segments", n_shards, balance(sizes, n_shards), sizes)

    @classmethod
    def replicated(cls, n_rows: int, n_shards: int) -> "Placement":
        return cls("replicated", n_shards, (), (), replicated_rows=int(n_rows))


def for_index(index, n_shards: int) -> Placement:
    """The placement an index kind elects for an ``n_shards`` mesh.

    Kinds expose a ``placement(n_shards)`` method (ivf -> lists, stream
    -> segments, graph walks -> replicated); anything without one gets
    the contiguous row-block default.
    """
    own = getattr(index, "placement", None)
    if callable(own):
        return own(n_shards)
    return Placement.rows(int(index.n), n_shards)
