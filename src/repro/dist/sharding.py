"""Mesh/NamedSharding rules for the dry-run cells and the launcher.

One convention everywhere: the production mesh is ("data", "model") —
optionally prefixed by a "pod" axis on the multi-pod mesh — and every
rule here degrades gracefully: a dimension is only sharded when its size
divides the axis size, otherwise that dimension is replicated, so the
same rules drive the 512-chip dry-run meshes and the 1-device host mesh
the tests run on.

Layout summary (DESIGN.md §4 records the serving side):
  * LM params: megatron-style — embed table vocab-sharded over "model";
    attention/MLP in-projections column-sharded, out-projections
    row-sharded over "model"; norms replicated.
  * ZeRO: gradient/optimizer accumulators additionally take "data" on
    their first replicated dimension (``lm_zero_spec``).
  * KV caches: batch-sharded over the data axes.
  * Recsys: big embedding tables row-sharded over ("data", "model")
    (DLRM hybrid parallelism); towers replicated.
  * GNN: edge lists sharded over the whole mesh; SchNet params replicated.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5
    from jax import shard_map
except ImportError:  # jax 0.4.x: experimental location, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, **kwargs):  # type: ignore[no-redef]
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map_04(f, **kwargs)

__all__ = [
    "P",
    "shard_map",
    "named",
    "replicated",
    "dp_axes",
    "corpus_shards",
    "sentinel_gids",
    "lm_params_sharding",
    "lm_opt_sharding",
    "lm_grad_specs",
    "lm_zero_spec",
    "lm_cache_spec",
    "recsys_params_sharding",
    "recsys_opt_sharding",
    "gnn_params_sharding",
    "gnn_edge_sharding",
]


# --------------------------------------------------------------------------
# Generic helpers
# --------------------------------------------------------------------------

def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh, tree: Any):
    """Fully-replicated NamedSharding for every leaf of ``tree``."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes the global batch is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def corpus_shards(mesh: Mesh) -> tuple[tuple[str, ...], int]:
    """Row-sharding rule for serving corpora (DESIGN.md §4/§9).

    A corpus ``CodeStore`` shards its rows over *every* mesh axis —
    queries are replicated, so there is no reason to leave devices idle —
    and the Searcher's compiled plan merges shard-local top-k with one
    k-sized cross-shard pass.  Returns (axes, n_shards).
    """
    axes = tuple(mesh.axis_names)
    return axes, int(mesh.devices.size)


def sentinel_gids(gids, valid, *, shard, local_rows, n_total: int,
                  padded_rows: int):
    """Replace invalid slots' gids with globally-unique pad sentinels.

    A shard's tile-pad rows used to keep their arithmetic gid
    ``shard*rows_per + lrow`` — for ``lrow >= rows_per`` that value lands
    inside the NEXT shard's id range, so the only thing standing between
    a pad row and a real neighbor was the score mask.  Here every invalid
    slot instead gets

        ``n_total + shard * padded_rows + local_row``

    which is (a) ``>= n_total``, so it can never name a real row, and
    (b) unique across shards (each shard owns a disjoint
    ``padded_rows``-wide sentinel band), so even a dropped mask cannot
    alias two shards' pads onto one id.  Callers still NEG-mask the
    scores and map sentinels to ``-1`` at the plan boundary; the
    sentinel is the belt under that braces.

    ``shard`` and ``local_rows`` broadcast against ``gids`` (int32).
    """
    sent = (jnp.int32(n_total) + jnp.asarray(shard, jnp.int32) * padded_rows
            + jnp.asarray(local_rows, jnp.int32))
    return jnp.where(valid, jnp.asarray(gids, jnp.int32), sent)


def _axes_size(mesh: Mesh, axes: str | tuple[str, ...]) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _divisible(shape: tuple[int, ...], dim: int, mesh: Mesh, axes) -> bool:
    return dim < len(shape) and shape[dim] % max(_axes_size(mesh, axes), 1) == 0


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _spec_tree(mesh: Mesh, tree: Any, rule) -> Any:
    """tree of NamedSharding from rule(path_str, shape) -> P."""

    def leaf(path, x):
        shape = tuple(getattr(x, "shape", ()))
        spec = rule(_path_str(path), shape)
        # drop axes that do not divide — replicate those dims instead
        fixed = []
        for dim, entry in enumerate(spec):
            if entry is None:
                fixed.append(None)
            elif _divisible(shape, dim, mesh, entry):
                fixed.append(entry)
            else:
                fixed.append(None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(leaf, tree)


# --------------------------------------------------------------------------
# LM rules (megatron-style tensor parallelism over "model")
# --------------------------------------------------------------------------

# param-name suffixes whose *last* dim is column-sharded ("model")
_COL_KEYS = ("gate", "up", "wq", "wk", "wv", "w_gate", "router")
# suffixes whose *first matrix* dim is row-sharded (outputs get reduced)
_ROW_KEYS = ("down", "wo", "w_down")


def _lm_rule(path: str, shape: tuple[int, ...]) -> P:
    nd = len(shape)
    if nd <= 1:
        return P()                                     # norms, biases, scalars
    pad = [None] * (nd - 2)                            # leading vmapped block dims
    last2 = P(*pad, None, None)
    if "embed" in path and "table" in path:
        return P(*([None] * (nd - 2)), "model", None)  # vocab-sharded
    for key in _ROW_KEYS:
        if f"/{key}/" in path or path.endswith(f"/{key}/w"):
            return P(*pad, "model", None)
    for key in _COL_KEYS:
        if f"/{key}/" in path:
            return P(*pad, None, "model")
    return last2


def lm_params_sharding(mesh: Mesh, aparams: Any):
    """NamedSharding tree mirroring an LM abstract-params tree."""
    return _spec_tree(mesh, aparams, _lm_rule)


def lm_opt_sharding(mesh: Mesh, aopt: Any):
    """Optimizer state: mu/nu mirror the param layout; counters replicate."""
    return _spec_tree(mesh, aopt, _lm_rule)


def lm_zero_spec(path: str, leaf) -> P:
    """ZeRO accumulator spec: the param's "model" layout plus "data" on the
    first still-replicated dimension, so grad/optimizer accumulators live
    as 1/(data*model) slices instead of data-replicated copies."""
    shape = tuple(getattr(leaf, "shape", (1,) * getattr(leaf, "ndim", 0)))
    base = list(_lm_rule(path, shape))
    base += [None] * (len(shape) - len(base))
    for dim, entry in enumerate(base):
        if entry is None:
            base[dim] = "data"
            break
    return P(*base)


def lm_grad_specs(aparams: Any):
    """P-spec tree (not NamedSharding — used inside jit under a mesh
    context) for gradient accumulators, ZeRO layout."""
    return jax.tree_util.tree_map_with_path(
        lambda path, x: lm_zero_spec(_path_str(path), x), aparams
    )


def lm_cache_spec(mesh: Mesh, batch: int) -> NamedSharding:
    """KV cache [n_blocks, block_layers, B, S, Hkv, hd]: batch-sharded over
    the data axes when divisible, replicated otherwise (tiny decode B)."""
    dp = dp_axes(mesh)
    if dp and batch % _axes_size(mesh, dp) == 0:
        return NamedSharding(mesh, P(None, None, dp, None, None, None))
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# Recsys rules (DLRM hybrid parallelism)
# --------------------------------------------------------------------------

_TABLE_MIN_ROWS = 4096  # below this, tables replicate (the dry-run's pad rule)


def _recsys_rule_for(mesh: Mesh):
    shards = _axes_size(mesh, dp_axes(mesh) + ("model",)) if "model" in mesh.axis_names else 1

    def rule(path: str, shape: tuple[int, ...]) -> P:
        table_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)
        if (
            ("tables" in path or "codes" in path)
            and len(shape) == 2
            and shape[0] >= max(shards, _TABLE_MIN_ROWS)
        ):
            return P(table_axes, None)   # row-sharded embedding table
        return P(*([None] * len(shape)))  # towers/interactions replicate

    return rule


def recsys_params_sharding(mesh: Mesh, aparams: Any):
    return _spec_tree(mesh, aparams, _recsys_rule_for(mesh))


def recsys_opt_sharding(mesh: Mesh, aopt: Any):
    return _spec_tree(mesh, aopt, _recsys_rule_for(mesh))


# --------------------------------------------------------------------------
# GNN rules
# --------------------------------------------------------------------------

def gnn_params_sharding(mesh: Mesh, aparams: Any):
    """SchNet is tiny — replicate everything."""
    return replicated(mesh, aparams)


def gnn_edge_sharding(mesh: Mesh) -> NamedSharding:
    """Edge lists are padded to the full mesh size and sharded over it."""
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))
