# The cascade subsystem (DESIGN.md §14): multi-stage quantization
# pipelines — a head index pruning into budgeted refinement stages — and
# density-aware per-region Eq. 1 constants for the partitioned kinds.
from repro.cascade.index import CascadeIndex
from repro.cascade.regions import RegionQuant, density_scales

__all__ = ["CascadeIndex", "RegionQuant", "density_scales"]
