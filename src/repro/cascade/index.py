"""The N-stage scoring cascade (kind ``"cascade"``, DESIGN.md §14).

``cascade(pq16x4|lpq8|r32)`` generalizes the binary ``+rN`` rerank tail:
the *head* stage (any non-stream factory) prunes the corpus to a
per-stage candidate budget, every later stage re-scores the survivors at
higher precision through ``engine.refine_among`` (the same compiled body
as the rerank tail), and the final stage settles the top-k.  A cascade
whose final stage is ``r32`` at budget n is therefore bit-identical to
the exact fp32 search — the depth=n ``+rN`` equivalence, generalized.

Budgets are plan-time knobs, not build-time structure: one built cascade
serves any schedule.  ``SearchParams.budgets`` gives them explicitly
(``budgets[i]`` = candidates entering refinement stage ``i``); when
absent they derive geometrically from the rerank depth the Searcher
resolves (final budget = depth, each earlier stage 4x wider, clamped to
the corpus).  Monotonicity — each stage's fetch depth >= the next
stage's >= k — is validated at plan time with a pointed ``ValueError``:
a refinement stage can only prune candidates, never invent them.

Per-stage stats ride in ``SearchResult.stats["stages"]`` as a tuple of
``(label, candidates, bytes_read, bits)`` rows (tuples, not lists: stats
are jit-static aux data and must stay hashable).
"""

from __future__ import annotations

import io
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import engine
from repro.knn import base as B
from repro.knn import registry
from repro.knn.spec import (
    _QUANT_RE,
    _RERANK_RE,
    IndexSpec,
    QuantSpec,
    parse_factory,
    resolve_build_spec,
)


def _build_stage_store(frag: str, corpus) -> engine.CodeStore:
    """Materialize one refinement stage's store from its normalized
    fragment: ``r32`` keeps the corpus verbatim, ``r8`` / ``lpq<bits>``
    learn their own Eq. 1 constants (a refinement stage's accuracy must
    not inherit the head's aggressive clamp — same rule as the ``+rN``
    store)."""
    mr = _RERANK_RE.match(frag)
    if mr:
        if int(mr.group(1)) == 32:
            return engine.CodeStore.dense(corpus)
        return QuantSpec(bits=8).build_store(corpus)
    mq = _QUANT_RE.match(frag)
    assert mq is not None, f"unparseable cascade stage {frag!r}"
    return QuantSpec(
        bits=int(mq.group(1)),
        scheme=mq.group(2) or "gaussian",
        sigmas=float(mq.group(3)) if mq.group(3) else 1.0,
    ).build_store(corpus)


def _stage_label(frag: str, store: engine.CodeStore) -> str:
    return frag if store.bits < 32 else "r32"


@registry.register("cascade")
class CascadeIndex:
    """Head index + ordered refinement stores over one id space."""

    handles_rerank = True   # the plan owns every re-scoring pass

    def __init__(
        self,
        metric: str,
        head,
        stage_specs: tuple[str, ...],
        stage_stores: tuple[engine.CodeStore, ...],
    ):
        if not stage_stores:
            raise ValueError("a cascade needs at least one refinement stage")
        self.metric = metric
        self.head = head
        self.stage_specs = tuple(stage_specs)
        self.stage_stores = tuple(stage_stores)

    # -- protocol surface --------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.head.n)

    @property
    def d(self) -> Optional[int]:
        from repro.knn.searcher import _query_dim

        return _query_dim(self.head)

    @property
    def rerank_bits(self) -> int:
        """Precision of the final (settling) stage — its presence is what
        makes the Searcher thread a rerank depth into ``plan``."""
        return int(self.stage_stores[-1].bits)

    @property
    def stages(self) -> str:
        """The normalized '|'-joined stage list (head first)."""
        head_factory = getattr(self.head, "factory", None)
        if head_factory is None:
            head_factory = self._head_factory
        return "|".join((head_factory, *self.stage_specs))

    # -- construction ------------------------------------------------------
    @staticmethod
    def build(
        corpus,
        spec: IndexSpec | str | None = None,
        *,
        key: jax.Array | None = None,
        metric: str = "ip",
        **overrides,
    ) -> "CascadeIndex":
        spec, params = resolve_build_spec("cascade", spec, metric=metric)
        stages = str(params["stages"]).split("|")
        head_spec = parse_factory(stages[0], metric=spec.metric)
        # head build overrides (kmeans_iters, ef_construction...) pass
        # through; 'stages' itself is the cascade's own parameter
        head_overrides = {k: v for k, v in overrides.items() if k != "stages"}
        head = registry.make_index(head_spec, corpus, key=key, **head_overrides)
        idx = CascadeIndex(
            metric=spec.metric,
            head=head,
            stage_specs=tuple(stages[1:]),
            stage_stores=tuple(
                _build_stage_store(f, corpus) for f in stages[1:]
            ),
        )
        idx._head_factory = head_spec.to_factory()
        return idx

    # -- budgets -----------------------------------------------------------
    def resolve_budgets(
        self,
        k: int,
        explicit: Optional[tuple[int, ...]],
        rerank_depth: Optional[int],
    ) -> tuple[int, ...]:
        """Per-stage fetch depths: ``out[i]`` candidates enter refinement
        stage ``i`` (``out[0]`` is what the head returns); the final stage
        emits k.  Explicit budgets are validated for monotonicity; derived
        budgets are monotone by construction (final = resolved rerank
        depth, each earlier stage 4x wider, clamped to the corpus)."""
        n_stages = len(self.stage_stores)
        n, cap = self.n, max(self.n, k)
        if explicit is not None:
            if len(explicit) != n_stages:
                raise ValueError(
                    f"cascade has {n_stages} refinement stage(s) "
                    f"({'|'.join(self.stage_specs)}) but SearchParams.budgets "
                    f"has {len(explicit)} entries: {explicit!r} — one fetch "
                    "depth per refinement stage"
                )
            seq = tuple(int(b) for b in explicit) + (k,)
            for i in range(len(seq) - 1):
                if seq[i] < seq[i + 1]:
                    raise ValueError(
                        f"cascade budgets must be non-increasing and >= k: "
                        f"stage {i} fetches {seq[i]} candidates but the next "
                        f"stage needs {seq[i + 1]} (budgets={tuple(explicit)}, "
                        f"k={k}) — a refinement stage can only prune "
                        "candidates, never invent them"
                    )
            return tuple(min(b, cap) for b in seq[:-1])
        from repro.knn.searcher import DEFAULT_RERANK_DEPTH

        last = (max(k, min(int(rerank_depth), cap))
                if rerank_depth is not None else DEFAULT_RERANK_DEPTH(k, n))
        out = [last]
        for _ in range(n_stages - 1):
            out.append(min(cap, out[-1] * 4))
        return tuple(reversed(out))

    # -- query -------------------------------------------------------------
    def placement(self, n_shards: int):
        """A cascade shards wherever its head shards — refinement stages
        gather by id against replicated stage stores."""
        from repro.dist import placement as dplacement

        return dplacement.for_index(self.head, n_shards)

    def plan(
        self,
        k: int,
        params: Optional[B.SearchParams] = None,
        *,
        mesh=None,
        placement=None,
        rerank_depth: Optional[int] = None,
    ):
        """Freeze budgets + per-stage runners into one pure runner: the
        head prunes, each stage refines via ``engine.refine_among``, and
        the Searcher compiles the whole chain per batch bucket."""
        sp = (params or B.SearchParams()).validate()
        budgets = self.resolve_budgets(k, sp.budgets, rerank_depth)
        # filter (DESIGN.md §16): the head prunes under the filter (it
        # receives sp verbatim), and every refinement stage re-applies
        # the bitmap on its candidate slots — a stage can only prune, so
        # no disallowed row can re-enter once the head dropped it, but
        # the re-apply keeps the invariant independent of head kind
        fmask, fstats = None, {}
        if sp.filter is not None:
            fmask = jnp.asarray(sp.filter.aligned(self.n))
            fstats = {"filter_selectivity":
                      round(sp.filter.selectivity, 6)}
        head_runner = self.head.plan(
            budgets[0], sp, mesh=mesh, placement=placement
        )
        outs = tuple(budgets[1:]) + (k,)
        labels = tuple(
            _stage_label(f, st)
            for f, st in zip(self.stage_specs, self.stage_stores)
        )

        def run(queries: jax.Array) -> B.SearchResult:
            q = jnp.asarray(queries, jnp.float32)
            res = head_runner(q)
            stats = dict(res.stats)
            s, ids = res.scores, res.ids
            total_bytes = int(stats.get("bytes_read", 0))
            stage_rows = [(
                f"head:{self.head.kind}", int(budgets[0]), total_bytes,
                int(stats.get("bits", 32)),
            )]
            for store, out_k, label in zip(self.stage_stores, outs, labels):
                s, ids, sst = engine.refine_among(
                    q, store, ids, out_k, self.metric, mask=fmask
                )
                total_bytes += sst["bytes_read"]
                stage_rows.append(
                    (label, sst["candidates"], sst["bytes_read"], sst["bits"])
                )
            stats.update(
                kind="cascade",
                bytes_read=total_bytes,
                stages=tuple(stage_rows),
                cascade_stages=1 + len(self.stage_stores),
                reranked=int(budgets[-1]),
                rerank_bits=self.rerank_bits,
                **fstats,
            )
            return B.SearchResult(s, ids, stats)

        return run

    def searcher(self, k: int, params: Optional[B.SearchParams] = None, **kw):
        from repro.knn.searcher import Searcher

        return Searcher(self, k, params, **kw)

    def search(
        self,
        queries,
        k: int,
        params: Optional[B.SearchParams] = None,
    ) -> B.SearchResult:
        from repro.knn import searcher as S

        return S.one_shot(self, queries, k, params)

    # -- accounting --------------------------------------------------------
    def memory_bytes(self) -> int:
        return int(self.head.memory_bytes()) + sum(
            st.memory_bytes() for st in self.stage_stores
        )

    # -- disk round-trip ---------------------------------------------------
    def save(self, path) -> None:
        buf = io.BytesIO()
        self.head.save(buf)
        arrays = {"cs_blob": np.frombuffer(buf.getvalue(), np.uint8)}
        meta = {
            "kind": "cascade",
            "metric": self.metric,
            "n": self.n,
            "stages": self.stages,
            "head_kind": self.head.kind,
        }
        for idx, st in enumerate(self.stage_stores):
            a, m = st.state(prefix=f"cs{idx}_")
            arrays.update(a)
            meta.update(m)
        B.save_state(path, arrays, meta)

    @staticmethod
    def load(path) -> "CascadeIndex":
        arrays, meta = B.load_state(path)
        blob = io.BytesIO(np.asarray(arrays["cs_blob"]).tobytes())
        head = registry.get_impl(meta["head_kind"]).load(blob)
        stages = str(meta["stages"]).split("|")
        idx = CascadeIndex(
            metric=meta["metric"],
            head=head,
            stage_specs=tuple(stages[1:]),
            stage_stores=tuple(
                engine.CodeStore.from_state(arrays, meta, prefix=f"cs{i}_")
                for i in range(len(stages) - 1)
            ),
        )
        idx._head_factory = stages[0]
        return idx
