"""Density-aware per-region Eq. 1 constants (DESIGN.md §14).

The paper fits ONE set of Eq. 1 constants per corpus; the stream
subsystem already relaxed that to one set per sealed segment.  This
module lifts the idea into the static kinds: one constant set per
*region* — an IVF list or a graph neighborhood — so each region's
quantizer matches its own local distribution (AQR-HNSW's density-aware
quantization, PAPERS.md arXiv 2602.21600).

Density-scaled clipping: the clamp width (in sigma units) of region r is

    sigmas_r = base_sigmas * clip((mean_count / count_r) ** 0.25, 0.5, 2.0)

— dense regions concentrate, so fewer sigmas capture their mass and the
LSB shrinks (finer resolution where points crowd); sparse regions spread
and get a wider clamp so their tails are not all saturated.  The fourth
root keeps the scaling gentle; the [0.5, 2.0] clip bounds the worst case.
Only the Gaussian-family schemes consume sigmas; range schemes
(absmax/minmax) ignore it, exactly as they do globally.

Codes quantized under different regions' constants live in different
integer spaces, so regional scoring dequantizes per row
(``engine.topk_among_regional``) instead of comparing raw codes.  When no
regions were requested the global single-constant path is untouched —
the graceful-degradation contract.

Persistence reuses the stream subsystem's DimStats<->npz representation
(``core.stats.stats_arrays``), stacked one row per region, plus the
[R, d] constant stacks and the [N] assignment — all plain npz fragments
under a caller-chosen prefix, like ``CodeStore.state``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Qz
from repro.core import stats as St

#: density-scale bounds: sigmas_r / base_sigmas stays inside these
DENSITY_SCALE_RANGE = (0.5, 2.0)
DENSITY_SCALE_POWER = 0.25


def density_scales(counts: np.ndarray) -> np.ndarray:
    """Per-region clamp-width multipliers from region populations."""
    counts = np.asarray(counts, np.float64)
    occupied = counts[counts > 0]
    mean_count = float(occupied.mean()) if occupied.size else 1.0
    lo, hi = DENSITY_SCALE_RANGE
    scales = (mean_count / np.maximum(counts, 1.0)) ** DENSITY_SCALE_POWER
    return np.clip(scales, lo, hi).astype(np.float32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class RegionQuant:
    """Per-region Eq. 1 constants + the row -> region assignment.

    assign [N] i32; lo/hi/zero [R, d] f32 constant stacks; sigmas [R]
    the density-scaled clamp widths actually used; stats the stacked
    per-region calibration ``DimStats`` (count [R], moments [R, d]) kept
    for drift reporting.
    """

    assign: jax.Array
    lo: jax.Array
    hi: jax.Array
    zero: jax.Array
    sigmas: jax.Array
    stats: St.DimStats
    bits: int = dataclasses.field(metadata=dict(static=True))
    scheme: str = dataclasses.field(metadata=dict(static=True))

    # -- accounting --------------------------------------------------------
    @property
    def n_regions(self) -> int:
        return int(self.lo.shape[0])

    @property
    def scale(self) -> jax.Array:
        """[R, d] LSB sizes — what the regional scorer gathers per row."""
        return (self.hi - self.lo) / (2.0 ** self.bits)

    def memory_bytes(self) -> int:
        return int(self.assign.nbytes) + 3 * int(self.lo.size) * 4

    # -- fit / encode ------------------------------------------------------
    @staticmethod
    def fit(
        corpus,
        assign,
        n_regions: int,
        *,
        bits: int = 8,
        scheme: str = "gaussian",
        sigmas: float = 1.0,
    ) -> "RegionQuant":
        """Fit one Eq. 1 constant set per region, density-scaled.

        ``assign`` [N] maps each corpus row to its region (IVF list id /
        nearest graph seed).  Empty regions get the empty-stats constants
        (never consulted: no row is assigned to them).
        """
        corpus = np.asarray(corpus, np.float32)
        assign = np.asarray(assign, np.int32)
        counts = np.bincount(assign, minlength=n_regions)[:n_regions]
        scales = density_scales(counts)
        per_stats, per_params = [], []
        for r in range(n_regions):
            rows = corpus[assign == r]
            s = St.corpus_stats(rows)
            per_stats.append(s)
            per_params.append(
                Qz.params_from_stats(
                    s, bits=bits, scheme=scheme,
                    sigmas=float(sigmas * scales[r]),
                )
            )
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_stats
        )
        return RegionQuant(
            assign=jnp.asarray(assign),
            lo=jnp.stack([p.lo for p in per_params]),
            hi=jnp.stack([p.hi for p in per_params]),
            zero=jnp.stack([p.zero for p in per_params]),
            sigmas=jnp.asarray(sigmas * scales),
            stats=stacked,
            bits=int(bits),
            scheme=str(scheme),
        )

    def region_params(self, r: int) -> Qz.QuantParams:
        """The r-th region's constants as an ordinary ``QuantParams``."""
        return Qz.QuantParams(
            lo=self.lo[r], hi=self.hi[r], zero=self.zero[r],
            bits=self.bits, scheme=self.scheme,
        )

    def encode(self, corpus) -> jax.Array:
        """Eq. 1 per row under the row's own region constants."""
        x = jnp.asarray(corpus, jnp.float32)
        lo, hi, zero = self.lo[self.assign], self.hi[self.assign], self.zero[self.assign]
        span = jnp.maximum(hi - lo, 1e-12)
        q = jnp.round((2.0 ** self.bits) * (x - zero) / span)
        qmin, qmax = -(2 ** (self.bits - 1)), 2 ** (self.bits - 1) - 1
        return jnp.clip(q, qmin, qmax).astype(jnp.int8)

    def dequant(self, codes: jax.Array, rows: jax.Array) -> jax.Array:
        """Midpoint reconstruction of ``codes`` gathered at row ids
        ``rows`` — each row through its own region's inverse map."""
        reg = self.assign[rows]
        return codes.astype(jnp.float32) * self.scale[reg] + self.zero[reg]

    # -- drift -------------------------------------------------------------
    def region_stats(self, r: int) -> St.DimStats:
        """Unstack the r-th region's calibration stats."""
        return jax.tree_util.tree_map(lambda x: x[r], self.stats)

    def drift_report(self, live_corpus, live_assign) -> np.ndarray:
        """Per-region calibration drift of a live corpus vs the fitted
        constants: ``[R]`` floats from ``stats.calibration_drift`` (+inf
        where either side is empty — stale by definition), the per-region
        generalization of the stream subsystem's per-segment drift."""
        live_corpus = np.asarray(live_corpus, np.float32)
        live_assign = np.asarray(live_assign, np.int32)
        out = np.zeros(self.n_regions, np.float64)
        for r in range(self.n_regions):
            live = St.corpus_stats(live_corpus[live_assign == r])
            out[r] = St.calibration_drift(self.region_stats(r), live)
        return out

    # -- disk round-trip fragments ----------------------------------------
    def state(self, prefix: str = "rg_") -> tuple[dict[str, Any], dict[str, Any]]:
        """(arrays, meta) npz fragments, ``CodeStore.state``-style."""
        arrays = {
            f"{prefix}assign": np.asarray(self.assign),
            f"{prefix}lo": np.asarray(self.lo),
            f"{prefix}hi": np.asarray(self.hi),
            f"{prefix}zero": np.asarray(self.zero),
            f"{prefix}sigmas": np.asarray(self.sigmas),
        }
        arrays.update(St.stats_arrays(f"{prefix}st_", self.stats))
        meta = {f"{prefix}regions": {
            "n_regions": self.n_regions,
            "bits": self.bits,
            "scheme": self.scheme,
        }}
        return arrays, meta

    @staticmethod
    def from_state(arrays, meta, prefix: str = "rg_") -> "RegionQuant":
        rm = meta[f"{prefix}regions"]
        return RegionQuant(
            assign=jnp.asarray(arrays[f"{prefix}assign"]),
            lo=jnp.asarray(arrays[f"{prefix}lo"]),
            hi=jnp.asarray(arrays[f"{prefix}hi"]),
            zero=jnp.asarray(arrays[f"{prefix}zero"]),
            sigmas=jnp.asarray(arrays[f"{prefix}sigmas"]),
            stats=St.stats_from_arrays(f"{prefix}st_", arrays),
            bits=int(rm["bits"]),
            scheme=str(rm["scheme"]),
        )
