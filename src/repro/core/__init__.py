# The paper's primary contribution: the low-precision quantization family
# (Q, phi) — data-driven clamped-linear scalar quantization (Eq. 1) plus
# integer-domain distance functions, with Definition-2 order-preservation
# validators. Sibling subpackages provide the substrates (knn, data, models,
# train, dist, kernels, launch).
from repro.core.stats import (
    DimStats,
    StreamingStats,
    corpus_stats,
    distributed_stats,
    merge_stats,
)
from repro.core.quant import (
    QuantParams,
    Scheme,
    dequantize,
    learn_params,
    params_from_stats,
    quantization_error,
    quantize,
    quantize_corpus,
)
from repro.core.distances import (
    angular_scores,
    ip_scores,
    l2_scores,
    pairwise_distance,
    qangular_scores,
    qip_scores,
    ql2_scores,
    scores,
)
from repro.core.preserve import knn_recall, order_agreement, recall_at_k

__all__ = [
    "DimStats",
    "StreamingStats",
    "corpus_stats",
    "distributed_stats",
    "merge_stats",
    "QuantParams",
    "Scheme",
    "dequantize",
    "learn_params",
    "params_from_stats",
    "quantization_error",
    "quantize",
    "quantize_corpus",
    "angular_scores",
    "ip_scores",
    "l2_scores",
    "pairwise_distance",
    "qangular_scores",
    "qip_scores",
    "ql2_scores",
    "scores",
    "knn_recall",
    "order_agreement",
    "recall_at_k",
]
