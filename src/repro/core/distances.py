"""Distance functions phi — full-precision references and their integer
counterparts (paper §3.1: phi : Z^d x Z^d -> Z).

The quantized variants take *integer codes* (int8/int16) and accumulate in
int32 via ``lax.dot_general(..., preferred_element_type=int32)``, which on
TPU lowers to the MXU's native int8 x int8 -> int32 path (2x bf16 peak on
v5e) and on CPU to VNNI-style integer dot products.  This is the
implementation-level substitution the paper makes inside HNSW/FAISS/NGT.

Convention: all ``*_scores`` functions are batched [Q, d] x [N, d] -> [Q, N]
and return *larger-is-closer* scores (inner product; negated L2) so that a
single top-k applies to every metric.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Metric = str  # "ip" | "l2" | "angular"

_VALID_METRICS = ("ip", "l2", "angular")


# --------------------------------------------------------------------------
# Full-precision references
# --------------------------------------------------------------------------

def ip_scores(q: jax.Array, x: jax.Array) -> jax.Array:
    """Maximum-inner-product scores, [Q, N] f32."""
    return jnp.dot(q.astype(jnp.float32), x.astype(jnp.float32).T)


def l2_scores(q: jax.Array, x: jax.Array) -> jax.Array:
    """Negated squared L2 (larger = closer), [Q, N] f32."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qq = jnp.sum(q * q, axis=-1, keepdims=True)          # [Q, 1]
    xx = jnp.sum(x * x, axis=-1)[None, :]                # [1, N]
    return -(qq + xx - 2.0 * jnp.dot(q, x.T))


def angular_scores(q: jax.Array, x: jax.Array) -> jax.Array:
    """Cosine similarity, [Q, N] f32."""
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    return jnp.dot(qn, xn.T)


# --------------------------------------------------------------------------
# Quantized (integer-domain) counterparts
# --------------------------------------------------------------------------

def _int_matmul(a: jax.Array, b_t: jax.Array) -> jax.Array:
    """[Q, d] int  x  [N, d] int  ->  [Q, N] int32 via one dot_general.

    ``preferred_element_type=int32`` is what turns this into the MXU's
    int8 path instead of a float fallback.
    """
    return jax.lax.dot_general(
        a,
        b_t,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def qip_scores(qc: jax.Array, xc: jax.Array) -> jax.Array:
    """phi_IP over codes: int32 inner product, [Q, N].

    Order-equivalence: with shared constants (k, s) per dim,
    IP(Q(a),Q(q)) ~= (IP(a,q) - k·sum(a) - k·sum(q) + d·k^2) / s^2, a
    positive-affine map of IP(a,q) for fixed q when k ~ 0 (narrow-band,
    zero-centred corpora — Fig. 1), hence Definition-2 preservation up to
    rounding/clamping.
    """
    return _int_matmul(qc, xc)


def ql2_scores(qc: jax.Array, xc: jax.Array) -> jax.Array:
    """Negated squared L2 over codes, int32 [Q, N].

    ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a·b, all in int32.  d * (2^{B} - 1)^2
    must stay below 2^31: fine for d <= 32k at B=8.
    """
    qi = qc.astype(jnp.int32)
    xi = xc.astype(jnp.int32)
    qq = jnp.sum(qi * qi, axis=-1, keepdims=True)
    xx = jnp.sum(xi * xi, axis=-1)[None, :]
    return -(qq + xx - 2 * _int_matmul(qc, xc))


def qangular_scores(qc: jax.Array, xc: jax.Array) -> jax.Array:
    """Cosine over codes: int32 dot, f32 norm rescale, [Q, N] f32.

    The integer part (the O(Q·N·d) work) runs on the int8 MXU path; the
    O(Q+N) norms are f32.
    """
    dot = _int_matmul(qc, xc).astype(jnp.float32)
    qn = jnp.sqrt(jnp.sum(qc.astype(jnp.float32) ** 2, axis=-1, keepdims=True))
    xn = jnp.sqrt(jnp.sum(xc.astype(jnp.float32) ** 2, axis=-1))[None, :]
    return dot / jnp.maximum(qn * xn, 1e-12)


# --------------------------------------------------------------------------
# Dispatch
# --------------------------------------------------------------------------

_FP: dict[str, Callable] = {"ip": ip_scores, "l2": l2_scores, "angular": angular_scores}
_Q: dict[str, Callable] = {"ip": qip_scores, "l2": ql2_scores, "angular": qangular_scores}


def scores(q: jax.Array, x: jax.Array, metric: Metric, quantized: bool = False) -> jax.Array:
    """Batched larger-is-closer scores for any supported metric."""
    if metric not in _VALID_METRICS:
        raise ValueError(f"metric must be one of {_VALID_METRICS}, got {metric!r}")
    fn = (_Q if quantized else _FP)[metric]
    return fn(q, x)


# --------------------------------------------------------------------------
# Per-query candidate scoring (q [Q, d] against gathered rows [Q, W, d])
# --------------------------------------------------------------------------

def _bmm(q: jax.Array, rows: jax.Array) -> jax.Array:
    """f32 batched row dot, [Q, W].  One einsum rather than a vmapped
    per-query matmul: XLA lowers the einsum identically inside and
    outside ``shard_map``, which is what makes sharded plans bit-match
    their unsharded twins (a vmapped [1, d] x [d, W] dot picks a
    different f32 accumulation order under ``shard_map``)."""
    return jnp.einsum(
        "qd,qwd->qw", q.astype(jnp.float32), rows.astype(jnp.float32)
    )


def _int_bmm(q: jax.Array, rows: jax.Array) -> jax.Array:
    """int batched row dot with int32 accumulation (exact), [Q, W]."""
    return jax.lax.dot_general(
        q,
        rows,
        dimension_numbers=(((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )


def scores_among(
    q: jax.Array, rows: jax.Array, metric: Metric, quantized: bool = False
) -> jax.Array:
    """Per-query candidate scores: q [Q, d] vs rows [Q, W, d] -> [Q, W].

    The candidate-list twin of :func:`scores` — same metric semantics,
    but each query scores its *own* gathered row set.  All reductions
    are batched (einsum / dot_general), never per-query vmapped dots,
    so the lowering is stable across jit and ``shard_map`` contexts
    (DESIGN.md §15 bit-parity).
    """
    if metric not in _VALID_METRICS:
        raise ValueError(f"metric must be one of {_VALID_METRICS}, got {metric!r}")
    if quantized:
        if metric == "ip":
            return _int_bmm(q, rows)
        if metric == "l2":
            qi = q.astype(jnp.int32)
            xi = rows.astype(jnp.int32)
            qq = jnp.sum(qi * qi, axis=-1, keepdims=True)     # [Q, 1]
            xx = jnp.sum(xi * xi, axis=-1)                    # [Q, W]
            return -(qq + xx - 2 * _int_bmm(q, rows))
        dot = _int_bmm(q, rows).astype(jnp.float32)
        qn = jnp.sqrt(jnp.sum(q.astype(jnp.float32) ** 2, axis=-1,
                              keepdims=True))
        xn = jnp.sqrt(jnp.sum(rows.astype(jnp.float32) ** 2, axis=-1))
        return dot / jnp.maximum(qn * xn, 1e-12)
    qf = q.astype(jnp.float32)
    xf = rows.astype(jnp.float32)
    if metric == "ip":
        return _bmm(qf, xf)
    if metric == "l2":
        qq = jnp.sum(qf * qf, axis=-1, keepdims=True)
        xx = jnp.sum(xf * xf, axis=-1)
        return -(qq + xx - 2.0 * _bmm(qf, xf))
    qn = qf / jnp.maximum(jnp.linalg.norm(qf, axis=-1, keepdims=True), 1e-12)
    xn = xf / jnp.maximum(jnp.linalg.norm(xf, axis=-1, keepdims=True), 1e-12)
    return _bmm(qn, xn)


def pairwise_distance(a: jax.Array, b: jax.Array, metric: Metric, quantized: bool = False) -> jax.Array:
    """Single-pair convenience wrapper (used by graph-walk code paths)."""
    return scores(a[None, :], b[None, :], metric, quantized)[0, 0]
