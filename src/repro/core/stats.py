"""Per-dimension corpus statistics for data-driven quantization (paper §3.2).

The paper fits a per-dimension Gaussian N(mu^i, sigma^i) by maximum
likelihood over the corpus I:

    theta = argmax_theta  prod_{x in I} prod_i P(x^i ; theta)

For a Gaussian this is exactly the per-dimension sample mean / std.  We
provide three collectors:

  * ``corpus_stats``      — one-shot over an in-memory [N, d] array.
  * ``StreamingStats``    — Chan/Welford parallel-merge over batches, for
                            corpora that do not fit in memory (the paper's
                            PRODUCT60M regime).
  * ``distributed_stats`` — the same moments reduced across a mesh axis with
                            ``jax.lax.psum`` (used under ``shard_map`` when
                            the corpus is row-sharded over devices).

All return a :class:`DimStats` with per-dimension mean / std / absmax /
min / max, which downstream ``quant.learn_params`` turns into the Eq. 1
normalizing constants.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DimStats:
    """Per-dimension first/second moments + range of a corpus."""

    count: jax.Array   # scalar f64-ish (f32) number of rows seen
    mean: jax.Array    # [d]
    m2: jax.Array      # [d] sum of squared deviations (Welford)
    amax: jax.Array    # [d] max |x|
    vmin: jax.Array    # [d]
    vmax: jax.Array    # [d]

    @property
    def var(self) -> jax.Array:
        return self.m2 / jnp.maximum(self.count, 1.0)

    @property
    def std(self) -> jax.Array:
        return jnp.sqrt(self.var)

    def uniform(self) -> "DimStats":
        """Collapse to a single (mu, sigma) across dims (paper §4.1).

        Interdimensional uniformity: for normalized, low-variance corpora
        the paper assumes one mean/std across all dimensions.  The pooled
        variance must include the between-dimension spread of means.
        """
        d = self.mean.shape[0]
        pooled_mean = jnp.mean(self.mean)
        # E[x^2] pooled across dims, then recentre on the pooled mean.
        ex2 = self.m2 / jnp.maximum(self.count, 1.0) + self.mean**2
        pooled_var = jnp.mean(ex2) - pooled_mean**2
        pooled_var = jnp.maximum(pooled_var, 0.0)
        full = jnp.full((d,), 1.0, self.mean.dtype)
        return DimStats(
            count=self.count,
            mean=full * pooled_mean,
            m2=full * pooled_var * jnp.maximum(self.count, 1.0),
            amax=full * jnp.max(self.amax),
            vmin=full * jnp.min(self.vmin),
            vmax=full * jnp.max(self.vmax),
        )


def empty_stats(d: int, dtype=jnp.float32) -> DimStats:
    """The identity element of ``merge_stats``: zero rows seen."""
    zero = jnp.zeros((d,), dtype)
    return DimStats(
        count=jnp.zeros((), dtype),
        mean=zero,
        m2=zero,
        amax=zero,
        vmin=jnp.full((d,), jnp.inf, dtype),
        vmax=jnp.full((d,), -jnp.inf, dtype),
    )


def corpus_stats(x: jax.Array) -> DimStats:
    """One-shot per-dimension stats of a [N, d] corpus.

    An empty batch ([0, d]) returns ``empty_stats`` — NOT the NaN mean
    (and zero-size-reduction error) a naive ``jnp.mean``/``jnp.max``
    would produce, which used to poison every later ``merge_stats``
    (NaN * 0 = NaN in the cross-term).
    """
    x = x.astype(jnp.float32)
    if x.shape[0] == 0:
        return empty_stats(x.shape[1], x.dtype)
    n = jnp.asarray(x.shape[0], jnp.float32)
    mean = jnp.mean(x, axis=0)
    m2 = jnp.sum((x - mean) ** 2, axis=0)
    return DimStats(
        count=n,
        mean=mean,
        m2=m2,
        amax=jnp.max(jnp.abs(x), axis=0),
        vmin=jnp.min(x, axis=0),
        vmax=jnp.max(x, axis=0),
    )


def merge_stats(a: DimStats, b: DimStats) -> DimStats:
    """Chan et al. parallel merge of two partial moment sets.

    Zero-count safe: merging an empty/fresh collector (count == 0) is the
    identity — the empty side's placeholder moments are masked out of the
    mean and the cross-term, so they can never surface as NaN even if a
    caller hands in a zero-count ``DimStats`` with garbage moments.
    """
    n = a.count + b.count
    safe_n = jnp.maximum(n, 1.0)
    a_mean = jnp.where(a.count > 0, a.mean, 0.0)
    b_mean = jnp.where(b.count > 0, b.mean, 0.0)
    delta = b_mean - a_mean
    both = (a.count > 0) & (b.count > 0)
    mean = jnp.where(
        both,
        a_mean + delta * (b.count / safe_n),
        jnp.where(b.count > 0, b_mean, a_mean),
    )
    m2 = (
        jnp.where(a.count > 0, a.m2, 0.0)
        + jnp.where(b.count > 0, b.m2, 0.0)
        + jnp.where(both, delta**2 * (a.count * b.count / safe_n), 0.0)
    )
    return DimStats(
        count=n,
        mean=mean,
        m2=m2,
        amax=jnp.maximum(a.amax, b.amax),
        vmin=jnp.minimum(a.vmin, b.vmin),
        vmax=jnp.maximum(a.vmax, b.vmax),
    )


def calibration_drift(calib: DimStats, live: DimStats) -> float:
    """How far a quantizer's calibration has drifted from the live corpus.

    Symmetric-ish, scale-aware scalar: mean over dimensions of the
    mean shift in live-sigma units plus the log std ratio —

        drift = mean_i ( |mu_c - mu_l| / sigma_l  +  |log(sigma_c / sigma_l)| )

    0 when the distributions match; ~s after an s-sigma mean shift.  The
    stream compactor re-quantizes a segment when this exceeds its
    threshold (DESIGN.md §10).  Returns +inf when either side is empty
    (an uncalibrated quantizer is maximally stale).
    """
    if float(calib.count) == 0.0 or float(live.count) == 0.0:
        return float("inf")
    sd_l = jnp.maximum(live.std, 1e-12)
    sd_c = jnp.maximum(calib.std, 1e-12)
    dmu = jnp.abs(calib.mean - live.mean) / sd_l
    dsd = jnp.abs(jnp.log(sd_c / sd_l))
    return float(jnp.mean(dmu + dsd))


# -- DimStats <-> npz fragments --------------------------------------------
# One representation for every persisted constant set: the stream
# subsystem's per-segment calibration stats and the cascade subsystem's
# per-region stats both round-trip through these.  Stacked variants
# ([R] count, [R, d] moments — one row per region) serialize identically
# because the helpers are shape-agnostic field maps.

STATS_FIELDS = ("count", "mean", "m2", "amax", "vmin", "vmax")


def stats_arrays(prefix: str, s: DimStats) -> dict:
    """DimStats -> npz-fragment dict keyed ``{prefix}{field}``."""
    import numpy as np

    return {f"{prefix}{f}": np.asarray(getattr(s, f)) for f in STATS_FIELDS}


def stats_from_arrays(prefix: str, arrays) -> DimStats:
    """Inverse of :func:`stats_arrays`."""
    return DimStats(
        **{f: jnp.asarray(arrays[f"{prefix}{f}"]) for f in STATS_FIELDS}
    )


class StreamingStats:
    """Accumulate :class:`DimStats` over a stream of [n_i, d] batches.

    Used by the data pipeline to fit quantization constants on corpora
    larger than memory (one pass, O(d) state).  ``update`` is jit-friendly;
    the object itself is a thin host-side holder.
    """

    def __init__(self, d: int, dtype=jnp.float32):
        self._s = empty_stats(d, dtype)

    def update(self, batch: jax.Array) -> "StreamingStats":
        self._s = merge_stats(self._s, corpus_stats(batch))
        return self

    def merge(self, other: "StreamingStats | DimStats") -> "StreamingStats":
        """Fold another collector (or raw ``DimStats``) into this one.

        Merging a fresh/empty collector is the identity (zero-count
        guard in ``merge_stats``) — it cannot NaN the moments.
        """
        s = other.stats if isinstance(other, StreamingStats) else other
        self._s = merge_stats(self._s, s)
        return self

    @property
    def stats(self) -> DimStats:
        return self._s


@partial(jax.jit, static_argnames=("axis_name",))
def _psum_stats(local: DimStats, axis_name: str) -> DimStats:
    # Moment-merge across an axis: psum of count / weighted mean / m2 with
    # the cross-shard correction term, max/min for ranges.
    n = jax.lax.psum(local.count, axis_name)
    safe_n = jnp.maximum(n, 1.0)
    gmean = jax.lax.psum(local.mean * local.count, axis_name) / safe_n
    # m2_global = sum_i [m2_i + n_i * (mean_i - gmean)^2]
    m2 = jax.lax.psum(local.m2 + local.count * (local.mean - gmean) ** 2, axis_name)
    return DimStats(
        count=n,
        mean=gmean,
        m2=m2,
        amax=jax.lax.pmax(local.amax, axis_name),
        vmin=jax.lax.pmin(local.vmin, axis_name),
        vmax=jax.lax.pmax(local.vmax, axis_name),
    )


def distributed_stats(local_shard: jax.Array, axis_name: str) -> DimStats:
    """Per-dim stats of a row-sharded corpus, reduced over ``axis_name``.

    Call inside ``shard_map``: each device computes moments of its local
    [n_local, d] shard, then the shards are merged with a single psum —
    O(d) bytes on the wire instead of O(N·d).
    """
    return _psum_stats(corpus_stats(local_shard), axis_name)
