"""The paper's quantization family (Q, phi) — §3 of the paper.

Eq. 1 (clamped linear quantization of dimension i at bit-width B):

    Q(x^i) = round( 2^B * (x^i - k^i) / (S_e^i - S_b^i) )   if x^i in [S_b^i, S_e^i]
           = -2^(B-1)                                        if x^i <  S_b^i
           = +2^(B-1)                                        if x^i >  S_e^i

with data-driven constants k^i = mu^i, S_b^i = mu^i - sigma^i,
S_e^i = mu^i + sigma^i fit per dimension (§3.2), or their simplified forms:
a single (mu, sigma) shared across dimensions (§4.1, interdimensional
uniformity) and an abs-max range (§4.2, intradimensional uniformity).

Storage note: with the paper's constants, Q(S_e) = +2^(B-1), which does not
fit a B-bit signed integer (max 2^(B-1)-1).  We keep Eq. 1 verbatim and clip
the stored code to the representable range [-2^(B-1), 2^(B-1)-1]; the single
saturated code at the top of the range is part of the clamp semantics and
affects only points already outside +-sigma.  This is recorded in DESIGN.md.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.stats import DimStats, corpus_stats


class Scheme(str, enum.Enum):
    """Which normalizing constants to use for Eq. 1.

    Geometry note: per-dimension spans (GAUSSIAN/ABSMAX/MINMAX) rescale
    dimensions independently — fine when dims are iso-distributed (the
    paper's Fig-1 corpora, §4.1), but a *reweighted* metric otherwise.
    For corpora with unequal per-dim spreads under L2/angular, use a
    GLOBAL_* scheme (one span for every dim = a single affine map, which
    preserves distance ordering exactly up to rounding: the paper's §4.2
    "absolute maximum observed" applied globally).
    """

    GAUSSIAN = "gaussian"            # §3.2: per-dim mu +- sigmas*sigma
    UNIFORM_GAUSSIAN = "uniform"     # §4.1: single (mu, sigma) for all dims
    ABSMAX = "absmax"                # §4.2: per-dim [-amax, +amax], k = 0
    MINMAX = "minmax"                # engineering variant: [vmin, vmax]
    GLOBAL_ABSMAX = "global_absmax"  # one symmetric span for all dims
    GLOBAL_MINMAX = "global_minmax"  # one [min, max] span for all dims


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Normalizing constants of Eq. 1 for one corpus.

    lo = S_b, hi = S_e, zero = k  — all shape [d] f32.
    ``bits`` is B.  ``scale`` is the derived LSB size (S_e-S_b)/2^B.
    """

    lo: jax.Array
    hi: jax.Array
    zero: jax.Array
    bits: int = dataclasses.field(metadata=dict(static=True))
    scheme: str = dataclasses.field(metadata=dict(static=True))

    @property
    def scale(self) -> jax.Array:
        return (self.hi - self.lo) / (2.0**self.bits)

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1))

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1

    @property
    def storage_dtype(self):
        if self.bits <= 8:
            return jnp.int8
        if self.bits <= 16:
            return jnp.int16
        return jnp.int32

    @property
    def acc_dtype(self):
        # int8 x int8 over d <= ~128k fits int32; wider codes accumulate in i32
        # on the MXU as well (TPU int matmul accumulates in 32 bit).
        return jnp.int32


def params_from_stats(
    stats: DimStats,
    bits: int = 8,
    scheme: Scheme | str = Scheme.GAUSSIAN,
    sigmas: float = 1.0,
) -> QuantParams:
    """Turn per-dimension corpus stats into Eq. 1 constants."""
    scheme = Scheme(scheme)
    if scheme == Scheme.UNIFORM_GAUSSIAN:
        stats = stats.uniform()

    if scheme in (Scheme.GAUSSIAN, Scheme.UNIFORM_GAUSSIAN):
        mu, sd = stats.mean, stats.std * sigmas
        sd = jnp.maximum(sd, 1e-12)
        lo, hi, zero = mu - sd, mu + sd, mu
    elif scheme == Scheme.ABSMAX:
        amax = jnp.maximum(stats.amax, 1e-12)
        lo, hi = -amax, amax
        zero = jnp.zeros_like(amax)
    elif scheme == Scheme.MINMAX:
        lo, hi = stats.vmin, stats.vmax
        hi = jnp.where(hi - lo < 1e-12, lo + 1e-12, hi)
        zero = (lo + hi) / 2.0
    elif scheme == Scheme.GLOBAL_ABSMAX:
        amax = jnp.maximum(jnp.max(stats.amax), 1e-12)
        full = jnp.ones_like(stats.amax)
        lo, hi = -amax * full, amax * full
        zero = jnp.zeros_like(full)
    elif scheme == Scheme.GLOBAL_MINMAX:
        gmin, gmax = jnp.min(stats.vmin), jnp.max(stats.vmax)
        gmax = jnp.where(gmax - gmin < 1e-12, gmin + 1e-12, gmax)
        full = jnp.ones_like(stats.amax)
        lo, hi = gmin * full, gmax * full
        zero = (gmin + gmax) / 2.0 * full
    else:  # pragma: no cover
        raise ValueError(f"unknown scheme {scheme}")
    return QuantParams(lo=lo, hi=hi, zero=zero, bits=bits, scheme=scheme.value)


def learn_params(
    corpus: jax.Array,
    bits: int = 8,
    scheme: Scheme | str = Scheme.GAUSSIAN,
    sigmas: float = 1.0,
    stats: Optional[DimStats] = None,
) -> QuantParams:
    """Fit Eq. 1 constants on a corpus ([N, d]) — the paper's MLE step.

    ``stats`` may be passed directly (e.g. from StreamingStats or
    distributed_stats) to skip the one-shot pass.
    """
    if stats is None:
        stats = corpus_stats(corpus)
    return params_from_stats(stats, bits=bits, scheme=scheme, sigmas=sigmas)


def quantize(x: jax.Array, params: QuantParams) -> jax.Array:
    """Eq. 1 applied elementwise over the trailing dim of ``x``.

    Returns the smallest signed integer dtype that holds B bits.
    """
    span = jnp.maximum(params.hi - params.lo, 1e-12)
    q = jnp.round((2.0**params.bits) * (x - params.zero) / span)
    # Clamp semantics of Eq. 1: below-range -> -2^(B-1); above-range -> +2^(B-1),
    # clipped to the storable max (see module docstring).
    q = jnp.clip(q, params.qmin, params.qmax)
    return q.astype(params.storage_dtype)


def dequantize(q: jax.Array, params: QuantParams) -> jax.Array:
    """Inverse linear map (midpoint reconstruction) — used only for
    diagnostics; the paper computes distances directly in Z^d."""
    return q.astype(jnp.float32) * params.scale + params.zero


def quantization_error(x: jax.Array, params: QuantParams) -> jax.Array:
    """Mean-squared reconstruction error (NOT the paper's objective — kept
    to demonstrate that order preservation, not MSE, is what drives recall)."""
    return jnp.mean((dequantize(quantize(x, params), params) - x) ** 2)


# --------------------------------------------------------------------------
# Convenience one-call API used by the index builders.
# --------------------------------------------------------------------------

def quantize_corpus(
    corpus: jax.Array,
    bits: int = 8,
    scheme: Scheme | str = Scheme.GAUSSIAN,
    sigmas: float = 1.0,
):
    """learn + apply: returns (codes, params)."""
    params = learn_params(corpus, bits=bits, scheme=scheme, sigmas=sigmas)
    return quantize(corpus, params), params
