"""int4 code packing — beyond-paper: two 4-bit codes per byte.

The paper stops at int8; Eq. 1 already supports B=4, but naive int8
storage of 4-bit codes wastes half the bytes.  Packing halves index
memory again (8x vs fp32) at the cost of an unpack shift-mask in the
scoring path (vectorizes on the VPU; on TPU the int4 MXU path of newer
generations removes even that).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pack_int4(codes: jax.Array) -> jax.Array:
    """[N, d] int8 values in [-8, 7] -> [N, d/2] uint8 (two nibbles)."""
    n, d = codes.shape
    assert d % 2 == 0, d
    u = (codes.astype(jnp.int32) + 8).astype(jnp.uint8)   # [0, 15]
    lo = u[:, 0::2]
    hi = u[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """[..., d/2] uint8 -> [..., d] int8 in [-8, 7] (any leading dims)."""
    lo = (packed & 0x0F).astype(jnp.int32) - 8
    hi = ((packed >> 4) & 0x0F).astype(jnp.int32) - 8
    half = packed.shape[-1]
    out = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], half * 2)
    return out.astype(jnp.int8)


def pack_uint4(codes: jax.Array) -> jax.Array:
    """[N, m] uint values in [0, 15] -> [N, ceil(m/2)] uint8.

    The *unsigned* sibling of :func:`pack_int4` for PQ codeword indexes
    (which address a 16-entry codebook, so they have no sign offset).
    Odd ``m`` pads one zero-code column — the ADC side pads its lookup
    tables with a zero subspace slice, so the pad contributes nothing.
    """
    n, m = codes.shape
    u = codes.astype(jnp.uint8)
    if m % 2:
        u = jnp.pad(u, ((0, 0), (0, 1)))
    lo = u[:, 0::2]
    hi = u[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_uint4(packed: jax.Array) -> jax.Array:
    """[N, ceil(m/2)] uint8 -> [N, 2*ceil(m/2)] uint8 in [0, 15].

    Returns the padded even width; callers slice back to the logical
    ``m`` when it was odd.
    """
    lo = (packed & 0x0F).astype(jnp.uint8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.uint8)
    half = packed.shape[-1]
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], half * 2)


def qip_scores_packed(q_codes: jax.Array, packed: jax.Array) -> jax.Array:
    """int4 MIP scores: unpack-in-flight + int32 dot, [Q, N]."""
    x = unpack_int4(packed)
    return jax.lax.dot_general(
        q_codes, x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
