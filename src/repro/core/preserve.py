"""Empirical validators for Definition 2 (partial distance preservation).

The paper's correctness claim is *not* low reconstruction error; it is:

    if d1(a, q) < d1(b, q)  then  d2(Q(a), h(q)) <= d2(Q(b), h(q))

We validate this directly: sample (a, b, q) triples, evaluate both the
original and the quantized metric, and measure the fraction of strict
orderings that survive (ties in the quantized domain are allowed — that is
the "equality relaxation" the paper attributes recall loss to).

Also provides recall@k, the paper's §5.3 quality metric.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import distances as D
from repro.core import quant as Qz


def order_agreement(
    corpus: jax.Array,
    queries: jax.Array,
    params: Qz.QuantParams,
    metric: str,
    n_triples: int = 4096,
    key: jax.Array | None = None,
    margin_quantile: float = 0.0,
) -> jax.Array:
    """Fraction of sampled (a,b,q) triples whose strict order is preserved.

    ``margin_quantile`` > 0 restricts to triples whose original distance gap
    exceeds that quantile of gaps — the paper's point is that *near*
    neighbors are preserved while far-apart aliasing is acceptable, so
    agreement should rise with the margin.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n = corpus.shape[0]
    nq = queries.shape[0]
    ka, kb, kq = jax.random.split(key, 3)
    ia = jax.random.randint(ka, (n_triples,), 0, n)
    ib = jax.random.randint(kb, (n_triples,), 0, n)
    iq = jax.random.randint(kq, (n_triples,), 0, nq)

    a, b, q = corpus[ia], corpus[ib], queries[iq]
    qa, qb = Qz.quantize(a, params), Qz.quantize(b, params)
    qq = Qz.quantize(q, params)

    # larger-is-closer scores, one triple at a time via the batched API
    s_a = jax.vmap(lambda u, v: D.scores(u[None], v[None], metric)[0, 0])(q, a)
    s_b = jax.vmap(lambda u, v: D.scores(u[None], v[None], metric)[0, 0])(q, b)
    t_a = jax.vmap(lambda u, v: D.scores(u[None], v[None], metric, quantized=True)[0, 0])(qq, qa)
    t_b = jax.vmap(lambda u, v: D.scores(u[None], v[None], metric, quantized=True)[0, 0])(qq, qb)

    gap = jnp.abs(s_a - s_b)
    strict = gap > 0
    if margin_quantile > 0.0:
        thresh = jnp.quantile(gap, margin_quantile)
        strict = strict & (gap >= thresh)

    # Definition 2: original strict order must map to <= (ties allowed).
    ok = jnp.where(
        s_a > s_b,
        t_a >= t_b,
        jnp.where(s_b > s_a, t_b >= t_a, True),
    )
    return jnp.sum(ok & strict) / jnp.maximum(jnp.sum(strict), 1)


def recall_at_k(exact_ids: jax.Array, approx_ids: jax.Array) -> jax.Array:
    """Paper §5.3: |S_E ∩ S_A| / |S_E| averaged over queries.

    Both inputs are [Q, k] integer id arrays.
    """
    hits = (exact_ids[:, :, None] == approx_ids[:, None, :]).any(-1)
    return jnp.mean(jnp.sum(hits, axis=-1) / exact_ids.shape[1])


def knn_recall(
    corpus: jax.Array,
    queries: jax.Array,
    params: Qz.QuantParams,
    metric: str,
    k: int = 100,
) -> jax.Array:
    """End-to-end exact-scan recall: fp32 top-k vs quantized top-k.

    This is exactly the paper's Table 2 protocol (FAISS exhaustive search,
    fp32 vs int8) on whatever corpus is passed in.
    """
    s_fp = D.scores(queries, corpus, metric)
    ids_fp = jax.lax.top_k(s_fp, k)[1]
    codes = Qz.quantize(corpus, params)
    qcodes = Qz.quantize(queries, params)
    s_q = D.scores(qcodes, codes, metric, quantized=True)
    ids_q = jax.lax.top_k(s_q, k)[1]
    return recall_at_k(ids_fp, ids_q)
